"""Chaos regression tests: site loss inside the two-timescale controller.

The invariants pinned here are the contract of the controller's fault path
(`simulate_placed(..., alive=mask)`):

* an all-ones mask is bit-exact with the no-fault path, on every policy
  path (state-dependent GMSA, precomputed-key RANDOM/DATA) and both rules;
* once a site dies it receives zero dispatch mass and serves nothing;
* its backlog is conserved — re-injected as an arrival burst, not dropped;
* ``recovery_cost`` fires exactly on death edges (and only bills when
  there is data to evacuate);
* revival hands the site back to the regular slow loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.fault import drop_site, drop_site_mask
from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import (
    data_dispatch,
    random_dispatch,
    static_placement_rule,
)
from repro.core.gmsa import dispatch_fn
from repro.core.iridium import build_task_allocation
from repro.core.simulator import SimInputs
from repro.placement import (
    PlacementConfig,
    evacuation_plan,
    make_adaptive_rule,
    simulate_placed,
    simulate_placed_many,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.faults import (
    failure_edges,
    scheduled_failure_trace,
    site_failure_trace,
)


@pytest.fixture(scope="module")
def paper_setup():
    cfg = PaperSimConfig()
    template, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)
    return cfg, template, build, up, down


def _pcfg(cfg, **kw):
    return PlacementConfig(
        epoch_slots=kw.pop("epoch_slots", 48),
        manager_share=cfg.manager_share, map_share=cfg.map_share, **kw
    )


# ---------------------------------------------------------------------------
# Bit-exactness of the all-alive fault path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [
    pytest.param(dispatch_fn(1.0), id="gmsa"),
    pytest.param(random_dispatch, id="random"),
    pytest.param(data_dispatch, id="data"),
])
@pytest.mark.parametrize("rule_name", ["static", "adaptive"])
def test_all_alive_mask_bit_exact(paper_setup, policy, rule_name):
    """alive=ones reproduces the no-fault outputs bit for bit — every
    masking op in the fault path is an exact identity or an edge select."""
    cfg, template, _, up, down = paper_setup
    rule = (static_placement_rule if rule_name == "static"
            else make_adaptive_rule(up))
    key = jax.random.key(21)
    pcfg = _pcfg(cfg)
    ones = jnp.ones((cfg.t_slots, cfg.n_sites), jnp.float32)
    o0 = simulate_placed(template, up, down, policy, rule, key, pcfg)
    o1 = simulate_placed(template, up, down, policy, rule, key, pcfg,
                         alive=ones)
    for field in o0._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(o0, field)), np.asarray(getattr(o1, field)),
            err_msg=field,
        )
    assert float(o1.recovery_cost.sum()) == 0.0
    assert float(o1.recovery_gb.sum()) == 0.0


# ---------------------------------------------------------------------------
# Site death mid-epoch
# ---------------------------------------------------------------------------

def test_dead_site_gets_no_dispatch_and_serves_nothing(paper_setup):
    cfg, template, _, up, down = paper_setup
    dead, t_die = 1, 100                                  # mid-epoch (W=48)
    mask = scheduled_failure_trace(
        cfg.t_slots, cfg.n_sites, [(dead, t_die, None)]
    )
    # RANDOM dispatches everywhere while a site is alive, so the zero after
    # the death edge is unambiguously the controller's masking at work.
    outs = simulate_placed(
        template, up, down, random_dispatch, make_adaptive_rule(up),
        jax.random.key(3), _pcfg(cfg), alive=mask,
    )
    f = np.asarray(outs.f_trace)
    assert float(np.abs(f[t_die:, dead, :]).max()) == 0.0
    assert float(np.abs(f[:t_die, dead, :]).max()) > 0.0   # alive before
    # Columns still dispatch all arrival mass (renormalized to survivors).
    np.testing.assert_allclose(f[t_die:].sum(axis=1), 1.0, atol=1e-5)
    # The dead site's queue is wiped and stays empty.
    assert float(np.asarray(outs.q_final)[dead].sum()) == 0.0
    # Later epochs place no data there.
    placements = np.asarray(outs.placements)              # (E, K, N)
    assert float(placements[3:, :, dead].max()) == 0.0


def test_backlog_conserved_through_reinjection():
    """With mu = 0 and arrivals only in the first slots, total backlog is an
    invariant — the dead site's queue must re-enter through the burst, not
    vanish."""
    n, k, t = 3, 2, 12
    up = down = jnp.ones((n,))
    d = jnp.array([[0.5, 0.3, 0.2], [0.2, 0.5, 0.3]], jnp.float32)
    arrivals = jnp.zeros((t, k), jnp.float32).at[0].set(
        jnp.array([4.0, 2.0])).at[1].set(jnp.array([1.0, 3.0]))
    inputs = SimInputs(
        arrivals=arrivals,
        mu=jnp.zeros((t, n, k), jnp.float32),
        omega=jnp.ones((t, n), jnp.float32),
        pue=jnp.ones((t, n), jnp.float32),
        r=build_task_allocation(d, up, down),
        p_it=jnp.ones((k,), jnp.float32),
        data_dist=d,
    )
    dead, t_die = 1, 8                                    # mid-epoch (W=6)
    mask = scheduled_failure_trace(t, n, [(dead, t_die, None)])
    outs = simulate_placed(
        inputs, up, down, data_dispatch, static_placement_rule,
        jax.random.key(0), PlacementConfig(epoch_slots=6), alive=mask,
    )
    btot = np.asarray(outs.backlog_total)
    total = float(arrivals.sum())
    np.testing.assert_allclose(btot[1:], total, rtol=1e-5)
    # Across the death edge in particular: nothing lost, nothing invented.
    np.testing.assert_allclose(btot[t_die], btot[t_die - 1], rtol=1e-5)
    q_final = np.asarray(outs.q_final)
    assert float(q_final[dead].sum()) == 0.0
    np.testing.assert_allclose(q_final.sum(), total, rtol=1e-5)
    # The burst was re-dispatched to survivors in the death slot.
    f = np.asarray(outs.f_trace)
    assert float(np.abs(f[t_die:, dead, :]).max()) == 0.0


def test_recovery_cost_fires_exactly_on_failure(paper_setup):
    """recovery_cost > 0 at the death edge (the initial layout spreads data
    on every site, so there is always something to evacuate) and is zero on
    every other slot; the all-alive run bills nothing."""
    cfg, template, _, up, down = paper_setup
    t_die = 77
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(2, t_die, None)])
    assert float(template.data_dist[:, 2].min()) > 0.01   # data to evacuate
    outs = simulate_placed(
        template, up, down, dispatch_fn(1.0), static_placement_rule,
        jax.random.key(5), _pcfg(cfg), alive=mask,
    )
    rc = np.asarray(outs.recovery_cost)
    rgb = np.asarray(outs.recovery_gb)
    assert rc[t_die] > 0.0 and rgb[t_die] > 0.0
    assert float(np.abs(np.delete(rc, t_die)).max()) == 0.0
    assert float(np.abs(np.delete(rgb, t_die)).max()) == 0.0
    # Static rule: the evacuation is pure re-replication of the lost share.
    lost_gb = float(
        (template.data_dist[:, 2] * jnp.asarray(cfg.k_types * [100.0])).sum()
    )
    assert rgb[t_die] == pytest.approx(lost_gb, rel=0.05)


def test_revived_site_rejoins_the_slow_loop(paper_setup):
    """Death then repair: no dispatch while down, and the adaptive slow loop
    is free to re-place data on the revived site afterwards."""
    cfg, template, _, up, down = paper_setup
    dead, t_die, t_up = 0, 60, 120
    mask = scheduled_failure_trace(
        cfg.t_slots, cfg.n_sites, [(dead, t_die, t_up)]
    )
    outs = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        jax.random.key(9), _pcfg(cfg), alive=mask,
    )
    f = np.asarray(outs.f_trace)
    assert float(np.abs(f[t_die:t_up, dead, :]).max()) == 0.0
    assert float(np.abs(f[t_up:, dead, :]).max()) > 0.0
    rc = np.asarray(outs.recovery_cost)
    assert rc[t_die] > 0.0
    assert float(np.abs(np.delete(rc, t_die)).max()) == 0.0  # revival is free


def test_vmapped_fault_path_runs(paper_setup):
    """simulate_placed_many shares the alive mask across Monte-Carlo runs
    (lax.cond lowers to select under vmap — the fault path must survive it)."""
    cfg, template, build, up, down = paper_setup
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(1, 100, None)])
    outs = simulate_placed_many(
        build, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        jax.random.key(1), 4, _pcfg(cfg), alive=mask,
    )
    assert outs.cost.shape == (4, cfg.t_slots)
    f = np.asarray(outs.f_trace)
    assert float(np.abs(f[:, 100:, 1, :]).max()) == 0.0
    assert (np.asarray(outs.recovery_cost)[:, 100] > 0.0).all()


# ---------------------------------------------------------------------------
# Fault-layer primitives
# ---------------------------------------------------------------------------

def test_drop_site_mask_matches_drop_site():
    """The static-shape mask variant agrees with the shape-changing
    original on the surviving coordinates."""
    key = jax.random.key(4)
    q = jax.random.uniform(key, (4, 2)) * 10
    d = jax.random.dirichlet(key, jnp.full((4,), 2.0), (2,))
    r = build_task_allocation(d, jnp.ones(4), jnp.ones(4))
    dead = 2
    alive = jnp.ones((4,)).at[dead].set(0.0)
    q_ref, _, d_ref, burst_ref = [
        np.asarray(x) for x in drop_site(q, r, d, dead)
    ]
    q2, d_masked, d_drop, burst = drop_site_mask(q, d, alive)
    keep = [0, 1, 3]
    np.testing.assert_allclose(np.asarray(q2)[keep], q_ref, rtol=1e-6)
    assert float(np.asarray(q2)[dead].sum()) == 0.0
    np.testing.assert_allclose(np.asarray(d_drop)[:, keep], d_ref, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(burst), burst_ref, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(d_masked), np.asarray(d) * np.asarray(alive)[None, :]
    )


def test_evacuation_plan_restores_coverage():
    d = jnp.array([[0.5, 0.3, 0.2]])
    alive = jnp.array([1.0, 0.0, 1.0])
    sizes = jnp.array([100.0])
    _, d_masked, d_drop, _ = drop_site_mask(jnp.zeros((3, 1)), d, alive)
    plan = evacuation_plan(d_masked, d_drop, sizes)              # (K, N, N)
    plan_np = np.asarray(plan)
    # Received bytes close exactly the holding gap; dead site neither sends
    # nor receives; nothing self-transfers.
    np.testing.assert_allclose(
        plan_np.sum(1), np.asarray((d_drop - d_masked) * sizes[:, None]),
        atol=1e-4,
    )
    assert plan_np[:, 1, :].sum() == 0.0 and plan_np[:, :, 1].sum() == 0.0
    assert float(np.trace(plan_np[0])) == 0.0
    assert (plan_np >= 0).all()


def test_site_failure_trace_is_seeded_and_respects_min_alive():
    key = jax.random.key(123)
    a = site_failure_trace(key, 500, 4, failure_prob=0.02, repair_slots=30)
    b = site_failure_trace(key, 500, 4, failure_prob=0.02, repair_slots=30)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert set(np.unique(np.asarray(a))) <= {0.0, 1.0}
    assert float(np.asarray(a).sum(1).min()) >= 1.0          # min_alive
    c = site_failure_trace(jax.random.key(7), 500, 4,
                           failure_prob=0.05, min_alive=3)
    assert float(np.asarray(c).sum(1).min()) >= 3.0
    # Something actually dies at these rates.
    assert float(np.asarray(a).min()) == 0.0
    # Permanent failures never revive.
    p = np.asarray(site_failure_trace(jax.random.key(9), 500, 4,
                                      failure_prob=0.02, repair_slots=None))
    assert (np.diff(p, axis=0) <= 0.0).all()


def test_failure_edges_mark_deaths_only():
    mask = scheduled_failure_trace(10, 2, [(0, 3, 7)])
    edges = np.asarray(failure_edges(mask))
    expected = np.zeros((10, 2), np.float32)
    expected[3, 0] = 1.0                     # death, not the revival at 7
    np.testing.assert_array_equal(edges, expected)
    # A trace that starts dead fires its edge at t=0.
    m0 = scheduled_failure_trace(4, 2, [(1, 0, None)])
    assert failure_edges(m0)[0, 1] == 1.0


@pytest.mark.slow
@pytest.mark.parametrize("trace_seed", [0, 1, 2, 3, 4])
def test_chaos_sweep_random_outages(paper_setup, trace_seed):
    """Nightly chaos sweep: random seeded outage schedules (with repair)
    must uphold every fault invariant at once — no dispatch to dead sites,
    recovery billed only on death edges, placements on the simplex, queues
    finite and non-negative."""
    cfg, template, _, up, down = paper_setup
    mask = site_failure_trace(
        jax.random.key(trace_seed), cfg.t_slots, cfg.n_sites,
        failure_prob=0.01, repair_slots=60,
    )
    outs = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        jax.random.key(trace_seed + 100), _pcfg(cfg), alive=mask,
    )
    m = np.asarray(mask)
    f = np.asarray(outs.f_trace)
    assert float((f * (1 - m)[:, :, None]).max()) == 0.0
    np.testing.assert_allclose(f.sum(1), 1.0, atol=1e-4)
    rc = np.asarray(outs.recovery_cost)
    edges = np.asarray(failure_edges(mask)).max(axis=1)       # (T,)
    assert (rc >= 0).all()
    assert float(rc[edges == 0].max(initial=0.0)) == 0.0      # only on edges
    if edges.any():
        assert rc[edges == 1].sum() >= 0.0
    placements = np.asarray(outs.placements)
    np.testing.assert_allclose(placements.sum(-1), 1.0, atol=1e-4)
    assert (placements >= -1e-6).all()
    btot = np.asarray(outs.backlog_total)
    assert np.isfinite(btot).all() and (btot >= 0).all()
    assert np.isfinite(np.asarray(outs.cost)).all()


def test_ingest_aimed_at_dead_site_redirects_to_survivors(paper_setup):
    """Fresh data cannot land at a dead site: an ingest trace one-hot on
    the dead site spreads uniformly over the survivors instead of silently
    vanishing (the drifted layout must still absorb cfg.growth mass)."""
    cfg, template, _, up, down = paper_setup
    dead = 1
    n_epochs = cfg.t_slots // 48
    one_hot_dead = jnp.zeros((n_epochs, cfg.k_types, cfg.n_sites),
                             jnp.float32).at[:, :, dead].set(1.0)
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(dead, 10, None)])
    pcfg = _pcfg(cfg, growth=0.4)
    outs = simulate_placed(
        template, up, down, data_dispatch, static_placement_rule,
        jax.random.key(2), pcfg, ingest=one_hot_dead, alive=mask,
    )
    placements = np.asarray(outs.placements)                  # (E, K, N)
    np.testing.assert_allclose(placements.sum(-1), 1.0, atol=1e-4)
    assert float(np.abs(placements[1:, :, dead]).max()) == 0.0
    # The redirected ingest visibly pulls later layouts toward uniform over
    # the survivors (static rule never corrects it back).
    survivors = [i for i in range(cfg.n_sites) if i != dead]
    gap0 = np.abs(placements[1][:, survivors] - 1 / 3).max()
    gap_last = np.abs(placements[-1][:, survivors] - 1 / 3).max()
    assert gap_last < gap0


# ---------------------------------------------------------------------------
# io_coupling across a death edge (the stale-epoch-scale fix)
# ---------------------------------------------------------------------------

def test_all_alive_mask_bit_exact_with_io_coupling(paper_setup):
    """The io_coupling fault path keeps the all-ones identity: the per-slot
    mu re-derivation is cond-gated on the death edge, so alive=ones never
    enters it."""
    cfg, template, _, up, down = paper_setup
    pcfg = _pcfg(cfg, io_coupling=True)
    key = jax.random.key(21)
    ones = jnp.ones((cfg.t_slots, cfg.n_sites), jnp.float32)
    o0 = simulate_placed(template, up, down, dispatch_fn(1.0),
                         make_adaptive_rule(up), key, pcfg)
    o1 = simulate_placed(template, up, down, dispatch_fn(1.0),
                         make_adaptive_rule(up), key, pcfg, alive=ones)
    for field in o0._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(o0, field)), np.asarray(getattr(o1, field)),
            err_msg=field,
        )


def test_io_coupling_rescales_inside_recovery_epoch(paper_setup):
    """A mid-epoch death re-derives the I/O service scale from the recovery
    layout per slot — not the stale epoch value.

    Single epoch (W = T), static rule, move_budget = 0: the post-edge
    layout is exactly the survivor-renormalized initial layout, so the
    coupled faulted run must match an UNcoupled faulted run whose mu trace
    is hand-scaled by that layout's slowdown ratio from the edge onward.
    The epoch-0 scale is exactly 1.0, so pre-edge slots agree bitwise.
    """
    from repro.traces.datasets import io_slowdown_from_bandwidth

    cfg, template, _, up, down = paper_setup
    dead, t_die = 1, 100
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites,
                                   [(dead, t_die, None)])
    pcfg = _pcfg(cfg, epoch_slots=cfg.t_slots, io_coupling=True,
                 move_budget=0.0)
    pcfg_off = _pcfg(cfg, epoch_slots=cfg.t_slots, io_coupling=False,
                     move_budget=0.0)
    pol = dispatch_fn(1.0)
    key = jax.random.key(13)

    coupled = simulate_placed(template, up, down, pol,
                              static_placement_rule, key, pcfg, alive=mask)

    # The recovery layout: survivors renormalized, nothing re-placed.
    alive_v = jnp.asarray(mask[t_die])
    masked = template.data_dist * alive_v[None, :]
    d_drop = masked / jnp.sum(masked, axis=1, keepdims=True)
    slow0 = io_slowdown_from_bandwidth(
        up, down, template.data_dist, pcfg.io_compute_seconds, pcfg.io_job_gb
    )
    scale = io_slowdown_from_bandwidth(
        up, down, d_drop, pcfg.io_compute_seconds, pcfg.io_job_gb
    ) / slow0                                                  # (N,)
    assert not np.allclose(np.asarray(scale), 1.0), (
        "evacuation must change the survivors' I/O slowdown for this "
        "scenario to pin anything"
    )
    mu_hand = template.mu.at[t_die:].set(
        template.mu[t_die:] * scale[None, :, None]
    )
    reference = simulate_placed(
        template._replace(mu=mu_hand), up, down, pol,
        static_placement_rule, key, pcfg_off, alive=mask,
    )
    np.testing.assert_allclose(np.asarray(coupled.cost),
                               np.asarray(reference.cost), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(coupled.backlog_total),
                               np.asarray(reference.backlog_total),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(coupled.q_final),
                               np.asarray(reference.q_final),
                               rtol=1e-5, atol=1e-3)

    # And the fix is live: the stale-scale behaviour (uncoupled mu after
    # the edge) visibly diverges from the coupled run.
    stale = simulate_placed(template, up, down, pol,
                            static_placement_rule, key, pcfg_off, alive=mask)
    assert not np.allclose(np.asarray(coupled.backlog_total)[t_die:],
                           np.asarray(stale.backlog_total)[t_die:],
                           rtol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(coupled.cost)[:t_die], np.asarray(stale.cost)[:t_die]
    )
