"""Degraded-mode fleet: stragglers, speculation, link faults (PR 9).

The load-bearing contracts, in test form:

* **bitwise identity** — all-ones health and all-alive links are bitwise
  identical to the pre-degraded-mode paths on every engine (and a
  ``health=None`` call traces to the byte-identical jaxpr);
* **trace validation** — malformed fault schedules (negative starts,
  empty windows, factors outside [0, 1], self-links, regions without an
  alive mask) raise instead of silently no-opping;
* **conservation properties** (18 hand-driven seeds) — hedging never
  loses or double-counts completed jobs, and the evacuation planner
  conserves GB even when links are severed;
* **the speculation pin** — on the calibrated straggler scenario,
  hedged re-execution cuts serve sojourn p99 by >= 20% at <= 10%
  duplicated-compute overhead, and the hedged run still replays
  ``simulate_staged`` on the shared scenario;
* **flight-recorder pairing** — a revival lands an EV_REPAIR event and
  the recovery event's SLO clock measures from the true revival slot.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.gmsa import dispatch_fn
from repro.core.simulator import SimInputs, simulate
from repro.jobs import (
    make_staged_policy,
    pad_chains,
    simulate_staged,
    summarize_staged,
)
from repro.launch.serve import build_engine
from repro.placement import (
    PlacementConfig,
    make_adaptive_rule,
    simulate_placed,
    wan_topology,
)
from repro.placement.controller import region_averse_weights
from repro.placement.wan import (
    degraded_surcharge,
    evacuation_plan,
    transfer_cost,
)
from repro.serve.engine import FleetConfig
from repro.telemetry import (
    TRACE,
    TelemetryConfig,
    collect_records,
    hedge_events,
    link_down_events,
    ring_events,
    straggler_spans,
)
from repro.telemetry.metrics import fifo_sojourn_replay, weighted_percentile
from repro.traces.bandwidth import (
    bandwidth_draw,
    link_fault_trace,
    scheduled_link_fault_trace,
)
from repro.traces.faults import (
    compose_health,
    failure_edges,
    health_to_alive,
    health_trace,
    region_assignment,
    regional_health_trace,
    repair_edges,
    scheduled_failure_trace,
    scheduled_health_trace,
    site_failure_trace,
)

SEEDS = list(range(18))
# One fixed shape across all seeds so the property loop compiles once.
T, N, K, S = 10, 4, 2, 3


def _random_case(seed):
    """A small random staged scenario (deterministic in seed)."""
    rng = np.random.default_rng(seed)
    arrivals = jnp.asarray(rng.integers(0, 20, (T, K)), jnp.float32)
    mu = jnp.asarray(rng.uniform(1.0, 30.0, (T, N, K)), jnp.float32)
    omega = jnp.asarray(rng.uniform(10.0, 60.0, (T, N)), jnp.float32)
    pue = jnp.asarray(rng.uniform(1.0, 1.3, (T, N)), jnp.float32)
    dd = jnp.asarray(rng.dirichlet(np.ones(N), K), jnp.float32)
    r = jnp.asarray(rng.dirichlet(np.ones(N), (K, N)), jnp.float32)
    p_it = jnp.asarray(rng.uniform(0.5, 2.0, (K,)), jnp.float32)
    inputs = SimInputs(arrivals, mu, omega, pue, r, p_it, dd)
    computes = [list(rng.uniform(0.2, 1.0, S)) for _ in range(K)]
    shuffles = [[0.0] + list(rng.uniform(0.0, 40.0, S - 1)) for _ in range(K)]
    dag = pad_chains(computes, shuffles)
    up = jnp.asarray(rng.uniform(0.2, 2.0, (N,)), jnp.float32)
    down = jnp.asarray(rng.uniform(0.2, 2.0, (N,)), jnp.float32)
    return inputs, dag, wan_topology(up, down, energy_per_gb=0.03)


def _random_health(seed):
    """A (T, N) health trace with stragglers but no full deaths."""
    rng = np.random.default_rng(1000 + seed)
    health = np.ones((T, N), np.float32)
    for site in rng.choice(N, size=2, replace=False):
        start = int(rng.integers(0, T - 2))
        health[start:, site] = rng.uniform(0.05, 0.6)
    return jnp.asarray(health)


@pytest.fixture(scope="module")
def fb_setup():
    cfg = dataclasses.replace(PaperSimConfig(), t_slots=96)
    template, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)
    return cfg, template, up, down


# ---------------------------------------------------------------------------
# The bitwise-identity contract
# ---------------------------------------------------------------------------

def _assert_fields_equal(a, b):
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=name)


def test_simulate_ones_health_bitwise(fb_setup):
    cfg, template, _, _ = fb_setup
    key = jax.random.key(0)
    pol = dispatch_fn(1.0)
    bare = simulate(template, pol, key)
    ones = simulate(template, pol, key,
                    health=jnp.ones((cfg.t_slots, cfg.n_sites)))
    _assert_fields_equal(bare, ones)


def test_simulate_staged_ones_bitwise(fb_setup):
    cfg, template, up, down = fb_setup
    inputs, dag, wan = _random_case(0)
    key = jax.random.key(1)
    pol = make_staged_policy(dag, wan)
    bare = simulate_staged(inputs, dag, wan, pol, key, scalar=5.0)
    ones = simulate_staged(
        inputs, dag, wan, pol, key, scalar=5.0,
        health=jnp.ones((T, N)), link_health=jnp.ones((T, N, N)),
    )
    _assert_fields_equal(bare, ones)
    # The hedge columns of a hedge-free run are exactly zero.
    assert float(jnp.sum(bare.hedge_cost)) == 0.0
    assert float(jnp.sum(bare.hedged_jobs)) == 0.0


def test_simulate_staged_health_none_jaxpr_identical():
    inputs, dag, wan = _random_case(0)
    pol = make_staged_policy(dag, wan)

    def bare(i, k):
        return simulate_staged(i, dag, wan, pol, k)

    def none(i, k):
        return simulate_staged(i, dag, wan, pol, k,
                               health=None, link_health=None)

    key = jax.random.key(0)
    assert (str(jax.make_jaxpr(bare)(inputs, key))
            == str(jax.make_jaxpr(none)(inputs, key)))


def test_simulate_placed_ones_bitwise(fb_setup):
    cfg, template, up, down = fb_setup
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    pol, rule = dispatch_fn(1.0), make_adaptive_rule(up)
    key = jax.random.key(3)
    bare = simulate_placed(template, up, down, pol, rule, key, pcfg)
    ones = simulate_placed(
        template, up, down, pol, rule, key, pcfg,
        health=jnp.ones((cfg.t_slots, cfg.n_sites)),
        link_health=jnp.ones((cfg.t_slots, cfg.n_sites, cfg.n_sites)),
    )
    _assert_fields_equal(bare, ones)


def test_simulate_placed_regions_all_alive_bitwise(fb_setup):
    cfg, template, up, down = fb_setup
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    pol, rule = dispatch_fn(1.0), make_adaptive_rule(up)
    key = jax.random.key(3)
    alive = jnp.ones((cfg.t_slots, cfg.n_sites))
    plain = simulate_placed(template, up, down, pol, rule, key, pcfg,
                            alive=alive)
    regional = simulate_placed(
        template, up, down, pol, rule, key, pcfg, alive=alive,
        regions=region_assignment(cfg.n_sites, 2),
    )
    _assert_fields_equal(plain, regional)


def test_fleet_ones_bitwise():
    classes = ["qwen2-0.5b", "mamba2-2.7b"]
    common = dict(slots=12, v=1.0, seed=3, arrival=4.0, admit_max=5.0)
    bare = build_engine(classes, **common).run(execute_real=False)
    ones = build_engine(
        classes, health=np.ones((12, 4), np.float32),
        link_health=np.ones((12, 4, 4), np.float32), **common,
    ).run(execute_real=False)
    for name in ("dispatch", "cost", "wan_cost", "wan_gb", "q_final",
                 "admitted", "completed", "backlog"):
        np.testing.assert_array_equal(bare[name], ones[name], err_msg=name)
    assert bare["total_billed_cost"] == ones["total_billed_cost"]


# ---------------------------------------------------------------------------
# Trace generators: validation and structure
# ---------------------------------------------------------------------------

def test_scheduled_failure_trace_rejects_bad_windows():
    with pytest.raises(ValueError, match="down_at=-1"):
        scheduled_failure_trace(10, 3, [(0, -1, 5)])
    with pytest.raises(ValueError, match="up_at=2"):
        scheduled_failure_trace(10, 3, [(0, 5, 2)])
    with pytest.raises(ValueError, match="up_at=5"):
        scheduled_failure_trace(10, 3, [(0, 5, 5)])
    with pytest.raises(ValueError, match="site 3"):
        scheduled_failure_trace(10, 3, [(3, 0, None)])


def test_scheduled_health_trace_validation_and_min_compose():
    with pytest.raises(ValueError, match="factor=1.5"):
        scheduled_health_trace(10, 3, [(0, 0, 5, 1.5)])
    with pytest.raises(ValueError, match="start=-2"):
        scheduled_health_trace(10, 3, [(0, -2, 5, 0.5)])
    h = scheduled_health_trace(10, 3, [(1, 2, 8, 0.5), (1, 4, 6, 0.2)])
    assert float(h[3, 1]) == 0.5 and float(h[5, 1]) == pytest.approx(0.2)
    assert float(h[9, 1]) == 1.0


def test_scheduled_link_fault_trace_validation():
    with pytest.raises(ValueError, match="self-link"):
        scheduled_link_fault_trace(10, 3, [(1, 1, 0, 5, 0.0)])
    lh = scheduled_link_fault_trace(10, 3, [(0, 2, 2, 6, 0.0)])
    assert float(lh[3, 0, 2]) == 0.0 and float(lh[3, 2, 0]) == 0.0
    asym = scheduled_link_fault_trace(10, 3, [(0, 2, 2, 6, 0.0)],
                                      symmetric=False)
    assert float(asym[3, 2, 0]) == 1.0


def test_markov_generators_seeded_and_bounded():
    key = jax.random.key(7)
    h = health_trace(key, 64, 4, straggle_prob=0.1, death_prob=0.3)
    assert h.shape == (64, 4)
    assert bool(jnp.all((h >= 0.0) & (h <= 1.0)))
    np.testing.assert_array_equal(
        np.asarray(h), np.asarray(health_trace(key, 64, 4,
                                               straggle_prob=0.1,
                                               death_prob=0.3)))
    regions = region_assignment(4, 2)
    np.testing.assert_array_equal(np.asarray(regions), [0, 0, 1, 1])
    rh = regional_health_trace(key, 64, regions, outage_prob=0.1)
    # Shared fate: both sites of a region always carry the same factor.
    np.testing.assert_array_equal(np.asarray(rh[:, 0]), np.asarray(rh[:, 1]))
    composed = compose_health(h, rh)
    assert bool(jnp.all(composed <= h + 1e-9))
    alive = health_to_alive(composed)
    assert set(np.unique(np.asarray(alive))) <= {0.0, 1.0}
    lh = link_fault_trace(key, 32, 4, degrade_prob=0.2)
    assert lh.shape == (32, 4, 4)
    np.testing.assert_array_equal(
        np.asarray(lh[:, np.arange(4), np.arange(4)]), 1.0)


def test_repair_edges_pairs_with_failure_edges():
    alive = scheduled_failure_trace(12, 3, [(1, 3, 7)])
    down = failure_edges(alive)
    up = repair_edges(alive)
    assert float(down[3, 1]) == 1.0 and float(down.sum()) == 1.0
    assert float(up[7, 1]) == 1.0 and float(up.sum()) == 1.0
    # An all-alive fleet has no edges of either kind; a trace can never
    # open with a revival (slot 0 compares against all-alive).
    ones = jnp.ones((12, 3))
    assert float(failure_edges(ones).sum()) == 0.0
    assert float(repair_edges(ones).sum()) == 0.0
    permanent = scheduled_failure_trace(12, 3, [(0, 2, None)])
    assert float(repair_edges(permanent).sum()) == 0.0


def test_engine_rejects_malformed_degraded_inputs(fb_setup):
    cfg, template, up, down = fb_setup
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    pol, rule = dispatch_fn(1.0), make_adaptive_rule(up)
    key = jax.random.key(0)
    with pytest.raises(ValueError):
        simulate_placed(template, up, down, pol, rule, key, pcfg,
                        health=jnp.ones((3, cfg.n_sites)))
    with pytest.raises(ValueError):
        simulate_placed(template, up, down, pol, rule, key, pcfg,
                        regions=region_assignment(cfg.n_sites, 2))
    with pytest.raises(ValueError):
        FleetConfig(n_pods=4, horizon_slots=8, hedge_threshold=0.5,
                    dispatch="kernel")
    with pytest.raises(ValueError):
        FleetConfig(n_pods=4, horizon_slots=8, hedge_threshold=-0.1)


# ---------------------------------------------------------------------------
# Degraded links: pricing, routing, surcharge identity
# ---------------------------------------------------------------------------

def test_degraded_links_price_up_and_severed_price_inf():
    inputs, dag, wan = _random_case(3)
    om, pu = inputs.omega[0], inputs.pue[0]
    rng = np.random.default_rng(3)
    plan = jnp.asarray(rng.uniform(0.0, 5.0, (K, N, N)), jnp.float32)
    plan = plan * (1.0 - jnp.eye(N))
    nominal, _, _ = transfer_cost(plan, wan, om, pu)
    lh = jnp.full((N, N), 0.5).at[jnp.arange(N), jnp.arange(N)].set(1.0)
    degraded, _, _ = transfer_cost(plan, wan, om, pu, link_health=lh)
    assert float(degraded) > float(nominal)
    severed, _, _ = transfer_cost(plan, wan, om, pu,
                                  link_health=jnp.zeros((N, N)))
    assert np.isinf(float(severed))
    # The surcharge form of the same bill is exactly zero at all-ones.
    d_old = jnp.asarray(rng.dirichlet(np.ones(N), K), jnp.float32)
    d_new = jnp.asarray(rng.dirichlet(np.ones(N), K), jnp.float32)
    sizes = jnp.asarray(rng.uniform(1.0, 50.0, K), jnp.float32)
    sur_c, sur_e = degraded_surcharge(d_old, d_new, sizes, wan, om, pu,
                                      jnp.ones((N, N)))
    assert float(sur_c) == 0.0 and float(sur_e) == 0.0


def test_evacuation_plan_routes_around_severed_links():
    d_masked = jnp.asarray([[0.5, 0.0, 0.3, 0.0]])
    d_drop = jnp.asarray([[0.5, 0.0, 0.3, 0.2]])
    sizes = jnp.asarray([10.0])
    lh = jnp.ones((4, 4)).at[0, 3].set(0.0)       # site 0 cannot reach 3
    plan = evacuation_plan(d_masked, d_drop, sizes, link_health=lh)
    assert float(plan[0, 0, 3]) == 0.0            # routed around
    assert float(plan[0, 2, 3]) == pytest.approx(2.0)   # all via site 2
    np.testing.assert_allclose(np.asarray(plan.sum(axis=1)[0]),
                               [0.0, 0.0, 0.0, 2.0], atol=1e-6)


def test_region_averse_weights_discount_shared_fate():
    regions = region_assignment(4, 2)
    alive = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    w = region_averse_weights(alive, regions)
    # Site 0 shares site 1's region: half its region is dead, so its
    # weight halves; dead sites stay at zero; the far region is untouched.
    np.testing.assert_allclose(np.asarray(w), [0.5, 0.0, 1.0, 1.0])
    np.testing.assert_array_equal(
        np.asarray(region_averse_weights(jnp.ones(4), regions)),
        np.ones(4))


def test_stragglers_and_degraded_links_move_placed_bills(fb_setup):
    cfg, template, up, down = fb_setup
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    pol, rule = dispatch_fn(1.0), make_adaptive_rule(up)
    key = jax.random.key(3)
    bare = simulate_placed(template, up, down, pol, rule, key, pcfg)
    slow = simulate_placed(
        template, up, down, pol, rule, key, pcfg,
        health=scheduled_health_trace(cfg.t_slots, cfg.n_sites,
                                      [(0, 10, None, 0.2)]),
    )
    assert (float(jnp.mean(slow.backlog_avg))
            > float(jnp.mean(bare.backlog_avg)))
    lh = np.full((cfg.t_slots, cfg.n_sites, cfg.n_sites), 0.4, np.float32)
    lh[:, np.arange(cfg.n_sites), np.arange(cfg.n_sites)] = 1.0
    linky = simulate_placed(
        template, up, down, pol, rule, key, pcfg, link_health=jnp.asarray(lh),
    )
    assert float(linky.wan_cost.sum()) > float(bare.wan_cost.sum())
    assert float(linky.wan_latency_s.sum()) > float(bare.wan_latency_s.sum())


# ---------------------------------------------------------------------------
# Conservation properties, 18 hand-driven seeds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_prop_hedging_conserves_jobs(seed):
    """Hedging never loses or double-counts completed jobs: arrivals
    still split exactly into completions + final backlog, and the hedge
    columns stay non-negative with the bill attached to the jobs."""
    inputs, dag, wan = _random_case(seed)
    health = _random_health(seed)
    pol = make_staged_policy(dag, wan, hedge=0.9)
    outs = simulate_staged(inputs, dag, wan, pol, jax.random.key(seed),
                           scalar=5.0, health=health)
    arrived = float(inputs.arrivals.sum())
    got = float(outs.completed.sum()) + float(outs.q_final.sum())
    assert got == pytest.approx(arrived, rel=1e-4, abs=1e-3)
    assert bool(jnp.all(outs.q_final >= 0.0))
    assert bool(jnp.all(outs.hedged_jobs >= 0.0))
    assert bool(jnp.all(outs.hedge_cost >= 0.0))
    # No phantom speculation: a zero-hedge slot bills nothing.
    hj = np.asarray(outs.hedged_jobs)
    hc = np.asarray(outs.hedge_cost)
    assert (hc[hj == 0.0] == 0.0).all()
    s = summarize_staged(outs)
    assert s["time_avg_total_cost"] == pytest.approx(
        s["time_avg_compute_cost"] + s["time_avg_wan_cost"]
        + s["time_avg_hedge_cost"], rel=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
def test_prop_evacuation_gb_conserved_under_severed_links(seed):
    """Severing links reroutes the evacuation burst, never shrinks it:
    each destination receives exactly its deficit, link faults or not."""
    rng = np.random.default_rng(seed)
    d_full = rng.dirichlet(np.ones(N), K).astype(np.float32)
    dead = rng.integers(0, N)
    mask = np.ones(N, np.float32)
    mask[dead] = 0.0
    d_masked = jnp.asarray(d_full * mask[None, :])
    d_drop = jnp.asarray(
        np.asarray(d_masked) / np.maximum(
            np.asarray(d_masked).sum(axis=1, keepdims=True), 1e-9))
    sizes = jnp.asarray(rng.uniform(1.0, 100.0, K), jnp.float32)
    lh = np.ones((N, N), np.float32)
    n_cut = int(rng.integers(0, N))
    for _ in range(n_cut):
        i, j = rng.integers(0, N, 2)
        if i != j:
            lh[i, j] = 0.0
    need = np.maximum(np.asarray(d_drop) - np.asarray(d_masked), 0.0) \
        * np.asarray(sizes)[:, None]
    for link_health in (None, jnp.asarray(lh)):
        plan = evacuation_plan(d_masked, d_drop, sizes,
                               link_health=link_health)
        np.testing.assert_allclose(np.asarray(plan.sum(axis=1)), need,
                                   rtol=1e-4, atol=1e-4)
        assert bool(jnp.all(plan >= 0.0))
        assert float(jnp.sum(plan * jnp.eye(N)[None])) == 0.0


# ---------------------------------------------------------------------------
# The speculation pin: p99 cut on the calibrated straggler scenario
# ---------------------------------------------------------------------------

CHAOS_CLASSES = ["qwen2-0.5b", "mamba2-2.7b"]
CHAOS_COMMON = dict(slots=24, v=1.0, seed=3, arrival=4.0, admit_max=5.0)
CHAOS_HEDGE = 0.35


def _chaos_health():
    health = np.ones((24, 4), np.float32)
    health[4:, 2] = 0.12      # the dominant-capacity pod straggles hard
    return health


def _sojourn_p99(out):
    soj, wgt = fifo_sojourn_replay(out["admitted"], out["completed"])
    return float(weighted_percentile(soj, wgt, [99.0])[0])


@pytest.fixture(scope="module")
def chaos_pair():
    health = _chaos_health()
    base = build_engine(CHAOS_CLASSES, health=health, **CHAOS_COMMON)
    hedged = build_engine(CHAOS_CLASSES, health=health, hedge=CHAOS_HEDGE,
                          **CHAOS_COMMON)
    return (hedged, base.run(execute_real=False),
            hedged.run(execute_real=False))


def test_speculation_cuts_p99_within_overhead_budget(chaos_pair):
    _, base, hedged = chaos_pair
    p_base, p_hedged = _sojourn_p99(base), _sojourn_p99(hedged)
    assert hedged["hedged_jobs"].sum() > 0.0
    cut = (p_base - p_hedged) / p_base
    assert cut >= 0.20, (p_base, p_hedged)
    overhead = float(hedged["hedge_cost"].sum()) / (
        float(hedged["cost"].sum()) + float(hedged["hedge_cost"].sum()))
    assert overhead <= 0.10, overhead
    # First-completion also clears backlog, not just the tail.
    assert hedged["final_backlog"] < base["final_backlog"]
    assert hedged["completed"].sum() > base["completed"].sum()


def test_hedged_serve_conserves_and_bills_honestly(chaos_pair):
    _, _, hedged = chaos_pair
    np.testing.assert_allclose(
        hedged["admitted"].sum(axis=0),
        hedged["completed"].sum(axis=0) + hedged["q_final"].sum(axis=(0, 2)),
        rtol=1e-5, atol=1e-3,
    )
    assert hedged["total_billed_cost"] == pytest.approx(
        float(hedged["cost"].sum()) + float(hedged["wan_cost"].sum())
        + float(hedged["hedge_cost"].sum()), rel=1e-6)
    # The per-slot history carries the hedge stream.
    hist_hj = np.asarray([h["hedged_jobs"] for h in hedged["history"]])
    np.testing.assert_allclose(hist_hj, hedged["hedged_jobs"], rtol=1e-6)


def test_hedged_fleet_replays_simulate_staged(chaos_pair):
    """Replay parity survives hedging: the engine's dispatch and billed
    totals match ``simulate_staged`` with the hedged policy on the shared
    (health-scaled) scenario."""
    from repro.serve.engine import serve_policy

    engine, _, hedged = chaos_pair
    scn = engine.scenario
    pol = serve_policy(engine.fcfg, scn)
    outs = simulate_staged(scn.inputs, scn.dag, scn.wan, pol,
                           jax.random.key(0), engine.fcfg.v)
    np.testing.assert_array_equal(hedged["dispatch"], np.asarray(outs.f_trace))
    np.testing.assert_allclose(hedged["hedge_cost"],
                               np.asarray(outs.hedge_cost),
                               rtol=1e-5, atol=1e-8)
    sim_total = float(np.asarray(outs.cost).sum()
                      + np.asarray(outs.wan_cost).sum()
                      + np.asarray(outs.hedge_cost).sum())
    assert hedged["total_billed_cost"] == pytest.approx(sim_total, rel=1e-5)


def test_hedge_never_fires_on_a_healthy_fleet():
    # At thresholds below the fleet's natural rate spread the hedge gate
    # stays shut without faults; the chaos threshold is deliberately
    # above it so stragglers (not heterogeneity) trip speculation.
    engine = build_engine(CHAOS_CLASSES, hedge=0.2, **CHAOS_COMMON)
    out = engine.run(execute_real=False)
    assert float(out["hedged_jobs"].sum()) == 0.0
    assert float(out["hedge_cost"].sum()) == 0.0


# ---------------------------------------------------------------------------
# Flight recorder: repair pairing, derived events, straggler spans
# ---------------------------------------------------------------------------

def test_revival_lands_repair_event_and_repairs_the_slo_clock(fb_setup):
    cfg, template, up, down = fb_setup
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(1, 30, 60)])
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    tcfg = TelemetryConfig(level=TRACE)
    traced, frame = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        jax.random.key(3), pcfg, alive=mask, telemetry=tcfg,
    )
    events, dropped = ring_events(frame.ring)
    assert dropped == 0
    records = collect_records(traced, frame, cfg=tcfg)
    evs = [r for r in records if r.get("type") == "event"]
    rep = [e for e in evs if e["code"] == "repair"]
    assert len(rep) == 1 and rep[0]["t"] == 60 and rep[0]["site"] == 1
    rec = next(e for e in evs if e["code"] == "recovery")
    assert rec["t"] == 30 and rec["repair_t"] == 60
    # The SLO clock starts at the revival, so it can never report a
    # negative-latency recovery measured from the death slot.
    assert rec["time_to_slo"] is None or rec["time_to_slo"] >= 0


def test_hedge_and_link_down_event_builders():
    hj = np.array([0.0, 2.5, 0.0, 1.0])
    hc = np.array([0.0, 0.01, 0.0, 0.002])
    he = hedge_events(hj, hc)
    assert [e["t"] for e in he] == [1, 3]
    assert he[0]["hedged_jobs"] == 2.5
    assert he[0]["hedge_cost"] == pytest.approx(0.01)
    lh = np.ones((12, 3, 3), np.float32)
    lh[4:8, 0, 2] = 0.0
    le = link_down_events(lh)
    assert [(e["t"], e["edge"]) for e in le] == [(4, "down"), (8, "up")]
    assert le[0]["src"] == 0 and le[0]["dst"] == 2
    # Degraded-but-usable links are not "down": no event below the cut.
    lh2 = np.full((6, 2, 2), 0.5, np.float32)
    assert link_down_events(lh2) == []


def test_straggler_spans_windows_and_overlay():
    h = np.ones((12, 3), np.float32)
    h[3:7, 1] = 0.25
    h[5:, 2] = 0.0
    lh = np.ones((12, 3, 3), np.float32)
    lh[4:8, 0, 2] = 0.0
    spans = straggler_spans(h, link_health=lh)
    cats = [s["cat"] for s in spans]
    assert cats.count("straggler") == 1 and cats.count("dead") == 1
    assert cats.count("repair") == 1      # only the closing window repairs
    assert cats.count("link") == 2
    strag = next(s for s in spans if s["cat"] == "straggler")
    assert (strag["t0"], strag["t1"]) == (3.0, 7.0)
    assert strag["args"]["factor_min"] == pytest.approx(0.25)
    dead = next(s for s in spans if s["cat"] == "dead")
    assert (dead["t0"], dead["t1"]) == (5.0, 12.0)
    assert straggler_spans(np.ones((8, 2))) == []
