"""repro.telemetry: the jit-safe flight recorder (PR 6).

The load-bearing guarantees, in test form:

* OFF (or ``telemetry=None``) is FREE — every engine traces to the
  byte-identical jaxpr of the pre-telemetry build, on every policy class.
* TRACE changes nothing — engine outputs under TRACE equal the bare-run
  outputs bitwise; telemetry rides alongside, never in the numbers.
* The event stream is trustworthy — ring capacity overflow is detected
  (never silent), and the stream carries enough to rebuild the
  ``summarize_*`` totals (the cross-check) on a faulted Facebook-4DC run.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import (
    data_dispatch,
    greedy_cost_dispatch,
    jsq_dispatch,
    random_dispatch,
    static_placement_rule,
)
from repro.core.gmsa import dispatch_fn, gmsa_policy
from repro.core.simulator import simulate, summarize
from repro.jobs import simulate_staged, summarize_staged
from repro.jobs.dag import single_stage_dag
from repro.placement import (
    PlacementConfig,
    make_adaptive_rule,
    simulate_placed,
    summarize_placed,
    wan_topology,
)
from repro.telemetry import (
    EV_EPOCH,
    EV_RECOVERY,
    OFF,
    SUMMARY,
    TRACE,
    TelemetryConfig,
    collect_records,
    cross_check,
    read_jsonl,
    render_timeline,
    ring_events,
    ring_init,
    ring_push,
    switch_events,
    time_to_slo,
    write_jsonl,
)
from repro.telemetry import report as report_cli
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.faults import scheduled_failure_trace

POLICIES = [
    pytest.param(dispatch_fn(1.0), id="gmsa"),
    pytest.param(data_dispatch, id="data"),
    pytest.param(random_dispatch, id="random"),
    pytest.param(jsq_dispatch, id="jsq"),
    pytest.param(greedy_cost_dispatch, id="greedy"),
]


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(PaperSimConfig(), t_slots=96)
    template, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)
    return cfg, template, up, down


@pytest.fixture(scope="module")
def faulted_placed(setup):
    """One faulted Facebook-4DC controller run, bare + TRACE."""
    cfg, template, up, down = setup
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(1, 30, None)])
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    pol, rule, key = dispatch_fn(1.0), make_adaptive_rule(up), jax.random.key(3)
    bare = simulate_placed(template, up, down, pol, rule, key, pcfg,
                           alive=mask)
    tcfg = TelemetryConfig(level=TRACE)
    traced, frame = simulate_placed(template, up, down, pol, rule, key, pcfg,
                                    alive=mask, telemetry=tcfg)
    return bare, traced, frame, tcfg


# ---------------------------------------------------------------------------
# The event ring
# ---------------------------------------------------------------------------

def test_ring_push_order_and_masking():
    ring = ring_init(4)
    ring = ring_push(ring, True, 3, EV_RECOVERY, (1.0, 2.0))
    ring = ring_push(ring, False, 4, EV_EPOCH, (9.0,))     # masked: no-op
    ring = ring_push(ring, True, 7, EV_EPOCH, (5.0,))
    events, dropped = ring_events(ring)
    assert dropped == 0
    assert [(e["t"], e["code"]) for e in events] == [(3, EV_RECOVERY),
                                                     (7, EV_EPOCH)]
    np.testing.assert_allclose(events[0]["val"][:2], [1.0, 2.0])
    # The masked push left the buffer bitwise untouched.
    assert int(ring.count) == 2


def test_ring_wraparound_reports_dropped():
    ring = ring_init(2)
    for t in range(5):
        ring = ring_push(ring, True, t, EV_EPOCH, (float(t),))
    events, dropped = ring_events(ring)
    assert dropped == 3
    assert [e["t"] for e in events] == [3, 4]              # newest survive


def test_ring_push_inside_scan():
    def body(ring, t):
        return ring_push(ring, t % 2 == 0, t, EV_EPOCH, (t.astype(jnp.float32),)), None

    ring, _ = jax.lax.scan(body, ring_init(8), jnp.arange(6))
    events, dropped = ring_events(ring)
    assert dropped == 0
    assert [e["t"] for e in events] == [0, 2, 4]


# ---------------------------------------------------------------------------
# OFF is free: byte-identical jaxprs (the PR-4 fast path survives)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_simulate_off_jaxpr_identical(setup, policy):
    _, template, _, _ = setup
    key = jax.random.key(0)
    j_none = jax.make_jaxpr(lambda i, k: simulate(i, policy, k))(template, key)
    j_off = jax.make_jaxpr(
        lambda i, k: simulate(i, policy, k, telemetry=TelemetryConfig(level=OFF))
    )(template, key)
    assert str(j_none) == str(j_off)


def test_simulate_placed_off_jaxpr_identical(setup):
    cfg, template, up, down = setup
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(1, 30, None)])
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    pol, rule = dispatch_fn(1.0), make_adaptive_rule(up)
    key = jax.random.key(3)

    def bare(i, k):
        return simulate_placed(i, up, down, pol, rule, k, pcfg, alive=mask)

    def off(i, k):
        return simulate_placed(i, up, down, pol, rule, k, pcfg, alive=mask,
                               telemetry=TelemetryConfig(level=OFF))

    assert (str(jax.make_jaxpr(bare)(template, key))
            == str(jax.make_jaxpr(off)(template, key)))


def test_simulate_staged_off_jaxpr_identical(setup):
    cfg, template, up, down = setup
    dag = single_stage_dag(cfg.k_types)
    wan = wan_topology(up, down)
    key = jax.random.key(0)

    def bare(i, k):
        return simulate_staged(i, dag, wan, data_dispatch, k)

    def off(i, k):
        return simulate_staged(i, dag, wan, data_dispatch, k,
                               telemetry=TelemetryConfig(level=OFF))

    assert (str(jax.make_jaxpr(bare)(template, key))
            == str(jax.make_jaxpr(off)(template, key)))


# ---------------------------------------------------------------------------
# TRACE observes without disturbing: outputs stay bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_simulate_trace_outputs_bitwise(setup, policy):
    cfg, template, _, _ = setup
    key = jax.random.key(7)
    o0 = simulate(template, policy, key)
    o1, frame = simulate(template, policy, key,
                         telemetry=TelemetryConfig(level=TRACE))
    for f in o0._fields:
        np.testing.assert_array_equal(np.asarray(getattr(o0, f)),
                                      np.asarray(getattr(o1, f)), err_msg=f)
    assert frame.metrics["q_site"].shape == (cfg.t_slots, cfg.n_sites)


def test_simulate_placed_trace_outputs_bitwise(faulted_placed):
    bare, traced, frame, _ = faulted_placed
    for f in bare._fields:
        np.testing.assert_array_equal(np.asarray(getattr(bare, f)),
                                      np.asarray(getattr(traced, f)),
                                      err_msg=f)


def test_simulate_staged_trace_outputs_bitwise(setup):
    cfg, template, up, down = setup
    dag = single_stage_dag(cfg.k_types)
    wan = wan_topology(up, down)
    key = jax.random.key(7)
    s0 = simulate_staged(template, dag, wan, random_dispatch, key)
    s1, frame = simulate_staged(template, dag, wan, random_dispatch, key,
                                telemetry=TelemetryConfig(level=TRACE))
    for f in s0._fields:
        np.testing.assert_array_equal(np.asarray(getattr(s0, f)),
                                      np.asarray(getattr(s1, f)), err_msg=f)
    # The per-stage WAN split re-sums to the fused per-slot bill.
    np.testing.assert_allclose(
        np.asarray(frame.metrics["stage_wan_cost"]).sum(-1),
        np.asarray(s1.wan_cost), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(frame.metrics["stage_wan_gb"]).sum(-1),
        np.asarray(s1.wan_gb), rtol=1e-4, atol=1e-6)


def test_summary_level_has_metrics_but_no_ring_events(setup):
    cfg, template, up, down = setup
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(1, 30, None)])
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    _, frame = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        jax.random.key(3), pcfg, alive=mask,
        telemetry=TelemetryConfig(level=SUMMARY),
    )
    assert frame.metrics["q_site"].shape == (cfg.t_slots, cfg.n_sites)
    events, dropped = ring_events(frame.ring)
    assert events == [] and dropped == 0


# ---------------------------------------------------------------------------
# The acceptance run: faulted Facebook-4DC stream rebuilds summarize_placed
# ---------------------------------------------------------------------------

def test_faulted_stream_has_recovery_and_epoch_events(faulted_placed):
    _, _, frame, _ = faulted_placed
    events, dropped = ring_events(frame.ring)
    assert dropped == 0
    codes = [e["code"] for e in events]
    assert EV_RECOVERY in codes
    assert EV_EPOCH in codes
    rec = next(e for e in events if e["code"] == EV_RECOVERY)
    assert rec["t"] == 30                       # the scheduled death edge
    assert rec["val"][0] > 0.0                  # evacuated GB


def test_faulted_stream_cross_checks_summarize_placed(faulted_placed):
    _, traced, frame, tcfg = faulted_placed
    records = collect_records(traced, frame, cfg=tcfg,
                              summary=summarize_placed(traced))
    res = cross_check(records)
    assert res["ok"], res
    for name in ("dispatch_cost", "wan_cost", "sync_cost",
                 "recovery_cost", "recovery_gb", "total_cost"):
        assert res["checks"][name]["ok"], res["checks"]
    # Recovery events carry the SLO clock.
    rec = next(r for r in records
               if r.get("type") == "event" and r.get("code") == "recovery")
    assert "time_to_slo" in rec and rec["slo_backlog"] > 0.0


def test_staged_stream_cross_checks_summarize_staged(setup):
    cfg, template, up, down = setup
    dag = single_stage_dag(cfg.k_types)
    wan = wan_topology(up, down)
    tcfg = TelemetryConfig(level=TRACE)
    outs, frame = simulate_staged(template, dag, wan, random_dispatch,
                                  jax.random.key(7), telemetry=tcfg)
    records = collect_records(outs, frame, cfg=tcfg,
                              summary=summarize_staged(outs))
    res = cross_check(records)
    assert res["ok"], res


def test_sim_stream_cross_checks_summarize(setup):
    _, template, _, _ = setup
    tcfg = TelemetryConfig(level=TRACE)
    outs, frame = simulate(template, dispatch_fn(1.0), jax.random.key(7),
                           telemetry=tcfg)
    records = collect_records(outs, frame, cfg=tcfg, summary=summarize(outs))
    res = cross_check(records)
    assert res["ok"], res


def test_collect_refuses_monte_carlo_axis(faulted_placed):
    bare, *_ = faulted_placed
    stacked = bare._replace(
        cost=jnp.stack([bare.cost, bare.cost]),
    )
    with pytest.raises(ValueError, match="ONE run"):
        collect_records(stacked)


# ---------------------------------------------------------------------------
# Derived events + SLO clock
# ---------------------------------------------------------------------------

def test_switch_events_flag_argmax_edges():
    f = np.zeros((3, 2, 1), np.float32)
    f[0, 0, 0] = 1.0
    f[1, 1, 0] = 1.0                              # switch at t=1: 0 -> 1
    f[2, 1, 0] = 1.0                              # no switch
    evs = switch_events(f)
    assert len(evs) == 1
    assert evs[0] == {"type": "event", "t": 1, "code": "switch",
                      "k": 0, "src": 0, "dst": 1}


def test_time_to_slo_derived_threshold():
    backlog = np.concatenate([np.full(12, 2.0), [9.0, 8.0, 2.9, 2.0]])
    slots, thr = time_to_slo(backlog, 12, TelemetryConfig())
    assert thr == pytest.approx(3.0)              # 1.5 x pre-fault mean 2.0
    assert slots == 2                             # 9, 8, then 2.9 <= 3.0
    stuck = np.concatenate([np.full(12, 2.0), np.full(8, 9.0)])
    never, _ = time_to_slo(stuck, 12, TelemetryConfig())
    assert never is None                          # 9 > 3.0 forever
    abs_slots, abs_thr = time_to_slo(
        backlog, 12, TelemetryConfig(slo_backlog=8.5))
    assert abs_thr == 8.5 and abs_slots == 1


# ---------------------------------------------------------------------------
# Export round trip + the report CLI
# ---------------------------------------------------------------------------

def test_jsonl_round_trip(tmp_path, faulted_placed):
    _, traced, frame, tcfg = faulted_placed
    records = collect_records(traced, frame, cfg=tcfg,
                              summary=summarize_placed(traced))
    path = write_jsonl(records, tmp_path / "run.jsonl")
    assert read_jsonl(path) == json.loads(json.dumps(records))


def test_render_timeline_mentions_the_death_edge(faulted_placed):
    _, traced, frame, tcfg = faulted_placed
    records = collect_records(traced, frame, cfg=tcfg,
                              summary=summarize_placed(traced))
    text = render_timeline(records, codes={"recovery", "epoch"})
    assert "death edge" in text and "evacuated" in text
    assert "engine=placed" in text


def test_report_cli_check_exit_codes(tmp_path, faulted_placed):
    _, traced, frame, tcfg = faulted_placed
    records = collect_records(traced, frame, cfg=tcfg,
                              summary=summarize_placed(traced))
    good = write_jsonl(records, tmp_path / "good.jsonl")
    assert report_cli.main([str(good), "--check"]) == 0
    # Corrupt the embedded summary: the cross-check must catch it.
    bad_records = [dict(r) for r in records]
    for r in bad_records:
        if r["type"] == "summary":
            r["time_avg_total_cost"] *= 2.0
    bad = write_jsonl(bad_records, tmp_path / "bad.jsonl")
    assert report_cli.main([str(bad), "--check"]) == 1


def test_dropped_events_fail_the_cross_check(faulted_placed):
    _, traced, frame, tcfg = faulted_placed
    records = collect_records(traced, frame, cfg=tcfg,
                              summary=summarize_placed(traced))
    records[0]["events_dropped"] = 3
    res = cross_check(records)
    assert not res["ok"]
    assert "dropped" in res["error"]


def test_tiny_capacity_overflows_and_is_detected(setup):
    cfg, template, up, down = setup
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(1, 30, None)])
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    _, frame = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        jax.random.key(3), pcfg, alive=mask,
        telemetry=TelemetryConfig(level=TRACE, capacity=2),
    )
    events, dropped = ring_events(frame.ring)
    assert len(events) == 2 and dropped > 0
