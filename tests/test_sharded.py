"""Sharded Monte-Carlo + carried-r kernel dispatch (repro.distributed.mesh).

Three contracts pinned here:

* **Device-count invariance** — every ``*_many`` / ``sweep_*`` entry point
  produces bitwise-identical outputs sharded over a runs mesh vs the
  single-device vmap, at every device count. The same split keys are
  merely laid out across devices, so this holds exactly, not just in
  distribution. In-process tests run on whatever devices the process has
  (1 in tier-1; 8 in the CI multi-device job); the subprocess test forces
  an 8-way CPU pod regardless, including the ``n_runs=1000`` case and a
  non-divisible ``n_runs`` exercising pad-and-mask.
* **Carried-r kernel dispatch** — ``make_kernel_policy(r=None)`` reads the
  per-slot ratio tensor from its aux, matching the e-table path on a
  drifting-r run in all three engines; the static-bound variant raises
  loudly when a time-varying trace reaches it.
* **XLA_FLAGS bootstrap ordering** — ``ensure_host_devices`` installs the
  host-device flag before backend init and raises after it.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.configs.facebook_4dc_stages import (
    StagedPaperConfig,
    make_staged_builder,
)
from repro.core.gmsa import gmsa_policy, make_kernel_policy
from repro.core.simulator import simulate, simulate_many
from repro.core.sweep import sweep_grid, sweep_placed_budgets
from repro.distributed.mesh import runs_mesh, sharded_runs
from repro.jobs import simulate_staged, simulate_staged_many
from repro.placement import PlacementConfig, make_adaptive_rule
from repro.placement.controller import simulate_placed, simulate_placed_many
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.datasets import io_slowdown_from_bandwidth
from repro.traces.faults import site_failure_trace

V_POINTS = (0.1, 1.0, 10.0)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _device_counts():
    have = jax.device_count()
    return [d for d in (1, 2, 4, 8) if d <= have]


def _trees_equal(a, b):
    return all(
        bool(jnp.all(x == y))
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b))
    )


@pytest.fixture(scope="module")
def paper_setup():
    cfg = PaperSimConfig(t_slots=48)
    template, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)
    return cfg, template, build, up, down


@pytest.fixture(scope="module")
def staged_setup():
    cfg = StagedPaperConfig(t_slots=48)
    template, dag, wan, build = make_staged_builder(cfg)
    return cfg, template, dag, wan, build


def drifting_r(template, t_slots):
    """A (T, K, N, N) ratio trace that actually moves over the horizon."""
    drift = jnp.linspace(0.0, 1.0, t_slots)[:, None, None, None]
    r_alt = jnp.roll(template.r, 1, axis=-1)
    r_tv = (1.0 - drift) * template.r[None] + drift * r_alt[None]
    return r_tv / jnp.maximum(r_tv.sum(-1, keepdims=True), 1e-9)


# ---------------------------------------------------------------------------
# device-count invariance (in-process: every count the process has)


@pytest.mark.parametrize("n_dev", _device_counts())
def test_simulate_many_mesh_invariance(paper_setup, n_dev):
    _, _, build, _, _ = paper_setup
    key = jax.random.key(3)
    mesh = runs_mesh(n_dev)
    # 10 is not divisible by 4 or 8: the pad-and-mask path runs in-process
    # whenever the process has the devices.
    ref = simulate_many(build, gmsa_policy, key, 10)
    out = simulate_many(build, gmsa_policy, key, 10, mesh=mesh)
    assert out.cost.shape == ref.cost.shape
    assert _trees_equal(ref, out)


@pytest.mark.parametrize("n_dev", _device_counts())
def test_sweep_grid_mesh_invariance(paper_setup, n_dev):
    cfg, _, build, _, _ = paper_setup
    key = jax.random.key(4)
    mesh = runs_mesh(n_dev)
    ref = sweep_grid(build, gmsa_policy, key, 6, V_POINTS)
    out = sweep_grid(build, gmsa_policy, key, 6, V_POINTS, mesh=mesh)
    assert out.cost.shape == (len(V_POINTS), 6, cfg.t_slots)
    assert _trees_equal(ref, out)


def test_staged_many_mesh_invariance(staged_setup):
    _, _, dag, wan, build = staged_setup
    key = jax.random.key(5)
    mesh = runs_mesh()
    ref = simulate_staged_many(build, dag, wan, gmsa_policy, key, 5)
    out = simulate_staged_many(build, dag, wan, gmsa_policy, key, 5,
                               mesh=mesh)
    assert _trees_equal(ref, out)


def test_placed_many_mesh_invariance_with_faults(paper_setup):
    cfg, _, build, up, down = paper_setup
    key = jax.random.key(6)
    rule = make_adaptive_rule(up)
    pcfg = PlacementConfig(epoch_slots=12, manager_share=cfg.manager_share)
    alive = site_failure_trace(
        jax.random.key(9), cfg.t_slots, cfg.n_sites,
        failure_prob=0.02, repair_slots=10,
    )
    assert bool(jnp.any(alive < 0.5)), "fault trace must actually fire"
    mesh = runs_mesh()
    ref = simulate_placed_many(build, up, down, gmsa_policy, rule, key, 5,
                               pcfg, alive=alive)
    out = simulate_placed_many(build, up, down, gmsa_policy, rule, key, 5,
                               pcfg, alive=alive, mesh=mesh)
    assert _trees_equal(ref, out)


def test_sweep_placed_budgets_mesh_invariance(paper_setup):
    cfg, _, build, up, down = paper_setup
    key = jax.random.key(7)
    rule = make_adaptive_rule(up)
    pcfg = PlacementConfig(epoch_slots=12, manager_share=cfg.manager_share)
    budgets = (0.1, 0.9)
    mesh = runs_mesh()
    ref = sweep_placed_budgets(build, up, down, gmsa_policy, rule, key, 5,
                               pcfg, budgets)
    out = sweep_placed_budgets(build, up, down, gmsa_policy, rule, key, 5,
                               pcfg, budgets, mesh=mesh)
    assert ref.cost.shape == out.cost.shape
    assert _trees_equal(ref, out)


def test_sharded_runs_rejects_foreign_mesh():
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("model",))
    keys = jax.random.split(jax.random.key(0), 4)
    with pytest.raises(ValueError, match="runs"):
        sharded_runs(lambda k: k, keys, mesh)


def test_runs_mesh_rejects_overask():
    with pytest.raises(ValueError, match="device"):
        runs_mesh(jax.device_count() + 1)


# ---------------------------------------------------------------------------
# carried-r kernel dispatch (the make_kernel_policy static-binding bugfix)


def test_carried_r_matches_ref_on_drifting_trace(paper_setup):
    cfg, template, _, _, _ = paper_setup
    key = jax.random.key(11)
    inp_tv = template._replace(r=drifting_r(template, cfg.t_slots))
    ref = simulate(inp_tv, gmsa_policy, key)          # e-tables see (T,K,N,N)
    for impl in ("ref", "kernel"):
        out = simulate(
            inp_tv, make_kernel_policy(p_it=template.p_it, impl=impl), key
        )
        np.testing.assert_array_equal(
            np.asarray(ref.f_trace), np.asarray(out.f_trace),
            err_msg=f"impl={impl}",
        )
        np.testing.assert_array_equal(
            np.asarray(ref.cost), np.asarray(out.cost), err_msg=f"impl={impl}"
        )


def test_static_r_policy_raises_on_time_varying_trace(paper_setup):
    cfg, template, _, _, _ = paper_setup
    inp_tv = template._replace(r=drifting_r(template, cfg.t_slots))
    static_pol = make_kernel_policy(template.r, template.p_it, impl="ref")
    with pytest.raises(ValueError, match="stale"):
        simulate(inp_tv, static_pol, jax.random.key(0))


def test_static_r_policy_still_exact_on_static_trace(paper_setup):
    _, template, _, _, _ = paper_setup
    key = jax.random.key(12)
    static_pol = make_kernel_policy(template.r, template.p_it, impl="ref")
    ref = simulate(template, gmsa_policy, key)
    out = simulate(template, static_pol, key)
    np.testing.assert_array_equal(
        np.asarray(ref.f_trace), np.asarray(out.f_trace)
    )


def test_carried_r_through_staged_engine(staged_setup):
    cfg, template, dag, wan, _ = staged_setup
    key = jax.random.key(13)
    inp_tv = template._replace(r=drifting_r(template, cfg.t_slots))
    ref = simulate_staged(inp_tv, dag, wan, gmsa_policy, key)
    out = simulate_staged(
        inp_tv, dag, wan, make_kernel_policy(p_it=template.p_it, impl="ref"),
        key,
    )
    np.testing.assert_array_equal(
        np.asarray(ref.f_trace), np.asarray(out.f_trace)
    )
    static_pol = make_kernel_policy(template.r, template.p_it, impl="ref")
    with pytest.raises(ValueError, match="stale"):
        simulate_staged(inp_tv, dag, wan, static_pol, key)


def test_carried_r_through_controller_with_faults(paper_setup):
    """The controller's carried r_c/r_e reaches the kernel path exactly.

    gmsa_policy consumes the controller's cond-carried energy rows; the
    carried-r kernel policy re-derives the same decision from the raw
    ``(r_c, wpue_t)`` operands — equality across epoch rebuilds AND
    mid-epoch recovery re-placements is the bugfix's acceptance gate.
    """
    cfg, template, _, up, down = paper_setup
    key = jax.random.key(14)
    rule = make_adaptive_rule(up)
    pcfg = PlacementConfig(epoch_slots=12, manager_share=cfg.manager_share)
    alive = site_failure_trace(
        jax.random.key(9), cfg.t_slots, cfg.n_sites,
        failure_prob=0.02, repair_slots=10,
    )
    carried = make_kernel_policy(p_it=template.p_it, impl="ref")
    for kwargs in ({}, {"alive": alive}):
        ref = simulate_placed(template, up, down, gmsa_policy, rule, key,
                              pcfg, **kwargs)
        out = simulate_placed(template, up, down, carried, rule, key,
                              pcfg, **kwargs)
        np.testing.assert_array_equal(
            np.asarray(ref.f_trace), np.asarray(out.f_trace),
            err_msg=f"kwargs={list(kwargs)}",
        )
    static_pol = make_kernel_policy(template.r, template.p_it, impl="ref")
    with pytest.raises(ValueError, match="stale"):
        simulate_placed(template, up, down, static_pol, rule, key, pcfg)


# ---------------------------------------------------------------------------
# per-reader I/O slowdown (carried ROADMAP follow-on)


def test_per_reader_io_slowdown_disagrees_with_average():
    """Averaged and per-reader models must disagree where locality is mixed.

    Two sites, two types: type 0 lives at site 0, type 1 at site 1. The
    averaged model sees 50% locality at both sites and slows every type;
    the per-reader model knows type 0's reader at site 0 holds a local
    replica (not slowed at all) while its reader at site 1 pulls remotely.
    """
    from repro.placement.replica import replica_read_assignment
    from repro.placement.wan import wan_topology as wt

    up = jnp.asarray([1.0, 1.0])
    down = jnp.asarray([0.1, 0.1])      # slow downlinks: visible transfer
    d = jnp.asarray([[1.0, 0.0], [0.0, 1.0]], jnp.float32)   # (K, N)
    wan = wt(up, down)
    reads = replica_read_assignment(d, wan, jnp.ones((2,), jnp.float32))

    avg = io_slowdown_from_bandwidth(up, down, d)            # (N,)
    per = io_slowdown_from_bandwidth(up, down, d, reads=reads)  # (N, K)
    assert per.shape == (2, 2)
    # Local type not slowed; remote type slowed more than the average says.
    np.testing.assert_allclose(float(per[0, 0]), 1.0)
    np.testing.assert_allclose(float(per[1, 1]), 1.0)
    assert float(per[0, 1]) < float(avg[0]) < 1.0
    assert float(per[1, 0]) < float(avg[1]) < 1.0


def test_controller_per_reader_io_differs_and_default_unchanged(paper_setup):
    cfg, template, _, up, down = paper_setup
    key = jax.random.key(15)
    rule = make_adaptive_rule(up)
    base = dict(epoch_slots=12, manager_share=cfg.manager_share,
                io_coupling=True)
    ref = simulate_placed(template, up, down, gmsa_policy, rule, key,
                          PlacementConfig(**base))
    per = simulate_placed(template, up, down, gmsa_policy, rule, key,
                          PlacementConfig(**base, io_per_reader=True))
    # The per-reader model is a different (finer) model: it must actually
    # change the realized service scale on a mixed-locality scenario.
    assert not np.array_equal(np.asarray(ref.mu_scale),
                              np.asarray(per.mu_scale))
    # And io_per_reader=False stays bitwise the pre-change model.
    again = simulate_placed(template, up, down, gmsa_policy, rule, key,
                            PlacementConfig(**base))
    assert _trees_equal(ref, again)


# ---------------------------------------------------------------------------
# subprocess: forced 8-way CPU pod — invariance at n_runs=1000 + pad case


_INVARIANCE_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
    from repro.configs.facebook_4dc_stages import (
        StagedPaperConfig, make_staged_builder,
    )
    from repro.core.gmsa import gmsa_policy
    from repro.core.simulator import simulate_many
    from repro.core.sweep import sweep_grid
    from repro.distributed.mesh import runs_mesh
    from repro.jobs import simulate_staged_many
    from repro.placement import PlacementConfig, make_adaptive_rule
    from repro.placement.controller import simulate_placed_many
    from repro.traces.bandwidth import bandwidth_draw
    from repro.traces.faults import site_failure_trace

    def eq(a, b):
        return all(bool(jnp.all(x == y))
                   for x, y in zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b)))

    report = {"devices": jax.device_count()}
    mesh = runs_mesh()
    key = jax.random.key(0)

    cfg = PaperSimConfig(t_slots=48)
    template, build = make_sim_builder(cfg)
    # n_runs=1000 divides 8 ways; 1001 exercises pad-and-mask.
    for n in (1000, 1001):
        ref = simulate_many(build, gmsa_policy, key, n)
        out = simulate_many(build, gmsa_policy, key, n, mesh=mesh)
        report[f"simulate_many_{n}"] = eq(ref, out)
        report[f"rows_{n}"] = int(out.cost.shape[0])

    ga = sweep_grid(build, gmsa_policy, key, 12, (0.1, 1.0, 10.0))
    gb = sweep_grid(build, gmsa_policy, key, 12, (0.1, 1.0, 10.0), mesh=mesh)
    report["sweep_grid"] = eq(ga, gb)

    scfg = StagedPaperConfig(t_slots=48)
    stemplate, dag, wan, sbuild = make_staged_builder(scfg)
    sa = simulate_staged_many(sbuild, dag, wan, gmsa_policy, key, 12)
    sb = simulate_staged_many(sbuild, dag, wan, gmsa_policy, key, 12,
                              mesh=mesh)
    report["simulate_staged_many"] = eq(sa, sb)

    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)
    rule = make_adaptive_rule(up)
    pcfg = PlacementConfig(epoch_slots=12, manager_share=cfg.manager_share)
    alive = site_failure_trace(jax.random.key(9), cfg.t_slots, cfg.n_sites,
                               failure_prob=0.02, repair_slots=10)
    report["fault_fired"] = bool(jnp.any(alive < 0.5))
    pa = simulate_placed_many(build, up, down, gmsa_policy, rule, key, 12,
                              pcfg, alive=alive)
    pb = simulate_placed_many(build, up, down, gmsa_policy, rule, key, 12,
                              pcfg, alive=alive, mesh=mesh)
    report["simulate_placed_many"] = eq(pa, pb)
    print(json.dumps(report))
""")


def test_eight_device_invariance_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _INVARIANCE_PROG],
        capture_output=True, text=True, cwd=_REPO_ROOT, env=env, timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    report = json.loads(res.stdout.strip().splitlines()[-1])
    assert report["devices"] == 8
    assert report["fault_fired"]
    assert report["simulate_many_1000"]
    assert report["simulate_many_1001"]
    assert report["rows_1000"] == 1000   # summaries weight real run count
    assert report["rows_1001"] == 1001   # padded-and-masked, not truncated
    assert report["sweep_grid"]
    assert report["simulate_staged_many"]
    assert report["simulate_placed_many"]


# ---------------------------------------------------------------------------
# XLA_FLAGS bootstrap ordering


_BOOTSTRAP_OK_PROG = textwrap.dedent("""
    import sys; sys.path.insert(0, "src")
    import json, os
    # Before any jax backend init: the flag must take effect.
    from repro.distributed.mesh import ensure_host_devices
    n = ensure_host_devices(6)
    import jax
    print(json.dumps({
        "requested": n,
        "flag": os.environ.get("XLA_FLAGS", ""),
        "devices": jax.device_count(),
    }))
""")

_BOOTSTRAP_LATE_PROG = textwrap.dedent("""
    import sys; sys.path.insert(0, "src")
    import json
    import jax
    jax.devices()          # backends initialize with 1 CPU device
    from repro.distributed.mesh import ensure_host_devices
    try:
        ensure_host_devices(8)
        print(json.dumps({"raised": False}))
    except RuntimeError as e:
        print(json.dumps({"raised": True, "msg": str(e)[:240]}))
""")


def test_xla_flags_bootstrap_ordering_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    ok = subprocess.run(
        [sys.executable, "-c", _BOOTSTRAP_OK_PROG],
        capture_output=True, text=True, cwd=_REPO_ROOT, env=env, timeout=240,
    )
    assert ok.returncode == 0, ok.stderr[-2000:]
    report = json.loads(ok.stdout.strip().splitlines()[-1])
    assert "--xla_force_host_platform_device_count=6" in report["flag"]
    assert report["devices"] == 6

    late = subprocess.run(
        [sys.executable, "-c", _BOOTSTRAP_LATE_PROG],
        capture_output=True, text=True, cwd=_REPO_ROOT, env=env, timeout=240,
    )
    assert late.returncode == 0, late.stderr[-2000:]
    report = json.loads(late.stdout.strip().splitlines()[-1])
    assert report["raised"]
    assert "before the first" in report["msg"]


def test_ensure_host_devices_noop_when_enough():
    # Backends are initialized in-process; asking for what we already have
    # is a no-op rather than an error.
    assert jax.device_count() >= 1
    from repro.distributed.mesh import ensure_host_devices

    assert ensure_host_devices(1) == jax.device_count()
