"""FleetEngine integration: GMSA dispatch over real (tiny) models."""

import numpy as np
import pytest

from repro.launch.serve import build_engine


@pytest.fixture(scope="module")
def engine():
    return build_engine(["qwen2-0.5b"], slots=12, v=1.0, seed=3, arrival=4.0)


def test_dispatch_only_run(engine):
    out = engine.run(execute_real=False)
    assert out["cost"].shape == (12,)
    assert np.all(out["cost"] >= 0)
    f = out["dispatch"]                      # (T, N, K)
    np.testing.assert_allclose(f.sum(axis=1), 1.0, atol=1e-5)
    # energy pricing uses the FULL architecture (0.49B params), not smoke
    assert engine.p_it[0] > 0


def test_history_records_choice_queue_energy(engine):
    out = engine.run(execute_real=False)
    hist = out["history"]
    assert [h["t"] for h in hist] == list(range(12))
    for t, h in enumerate(hist):
        # Choice is the argmax pod of the recorded dispatch row.
        np.testing.assert_array_equal(
            h["choice"], out["dispatch"][t].argmax(axis=0))
        assert len(h["q_pod"]) == engine.fcfg.n_pods
        assert all(d >= 0.0 for d in h["q_pod"])
        assert all(j >= 0.0 for j in h["energy_j"])
    # Per-pod depths re-sum to the recorded total backlog.
    np.testing.assert_allclose(
        [sum(h["q_pod"]) for h in hist], out["backlog"], rtol=1e-5)
    # Energy pricing actually priced something over the horizon.
    assert sum(sum(h["energy_j"]) for h in hist) > 0.0


def test_stream_callback_receives_ordered_slots(engine):
    seen = []
    out = engine.run(execute_real=False, stream=seen.append)
    assert [r["t"] for r in seen] == list(range(12))
    for r, c, b in zip(seen, out["cost"], out["backlog"]):
        assert r["type"] == "metric" and r["engine"] == "serve"
        assert r["cost"] == pytest.approx(float(c), rel=1e-5, abs=1e-12)
        assert r["backlog"] == pytest.approx(float(b), rel=1e-5, abs=1e-12)


def test_real_execution_smoke(engine):
    out = engine.run(execute_real=True)
    assert out["exec_seconds"] > 0           # models actually ran
    assert out["final_backlog"] < 200        # stable under GMSA


def test_high_v_prefers_cheap_pods():
    e1 = build_engine(["qwen2-0.5b"], slots=24, v=0.001, seed=5, arrival=4.0)
    e2 = build_engine(["qwen2-0.5b"], slots=24, v=1000.0, seed=5, arrival=4.0)
    o1 = e1.run(execute_real=False)
    o2 = e2.run(execute_real=False)
    assert o2["mean_cost"] <= o1["mean_cost"] * 1.001


def test_gmsa_beats_random_dispatch_on_fleet():
    """Fleet-level quantification: GMSA's energy-cost saving vs RANDOM
    dispatch on the same arrivals/pods (the paper's headline, on the LLM
    fleet instead of Hadoop jobs)."""
    import jax
    import jax.numpy as jnp

    from repro.core.baselines import random_dispatch
    from repro.core.energy import manager_energy_cost
    from repro.core.queues import queue_step

    engine = build_engine(["qwen2-0.5b", "granite-3-2b"], slots=48, v=10.0,
                          seed=7, arrival=5.0)
    out_gmsa = engine.run(execute_real=False)

    # Replay identical slots under RANDOM dispatch.
    rng = np.random.default_rng(7)
    n, k = 4, 2
    q = jnp.zeros((n, k), jnp.float32)
    shares = np.asarray(engine.fcfg.capacity_shares[:n], np.float32)
    key = jax.random.key(123)
    costs = []
    for t in range(48):
        arrivals = jnp.asarray(
            [rng.poisson(rc.arrival_rate) for rc in engine.classes], jnp.float32
        )
        omega_t = jnp.asarray(engine.omega[t % len(engine.omega)])
        pue_t = jnp.asarray(engine.pue[t % len(engine.pue)])
        e = manager_energy_cost(omega_t, pue_t, jnp.asarray(engine.r), engine.p_it)
        lam_tot = sum(rc.arrival_rate for rc in engine.classes)
        mu = jnp.asarray(rng.poisson(shares[:, None] * lam_tot / k, size=(n, k)),
                         jnp.float32)
        key, sub = jax.random.split(key)
        f = random_dispatch(sub, q, arrivals, mu, e, None)
        costs.append(float(jnp.sum((f * arrivals[None, :]).T * e)))
        q = queue_step(q, f, arrivals, mu)
    mean_random = float(np.mean(costs))
    saving = 1.0 - out_gmsa["mean_cost"] / mean_random
    # GMSA should save a double-digit fraction of fleet energy cost.
    assert saving > 0.10, f"fleet saving only {100*saving:.1f}%"
