"""FleetEngine integration: staged dispatch over real (tiny) models."""

import numpy as np
import pytest

from repro.launch.serve import build_engine


@pytest.fixture(scope="module")
def engine():
    return build_engine(["qwen2-0.5b"], slots=12, v=1.0, seed=3, arrival=4.0)


def test_dispatch_only_run(engine):
    out = engine.run(execute_real=False)
    assert out["cost"].shape == (12,)
    assert np.all(out["cost"] >= 0)
    f = out["dispatch"]                      # (T, N, K, S)
    assert f.shape == (12, 4, 1, 2)
    np.testing.assert_allclose(f.sum(axis=1), 1.0, atol=1e-5)
    # energy pricing uses the FULL architecture (0.49B params), not smoke
    assert engine.p_it[0] > 0


def test_history_records_choice_queue_energy(engine):
    out = engine.run(execute_real=False)
    hist = out["history"]
    assert [h["t"] for h in hist] == list(range(12))
    for t, h in enumerate(hist):
        # Choice is the argmax pod of the decode (final) stage's dispatch.
        np.testing.assert_array_equal(
            h["choice"], out["dispatch"][t][:, :, -1].argmax(axis=0))
        assert len(h["q_pod"]) == engine.fcfg.n_pods
        assert all(d >= 0.0 for d in h["q_pod"])
        assert all(j >= 0.0 for j in h["energy_j"])
    # Per-pod depths re-sum to the recorded total backlog.
    np.testing.assert_allclose(
        [sum(h["q_pod"]) for h in hist], out["backlog"], rtol=1e-5)
    # Energy pricing actually priced something over the horizon.
    assert sum(sum(h["energy_j"]) for h in hist) > 0.0


def test_stream_callback_receives_ordered_slots(engine):
    seen = []
    out = engine.run(execute_real=False, stream=seen.append)
    import jax
    jax.effects_barrier()
    assert [r["t"] for r in seen] == list(range(12))
    for r, c, b in zip(seen, out["cost"], out["backlog"]):
        assert r["type"] == "metric" and r["engine"] == "serve"
        assert r["cost"] == pytest.approx(float(c), rel=1e-4, abs=1e-10)
        assert r["backlog"] == pytest.approx(float(b), rel=1e-5, abs=1e-12)


def test_real_execution_smoke(engine):
    out = engine.run(execute_real=True)
    assert out["exec_seconds"] > 0           # models actually ran
    assert out["exec_jobs"] > 0
    assert out["final_backlog"] < 200        # stable under staged dispatch


def test_high_v_prefers_cheap_pods():
    e1 = build_engine(["qwen2-0.5b"], slots=24, v=0.001, seed=5, arrival=4.0)
    e2 = build_engine(["qwen2-0.5b"], slots=24, v=1000.0, seed=5, arrival=4.0)
    o1 = e1.run(execute_real=False)
    o2 = e2.run(execute_real=False)
    assert o2["mean_cost"] <= o1["mean_cost"] * 1.001


def test_staged_beats_random_dispatch_on_fleet():
    """Fleet-level quantification: the joint stage scheduler vs RANDOM
    dispatch on the SAME scenario traces (the paper's headline, on the
    LLM fleet instead of Hadoop jobs). Unlike the old hand-rolled replay,
    both arms now run the same engine on the same arrivals/mu draws, so
    the deltas are pure policy. In the serving regime the per-job energy
    is kWh-scale, so most of the dispatchable headroom is queueing: the
    pin is a strict compute-cost saving plus a large backlog reduction."""
    import jax

    from repro.core.baselines import random_dispatch
    from repro.jobs.engine import simulate_staged
    from repro.jobs.scheduler import stage_oblivious

    engine = build_engine(["qwen2-0.5b", "granite-3-2b"], slots=48, v=10.0,
                          seed=7, arrival=5.0)
    out = engine.run(execute_real=False)

    # RANDOM as the old engine ran it: any pod may serve any job
    # (unpinned), on the identical admitted arrivals / capacity draws.
    scn = engine.scenario
    outs = simulate_staged(
        scn.inputs, scn.dag, scn.wan,
        stage_oblivious(random_dispatch, pin_map=False),
        jax.random.key(123), engine.fcfg.v,
    )
    mean_random = float(np.asarray(outs.cost).mean())
    saving = 1.0 - out["mean_cost"] / mean_random
    assert saving > 0.03, f"fleet compute saving only {100*saving:.1f}%"
    backlog_ratio = (out["backlog"].mean()
                     / float(np.asarray(outs.backlog_total).mean()))
    assert backlog_ratio < 0.8, f"backlog ratio {backlog_ratio:.2f}"
