"""Iridium bisection edge cases: degenerate data layouts and bandwidths.

The property suite (test_properties.py) fuzzes the interior of the domain;
these pin down the boundary: d_j in {0, 1}, single-site jobs, and equal
bandwidths, where the feasible-box arithmetic divides by (1 - d) or d.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.iridium import (
    build_task_allocation,
    iridium_reduce_placement,
    make_allocation_rebuilder,
)


def _assert_simplex(r, atol=1e-4):
    r = np.asarray(r)
    assert (r >= -1e-6).all(), r
    np.testing.assert_allclose(r.sum(-1), 1.0, atol=atol)


def test_all_data_at_one_site():
    """d is one-hot: uplink of the hot site is the only exporter."""
    d = jnp.array([0.0, 1.0, 0.0, 0.0])
    up = jnp.array([1.0, 0.5, 2.0, 1.5])
    down = jnp.array([1.0, 1.0, 1.0, 1.0])
    r, z = iridium_reduce_placement(d, up, down, size=1.0)
    _assert_simplex(r)
    assert float(z) >= 0.0
    # The bottleneck is no worse than the trivial everything-at-site-1 plan
    # (z = 0 there) relaxed by shipping work out, and no worse than the
    # everything-remote plan.
    assert float(z) <= 1.0 / 0.5 + 1e-3


def test_no_data_anywhere_but_one_with_zero_bandwidth_headroom():
    """d_j = 0 sites have lo_j = 0 (no export pressure): placement valid."""
    d = jnp.array([1.0, 0.0])
    up = jnp.array([0.1, 2.0])
    down = jnp.array([2.0, 0.1])
    r, z = iridium_reduce_placement(d, up, down, size=1.0)
    _assert_simplex(r)


def test_single_site_job():
    """N = 1: the only feasible placement is r = [1], z = 0-ish."""
    d = jnp.array([1.0])
    up = jnp.array([0.7])
    down = jnp.array([1.3])
    r, z = iridium_reduce_placement(d, up, down, size=1.0)
    _assert_simplex(r)
    np.testing.assert_allclose(np.asarray(r), [1.0], atol=1e-5)


def test_degenerate_equal_bandwidths():
    """All links identical: uniform data should give (near-)uniform reduce."""
    n = 4
    d = jnp.full((n,), 1.0 / n)
    up = jnp.full((n,), 1.0)
    down = jnp.full((n,), 1.0)
    r, z = iridium_reduce_placement(d, up, down, size=1.0)
    _assert_simplex(r)
    np.testing.assert_allclose(np.asarray(r), np.full(n, 1.0 / n), atol=5e-3)


def test_equal_bandwidths_skewed_data_stays_on_simplex():
    d = jnp.array([0.7, 0.1, 0.1, 0.1])
    up = jnp.full((4,), 1.0)
    down = jnp.full((4,), 1.0)
    r, _ = iridium_reduce_placement(d, up, down, size=2.0)
    _assert_simplex(r)


def test_build_task_allocation_one_hot_rows():
    """The full (K, N, N) tensor stays row-stochastic on boundary data."""
    data_dist = jnp.array([
        [1.0, 0.0, 0.0],
        [0.0, 0.0, 1.0],
        [0.5, 0.5, 0.0],
    ])
    up = jnp.array([0.3, 1.0, 2.0])
    down = jnp.array([2.0, 0.3, 1.0])
    r = build_task_allocation(data_dist, up, down)
    _assert_simplex(r)
    assert r.shape == (3, 3, 3)


def test_rebuilder_matches_build_task_allocation():
    data_dist = jnp.array([[0.2, 0.5, 0.3]])
    up = jnp.array([1.0, 0.4, 2.0])
    down = jnp.array([0.8, 1.6, 0.6])
    rebuild = make_allocation_rebuilder(
        up, down, size=1.0, manager_share=0.62, map_share=0.6
    )
    r1 = rebuild(data_dist)
    r2 = build_task_allocation(
        data_dist, up, down, size=1.0, manager_share=0.62, map_share=0.6
    )
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
