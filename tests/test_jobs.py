"""Stage-structured jobs subsystem tests (repro.jobs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.configs.facebook_4dc_stages import (
    StagedPaperConfig,
    make_staged_builder,
)
from repro.core.baselines import (
    data_dispatch,
    greedy_cost_dispatch,
    jsq_dispatch,
    random_dispatch,
)
from repro.core.gmsa import dispatch_fn, gmsa_policy
from repro.core.simulator import simulate
from repro.jobs import (
    chain_dag,
    make_staged_policy,
    map_reduce_dag,
    pad_chains,
    shuffle_volumes_from_selectivity,
    simulate_staged,
    simulate_staged_many,
    single_stage_dag,
    stage_oblivious,
    summarize_staged,
    validate_dag,
)
from repro.placement import wan_topology
from repro.placement.wan import transfer_cost, transfer_plan
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.stages import (
    selectivity_trace,
    stage_compute_profile,
    stage_depth_mask,
)


@pytest.fixture(scope="module")
def paper_setup():
    cfg = PaperSimConfig()
    template, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)
    return cfg, template, build, wan_topology(up, down)


@pytest.fixture(scope="module")
def staged_setup():
    cfg = StagedPaperConfig()
    template, dag, wan, build = make_staged_builder(cfg)
    return cfg, template, dag, wan, build


# ---------------------------------------------------------------------------
# DAG representation
# ---------------------------------------------------------------------------

def test_pad_chains_ragged_depths():
    dag = pad_chains(
        [[0.5, 0.3, 0.2], [0.6, 0.4]],
        [[0.0, 20.0, 4.0], [0.0, 8.0]],
    )
    validate_dag(dag)
    assert dag.s_max == 3 and dag.k_types == 2
    np.testing.assert_array_equal(np.asarray(dag.n_stages), [3, 2])
    # Padding is the identity stage: compute 1, shuffle 0, mask 0.
    assert float(dag.compute[1, 2]) == 1.0
    assert float(dag.shuffle_gb[1, 2]) == 0.0
    assert float(dag.stage_mask[1, 2]) == 0.0


def test_validate_dag_rejects_bad_masks():
    bad = chain_dag(
        jnp.ones((1, 3)), jnp.zeros((1, 3)), jnp.array([[1.0, 0.0, 1.0]])
    )
    with pytest.raises(ValueError, match="monotone"):
        validate_dag(bad)
    empty = chain_dag(
        jnp.ones((1, 2)), jnp.zeros((1, 2)), jnp.array([[0.0, 0.0]])
    )
    with pytest.raises(ValueError, match="at least one"):
        validate_dag(empty)


def test_shuffle_volumes_from_selectivity():
    sel = jnp.array([[0.2, 0.5, 1.0]])
    vols = shuffle_volumes_from_selectivity(100.0, sel)
    # Stage 0 free (data-local map); stage 1 sees 100*0.2; stage 2 100*0.2*0.5.
    np.testing.assert_allclose(np.asarray(vols[0]), [0.0, 20.0, 10.0], rtol=1e-6)
    vols_in = shuffle_volumes_from_selectivity(100.0, sel, bill_input=True)
    assert float(vols_in[0, 0]) == pytest.approx(100.0)


def test_stage_trace_generators_shapes():
    key = jax.random.key(0)
    mask = stage_depth_mask(key, 5, 4, min_stages=2)
    assert mask.shape == (5, 4)
    assert bool(jnp.all(mask[:, :-1] >= mask[:, 1:]))          # monotone
    assert bool(jnp.all(jnp.sum(mask, 1) >= 2))
    comp = stage_compute_profile(jax.random.key(1), mask)
    active_sum = np.asarray(jnp.sum(comp * mask, axis=1))
    np.testing.assert_allclose(active_sum, 1.0, atol=1e-5)
    sel = selectivity_trace(jax.random.key(2), 5, 4)
    assert bool(jnp.all((sel >= 0.02) & (sel <= 1.2)))


# ---------------------------------------------------------------------------
# Single-stage equivalence: the staged engine degenerates to `simulate`
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [
    dispatch_fn(1.0), data_dispatch, random_dispatch, jsq_dispatch,
    greedy_cost_dispatch,
], ids=["gmsa", "data", "random", "jsq", "greedy"])
def test_single_stage_bit_exact(paper_setup, policy):
    """A trivial one-stage dag (selectivity 1, no shuffle) reproduces
    `simulate`'s cost/backlog/dispatch bit for bit, on every policy."""
    cfg, template, _, wan = paper_setup
    dag = single_stage_dag(cfg.k_types)
    key = jax.random.key(3)
    o_s = simulate(template, policy, key)
    o_j = simulate_staged(template, dag, wan, policy, key)
    np.testing.assert_array_equal(np.asarray(o_s.cost), np.asarray(o_j.cost))
    np.testing.assert_array_equal(
        np.asarray(o_s.energy), np.asarray(o_j.energy)
    )
    np.testing.assert_array_equal(
        np.asarray(o_s.backlog_total), np.asarray(o_j.backlog_total)
    )
    np.testing.assert_array_equal(
        np.asarray(o_s.backlog_avg), np.asarray(o_j.backlog_avg)
    )
    np.testing.assert_array_equal(
        np.asarray(o_s.f_trace), np.asarray(o_j.f_trace[..., 0])
    )
    np.testing.assert_array_equal(
        np.asarray(o_s.q_final), np.asarray(o_j.q_final[..., 0])
    )
    assert float(o_j.wan_cost.sum()) == 0.0
    assert float(o_j.wan_gb.sum()) == 0.0


# ---------------------------------------------------------------------------
# Multi-stage dynamics
# ---------------------------------------------------------------------------

def test_stage_flow_conservation(staged_setup):
    """Jobs are conserved through the chain: every arrival either finishes
    its last stage or sits in some stage queue at the horizon."""
    cfg, template, dag, wan, _ = staged_setup
    outs = simulate_staged(
        template, dag, wan, make_staged_policy(dag, wan),
        jax.random.key(0), scalar=cfg.v,
    )
    arrived = float(template.arrivals.sum())
    finished = float(outs.completed.sum())
    queued = float(outs.q_final.sum())
    assert finished + queued == pytest.approx(arrived, rel=1e-5)
    assert bool(jnp.all(outs.q_final >= 0.0))
    # Padded stages hold no backlog.
    mask = np.asarray(dag.stage_mask)                    # (K, S)
    qf = np.asarray(outs.q_final)                        # (N, K, S)
    assert float(qf[:, mask < 0.5].sum()) == 0.0


def test_shuffle_billing_matches_transfer_plan(paper_setup):
    """One slot of the engine bills exactly transfer_cost(transfer_plan(...))
    of the realized stage flows — the placement layer's WAN semantics."""
    cfg, template, _, wan = paper_setup
    k_types = cfg.k_types
    dag = map_reduce_dag(k_types, intermediate_gb=20.0, map_share=0.5)
    # Deterministic two-slot trace: all mass arrives in slot 0.
    t = 2
    n = cfg.n_sites
    arrivals = jnp.zeros((t, k_types)).at[0].set(10.0)
    mu = jnp.full((t, n, k_types), 50.0)
    inputs = template._replace(
        arrivals=arrivals, mu=mu,
        omega=template.omega[:t], pue=template.pue[:t],
    )
    pol = stage_oblivious(gmsa_policy, pin_map=True)
    outs = simulate_staged(inputs, dag, wan, pol, jax.random.key(0),
                           scalar=1.0)
    # Slot 0: map completes min(10*d, mu/0.5) = 10*d at the data sites; the
    # whole 10-job batch shuffles 20 GB/job into the reduce site chosen by
    # the policy (columns of f[...,1]).
    f1 = np.asarray(outs.f_trace[0, :, :, 1])            # (N, K)
    src = np.asarray(inputs.data_dist)                   # (K, N)
    vol = 10.0 * np.asarray(dag.shuffle_gb[:, 1])        # (K,)
    plan = transfer_plan(jnp.asarray(src), jnp.asarray(f1.T), jnp.asarray(vol))
    wc, wen, wgb = transfer_cost(plan, wan, inputs.omega[0], inputs.pue[0])
    assert float(outs.wan_cost[0]) == pytest.approx(float(wc), rel=1e-5)
    assert float(outs.wan_gb[0]) == pytest.approx(float(wgb), rel=1e-5)
    assert float(outs.wan_energy[0]) == pytest.approx(float(wen), rel=1e-5)
    assert float(outs.wan_gb[0]) > 0.0


def test_completed_jobs_drain_when_stable(staged_setup):
    """On the canonical (stable) scenario the chain drains: completions
    track arrivals and no stage queue diverges."""
    cfg, template, dag, wan, _ = staged_setup
    outs = simulate_staged(
        template, dag, wan, make_staged_policy(dag, wan),
        jax.random.key(1), scalar=cfg.v,
    )
    arrived = float(template.arrivals.sum())
    assert float(outs.completed.sum()) > 0.98 * arrived
    assert float(outs.backlog_total[-1]) < 0.02 * arrived


def test_stage_aware_beats_oblivious(staged_setup):
    """The benchmark claim at reduced Monte-Carlo scale: on the multi-stage
    mix, pricing the shuffle into the per-stage score beats the one-manager
    dispatch on total (compute + WAN) cost, with WAN GB reported."""
    cfg, template, dag, wan, build = staged_setup
    key = jax.random.key(0)
    res = {}
    for name, pol in [
        ("oblivious", stage_oblivious(gmsa_policy, pin_map=True)),
        ("aware", make_staged_policy(dag, wan)),
    ]:
        outs = simulate_staged_many(build, dag, wan, pol, key, 16,
                                    scalar=cfg.v)
        assert outs.cost.shape == (16, cfg.t_slots)
        res[name] = summarize_staged(outs)
    assert (res["aware"]["time_avg_total_cost"]
            < res["oblivious"]["time_avg_total_cost"]), res
    assert res["aware"]["total_wan_gb"] > 0.0
    assert res["oblivious"]["total_wan_gb"] > 0.0
    # The win is routing, not starvation: the aware arm completes at least
    # as much work.
    assert (res["aware"]["jobs_completed"]
            >= 0.999 * res["oblivious"]["jobs_completed"])


def test_staged_composes_with_simulate_placed(staged_setup):
    """Slow-loop re-placement reshapes map locality: the controller's
    evolving placements/ratios replay through the staged engine as
    time-varying inputs, and moving data off the expensive drift target
    cuts the staged bill."""
    from repro.core.baselines import static_placement_rule
    from repro.placement import (
        PlacementConfig,
        make_adaptive_rule,
        simulate_placed,
    )
    from repro.traces.drift import ingest_drift_trace

    cfg, template, dag, wan, _ = staged_setup
    w = 48
    n_epochs = cfg.t_slots // w
    ingest = ingest_drift_trace(
        jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites,
        bias=jnp.array([0.05, 0.8, 0.05, 0.10]), bias_strength=0.5,
    )
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25, dataset_gb=cfg.input_gb,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    key = jax.random.key(1)
    pol = dispatch_fn(cfg.v)
    aware = make_staged_policy(dag, wan)
    totals = {}
    for arm, rule in [("static", static_placement_rule),
                      ("adaptive", make_adaptive_rule(wan.up))]:
        placed = simulate_placed(
            template, wan.up, wan.down, pol, rule, key, pcfg, ingest=ingest
        )
        staged_inputs = template._replace(
            data_dist=jnp.repeat(placed.placements, w, axis=0),
            r=jnp.repeat(placed.r_trace, w, axis=0),
        )
        outs = simulate_staged(staged_inputs, dag, wan, aware, key,
                               scalar=cfg.v)
        totals[arm] = summarize_staged(outs)["time_avg_total_cost"]
        # The time-varying path conserves jobs too.
        assert (float(outs.completed.sum()) + float(outs.q_final.sum())
                == pytest.approx(float(template.arrivals.sum()), rel=1e-5))
    assert totals["adaptive"] < totals["static"], totals


def test_returns_flow_export_matches_engine_recursion(staged_setup):
    """A returns_flow policy's exported inflows reproduce the engine's own
    within-slot flow recursion exactly: stripping the export (forcing the
    engine to re-derive the chain) changes nothing."""
    cfg, template, dag, wan, _ = staged_setup
    aware = make_staged_policy(dag, wan)

    def stripped(key, q, arrivals, mu, e, aux, scalar):
        return aware(key, q, arrivals, mu, e, aux, scalar)[0]

    stripped.staged = True
    stripped.consumes_key = False
    key = jax.random.key(4)
    o_exp = simulate_staged(template, dag, wan, aware, key, scalar=cfg.v)
    o_rec = simulate_staged(template, dag, wan, stripped, key, scalar=cfg.v)
    for field in o_exp._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(o_exp, field)),
            np.asarray(getattr(o_rec, field)),
            rtol=1e-6, err_msg=field,
        )


def test_staged_many_shapes_and_determinism(staged_setup):
    cfg, template, dag, wan, build = staged_setup
    pol = make_staged_policy(dag, wan)
    o1 = simulate_staged_many(build, dag, wan, pol, jax.random.key(5), 4,
                              scalar=cfg.v)
    o2 = simulate_staged_many(build, dag, wan, pol, jax.random.key(5), 4,
                              scalar=cfg.v)
    assert o1.f_trace.shape == (4, cfg.t_slots, cfg.n_sites, cfg.k_types,
                                dag.s_max)
    np.testing.assert_array_equal(np.asarray(o1.cost), np.asarray(o2.cost))


# Hypothesis property tests (stage-flow conservation, shuffle billing vs.
# transfer_plan, random single-stage bit-exactness) live in
# tests/test_jobs_properties.py — slow-marked, nightly CI job.
