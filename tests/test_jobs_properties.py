"""Hypothesis properties of the staged-jobs engine (slow / nightly suite).

Pinned invariants, over random dags, traces and WAN topologies:

* stage-flow conservation — every arrival either completes its last stage
  or sits in some stage queue at the horizon;
* shuffle-volume billing — the engine's per-slot WAN bill equals
  re-deriving ``transfer_cost(transfer_plan(...))`` over the realized
  stage flows (the placement layer's semantics, to the byte);
* single-stage degeneration — a trivial one-stage dag is bit-exact with
  ``repro.core.simulator.simulate``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.gmsa import dispatch_fn
from repro.core.simulator import SimInputs, simulate
from repro.jobs import (
    flow_step,
    make_staged_policy,
    pad_chains,
    simulate_staged,
    single_stage_dag,
    stage_service_rates,
)
from repro.placement.wan import transfer_cost, transfer_plan, wan_topology


def _random_case(seed, n, k, s, t):
    """A small random staged scenario (deterministic in seed)."""
    rng = np.random.default_rng(seed)
    arrivals = jnp.asarray(rng.integers(0, 20, (t, k)), jnp.float32)
    mu = jnp.asarray(rng.uniform(1.0, 30.0, (t, n, k)), jnp.float32)
    omega = jnp.asarray(rng.uniform(10.0, 60.0, (t, n)), jnp.float32)
    pue = jnp.asarray(rng.uniform(1.0, 1.3, (t, n)), jnp.float32)
    dd = jnp.asarray(rng.dirichlet(np.ones(n), k), jnp.float32)
    r = jnp.asarray(rng.dirichlet(np.ones(n), (k, n)), jnp.float32)
    p_it = jnp.asarray(rng.uniform(0.5, 2.0, (k,)), jnp.float32)
    inputs = SimInputs(arrivals, mu, omega, pue, r, p_it, dd)
    depths = rng.integers(1, s + 1, k)
    computes = [list(rng.uniform(0.2, 1.0, d)) for d in depths]
    shuffles = [[0.0] + list(rng.uniform(0.0, 40.0, d - 1)) for d in depths]
    dag = pad_chains(computes, shuffles)
    up = jnp.asarray(rng.uniform(0.2, 2.0, (n,)), jnp.float32)
    down = jnp.asarray(rng.uniform(0.2, 2.0, (n,)), jnp.float32)
    return inputs, dag, wan_topology(up, down, energy_per_gb=0.03)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 5),
       k=st.integers(1, 4), s=st.integers(1, 4))
def test_prop_stage_flow_conservation(seed, n, k, s):
    """Arrivals = completions + final backlog, for random dags/traces."""
    inputs, dag, wan = _random_case(seed, n, k, s, t=16)
    outs = simulate_staged(
        inputs, dag, wan, make_staged_policy(dag, wan),
        jax.random.key(seed % 1000), scalar=5.0,
    )
    arrived = float(inputs.arrivals.sum())
    got = float(outs.completed.sum()) + float(outs.q_final.sum())
    assert got == pytest.approx(arrived, rel=1e-4, abs=1e-3)
    assert bool(jnp.all(outs.q_final >= 0.0))
    assert bool(jnp.all(outs.wan_gb >= 0.0))


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 4),
       k=st.integers(1, 3))
def test_prop_single_stage_bit_exact(seed, n, k):
    """Random single-stage scenarios are bit-exact with `simulate`."""
    inputs, _, wan = _random_case(seed, n, k, s=1, t=12)
    dag = single_stage_dag(k)
    key = jax.random.key(seed % 997)
    pol = dispatch_fn(2.0)
    o_s = simulate(inputs, pol, key)
    o_j = simulate_staged(inputs, dag, wan, pol, key)
    np.testing.assert_array_equal(np.asarray(o_s.cost), np.asarray(o_j.cost))
    np.testing.assert_array_equal(
        np.asarray(o_s.q_final), np.asarray(o_j.q_final[..., 0])
    )
    assert float(o_j.wan_cost.sum()) == 0.0


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 4),
       k=st.integers(1, 3), s=st.integers(2, 4))
def test_prop_shuffle_billing_matches_transfer_plan(seed, n, k, s):
    """The engine's per-slot WAN bill equals re-deriving transfer_cost over
    the realized flows, for random multi-stage scenarios."""
    inputs, dag, wan = _random_case(seed, n, k, s, t=6)
    pol = make_staged_policy(dag, wan)
    outs = simulate_staged(inputs, dag, wan, pol, jax.random.key(0),
                           scalar=5.0)
    # Replay slot 0 by hand: stage flows from the recorded dispatch.
    q = jnp.zeros((n, k, dag.s_max))
    f = outs.f_trace[0]
    mu_st = stage_service_rates(inputs.mu[0], dag)
    total_in, src = inputs.arrivals[0], inputs.data_dist
    wan_cost = 0.0
    for stage in range(dag.s_max):
        vol = total_in * dag.shuffle_gb[:, stage]
        plan = transfer_plan(src, f[:, :, stage].T, vol)
        wc, _, _ = transfer_cost(plan, wan, inputs.omega[0], inputs.pue[0])
        wan_cost += float(wc)
        total_done, src = flow_step(
            q[:, :, stage], f[:, :, stage], total_in, mu_st[:, :, stage]
        )
        if stage + 1 < dag.s_max:
            total_in = total_done * dag.stage_mask[:, stage + 1]
    assert float(outs.wan_cost[0]) == pytest.approx(
        wan_cost, rel=1e-4, abs=1e-4
    )
