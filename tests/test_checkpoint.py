"""Checkpoint/restart + fault-tolerance tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    FailureInjector,
    SimulatedFailure,
    restore_tree,
    run_with_restarts,
    save_tree,
)
from repro.traces.tokens import SyntheticTokenStream, TokenPipelineConfig


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    save_tree(tmp_path, 3, tree, {"note": "hi"})
    restored, meta = restore_tree(tmp_path, 3, like=tree)
    assert meta == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checksum_detects_corruption(tmp_path):
    tree = _tree()
    final = save_tree(tmp_path, 1, tree)
    victim = sorted(final.glob("*.npy"))[0]
    arr = np.load(victim)
    arr_flat = arr.reshape(-1)
    arr_flat[arr_flat.size // 2] += 1
    np.save(victim, arr)
    with pytest.raises(IOError, match="checksum"):
        restore_tree(tmp_path, 1, like=tree)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, save_interval=10)
    for s in (10, 20, 30, 40):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [30, 40]
    assert mgr.latest_step() == 40


def test_run_with_restarts_replays_identically(tmp_path):
    """Failure + restart reproduces the exact no-failure trajectory."""

    def init_state():
        return {"x": jnp.float32(0.0), "hist": jnp.zeros((64,))}

    def step_fn(state, step):
        rng = np.random.default_rng((7, step))   # seeded-by-step pipeline
        inc = float(rng.uniform())
        return {
            "x": state["x"] + inc,
            "hist": state["hist"].at[step].set(inc),
        }

    clean, _ = run_with_restarts(
        init_state, step_fn, CheckpointManager(tmp_path / "a", save_interval=16),
        total_steps=50,
    )
    failed, stats = run_with_restarts(
        init_state, step_fn, CheckpointManager(tmp_path / "b", save_interval=16),
        total_steps=50,
        injector=FailureInjector(fail_at_steps=(23, 41)),
    )
    assert stats["restarts"] == 2
    assert stats["replayed_steps"] == (23 - 16) + (41 - 32)
    np.testing.assert_allclose(clean["x"], failed["x"], rtol=1e-6)
    np.testing.assert_array_equal(clean["hist"], failed["hist"])


def test_injector_exhausts_restarts(tmp_path):
    injector = FailureInjector(fail_at_steps=(0,))
    with pytest.raises(SimulatedFailure):
        run_with_restarts(
            lambda: {"x": jnp.float32(0)},
            lambda s, i: s,
            CheckpointManager(tmp_path, save_interval=100),
            total_steps=5, injector=injector, max_restarts=0,
        )


def test_token_pipeline_restartable():
    """Stream step s is a pure function of (seed, s) — restart-safe."""
    cfg = TokenPipelineConfig(vocab_size=128, seq_len=32, global_batch=4, seed=9)
    a = SyntheticTokenStream(cfg).batch(17)
    b = SyntheticTokenStream(cfg).batch(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokenStream(cfg).batch(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_sharding_partitions_batch():
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=8, seed=3)
    stream = SyntheticTokenStream(cfg)
    full = stream.batch(0)["tokens"]
    shards = []
    for h in range(4):
        it = stream.shard_iterator(h, 4)
        shards.append(next(it)["tokens"])
    merged = np.empty_like(full)
    for h in range(4):
        merged[h::4] = shards[h]
    np.testing.assert_array_equal(merged, full)


def test_async_save_overlaps_and_restores(tmp_path):
    """save_async: non-blocking write; wait()/restore() join correctly; the
    snapshot is taken at call time (later mutations don't leak in)."""
    mgr = CheckpointManager(tmp_path, save_interval=1)
    tree = {"x": jnp.arange(8, dtype=jnp.float32)}
    mgr.save_async(3, tree)
    tree["x"] = tree["x"] + 100.0   # mutate after snapshot
    mgr.wait()
    restored, _, step = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(8))
