"""Checkpoint/restart + fault-tolerance tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    FailureInjector,
    SimulatedFailure,
    restore_tree,
    run_with_restarts,
    save_tree,
)
from repro.traces.tokens import SyntheticTokenStream, TokenPipelineConfig


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    save_tree(tmp_path, 3, tree, {"note": "hi"})
    restored, meta = restore_tree(tmp_path, 3, like=tree)
    assert meta == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checksum_detects_corruption(tmp_path):
    tree = _tree()
    final = save_tree(tmp_path, 1, tree)
    victim = sorted(final.glob("*.npy"))[0]
    arr = np.load(victim)
    arr_flat = arr.reshape(-1)
    arr_flat[arr_flat.size // 2] += 1
    np.save(victim, arr)
    with pytest.raises(IOError, match="checksum"):
        restore_tree(tmp_path, 1, like=tree)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, save_interval=10)
    for s in (10, 20, 30, 40):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [30, 40]
    assert mgr.latest_step() == 40


def test_run_with_restarts_replays_identically(tmp_path):
    """Failure + restart reproduces the exact no-failure trajectory."""

    def init_state():
        return {"x": jnp.float32(0.0), "hist": jnp.zeros((64,))}

    def step_fn(state, step):
        rng = np.random.default_rng((7, step))   # seeded-by-step pipeline
        inc = float(rng.uniform())
        return {
            "x": state["x"] + inc,
            "hist": state["hist"].at[step].set(inc),
        }

    clean, _ = run_with_restarts(
        init_state, step_fn, CheckpointManager(tmp_path / "a", save_interval=16),
        total_steps=50,
    )
    failed, stats = run_with_restarts(
        init_state, step_fn, CheckpointManager(tmp_path / "b", save_interval=16),
        total_steps=50,
        injector=FailureInjector(fail_at_steps=(23, 41)),
    )
    assert stats["restarts"] == 2
    assert stats["replayed_steps"] == (23 - 16) + (41 - 32)
    np.testing.assert_allclose(clean["x"], failed["x"], rtol=1e-6)
    np.testing.assert_array_equal(clean["hist"], failed["hist"])


def test_failure_schedule_is_pure_in_seed_and_step():
    """The probability path derives firing purely from (seed, step): every
    injector built with the same config sees the identical outage schedule,
    regardless of call order or how many times a step is queried."""
    a = FailureInjector(probability=0.2, seed=42)
    b = FailureInjector(probability=0.2, seed=42)
    sched_a = [a.fails_at(s) for s in range(200)]
    sched_b = [b.fails_at(s) for s in reversed(range(200))][::-1]
    assert sched_a == sched_b
    assert any(sched_a) and not all(sched_a)
    # Re-querying the same step never re-rolls a different coin.
    assert all(a.fails_at(7) == a.fails_at(7) for _ in range(5))
    # A different seed gives a different schedule.
    c = FailureInjector(probability=0.2, seed=43)
    assert sched_a != [c.fails_at(s) for s in range(200)]


def test_restarted_process_replays_identical_failure_schedule(tmp_path):
    """Process death + fresh injector: the restarted run must not
    re-experience failures the original already survived (the fired set
    travels through checkpoint metadata), and must reach the exact state
    of a never-failed run."""

    def init_state():
        return {"x": jnp.float32(0.0), "hist": jnp.zeros((64,))}

    def step_fn(state, step):
        rng = np.random.default_rng((7, step))   # seeded-by-step pipeline
        inc = float(rng.uniform())
        return {
            "x": state["x"] + inc,
            "hist": state["hist"].at[step].set(inc),
        }

    # Seed chosen so the schedule fires in both halves of the run
    # (fails_at(seed=0) -> steps 7, 29, 38, 53).
    seed, prob, total = 0, 0.04, 60
    probe = FailureInjector(probability=prob, seed=seed)
    sched = [s for s in range(total) if probe.fails_at(s)]
    assert sched, "pick a seed whose schedule actually fires"

    clean, _ = run_with_restarts(
        init_state, step_fn, CheckpointManager(tmp_path / "a", save_interval=8),
        total_steps=total,
    )

    # Process 1: survives its scheduled failures (in-memory fired set),
    # checkpoints along the way, then "dies" for good mid-run.
    mgr_dir = tmp_path / "b"
    injector1 = FailureInjector(probability=prob, seed=seed)
    half = max(sched[0] + 8, total // 2)
    state1, stats1 = run_with_restarts(
        init_state, step_fn, CheckpointManager(mgr_dir, save_interval=8),
        total_steps=half, injector=injector1,
    )
    fired_before = set(injector1.fired_steps())
    assert stats1["restarts"] == len([s for s in sched if s < half])

    # Process 2: a FRESH injector (empty in-memory state) resumes from the
    # on-disk checkpoint. Failures already survived before the checkpoint
    # must not fire again on replay; later scheduled ones still do.
    injector2 = FailureInjector(probability=prob, seed=seed)
    mgr2 = CheckpointManager(mgr_dir, save_interval=8)
    resumed_at = mgr2.latest_step()
    failed, stats2 = run_with_restarts(
        init_state, step_fn, mgr2, total_steps=total, injector=injector2,
    )
    replayed_old = [s for s in fired_before if s >= resumed_at]
    fresh = [s for s in sched if s >= half]
    assert stats2["restarts"] == len(replayed_old) + len(fresh), (
        sched, resumed_at, stats2
    )
    np.testing.assert_allclose(clean["x"], failed["x"], rtol=1e-6)
    np.testing.assert_array_equal(clean["hist"], failed["hist"])


def test_injector_exhausts_restarts(tmp_path):
    injector = FailureInjector(fail_at_steps=(0,))
    with pytest.raises(SimulatedFailure):
        run_with_restarts(
            lambda: {"x": jnp.float32(0)},
            lambda s, i: s,
            CheckpointManager(tmp_path, save_interval=100),
            total_steps=5, injector=injector, max_restarts=0,
        )


def test_token_pipeline_restartable():
    """Stream step s is a pure function of (seed, s) — restart-safe."""
    cfg = TokenPipelineConfig(vocab_size=128, seq_len=32, global_batch=4, seed=9)
    a = SyntheticTokenStream(cfg).batch(17)
    b = SyntheticTokenStream(cfg).batch(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokenStream(cfg).batch(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_sharding_partitions_batch():
    cfg = TokenPipelineConfig(vocab_size=64, seq_len=16, global_batch=8, seed=3)
    stream = SyntheticTokenStream(cfg)
    full = stream.batch(0)["tokens"]
    shards = []
    for h in range(4):
        it = stream.shard_iterator(h, 4)
        shards.append(next(it)["tokens"])
    merged = np.empty_like(full)
    for h in range(4):
        merged[h::4] = shards[h]
    np.testing.assert_array_equal(merged, full)


def test_async_save_overlaps_and_restores(tmp_path):
    """save_async: non-blocking write; wait()/restore() join correctly; the
    snapshot is taken at call time (later mutations don't leak in)."""
    mgr = CheckpointManager(tmp_path, save_interval=1)
    tree = {"x": jnp.arange(8, dtype=jnp.float32)}
    mgr.save_async(3, tree)
    tree["x"] = tree["x"] + 100.0   # mutate after snapshot
    mgr.wait()
    restored, _, step = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(8))
