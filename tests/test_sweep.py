"""One-launch sweep grids (repro.core.sweep) + the traced move budget.

The contract: a vmapped grid must reproduce the per-point launches —
same keys, same traces, same numbers — it only changes how many device
programs run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import static_placement_rule
from repro.core.gmsa import dispatch_fn, gmsa_policy
from repro.core.simulator import simulate, simulate_many
from repro.core.sweep import simulate_sweep, sweep_grid, sweep_placed_budgets
from repro.placement import (
    PlacementConfig,
    make_adaptive_rule,
    simulate_placed_many,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.drift import ingest_drift_trace

V_POINTS = (0.01, 1.0, 100.0)


@pytest.fixture(scope="module")
def paper_setup():
    cfg = PaperSimConfig()
    template, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)
    return cfg, template, build, up, down


def test_simulate_sweep_matches_per_point(paper_setup):
    cfg, template, _, _, _ = paper_setup
    key = jax.random.key(5)
    grid = simulate_sweep(template, gmsa_policy, key, V_POINTS)
    assert grid.cost.shape == (len(V_POINTS), cfg.t_slots)
    for i, v in enumerate(V_POINTS):
        per = simulate(template, gmsa_policy, key, v)
        np.testing.assert_allclose(
            np.asarray(grid.cost[i]), np.asarray(per.cost), rtol=1e-6
        )
        np.testing.assert_array_equal(
            np.asarray(grid.f_trace[i]), np.asarray(per.f_trace)
        )


def test_sweep_grid_matches_per_point_monte_carlo(paper_setup):
    cfg, _, build, _, _ = paper_setup
    key = jax.random.key(43)
    n_runs = 8
    grid = sweep_grid(build, gmsa_policy, key, n_runs, V_POINTS)
    assert grid.cost.shape == (len(V_POINTS), n_runs, cfg.t_slots)
    for i, v in enumerate(V_POINTS):
        per = simulate_many(build, gmsa_policy, key, n_runs, scalar=v)
        np.testing.assert_allclose(
            np.asarray(grid.cost[i]), np.asarray(per.cost), rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(grid.backlog_avg[i]), np.asarray(per.backlog_avg),
            rtol=1e-6,
        )


def test_sweep_grid_v_monotonicity(paper_setup):
    """The Fig.-6 structure survives the one-launch migration: cost falls
    with V, backlog rises."""
    _, _, build, _, _ = paper_setup
    grid = sweep_grid(build, gmsa_policy, jax.random.key(43), 16, V_POINTS)
    costs = [float(grid.cost[i].mean()) for i in range(len(V_POINTS))]
    backlogs = [float(grid.backlog_avg[i].mean())
                for i in range(len(V_POINTS))]
    assert costs[0] >= costs[1] >= costs[2] * 0.99
    assert backlogs[-1] >= backlogs[0]


def test_sweep_placed_budgets_matches_per_budget(paper_setup):
    cfg, _, build, up, down = paper_setup
    w = 48
    n_epochs = cfg.t_slots // w
    ing = ingest_drift_trace(jax.random.key(7), n_epochs, cfg.k_types,
                             cfg.n_sites)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    key = jax.random.key(3)
    pol = dispatch_fn(cfg.v)
    rule = make_adaptive_rule(up)
    budgets = (0.25, 1.0)
    grid = sweep_placed_budgets(
        build, up, down, pol, rule, key, 4, pcfg, budgets, ingest=ing
    )
    assert grid.cost.shape == (len(budgets), 4, cfg.t_slots)
    for i, b in enumerate(budgets):
        per = simulate_placed_many(
            build, up, down, pol, rule, key, 4, pcfg, ingest=ing,
            move_budget=jnp.float32(b),
        )
        np.testing.assert_allclose(
            np.asarray(grid.cost[i]), np.asarray(per.cost), rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(grid.wan_gb[i]), np.asarray(per.wan_gb), rtol=1e-5
        )
    # A bigger correction step chases the drift with more WAN churn.
    assert (float(grid.wan_gb[1].sum()) > float(grid.wan_gb[0].sum()))


def test_move_budget_override_none_matches_config(paper_setup):
    """move_budget=None (static config) == passing the same value traced,
    and the None path keeps the pre-override W >= T bit-exactness (pinned
    separately in test_placement.py)."""
    cfg, _, build, up, down = paper_setup
    pcfg = PlacementConfig(
        epoch_slots=48, move_budget=0.5,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    key = jax.random.key(9)
    pol = dispatch_fn(1.0)
    rule = make_adaptive_rule(up)
    a = simulate_placed_many(build, up, down, pol, rule, key, 4, pcfg)
    b = simulate_placed_many(build, up, down, pol, rule, key, 4, pcfg,
                             move_budget=jnp.float32(0.5))
    for field in a._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
            rtol=1e-6, err_msg=field,
        )
