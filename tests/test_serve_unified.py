"""The unified serving control plane: FleetEngine driven by the sim stack.

The contracts pinned here are the point of the serving refactor:

* **replay parity** — a dispatch-only run replays ``simulate_staged`` on
  the shared scenario: per-slot dispatch choices bit-for-bit, billed cost
  to float tolerance;
* **seed determinism** — same config, same traces, same decisions;
* **request conservation** — raw arrivals split exactly into
  admitted + rejected, and admitted mass ends as completed + backlog;
* **served-priced energy** — ``history["energy_j"]`` bills jobs actually
  served (``min(q + f·A, mu)``, compute-weighted), never more than
  admitted;
* **capacity_shares derivation** — ``n_pods=8`` runs end-to-end instead
  of silently truncating (or crashing in) the shares tuple;
* **exact execution counts** — ``_execute_jobs`` runs exactly ``n_jobs``,
  not the next multiple of ``batch_per_exec``;
* **pod-death recovery** — the drain wipes the dead pod, re-injects its
  backlog at the prefill stage, lands a recovery event in the history and
  the telemetry stream, and an all-ones mask is bit-exact no-fault.
"""

import jax
import numpy as np
import pytest

from repro.jobs.engine import simulate_staged
from repro.launch.serve import build_engine
from repro.serve.engine import (
    FleetConfig,
    FleetEngine,
    build_serve_scenario,
    serve_policy,
)


@pytest.fixture(scope="module")
def engine():
    return build_engine(["qwen2-0.5b", "mamba2-2.7b"], slots=12, v=1.0,
                        seed=3, arrival=4.0, admit_max=5.0)


@pytest.fixture(scope="module")
def out(engine):
    return engine.run(execute_real=False)


# ---------------------------------------------------------------------------
# Replay parity and determinism
# ---------------------------------------------------------------------------

def test_dispatch_replays_simulate_staged(engine, out):
    """The parity pin: FleetEngine.run is simulate_staged on the shared
    scenario — same per-slot dispatch vertices, same bills."""
    scn = engine.scenario
    pol = serve_policy(engine.fcfg, scn)
    outs = simulate_staged(
        scn.inputs, scn.dag, scn.wan, pol, jax.random.key(0), engine.fcfg.v
    )
    np.testing.assert_array_equal(out["dispatch"], np.asarray(outs.f_trace))
    np.testing.assert_allclose(
        out["cost"], np.asarray(outs.cost), rtol=1e-5, atol=1e-12
    )
    np.testing.assert_array_equal(out["wan_cost"], np.asarray(outs.wan_cost))
    sim_total = float(
        np.asarray(outs.cost).sum() + np.asarray(outs.wan_cost).sum()
    )
    assert out["total_billed_cost"] == pytest.approx(sim_total, rel=1e-6)
    np.testing.assert_allclose(
        out["backlog"], np.asarray(outs.backlog_total), rtol=1e-5, atol=1e-5
    )


def test_seed_determinism(engine, out):
    eng2 = build_engine(["qwen2-0.5b", "mamba2-2.7b"], slots=12, v=1.0,
                        seed=3, arrival=4.0, admit_max=5.0)
    out2 = eng2.run(execute_real=False)
    np.testing.assert_array_equal(out["dispatch"], out2["dispatch"])
    np.testing.assert_array_equal(out["cost"], out2["cost"])
    np.testing.assert_array_equal(out["raw_arrivals"], out2["raw_arrivals"])
    # A different seed draws different traffic.
    eng3 = build_engine(["qwen2-0.5b", "mamba2-2.7b"], slots=12, v=1.0,
                        seed=4, arrival=4.0, admit_max=5.0)
    assert not np.array_equal(
        eng3.scenario.raw_arrivals, out["raw_arrivals"]
    )


# ---------------------------------------------------------------------------
# Conservation and the accounting fixes
# ---------------------------------------------------------------------------

def test_request_conservation(engine, out):
    # Admission split is exact, elementwise.
    np.testing.assert_array_equal(
        out["raw_arrivals"], out["admitted"] + out["rejected"]
    )
    assert out["rejected"].sum() > 0          # the cap actually binds here
    assert (out["admitted"] <= engine.fcfg.admit_max + 1e-6).all()
    # Everything admitted is either completed or still queued.
    np.testing.assert_allclose(
        out["admitted"].sum(axis=0),
        out["completed"].sum(axis=0) + out["q_final"].sum(axis=(0, 2)),
        rtol=1e-5, atol=1e-3,
    )


def test_energy_prices_served_not_dispatched(engine, out):
    e_per_job = np.asarray([rc.energy_per_job_j() for rc in engine.classes])
    hist_e = np.asarray([h["energy_j"] for h in out["history"]])   # (T, K)
    np.testing.assert_allclose(
        hist_e, out["served"] * e_per_job[None, :], rtol=1e-6
    )
    # Never bill more than the admitted mass (the old engine billed every
    # dispatched job even when execution capped far below).
    assert (
        hist_e.sum(axis=0) <= e_per_job * out["admitted"].sum(axis=0) + 1e-6
    ).all()
    # With positive backlog at some slot, served < dispatched mass there.
    assert out["served"].sum() < out["admitted"].sum() + 1e-6


def test_execute_jobs_exact_count(engine):
    rc = engine.classes[0]
    b = engine.fcfg.batch_per_exec
    for n_jobs in (1, b - 1, b, b + 1, 2 * b + 3):
        done, secs = engine._execute_jobs(rc, n_jobs)
        assert done == n_jobs, (n_jobs, done)
    assert engine._execute_jobs(rc, 0) == (0, 0.0)


# ---------------------------------------------------------------------------
# FleetConfig shares derivation
# ---------------------------------------------------------------------------

def test_capacity_shares_derived_for_any_pod_count():
    fc = FleetConfig(n_pods=8)
    assert len(fc.capacity_shares) == 8
    assert fc.capacity_shares[:4] == fc.capacity_shares[4:]   # cycled
    fc3 = FleetConfig(n_pods=3)
    assert fc3.capacity_shares == (0.3, 0.2, 0.9)
    with pytest.raises(ValueError):
        FleetConfig(n_pods=2, capacity_shares=())
    with pytest.raises(ValueError):
        FleetConfig(dispatch="magic")


def test_eight_pods_run_end_to_end():
    eng = build_engine(["qwen2-0.5b"], slots=8, v=1.0, seed=1, arrival=4.0,
                       n_pods=8)
    out = eng.run(execute_real=False)
    assert out["dispatch"].shape == (8, 8, 1, 2)
    np.testing.assert_allclose(out["dispatch"].sum(axis=1), 1.0, atol=1e-5)
    assert np.isfinite(out["cost"]).all()


# ---------------------------------------------------------------------------
# Pod death: drain, re-injection, telemetry
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fault_run():
    base = build_engine(["qwen2-0.5b"], slots=12, v=1.0, seed=3, arrival=6.0)
    # Slow pods down so the dying pod carries backlog at the edge.
    fcfg = FleetConfig(
        n_pods=4, horizon_slots=12, v=1.0, seed=3,
        capacity_shares=(0.1, 0.1, 0.1, 0.1),
    )
    dead, t_die = 1, 6
    alive = np.ones((12, 4), np.float32)
    alive[t_die:, dead] = 0.0
    eng = FleetEngine(fcfg, base.classes, base.omega, base.pue, base.r,
                      alive=alive)
    stream = []
    out = eng.run(execute_real=False, stream=stream.append)
    jax.effects_barrier()
    return eng, out, stream, dead, t_die


def test_pod_death_drains_and_reinjects(fault_run):
    eng, out, _, dead, t_die = fault_run
    f = out["dispatch"]
    assert float(np.abs(f[t_die:, dead]).max()) == 0.0       # no new work
    assert float(np.abs(f[:t_die, dead]).max()) > 0.0        # busy before
    np.testing.assert_allclose(f.sum(axis=1), 1.0, atol=1e-5)
    # The wiped queue re-enters as a prefill burst: nothing admitted is lost.
    np.testing.assert_allclose(
        out["admitted"].sum(axis=0),
        out["completed"].sum(axis=0) + out["q_final"].sum(axis=(0, 2)),
        rtol=1e-4, atol=1e-2,
    )
    assert float(out["q_final"][dead].sum()) == 0.0
    ev = out["events"]
    assert len(ev) == 1 and ev[0]["t"] == t_die and ev[0]["pod"] == dead
    assert ev[0]["drained"] > 0.0                            # real backlog
    assert out["history"][t_die]["recovery"]["code"] == "recovery"


def test_recovery_event_reaches_stream_in_order(fault_run):
    _, out, stream, dead, t_die = fault_run
    kinds = [(r["type"], r["t"]) for r in stream]
    assert ("event", t_die) in kinds
    # The event lands at its slot position within the ordered stream.
    idx = kinds.index(("event", t_die))
    assert kinds[idx - 1] == ("metric", t_die)
    ev = stream[idx]
    assert ev["code"] == "recovery" and ev["pod"] == dead
    metrics = [r for r in stream if r["type"] == "metric"]
    assert [r["t"] for r in metrics] == list(range(12))


def test_all_ones_alive_is_bit_exact(engine, out):
    ones = np.ones((12, 4), np.float32)
    eng = FleetEngine(engine.fcfg, engine.classes, engine.omega, engine.pue,
                      engine.r, alive=ones)
    out1 = eng.run(execute_real=False)
    np.testing.assert_array_equal(out["dispatch"], out1["dispatch"])
    np.testing.assert_array_equal(out["cost"], out1["cost"])
    np.testing.assert_array_equal(out["wan_cost"], out1["wan_cost"])
    assert out1["events"] == []


# ---------------------------------------------------------------------------
# Scenario construction details
# ---------------------------------------------------------------------------

def test_replica_reads_route_prefill(engine):
    scn = engine.scenario
    reads = np.asarray(scn.reads)                            # (K, N, N)
    np.testing.assert_allclose(reads.sum(axis=-1), 1.0, atol=1e-5)
    serve_dist = np.asarray(scn.inputs.data_dist)
    np.testing.assert_allclose(serve_dist, reads.mean(axis=1), atol=1e-6)
    # Prefill dispatch is pinned to the serving distribution every slot.
    out = engine.run(execute_real=False)
    for t in range(12):
        np.testing.assert_allclose(
            out["dispatch"][t][:, :, 0], serve_dist.T, atol=1e-6
        )


def test_kv_handoff_priced_when_decode_moves(engine, out):
    scn = engine.scenario
    kv = np.asarray(scn.dag.shuffle_gb)
    assert (kv[:, 0] == 0.0).all() and (kv[:, 1] > 0.0).all()
    # Decode sometimes lands off the prefill mix, so the KV bill is real.
    assert out["wan_gb"].sum() > 0.0


def test_fleet_records_stream(engine, out):
    from repro.telemetry import fleet_records

    recs = fleet_records(out, meta={"slo_backlog": engine.fcfg.slo_backlog})
    assert recs[0]["type"] == "meta" and recs[0]["kind"] == "serve"
    metrics = [r for r in recs if r["type"] == "metric"]
    assert [r["t"] for r in metrics] == list(range(12))
    assert recs[-1]["type"] == "summary"
    assert recs[-1]["total_billed_cost"] == pytest.approx(
        out["total_billed_cost"]
    )
