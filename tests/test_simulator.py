"""Trace-driven simulator + paper-claim integration tests (reduced runs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import (
    data_dispatch,
    greedy_cost_dispatch,
    random_dispatch,
)
from repro.core.gmsa import dispatch_fn
from repro.core.simulator import simulate, simulate_many, summarize


@pytest.fixture(scope="module")
def builder():
    cfg = PaperSimConfig()
    template, build = make_sim_builder(cfg)
    return cfg, template, build


def test_trace_shapes_and_ranges(builder):
    cfg, template, _ = builder
    assert template.omega.shape == (cfg.t_slots, cfg.n_sites)
    assert template.pue.shape == (cfg.t_slots, cfg.n_sites)
    assert bool(jnp.all(template.pue >= 1.0))
    assert bool(jnp.all(template.omega > 0))
    assert template.r.shape == (cfg.k_types, cfg.n_sites, cfg.n_sites)
    np.testing.assert_allclose(template.r.sum(-1), 1.0, atol=1e-5)
    assert float(template.arrivals.mean()) == pytest.approx(cfg.lam, rel=0.15)


def test_single_run_deterministic(builder):
    _, template, _ = builder
    k = jax.random.key(0)
    o1 = simulate(template, dispatch_fn(1.0), k)
    o2 = simulate(template, dispatch_fn(1.0), k)
    np.testing.assert_array_equal(o1.cost, o2.cost)


def test_paper_claims_reduced(builder):
    """Fig 5/6 qualitative claims at 48 Monte-Carlo runs (fast CI version;
    benchmarks/fig5.py + fig6.py run the full 1000)."""
    _, _, build = builder
    key = jax.random.key(1)
    res = {}
    for name, pol in [
        ("gmsa1", dispatch_fn(1.0)), ("gmsa100", dispatch_fn(100.0)),
        ("data", data_dispatch), ("random", random_dispatch),
        ("greedy", greedy_cost_dispatch),
    ]:
        res[name] = summarize(simulate_many(build, pol, key, 48))

    base = 0.5 * (res["data"]["time_avg_cost"] + res["random"]["time_avg_cost"])
    # ~30% cost reduction at large V (paper Fig. 6a)
    reduction = 1 - res["gmsa100"]["time_avg_cost"] / base
    assert 0.2 < reduction < 0.45, reduction
    # GMSA stable, baselines diverging (paper Fig. 5b)
    assert res["gmsa1"]["time_avg_backlog"] < 50
    assert res["data"]["time_avg_backlog"] > 4 * res["gmsa1"]["time_avg_backlog"]
    assert res["random"]["time_avg_backlog"] > 4 * res["gmsa1"]["time_avg_backlog"]
    # V trade-off: cost(V=100) < cost(V=1); backlog(V=100) > backlog(V=1)
    assert res["gmsa100"]["time_avg_cost"] < res["gmsa1"]["time_avg_cost"]
    assert res["gmsa100"]["time_avg_backlog"] > res["gmsa1"]["time_avg_backlog"]
    # GREEDY is the cost floor but pays in backlog
    assert res["greedy"]["time_avg_cost"] <= res["gmsa100"]["time_avg_cost"] + 1
    assert res["greedy"]["time_avg_backlog"] > res["gmsa100"]["time_avg_backlog"]


def test_unrolled_threefry_streams_bitwise_identical():
    """The CPU threefry lowering swap (repro.core.prngfast) must not move
    a single random bit: draws under the default rolled lowering (opt-out
    subprocess) equal this process's unrolled draws exactly."""
    import os
    import subprocess
    import sys

    from repro.core.prngfast import _INSTALLED

    if not _INSTALLED:
        pytest.skip("unrolled threefry not installed (non-CPU or opted out)")
    probe = (
        "import jax, numpy as np\n"
        "import repro  # noqa: F401  (opt-out env below keeps it rolled)\n"
        "k = jax.random.key(7)\n"
        "u = np.asarray(jax.random.uniform(k, (64, 5)))\n"
        "s = np.asarray(jax.random.key_data(jax.random.split(k, 3)))\n"
        "print(u.tobytes().hex()); print(s.tobytes().hex())\n"
    )
    env = dict(os.environ, REPRO_ROLLED_THREEFRY="1", JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(sys.path)
    out = subprocess.run(
        [sys.executable, "-c", probe], env=env,
        capture_output=True, text=True, check=True,
    ).stdout.splitlines()
    k = jax.random.key(7)
    u = np.asarray(jax.random.uniform(k, (64, 5)))
    s = np.asarray(jax.random.key_data(jax.random.split(k, 3)))
    assert out[0] == u.tobytes().hex()
    assert out[1] == s.tobytes().hex()


def test_elastic_drop_site(builder):
    """Losing a DC mid-horizon: system re-stabilizes on survivors."""
    from repro.checkpoint.fault import drop_site

    cfg, template, build = builder
    key = jax.random.key(2)
    inputs = build(key)
    outs = simulate(inputs, dispatch_fn(1.0), key)
    q = outs.q_final
    q2, r2, d2, burst = drop_site(q, inputs.r, inputs.data_dist, dead=3)
    assert q2.shape == (3, 1) and r2.shape == (1, 3, 3)
    np.testing.assert_allclose(np.asarray(r2).sum(-1), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(d2).sum(-1), 1.0, atol=1e-5)
    assert float(burst[0]) == pytest.approx(float(q[3, 0]))
    # survivors (capacity shares 0.3+0.2+0.9 = 1.4x lam without site 3's
    # 0.6) can still absorb the arrival rate => GMSA remains stable.
    shrunk = inputs._replace(
        mu=inputs.mu[:, :3, :], r=r2, data_dist=d2,
        omega=inputs.omega[:, :3], pue=inputs.pue[:, :3],
        arrivals=inputs.arrivals.at[0, 0].add(float(burst[0])),
    )
    outs2 = simulate(shrunk, dispatch_fn(1.0), key)
    assert float(outs2.backlog_avg[-1]) < 100
