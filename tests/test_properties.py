"""Hypothesis property tests on the system's control-plane invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.energy import manager_energy_cost, slot_cost
from repro.core.gmsa import gmsa_dispatch, lyapunov_drift_bound_B
from repro.core.iridium import iridium_reduce_placement
from repro.core.queues import lyapunov, queue_step
from repro.core.baselines import random_dispatch


small = st.floats(0, 100, allow_nan=False, width=32)


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 8), k=st.integers(1, 5), seed=st.integers(0, 2**31 - 1),
)
def test_queue_law_invariants(n, k, seed):
    """Eq.(1): non-negativity and the one-slot growth bound."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.uniform(0, 100, (n, k)), jnp.float32)
    f = jnp.asarray(rng.dirichlet(np.ones(n), k).T, jnp.float32)
    a = jnp.asarray(rng.uniform(0, 50, k), jnp.float32)
    mu = jnp.asarray(rng.uniform(0, 50, (n, k)), jnp.float32)
    q2 = queue_step(q, f, a, mu)
    assert bool(jnp.all(q2 >= 0))
    # |Q(t+1) - Q(t)| <= max(arrival, service) elementwise
    assert bool(jnp.all(q2 <= q + f * a[None, :]))
    assert bool(jnp.all(q2 >= q - mu))


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 8), k=st.integers(1, 5), seed=st.integers(0, 2**31 - 1),
       v=st.floats(0, 1000, allow_nan=False))
def test_gmsa_minimizes_among_onehots(n, k, seed, v):
    """The GMSA vertex beats every other one-hot dispatch (exact LP opt)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.uniform(0, 200, (n, k)), jnp.float32)
    a = jnp.asarray(rng.uniform(0, 60, k), jnp.float32)
    mu = jnp.asarray(rng.uniform(0, 40, (n, k)), jnp.float32)
    e = jnp.asarray(rng.uniform(5, 30, (k, n)), jnp.float32)
    from repro.core.gmsa import lp_objective
    f_star = gmsa_dispatch(q, a, mu, e, v)
    best = float(lp_objective(f_star, q, a, mu, e, v))
    for i in range(n):
        f_alt = jnp.zeros((n, k)).at[i, :].set(1.0)
        assert best <= float(lp_objective(f_alt, q, a, mu, e, v)) + 1e-2


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 6), k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_cost_nonnegative_and_linear(n, k, seed):
    rng = np.random.default_rng(seed)
    omega = jnp.asarray(rng.uniform(5, 30, n), jnp.float32)
    pue = jnp.asarray(rng.uniform(1.0, 1.2, n), jnp.float32)
    r = jnp.asarray(rng.dirichlet(np.ones(n), (k, n)), jnp.float32)
    p = jnp.asarray(rng.uniform(0.1, 3, k), jnp.float32)
    e = manager_energy_cost(omega, pue, r, p)
    assert bool(jnp.all(e > 0))
    f = jnp.asarray(rng.dirichlet(np.ones(n), k).T, jnp.float32)
    a = jnp.asarray(rng.uniform(0, 50, k), jnp.float32)
    c1 = slot_cost(f, a, e)
    c2 = slot_cost(f, 2 * a, e)
    np.testing.assert_allclose(2 * float(c1), float(c2), rtol=1e-5)
    assert float(c1) >= 0


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 6), seed=st.integers(0, 2**31 - 1),
       size=st.floats(0.1, 100))
def test_iridium_placement_feasible_and_bottleneck(n, seed, size):
    """Placement lies in the simplex and achieves the bisection bottleneck."""
    rng = np.random.default_rng(seed)
    d = rng.dirichlet(np.ones(n)).astype(np.float32)
    up = rng.uniform(0.1, 2.0, n).astype(np.float32)
    down = rng.uniform(0.1, 2.0, n).astype(np.float32)
    r, z = iridium_reduce_placement(jnp.asarray(d), jnp.asarray(up),
                                    jnp.asarray(down), size)
    r = np.asarray(r)
    np.testing.assert_allclose(r.sum(), 1.0, atol=1e-4)
    assert np.all(r >= -1e-6)
    t_up = (1 - r) * d * size / up
    t_down = r * (1 - d) * size / down
    achieved = max(t_up.max(), t_down.max())
    assert achieved <= float(z) * 1.05 + 1e-5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_drift_bound_lemma1(seed):
    """One-slot Lyapunov drift <= B + Σ Q·(arrival − service) (Lemma 1 core).

    With f one-hot and |A|<=A_max, |mu|<=mu_max, the quadratic expansion of
    Eq.(1) gives L(t+1)-L(t) <= B + Σ_{ik} Q_i^k (f_i^k A^k − mu_i^k).
    """
    rng = np.random.default_rng(seed)
    n, k = 4, 2
    a_max, mu_max = 50.0, 40.0
    q = jnp.asarray(rng.uniform(0, 300, (n, k)), jnp.float32)
    a = jnp.asarray(rng.uniform(0, a_max, k), jnp.float32)
    mu = jnp.asarray(rng.uniform(0, mu_max, (n, k)), jnp.float32)
    f = jnp.zeros((n, k)).at[rng.integers(0, n), jnp.arange(k)].set(1.0)
    drift = float(lyapunov(queue_step(q, f, a, mu)) - lyapunov(q))
    b_const = float(lyapunov_drift_bound_B(
        jnp.full((k,), a_max), jnp.full((k,), mu_max), n
    ))
    rhs = b_const + float(jnp.sum(q * (f * a[None, :] - mu)))
    assert drift <= rhs + 1e-2


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 4))
def test_random_dispatch_is_exact_multinomial(seed, k):
    """RANDOM: fractions sum to 1; counts integral; empty slots uniform."""
    n = 4
    rng = np.random.default_rng(seed)
    key = jax.random.key(seed)
    q = jnp.zeros((n, k))
    a = jnp.asarray(rng.integers(0, 60, k), jnp.float32)
    f = random_dispatch(key, q, a, None, None, None)
    np.testing.assert_allclose(np.asarray(f).sum(axis=0), 1.0, atol=1e-5)
    counts = np.asarray(f) * np.asarray(a)[None, :]
    np.testing.assert_allclose(counts, np.round(counts), atol=1e-3)
