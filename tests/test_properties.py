"""Hypothesis property tests on the system's control-plane invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint.fault import drop_site_mask
from repro.core.energy import manager_energy_cost, slot_cost
from repro.core.gmsa import gmsa_dispatch, lyapunov_drift_bound_B
from repro.core.iridium import iridium_reduce_placement
from repro.core.queues import lyapunov, queue_step
from repro.core.baselines import random_dispatch, static_placement_rule
from repro.placement import (
    capacity_project,
    evacuation_plan,
    replica_read_assignment,
    sync_cost,
    transfer_cost,
    transfer_latency,
    transfer_plan,
    wan_topology,
)
from repro.placement.controller import SlowObs
from repro.placement.replica import REPLICA_THRESHOLD


small = st.floats(0, 100, allow_nan=False, width=32)


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 8), k=st.integers(1, 5), seed=st.integers(0, 2**31 - 1),
)
def test_queue_law_invariants(n, k, seed):
    """Eq.(1): non-negativity and the one-slot growth bound."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.uniform(0, 100, (n, k)), jnp.float32)
    f = jnp.asarray(rng.dirichlet(np.ones(n), k).T, jnp.float32)
    a = jnp.asarray(rng.uniform(0, 50, k), jnp.float32)
    mu = jnp.asarray(rng.uniform(0, 50, (n, k)), jnp.float32)
    q2 = queue_step(q, f, a, mu)
    assert bool(jnp.all(q2 >= 0))
    # |Q(t+1) - Q(t)| <= max(arrival, service) elementwise
    assert bool(jnp.all(q2 <= q + f * a[None, :]))
    assert bool(jnp.all(q2 >= q - mu))


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 8), k=st.integers(1, 5), seed=st.integers(0, 2**31 - 1),
       v=st.floats(0, 1000, allow_nan=False))
def test_gmsa_minimizes_among_onehots(n, k, seed, v):
    """The GMSA vertex beats every other one-hot dispatch (exact LP opt)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.uniform(0, 200, (n, k)), jnp.float32)
    a = jnp.asarray(rng.uniform(0, 60, k), jnp.float32)
    mu = jnp.asarray(rng.uniform(0, 40, (n, k)), jnp.float32)
    e = jnp.asarray(rng.uniform(5, 30, (k, n)), jnp.float32)
    from repro.core.gmsa import lp_objective
    f_star = gmsa_dispatch(q, a, mu, e, v)
    best = float(lp_objective(f_star, q, a, mu, e, v))
    for i in range(n):
        f_alt = jnp.zeros((n, k)).at[i, :].set(1.0)
        assert best <= float(lp_objective(f_alt, q, a, mu, e, v)) + 1e-2


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 6), k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_cost_nonnegative_and_linear(n, k, seed):
    rng = np.random.default_rng(seed)
    omega = jnp.asarray(rng.uniform(5, 30, n), jnp.float32)
    pue = jnp.asarray(rng.uniform(1.0, 1.2, n), jnp.float32)
    r = jnp.asarray(rng.dirichlet(np.ones(n), (k, n)), jnp.float32)
    p = jnp.asarray(rng.uniform(0.1, 3, k), jnp.float32)
    e = manager_energy_cost(omega, pue, r, p)
    assert bool(jnp.all(e > 0))
    f = jnp.asarray(rng.dirichlet(np.ones(n), k).T, jnp.float32)
    a = jnp.asarray(rng.uniform(0, 50, k), jnp.float32)
    c1 = slot_cost(f, a, e)
    c2 = slot_cost(f, 2 * a, e)
    np.testing.assert_allclose(2 * float(c1), float(c2), rtol=1e-5)
    assert float(c1) >= 0


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 6), seed=st.integers(0, 2**31 - 1),
       size=st.floats(0.1, 100))
def test_iridium_placement_feasible_and_bottleneck(n, seed, size):
    """Placement lies in the simplex and achieves the bisection bottleneck."""
    rng = np.random.default_rng(seed)
    d = rng.dirichlet(np.ones(n)).astype(np.float32)
    up = rng.uniform(0.1, 2.0, n).astype(np.float32)
    down = rng.uniform(0.1, 2.0, n).astype(np.float32)
    r, z = iridium_reduce_placement(jnp.asarray(d), jnp.asarray(up),
                                    jnp.asarray(down), size)
    r = np.asarray(r)
    np.testing.assert_allclose(r.sum(), 1.0, atol=1e-4)
    assert np.all(r >= -1e-6)
    t_up = (1 - r) * d * size / up
    t_down = r * (1 - d) * size / down
    achieved = max(t_up.max(), t_down.max())
    assert achieved <= float(z) * 1.05 + 1e-5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_drift_bound_lemma1(seed):
    """One-slot Lyapunov drift <= B + Σ Q·(arrival − service) (Lemma 1 core).

    With f one-hot and |A|<=A_max, |mu|<=mu_max, the quadratic expansion of
    Eq.(1) gives L(t+1)-L(t) <= B + Σ_{ik} Q_i^k (f_i^k A^k − mu_i^k).
    """
    rng = np.random.default_rng(seed)
    n, k = 4, 2
    a_max, mu_max = 50.0, 40.0
    q = jnp.asarray(rng.uniform(0, 300, (n, k)), jnp.float32)
    a = jnp.asarray(rng.uniform(0, a_max, k), jnp.float32)
    mu = jnp.asarray(rng.uniform(0, mu_max, (n, k)), jnp.float32)
    f = jnp.zeros((n, k)).at[rng.integers(0, n), jnp.arange(k)].set(1.0)
    drift = float(lyapunov(queue_step(q, f, a, mu)) - lyapunov(q))
    b_const = float(lyapunov_drift_bound_B(
        jnp.full((k,), a_max), jnp.full((k,), mu_max), n
    ))
    rhs = b_const + float(jnp.sum(q * (f * a[None, :] - mu)))
    assert drift <= rhs + 1e-2


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 4))
def test_random_dispatch_is_exact_multinomial(seed, k):
    """RANDOM: fractions sum to 1; counts integral; empty slots uniform."""
    n = 4
    rng = np.random.default_rng(seed)
    key = jax.random.key(seed)
    q = jnp.zeros((n, k))
    a = jnp.asarray(rng.integers(0, 60, k), jnp.float32)
    f = random_dispatch(key, q, a, None, None, None)
    np.testing.assert_allclose(np.asarray(f).sum(axis=0), 1.0, atol=1e-5)
    counts = np.asarray(f) * np.asarray(a)[None, :]
    np.testing.assert_allclose(counts, np.round(counts), atol=1e-3)


# ---------------------------------------------------------------------------
# Placement-layer invariants (repro.placement.wan / .replica) — slow suite
# ---------------------------------------------------------------------------

def _simplex(rng, k, n):
    return jnp.asarray(rng.dirichlet(np.ones(n), k), jnp.float32)


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 7), k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_transfer_plan_conserves_shard_mass(n, k, seed):
    """Exports/imports match the placement delta exactly; nothing rides the
    diagonal; no negative flows."""
    rng = np.random.default_rng(seed)
    d_old = _simplex(rng, k, n)
    d_new = _simplex(rng, k, n)
    sizes = jnp.asarray(rng.uniform(1.0, 500.0, k), jnp.float32)
    plan = np.asarray(transfer_plan(d_old, d_new, sizes))           # (K,N,N)
    assert (plan >= 0).all()
    out_gb = np.maximum(np.asarray(d_old - d_new), 0) * np.asarray(sizes)[:, None]
    in_gb = np.maximum(np.asarray(d_new - d_old), 0) * np.asarray(sizes)[:, None]
    np.testing.assert_allclose(plan.sum(2), out_gb, atol=1e-3)
    np.testing.assert_allclose(plan.sum(1), in_gb, atol=1e-3)
    for kk in range(k):
        assert float(np.trace(plan[kk])) == pytest.approx(0.0, abs=1e-6)


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 6), k=st.integers(1, 3), seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1.0, 10.0))
def test_transfer_cost_nonnegative_and_monotone_in_price(n, k, seed, scale):
    """Costs/latencies are non-negative; cost is linear in energy_per_gb and
    monotone (elementwise) in the price vector."""
    rng = np.random.default_rng(seed)
    up = jnp.asarray(rng.uniform(0.1, 2.0, n), jnp.float32)
    down = jnp.asarray(rng.uniform(0.1, 2.0, n), jnp.float32)
    plan = transfer_plan(_simplex(rng, k, n), _simplex(rng, k, n),
                         jnp.asarray(rng.uniform(1, 200, k), jnp.float32))
    omega = jnp.asarray(rng.uniform(5, 50, n), jnp.float32)
    pue = jnp.asarray(rng.uniform(1.0, 1.3, n), jnp.float32)
    w1 = wan_topology(up, down, energy_per_gb=0.01)
    w2 = wan_topology(up, down, energy_per_gb=0.03)
    c1, e1, gb = transfer_cost(plan, w1, omega, pue)
    assert float(c1) >= 0 and float(e1) >= 0 and float(gb) >= 0
    c2, e2, _ = transfer_cost(plan, w2, omega, pue)
    np.testing.assert_allclose(float(c2), 3 * float(c1), rtol=1e-5)
    np.testing.assert_allclose(float(e2), 3 * float(e1), rtol=1e-5)
    c_hi, _, _ = transfer_cost(plan, w1, omega * scale, pue)
    assert float(c_hi) >= float(c1) * 0.999
    np.testing.assert_allclose(float(c_hi), scale * float(c1), rtol=1e-4)
    lat = transfer_latency(plan, w1)
    assert float(lat) >= 0 and np.isfinite(float(lat))


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 7), k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_drop_renormalization_stays_on_simplex(n, k, seed):
    """drop_site_mask keeps placements on the simplex with zero mass at dead
    sites, and the evacuation plan exactly closes the holding gap."""
    rng = np.random.default_rng(seed)
    d = _simplex(rng, k, n)
    n_dead = int(rng.integers(1, n))                  # always >= 1 survivor
    dead = rng.choice(n, n_dead, replace=False)
    alive = jnp.asarray(np.isin(np.arange(n), dead, invert=True), jnp.float32)
    q = jnp.asarray(rng.uniform(0, 50, (n, k)), jnp.float32)
    q2, d_masked, d_drop, burst = drop_site_mask(q, d, alive)
    d_drop_np = np.asarray(d_drop)
    np.testing.assert_allclose(d_drop_np.sum(1), 1.0, atol=1e-4)
    assert (d_drop_np >= -1e-7).all()
    assert float(np.abs(d_drop_np[:, dead]).max()) == 0.0
    assert float(np.asarray(q2)[dead].sum()) == 0.0
    np.testing.assert_allclose(
        np.asarray(burst), np.asarray(q)[dead].sum(0), rtol=1e-5
    )
    sizes = jnp.asarray(rng.uniform(1, 300, k), jnp.float32)
    plan = np.asarray(evacuation_plan(d_masked, d_drop, sizes))
    assert (plan >= 0).all()
    # Receivers with at least one surviving *peer* holding data get their
    # holding gap closed exactly over the WAN; a receiver that is the sole
    # surviving holder restores from local backup instead (no WAN bytes).
    gap = np.maximum(np.asarray(d_drop - d_masked), 0) * np.asarray(sizes)[:, None]
    src = np.asarray(jnp.where(
        jnp.sum(d_masked, axis=1, keepdims=True) <= 1e-9, d_drop, d_masked
    ))
    peer_mass = src.sum(1, keepdims=True) - src              # (K, N)
    expected = gap * np.minimum(peer_mass / 1e-12, 1.0)
    np.testing.assert_allclose(plan.sum(1), expected, atol=1e-3)
    # Dead sites neither send nor receive.
    assert float(np.abs(plan[:, dead, :]).sum()) == 0.0
    assert float(np.abs(plan[:, :, dead]).sum()) == 0.0


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 6), k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_survivor_placement_respects_storage_caps(n, k, seed):
    """After drop_site renormalization, capacity projection still lands on
    the simplex and within per-site caps (feasible totals provisioned)."""
    rng = np.random.default_rng(seed)
    d = _simplex(rng, k, n)
    dead = int(rng.integers(0, n))
    alive = jnp.ones((n,)).at[dead].set(0.0)
    if n == 1 + int(jnp.sum(1 - alive)):              # never kill everyone
        alive = jnp.ones((n,))
    _, _, d_drop, _ = drop_site_mask(jnp.zeros((n, k)), d, alive)
    sizes = jnp.asarray(rng.uniform(10, 100, k), jnp.float32)
    # Provision survivors with 2x headroom so the projection is feasible.
    n_alive = float(jnp.sum(alive))
    cap_each = 2.0 * float(sizes.sum()) / max(n_alive, 1.0)
    caps = jnp.where(alive > 0.5, cap_each, 0.0)
    p = np.asarray(capacity_project(d_drop, sizes, caps))
    np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-3)
    assert (p >= -1e-6).all()
    load = (p * np.asarray(sizes)[:, None]).sum(0)
    assert (load <= np.asarray(caps) * 1.02 + 1e-3).all(), load


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 6), k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_static_rule_survivor_aware(n, k, seed):
    """With obs.alive the STATIC rule renormalizes over survivors (simplex,
    zero at dead); with all alive it returns its input bit for bit."""
    rng = np.random.default_rng(seed)
    d = _simplex(rng, k, n)
    obs_alive = SlowObs(
        wpue_bar=jnp.ones(n), mu_bar=jnp.ones((n, k)), q=jnp.zeros((n, k)),
        sizes_gb=jnp.ones(k), capacity_gb=jnp.full((n,), jnp.inf),
        alive=jnp.ones((n,)),
    )
    np.testing.assert_array_equal(
        np.asarray(static_placement_rule(d, obs_alive)), np.asarray(d)
    )
    dead = int(rng.integers(0, n))
    obs_dead = obs_alive._replace(alive=jnp.ones((n,)).at[dead].set(0.0))
    out = np.asarray(static_placement_rule(d, obs_dead))
    np.testing.assert_allclose(out.sum(1), 1.0, atol=1e-4)
    assert float(np.abs(out[:, dead]).max()) == 0.0


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 6), k=st.integers(1, 3), seed=st.integers(0, 2**31 - 1),
       uf=st.floats(0.001, 0.2))
def test_sync_cost_nonnegative_and_monotone(n, k, seed, uf):
    rng = np.random.default_rng(seed)
    d = _simplex(rng, k, n)
    sizes = jnp.asarray(rng.uniform(1, 300, k), jnp.float32)
    wan = wan_topology(jnp.asarray(rng.uniform(0.1, 2, n), jnp.float32),
                       jnp.asarray(rng.uniform(0.1, 2, n), jnp.float32))
    wpue = jnp.asarray(rng.uniform(5, 50, n), jnp.float32)
    c1 = float(sync_cost(d, sizes, wan, wpue, uf))
    c2 = float(sync_cost(d, sizes, wan, wpue, 2 * uf))
    assert c1 >= 0
    np.testing.assert_allclose(c2, 2 * c1, rtol=1e-5)


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 6), k=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_replica_read_assignment_picks_live_hosts(n, k, seed):
    """Selections are one-hot and never point at an unmaterialized shard
    (as long as each dataset has at least one live replica)."""
    rng = np.random.default_rng(seed)
    d = _simplex(rng, k, n)
    wan = wan_topology(jnp.asarray(rng.uniform(0.1, 2, n), jnp.float32),
                       jnp.asarray(rng.uniform(0.1, 2, n), jnp.float32))
    wpue = jnp.asarray(rng.uniform(5, 50, n), jnp.float32)
    sel = np.asarray(replica_read_assignment(d, wan, wpue))        # (K,N,N)
    np.testing.assert_allclose(sel.sum(-1), 1.0, atol=1e-6)
    live = np.asarray(d) >= REPLICA_THRESHOLD                      # (K,N)
    for kk in range(k):
        if live[kk].any():
            hosts = sel[kk].argmax(-1)
            assert live[kk][hosts].all()
