"""repro.telemetry observability layer (PR 8): distributions, spans, SLOs.

The load-bearing guarantees, in test form:

* The histogram layer is FREE when off — ``TelemetryConfig(level=OFF,
  hist=...)`` still traces to the byte-identical jaxpr of ``telemetry=None``
  on every engine (metrics enabled-then-disabled), and with metrics ON the
  engine *outputs* stay bitwise.
* The decode is HONEST — histogram percentile estimates sit within their
  own reported error bound of the exact ``np.percentile`` /
  weighted-replay answer, for interior, underflow and overflow mass.
* The serving sojourn clock matches an exact host-side FIFO replay of the
  same admitted/completed flow, faulted or not, and conserves mass.
* Span export emits valid Chrome trace-event JSON for a faulted serve
  run with the recovery visible.
* ``bench_check`` passes on the repo's committed trajectory and fails on
  a synthetically injected regression.
"""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import data_dispatch
from repro.core.gmsa import dispatch_fn
from repro.core.simulator import _energy_tables, simulate
from repro.jobs import simulate_staged
from repro.jobs.dag import single_stage_dag
from repro.jobs.scheduler import stage_service_rates_all
from repro.launch.serve import build_engine
from repro.placement import (
    PlacementConfig,
    make_adaptive_rule,
    simulate_placed,
    wan_topology,
)
from repro.telemetry import (
    OFF,
    SUMMARY,
    TRACE,
    HistogramSpec,
    SloSpec,
    TelemetryConfig,
    fifo_sojourn_replay,
    fleet_records,
    hist_add,
    hist_init,
    hist_quantiles,
    hist_series,
    read_jsonl,
    render_timeline,
    sojourn_init,
    sojourn_step,
    sparkline,
    to_chrome_trace,
    weighted_percentile,
    write_jsonl,
)
from repro.telemetry import bench_check
from repro.telemetry.slo import bad_fraction, burn_events, evaluate_slo
from repro.telemetry.spans import (
    controller_spans,
    request_spans,
    spans_from_records,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.faults import scheduled_failure_trace

REPO = pathlib.Path(__file__).resolve().parent.parent
HSPEC = HistogramSpec(lo=0.5, hi=64.0, n_buckets=20)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(PaperSimConfig(), t_slots=96)
    template, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)
    return cfg, template, up, down


@pytest.fixture(scope="module")
def faulted_serve():
    """One faulted serving run with the histogram layer on, plus its twin
    without telemetry (for bitwise comparison)."""
    alive = np.ones((12, 4), np.float32)
    alive[6:, 2] = 0.0
    kw = dict(slots=12, v=1.0, seed=3, arrival=6.0, alive=alive)
    tcfg = TelemetryConfig(level=SUMMARY, hist=HSPEC)
    eng = build_engine(["qwen2-0.5b", "granite-3-2b"], telemetry=tcfg, **kw)
    bare = build_engine(["qwen2-0.5b", "granite-3-2b"], **kw)
    return eng.run(execute_real=False), bare.run(execute_real=False)


# ---------------------------------------------------------------------------
# The histogram spec and its decode
# ---------------------------------------------------------------------------

def test_histogram_spec_edges_and_bucket_index():
    edges = HSPEC.edges()
    assert edges.shape == (HSPEC.n_buckets + 1,)
    assert edges[0] == 0.0 and edges[1] == HSPEC.lo
    assert edges[-2] == HSPEC.hi and np.isinf(edges[-1])
    assert np.all(np.diff(edges[:-1]) > 0)
    idx = np.asarray(HSPEC.bucket_index(
        jnp.asarray([0.0, 0.49, 0.5, 1.0, 63.9, 64.0, 1e9])
    ))
    assert idx[0] == 0 and idx[1] == 0                  # underflow
    assert idx[2] == 1                                  # first interior
    assert idx[-2] == HSPEC.n_buckets - 1               # hi -> overflow
    assert idx[-1] == HSPEC.n_buckets - 1
    # Every interior value lands in the bucket its edges bound.
    vals = np.asarray([0.7, 2.3, 10.0, 33.3, 60.0])
    b = np.asarray(HSPEC.bucket_index(jnp.asarray(vals)))
    assert np.all(edges[b] <= vals) and np.all(vals < edges[b + 1])


def test_hist_quantiles_within_one_bucket_of_exact():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=1.0, sigma=1.0, size=5000)
    counts = np.asarray(hist_add(HSPEC, hist_init(HSPEC),
                                 jnp.asarray(samples)))
    qs = (50.0, 95.0, 99.0)
    est, err = hist_quantiles(counts, HSPEC, qs)
    exact = np.percentile(samples, qs)
    assert np.all(np.isfinite(est))
    assert np.all(np.abs(est - exact) <= err + 1e-9), (est, exact, err)


def test_hist_quantiles_overflow_and_empty():
    counts = np.asarray(hist_add(HSPEC, hist_init(HSPEC),
                                 jnp.asarray([1e6, 2e6, 3e6])))
    est, err = hist_quantiles(counts, HSPEC, (50.0,))
    assert est[0] == HSPEC.hi and np.isinf(err[0])      # lower bound, ±inf
    est0, err0 = hist_quantiles(np.zeros(HSPEC.n_buckets), HSPEC, (50.0,))
    assert np.isnan(est0[0]) and np.isnan(err0[0])


def test_hist_series_matches_per_row_hist_add():
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.lognormal(1.0, 1.5, size=(3, 40)))
    stacked = np.asarray(hist_series(HSPEC, vals, axis=-1))
    for i in range(3):
        row = np.asarray(hist_add(HSPEC, hist_init(HSPEC), vals[i]))
        np.testing.assert_allclose(stacked[i], row)


# ---------------------------------------------------------------------------
# The sojourn clock: device scan state vs exact host replay
# ---------------------------------------------------------------------------

def test_sojourn_step_matches_fifo_replay():
    rng = np.random.default_rng(2)
    t_slots, k = 24, 2
    admitted = rng.uniform(0.0, 8.0, size=(t_slots, k))
    # Completions lag arrivals: serve ~70% of current backlog per slot.
    completed = np.zeros_like(admitted)
    backlog = np.zeros(k)
    for t in range(t_slots):
        backlog += admitted[t]
        completed[t] = 0.7 * backlog
        backlog -= completed[t]
    age, hist = sojourn_init(HSPEC, k, t_slots)
    for t in range(t_slots):
        age, hist = sojourn_step(HSPEC, age, hist,
                                 jnp.asarray(admitted[t], jnp.float32),
                                 jnp.asarray(completed[t], jnp.float32))
    counts = np.asarray(hist)
    # Conservation: every completed unit landed in exactly one bucket.
    np.testing.assert_allclose(counts.sum(-1), completed.sum(0), rtol=1e-5)
    # Percentiles agree with the exact weighted replay within the bound.
    soj, wgt = fifo_sojourn_replay(admitted, completed)
    qs = (50.0, 95.0, 99.0)
    est, err = hist_quantiles(counts, HSPEC, qs)
    for ki in range(k):
        exact = weighted_percentile(soj[ki], wgt[ki], qs)
        assert np.all(np.abs(est[ki] - exact) <= err[ki] + 1e-6), (
            ki, est[ki], exact, err[ki]
        )


def test_fleet_sojourn_matches_exact_replay_faulted(faulted_serve):
    out, _ = faulted_serve
    spec = HistogramSpec(**out["sojourn_spec"])
    counts = out["sojourn_hist"]
    np.testing.assert_allclose(
        counts.sum(-1), out["completed"].sum(0), atol=1e-3
    )
    soj, wgt = fifo_sojourn_replay(out["admitted"], out["completed"])
    qs = (50.0, 95.0, 99.0)
    est, err = hist_quantiles(counts, spec, qs)
    for ki in range(counts.shape[0]):
        exact = weighted_percentile(soj[ki], wgt[ki], qs)
        assert np.all(np.abs(est[ki] - exact) <= err[ki] + 1e-6)
    # The decoded table carries the same numbers, named per class.
    tab = out["sojourn_percentiles"]
    assert [r["name"] for r in tab] == out["class_names"]
    np.testing.assert_allclose([r["p99"] for r in tab], est[:, 2])


# ---------------------------------------------------------------------------
# Enabled-then-disabled: OFF with a hist spec is still byte-identical
# ---------------------------------------------------------------------------

def test_off_with_hist_spec_jaxpr_identical_sim(setup):
    _, template, _, _ = setup
    pol, key = dispatch_fn(1.0), jax.random.key(0)
    # Trace once with the layer ON (enabled), then pin OFF == None.
    simulate(template, pol, key,
             telemetry=TelemetryConfig(level=SUMMARY, hist=HSPEC))
    j_none = jax.make_jaxpr(lambda i, k: simulate(i, pol, k))(template, key)
    j_off = jax.make_jaxpr(
        lambda i, k: simulate(i, pol, k,
                              telemetry=TelemetryConfig(level=OFF, hist=HSPEC))
    )(template, key)
    assert str(j_none) == str(j_off)


def test_off_with_hist_spec_jaxpr_identical_staged(setup):
    cfg, template, up, down = setup
    dag = single_stage_dag(cfg.k_types)
    wan = wan_topology(up, down)
    key = jax.random.key(0)
    simulate_staged(template, dag, wan, data_dispatch, key,
                    telemetry=TelemetryConfig(level=SUMMARY, hist=HSPEC))
    j_none = jax.make_jaxpr(
        lambda i, k: simulate_staged(i, dag, wan, data_dispatch, k)
    )(template, key)
    j_off = jax.make_jaxpr(
        lambda i, k: simulate_staged(
            i, dag, wan, data_dispatch, k,
            telemetry=TelemetryConfig(level=OFF, hist=HSPEC))
    )(template, key)
    assert str(j_none) == str(j_off)


def test_off_with_hist_spec_jaxpr_identical_placed(setup):
    cfg, template, up, down = setup
    mask = scheduled_failure_trace(cfg.t_slots, cfg.n_sites, [(1, 30, None)])
    pcfg = PlacementConfig(epoch_slots=24, manager_share=cfg.manager_share,
                           map_share=cfg.map_share)
    pol, rule = dispatch_fn(1.0), make_adaptive_rule(up)
    key = jax.random.key(3)
    j_none = jax.make_jaxpr(
        lambda i, k: simulate_placed(i, up, down, pol, rule, k, pcfg,
                                     alive=mask)
    )(template, key)
    j_off = jax.make_jaxpr(
        lambda i, k: simulate_placed(
            i, up, down, pol, rule, k, pcfg, alive=mask,
            telemetry=TelemetryConfig(level=OFF, hist=HSPEC))
    )(template, key)
    assert str(j_none) == str(j_off)


def _fleet_step_jaxpr(eng) -> str:
    scn, inputs = eng.scenario, eng.scenario.inputs
    e_cost_all, _ = _energy_tables(inputs)
    mu_stage_all = stage_service_rates_all(inputs.mu, scn.dag)
    wpue = inputs.omega * inputs.pue
    q = jnp.zeros((eng.fcfg.n_pods, len(eng.classes), scn.dag.s_max),
                  jnp.float32)
    args = (q, inputs.arrivals[0], inputs.mu[0], e_cost_all[0],
            mu_stage_all[0], inputs.data_dist, wpue[0],
            jnp.float32(eng.fcfg.v))
    return str(jax.make_jaxpr(eng._step)(*args))


def test_fleet_step_jaxpr_identical_off_with_hist():
    kw = dict(slots=8, v=1.0, seed=3, arrival=4.0)
    none = build_engine(["qwen2-0.5b"], **kw)
    off = build_engine(["qwen2-0.5b"], **kw,
                       telemetry=TelemetryConfig(level=OFF, hist=HSPEC))
    assert _fleet_step_jaxpr(none) == _fleet_step_jaxpr(off)


def test_fleet_outputs_bitwise_with_hist_on(faulted_serve):
    out, bare = faulted_serve
    np.testing.assert_array_equal(out["cost"], bare["cost"])
    np.testing.assert_array_equal(out["backlog"], bare["backlog"])
    np.testing.assert_array_equal(np.asarray(out["dispatch"]),
                                  np.asarray(bare["dispatch"]))
    assert out["total_billed_cost"] == bare["total_billed_cost"]


def test_trace_level_with_hist_outputs_bitwise(setup):
    _, template, _, _ = setup
    pol, key = dispatch_fn(1.0), jax.random.key(7)
    o0 = simulate(template, pol, key)
    o1, frame = simulate(template, pol, key,
                         telemetry=TelemetryConfig(level=TRACE, hist=HSPEC))
    for f in o0._fields:
        np.testing.assert_array_equal(np.asarray(getattr(o0, f)),
                                      np.asarray(getattr(o1, f)), err_msg=f)
    assert "site_cost_hist" in frame.metrics


# ---------------------------------------------------------------------------
# SLO monitor: bad fraction, burn-rate alerts, conservative verdicts
# ---------------------------------------------------------------------------

def test_bad_fraction_hand_example():
    admitted = np.asarray([[4.0], [0.0], [0.0]])
    completed = np.asarray([[1.0], [1.0], [2.0]])
    frac = bad_fraction(admitted, completed, target=1.0)
    # t=0: sojourn 0; t=1: sojourn 1 (not > 1); t=2: sojourn 2 (> 1).
    np.testing.assert_allclose(frac[:, 0], [0.0, 0.0, 1.0])


def test_burn_events_fire_on_overload_only():
    t_slots = 20
    slo = SloSpec(target=1.0, percentile=95.0, windows=((3, 8, 1.0),))
    # Underloaded: everything completes the slot it arrives.
    adm = np.full((t_slots, 1), 4.0)
    assert burn_events(adm, adm.copy(), slo) == []
    # Overloaded: a big backlog drains slowly — late mass is all bad.
    admitted = np.zeros((t_slots, 1))
    admitted[0, 0] = 40.0
    completed = np.full((t_slots, 1), 2.0)
    evs = burn_events(admitted, completed, slo)
    assert evs and all(e["code"] == "slo_burn" for e in evs)
    # Rising-edge dedup: the alert opens once, not every slot.
    assert len(evs) == 1
    assert evs[0]["burn_short"] > 1.0 and evs[0]["burn_long"] > 1.0


def test_evaluate_slo_conservative_on_overflow():
    counts = np.asarray(hist_add(HSPEC, hist_init(HSPEC),
                                 jnp.asarray([1e6] * 10)))
    slo = SloSpec(target=1e9, percentile=99.0)
    (v,) = evaluate_slo(counts, HSPEC, slo)
    assert not v["ok"]                      # ±inf can never certify a pass
    fast = np.asarray(hist_add(HSPEC, hist_init(HSPEC),
                               jnp.asarray([1.0] * 100)))
    (v2,) = evaluate_slo(fast, HSPEC, SloSpec(target=8.0, percentile=99.0))
    assert v2["ok"]


# ---------------------------------------------------------------------------
# Spans and the Chrome trace export
# ---------------------------------------------------------------------------

def test_request_spans_phases_and_unserved():
    out = {
        "admitted": np.asarray([[2.0], [1.0]]),
        "completed": np.asarray([[1.0], [1.0]]),
    }
    spans = request_spans(out, class_names=["c0"])
    names = [s["name"] for s in spans]
    cats = {s["cat"] for s in spans}
    assert "unserved" in cats               # 1 unit still queued at horizon
    for phase in ("admit", "prefill", "kv_shuffle", "decode", "served"):
        assert phase in names
    parents = [s for s in spans if s["cat"] in ("request", "unserved")]
    assert len(parents) == 2 and all(s["track"] == "c0" for s in parents)


def test_controller_spans_from_synthetic_stream():
    records = [
        {"type": "meta", "kind": "placed", "t_slots": 48},
        {"type": "event", "t": 23, "code": "epoch", "epoch": 0,
         "wan_gb": 1.5, "wan_cost": 0.2, "sync_cost": 0.1,
         "churn": 0.3, "budget_use": 0.8},
        {"type": "event", "t": 30, "code": "recovery", "site": 1,
         "n_died": 1, "recovery_gb": 4.0, "time_to_slo": 5,
         "slo_backlog": 3.0},
        {"type": "event", "t": 40, "code": "recovery", "site": 2,
         "n_died": 1, "time_to_slo": None, "slo_backlog": 3.0},
        {"type": "event", "t": 31, "code": "switch", "k": 0,
         "src": 1, "dst": 2},
    ]
    spans = controller_spans(records)
    by_name = {s["name"]: s for s in spans}
    ep = by_name["epoch 0"]
    assert ep["t0"] == 0 and ep["t1"] == 24
    rec = by_name["recovery→SLO"]
    assert rec["t0"] == 30 and rec["t1"] == 35
    unrec = by_name["unrecovered"]
    assert unrec["t1"] == 48                # horizon-capped
    assert "death edge @1" in by_name and "switch k0→2" in by_name


def test_chrome_trace_valid_for_faulted_serve(faulted_serve, tmp_path):
    out, _ = faulted_serve
    records = fleet_records(
        out, meta={"slo_backlog": 50.0},
        slo=SloSpec(target=4.0, percentile=99.0),
    )
    spans = spans_from_records(records)
    trace = to_chrome_trace(spans, slot_ms=2.0)
    # Valid trace-event JSON: serializable, every event well-formed.
    blob = json.dumps(trace)
    parsed = json.loads(blob)
    assert parsed["displayTimeUnit"] == "ms"
    phs = set()
    for ev in parsed["traceEvents"]:
        assert {"ph", "pid", "tid", "name"} <= set(ev)
        phs.add(ev["ph"])
        if ev["ph"] == "X":
            assert ev["dur"] > 0 and ev["ts"] >= 0
    assert {"X", "i", "M"} <= phs
    # The fault is visible: a death-edge instant on its own track.
    names = [ev["name"] for ev in parsed["traceEvents"]]
    assert any("death edge" in n or "died" in n for n in names)
    # Request lifecycles made it in from the metric rows alone.
    assert any(n.startswith("req ") for n in names)


def test_fleet_records_round_trip_with_hist_and_slo(faulted_serve, tmp_path):
    out, _ = faulted_serve
    records = fleet_records(out, meta={"slo_backlog": 50.0},
                            slo=SloSpec(target=8.0, percentile=99.0))
    kinds = {r["type"] for r in records}
    assert {"meta", "event", "metric", "hist", "slo", "summary"} <= kinds
    path = write_jsonl(records, tmp_path / "serve.jsonl")
    assert read_jsonl(path) == json.loads(json.dumps(records))
    text = render_timeline(records, codes={"recovery"})
    assert "death edge" in text
    hist = next(r for r in records if r["type"] == "hist")
    assert hist["name"] == "sojourn" and len(hist["percentiles"]) == 2


# ---------------------------------------------------------------------------
# The perf-regression sentinel
# ---------------------------------------------------------------------------

def test_bench_check_series_logic():
    stable = [100.0, 102.0, 98.0, 101.0]
    assert bench_check.check_series(stable + [103.0])["status"] == "ok"
    r = bench_check.check_series(stable + [400.0])
    assert r["status"] == "regression" and r["z"] > 3.0
    # Below the relative gate: a 3-sigma wobble on a flat series is noise.
    tiny = bench_check.check_series(stable + [104.0], min_rel=0.25)
    assert tiny["status"] == "ok"
    assert bench_check.check_series([1.0, 2.0])["status"] == "skipped"


def test_bench_check_passes_on_committed_trajectory():
    assert bench_check.main([str(REPO / "BENCH_sim.json"), "--quiet"]) == 0


def test_bench_check_fails_on_injected_regression(tmp_path):
    src = json.loads((REPO / "BENCH_sim.json").read_text())
    series = bench_check.load_series(REPO / "BENCH_sim.json")
    label, name = next(
        (k for k, v in series.items() if len(v) >= 4 and np.median(v) > 0)
    )
    spike = float(np.median(series[(label, name)]) * 10.0)
    src.append({"label": label,
                "benches": [{"name": name, "us_per_call": spike}]})
    bad = tmp_path / "BENCH_sim.json"
    bad.write_text(json.dumps(src))
    assert bench_check.main([str(bad), "--quiet"]) == 1
    # The untouched copy of the same file still passes.
    good = tmp_path / "BENCH_ok.json"
    good.write_text(json.dumps(src[:-1]))
    assert bench_check.main([str(good), "--quiet"]) == 0


# ---------------------------------------------------------------------------
# sparkline: empty-bin carry + constant-series pin
# ---------------------------------------------------------------------------

def test_sparkline_constant_series_pins_lowest_block():
    assert sparkline([5.0] * 100, width=60) == "▁" * 60
    assert sparkline([0.0] * 10) == "▁" * 10
    assert sparkline([]) == ""


def test_sparkline_monotone_series_never_spikes():
    s = sparkline(np.linspace(0.0, 1.0, 97), width=60)
    assert len(s) == 60
    blocks = " ▁▂▃▄▅▆▇█"
    levels = [blocks.index(c) for c in s]
    assert levels == sorted(levels)         # nondecreasing, no invented spike
