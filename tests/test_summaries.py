"""Summarize invariants on random inputs (PR 6 satellite).

The ``summarize_*`` dicts are the contract the telemetry cross-check
rebuilds from event streams, so their internal identities are pinned here
directly on random arrays — no engine run required:

* ``time_avg_total_cost == dispatch/compute + wan (+ sync + recovery)``
* GB totals are the plain sums of the per-slot/per-epoch GB streams
  (conservation: summarizing never invents or loses bytes),

with and without a leading Monte-Carlo runs axis.
"""

import numpy as np
import pytest

from repro.core.simulator import SimOutputs, summarize
from repro.jobs.engine import StagedOutputs, summarize_staged
from repro.placement.controller import PlacedOutputs, summarize_placed

T, E, N, K, S = 20, 4, 3, 2, 3


def _rand(rng, *shape):
    return rng.uniform(0.1, 5.0, size=shape).astype(np.float32)


def _maybe_runs(shape, runs):
    return shape if runs is None else (runs, *shape)


@pytest.fixture(params=[None, 5], ids=["single", "runs5"])
def runs(request):
    return request.param


def _sim_outputs(rng, runs):
    return SimOutputs(
        cost=_rand(rng, *_maybe_runs((T,), runs)),
        energy=_rand(rng, *_maybe_runs((T,), runs)),
        backlog_total=_rand(rng, *_maybe_runs((T,), runs)),
        backlog_avg=_rand(rng, *_maybe_runs((T,), runs)),
        q_final=_rand(rng, *_maybe_runs((N, K), runs)),
        f_trace=_rand(rng, *_maybe_runs((T, N, K), runs)),
    )


def _placed_outputs(rng, runs):
    sim = _sim_outputs(rng, runs)
    return PlacedOutputs(
        cost=sim.cost, energy=sim.energy,
        backlog_total=sim.backlog_total, backlog_avg=sim.backlog_avg,
        q_final=sim.q_final, f_trace=sim.f_trace,
        placements=_rand(rng, *_maybe_runs((E, K, N), runs)),
        r_trace=_rand(rng, *_maybe_runs((E, K, N, N), runs)),
        wan_cost=_rand(rng, *_maybe_runs((E,), runs)),
        wan_energy=_rand(rng, *_maybe_runs((E,), runs)),
        wan_gb=_rand(rng, *_maybe_runs((E,), runs)),
        wan_latency_s=_rand(rng, *_maybe_runs((E,), runs)),
        sync_cost=_rand(rng, *_maybe_runs((E,), runs)),
        recovery_cost=_rand(rng, *_maybe_runs((T,), runs)),
        recovery_gb=_rand(rng, *_maybe_runs((T,), runs)),
        mu_scale=_rand(rng, *_maybe_runs((E, N), runs)),
    )


def _staged_outputs(rng, runs):
    return StagedOutputs(
        cost=_rand(rng, *_maybe_runs((T,), runs)),
        energy=_rand(rng, *_maybe_runs((T,), runs)),
        backlog_total=_rand(rng, *_maybe_runs((T,), runs)),
        backlog_avg=_rand(rng, *_maybe_runs((T,), runs)),
        q_final=_rand(rng, *_maybe_runs((N, K, S), runs)),
        f_trace=_rand(rng, *_maybe_runs((T, N, K, S), runs)),
        wan_cost=_rand(rng, *_maybe_runs((T,), runs)),
        wan_energy=_rand(rng, *_maybe_runs((T,), runs)),
        wan_gb=_rand(rng, *_maybe_runs((T,), runs)),
        completed=_rand(rng, *_maybe_runs((T, K), runs)),
        hedge_cost=_rand(rng, *_maybe_runs((T,), runs)),
        hedge_gb=_rand(rng, *_maybe_runs((T,), runs)),
        hedged_jobs=_rand(rng, *_maybe_runs((T,), runs)),
    )


def test_summarize_means(runs):
    rng = np.random.default_rng(0)
    outs = _sim_outputs(rng, runs)
    s = summarize(outs)
    assert s["time_avg_cost"] == pytest.approx(float(outs.cost.mean()),
                                               rel=1e-6)
    assert s["time_avg_backlog"] == pytest.approx(
        float(outs.backlog_avg.mean()), rel=1e-6)
    assert s["final_backlog_total"] == pytest.approx(
        float(outs.q_final.sum(axis=(-2, -1)).mean()), rel=1e-6)


def test_summarize_placed_total_is_the_sum_of_parts(runs):
    rng = np.random.default_rng(1)
    outs = _placed_outputs(rng, runs)
    s = summarize_placed(outs)
    expect = (s["time_avg_dispatch_cost"] + s["time_avg_wan_cost"]
              + s["time_avg_sync_cost"] + s["time_avg_recovery_cost"])
    assert s["time_avg_total_cost"] == pytest.approx(expect, rel=1e-6)
    # The parts themselves are the declared reductions of the raw streams.
    assert s["time_avg_dispatch_cost"] == pytest.approx(
        float(outs.cost.mean()), rel=1e-6)
    assert s["time_avg_wan_cost"] == pytest.approx(
        float(outs.wan_cost.sum(axis=-1).mean()) / T, rel=1e-6)
    assert s["time_avg_sync_cost"] == pytest.approx(
        float(outs.sync_cost.sum(axis=-1).mean()) / T, rel=1e-6)
    assert s["time_avg_recovery_cost"] == pytest.approx(
        float(outs.recovery_cost.mean()), rel=1e-6)


def test_summarize_placed_gb_conservation(runs):
    rng = np.random.default_rng(2)
    outs = _placed_outputs(rng, runs)
    s = summarize_placed(outs)
    assert s["total_wan_gb"] == pytest.approx(
        float(outs.wan_gb.sum(axis=-1).mean()), rel=1e-6)
    assert s["total_recovery_gb"] == pytest.approx(
        float(outs.recovery_gb.sum(axis=-1).mean()), rel=1e-6)


def test_summarize_staged_total_is_the_sum_of_parts(runs):
    rng = np.random.default_rng(3)
    outs = _staged_outputs(rng, runs)
    s = summarize_staged(outs)
    assert s["time_avg_total_cost"] == pytest.approx(
        s["time_avg_compute_cost"] + s["time_avg_wan_cost"]
        + s["time_avg_hedge_cost"], rel=1e-6)
    assert s["time_avg_compute_cost"] == pytest.approx(
        float(outs.cost.mean()), rel=1e-6)
    assert s["time_avg_wan_cost"] == pytest.approx(
        float(outs.wan_cost.mean()), rel=1e-6)
    assert s["time_avg_hedge_cost"] == pytest.approx(
        float(outs.hedge_cost.mean()), rel=1e-6)


def test_summarize_staged_gb_conservation(runs):
    rng = np.random.default_rng(4)
    outs = _staged_outputs(rng, runs)
    s = summarize_staged(outs)
    assert s["total_wan_gb"] == pytest.approx(
        float(outs.wan_gb.sum(axis=-1).mean()), rel=1e-6)
