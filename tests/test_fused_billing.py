"""Fused WAN billing: plan_cost / evacuation_cost / expected_pull.

The fast-path bilinear forms must price EXACTLY what the materialized
(K, N, N) plans price (≤ 1e-5 relative — float reassociation only):

* ``plan_cost(d_old, d_new, ...) == transfer_cost(transfer_plan(...))``
  — scalars and leading-batch-dim forms;
* ``evacuation_cost(...) == transfer_cost(evacuation_plan(...))`` —
  including datasets whose replicas were ALL lost (restore-from-backup);
* a recovery burst's fused total equals billing the summed plan (pricing
  is linear in the plan);
* ``expected_pull(src, w) == src @ link_price_matrix(w)``;
* a no-move placement bills exactly 0.0 (the W >= T / epoch-0 contract).

The engine-level consequences — staged single-stage bit-exactness with
``simulate``, the fault path's all-ones-mask bit-exactness against the
``lax.cond``-gated recovery body, billing == transfer_plan replay — are
pinned in tests/test_jobs.py and tests/test_fault_placement.py, which run
against the fused implementations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.fault import drop_site_mask
from repro.placement.wan import (
    evacuation_cost,
    evacuation_plan,
    expected_pull,
    link_price_matrix,
    plan_cost,
    transfer_cost,
    transfer_plan,
    wan_topology,
)


def _case(rng, k, n):
    d_old = jnp.asarray(rng.dirichlet(np.ones(n), k), jnp.float32)
    d_new = jnp.asarray(rng.dirichlet(np.ones(n), k), jnp.float32)
    sizes = jnp.asarray(rng.uniform(0.0, 200.0, k), jnp.float32)
    omega = jnp.asarray(rng.uniform(5.0, 40.0, n), jnp.float32)
    pue = jnp.asarray(rng.uniform(1.0, 1.3, n), jnp.float32)
    wan = wan_topology(
        jnp.asarray(rng.uniform(0.2, 2.0, n), jnp.float32),
        jnp.asarray(rng.uniform(0.2, 2.0, n), jnp.float32),
        energy_per_gb=0.03,
    )
    return d_old, d_new, sizes, omega, pue, wan


@pytest.mark.parametrize("seed,k,n", [(0, 1, 2), (1, 3, 4), (2, 5, 8),
                                      (3, 2, 16), (4, 8, 5)])
def test_plan_cost_matches_materialized(seed, k, n):
    rng = np.random.default_rng(seed)
    d_old, d_new, sizes, omega, pue, wan = _case(rng, k, n)
    if seed % 2:
        d_new = d_new.at[0].set(d_old[0])        # a no-move row
        sizes = sizes.at[-1].set(0.0)            # a zero-size dataset
    ref = transfer_cost(transfer_plan(d_old, d_new, sizes), wan, omega, pue)
    fused = plan_cost(d_old, d_new, sizes, wan, omega, pue)
    for r, f in zip(ref, fused):
        assert float(f) == pytest.approx(float(r), rel=1e-5, abs=1e-5)


def test_plan_cost_batched_leading_dims():
    """The (T, K, N) batched form prices each slice like the 2D form."""
    rng = np.random.default_rng(7)
    t, k, n = 5, 3, 4
    d_old = jnp.asarray(rng.dirichlet(np.ones(n), (t, k)), jnp.float32)
    d_new = jnp.asarray(rng.dirichlet(np.ones(n), (t, k)), jnp.float32)
    sizes = jnp.asarray(rng.uniform(0, 100, (t, k)), jnp.float32)
    omega = jnp.asarray(rng.uniform(5, 40, (t, n)), jnp.float32)
    pue = jnp.asarray(rng.uniform(1.0, 1.3, (t, n)), jnp.float32)
    wan = wan_topology(jnp.ones(n), jnp.ones(n))
    cost, energy, gb = plan_cost(d_old, d_new, sizes, wan, omega, pue)
    assert cost.shape == (t,)
    for i in range(t):
        ci, ei, gi = plan_cost(d_old[i], d_new[i], sizes[i], wan,
                               omega[i], pue[i])
        assert float(cost[i]) == pytest.approx(float(ci), rel=1e-6)
        assert float(energy[i]) == pytest.approx(float(ei), rel=1e-6)
        assert float(gb[i]) == pytest.approx(float(gi), rel=1e-6)


def test_plan_cost_no_move_is_exactly_zero():
    d = jnp.asarray(np.random.default_rng(0).dirichlet(np.ones(4), 2),
                    jnp.float32)
    wan = wan_topology(jnp.ones(4), jnp.ones(4))
    c, e, gb = plan_cost(d, d, jnp.array([100.0, 50.0]), wan,
                         jnp.ones(4) * 20.0, jnp.ones(4) * 1.1)
    assert float(c) == 0.0 and float(e) == 0.0 and float(gb) == 0.0


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_evacuation_cost_matches_materialized(seed):
    rng = np.random.default_rng(100 + seed)
    k, n = 3, 5
    d, _, sizes, omega, pue, wan = _case(rng, k, n)
    alive = jnp.asarray((rng.random(n) > 0.4).astype(np.float32))
    if float(alive.sum()) == 0:
        alive = alive.at[0].set(1.0)
    if seed == 2:
        # A dataset whose replicas all sat on dead sites: the
        # restore-from-backup source mix (lost_all branch).
        d = d.at[0].set(jnp.where(alive > 0.5, 0.0, d[0]))
        d = d.at[0].set(d[0] / jnp.maximum(d[0].sum(), 1e-9))
    _, d_masked, d_drop, _ = drop_site_mask(jnp.zeros((n, k)), d, alive)
    ref = transfer_cost(
        evacuation_plan(d_masked, d_drop, sizes), wan, omega, pue
    )
    fused = evacuation_cost(d_masked, d_drop, sizes, wan, omega, pue)
    for r, f in zip(ref, fused):
        assert float(f) == pytest.approx(float(r), rel=2e-5, abs=1e-4)


def test_evacuation_cost_one_hot_source_no_cancellation_blowup():
    """A survivor layout concentrated (near-)entirely at one site is the
    catastrophic-cancellation case of the leave-one-out source mean: the
    fused bill must stay tiny and non-negative, like the materialized one
    (caught by the slow chaos sweep before the clamp landed)."""
    n, k = 4, 2
    wan = wan_topology(jnp.ones(n), jnp.ones(n))
    omega = jnp.asarray([20.0, 35.0, 10.0, 25.0])
    pue = jnp.asarray([1.1, 1.2, 1.05, 1.15])
    sizes = jnp.asarray([100.0, 80.0])
    # One-hot + ulp-scale residue holdings; dead site 1 forces need > 0.
    d = jnp.asarray([[1.0 - 3e-8, 1e-8, 1e-8, 1e-8],
                     [0.0, 1.0, 0.0, 0.0]], jnp.float32)
    alive = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    _, d_masked, d_drop, _ = drop_site_mask(jnp.zeros((n, k)), d, alive)
    ref = transfer_cost(
        evacuation_plan(d_masked, d_drop, sizes), wan, omega, pue
    )
    fused = evacuation_cost(d_masked, d_drop, sizes, wan, omega, pue)
    for r, f in zip(ref, fused):
        assert float(f) >= 0.0
        assert float(f) == pytest.approx(float(r), rel=2e-5, abs=1e-3)


def test_recovery_burst_fused_sum_matches_combined_plan():
    """cost(evac + move) == cost(evac) + cost(move): pricing is linear in
    the plan, so the controller's fused fault-path total equals billing
    the summed (K, N, N) burst as one event (what the pre-fused
    controller did)."""
    rng = np.random.default_rng(11)
    k, n = 2, 6
    d, d_tgt, sizes, omega, pue, wan = _case(rng, k, n)
    alive = jnp.ones(n).at[2].set(0.0)
    _, d_masked, d_drop, _ = drop_site_mask(jnp.zeros((n, k)), d, alive)
    d_rec = d_drop + 0.5 * (d_tgt * alive[None, :] - d_drop)
    d_rec = d_rec / jnp.sum(d_rec, axis=1, keepdims=True)
    combined = (evacuation_plan(d_masked, d_drop, sizes)
                + transfer_plan(d_drop, d_rec, sizes))
    ref_c, _, ref_g = transfer_cost(combined, wan, omega, pue)
    ev_c, _, ev_g = evacuation_cost(d_masked, d_drop, sizes, wan, omega, pue)
    mv_c, _, mv_g = plan_cost(d_drop, d_rec, sizes, wan, omega, pue)
    assert float(ev_c + mv_c) == pytest.approx(float(ref_c), rel=1e-5)
    assert float(ev_g + mv_g) == pytest.approx(float(ref_g), rel=1e-5)


@pytest.mark.parametrize("seed,k,n", [(0, 1, 3), (1, 4, 4), (2, 3, 9)])
def test_expected_pull_matches_price_matrix(seed, k, n):
    rng = np.random.default_rng(200 + seed)
    src = jnp.asarray(rng.dirichlet(np.ones(n), k), jnp.float32)
    w = jnp.asarray(rng.uniform(5, 50, n), jnp.float32)
    ref = src @ link_price_matrix(w)
    np.testing.assert_allclose(
        np.asarray(expected_pull(src, w)), np.asarray(ref),
        rtol=1e-5, atol=1e-5,
    )


def test_fused_billing_is_jit_and_vmap_safe():
    """The hot-loop forms must survive jit + vmap (the engines' usage)."""
    rng = np.random.default_rng(3)
    d_old, d_new, sizes, omega, pue, wan = _case(rng, 2, 4)

    @jax.jit
    def run(keys):
        def one(_):
            return plan_cost(d_old, d_new, sizes, wan, omega, pue)[0]
        return jax.vmap(one)(keys)

    out = run(jnp.arange(3))
    assert out.shape == (3,)
    ref = transfer_cost(transfer_plan(d_old, d_new, sizes), wan, omega, pue)
    np.testing.assert_allclose(np.asarray(out), float(ref[0]), rtol=1e-5)
