"""Roofline machinery tests: HLO collective parsing + term derivation."""

import numpy as np

from repro.launch.roofline import (
    _shape_bytes,
    collective_bytes_from_hlo,
    roofline_terms,
)

_HLO = """
HloModule test
ENTRY %main {
  %p0 = f32[1024,512]{1,0} parameter(0)
  %ag = f32[1024,512]{1,0} all-gather(%p0), dimensions={0}
  %ar.1 = bf16[2048]{0} all-reduce(%x), to_apply=%add
  %tuple_ar = (f32[16,64]{1,0}, f32[16,64]{1,0}) all-reduce(%a, %b), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
  %a2a = s8[128,256]{1,0} all-to-all(%z), dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[1024,1024]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}
  %nota = f32[9]{0} add(%q, %r)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[1024,512]") == 1024 * 512 * 4
    assert _shape_bytes("bf16[2048]") == 2048 * 2
    assert _shape_bytes("(f32[16,64], f32[16,64])") == 2 * 16 * 64 * 4
    assert _shape_bytes("s8[128,256]") == 128 * 256
    assert _shape_bytes("pred[]") == 1  # scalar: empty dims = 1 element


def test_collective_parsing_counts_only_collectives():
    out = collective_bytes_from_hlo(_HLO)
    expect = {
        "all-gather": 1024 * 512 * 4,
        "all-reduce": 2048 * 2 + 2 * 16 * 64 * 4,
        "reduce-scatter": 64 * 4,
        "all-to-all": 128 * 256,
        "collective-permute": 8 * 8 * 4,
    }
    assert out["by_kind"] == expect
    assert out["total"] == sum(expect.values())


def test_roofline_terms_dominance():
    # compute-bound case
    r = roofline_terms(flops=197e12, bytes_accessed=819e7, collective_bytes=0, chips=256)
    assert r["dominant"] == "compute_s"
    np.testing.assert_allclose(r["compute_s"], 1.0)
    np.testing.assert_allclose(r["roofline_fraction"], 1.0)
    # memory-bound case
    r = roofline_terms(flops=197e10, bytes_accessed=819e9, collective_bytes=0, chips=256)
    assert r["dominant"] == "memory_s"
    np.testing.assert_allclose(r["memory_s"], 1.0)
    assert r["roofline_fraction"] < 0.05
    # collective-bound case
    r = roofline_terms(flops=0, bytes_accessed=0, collective_bytes=50e9, chips=256)
    assert r["dominant"] == "collective_s"
    np.testing.assert_allclose(r["collective_s"], 1.0)


def test_terms_are_per_device_semantics():
    """chips must NOT divide again (cost_analysis is already per-device)."""
    a = roofline_terms(1e12, 1e9, 1e9, chips=16)
    b = roofline_terms(1e12, 1e9, 1e9, chips=512)
    assert a == b
