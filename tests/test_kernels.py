"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode — executes kernel bodies in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# The parametrized equivalence sweeps below run without hypothesis; only the
# @given property tests need it, so they alone are skipped when it's absent.
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.kernels.gmsa_score import gmsa_score, gmsa_score_ref
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref
from repro.models.ssm import ssd_chunked


# ---------------------------------------------------------------------------
# gmsa_score
# ---------------------------------------------------------------------------

def _gmsa_inputs(key, k, n, dtype):
    ks = jax.random.split(key, 6)
    return (
        (jax.random.uniform(ks[0], (k, n)) * 100).astype(dtype),
        (jax.random.uniform(ks[1], (k, n)) * 50).astype(dtype),
        (jax.random.uniform(ks[2], (k,)) * 40).astype(dtype),
        (jax.random.uniform(ks[3], (k,)) * 10).astype(dtype),
        jax.random.dirichlet(ks[4], jnp.ones(n), (k, n)).astype(dtype),
        (jax.random.uniform(ks[5], (n,)) * 20).astype(dtype),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("k,n", [(1, 4), (4, 17), (8, 128), (8, 256),
                                 (9, 129), (16, 256)])
def test_gmsa_score_matches_ref(k, n, dtype):
    q, mu, a, vp, r, wpue = _gmsa_inputs(jax.random.key(k * 1000 + n), k, n, dtype)
    s_ref, b_ref = gmsa_score_ref(q, mu, a, vp, r, wpue)
    s, b = gmsa_score(q, mu, a, vp, r, wpue, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(s, s_ref, rtol=tol, atol=tol)
    # argmin is a discrete boundary: equal iff no near-tie at tolerance.
    gap = np.partition(np.asarray(s_ref, np.float64), 1, axis=1)
    near_tie = (gap[:, 1] - gap[:, 0]) < 1e-2 * np.abs(gap[:, 0])
    agree = np.asarray(b) == np.asarray(b_ref)
    assert np.all(agree | near_tie)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        k=st.integers(1, 24),
        n=st.integers(2, 200),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_gmsa_score_property(k, n, seed):
        """Property: kernel argmin always indexes a true row minimum."""
        q, mu, a, vp, r, wpue = _gmsa_inputs(jax.random.key(seed), k, n, jnp.float32)
        s_ref, _ = gmsa_score_ref(q, mu, a, vp, r, wpue)
        s, b = gmsa_score(q, mu, a, vp, r, wpue, interpret=True)
        picked = np.asarray(s_ref)[np.arange(k), np.asarray(b)]
        best = np.min(np.asarray(s_ref), axis=1)
        np.testing.assert_allclose(picked, best, rtol=1e-5, atol=1e-4)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_gmsa_score_property():
        pass


# ---------------------------------------------------------------------------
# gmsa_dispatch impl="kernel" — the dispatch-path wiring + fleet e2e
# ---------------------------------------------------------------------------

def test_gmsa_dispatch_kernel_impl_matches_ref_path():
    """The kernel dispatch path agrees with the e-table closed form on the
    fleet tile shape (K=8, N=256 — one K-tile, 2x2 N/J tiles)."""
    from repro.core.gmsa import gmsa_dispatch

    k, n = 8, 256
    q, mu, a, _, r, wpue = _gmsa_inputs(jax.random.key(42), k, n, jnp.float32)
    # e-table path (V applied to the precomputed cost table) vs the
    # raw-(r, wpue) kernel/oracle paths at the same V: the score formulas
    # are algebraically identical (p_it = 1).
    v = 3.0
    e_table = jnp.einsum("kij,j->ki", r, wpue)          # p_it = 1
    f_ref = gmsa_dispatch(q.T, a, mu.T, e_table, v)
    f_kernel = gmsa_dispatch(
        q.T, a, mu.T, None, v, impl="kernel", r=r, wpue=wpue, interpret=True
    )
    f_oracle = gmsa_dispatch(
        q.T, a, mu.T, None, v, impl="ref", r=r, wpue=wpue
    )
    # One-hot columns: near-ties may differ by a ULP of score — compare
    # through realized scores instead of argmin indices.
    s_ref, _ = gmsa_score_ref(q, mu, a, v * jnp.ones((k,)), r, wpue)
    picked = lambda f: np.asarray(s_ref)[np.arange(k), np.asarray(f).argmax(0)]
    best = np.min(np.asarray(s_ref), axis=1)
    np.testing.assert_allclose(picked(f_kernel), best, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(picked(f_oracle), best, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(picked(f_ref), best, rtol=1e-4, atol=1e-3)


def test_gmsa_dispatch_kernel_requires_raw_operands():
    from repro.core.gmsa import gmsa_dispatch

    q = jnp.zeros((4, 2))
    with pytest.raises(ValueError, match="raw operands"):
        gmsa_dispatch(q, jnp.ones(2), q, None, 1.0, impl="kernel")
    with pytest.raises(ValueError, match="unknown impl"):
        gmsa_dispatch(q, jnp.ones(2), q, jnp.zeros((2, 4)), 1.0,
                      impl="bogus")


def test_fleet256_end_to_end_kernel_vs_ref():
    """A short N=256 fleet_256 GMSA run completes through
    gmsa_dispatch(..., impl="kernel") (interpret mode) and matches the
    reference engine slot for slot."""
    from repro.configs.fleet_256 import FleetConfig, make_fleet_builder
    from repro.core.gmsa import gmsa_policy, make_kernel_policy
    from repro.core.simulator import simulate

    cfg = FleetConfig(t_slots=8)
    template, _ = make_fleet_builder(cfg)
    key = jax.random.key(0)
    o_ref = simulate(template, gmsa_policy, key, cfg.v)
    o_k = simulate(
        template, make_kernel_policy(template.r, template.p_it), key, cfg.v
    )
    agree = float((o_ref.f_trace == o_k.f_trace).mean())
    assert agree > 0.999, agree
    np.testing.assert_allclose(
        np.asarray(o_k.cost), np.asarray(o_ref.cost), rtol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(o_k.q_final), np.asarray(o_ref.q_final), rtol=1e-4
    )
    # The pure-jnp oracle fallback drives the same run too.
    o_r = simulate(
        template,
        make_kernel_policy(template.r, template.p_it, impl="ref"),
        key, cfg.v,
    )
    np.testing.assert_allclose(
        np.asarray(o_r.cost), np.asarray(o_ref.cost), rtol=1e-4
    )


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

def _ssd_inputs(key, b, s, h, p, n, dtype):
    ks = jax.random.split(key, 5)
    return (
        jax.random.normal(ks[0], (b, s, h, p)).astype(dtype),
        jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype),
        -jnp.exp(jax.random.normal(ks[2], (h,))),
        jax.random.normal(ks[3], (b, s, n)).astype(dtype),
        jax.random.normal(ks[4], (b, s, n)).astype(dtype),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [(1, 32, 2, 8, 16, 8), (2, 128, 3, 64, 128, 128), (1, 72, 2, 32, 64, 16)],
)
def test_ssd_scan_matches_ref(b, s, h, p, n, chunk, dtype):
    x, dt, a, bm, cm = _ssd_inputs(jax.random.key(b + s), b, s, h, p, n, dtype)
    y_ref, h_ref = ssd_scan_ref(x, dt, a, bm, cm)
    y, hf = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    tol = 3e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(hf, h_ref, rtol=tol, atol=tol)


def test_ssd_scan_matches_model_path():
    """Kernel == the model's chunked pure-JAX path (third formulation)."""
    b, s, h, p, n = 2, 64, 2, 16, 32
    x, dt, a, bm, cm = _ssd_inputs(jax.random.key(7), b, s, h, p, n, jnp.float32)
    y_kernel, h_kernel = ssd_scan(x, dt, a, bm, cm, chunk=16, interpret=True)
    y_model, h_model = ssd_chunked(x, dt, a, bm, cm, 16)
    np.testing.assert_allclose(y_kernel, y_model, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_kernel, h_model, rtol=2e-4, atol=2e-4)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        s=st.integers(4, 96),
        chunk=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_ssd_scan_chunk_invariance(s, chunk, seed):
        """Property: the result must not depend on the chunk size."""
        b, h, p, n = 1, 2, 8, 16
        x, dt, a, bm, cm = _ssd_inputs(jax.random.key(seed), b, s, h, p, n, jnp.float32)
        y1, h1 = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
        y2, h2 = ssd_scan(x, dt, a, bm, cm, chunk=s, interpret=True)
        np.testing.assert_allclose(y1, y2, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(h1, h2, rtol=3e-4, atol=3e-4)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_ssd_scan_chunk_invariance():
        pass
