"""Distributed-runtime tests.

Multi-device cases run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps its single CPU device (the dry-run is the only place allowed
to fake 512 devices; see the assignment note).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.distributed.sharding import param_pspecs
from repro.launch.mesh import make_debug_mesh
from repro.models.lm import layer_param_specs, top_param_specs


def test_param_pspecs_cover_every_param():
    mesh = make_debug_mesh(1)
    for arch in C.list_archs():
        cfg = C.get_arch(arch, "smoke")
        specs = param_pspecs(cfg, mesh)
        assert set(specs["blocks"]) == set(layer_param_specs(cfg))
        assert set(specs) - {"blocks"} == set(top_param_specs(cfg))


def test_fallback_logged_for_indivisible_heads():
    """qwen2: 14 heads on a 16-way model axis must fall back to replication."""
    import jax as _jax
    mesh = _jax.make_mesh((1, 1), ("data", "model"))  # sizes 1: all shardable
    log: dict = {}
    cfg = C.get_arch("qwen2-0.5b")
    param_pspecs(cfg, mesh, log)
    assert "replicated_fallbacks" not in log  # axis size 1 always shards

    # Fake a 16-way model axis via divisibility check only.
    from repro.distributed.sharding import _shardable
    assert not _shardable("q_out", cfg, 16)
    assert not _shardable("kv_out", cfg, 16)
    assert _shardable("mlp", cfg, 16)
    assert _shardable("vocab", cfg, 16)


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp, numpy as np
    import repro.configs as C
    from repro.configs.base import ShapeConfig
    from repro.models import init_params, init_cache
    from repro.models.inputs import make_batch, make_decode_tokens
    from repro.train.step import TrainStepConfig, make_train_step
    from repro.train.optimizer import adamw_init
    from repro.serve.step import make_decode_step

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    # jax >= 0.5 spells the mesh context jax.set_mesh; on older versions the
    # Mesh object itself is the context manager.
    mesh_ctx = (lambda m: jax.set_mesh(m)) if hasattr(jax, "set_mesh") else (lambda m: m)
    cfg = C.get_arch("qwen2-0.5b", "smoke")
    shape = ShapeConfig("t", "train", 64, 8)
    out = {}
    params_result = {}
    for sync in ["native", "int8"]:
        tcfg = TrainStepConfig(microbatches=2, remat="dots", grad_sync=sync)
        step, pspecs, opt_specs, shardings_for, init_efb = make_train_step(cfg, mesh, tcfg)
        batch = make_batch(cfg, shape, jax.random.key(0), embed_dtype=jnp.float32)
        with mesh_ctx(mesh):
            in_sh, out_sh = shardings_for(batch, shape.global_batch)
            params = jax.device_put(init_params(jax.random.key(1), cfg, jnp.float32), in_sh[0])
            opt = jax.device_put(adamw_init(params), in_sh[1])
            batchp = jax.device_put(batch, in_sh[2])
            efb = jax.device_put(init_efb(params), in_sh[3])
            jstep = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            p2, o2, metrics, efb2 = jstep(params, opt, batchp, efb)
            out[sync] = float(metrics["loss"])
            params_result[sync] = p2
    delta = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params_result["native"]),
                        jax.tree.leaves(params_result["int8"]))
    )
    # sharded decode
    dshape = ShapeConfig("d", "decode", 128, 8)
    fn, pspecs, shardings_for = make_decode_step(cfg, mesh)
    with mesh_ctx(mesh):
        cache = init_cache(cfg, 8, 128, jnp.float32, prefilled=128)
        in_sh, out_sh = shardings_for(cache, 8)
        params = jax.device_put(init_params(jax.random.key(1), cfg, jnp.float32), in_sh[0])
        cache = jax.device_put(cache, in_sh[1])
        toks = jax.device_put(make_decode_tokens(cfg, dshape), in_sh[2])
        logits, _ = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)(params, cache, toks)
        decode_finite = bool(jnp.all(jnp.isfinite(logits)))
    print(json.dumps({"loss": out, "param_delta": delta, "decode_finite": decode_finite}))
""")


@pytest.mark.slow
def test_multidevice_train_and_decode_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True, text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        env=env, timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    report = json.loads(res.stdout.strip().splitlines()[-1])
    # int8-compressed grads track native within quantization error.
    assert abs(report["loss"]["native"] - report["loss"]["int8"]) < 1e-3
    assert report["param_delta"] < 1e-4
    assert report["decode_finite"]


_MOE_MESH_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import jax, jax.numpy as jnp
    import repro.configs as C
    from repro.distributed.compat import get_abstract_mesh
    from repro.models.moe import moe_ffn, _moe_local, expert_capacity

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    mesh_ctx = (lambda m: jax.set_mesh(m)) if hasattr(jax, "set_mesh") else (lambda m: m)
    cfg = C.get_arch("deepseek-moe-16b", "smoke")
    d, e = cfg.d_model, cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    k = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(k[0], (4, 16, d), jnp.float32)
    wr = jax.random.normal(k[1], (d, e), jnp.float32) * 0.02
    wg = jax.random.normal(k[2], (e, d, f), jnp.float32) * 0.02
    wu = jax.random.normal(k[3], (e, d, f), jnp.float32) * 0.02
    wd = jax.random.normal(k[4], (e, f, d), jnp.float32) * 0.02

    y_ref, aux_ref = _moe_local(x, wr, wg, wu, wd, cfg, expert_capacity(16, cfg, 1.25))
    with mesh_ctx(mesh):
        ambient = not get_abstract_mesh().empty
        y, aux = jax.jit(lambda *a: moe_ffn(*a, cfg))(x, wr, wg, wu, wd)
    print(json.dumps({
        "ambient": ambient,
        "dy": float(jnp.max(jnp.abs(y - y_ref))),
        "daux": abs(float(aux) - float(aux_ref)),
    }))
""")


@pytest.mark.slow
def test_moe_manual_shard_map_path_live_subprocess():
    """The ambient-mesh compat shim must expose the mesh on every jax version,
    so moe_ffn's manual shard_map path (not the replicating fallback) runs —
    and agrees with the single-device reference."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _MOE_MESH_PROG],
        capture_output=True, text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        env=env, timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    report = json.loads(res.stdout.strip().splitlines()[-1])
    assert report["ambient"], "compat.get_abstract_mesh missed the ambient mesh"
    assert report["dy"] < 1e-5
    assert report["daux"] < 1e-6


def test_compression_roundtrip_single_pod():
    """n_pods=1 degenerate case: compressed sum == identity + residual."""
    from repro.distributed.compression import _dequantize, _quantize
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, 256)).astype(np.float32)
    q, s = _quantize(jax.numpy.asarray(x))
    back = np.asarray(_dequantize(q, s))
    assert np.max(np.abs(back - x)) <= np.max(np.abs(x)) / 127 + 1e-6
