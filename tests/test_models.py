"""Model-zoo tests: per-arch smoke, attention/SSD equivalences, decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import ShapeConfig
from repro.models import (
    count_params,
    decode_step,
    forward,
    init_params,
    loss_fn,
    prefill_step,
)
from repro.models.attention import attend_blockwise, attend_naive
from repro.models.inputs import make_batch
from repro.models.ssm import ssd_chunked, ssd_step

ARCHS = C.list_archs()
SMOKE_TRAIN = ShapeConfig("smoke_train", "train", 64, 2)


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


# ---------------------------------------------------------------------------
# Per-arch smoke: reduced config, one forward/train step, shapes + no NaNs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_grad(arch, key):
    cfg = C.get_arch(arch, "smoke")
    params = init_params(key, cfg, jnp.float32)
    batch = make_batch(cfg, SMOKE_TRAIN, key, embed_dtype=jnp.float32)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True
    )(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_exact(arch, key):
    cfg = C.get_arch(arch, "smoke")
    params = init_params(key, cfg, jnp.float32)
    n = sum(x.size for x in jax.tree.leaves(params))
    assert n == count_params(cfg)
    # Active <= total; strictly less iff MoE.
    assert count_params(cfg, active_only=True) <= n
    if cfg.is_moe:
        assert count_params(cfg, active_only=True) < n


def test_full_configs_match_published_sizes():
    published = {
        "phi3.5-moe-42b-a6.6b": (41.9e9, 6.6e9),
        "deepseek-moe-16b": (16.4e9, 2.8e9),
        "granite-3-2b": (2.5e9, None),
        "stablelm-12b": (12.1e9, None),
        "phi4-mini-3.8b": (3.8e9, None),
        "qwen2-0.5b": (0.49e9, None),
        "hymba-1.5b": (1.5e9, None),
        "internvl2-76b": (70.0e9, None),
        "mamba2-2.7b": (2.7e9, None),
        "hubert-xlarge": (0.96e9, None),
    }
    for arch, (tot, act) in published.items():
        cfg = C.get_arch(arch)
        assert abs(cfg.param_count() - tot) / tot < 0.12, arch
        if act:
            assert abs(cfg.active_param_count() - act) / act < 0.12, arch


# ---------------------------------------------------------------------------
# Attention equivalences
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 7)])
@pytest.mark.parametrize("sq,sk,h,hkv,d", [(16, 16, 4, 2, 8), (8, 24, 6, 2, 16)])
def test_blockwise_matches_naive(causal, window, sq, sk, h, hkv, d, key):
    if sq != sk and causal:
        return  # cross-length causal needs aligned positions; covered by decode tests
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (2, sk, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (2, sk, hkv, d), jnp.float32)
    qp, kp = jnp.arange(sq), jnp.arange(sk)
    ref = attend_naive(q, k, v, qp, kp, causal=causal, window=window)
    out = attend_blockwise(q, k, v, qp, kp, causal=causal, window=window, chunk=8)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD: chunked scan == step recurrence
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_step(key):
    b, s, h, p, n, chunk = 2, 32, 3, 4, 8, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y_chunk, h_final = ssd_chunked(x, dt, a, bm, cm, chunk)

    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y_t, state = ssd_step(x[:, t], dt[:, t], a, bm[:, t], cm[:, t], state)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_step, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h_final, state, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Decode parity: prefill + decode_step == full forward (non-MoE archs)
# ---------------------------------------------------------------------------

DECODE_ARCHS = ["qwen2-0.5b", "granite-3-2b", "mamba2-2.7b", "hymba-1.5b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch, key):
    cfg = C.get_arch(arch, "smoke")
    s = 24
    params = init_params(key, cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(7), (2, s + 1), 0, cfg.vocab_size)

    full_logits, _ = forward(params, cfg, tokens, attn_impl="naive")
    last_ref = full_logits[:, -1, : cfg.vocab_size]

    _, cache = prefill_step(
        params, cfg, tokens[:, :s], attn_impl="naive", cache_dtype=jnp.float32,
        cache_len=s + 8,
    )
    step_logits, cache = decode_step(params, cfg, cache, tokens[:, s:])
    last = step_logits[:, 0, : cfg.vocab_size]
    np.testing.assert_allclose(last, last_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b", "deepseek-moe-16b"])
def test_moe_decode_runs(arch, key):
    """MoE decode parity is capacity-dependent; assert structure + finiteness."""
    cfg = C.get_arch(arch, "smoke")
    params = init_params(key, cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(3), (2, 17), 0, cfg.vocab_size)
    _, cache = prefill_step(
        params, cfg, tokens[:, :16], cache_dtype=jnp.float32, cache_len=24
    )
    logits, cache2 = decode_step(params, cfg, cache, tokens[:, 16:])
    assert logits.shape[:2] == (2, 1)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"][0]) == 17


def test_vocab_padding_is_masked(key):
    cfg = C.get_arch("qwen2-0.5b", "smoke")
    params = init_params(key, cfg, jnp.float32)
    batch = make_batch(cfg, SMOKE_TRAIN, key, embed_dtype=jnp.float32)
    loss1, _ = loss_fn(params, cfg, batch)
    # Corrupt padded embedding rows; loss must not change.
    emb = params["embed"]
    params2 = dict(params)
    params2["embed"] = emb.at[cfg.vocab_size:].set(1e3)
    # Padded vocab rows feed the tied head only through masked logit columns.
    loss2, _ = loss_fn(params2, cfg, batch)
    np.testing.assert_allclose(loss1, loss2, rtol=1e-6)


def test_sliding_window_ring_decode_parity(key):
    """Ring-buffer eviction: decode through a window-sized cache matches the
    windowed full forward even after positions wrap the ring."""
    import dataclasses

    cfg = dataclasses.replace(
        C.get_arch("hymba-1.5b", "smoke"), sliding_window=16, ssm_chunk=8
    )
    s = 40  # prompt longer than the window: ring has wrapped twice
    params = init_params(key, cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(11), (2, s + 1), 0, cfg.vocab_size)

    full_logits, _ = forward(params, cfg, tokens, attn_impl="naive")
    last_ref = full_logits[:, -1, : cfg.vocab_size]

    _, cache = prefill_step(
        params, cfg, tokens[:, :s], attn_impl="naive", cache_dtype=jnp.float32
    )
    assert cache["k"].shape[2] == 16  # window-sized ring
    step_logits, _ = decode_step(params, cfg, cache, tokens[:, s:])
    np.testing.assert_allclose(
        step_logits[:, 0, : cfg.vocab_size], last_ref, rtol=3e-4, atol=3e-4
    )


def test_fp8_kv_cache_decode_close(key):
    """Quantized (fp8 direct-cast) KV cache: decode logits stay close to fp32."""
    cfg = C.get_arch("granite-3-2b", "smoke")
    s = 24
    params = init_params(key, cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(5), (2, s + 1), 0, cfg.vocab_size)
    _, cache32 = prefill_step(
        params, cfg, tokens[:, :s], attn_impl="naive",
        cache_dtype=jnp.float32, cache_len=s + 4,
    )
    ref, _ = decode_step(params, cfg, cache32, tokens[:, s:])
    _, cache8 = prefill_step(
        params, cfg, tokens[:, :s], attn_impl="naive",
        cache_dtype=jnp.float8_e4m3fn, cache_len=s + 4,
    )
    out, _ = decode_step(params, cfg, cache8, tokens[:, s:])
    scale = float(jnp.max(jnp.abs(ref)))
    err = float(jnp.max(jnp.abs(out - ref))) / scale
    assert err < 0.08, f"fp8 KV decode relative error {err:.3f}"


def test_head_padding_zero_init_equivalence(key):
    """Deployment head-padding (§Perf C1): extra heads with zeroed output
    rows leave the function unchanged — padding is arch-equivalent."""
    import dataclasses

    cfg = C.get_arch("qwen2-0.5b", "smoke")          # 4 heads, qkv bias
    hd = cfg.resolved_head_dim
    cfg_pad = dataclasses.replace(cfg, num_heads=6, head_dim=hd)
    params = init_params(key, cfg, jnp.float32)
    params_pad = init_params(jax.random.key(99), cfg_pad, jnp.float32)

    # Padding must preserve the GQA grouping: group g of the padded model
    # holds the base group's heads plus one inert head (per-group append).
    kv = cfg.num_kv_heads
    g_base = cfg.num_heads // kv            # heads per group, base
    g_pad = cfg_pad.num_heads // kv         # heads per group, padded
    src_cols, dst_cols = [], []
    for g in range(kv):
        for j in range(g_base):
            src_cols += list(range((g * g_base + j) * hd, (g * g_base + j + 1) * hd))
            dst_cols += list(range((g * g_pad + j) * hd, (g * g_pad + j + 1) * hd))
    src_cols = np.asarray(src_cols)
    dst_cols = np.asarray(dst_cols)

    blocks = dict(params_pad["blocks"])
    base = params["blocks"]
    blocks["wq"] = blocks["wq"].at[:, :, dst_cols].set(base["wq"][:, :, src_cols])
    blocks["bq"] = blocks["bq"].at[:, dst_cols].set(base["bq"][:, src_cols])
    wo = jnp.zeros_like(blocks["wo"])
    blocks["wo"] = wo.at[:, dst_cols, :].set(base["wo"][:, src_cols, :])
    for name in base:
        if name not in ("wq", "bq", "wo"):
            blocks[name] = base[name]
    padded = {**{k: v for k, v in params.items() if k != "blocks"}, "blocks": blocks}

    tokens = jax.random.randint(jax.random.key(2), (2, 16), 0, cfg.vocab_size)
    ref, _ = forward(params, cfg, tokens, attn_impl="naive")
    out, _ = forward(padded, cfg_pad, tokens, attn_impl="naive")
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


# Only this one property test needs hypothesis; the arch smoke / decode
# parity tests above must keep running without it.
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        sq=st.integers(2, 40),
        h=st.sampled_from([2, 4, 6]),
        hkv=st.sampled_from([1, 2]),
        d=st.sampled_from([4, 8]),
        chunk=st.sampled_from([4, 8, 16]),
        q_chunk=st.sampled_from([8, 16]),
        causal=st.booleans(),
        window=st.sampled_from([0, 5]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_blockwise_attention_property(sq, h, hkv, d, chunk, q_chunk, causal,
                                          window, seed):
        """Property: double-tiled online-softmax == naive attention for any
        (shape, tiling, mask) combination."""
        if h % hkv:
            h = hkv * (h // hkv or 1)
        ks = jax.random.split(jax.random.key(seed), 3)
        q = jax.random.normal(ks[0], (1, sq, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (1, sq, hkv, d), jnp.float32)
        v = jax.random.normal(ks[2], (1, sq, hkv, d), jnp.float32)
        pos = jnp.arange(sq)
        ref = attend_naive(q, k, v, pos, pos, causal=causal, window=window)
        out = attend_blockwise(q, k, v, pos, pos, causal=causal, window=window,
                               chunk=chunk, q_chunk=q_chunk)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_blockwise_attention_property():
        pass
