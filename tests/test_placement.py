"""Two-timescale placement subsystem tests (repro.placement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import data_dispatch, static_placement_rule
from repro.core.gmsa import dispatch_fn
from repro.core.simulator import simulate
from repro.placement import (
    PlacementConfig,
    capacity_project,
    effective_replicas,
    make_adaptive_rule,
    replica_read_assignment,
    simulate_placed,
    simulate_placed_many,
    summarize_placed,
    target_placement,
    transfer_cost,
    transfer_latency,
    transfer_plan,
    wan_topology,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.drift import dataset_growth_trace, ingest_drift_trace


@pytest.fixture(scope="module")
def paper_setup():
    cfg = PaperSimConfig()
    template, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    k_bw = jax.random.split(root, 6)[2]
    up, down = bandwidth_draw(k_bw, cfg.n_sites)
    return cfg, template, build, up, down


# ---------------------------------------------------------------------------
# WAN transfer-cost accounting
# ---------------------------------------------------------------------------

def test_transfer_plan_conserves_bytes():
    d_old = jnp.array([[0.5, 0.3, 0.2, 0.0], [0.25, 0.25, 0.25, 0.25]])
    d_new = jnp.array([[0.1, 0.3, 0.2, 0.4], [0.25, 0.25, 0.25, 0.25]])
    sizes = jnp.array([100.0, 40.0])
    plan = transfer_plan(d_old, d_new, sizes)                    # (K, N, N)
    # Row sums = per-site exports, col sums = per-site imports.
    out_gb = np.maximum(np.asarray(d_old - d_new), 0.0) * np.asarray(sizes)[:, None]
    in_gb = np.maximum(np.asarray(d_new - d_old), 0.0) * np.asarray(sizes)[:, None]
    np.testing.assert_allclose(np.asarray(plan).sum(2), out_gb, atol=1e-4)
    np.testing.assert_allclose(np.asarray(plan).sum(1), in_gb, atol=1e-4)
    # Unchanged dataset (type 1) moves nothing; diagonal never used.
    assert float(plan[1].sum()) == pytest.approx(0.0, abs=1e-6)
    assert float(jnp.trace(plan[0])) == pytest.approx(0.0, abs=1e-6)


def test_transfer_cost_scales_with_energy_per_gb():
    up = jnp.array([1.0, 2.0, 0.5])
    down = jnp.array([1.5, 0.8, 2.0])
    d_old = jnp.array([[1.0, 0.0, 0.0]])
    d_new = jnp.array([[0.0, 0.5, 0.5]])
    sizes = jnp.array([100.0])
    omega = jnp.array([20.0, 10.0, 15.0])
    pue = jnp.array([1.1, 1.05, 1.2])
    plan = transfer_plan(d_old, d_new, sizes)
    w1 = wan_topology(up, down, energy_per_gb=0.01)
    w2 = wan_topology(up, down, energy_per_gb=0.02)
    c1, e1, gb1 = transfer_cost(plan, w1, omega, pue)
    c2, e2, gb2 = transfer_cost(plan, w2, omega, pue)
    assert float(gb1) == pytest.approx(100.0, rel=1e-5)
    assert float(c2) == pytest.approx(2 * float(c1), rel=1e-5)
    assert float(e2) == pytest.approx(2 * float(e1), rel=1e-5)
    # Latency: bottleneck link drains 50 GB over the harmonic i->j rate.
    lat = transfer_latency(plan, w1)
    bw = np.asarray(w1.link_bw)
    expected = max(50.0 * 8.0 / bw[0, 1], 50.0 * 8.0 / bw[0, 2])
    assert float(lat) == pytest.approx(expected, rel=1e-4)


def test_transfer_cost_zero_when_no_move():
    up = down = jnp.ones((4,))
    d = jnp.array([[0.4, 0.3, 0.2, 0.1]])
    plan = transfer_plan(d, d, jnp.array([100.0]))
    c, e, gb = transfer_cost(plan, wan_topology(up, down), jnp.ones(4), jnp.ones(4))
    assert float(c) == 0.0 and float(e) == 0.0 and float(gb) == 0.0


# ---------------------------------------------------------------------------
# Capacity-constraint respect
# ---------------------------------------------------------------------------

def test_capacity_project_respects_caps_and_simplex():
    key = jax.random.key(0)
    pref = jax.random.dirichlet(key, jnp.full((5,), 2.0), (6,))     # (K=6, N=5)
    sizes = jnp.full((6,), 100.0)                                   # 600 GB total
    cap = jnp.array([150.0, 150.0, 150.0, 150.0, 150.0])            # 750 GB room
    p = capacity_project(pref, sizes, cap)
    np.testing.assert_allclose(np.asarray(p).sum(1), 1.0, atol=1e-4)
    load = np.asarray(jnp.sum(p * sizes[:, None], axis=0))
    assert (load <= np.asarray(cap) * 1.005).all(), load
    assert (np.asarray(p) >= -1e-7).all()


def test_target_placement_vertex_limit():
    """temp -> 0 with no caps recovers the one-hot LP vertex (argmin site)."""
    scores = jnp.array([[3.0, 1.0, 2.0], [0.5, 4.0, 2.0]])
    sizes = jnp.array([10.0, 10.0])
    cap = jnp.full((3,), jnp.inf)
    p = target_placement(scores, sizes, cap, temp=1e-4)
    np.testing.assert_allclose(np.asarray(p), np.array(
        [[0.0, 1.0, 0.0], [1.0, 0.0, 0.0]]), atol=1e-4)


def test_simulate_placed_capacity_respected(paper_setup):
    cfg, template, _, up, down = paper_setup
    n_epochs = cfg.t_slots // 48
    ing = ingest_drift_trace(jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites)
    sizes = dataset_growth_trace(n_epochs, cfg.k_types, 100.0, 0.05)
    pcfg = PlacementConfig(
        epoch_slots=48, growth=0.25, capacity_gb=(80.0, 80.0, 80.0, 80.0),
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    outs = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        jax.random.key(3), pcfg, ingest=ing, sizes_gb=sizes,
    )
    np.testing.assert_allclose(np.asarray(outs.placements).sum(-1), 1.0, atol=1e-4)
    # Epochs the controller touched (e > 0) respect the caps. (The drifted
    # layout it inherits may violate them transiently; the controller can
    # only correct within its move budget.)
    load = (np.asarray(outs.placements) * np.asarray(sizes)[:, :, None]).sum(1)
    assert (load[1:] <= 80.0 * 1.02 + np.asarray(sizes)[1:].sum(1, keepdims=True)
            * pcfg.growth).all(), load


# ---------------------------------------------------------------------------
# Two-timescale engine
# ---------------------------------------------------------------------------

def test_equivalence_to_plain_simulate_when_w_geq_t(paper_setup):
    cfg, template, _, up, down = paper_setup
    key = jax.random.key(11)
    pol = dispatch_fn(1.0)
    for w in (cfg.t_slots, 4 * cfg.t_slots):        # W = T and W > T
        pcfg = PlacementConfig(
            epoch_slots=w,
            manager_share=cfg.manager_share, map_share=cfg.map_share,
        )
        outs_p = simulate_placed(
            template, up, down, pol, static_placement_rule, key, pcfg
        )
        outs_s = simulate(template, pol, key)
        np.testing.assert_array_equal(np.asarray(outs_p.cost), np.asarray(outs_s.cost))
        np.testing.assert_array_equal(
            np.asarray(outs_p.f_trace), np.asarray(outs_s.f_trace)
        )
        np.testing.assert_array_equal(
            np.asarray(outs_p.q_final), np.asarray(outs_s.q_final)
        )
        assert float(outs_p.wan_cost.sum()) == 0.0


def test_equivalence_w_geq_t_randomized_policy(paper_setup):
    """The PRNG stream matches simulate's precomputed path, so even the
    RANDOM baseline (which consumes the keys) reproduces bit-for-bit."""
    from repro.core.baselines import random_dispatch

    cfg, template, _, up, down = paper_setup
    key = jax.random.key(21)
    pcfg = PlacementConfig(
        epoch_slots=cfg.t_slots,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    outs_p = simulate_placed(
        template, up, down, random_dispatch, static_placement_rule, key, pcfg
    )
    outs_s = simulate(template, random_dispatch, key)
    np.testing.assert_array_equal(
        np.asarray(outs_p.f_trace), np.asarray(outs_s.f_trace)
    )
    np.testing.assert_array_equal(np.asarray(outs_p.cost), np.asarray(outs_s.cost))


def test_equivalence_adaptive_rule_w_geq_t(paper_setup):
    """Epoch 0 never moves data, so even the adaptive rule is a no-op at W >= T."""
    cfg, template, _, up, down = paper_setup
    key = jax.random.key(12)
    pcfg = PlacementConfig(
        epoch_slots=cfg.t_slots,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    outs_p = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up), key, pcfg
    )
    outs_s = simulate(template, dispatch_fn(1.0), key)
    np.testing.assert_array_equal(np.asarray(outs_p.cost), np.asarray(outs_s.cost))
    assert float(outs_p.wan_gb.sum()) == 0.0


def test_controller_matches_time_varying_replay(paper_setup):
    """Scan-of-scans == plain simulate over the materialized (T,K,N,N) traces."""
    cfg, template, _, up, down = paper_setup
    key = jax.random.key(13)
    w = 48
    n_epochs = cfg.t_slots // w
    ing = ingest_drift_trace(jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites,
                             bias_strength=0.3)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.2,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    pol = dispatch_fn(1.0)
    outs = simulate_placed(
        template, up, down, pol, make_adaptive_rule(up), key, pcfg, ingest=ing
    )
    replay = simulate(
        template._replace(
            r=jnp.repeat(outs.r_trace, w, axis=0),
            data_dist=jnp.repeat(outs.placements, w, axis=0),
        ),
        pol, key,
    )
    np.testing.assert_allclose(
        np.asarray(replay.cost), np.asarray(outs.cost), rtol=1e-5
    )
    np.testing.assert_array_equal(
        np.asarray(replay.f_trace), np.asarray(outs.f_trace)
    )


def test_simulate_time_varying_inputs_match_static(paper_setup):
    """Tiling static (r, data_dist) over T changes nothing, on both policy paths."""
    cfg, template, _, _, _ = paper_setup
    key = jax.random.key(14)
    tiled = template._replace(
        r=jnp.broadcast_to(template.r, (cfg.t_slots,) + template.r.shape),
        data_dist=jnp.broadcast_to(
            template.data_dist, (cfg.t_slots,) + template.data_dist.shape
        ),
    )
    for pol in (dispatch_fn(1.0), data_dispatch):   # scan path + precomputed path
        o_s = simulate(template, pol, key)
        o_t = simulate(tiled, pol, key)
        np.testing.assert_allclose(np.asarray(o_t.cost), np.asarray(o_s.cost),
                                   rtol=1e-6)


def test_adaptive_beats_static_on_drifting_trace(paper_setup):
    """The benchmark claim at reduced Monte-Carlo scale: drifting ingest
    toward the expensive site, adaptive re-placement wins on total cost."""
    cfg, template, build, up, down = paper_setup
    w = 48
    n_epochs = cfg.t_slots // w
    # New data concentrates at ForestCity (priciest power) over the day.
    ing = ingest_drift_trace(
        jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites,
        bias=jnp.array([0.05, 0.8, 0.05, 0.10]), bias_strength=0.5,
    )
    sizes = dataset_growth_trace(n_epochs, cfg.k_types, 100.0, 0.05)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    key = jax.random.key(15)
    pol = dispatch_fn(1.0)
    res = {}
    for name, rule in [
        ("adaptive", make_adaptive_rule(up)),
        ("static", static_placement_rule),
    ]:
        outs = simulate_placed_many(
            build, up, down, pol, rule, key, 16, pcfg, ingest=ing, sizes_gb=sizes
        )
        assert outs.cost.shape == (16, cfg.t_slots)
        res[name] = summarize_placed(outs)
    assert (res["adaptive"]["time_avg_total_cost"]
            < res["static"]["time_avg_total_cost"]), res
    assert res["adaptive"]["time_avg_wan_cost"] > 0.0
    assert res["static"]["total_wan_gb"] == 0.0


def test_sync_premium_charged_per_epoch(paper_setup):
    """Spread layouts pay the replication sync bill every epoch (including
    epoch 0); a fully concentrated layout pays nothing."""
    cfg, template, _, up, down = paper_setup
    pol = dispatch_fn(1.0)
    pcfg = PlacementConfig(
        epoch_slots=48, update_fraction=0.01,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    outs = simulate_placed(
        template, up, down, pol, static_placement_rule, jax.random.key(5), pcfg
    )
    # facebook_4dc's initial layout spans several sites -> >1 effective
    # replica -> a positive sync bill in every epoch, even with no moves.
    assert (np.asarray(outs.sync_cost) > 0.0).all()
    assert float(outs.wan_cost.sum()) == 0.0

    one_hot_d = jnp.zeros_like(template.data_dist).at[:, 0].set(1.0)
    outs1 = simulate_placed(
        template._replace(data_dist=one_hot_d), up, down, pol,
        static_placement_rule, jax.random.key(5), pcfg,
    )
    assert float(outs1.sync_cost.sum()) == pytest.approx(0.0, abs=1e-6)

    # The premium is linear in update_fraction.
    outs2 = simulate_placed(
        template, up, down, pol, static_placement_rule, jax.random.key(5),
        PlacementConfig(
            epoch_slots=48, update_fraction=0.02,
            manager_share=cfg.manager_share, map_share=cfg.map_share,
        ),
    )
    np.testing.assert_allclose(
        np.asarray(outs2.sync_cost), 2.0 * np.asarray(outs.sync_cost), rtol=1e-5
    )


def test_simulate_placed_rejects_indivisible_horizon(paper_setup):
    cfg, template, _, up, down = paper_setup
    pcfg = PlacementConfig(epoch_slots=50)          # 288 % 50 != 0
    with pytest.raises(ValueError, match="multiple"):
        simulate_placed(
            template, up, down, dispatch_fn(1.0), static_placement_rule,
            jax.random.key(0), pcfg,
        )


# ---------------------------------------------------------------------------
# Replica selection
# ---------------------------------------------------------------------------

def test_replica_read_assignment_prefers_local_replica():
    up = jnp.array([1.0, 1.0, 1.0])
    down = jnp.array([1.0, 1.0, 1.0])
    wan = wan_topology(up, down)
    wpue = jnp.array([30.0, 10.0, 20.0])
    d = jnp.array([[0.5, 0.5, 0.0]])                # replicas at sites 0, 1
    sel = replica_read_assignment(d, wan, wpue)     # (K, reader, host)
    # Readers holding a replica read locally; site 2 pulls from the cheap host.
    assert int(jnp.argmax(sel[0, 0])) == 0
    assert int(jnp.argmax(sel[0, 1])) == 1
    assert int(jnp.argmax(sel[0, 2])) == 1
    np.testing.assert_allclose(np.asarray(sel).sum(-1), 1.0)


def test_effective_replicas_bounds():
    d = jnp.array([[1.0, 0.0, 0.0, 0.0], [0.25, 0.25, 0.25, 0.25]])
    er = np.asarray(effective_replicas(d))
    assert er[0] == pytest.approx(1.0, rel=1e-5)
    assert er[1] == pytest.approx(4.0, rel=1e-5)


def test_sync_cost_ignores_unmaterialized_shards():
    """Softmin residue below REPLICA_THRESHOLD holds no copy and syncs
    nothing — same materialization rule as replica_read_assignment."""
    from repro.placement import sync_cost

    wan = wan_topology(jnp.ones(4), jnp.ones(4))
    wpue = jnp.full((4,), 20.0)
    sizes = jnp.array([100.0])
    residue = jnp.array([[0.985, 0.005, 0.005, 0.005]])
    assert float(sync_cost(residue, sizes, wan, wpue)) == pytest.approx(0.0)
    spread = jnp.array([[0.5, 0.5, 0.0, 0.0]])
    assert float(sync_cost(spread, sizes, wan, wpue)) > 0.0


# ---------------------------------------------------------------------------
# Latency-aware replica reads: the io_coupling service model
# ---------------------------------------------------------------------------

def _drifting_setup(cfg):
    w = 48
    n_epochs = cfg.t_slots // w
    ing = ingest_drift_trace(
        jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites,
        bias=jnp.array([0.05, 0.8, 0.05, 0.10]), bias_strength=0.5,
    )
    sizes = dataset_growth_trace(n_epochs, cfg.k_types, 100.0, 0.05)
    return w, ing, sizes


def test_io_coupling_off_is_bit_exact(paper_setup):
    """io_coupling=False leaves the controller untouched (mu_scale all 1)."""
    cfg, template, _, up, down = paper_setup
    key = jax.random.key(11)
    pol = dispatch_fn(1.0)
    pcfg = PlacementConfig(
        epoch_slots=cfg.t_slots,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    outs_p = simulate_placed(
        template, up, down, pol, static_placement_rule, key, pcfg
    )
    outs_s = simulate(template, pol, key)
    np.testing.assert_array_equal(np.asarray(outs_p.cost), np.asarray(outs_s.cost))
    np.testing.assert_array_equal(np.asarray(outs_p.mu_scale),
                                  np.ones_like(np.asarray(outs_p.mu_scale)))


def test_io_coupling_adaptive_buys_throughput(paper_setup):
    """Regression for the latency-aware-reads ROADMAP item: with the
    evolving placement threaded into mu, adaptive re-placement yields at
    least the fleet-effective service rate of static placement on a
    drifting trace (capacity-share weighted), and no worse backlog."""
    from repro.traces.datasets import DEFAULT_CAPACITY_SHARES

    cfg, template, _, up, down = paper_setup
    w, ing, sizes = _drifting_setup(cfg)
    key = jax.random.key(11)
    pol = dispatch_fn(1.0)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25, io_coupling=True,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    res = {}
    for name, rule in [("static", static_placement_rule),
                       ("adaptive", make_adaptive_rule(up))]:
        outs = simulate_placed(
            template, up, down, pol, rule, key, pcfg,
            ingest=ing, sizes_gb=sizes,
        )
        shares = np.asarray(DEFAULT_CAPACITY_SHARES)
        scale = np.asarray(outs.mu_scale)                          # (E, N)
        res[name] = {
            "eff_mu": float((scale * shares[None, :]).sum(1).mean()
                            / shares.sum()),
            "backlog": float(jnp.mean(outs.backlog_avg)),
        }
    assert res["adaptive"]["eff_mu"] >= res["static"]["eff_mu"], res
    assert res["adaptive"]["backlog"] <= res["static"]["backlog"] * 1.01, res


def test_io_coupling_scale_matches_layout(paper_setup):
    """mu_scale is exactly the slowdown ratio of the epoch layout in force."""
    from repro.traces.datasets import io_slowdown_from_bandwidth

    cfg, template, _, up, down = paper_setup
    w, ing, sizes = _drifting_setup(cfg)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25, io_coupling=True,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    outs = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        jax.random.key(3), pcfg, ingest=ing, sizes_gb=sizes,
    )
    slow0 = io_slowdown_from_bandwidth(up, down, template.data_dist)
    for e in range(outs.placements.shape[0]):
        expect = io_slowdown_from_bandwidth(
            up, down, outs.placements[e]
        ) / slow0
        np.testing.assert_allclose(
            np.asarray(outs.mu_scale[e]), np.asarray(expect), rtol=1e-5
        )
    # Epoch 0 runs the given layout: scale is exactly 1.
    np.testing.assert_array_equal(np.asarray(outs.mu_scale[0]),
                                  np.ones(cfg.n_sites, np.float32))


# ---------------------------------------------------------------------------
# Sync-aware hosting rule (replication premium folded into the objective)
# ---------------------------------------------------------------------------

def test_sync_weight_zero_preserves_rule(paper_setup):
    """sync_weight=0 is the original rule, decision for decision."""
    cfg, template, _, up, down = paper_setup
    w, ing, sizes = _drifting_setup(cfg)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    key = jax.random.key(15)
    o1 = simulate_placed(
        template, up, down, dispatch_fn(1.0), make_adaptive_rule(up),
        key, pcfg, ingest=ing, sizes_gb=sizes,
    )
    o2 = simulate_placed(
        template, up, down, dispatch_fn(1.0),
        make_adaptive_rule(up, sync_weight=0.0), key, pcfg,
        ingest=ing, sizes_gb=sizes,
    )
    np.testing.assert_array_equal(np.asarray(o1.placements),
                                  np.asarray(o2.placements))


def test_sync_aware_rule_trades_spread_for_sync(paper_setup):
    """The sync_weight dial responds (ROADMAP multi-replica item): a small
    weight keeps warm, replica-rich placements for read locality; a large
    weight consolidates, pays less sync, and stays no worse on total
    cost. The degenerate ladder (vertex always winning regardless of
    weight) would fail the low-weight assertions."""
    cfg, template, _, up, down = paper_setup
    w, ing, sizes = _drifting_setup(cfg)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    key = jax.random.key(15)
    res = {}
    for sw in (0.0, 0.2, 5.0):
        outs = simulate_placed(
            template, up, down, dispatch_fn(1.0),
            make_adaptive_rule(up, sync_weight=sw), key, pcfg,
            ingest=ing, sizes_gb=sizes,
        )
        s = summarize_placed(outs)
        res[sw] = {
            "eff_replicas": float(jnp.mean(effective_replicas(
                outs.placements.reshape(-1, cfg.n_sites)
            ))),
            "sync": s["time_avg_sync_cost"],
            "total": s["time_avg_total_cost"],
        }
    # Large weight consolidates below the plain rule and pays less sync...
    assert res[5.0]["eff_replicas"] < res[0.0]["eff_replicas"], res
    assert res[5.0]["sync"] <= res[0.0]["sync"], res
    assert res[5.0]["total"] <= res[0.0]["total"] * 1.02, res
    # ...while a small weight keeps MORE replicas than the large one (the
    # read-locality benefit wins when sync is cheap) — the dial moves.
    assert res[0.2]["eff_replicas"] > res[5.0]["eff_replicas"], res
    assert res[0.2]["sync"] > res[5.0]["sync"], res


def test_replication_premium_thresholds_like_sync_cost():
    from repro.placement import replication_premium

    residue = jnp.array([[0.985, 0.005, 0.005, 0.005]])
    assert float(replication_premium(residue, 0.01)[0]) == pytest.approx(0.0)
    spread = jnp.array([[0.5, 0.5, 0.0, 0.0]])
    assert float(replication_premium(spread, 0.01)[0]) == pytest.approx(
        0.01, rel=1e-5
    )
