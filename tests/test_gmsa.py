"""GMSA correctness: the analytic vertex solution == scipy LP optimum."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.optimize import linprog

from repro.core.energy import manager_energy_cost
from repro.core.gmsa import (
    drift_plus_penalty_scores,
    gmsa_dispatch,
    lp_objective,
)


def _random_instance(seed, n=4, k=3):
    rng = np.random.default_rng(seed)
    q = rng.uniform(0, 200, (n, k)).astype(np.float32)
    arrivals = rng.uniform(0, 60, (k,)).astype(np.float32)
    mu = rng.uniform(0, 40, (n, k)).astype(np.float32)
    omega = rng.uniform(8, 30, (n,)).astype(np.float32)
    pue = rng.uniform(1.03, 1.15, (n,)).astype(np.float32)
    r = rng.dirichlet(np.ones(n), (k, n)).astype(np.float32)
    p = rng.uniform(0.5, 2.0, (k,)).astype(np.float32)
    e = manager_energy_cost(jnp.asarray(omega), jnp.asarray(pue),
                            jnp.asarray(r), jnp.asarray(p))
    return map(jnp.asarray, (q, arrivals, mu)), e


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("v", [0.0, 0.5, 5.0, 100.0])
def test_vertex_solution_matches_scipy_lp(seed, v):
    """Paper Sec. IV-B LP: min_f Σ f_i^k A^k (Q-μ) + V·Cost s.t. simplex/k.

    The drift-plus-penalty objective is linear in f with independent simplex
    constraints per job type, so scipy's LP optimum and GMSA's argmin vertex
    must agree in objective value (the argmax vertex itself may differ only
    under exact ties).
    """
    (q, arrivals, mu), e = _random_instance(seed)
    n, k = q.shape
    f_gmsa = gmsa_dispatch(q, arrivals, mu, e, v)
    obj_gmsa = float(lp_objective(f_gmsa, q, arrivals, mu, e, v))

    # scipy: decision variables f[i,k] flattened per type (independent LPs,
    # solved jointly as one block-diagonal LP).
    scores = np.asarray(drift_plus_penalty_scores(q, arrivals, mu, e, v))  # (K,N)
    const = -float(jnp.sum(q * mu))
    c = scores.T.flatten()            # [i,k] order: f[:, k] blocks? build per k
    obj_scipy = const
    for kk in range(k):
        res = linprog(
            c=scores[kk],             # coefficients over managers i
            A_eq=np.ones((1, n)), b_eq=[1.0], bounds=[(0, 1)] * n,
            method="highs",
        )
        assert res.success
        obj_scipy += res.fun
    np.testing.assert_allclose(obj_gmsa, obj_scipy, rtol=1e-5, atol=1e-3)


def test_dispatch_is_one_hot_simplex():
    (q, arrivals, mu), e = _random_instance(123)
    f = gmsa_dispatch(q, arrivals, mu, e, 1.0)
    np.testing.assert_allclose(f.sum(axis=0), 1.0, rtol=1e-6)
    assert np.all((np.asarray(f) == 0) | (np.asarray(f) == 1))


def test_v_zero_is_pure_drift_jsq_like():
    """V=0 ignores cost: argmin over A(Q-mu) == drift-greedy choice."""
    (q, arrivals, mu), e = _random_instance(7)
    f0 = gmsa_dispatch(q, arrivals, mu, e, 0.0)
    expect = jnp.argmin(q - mu, axis=0)
    got = jnp.argmax(f0, axis=0)
    np.testing.assert_array_equal(got, expect)


def test_v_large_is_greedy_cost():
    (q, arrivals, mu), e = _random_instance(9)
    f_inf = gmsa_dispatch(q, arrivals, mu, e, 1e9)
    expect = jnp.argmin(e, axis=1)
    got = jnp.argmax(f_inf, axis=0)
    np.testing.assert_array_equal(got, expect)
