"""Sharded Monte-Carlo throughput: the runs mesh vs the single-device vmap.

The paper's methodology is 1000 Monte-Carlo runs per configuration; the
tentpole question is whether sharding the ``runs`` axis over a host-device
mesh (:mod:`repro.distributed.mesh`) buys wall-clock at that scale without
costing determinism. Two arms, same entry point, same key stream:

* ``dev1``  — ``simulate_many`` exactly as every figure script calls it
  (one ``vmap`` over the (n_runs,) key axis);
* ``devN``  — the same call with ``mesh=runs_mesh(N)``.

The devN arm must be **bitwise identical** to dev1 (asserted every run —
the determinism contract of ``sharded_runs``), and its per-run time is
reported with the speedup in the derived payload so BENCH_sim.json carries
the trajectory per (backend, device count).

Honesty note: forcing 8 host devices on a box with fewer physical cores
time-slices one core and proves nothing about throughput — the >= 3x
speedup gate therefore only arms when the machine really has >= 8 CPUs
(the 8-device CI job and real workstations). Elsewhere the numbers are
still recorded, labeled with ``cpus=`` so the trajectory can't be misread.

Run standalone (the flag must precede jax backend init, which this module
defers until after ``ensure_host_devices``):

    PYTHONPATH=src python -m benchmarks.shard_bench --devices 8 --runs 1000

Under ``benchmarks.run`` jax is usually already initialized by earlier
sections; the bench then degrades to however many devices exist.
"""

from __future__ import annotations

import argparse
import os

from benchmarks.common import N_RUNS, emit, timed_compile_sweep

#: Paper-methodology run count for the throughput claim.
SHARD_RUNS = 1000

#: Short horizon: the throughput ratio is about the runs axis, not T.
SHARD_SLOTS = 48


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--devices", type=int, default=8,
        help="host devices to request for the mesh arm (default 8)",
    )
    parser.add_argument(
        "--runs", type=int, default=min(SHARD_RUNS, N_RUNS),
        help="Monte-Carlo runs per arm (default min(1000, REPRO_BENCH_RUNS))",
    )
    args, _ = parser.parse_known_args(argv)

    # Must happen before anything touches a jax device: when this module
    # is the process entry the flag lands in time; under benchmarks.run
    # the backends are already up and we use whatever devices exist.
    from repro.distributed.mesh import ensure_host_devices, runs_mesh

    try:
        ensure_host_devices(args.devices)
    except RuntimeError:
        pass

    import jax

    from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
    from repro.core.gmsa import gmsa_policy
    from repro.core.simulator import simulate_many

    n_dev = min(args.devices, jax.device_count())
    n_runs = args.runs
    cpus = os.cpu_count() or 1

    cfg = PaperSimConfig(t_slots=SHARD_SLOTS)
    _, build = make_sim_builder(cfg)
    key = jax.random.key(0)

    ref, us1, c1 = timed_compile_sweep(
        lambda: simulate_many(build, gmsa_policy, key, n_runs), n_runs
    )
    emit(
        f"shard_simulate_many_{n_runs}runs_dev1", us1,
        f"devices=1;cpus={cpus};compile_us={c1:.0f}",
    )

    mesh = runs_mesh(n_dev)
    outs, usn, cn = timed_compile_sweep(
        lambda: simulate_many(build, gmsa_policy, key, n_runs, mesh=mesh),
        n_runs,
    )
    bitwise = all(
        bool(jax.numpy.all(x == y))
        for x, y in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(outs))
    )
    speedup = us1 / max(usn, 1e-9)
    emit(
        f"shard_simulate_many_{n_runs}runs_dev{n_dev}", usn,
        f"devices={n_dev};cpus={cpus};speedup_vs_dev1={speedup:.2f}x;"
        f"bitwise={bitwise};compile_us={cn:.0f}",
    )

    assert bitwise, (
        "sharded Monte-Carlo must be bitwise identical to the "
        "single-device vmap (determinism contract of sharded_runs)"
    )
    if n_dev >= 8 and cpus >= 8:
        assert speedup >= 3.0, (
            f"8-device runs mesh on {cpus} CPUs must deliver >= 3x per-run "
            f"throughput at n_runs={n_runs} (got {speedup:.2f}x)"
        )


if __name__ == "__main__":
    main()
    from benchmarks.common import write_bench_json
    write_bench_json(label="shard_bench")
