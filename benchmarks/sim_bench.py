"""GMSA simulator throughput — the wall-clock §Perf hillclimb target.

Reports µs per simulated run (288 slots) under the paper's configuration for
(a) the paper-faithful jitted lax.scan engine vmapped over Monte-Carlo runs
(b) a naive per-slot Python loop (the "paper-faithful unoptimized" baseline)
so the optimization path is measurable on this CPU (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.energy import manager_energy_cost
from repro.core.gmsa import dispatch_fn, gmsa_dispatch
from repro.core.queues import queue_step
from repro.core.simulator import simulate, simulate_many


def python_loop_reference(inputs, v: float) -> tuple[float, float]:
    """Paper-faithful unvectorized engine: per-slot Python, per-DC numpy."""
    t_slots, k_types = inputs.arrivals.shape
    n = inputs.mu.shape[1]
    q = np.zeros((n, k_types), np.float32)
    arr = np.asarray(inputs.arrivals)
    mu = np.asarray(inputs.mu)
    omega = np.asarray(inputs.omega)
    pue = np.asarray(inputs.pue)
    r = np.asarray(inputs.r)
    p = np.asarray(inputs.p_it)
    total_cost = 0.0
    for t in range(t_slots):
        wpue = omega[t] * pue[t]
        e = (r @ wpue) * p[:, None]                      # (K, N)
        score = arr[t][:, None] * ((q - mu[t]).T + v * e)
        best = score.argmin(axis=1)
        f = np.zeros((n, k_types), np.float32)
        f[best, np.arange(k_types)] = 1.0
        total_cost += float((f * arr[t][None, :]).T.flatten() @ e.flatten())
        q = np.maximum(q + f * arr[t][None, :] - mu[t], 0.0)
    return total_cost / t_slots, float(q.sum())


def main():
    cfg = PaperSimConfig()
    template, build = make_sim_builder(cfg)

    # (a) naive python loop (1 run)
    t0 = time.perf_counter()
    cost_py, _ = python_loop_reference(template, 1.0)
    us_py = (time.perf_counter() - t0) * 1e6
    emit("sim_python_loop_1run", us_py, f"avg_cost={cost_py:.1f}")

    # (b) jitted scan, single run
    pol = dispatch_fn(1.0)
    key = jax.random.key(0)
    outs = simulate(template, pol, key)          # compile
    jax.block_until_ready(outs.cost)
    t0 = time.perf_counter()
    for _ in range(10):
        outs = simulate(template, pol, key)
        jax.block_until_ready(outs.cost)
    us_scan = (time.perf_counter() - t0) * 1e6 / 10
    emit("sim_jit_scan_1run", us_scan, f"speedup_vs_python={us_py/us_scan:.1f}x")

    # (c) vmapped Monte-Carlo engine (the production path), per-run cost
    for n_runs in (100, 1000):
        outs = simulate_many(build, pol, key, n_runs)   # compile
        jax.block_until_ready(outs.cost)
        t0 = time.perf_counter()
        outs = simulate_many(build, pol, key, n_runs)
        jax.block_until_ready(outs.cost)
        us = (time.perf_counter() - t0) * 1e6 / n_runs
        emit(f"sim_vmap_{n_runs}runs_per_run", us,
             f"runs_per_sec={1e6/us:.0f}")


if __name__ == "__main__":
    main()
    from benchmarks.common import write_bench_json
    write_bench_json(label="sim_bench")
