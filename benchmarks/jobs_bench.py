"""Stage-aware vs. stage-oblivious dispatch on the multi-stage scenario.

The scenario the base algorithm cannot see: the K = 3 shuffle-heavy
analytics mix of :mod:`repro.configs.facebook_4dc_stages`, where every
job is a 2–3 stage chain and 30–60 GB of intermediate data per job must
cross the WAN between consecutive stages' sites.

Both arms run the same staged engine and pay the same bills (per-stage
compute at the executing site's price*PUE, shuffle bytes through the WAN
model), and both keep the map stage data-local (the GDA premise):

* **oblivious** — the current dispatch: base GMSA picks one manager per
  type per slot from the *aggregate* backlog and the plain cost table,
  and every post-map stage follows it; the shuffle bytes land wherever
  that choice implies, unpriced at decision time.
* **aware** — :func:`repro.jobs.scheduler.make_staged_policy`: each
  stage's site chosen by the drift-plus-penalty score extended with the
  stage's WAN pull term (and per-stage queues in the drift).

Reports, per arm: time-averaged total cost (stage compute + shuffle WAN),
the WAN bill and intermediate GB, backlog, jobs completed, and wall-clock
per Monte-Carlo run for the jit-compiled engine (compilation isolated).

``--quick`` runs a 4-run smoke version (the tier-1 CI step).
``--telemetry PATH`` additionally runs the aware arm once at TRACE level
and writes the flight record to PATH as JSONL (rendered/verified by
``python -m repro.telemetry.report PATH --check`` — the CI round-trip).
``--trace-dir DIR`` profiles the timed sweeps with ``jax.profiler``.
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import N_RUNS, emit, timed_compile_sweep
from repro.configs.facebook_4dc_stages import (
    StagedPaperConfig,
    make_staged_builder,
)
from repro.core.gmsa import gmsa_policy
from repro.jobs import (
    make_staged_policy,
    simulate_staged,
    simulate_staged_many,
    stage_oblivious,
    summarize_staged,
)


def _timed_sweep(build, dag, wan, pol, key, n_runs, v, trace_dir=None,
                 mesh=None):
    return timed_compile_sweep(
        lambda: simulate_staged_many(build, dag, wan, pol, key, n_runs,
                                     scalar=v, mesh=mesh),
        n_runs,
        trace_dir=trace_dir,
    )


def _write_flight_record(path, template, dag, wan, pol, key, v):
    """One aware-arm run at TRACE level -> JSONL flight record at ``path``."""
    from repro.telemetry import TRACE, TelemetryConfig, collect_records, write_jsonl

    tcfg = TelemetryConfig(level=TRACE)
    outs, frame = simulate_staged(template, dag, wan, pol, key, scalar=v,
                                  telemetry=tcfg)
    records = collect_records(
        outs, frame, cfg=tcfg, summary=summarize_staged(outs),
        meta={"bench": "jobs_bench", "arm": "aware"},
    )
    write_jsonl(records, path)
    print(f"# flight record: {len(records)} records -> {path}", flush=True)


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--quick", action="store_true",
        help="4-run smoke version (CI tier-1 step)",
    )
    parser.add_argument(
        "--telemetry", default=None, metavar="PATH",
        help="write a TRACE-level JSONL flight record of one aware-arm "
             "run to PATH",
    )
    parser.add_argument(
        "--trace-dir", default=None, metavar="DIR",
        help="profile the timed sweeps with jax.profiler.trace(DIR)",
    )
    parser.add_argument(
        "--devices", type=int, default=None, metavar="N",
        help="shard the Monte-Carlo runs axis over an N-device host mesh "
             "(repro.distributed.mesh; bitwise-identical results). Needs "
             "the XLA host-device flag before jax init — this entry point "
             "installs it when run standalone",
    )
    args, _ = parser.parse_known_args(argv)

    mesh = None
    if args.devices:
        # Before any jax device touch (ensure_host_devices raises if the
        # backends already came up short — e.g. under benchmarks.run).
        from repro.distributed.mesh import ensure_host_devices, runs_mesh

        try:
            ensure_host_devices(args.devices)
        except RuntimeError:
            pass
        mesh = runs_mesh(min(args.devices, jax.device_count()))

    cfg = StagedPaperConfig()
    template, dag, wan, build = make_staged_builder(cfg)
    key = jax.random.key(0)
    n_runs = 4 if args.quick else min(N_RUNS, cfg.n_runs)

    results = {}
    for name, pol in [
        ("oblivious", stage_oblivious(gmsa_policy, pin_map=True)),
        ("aware", make_staged_policy(dag, wan)),
    ]:
        outs, us_per_run, compile_us = _timed_sweep(
            build, dag, wan, pol, key, n_runs, cfg.v,
            trace_dir=args.trace_dir, mesh=mesh,
        )
        s = summarize_staged(outs)
        results[name] = s
        dev_tag = f"_dev{mesh.devices.size}" if mesh is not None else ""
        emit(
            f"jobs_{name}_{n_runs}runs_per_run{dev_tag}", us_per_run,
            f"total_cost={s['time_avg_total_cost']:.1f};"
            f"compute_cost={s['time_avg_compute_cost']:.1f};"
            f"wan_cost={s['time_avg_wan_cost']:.1f};"
            f"wan_gb={s['total_wan_gb']:.0f};"
            f"backlog={s['time_avg_backlog']:.3f};"
            f"completed={s['jobs_completed']:.0f};"
            f"compile_us={compile_us:.0f}",
        )

    saving = 1.0 - (results["aware"]["time_avg_total_cost"]
                    / results["oblivious"]["time_avg_total_cost"])
    gb_saved = (results["oblivious"]["total_wan_gb"]
                - results["aware"]["total_wan_gb"])
    emit("jobs_aware_saving", 0.0,
         f"saving_frac={saving:.4f};wan_gb_saved={gb_saved:.0f}")
    assert saving > 0.0, (
        "stage-aware dispatch must beat stage-oblivious total cost on the "
        "multi-stage scenario"
    )
    assert results["aware"]["total_wan_gb"] > 0.0, (
        "the multi-stage scenario must report intermediate WAN GB"
    )

    if args.telemetry:
        _write_flight_record(args.telemetry, template, dag, wan,
                             make_staged_policy(dag, wan), key, cfg.v)


if __name__ == "__main__":
    main()
    from benchmarks.common import write_bench_json
    write_bench_json(label="jobs_bench")
