"""Paper Fig. 6 — sensitivity to the control parameter V (0.001 … 100).

(a) time-average energy cost vs V — GMSA decreases monotonically toward the
    optimum, baselines flat ≈$750; best-case reduction ≈30%;
(b) time-average backlog vs V — grows with V (the O(1/V)/O(V) trade-off);
    our calibration crosses the baselines' 24h averages at V ≈ O(100)
    (paper: ≈10; noted in EXPERIMENTS.md §Calibration).

Since §Perf v6 the whole V-grid runs through
:func:`repro.core.sweep.sweep_grid` — ONE compilation + ONE launch for all
|V| x n_runs simulations (V was already a traced scalar; now the grid axis
is vmapped on top of the Monte-Carlo vmap). The bench still times the old
per-cell launch loop once and reports the compile-time and steady-state
deltas (``fig6_grid_vs_percell``).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import ART, N_RUNS, emit, timed_compile_sweep
from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import data_dispatch, greedy_cost_dispatch, random_dispatch
from repro.core.gmsa import gmsa_policy
from repro.core.simulator import simulate_many
from repro.core.sweep import sweep_grid

#: Paper grid (0.001…100) + one extra decade to exhibit the backlog
#: crossing of Fig. 6(b) under our calibration (EXPERIMENTS.md §Calibration).
V_GRID = (0.001, 0.01, 0.1, 1.0, 10.0, 100.0, 1000.0)


def run(n_runs: int = N_RUNS) -> dict:
    cfg = PaperSimConfig()
    _, build = make_sim_builder(cfg)
    key = jax.random.key(43)

    # One-launch V-grid (sweep axis on top of the Monte-Carlo vmap).
    outs, grid_us_per_run, grid_compile_us = timed_compile_sweep(
        lambda: sweep_grid(build, gmsa_policy, key, n_runs, V_GRID),
        n_runs * len(V_GRID),
    )
    rows = {
        v: {
            "cost": float(outs.cost[i].mean()),
            "backlog": float(outs.backlog_avg[i].mean()),
        }
        for i, v in enumerate(V_GRID)
    }

    # The pre-sweep_grid path (one launch per V, shared compilation via
    # the traced scalar) — measured with the SAME best-of estimator as the
    # grid, for an unbiased migration delta report.
    def percell_pass():
        last = None
        for v in V_GRID:
            last = simulate_many(build, gmsa_policy, key, n_runs, scalar=v)
        return last

    _, percell_us_per_run, percell_compile_us = timed_compile_sweep(
        percell_pass, n_runs * len(V_GRID)
    )

    t1 = time.perf_counter()
    base = {}
    for name, pol in [("DATA", data_dispatch), ("RANDOM", random_dispatch),
                      ("GREEDY", greedy_cost_dispatch)]:
        o = simulate_many(build, pol, key, n_runs)
        base[name] = {
            "cost": float(o.cost.mean()),
            "backlog": float(o.backlog_avg.mean()),
        }
    baselines_us = (time.perf_counter() - t1) * 1e6
    # The figure's own cost (grid compile + one steady grid + baselines) —
    # excludes the delta-report harness above, keeping this number
    # comparable across BENCH_sim.json entries.
    total_us = (grid_compile_us + n_runs * len(V_GRID) * grid_us_per_run
                + baselines_us)

    costs = [rows[v]["cost"] for v in V_GRID]
    backlogs = [rows[v]["backlog"] for v in V_GRID]
    baseline_cost = 0.5 * (base["DATA"]["cost"] + base["RANDOM"]["cost"])
    baseline_backlog = min(base["DATA"]["backlog"], base["RANDOM"]["backlog"])
    # paper reports its headline reduction at the top of its grid (V=100)
    reduction = 1.0 - rows[100.0]["cost"] / baseline_cost
    crossing_v = next(
        (v for v in V_GRID if rows[v]["backlog"] > baseline_backlog), None
    )

    out = {
        "n_runs": n_runs,
        "v_grid": list(V_GRID),
        "gmsa": rows,
        "baselines": base,
        "sweep_grid": {
            "grid_us_per_run": grid_us_per_run,
            "grid_compile_us": grid_compile_us,
            "percell_us_per_run": percell_us_per_run,
            "percell_compile_us": percell_compile_us,
        },
        "checks": {
            "cost_monotone_nonincreasing": bool(
                all(costs[i + 1] <= costs[i] * 1.01 for i in range(len(costs) - 1))
            ),
            "backlog_monotone_nondecreasing": bool(
                all(backlogs[i + 1] >= backlogs[i] * 0.99 for i in range(len(backlogs) - 1))
            ),
            "baseline_cost": baseline_cost,
            "best_gmsa_cost": min(costs),
            "reduction_at_v100": reduction,
            "greedy_floor_cost": base["GREEDY"]["cost"],
            "backlog_crossing_v": crossing_v,
        },
        "total_us": total_us,
    }
    (ART / "fig6.json").write_text(json.dumps(out, indent=1))
    return out


def main():
    out = run()
    c = out["checks"]
    s = out["sweep_grid"]
    emit("fig6a_cost_vs_V", out["total_us"] / (len(V_GRID) + 3),
         f"baseline={c['baseline_cost']:.0f};best={c['best_gmsa_cost']:.0f};"
         f"reduction={100*c['reduction_at_v100']:.1f}%")
    emit("fig6b_backlog_vs_V", out["total_us"] / (len(V_GRID) + 3),
         f"monotone_cost={c['cost_monotone_nonincreasing']};"
         f"monotone_backlog={c['backlog_monotone_nondecreasing']};"
         f"crosses_baselines_at_V={c['backlog_crossing_v']}")
    emit("fig6_grid_vs_percell", s["grid_us_per_run"],
         f"percell_us_per_run={s['percell_us_per_run']:.1f};"
         f"steady_speedup={s['percell_us_per_run']/max(s['grid_us_per_run'],1e-9):.2f}x;"
         f"grid_compile_us={s['grid_compile_us']:.0f};"
         f"percell_compile_us={s['percell_compile_us']:.0f}")
    assert c["cost_monotone_nonincreasing"], "Fig6a: cost must fall with V"
    assert c["backlog_monotone_nondecreasing"], "Fig6b: backlog must rise with V"
    assert 0.2 <= c["reduction_at_v100"] <= 0.45, (
        f"paper claims ~30% reduction; got {100*c['reduction_at_v100']:.1f}%"
    )


if __name__ == "__main__":
    main()
    from benchmarks.common import write_bench_json
    write_bench_json(label="fig6")
