"""Paper Fig. 6 — sensitivity to the control parameter V (0.001 … 100).

(a) time-average energy cost vs V — GMSA decreases monotonically toward the
    optimum, baselines flat ≈$750; best-case reduction ≈30%;
(b) time-average backlog vs V — grows with V (the O(1/V)/O(V) trade-off);
    our calibration crosses the baselines' 24h averages at V ≈ O(100)
    (paper: ≈10; noted in EXPERIMENTS.md §Calibration).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import ART, N_RUNS, emit
from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import data_dispatch, greedy_cost_dispatch, random_dispatch
from repro.core.gmsa import gmsa_policy
from repro.core.simulator import simulate_many

#: Paper grid (0.001…100) + one extra decade to exhibit the backlog
#: crossing of Fig. 6(b) under our calibration (EXPERIMENTS.md §Calibration).
V_GRID = (0.001, 0.01, 0.1, 1.0, 10.0, 100.0, 1000.0)


def run(n_runs: int = N_RUNS) -> dict:
    cfg = PaperSimConfig()
    _, build = make_sim_builder(cfg)
    key = jax.random.key(43)

    t0 = time.perf_counter()
    rows = {}
    for v in V_GRID:
        # V is a *traced* scalar (repro.core.gmsa.gmsa_policy): the whole
        # sweep shares one compiled simulation (§Perf wall-clock track).
        outs = simulate_many(build, gmsa_policy, key, n_runs, scalar=v)
        rows[v] = {
            "cost": float(outs.cost.mean()),
            "backlog": float(outs.backlog_avg.mean()),
        }
    base = {}
    for name, pol in [("DATA", data_dispatch), ("RANDOM", random_dispatch),
                      ("GREEDY", greedy_cost_dispatch)]:
        outs = simulate_many(build, pol, key, n_runs)
        base[name] = {
            "cost": float(outs.cost.mean()),
            "backlog": float(outs.backlog_avg.mean()),
        }
    total_us = (time.perf_counter() - t0) * 1e6

    costs = [rows[v]["cost"] for v in V_GRID]
    backlogs = [rows[v]["backlog"] for v in V_GRID]
    baseline_cost = 0.5 * (base["DATA"]["cost"] + base["RANDOM"]["cost"])
    baseline_backlog = min(base["DATA"]["backlog"], base["RANDOM"]["backlog"])
    # paper reports its headline reduction at the top of its grid (V=100)
    reduction = 1.0 - rows[100.0]["cost"] / baseline_cost
    crossing_v = next(
        (v for v in V_GRID if rows[v]["backlog"] > baseline_backlog), None
    )

    out = {
        "n_runs": n_runs,
        "v_grid": list(V_GRID),
        "gmsa": rows,
        "baselines": base,
        "checks": {
            "cost_monotone_nonincreasing": bool(
                all(costs[i + 1] <= costs[i] * 1.01 for i in range(len(costs) - 1))
            ),
            "backlog_monotone_nondecreasing": bool(
                all(backlogs[i + 1] >= backlogs[i] * 0.99 for i in range(len(backlogs) - 1))
            ),
            "baseline_cost": baseline_cost,
            "best_gmsa_cost": min(costs),
            "reduction_at_v100": reduction,
            "greedy_floor_cost": base["GREEDY"]["cost"],
            "backlog_crossing_v": crossing_v,
        },
        "total_us": total_us,
    }
    (ART / "fig6.json").write_text(json.dumps(out, indent=1))
    return out


def main():
    out = run()
    c = out["checks"]
    emit("fig6a_cost_vs_V", out["total_us"] / (len(V_GRID) + 3),
         f"baseline={c['baseline_cost']:.0f};best={c['best_gmsa_cost']:.0f};"
         f"reduction={100*c['reduction_at_v100']:.1f}%")
    emit("fig6b_backlog_vs_V", out["total_us"] / (len(V_GRID) + 3),
         f"monotone_cost={c['cost_monotone_nonincreasing']};"
         f"monotone_backlog={c['backlog_monotone_nondecreasing']};"
         f"crosses_baselines_at_V={c['backlog_crossing_v']}")
    assert c["cost_monotone_nonincreasing"], "Fig6a: cost must fall with V"
    assert c["backlog_monotone_nondecreasing"], "Fig6b: backlog must rise with V"
    assert 0.2 <= c["reduction_at_v100"] <= 0.45, (
        f"paper claims ~30% reduction; got {100*c['reduction_at_v100']:.1f}%"
    )


if __name__ == "__main__":
    main()
