"""Aggregate the dry-run artifacts into the §Roofline table.

Reads benchmarks/artifacts/dryrun/*__<variant>.json, emits
  * benchmarks/artifacts/roofline_<variant>.csv
  * benchmarks/artifacts/roofline_<variant>.md   (the EXPERIMENTS.md table)
and prints one summary line per (arch × shape × mesh).
"""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import ART, emit

DRY = ART / "dryrun"


def load(variant: str = "baseline") -> list[dict]:
    rows = []
    for p in sorted(DRY.glob(f"*__{variant}.json")):
        rec = json.loads(p.read_text())
        rows.append(rec)
    return rows


def fmt_row(r: dict) -> dict:
    if "skipped" in r:
        return {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": f"skipped: {r['skipped']}",
        }
    rl = r["roofline"]
    hbm_gb = (r["memory"]["argument_bytes"] or 0) / 1e9
    frac = rl["roofline_fraction"] or 0.0
    useful = rl["useful_flops_ratio"] or 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
        "collective_s": rl["collective_s"], "dominant": rl["dominant"],
        "roofline_fraction": frac,
        "useful_flops_ratio": useful,
        # MFU proxy: useful model FLOPs / (chips × peak × step_time).
        # Separates "runs at peak on redundant work" (replicated attention)
        # from genuine utilization.
        "mfu_proxy": frac * useful,
        "args_gb_per_dev": hbm_gb,
        "peak_gb_per_dev": (r["memory"]["peak_bytes"] or 0) / 1e9,
        "status": "ok",
    }


def main(variant: str = "baseline"):
    rows = [fmt_row(r) for r in load(variant)]
    ok = [r for r in rows if r["status"] == "ok"]
    if not ok:
        raise FileNotFoundError(
            f"no usable dry-run records under {DRY}/*__{variant}.json — "
            "run the dry-run sweep first; refusing to write empty tables"
        )

    csv_path = ART / f"roofline_{variant}.csv"
    md_path = ART / f"roofline_{variant}.md"
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "dominant", "roofline_fraction", "useful_flops_ratio", "mfu_proxy",
            "args_gb_per_dev", "peak_gb_per_dev"]
    with open(csv_path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in ok:
            f.write(",".join(
                f"{r[c]:.4e}" if isinstance(r[c], float) else str(r[c]) for c in cols
            ) + "\n")
    with open(md_path, "w") as f:
        f.write("| " + " | ".join(cols) + " |\n")
        f.write("|" + "---|" * len(cols) + "\n")
        for r in rows:
            if r["status"] != "ok":
                f.write(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        + " | ".join(["—"] * (len(cols) - 4))
                        + f" | {r['status']} |\n")
                continue
            f.write("| " + " | ".join(
                f"{r[c]:.3e}" if isinstance(r[c], float) else str(r[c]) for c in cols
            ) + " |\n")

    worst = min(ok, key=lambda r: r["roofline_fraction"] or 1.0)
    most_coll = max(ok, key=lambda r: r["collective_s"] / max(r["compute_s"], 1e-12))
    emit(f"roofline_table_{variant}", 0.0,
         f"cells={len(ok)};worst_fraction={worst['arch']}×{worst['shape']}"
         f"={worst['roofline_fraction']:.3f};"
         f"most_collective={most_coll['arch']}×{most_coll['shape']}")
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "baseline")
