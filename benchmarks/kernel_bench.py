"""Kernel-layer microbenchmarks + the fleet-scale end-to-end run.

Wall-clock on this container measures the pure-JAX (XLA:CPU) paths — the
TPU Pallas kernels are the *target* (validated in interpret mode, timed
meaningfully only on hardware). Reported here:

  * gmsa dispatch (jnp path) at fleet scales (N pods × K classes) — the
    per-slot control-plane latency budget;
  * the N = 256 ``configs.fleet_256`` scenario END-TO-END: a full GMSA
    simulation through ``gmsa_dispatch(..., impl="kernel")`` (interpret
    mode off-TPU — a correctness/viability gate, not a speed number on
    CPU) against the same run on the hoisted-einsum reference engine,
    with dispatch-agreement and cost-parity checks;
  * ssd chunked scan (jnp path) at mamba2-2.7b layer geometry;
  * per-shape interpret-mode *correctness* spot checks for both kernels
    (already swept in tests; repeated here so the bench run self-validates).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.configs.fleet_256 import (
    FleetConfig,
    make_fleet_builder,
    make_score_operands,
)
from repro.core.gmsa import gmsa_policy, make_kernel_policy
from repro.core.simulator import simulate
from repro.kernels import pallas_backend, supports_compiled_pallas
from repro.kernels.gmsa_score.ref import gmsa_score_ref
from repro.kernels.gmsa_score.ops import gmsa_score
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.ssm import ssd_chunked

#: End-to-end fleet horizon: long enough that queues develop (the argmin
#: is exercised against live backlogs), short enough that the Python-free
#: interpret-mode kernel path compiles and runs in CI time.
FLEET_E2E_SLOTS = 48


def bench_gmsa_dispatch():
    for (k, n) in [(1, 4), (16, 64), (128, 1024)]:
        key = jax.random.key(0)
        ks = jax.random.split(key, 6)
        q = jax.random.uniform(ks[0], (k, n)) * 100
        mu = jax.random.uniform(ks[1], (k, n)) * 50
        a = jax.random.uniform(ks[2], (k,)) * 40
        vp = jax.random.uniform(ks[3], (k,)) * 10
        # normalized uniforms, not dirichlet: gamma rejection sampling for
        # (128, 1024, 1024) takes minutes on one CPU core
        raw = jax.random.uniform(ks[4], (k, n, n)) + 1e-3
        r = raw / raw.sum(-1, keepdims=True)
        wpue = jax.random.uniform(ks[5], (n,)) * 20
        fn = jax.jit(gmsa_score_ref)
        (_, best), us = timed(fn, q, mu, a, vp, r, wpue)
        emit(f"gmsa_dispatch_jnp_K{k}_N{n}", us,
             f"r_tensor_mb={r.size*4/1e6:.1f}")
        # interpret-mode kernel spot check (small scales only: interpret
        # executes each grid cell in Python — fleet scale is covered by the
        # tiled test sweep in tests/test_kernels.py)
        if k * n <= 16 * 64:
            s_ref, b_ref = gmsa_score_ref(q, mu, a, vp, r, wpue)
            _, b_k = gmsa_score(q, mu, a, vp, r, wpue, interpret=True)
            assert np.array_equal(np.asarray(b_k), np.asarray(b_ref))


def bench_gmsa_matrix():
    """Compiled-vs-interpret-vs-hoisted-einsum dispatch matrix at N = 256.

    One realistic fleet-scale slot (developed backlog, scenario prices and
    ratios — :func:`repro.configs.fleet_256.make_score_operands`), three
    arms of the SAME argmin decision, each row stamped with the backend:

    * ``einsum``    — the simulator's hoisted path: the (K, N) per-job cost
      table is precomputed once per epoch, so the per-slot work is just the
      drift score + argmin (this is what ``simulate`` amortizes to);
    * ``interpret`` — the Pallas kernel under the interpreter, from the raw
      (K, N, N) ratio tensor (a correctness/viability row off-TPU, not a
      speed number: the interpreter executes grid cells in Python);
    * ``compiled``  — the same kernel lowered for real, only where the
      backend supports it (:func:`repro.kernels.supports_compiled_pallas`
      — TPU; recorded as skipped elsewhere so the per-backend trajectory
      in BENCH_sim.json stays honest).

    All arms must agree on the argmin before any timing is reported.
    """
    backend = pallas_backend()
    cfg = FleetConfig(t_slots=FLEET_E2E_SLOTS)
    q, mu, a, vp, r, wpue, e = make_score_operands(cfg)
    n, k = q.shape[1], q.shape[0]

    _, best_oracle = gmsa_score_ref(q, mu, a, vp, r, wpue)

    # Arm 1: hoisted einsum — the table V·P^k·(r·wpue) is precomputed once
    # per epoch (exactly what ``simulate`` closes over; ``energy_row``
    # already folds P^k, scale by V), so the per-slot work is score+argmin.
    e_hoist = jnp.asarray(cfg.v, jnp.float32) * e            # (K, N)
    ein = jax.jit(
        lambda qk, muk: jnp.argmin(a[:, None] * (qk - muk + e_hoist), axis=1)
    )
    best_ein, us_ein = timed(ein, q, mu)
    assert np.array_equal(np.asarray(best_ein), np.asarray(best_oracle))
    emit(f"gmsa_matrix_einsum_N{n}_K{k}", us_ein,
         f"backend={backend};arm=einsum;agree=1.0")

    # Arm 2: interpret-mode Pallas kernel (raw operands, fused pass).
    _, us_int = timed(
        lambda: gmsa_score(q, mu, a, vp, r, wpue, interpret=True),
        warmup=1, iters=1,
    )
    _, best_int = gmsa_score(q, mu, a, vp, r, wpue, interpret=True)
    assert np.array_equal(np.asarray(best_int), np.asarray(best_oracle))
    emit(f"gmsa_matrix_interpret_N{n}_K{k}", us_int,
         f"backend={backend};arm=interpret;agree=1.0")

    # Arm 3: compiled Pallas kernel — TPU only; skipped rows keep the
    # per-backend trajectory honest instead of mislabeling interpret time.
    if supports_compiled_pallas():
        _, us_c = timed(
            lambda: gmsa_score(q, mu, a, vp, r, wpue, interpret=False)
        )
        _, best_c = gmsa_score(q, mu, a, vp, r, wpue, interpret=False)
        assert np.array_equal(np.asarray(best_c), np.asarray(best_oracle))
        emit(f"gmsa_matrix_compiled_N{n}_K{k}", us_c,
             f"backend={backend};arm=compiled;agree=1.0")
    else:
        emit(f"gmsa_matrix_compiled_N{n}_K{k}", 0.0,
             f"backend={backend};arm=compiled;status=skipped_no_pallas")


def bench_ssd_matrix():
    """The same three-arm matrix for the ssd chunked-scan kernel.

    Interpret-mode Pallas is Python-per-grid-cell, so the matrix runs at a
    reduced (b=1, s=256, h=2) slice of the mamba2-2.7b layer geometry —
    large enough to cross chunk boundaries (s/chunk = 4 grid steps), small
    enough that the interpret row completes in CI time. The jnp reference
    (``ssd_chunked``) is the production CPU path and the baseline column.
    """
    backend = pallas_backend()
    b, s, h, p, n, chunk = 1, 256, 2, 64, 128, 64
    key = jax.random.key(2)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))

    y_ref, _ = ssd_scan_ref(x, dt, a, bm, cm)

    ref = jax.jit(lambda *args: ssd_chunked(*args, chunk))
    _, us_ref = timed(ref, x, dt, a, bm, cm)
    emit(f"ssd_matrix_jnp_S{s}_H{h}", us_ref,
         f"backend={backend};arm=jnp_chunked")

    (y_int, _), us_int = timed(
        lambda: ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True),
        warmup=1, iters=1,
    )
    np.testing.assert_allclose(y_int, y_ref, rtol=3e-4, atol=3e-4)
    emit(f"ssd_matrix_interpret_S{s}_H{h}", us_int,
         f"backend={backend};arm=interpret")

    if supports_compiled_pallas():
        (y_c, _), us_c = timed(
            lambda: ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=False)
        )
        np.testing.assert_allclose(y_c, y_ref, rtol=3e-4, atol=3e-4)
        emit(f"ssd_matrix_compiled_S{s}_H{h}", us_c,
             f"backend={backend};arm=compiled")
    else:
        emit(f"ssd_matrix_compiled_S{s}_H{h}", 0.0,
             f"backend={backend};arm=compiled;status=skipped_no_pallas")


def bench_fleet_e2e():
    """N = 256 fleet GMSA, end-to-end through the kernel dispatch path."""
    cfg = FleetConfig(t_slots=FLEET_E2E_SLOTS)
    template, _ = make_fleet_builder(cfg)
    key = jax.random.key(0)

    # Reference engine: hoisted-einsum cost tables + pure-XLA argmin.
    o_ref, us_ref = timed(
        lambda: simulate(template, gmsa_policy, key, cfg.v)
    )
    emit(
        f"fleet256_e2e_ref_T{cfg.t_slots}", us_ref,
        f"n={cfg.n_sites};k={cfg.k_types};"
        f"us_per_slot={us_ref/cfg.t_slots:.1f};"
        f"avg_cost={float(o_ref.cost.mean()):.0f};"
        f"final_backlog={float(o_ref.backlog_total[-1]):.1f}",
    )

    # Kernel engine: the fused Pallas score+argmin per slot (interpret
    # mode off-TPU — this row gates that the fleet scenario COMPLETES
    # through gmsa_dispatch(impl="kernel"); compiled-TPU timing is the
    # hardware target).
    pol_k = make_kernel_policy(template.r, template.p_it)
    o_k, us_k = timed(
        lambda: simulate(template, pol_k, key, cfg.v), iters=1
    )
    agree = float((o_k.f_trace == o_ref.f_trace).mean())
    cost_rel = abs(float(o_k.cost.mean()) - float(o_ref.cost.mean())) / max(
        float(o_ref.cost.mean()), 1e-9
    )
    interp = jax.default_backend() != "tpu"
    emit(
        f"fleet256_e2e_kernel_T{cfg.t_slots}", us_k,
        f"interpret={interp};dispatch_agreement={agree:.4f};"
        f"cost_rel_err={cost_rel:.2e}",
    )
    assert agree > 0.999, (
        f"kernel dispatch must match the reference engine (got {agree})"
    )
    assert cost_rel < 1e-3, f"fleet e2e cost diverged ({cost_rel})"


def bench_ssd():
    b, s, h, p, n = 1, 2048, 80, 64, 128   # mamba2-2.7b layer geometry
    key = jax.random.key(1)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    fn = jax.jit(lambda *args: ssd_chunked(*args, 256))
    _, us = timed(fn, x, dt, a, bm, cm)
    flops = 2 * b * s * h * (256 * p + 2 * p * n)  # per-token chunk matmuls (approx)
    emit("ssd_chunked_jnp_mamba2_layer_S2048", us, f"approx_gflop={flops/1e9:.1f}")
    # interpret spot check at reduced shape
    xs, dts, bms, cms = x[:, :256, :2], dt[:, :256, :2], bm[:, :256], cm[:, :256]
    y_k, h_k = ssd_scan(xs, dts, a[:2], bms, cms, chunk=64, interpret=True)
    y_r, h_r = ssd_scan_ref(xs, dts, a[:2], bms, cms)
    np.testing.assert_allclose(y_k, y_r, rtol=3e-4, atol=3e-4)


def main():
    bench_gmsa_dispatch()
    bench_gmsa_matrix()
    bench_fleet_e2e()
    bench_ssd()
    bench_ssd_matrix()


if __name__ == "__main__":
    main()
    from benchmarks.common import write_bench_json
    write_bench_json(label="kernel_bench")
