"""Kernel-layer microbenchmarks.

Wall-clock on this container measures the pure-JAX (XLA:CPU) paths — the
TPU Pallas kernels are the *target* (validated in interpret mode, timed
meaningfully only on hardware). Reported here:

  * gmsa dispatch (jnp path) at fleet scales (N pods × K classes) — the
    per-slot control-plane latency budget;
  * ssd chunked scan (jnp path) at mamba2-2.7b layer geometry;
  * per-shape interpret-mode *correctness* spot checks for both kernels
    (already swept in tests; repeated here so the bench run self-validates).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.gmsa_score.ref import gmsa_score_ref
from repro.kernels.gmsa_score.ops import gmsa_score
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.ssm import ssd_chunked


def bench_gmsa_dispatch():
    for (k, n) in [(1, 4), (16, 64), (128, 1024)]:
        key = jax.random.key(0)
        ks = jax.random.split(key, 6)
        q = jax.random.uniform(ks[0], (k, n)) * 100
        mu = jax.random.uniform(ks[1], (k, n)) * 50
        a = jax.random.uniform(ks[2], (k,)) * 40
        vp = jax.random.uniform(ks[3], (k,)) * 10
        # normalized uniforms, not dirichlet: gamma rejection sampling for
        # (128, 1024, 1024) takes minutes on one CPU core
        raw = jax.random.uniform(ks[4], (k, n, n)) + 1e-3
        r = raw / raw.sum(-1, keepdims=True)
        wpue = jax.random.uniform(ks[5], (n,)) * 20
        fn = jax.jit(gmsa_score_ref)
        (_, best), us = timed(fn, q, mu, a, vp, r, wpue)
        emit(f"gmsa_dispatch_jnp_K{k}_N{n}", us,
             f"r_tensor_mb={r.size*4/1e6:.1f}")
        # interpret-mode kernel spot check (small scales only: interpret
        # executes each grid cell in Python — fleet scale is covered by the
        # tiled test sweep in tests/test_kernels.py)
        if k * n <= 16 * 64:
            s_ref, b_ref = gmsa_score_ref(q, mu, a, vp, r, wpue)
            _, b_k = gmsa_score(q, mu, a, vp, r, wpue, interpret=True)
            assert np.array_equal(np.asarray(b_k), np.asarray(b_ref))


def bench_ssd():
    b, s, h, p, n = 1, 2048, 80, 64, 128   # mamba2-2.7b layer geometry
    key = jax.random.key(1)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    fn = jax.jit(lambda *args: ssd_chunked(*args, 256))
    _, us = timed(fn, x, dt, a, bm, cm)
    flops = 2 * b * s * h * (256 * p + 2 * p * n)  # per-token chunk matmuls (approx)
    emit("ssd_chunked_jnp_mamba2_layer_S2048", us, f"approx_gflop={flops/1e9:.1f}")
    # interpret spot check at reduced shape
    xs, dts, bms, cms = x[:, :256, :2], dt[:, :256, :2], bm[:, :256], cm[:, :256]
    y_k, h_k = ssd_scan(xs, dts, a[:2], bms, cms, chunk=64, interpret=True)
    y_r, h_r = ssd_scan_ref(xs, dts, a[:2], bms, cms)
    np.testing.assert_allclose(y_k, y_r, rtol=3e-4, atol=3e-4)


def main():
    bench_gmsa_dispatch()
    bench_ssd()


if __name__ == "__main__":
    main()
