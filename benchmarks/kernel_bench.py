"""Kernel-layer microbenchmarks + the fleet-scale end-to-end run.

Wall-clock on this container measures the pure-JAX (XLA:CPU) paths — the
TPU Pallas kernels are the *target* (validated in interpret mode, timed
meaningfully only on hardware). Reported here:

  * gmsa dispatch (jnp path) at fleet scales (N pods × K classes) — the
    per-slot control-plane latency budget;
  * the N = 256 ``configs.fleet_256`` scenario END-TO-END: a full GMSA
    simulation through ``gmsa_dispatch(..., impl="kernel")`` (interpret
    mode off-TPU — a correctness/viability gate, not a speed number on
    CPU) against the same run on the hoisted-einsum reference engine,
    with dispatch-agreement and cost-parity checks;
  * ssd chunked scan (jnp path) at mamba2-2.7b layer geometry;
  * per-shape interpret-mode *correctness* spot checks for both kernels
    (already swept in tests; repeated here so the bench run self-validates).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.configs.fleet_256 import FleetConfig, make_fleet_builder
from repro.core.gmsa import gmsa_policy, make_kernel_policy
from repro.core.simulator import simulate
from repro.kernels.gmsa_score.ref import gmsa_score_ref
from repro.kernels.gmsa_score.ops import gmsa_score
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.ssm import ssd_chunked

#: End-to-end fleet horizon: long enough that queues develop (the argmin
#: is exercised against live backlogs), short enough that the Python-free
#: interpret-mode kernel path compiles and runs in CI time.
FLEET_E2E_SLOTS = 48


def bench_gmsa_dispatch():
    for (k, n) in [(1, 4), (16, 64), (128, 1024)]:
        key = jax.random.key(0)
        ks = jax.random.split(key, 6)
        q = jax.random.uniform(ks[0], (k, n)) * 100
        mu = jax.random.uniform(ks[1], (k, n)) * 50
        a = jax.random.uniform(ks[2], (k,)) * 40
        vp = jax.random.uniform(ks[3], (k,)) * 10
        # normalized uniforms, not dirichlet: gamma rejection sampling for
        # (128, 1024, 1024) takes minutes on one CPU core
        raw = jax.random.uniform(ks[4], (k, n, n)) + 1e-3
        r = raw / raw.sum(-1, keepdims=True)
        wpue = jax.random.uniform(ks[5], (n,)) * 20
        fn = jax.jit(gmsa_score_ref)
        (_, best), us = timed(fn, q, mu, a, vp, r, wpue)
        emit(f"gmsa_dispatch_jnp_K{k}_N{n}", us,
             f"r_tensor_mb={r.size*4/1e6:.1f}")
        # interpret-mode kernel spot check (small scales only: interpret
        # executes each grid cell in Python — fleet scale is covered by the
        # tiled test sweep in tests/test_kernels.py)
        if k * n <= 16 * 64:
            s_ref, b_ref = gmsa_score_ref(q, mu, a, vp, r, wpue)
            _, b_k = gmsa_score(q, mu, a, vp, r, wpue, interpret=True)
            assert np.array_equal(np.asarray(b_k), np.asarray(b_ref))


def bench_fleet_e2e():
    """N = 256 fleet GMSA, end-to-end through the kernel dispatch path."""
    cfg = FleetConfig(t_slots=FLEET_E2E_SLOTS)
    template, _ = make_fleet_builder(cfg)
    key = jax.random.key(0)

    # Reference engine: hoisted-einsum cost tables + pure-XLA argmin.
    o_ref, us_ref = timed(
        lambda: simulate(template, gmsa_policy, key, cfg.v)
    )
    emit(
        f"fleet256_e2e_ref_T{cfg.t_slots}", us_ref,
        f"n={cfg.n_sites};k={cfg.k_types};"
        f"us_per_slot={us_ref/cfg.t_slots:.1f};"
        f"avg_cost={float(o_ref.cost.mean()):.0f};"
        f"final_backlog={float(o_ref.backlog_total[-1]):.1f}",
    )

    # Kernel engine: the fused Pallas score+argmin per slot (interpret
    # mode off-TPU — this row gates that the fleet scenario COMPLETES
    # through gmsa_dispatch(impl="kernel"); compiled-TPU timing is the
    # hardware target).
    pol_k = make_kernel_policy(template.r, template.p_it)
    o_k, us_k = timed(
        lambda: simulate(template, pol_k, key, cfg.v), iters=1
    )
    agree = float((o_k.f_trace == o_ref.f_trace).mean())
    cost_rel = abs(float(o_k.cost.mean()) - float(o_ref.cost.mean())) / max(
        float(o_ref.cost.mean()), 1e-9
    )
    interp = jax.default_backend() != "tpu"
    emit(
        f"fleet256_e2e_kernel_T{cfg.t_slots}", us_k,
        f"interpret={interp};dispatch_agreement={agree:.4f};"
        f"cost_rel_err={cost_rel:.2e}",
    )
    assert agree > 0.999, (
        f"kernel dispatch must match the reference engine (got {agree})"
    )
    assert cost_rel < 1e-3, f"fleet e2e cost diverged ({cost_rel})"


def bench_ssd():
    b, s, h, p, n = 1, 2048, 80, 64, 128   # mamba2-2.7b layer geometry
    key = jax.random.key(1)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    fn = jax.jit(lambda *args: ssd_chunked(*args, 256))
    _, us = timed(fn, x, dt, a, bm, cm)
    flops = 2 * b * s * h * (256 * p + 2 * p * n)  # per-token chunk matmuls (approx)
    emit("ssd_chunked_jnp_mamba2_layer_S2048", us, f"approx_gflop={flops/1e9:.1f}")
    # interpret spot check at reduced shape
    xs, dts, bms, cms = x[:, :256, :2], dt[:, :256, :2], bm[:, :256], cm[:, :256]
    y_k, h_k = ssd_scan(xs, dts, a[:2], bms, cms, chunk=64, interpret=True)
    y_r, h_r = ssd_scan_ref(xs, dts, a[:2], bms, cms)
    np.testing.assert_allclose(y_k, y_r, rtol=3e-4, atol=3e-4)


def main():
    bench_gmsa_dispatch()
    bench_fleet_e2e()
    bench_ssd()


if __name__ == "__main__":
    main()
    from benchmarks.common import write_bench_json
    write_bench_json(label="kernel_bench")
