"""Two-timescale placement vs. static placement on a drifting dataset.

The scenario the base paper cannot express: over the 24 h horizon, new data
is ingested disproportionately at ForestCity (the priciest power in the
fleet) and datasets grow 5%/epoch. GMSA keeps dispatching per slot in both
arms; the adaptive arm additionally re-places data every W = 48 slots
(4 hours) through the WAN cost model, the static arm never moves a byte.

Reports, per arm: time-averaged total cost (dispatch + WAN moves +
replication sync), the WAN and sync bills, and wall-clock per Monte-Carlo
run for the jit-compiled scan-of-scans engine (compile once, reuse across
runs — the steady-state number excludes the single compilation, which is
reported separately).

``--fault`` runs the chaos scenario instead: the same drifting trace, but
ForestCity drops dead mid-trace (slot 144 of 288, permanently). Both arms
run the controller's recovery path — backlog re-injection, survivor
re-replication, emergency WAN billing — and the bench reports the recovery
bill plus *recovery-time-to-SLO*: how many slots after the loss the fleet
backlog needs to drain back under 1.5x its pre-loss level.

``--sweep`` maps the slow-timescale analogue of GMSA's V trade-off: the
adaptive arm swept over ``epoch_slots`` (re-decision period W) x
``move_budget`` (per-epoch correction step alpha), reporting the
cost-vs-churn frontier — time-averaged total cost against WAN GB moved
(placement churn). Small W / large alpha chases the drift aggressively
(low dispatch cost, high churn); large W / small alpha barely moves
(static-like). Each W is its own compilation (the epoch structure is
static), so the sweep reports per-cell compile time too.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import N_RUNS, emit, timed_compile_sweep
from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import static_placement_rule
from repro.core.gmsa import dispatch_fn
from repro.core.sweep import sweep_placed_budgets
from repro.placement import (
    PlacementConfig,
    make_adaptive_rule,
    simulate_placed_many,
    summarize_placed,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.drift import dataset_growth_trace, ingest_drift_trace
from repro.traces.faults import scheduled_failure_trace

EPOCH_SLOTS = 48          # 4 h slow-loop period
GROWTH_PER_EPOCH = 0.05   # dataset volume growth
INGEST_FRACTION = 0.25    # share of each dataset that is fresh per epoch
FAULT_SITE = 1            # ForestCity — where the drifting ingest piles up
FAULT_SLOT = 144          # noon of the 24 h horizon
SLO_FACTOR = 1.5          # "recovered" = backlog back under 1.5x pre-loss

SWEEP_EPOCH_SLOTS = (24, 48, 96, 144)   # divisors of the 288-slot horizon
SWEEP_MOVE_BUDGETS = (0.25, 0.5, 1.0)
SWEEP_RUNS = 64           # per-cell Monte-Carlo runs (12 compiled cells)


def recovery_time_to_slo(backlog_avg: np.ndarray, t_die: int) -> int:
    """Slots after ``t_die`` until the run-mean backlog re-enters the SLO.

    The SLO level is ``SLO_FACTOR`` x the mean backlog over the epoch
    preceding the loss. Returns the horizon remainder if it never recovers.
    """
    trace = backlog_avg.mean(axis=0) if backlog_avg.ndim == 2 else backlog_avg
    pre = float(trace[max(t_die - EPOCH_SLOTS, 0):t_die].mean())
    slo = SLO_FACTOR * max(pre, 1e-6)
    post = trace[t_die:]
    ok = np.nonzero(post <= slo)[0]
    return int(ok[0]) if ok.size else int(post.size)


def _timed_sweep(build, up, down, pol, rule, key, n_runs, pcfg, **kw):
    return timed_compile_sweep(
        lambda: simulate_placed_many(build, up, down, pol, rule, key,
                                     n_runs, pcfg, **kw),
        n_runs,
    )


def sweep(cfg, build, up, down):
    """The epoch-length x move-budget frontier (cost vs. churn).

    Every cell faces the *same* exogenous drift: one ingest walk drawn at
    the finest epoch granularity, aggregated per slow-loop window (mean
    mix over the window), with the per-epoch mixing fraction and dataset
    growth compounded so a W-slot epoch applies exactly the cumulative
    drift of W/W0 fine epochs. Only the controller's re-decision period
    and step size vary — otherwise large-W cells would see ~(W/W0)x less
    drift and the frontier would reward slow loops for the wrong reason.

    §Perf v6: each W (one compilation — the epoch structure is static) now
    runs its WHOLE move-budget column as ONE launch through
    :func:`repro.core.sweep.sweep_placed_budgets` (the controller's
    ``move_budget`` became traced data). The old per-cell launch path is
    timed once, at the first W, for the migration delta
    (``placement_sweep_grid_vs_percell``).
    """
    pol = dispatch_fn(cfg.v)
    key = jax.random.key(0)
    n_runs = min(N_RUNS, SWEEP_RUNS)
    rule = make_adaptive_rule(up, temp=2.0)
    w0 = min(SWEEP_EPOCH_SLOTS)
    fine = ingest_drift_trace(
        jax.random.key(7), cfg.t_slots // w0, cfg.k_types, cfg.n_sites,
        bias=jnp.array([0.05, 0.8, 0.05, 0.10]), bias_strength=0.5,
    )                                                     # (E0, K, N)
    frontier = []
    percell_report = None
    for w in SWEEP_EPOCH_SLOTS:
        n_epochs = cfg.t_slots // w
        stride = w // w0
        ingest = fine.reshape(n_epochs, stride, cfg.k_types, cfg.n_sites).mean(1)
        ingest = ingest / jnp.sum(ingest, axis=-1, keepdims=True)
        # Compound the headline scenario's per-48-slot rates to this W.
        growth = 1.0 - (1.0 - INGEST_FRACTION) ** (w / EPOCH_SLOTS)
        sizes = dataset_growth_trace(
            n_epochs, cfg.k_types, 100.0,
            (1.0 + GROWTH_PER_EPOCH) ** (w / EPOCH_SLOTS) - 1.0,
        )
        pcfg = PlacementConfig(
            epoch_slots=w, growth=growth,
            capacity_gb=(220.0, 220.0, 220.0, 220.0),
            manager_share=cfg.manager_share, map_share=cfg.map_share,
        )
        # The whole move-budget column in one compilation + one launch.
        col, col_us_per_run, col_compile_us = timed_compile_sweep(
            lambda: sweep_placed_budgets(
                build, up, down, pol, rule, key, n_runs, pcfg,
                SWEEP_MOVE_BUDGETS, ingest=ingest, sizes_gb=sizes,
            ),
            n_runs * len(SWEEP_MOVE_BUDGETS),
        )
        for i, mb in enumerate(SWEEP_MOVE_BUDGETS):
            s = summarize_placed(jax.tree_util.tree_map(lambda x: x[i], col))
            frontier.append((w, mb, s))
            emit(
                f"placement_sweep_w{w}_b{mb}", col_us_per_run,
                f"total_cost={s['time_avg_total_cost']:.1f};"
                f"wan_gb={s['total_wan_gb']:.0f};"
                f"wan_cost={s['time_avg_wan_cost']:.2f};"
                f"backlog={s['time_avg_backlog']:.2f};"
                f"grid_compile_us={col_compile_us:.0f}",
            )
        if percell_report is None:
            # Old per-cell path (one launch + one compile per move budget,
            # since the static cfg.move_budget re-specializes the jit) —
            # measured with the SAME best-of estimator as the grid column,
            # for an unbiased delta report.
            cfgs = [
                PlacementConfig(
                    epoch_slots=w, move_budget=mb, growth=growth,
                    capacity_gb=(220.0, 220.0, 220.0, 220.0),
                    manager_share=cfg.manager_share, map_share=cfg.map_share,
                )
                for mb in SWEEP_MOVE_BUDGETS
            ]

            def percell_pass():
                last = None
                for pc in cfgs:
                    last = simulate_placed_many(
                        build, up, down, pol, rule, key, n_runs, pc,
                        ingest=ingest, sizes_gb=sizes,
                    )
                return last

            _, percell_us_per_run, percell_compile_us = timed_compile_sweep(
                percell_pass, n_runs * len(SWEEP_MOVE_BUDGETS)
            )
            percell_report = (
                col_us_per_run, col_compile_us,
                percell_us_per_run, percell_compile_us,
            )
    g_us, g_c, p_us, p_c = percell_report
    emit(
        "placement_sweep_grid_vs_percell", g_us,
        f"percell_us_per_run={p_us:.1f};"
        f"steady_speedup={p_us/max(g_us,1e-9):.2f}x;"
        f"grid_compile_us={g_c:.0f};percell_compile_us={p_c:.0f}",
    )
    best = min(frontier, key=lambda c: c[2]["time_avg_total_cost"])
    emit(
        "placement_sweep_best", 0.0,
        f"epoch_slots={best[0]};move_budget={best[1]};"
        f"total_cost={best[2]['time_avg_total_cost']:.1f};"
        f"wan_gb={best[2]['total_wan_gb']:.0f}",
    )


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--fault", action="store_true",
        help="mid-trace site-loss chaos scenario (adaptive-with-recovery "
             "vs static under the same outage)",
    )
    parser.add_argument(
        "--sweep", action="store_true",
        help="epoch_slots x move_budget sweep: the cost-vs-churn frontier "
             "(the slow-timescale analogue of GMSA's V sweep)",
    )
    args, _ = parser.parse_known_args(argv)

    cfg = PaperSimConfig()
    _, build = make_sim_builder(cfg)
    root = jax.random.key(cfg.trace_seed)
    up, down = bandwidth_draw(jax.random.split(root, 6)[2], cfg.n_sites)

    if args.sweep:
        sweep(cfg, build, up, down)
        return

    n_epochs = cfg.t_slots // EPOCH_SLOTS
    # Ingest drifts toward ForestCity — the expensive site (traces.price).
    ingest = ingest_drift_trace(
        jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites,
        bias=jnp.array([0.05, 0.8, 0.05, 0.10]), bias_strength=0.5,
    )
    sizes = dataset_growth_trace(n_epochs, cfg.k_types, 100.0, GROWTH_PER_EPOCH)
    pcfg = PlacementConfig(
        epoch_slots=EPOCH_SLOTS, growth=INGEST_FRACTION,
        capacity_gb=(220.0, 220.0, 220.0, 220.0),
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    pol = dispatch_fn(cfg.v)
    key = jax.random.key(0)
    n_runs = min(N_RUNS, 1000)

    alive = None
    tag = ""
    if args.fault:
        alive = scheduled_failure_trace(
            cfg.t_slots, cfg.n_sites, [(FAULT_SITE, FAULT_SLOT, None)]
        )
        tag = "fault_"

    results = {}
    for name, rule in [
        ("static", static_placement_rule),
        ("adaptive", make_adaptive_rule(up, temp=2.0)),
    ]:
        outs, us_per_run, compile_us = _timed_sweep(
            build, up, down, pol, rule, key, n_runs, pcfg,
            ingest=ingest, sizes_gb=sizes, alive=alive,
        )
        s = summarize_placed(outs)
        results[name] = s
        derived = (
            f"total_cost={s['time_avg_total_cost']:.1f};"
            f"wan_cost={s['time_avg_wan_cost']:.2f};"
            f"sync_cost={s['time_avg_sync_cost']:.2f};"
            f"wan_gb={s['total_wan_gb']:.0f};"
            f"backlog={s['time_avg_backlog']:.2f};"
            f"compile_us={compile_us:.0f}"
        )
        if args.fault:
            ttr = recovery_time_to_slo(np.asarray(outs.backlog_avg),
                                       FAULT_SLOT)
            results[name]["recovery_slots_to_slo"] = ttr
            derived += (
                f";recovery_cost={s['time_avg_recovery_cost']:.3f}"
                f";recovery_gb={s['total_recovery_gb']:.0f}"
                f";recovery_slots_to_slo={ttr}"
            )
        emit(f"placement_{tag}{name}_{n_runs}runs_per_run", us_per_run,
             derived)

    saving = 1.0 - (results["adaptive"]["time_avg_total_cost"]
                    / results["static"]["time_avg_total_cost"])
    emit(f"placement_{tag}adaptive_saving", 0.0, f"saving_frac={saving:.3f}")
    scenario = "site-loss" if args.fault else "drifting"
    assert saving > 0.0, (
        f"adaptive placement must beat STATIC-PLACEMENT on the {scenario} "
        "trace"
    )


if __name__ == "__main__":
    main()
    from benchmarks.common import write_bench_json
    write_bench_json(label="placement_bench")
