"""Serving control-plane benchmark: FleetEngine on the simulation stack.

Times the unified serving loop (staged prefill→decode dispatch, replica-
read routing, admission control) in dispatch-only mode, asserts the two
invariants the refactor introduced, and exercises the fleet-scale kernel
path:

* **replay parity** — a dispatch-only ``FleetEngine.run`` must agree with
  ``simulate_staged`` on the shared :class:`repro.serve.engine.ServeScenario`:
  per-slot dispatch choices bit-for-bit, total billed cost (compute $ +
  KV-handoff WAN $) to float tolerance.
* **request conservation** — admitted arrivals = completed + final
  backlog per class (the served-vs-billed accounting fix).
* **fleet grid** — an N = 256 pod grid from
  :func:`repro.configs.fleet_256.make_serve_grid` where every slot's
  decision runs through ``gmsa_dispatch(impl="kernel")`` (interpret mode
  on CPU/CI).

``--quick`` is the tier-1 CI step: dispatch-only, n_pods = 8, a few
slots of the kernel grid. The staged run carries the sojourn-histogram
layer; ``--flight OUT.jsonl`` saves its flight-record stream and
``--trace OUT.json`` the folded Chrome trace (CI uploads both as
artifacts). The full run adds a real-execution row (prefill+decode for
drained jobs) on the smoke models.

``--chaos`` adds the degraded-mode arm: the calibrated straggler +
link-fault trace over the serve scenario, run twice — without and with
speculative re-execution — recording hedged-job count, duplicated-
compute overhead, and sojourn p99 for both arms into ``BENCH_sim.json``
(the trajectory behind the speculation-protocol frontier in
EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.configs.fleet_256 import FleetConfig as GridConfig
from repro.configs.fleet_256 import make_serve_grid
from repro.jobs.engine import simulate_staged
from repro.launch.serve import build_engine
from repro.serve.engine import FleetConfig, FleetEngine, RequestClass, serve_policy
from repro.telemetry import (
    SUMMARY,
    HistogramSpec,
    SloSpec,
    TelemetryConfig,
    fleet_records,
    spans_from_records,
    write_chrome_trace,
    write_jsonl,
)


def _timed_run(engine: FleetEngine, execute_real: bool):
    t0 = time.perf_counter()
    out = engine.run(execute_real=execute_real)
    return out, (time.perf_counter() - t0) * 1e6


def _assert_parity(engine: FleetEngine, out: dict):
    """Dispatch-only replay vs simulate_staged on the shared scenario."""
    scn = engine.scenario
    pol = serve_policy(engine.fcfg, scn)
    outs = simulate_staged(
        scn.inputs, scn.dag, scn.wan, pol, jax.random.key(0), engine.fcfg.v
    )
    assert np.array_equal(out["dispatch"], np.asarray(outs.f_trace)), (
        "serving dispatch trace diverged from simulate_staged"
    )
    # Hedge-free runs bill zero here, so the pre-speculation parity
    # contract is unchanged; hedged runs must agree on the full bill.
    sim_total = float(
        np.asarray(outs.cost).sum() + np.asarray(outs.wan_cost).sum()
        + np.asarray(outs.hedge_cost).sum()
    )
    assert np.isclose(out["total_billed_cost"], sim_total, rtol=1e-5), (
        f"billed cost diverged: engine {out['total_billed_cost']} "
        f"vs simulator {sim_total}"
    )


def _assert_conservation(out: dict):
    adm = out["admitted"].sum(axis=0)
    comp = out["completed"].sum(axis=0)
    qf = out["q_final"].sum(axis=(0, 2))
    assert np.allclose(adm, comp + qf, atol=1e-3), (
        f"request conservation violated: admitted {adm} != "
        f"completed {comp} + backlog {qf}"
    )
    assert np.allclose(
        out["raw_arrivals"], out["admitted"] + out["rejected"]
    ), "admission split is not exact"
    if "sojourn_hist" in out:
        # The sojourn clock conserves the same flow: every unit of
        # completed mass landed in exactly one histogram bucket.
        hist_mass = out["sojourn_hist"].sum(axis=-1)
        assert np.allclose(hist_mass, comp, atol=1e-2), (
            f"sojourn histogram lost mass: {hist_mass} vs completed {comp}"
        )


def _sojourn_p99(out: dict) -> float:
    from repro.telemetry.metrics import fifo_sojourn_replay, weighted_percentile

    soj, wgt = fifo_sojourn_replay(out["admitted"], out["completed"])
    return float(weighted_percentile(soj, wgt, [99.0])[0])


def _chaos_arm():
    """Degraded-mode pair: the calibrated straggler scenario, hedged vs not.

    Pod 2 (the dominant-capacity pod) drops to 12% of nominal rate from
    slot 4, and one WAN link browns out mid-run; the hedged arm clones
    starved stages at threshold 0.35. The recorded frontier point —
    p99 cut vs duplicated-compute overhead — is the bench twin of the
    ``test_degraded`` speculation pin (>= 20% cut at <= 10% overhead).
    """
    slots, n_pods, hedge = 24, 4, 0.35
    classes = ["qwen2-0.5b", "mamba2-2.7b"]
    common = dict(slots=slots, v=1.0, seed=3, arrival=4.0, admit_max=5.0)
    health = np.ones((slots, n_pods), np.float32)
    health[4:, 2] = 0.12
    link_health = np.ones((slots, n_pods, n_pods), np.float32)
    link_health[8:16, 0, 1] = link_health[8:16, 1, 0] = 0.5

    base = build_engine(classes, health=health, link_health=link_health,
                        **common)
    bout, bus = _timed_run(base, execute_real=False)
    _assert_conservation(bout)
    hedged = build_engine(classes, health=health, link_health=link_health,
                          hedge=hedge, **common)
    hout, hus = _timed_run(hedged, execute_real=False)
    _assert_conservation(hout)

    p99_b, p99_h = _sojourn_p99(bout), _sojourn_p99(hout)
    overhead = float(hout["hedge_cost"].sum()) / max(
        float(hout["cost"].sum()) + float(hout["hedge_cost"].sum()), 1e-12)
    emit(
        f"serve_chaos_nohedge_{slots}slots", bus,
        f"sojourn_p99={p99_b:.2f};"
        f"backlog={bout['final_backlog']:.1f};"
        f"completed={bout['completed'].sum():.1f}",
    )
    emit(
        f"serve_chaos_hedge_{slots}slots", hus,
        f"sojourn_p99={p99_h:.2f};"
        f"backlog={hout['final_backlog']:.1f};"
        f"completed={hout['completed'].sum():.1f};"
        f"hedged_jobs={hout['hedged_jobs'].sum():.2f};"
        f"hedge_overhead={overhead:.4f};"
        f"p99_cut={(p99_b - p99_h) / max(p99_b, 1e-12):.3f}",
    )


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--quick", action="store_true",
        help="dispatch-only smoke version (CI tier-1 step)",
    )
    parser.add_argument(
        "--chaos", action="store_true",
        help="add the degraded-mode arm (stragglers + link faults, "
             "speculation on/off pair)",
    )
    parser.add_argument(
        "--flight", default=None, metavar="OUT.jsonl",
        help="write the staged run's flight-record stream here",
    )
    parser.add_argument(
        "--trace", default=None, metavar="OUT.json",
        help="write the staged run's Chrome trace (Perfetto) here",
    )
    args, _ = parser.parse_known_args(argv)

    slots = 16 if args.quick else 32

    # -- staged dispatch, 8 pods (the capacity_shares-derivation regression),
    #    with the sojourn-histogram layer on (telemetry must not perturb
    #    the replay-parity or conservation pins).
    tcfg = TelemetryConfig(level=SUMMARY, hist=HistogramSpec())
    eng = build_engine(
        ["qwen2-0.5b"], slots, v=1.0, seed=3, arrival=6.0,
        n_pods=8, admit_max=10.0, telemetry=tcfg,
    )
    out, us = _timed_run(eng, execute_real=False)
    _assert_parity(eng, out)
    _assert_conservation(out)
    p99 = out["sojourn_percentiles"][0]["p99"]
    emit(
        f"serve_staged_8pods_{slots}slots", us,
        f"mean_cost={out['mean_cost']:.3e};"
        f"wan_cost={out['wan_cost'].sum():.3e};"
        f"backlog={out['final_backlog']:.1f};"
        f"admitted={out['admitted'].sum():.0f};"
        f"rejected={out['rejected'].sum():.0f};"
        f"sojourn_p99={p99:.2f}",
    )
    if args.flight or args.trace:
        slo = SloSpec(target=8.0, percentile=99.0)
        records = fleet_records(out, meta={"slo_backlog": 50.0}, slo=slo)
        if args.flight:
            write_jsonl(records, args.flight)
            print(f"flight record -> {args.flight}")
        if args.trace:
            write_chrome_trace(spans_from_records(records), args.trace)
            print(f"chrome trace  -> {args.trace}")

    # -- fleet-scale kernel dispatch: N = 256 pod grid through the Pallas
    #    path (interpret on CPU).
    grid_slots = 4 if args.quick else 8
    gc = GridConfig()
    omega, pue, r, up, down, layout, shares = make_serve_grid(gc, 2, grid_slots)
    rcs = [
        RequestClass(name=a, cfg=get_arch(a, "smoke"),
                     energy_cfg=get_arch(a, "full"), arrival_rate=40.0)
        for a in ["qwen2-0.5b", "mamba2-2.7b"]
    ]
    fc = FleetConfig(
        n_pods=gc.n_sites, horizon_slots=grid_slots, v=gc.v, seed=1,
        capacity_shares=shares, dispatch="kernel", admit_max=64.0,
    )
    keng = FleetEngine(fc, rcs, omega, pue, r, up=up, down=down, layout=layout)
    kout, kus = _timed_run(keng, execute_real=False)
    _assert_conservation(kout)
    emit(
        f"serve_kernel_{gc.n_sites}pods_{grid_slots}slots", kus,
        f"mean_cost={kout['mean_cost']:.3e};"
        f"backlog={kout['final_backlog']:.1f};"
        f"admitted={kout['admitted'].sum():.0f}",
    )

    if args.chaos:
        _chaos_arm()

    if not args.quick:
        # -- real execution: drained jobs run prefill+decode (smoke models).
        ex = build_engine(["qwen2-0.5b"], 8, v=1.0, seed=3, arrival=4.0)
        xout, xus = _timed_run(ex, execute_real=True)
        emit(
            "serve_exec_4pods_8slots", xus,
            f"exec_jobs={xout['exec_jobs']};"
            f"exec_seconds={xout['exec_seconds']:.2f}",
        )


if __name__ == "__main__":
    main()
    from benchmarks.common import write_bench_json
    write_bench_json(label="serve_bench")
