"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import json
import os
import pathlib
import time

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
ART.mkdir(exist_ok=True)

#: Machine-readable perf trajectory (EXPERIMENTS.md §Perf): every bench run
#: appends one entry here so future PRs can diff per-bench ``us_per_call``
#: against history. Lives at the repo root (committed; CI also uploads it
#: as an artifact).
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sim.json"

#: Paper methodology: 1000 Monte-Carlo runs. Override for quick iterations:
#: REPRO_BENCH_RUNS=100 python -m benchmarks.run
N_RUNS = int(os.environ.get("REPRO_BENCH_RUNS", "1000"))

#: Records accumulated by :func:`emit` in this process, flushed to
#: :data:`BENCH_JSON` by :func:`write_bench_json`.
_RECORDS: list[dict] = []


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Wall-time fn (already-jitted callables): returns (result, us_per_call)."""
    import jax

    result = None
    for _ in range(warmup):
        result = fn(*args)
        jax.block_until_ready(result)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args)
        jax.block_until_ready(result)
    dt = (time.perf_counter() - t0) / iters
    return result, dt * 1e6


def timed_compile_sweep(thunk, n_runs: int, iters: int = 4,
                        trace_dir: str | None = None):
    """Time a jit-compiled Monte-Carlo sweep, isolating compilation.

    The first call pays compilation plus one full sweep; steady state is
    the MINIMUM of ``iters`` further calls — the timeit-style best-of
    estimator: on shared/noisy CPUs every timing above the minimum is
    scheduler interference, not the program (a single call, which this
    harness used to take, is hostage to that noise). Subtracting isolates
    the one-time compile. Returns ``(outs, us_per_run, compile_us)``.

    ``trace_dir`` wraps the steady-state calls (compilation excluded) in
    ``jax.profiler.trace`` — open the result with TensorBoard's profile
    plugin or Perfetto. Timings taken under the profiler carry its
    overhead; use them for the op-level breakdown, not the trajectory.
    """
    import contextlib

    import jax

    t0 = time.perf_counter()
    outs = thunk()
    jax.block_until_ready(outs)
    first_call_us = (time.perf_counter() - t0) * 1e6

    prof = (jax.profiler.trace(trace_dir) if trace_dir
            else contextlib.nullcontext())
    steady = []
    with prof:
        for _ in range(max(iters, 1)):
            t0 = time.perf_counter()
            outs = thunk()
            jax.block_until_ready(outs)
            steady.append((time.perf_counter() - t0) * 1e6)
    us_per_run = min(steady) / n_runs
    compile_us = max(first_call_us - n_runs * us_per_run, 0.0)
    return outs, us_per_run, compile_us


def _parse_derived(derived: str) -> dict:
    """Best-effort ``k=v;k=v`` -> dict (values floated when possible)."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v.rstrip("%x"))
        except ValueError:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str):
    """The run.py output contract: ``name,us_per_call,derived`` CSV.

    Also records the row for :func:`write_bench_json`.
    """
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    _RECORDS.append({
        "name": name,
        "us_per_call": round(us_per_call, 1),
        "derived": _parse_derived(derived),
    })


def _provenance() -> dict:
    """Stamp for a BENCH_sim.json entry: git SHA, jax version, backend."""
    import subprocess

    import jax

    sha = None
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        pass
    return {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
    }


def read_bench_history(path=None) -> list[dict]:
    """Load the perf-trajectory entries (``[]`` on missing/corrupt file).

    Shared by :func:`write_bench_json` (append + dedup) and callers that
    want to inspect the trajectory (e.g. before handing it to
    ``repro.telemetry.bench_check``).
    """
    path = pathlib.Path(path) if path is not None else BENCH_JSON
    if not path.exists():
        return []
    try:
        return json.loads(path.read_text())
    except json.JSONDecodeError:
        return []


def write_bench_json(label: str | None = None):
    """Append this process's emitted records to :data:`BENCH_JSON`.

    Called by ``benchmarks.run`` after the full suite and by each bench
    module's ``__main__`` guard when run standalone (the CI smoke step),
    so the perf trajectory accrues either way. No-op when nothing was
    emitted.

    Each entry is stamped with provenance (git SHA, jax version, backend)
    so a trajectory diff can tell a regression from an environment change.
    Re-runs that produce a ``derived`` payload identical to the previous
    entry with the same label are SKIPPED — ``us_per_call`` is timing
    noise, so without the dedup every CI retry would grow the file with
    rows that say nothing new.
    """
    if not _RECORDS:
        return
    history = read_bench_history()
    payload = [(r["name"], r["derived"]) for r in _RECORDS]
    for prev in reversed(history):
        if prev.get("label") != label:
            continue
        prev_payload = [
            (b.get("name"), b.get("derived")) for b in prev.get("benches", [])
        ]
        if prev_payload == payload:
            return                      # identical derived results: no news
        break
    history.append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "label": label,
        "n_runs_env": N_RUNS,
        **_provenance(),
        "benches": list(_RECORDS),
    })
    BENCH_JSON.write_text(json.dumps(history, indent=1) + "\n")
