"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import os
import pathlib
import time

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
ART.mkdir(exist_ok=True)

#: Paper methodology: 1000 Monte-Carlo runs. Override for quick iterations:
#: REPRO_BENCH_RUNS=100 python -m benchmarks.run
N_RUNS = int(os.environ.get("REPRO_BENCH_RUNS", "1000"))


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Wall-time fn (already-jitted callables): returns (result, us_per_call)."""
    import jax

    result = None
    for _ in range(warmup):
        result = fn(*args)
        jax.block_until_ready(result)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args)
        jax.block_until_ready(result)
    dt = (time.perf_counter() - t0) / iters
    return result, dt * 1e6


def timed_compile_sweep(thunk, n_runs: int):
    """Time a jit-compiled Monte-Carlo sweep, isolating compilation.

    Calls the zero-arg ``thunk`` twice: the first call pays compilation
    plus one full sweep, the second is steady state; subtracting isolates
    the one-time compile. Returns ``(outs, us_per_run, compile_us)``.
    """
    import jax

    t0 = time.perf_counter()
    outs = thunk()
    jax.block_until_ready(outs)
    first_call_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    outs = thunk()
    jax.block_until_ready(outs)
    us_per_run = (time.perf_counter() - t0) * 1e6 / n_runs
    compile_us = max(first_call_us - n_runs * us_per_run, 0.0)
    return outs, us_per_run, compile_us


def emit(name: str, us_per_call: float, derived: str):
    """The run.py output contract: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
