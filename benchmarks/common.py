"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import os
import pathlib
import time

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
ART.mkdir(exist_ok=True)

#: Paper methodology: 1000 Monte-Carlo runs. Override for quick iterations:
#: REPRO_BENCH_RUNS=100 python -m benchmarks.run
N_RUNS = int(os.environ.get("REPRO_BENCH_RUNS", "1000"))


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Wall-time fn (already-jitted callables): returns (result, us_per_call)."""
    import jax

    result = None
    for _ in range(warmup):
        result = fn(*args)
        jax.block_until_ready(result)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args)
        jax.block_until_ready(result)
    dt = (time.perf_counter() - t0) / iters
    return result, dt * 1e6


def emit(name: str, us_per_call: float, derived: str):
    """The run.py output contract: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
