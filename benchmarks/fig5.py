"""Paper Fig. 5 — performance along time (24 h, 288 five-minute slots).

(a) energy cost per slot; (b) average queue backlog per slot — for
GMSA(V=1), GMSA(V=10), DATA, RANDOM, averaged over N_RUNS Monte-Carlo runs.

Validations against the paper's claims (printed as derived fields):
  * GMSA cost below DATA/RANDOM in ≥90% of slots (paper: "almost all");
  * GMSA(V=1) average backlog stays below 50 (paper Fig. 5(b));
  * DATA/RANDOM backlogs grow ~linearly (divergence slope > 0);
    GMSA's is bounded (late-window slope ≈ 0).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import ART, N_RUNS, emit
from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import data_dispatch, random_dispatch
from repro.core.gmsa import dispatch_fn
from repro.core.simulator import simulate_many

POLICIES = {
    "GMSA_V1": dispatch_fn(1.0),
    "GMSA_V10": dispatch_fn(10.0),
    "DATA": data_dispatch,
    "RANDOM": random_dispatch,
}


def run(n_runs: int = N_RUNS) -> dict:
    cfg = PaperSimConfig()
    _, build = make_sim_builder(cfg)
    key = jax.random.key(42)
    series = {}
    t_us = {}
    for name, pol in POLICIES.items():
        t0 = time.perf_counter()
        outs = simulate_many(build, pol, key, n_runs)
        jax.block_until_ready(outs.cost)
        t_us[name] = (time.perf_counter() - t0) * 1e6 / n_runs
        series[name] = {
            "cost": np.asarray(outs.cost.mean(axis=0)),
            "backlog": np.asarray(outs.backlog_avg.mean(axis=0)),
        }

    gmsa1, data, rnd = series["GMSA_V1"], series["DATA"], series["RANDOM"]
    frac_below = float(np.mean(
        (gmsa1["cost"] <= data["cost"]) & (gmsa1["cost"] <= rnd["cost"])
    ))
    t = np.arange(cfg.t_slots)
    late = slice(cfg.t_slots // 2, None)
    slope = lambda y: float(np.polyfit(t[late], y[late], 1)[0])
    checks = {
        "frac_slots_gmsa_cheapest": frac_below,
        "gmsa_v1_max_avg_backlog": float(gmsa1["backlog"].max()),
        "slope_data": slope(data["backlog"]),
        "slope_random": slope(rnd["backlog"]),
        "slope_gmsa_v1": slope(gmsa1["backlog"]),
    }

    out = {
        "n_runs": n_runs,
        "per_policy_us": t_us,
        "checks": checks,
        "series": {k: {kk: vv.tolist() for kk, vv in v.items()} for k, v in series.items()},
    }
    (ART / "fig5.json").write_text(json.dumps(out, indent=1))
    return out


def main():
    out = run()
    c = out["checks"]
    emit("fig5a_cost_along_time", np.mean(list(out["per_policy_us"].values())),
         f"gmsa_cheapest_frac={c['frac_slots_gmsa_cheapest']:.3f}")
    emit("fig5b_backlog_along_time", np.mean(list(out["per_policy_us"].values())),
         f"v1_max_backlog={c['gmsa_v1_max_avg_backlog']:.1f};"
         f"slopes_data/rand/gmsa={c['slope_data']:.3f}/{c['slope_random']:.3f}/{c['slope_gmsa_v1']:.4f}")
    assert c["frac_slots_gmsa_cheapest"] >= 0.9, "GMSA not cheapest in >=90% slots"
    assert c["gmsa_v1_max_avg_backlog"] < 50, "paper: V=1 backlog below 50"
    assert c["slope_data"] > 10 * max(c["slope_gmsa_v1"], 1e-9)
    assert c["slope_random"] > 10 * max(c["slope_gmsa_v1"], 1e-9)


if __name__ == "__main__":
    main()
