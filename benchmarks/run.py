"""Benchmark orchestrator — one section per paper table/figure + systems
benches. Prints ``name,us_per_call,derived`` CSV lines (stdout contract).

  PYTHONPATH=src python -m benchmarks.run            # full (1000 runs)
  REPRO_BENCH_RUNS=100 PYTHONPATH=src python -m benchmarks.run   # quick
"""

from __future__ import annotations

import sys
import traceback

from benchmarks.common import write_bench_json


def main() -> None:
    failures = []
    print("name,us_per_call,derived")
    for name, modpath in [
        ("fig5", "benchmarks.fig5"),
        ("fig6", "benchmarks.fig6"),
        ("sim_bench", "benchmarks.sim_bench"),
        ("placement_bench", "benchmarks.placement_bench"),
        ("jobs_bench", "benchmarks.jobs_bench"),
        ("kernel_bench", "benchmarks.kernel_bench"),
        ("roofline", "benchmarks.roofline"),
    ]:
        try:
            mod = __import__(modpath, fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"{name},-1,FAILED", flush=True)
            traceback.print_exc()
    # Machine-readable perf trajectory (EXPERIMENTS.md §Perf): append this
    # run's rows to BENCH_sim.json at the repo root.
    write_bench_json(label="full" if not failures else "partial")
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
