"""Benchmark orchestrator — one section per paper table/figure + systems
benches. Prints ``name,us_per_call,derived`` CSV lines (stdout contract).

  PYTHONPATH=src python -m benchmarks.run            # full (1000 runs)
  REPRO_BENCH_RUNS=100 PYTHONPATH=src python -m benchmarks.run   # quick
  ... python -m benchmarks.run --trace-dir /tmp/prof  # + profiler trace
"""

from __future__ import annotations

import argparse
import contextlib
import sys
import traceback

from benchmarks.common import write_bench_json


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--trace-dir", default=None, metavar="DIR",
        help="wrap the whole suite in jax.profiler.trace(DIR) — view the "
             "op-level breakdown with TensorBoard's profile plugin",
    )
    args, _ = parser.parse_known_args(argv)

    failures = []
    print("name,us_per_call,derived")
    if args.trace_dir:
        import jax

        prof = jax.profiler.trace(args.trace_dir)
    else:
        prof = contextlib.nullcontext()
    with prof:
        run_benches(failures)
    # Machine-readable perf trajectory (EXPERIMENTS.md §Perf): append this
    # run's rows to BENCH_sim.json at the repo root.
    write_bench_json(label="full" if not failures else "partial")
    if failures:
        sys.exit(f"benchmark failures: {failures}")


def run_benches(failures: list) -> None:
    for name, modpath in [
        ("fig5", "benchmarks.fig5"),
        ("fig6", "benchmarks.fig6"),
        ("sim_bench", "benchmarks.sim_bench"),
        ("placement_bench", "benchmarks.placement_bench"),
        ("jobs_bench", "benchmarks.jobs_bench"),
        ("kernel_bench", "benchmarks.kernel_bench"),
        ("shard_bench", "benchmarks.shard_bench"),
        ("serve_bench", "benchmarks.serve_bench"),
        ("roofline", "benchmarks.roofline"),
    ]:
        try:
            mod = __import__(modpath, fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"{name},-1,FAILED", flush=True)
            traceback.print_exc()


if __name__ == "__main__":
    main()
