"""End-to-end driver: GMSA-dispatched LLM serving across a simulated
geo-distributed fleet — the paper's framework doing real work.

Two request classes (two architectures from the assigned pool, smoke-scale),
Poisson request arrivals, four pods with heterogeneous capacity and
price/PUE traces. Every slot:

  1. the front-end observes queues + per-pod energy cost (PUE × price ×
     Iridium fan-out) and runs GMSA to pick each class's manager pod;
  2. drained requests execute REAL batched prefill + decode steps;
  3. queues update by the paper's Eq. (1).

A second pass with V=100 shows the cost/backlog trade-off live, and a
dispatch-only RANDOM pass quantifies GMSA's savings.

    PYTHONPATH=src python examples/serve_geo.py
"""

import numpy as np

from repro.launch.serve import build_engine


def main():
    classes = ["qwen2-0.5b", "granite-3-2b"]
    slots = 16

    print("=== GMSA fleet serving (V=1), real model execution ===")
    engine = build_engine(classes, slots, v=1.0, arrival=5.0)
    out = engine.run(execute_real=True)
    print(f"mean energy cost/slot : {out['mean_cost']*1e6:.3f} µ$ "
          "(full-arch energy pricing, smoke-scale execution)")
    print(f"final backlog         : {out['final_backlog']:.0f} requests")
    print(f"model execution time  : {out['exec_seconds']:.1f}s "
          f"(batched prefill+decode on CPU)")
    share = out["dispatch"].mean(axis=0).sum(axis=1)
    print(f"dispatch share per pod: {np.round(share / share.sum(), 3)}")

    # Per-slot timeline straight from the engine's history records —
    # manager choice per class, pod queue depths, IT Joules per class.
    print("\nslot timeline (manager pod per class | pod queue depths | J):")
    for h in out["history"]:
        choices = " ".join(
            f"{c}->pod{p}" for c, p in zip(classes, h["choice"])
        )
        depths = " ".join(f"{d:5.1f}" for d in h["q_pod"])
        joules = " ".join(f"{j:6.1f}" for j in h["energy_j"])
        print(f"  t={h['t']:>2}  {choices}  | q [{depths}] | E [{joules}]")

    print("\n=== V=100 (cost-greedy) — dispatch only ===")
    engine = build_engine(classes, slots, v=100.0, arrival=5.0)
    out100 = engine.run(execute_real=False)
    print(f"mean cost {out100['mean_cost']*1e6:.3f} µ$ "
          f"(vs {out['mean_cost']*1e6:.3f} µ$ at V=1) | "
          f"backlog {out100['final_backlog']:.0f} (vs {out['final_backlog']:.0f})")

    print("\nThe cheap/cool pods (Luleå-like) absorb most requests until their")
    print("queues push back — the paper's drift-plus-penalty balance, applied")
    print("to real transformer serving.")


if __name__ == "__main__":
    main()
