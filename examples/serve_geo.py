"""End-to-end driver: GMSA-dispatched LLM serving across a simulated
geo-distributed fleet — the paper's framework doing real work.

Two request classes (two architectures from the assigned pool, smoke-scale),
Poisson request arrivals, four pods with heterogeneous capacity and
price/PUE traces. Every slot:

  1. each class's prefill routes through the placement layer's
     replica-read mix over the drawn dataset layout, and the joint stage
     scheduler places the decode stage (KV handoff billed via the WAN
     model) by drift-plus-penalty;
  2. drained requests execute REAL batched prefill + decode steps;
  3. per-stage queues update by the staged generalization of Eq. (1) —
     the same slot body `simulate_staged` scans, so a dispatch-only run
     replays the simulator bit-for-bit.

A second pass with V=100 shows the cost/backlog trade-off live
(serving energy is kWh-scale, so dispatch is nearly V-insensitive —
the drift term dominates).

    PYTHONPATH=src python examples/serve_geo.py
"""

import numpy as np

from repro.launch.serve import build_engine


def main():
    classes = ["qwen2-0.5b", "granite-3-2b"]
    slots = 16

    print("=== GMSA fleet serving (V=1), real model execution ===")
    engine = build_engine(classes, slots, v=1.0, arrival=5.0)
    out = engine.run(execute_real=True)
    print(f"mean energy cost/slot : {out['mean_cost']*1e6:.3f} µ$ "
          "(full-arch energy pricing, smoke-scale execution)")
    print(f"final backlog         : {out['final_backlog']:.0f} requests")
    print(f"model execution time  : {out['exec_seconds']:.1f}s "
          f"(batched prefill+decode on CPU)")
    print(f"KV-handoff WAN bill   : {out['wan_cost'].sum():.3e} $ "
          f"({out['wan_gb'].sum():.2f} GB)")
    share = out["dispatch"].mean(axis=0).sum(axis=(1, 2))
    print(f"dispatch share per pod: {np.round(share / share.sum(), 3)}")

    # Per-slot timeline straight from the engine's history records —
    # decode pod per class, pod queue depths, served-priced IT Joules.
    print("\nslot timeline (decode pod per class | pod queue depths | J):")
    for h in out["history"]:
        choices = " ".join(
            f"{c}->pod{p}" for c, p in zip(classes, h["choice"])
        )
        depths = " ".join(f"{d:5.1f}" for d in h["q_pod"])
        joules = " ".join(f"{j:6.1f}" for j in h["energy_j"])
        print(f"  t={h['t']:>2}  {choices}  | q [{depths}] | E [{joules}]")

    print("\n=== V=100 (cost-greedy) — dispatch only ===")
    engine = build_engine(classes, slots, v=100.0, arrival=5.0)
    out100 = engine.run(execute_real=False)
    print(f"mean cost {out100['mean_cost']*1e6:.3f} µ$ "
          f"(vs {out['mean_cost']*1e6:.3f} µ$ at V=1) | "
          f"backlog {out100['final_backlog']:.0f} (vs {out['final_backlog']:.0f})")

    print("\nThe cheap/cool pods (Luleå-like) absorb most requests until their")
    print("queues push back — the paper's drift-plus-penalty balance, applied")
    print("to real transformer serving. Per-job energy is kWh-scale, so the")
    print("V sweep barely moves cost: the drift (queueing) term dominates.")


if __name__ == "__main__":
    main()
