"""Two-timescale placement demo: GMSA dispatch x 4-hourly re-placement.

Over the paper's 24 h / 4-DC horizon, new data keeps arriving at ForestCity
(the most expensive power in the fleet). The slow loop re-places datasets
every 4 hours toward cheap, capacity-rich sites — paying for every byte it
moves over the WAN — while GMSA keeps picking managers per 5-min slot.

The second act is the chaos scenario: ForestCity drops dead at noon. The
controller fires an off-schedule recovery epoch on the death edge — wipes
the dead queues and re-injects them as an arrival burst, re-replicates the
lost dataset share over the survivors (billed as ``recovery_cost``), and
keeps dispatching without ever touching the dead site.

    PYTHONPATH=src python examples/adaptive_placement.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import static_placement_rule
from repro.core.gmsa import dispatch_fn
from repro.placement import (
    PlacementConfig,
    make_adaptive_rule,
    simulate_placed,
    simulate_placed_many,
    summarize_placed,
)
from repro.telemetry import (
    TRACE,
    TelemetryConfig,
    collect_records,
    render_timeline,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.drift import dataset_growth_trace, ingest_drift_trace
from repro.traces.faults import scheduled_failure_trace
from repro.traces.price import FACEBOOK_SITES


def main():
    cfg = PaperSimConfig()
    _, build = make_sim_builder(cfg)
    up, down = bandwidth_draw(jax.random.split(jax.random.key(cfg.trace_seed), 6)[2],
                              cfg.n_sites)

    w = 48                                        # 4 h slow-loop period
    n_epochs = cfg.t_slots // w
    ingest = ingest_drift_trace(
        jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites,
        bias=jnp.array([0.05, 0.8, 0.05, 0.10]),  # ForestCity-heavy ingest
        bias_strength=0.5,
    )
    sizes = dataset_growth_trace(n_epochs, cfg.k_types, 100.0, 0.05)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25, capacity_gb=(220.0,) * 4,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    key = jax.random.key(0)
    pol = dispatch_fn(cfg.v)

    print(f"{cfg.t_slots} slots, W = {w} (epochs: {n_epochs}), "
          f"ingest drifting toward ForestCity, 200 Monte-Carlo runs\n")
    print(f"{'arm':<10} {'total $/slot':>13} {'wan $/slot':>11} "
          f"{'sync $/slot':>12} {'GB moved':>9} {'backlog':>8}")
    outs_by_arm = {}
    for name, rule in [
        ("static", static_placement_rule),
        ("adaptive", make_adaptive_rule(up, temp=2.0)),
    ]:
        outs = simulate_placed_many(
            build, up, down, pol, rule, key, 200, pcfg,
            ingest=ingest, sizes_gb=sizes,
        )
        outs_by_arm[name] = outs
        s = summarize_placed(outs)
        print(f"{name:<10} {s['time_avg_total_cost']:>13.1f} "
              f"{s['time_avg_wan_cost']:>11.2f} {s['time_avg_sync_cost']:>12.2f} "
              f"{s['total_wan_gb']:>9.0f} {s['time_avg_backlog']:>8.2f}")

    names = [s.name for s in FACEBOOK_SITES[: cfg.n_sites]]
    print("\ndataset layout per epoch (type 0, run 0, adaptive arm):")
    print("epoch  " + "  ".join(f"{n:>10}" for n in names))
    placements = outs_by_arm["adaptive"].placements[0]     # (E, K, N)
    for e in range(n_epochs):
        row = "  ".join(f"{float(x):>10.2f}" for x in placements[e, 0])
        print(f"{e:>5}  {row}")
    print("\nThe slow loop drains ForestCity as ingest piles up there, and the")
    print("fast loop (GMSA) keeps queues bounded throughout — two timescales,")
    print("one jit-compiled scan-of-scans.")

    # ---- act two: chaos. ForestCity dies at noon, permanently. ----------
    dead_site, t_die = 1, cfg.t_slots // 2
    alive = scheduled_failure_trace(
        cfg.t_slots, cfg.n_sites, [(dead_site, t_die, None)]
    )
    print(f"\n=== site loss: {names[dead_site]} dies at slot {t_die} "
          f"(hour {t_die * 5 // 60}) ===")
    outs = simulate_placed_many(
        build, up, down, pol, make_adaptive_rule(up, temp=2.0), key, 200,
        pcfg, ingest=ingest, sizes_gb=sizes, alive=alive,
    )
    s = summarize_placed(outs)
    f = np.asarray(outs.f_trace)
    print(f"dispatch mass to the dead site after the loss: "
          f"{float(np.abs(f[:, t_die:, dead_site]).max()):.1f}")
    print(f"total cost with recovery: {s['time_avg_total_cost']:.1f} $/slot")

    # The recovery timeline comes straight off the flight recorder: one
    # TRACE-level run, and the death edge (evacuation GB/$ + time-to-SLO),
    # the epoch churn and the ingest redirects are in the event stream —
    # no digging through PlacedOutputs fields.
    tcfg = TelemetryConfig(level=TRACE)
    outs1, frame = simulate_placed(
        build(jax.random.split(key, 2)[0]), up, down, pol,
        make_adaptive_rule(up, temp=2.0), key, pcfg,
        ingest=ingest, sizes_gb=sizes, alive=alive, telemetry=tcfg,
    )
    records = collect_records(
        outs1, frame, cfg=tcfg, summary=summarize_placed(outs1),
    )
    print("\nrecovery timeline (one TRACE run, event codes: recovery/"
          "epoch/ingest_redirect):")
    print(render_timeline(
        records, codes={"recovery", "epoch", "ingest_redirect"},
    ))
    print("\nThe dead site's backlog re-enters as an arrival burst, its data")
    print("re-replicates over the survivors, and GMSA never dispatches to a")
    print("dead DC again — the chaos path of the same compiled controller.")


if __name__ == "__main__":
    main()
