"""Two-timescale placement demo: GMSA dispatch x 4-hourly re-placement.

Over the paper's 24 h / 4-DC horizon, new data keeps arriving at ForestCity
(the most expensive power in the fleet). The slow loop re-places datasets
every 4 hours toward cheap, capacity-rich sites — paying for every byte it
moves over the WAN — while GMSA keeps picking managers per 5-min slot.

    PYTHONPATH=src python examples/adaptive_placement.py
"""

import jax
import jax.numpy as jnp

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import static_placement_rule
from repro.core.gmsa import dispatch_fn
from repro.placement import (
    PlacementConfig,
    make_adaptive_rule,
    simulate_placed_many,
    summarize_placed,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.drift import dataset_growth_trace, ingest_drift_trace
from repro.traces.price import FACEBOOK_SITES


def main():
    cfg = PaperSimConfig()
    _, build = make_sim_builder(cfg)
    up, down = bandwidth_draw(jax.random.split(jax.random.key(cfg.trace_seed), 6)[2],
                              cfg.n_sites)

    w = 48                                        # 4 h slow-loop period
    n_epochs = cfg.t_slots // w
    ingest = ingest_drift_trace(
        jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites,
        bias=jnp.array([0.05, 0.8, 0.05, 0.10]),  # ForestCity-heavy ingest
        bias_strength=0.5,
    )
    sizes = dataset_growth_trace(n_epochs, cfg.k_types, 100.0, 0.05)
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25, capacity_gb=(220.0,) * 4,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    key = jax.random.key(0)
    pol = dispatch_fn(cfg.v)

    print(f"{cfg.t_slots} slots, W = {w} (epochs: {n_epochs}), "
          f"ingest drifting toward ForestCity, 200 Monte-Carlo runs\n")
    print(f"{'arm':<10} {'total $/slot':>13} {'wan $/slot':>11} "
          f"{'sync $/slot':>12} {'GB moved':>9} {'backlog':>8}")
    outs_by_arm = {}
    for name, rule in [
        ("static", static_placement_rule),
        ("adaptive", make_adaptive_rule(up, temp=2.0)),
    ]:
        outs = simulate_placed_many(
            build, up, down, pol, rule, key, 200, pcfg,
            ingest=ingest, sizes_gb=sizes,
        )
        outs_by_arm[name] = outs
        s = summarize_placed(outs)
        print(f"{name:<10} {s['time_avg_total_cost']:>13.1f} "
              f"{s['time_avg_wan_cost']:>11.2f} {s['time_avg_sync_cost']:>12.2f} "
              f"{s['total_wan_gb']:>9.0f} {s['time_avg_backlog']:>8.2f}")

    names = [s.name for s in FACEBOOK_SITES[: cfg.n_sites]]
    print("\ndataset layout per epoch (type 0, run 0, adaptive arm):")
    print("epoch  " + "  ".join(f"{n:>10}" for n in names))
    placements = outs_by_arm["adaptive"].placements[0]     # (E, K, N)
    for e in range(n_epochs):
        row = "  ".join(f"{float(x):>10.2f}" for x in placements[e, 0])
        print(f"{e:>5}  {row}")
    print("\nThe slow loop drains ForestCity as ingest piles up there, and the")
    print("fast loop (GMSA) keeps queues bounded throughout — two timescales,")
    print("one jit-compiled scan-of-scans.")


if __name__ == "__main__":
    main()
