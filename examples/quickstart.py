"""Quickstart: GMSA in 40 lines.

Builds the paper's 4-DC / 1-job-type scenario, runs GMSA against the DATA
and RANDOM baselines for one 24-hour horizon, and prints the cost/backlog
comparison.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs.facebook_4dc import PaperSimConfig, make_sim_builder
from repro.core.baselines import data_dispatch, random_dispatch
from repro.core.gmsa import dispatch_fn
from repro.core.simulator import simulate_many, summarize


def main():
    cfg = PaperSimConfig()
    _, build_inputs = make_sim_builder(cfg)
    key = jax.random.key(0)

    print(f"4 Facebook DCs, lambda = {cfg.lam:.1f} jobs / 5-min slot, "
          f"{cfg.t_slots} slots, 200 Monte-Carlo runs\n")
    print(f"{'policy':<12} {'avg cost $/slot':>16} {'avg backlog':>12}")
    for name, policy, v in [
        ("GMSA V=1", dispatch_fn(1.0), 1.0),
        ("GMSA V=100", dispatch_fn(100.0), 100.0),
        ("DATA", data_dispatch, 0.0),
        ("RANDOM", random_dispatch, 0.0),
    ]:
        outs = simulate_many(build_inputs, policy, key, 200)
        s = summarize(outs)
        print(f"{name:<12} {s['time_avg_cost']:>16.1f} {s['time_avg_backlog']:>12.2f}")

    print("\nGMSA rides the cheap-energy sites while keeping queues bounded;")
    print("the baselines pay ~30-40% more and (DATA/RANDOM) overload slow DCs.")


if __name__ == "__main__":
    main()
