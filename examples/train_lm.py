"""End-to-end training driver with fault tolerance.

Trains a ~1M-param qwen2-family model for 300 steps on the synthetic token
pipeline, checkpoints every 60 steps, injects a failure at step 150, and
shows the run resume bit-identically — the checkpoint/restart path a real
fleet uses, in miniature.

    PYTHONPATH=src python examples/train_lm.py
"""

import shutil
import tempfile

from repro.launch.train import main as train_main


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    try:
        print("=== train 300 steps, checkpoint every 60, failure injected @150 ===")
        first, last = train_main([
            "--arch", "qwen2-0.5b", "--variant", "smoke",
            "--steps", "300", "--batch", "8", "--seq", "128",
            "--ckpt-dir", ckpt, "--ckpt-every", "60",
            "--fail-at", "150", "--log-every", "50",
        ])
        assert last < first - 1.0, "model failed to learn"
        print(f"\nlearned bigram structure through a mid-run failure: "
              f"loss {first:.2f} -> {last:.2f}")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
