"""Stage-structured jobs demo: joint manager selection x stage placement.

Act 1 — the multi-stage Facebook-4DC mix (3 job types, 2-3 stage chains,
30-60 GB of intermediate data per job): stage-aware scheduling prices the
shuffle WAN pull into every stage's drift-plus-penalty score, against the
stage-oblivious baseline that routes every stage to the one manager base
GMSA picks. Same engine, same bills — the aware arm wins on total cost
and WAN GB, trading a small, bounded amount of extra queueing for it
(both arms complete the same work).

Act 2 — composition with the two-timescale placement layer: ingest drifts
the datasets toward ForestCity over the day, the slow loop re-places them
every 4 hours (``simulate_placed``), and the staged engine replays the
evolving layout (time-varying ``data_dist``/``r``) — re-placement
reshapes the map stage's locality and with it the whole chain's shuffle
sources.

    PYTHONPATH=src python examples/staged_jobs.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.facebook_4dc_stages import (
    StagedPaperConfig,
    make_staged_builder,
)
from repro.core.baselines import static_placement_rule
from repro.core.gmsa import dispatch_fn, gmsa_policy
from repro.jobs import (
    make_staged_policy,
    simulate_staged,
    simulate_staged_many,
    stage_oblivious,
    summarize_staged,
)
from repro.placement import (
    PlacementConfig,
    make_adaptive_rule,
    simulate_placed,
    summarize_placed,
)
from repro.traces.drift import ingest_drift_trace
from repro.traces.price import FACEBOOK_SITES

N_RUNS = 100
EPOCH_SLOTS = 48


def act1(cfg, template, dag, wan, build):
    print(f"Act 1 — stage-aware vs stage-oblivious "
          f"({cfg.k_types} types, S<= {dag.s_max} stages, {N_RUNS} runs)\n")
    print(f"{'arm':<11} {'total $/slot':>12} {'wan $/slot':>11} "
          f"{'GB moved':>9} {'backlog':>8} {'completed':>10}")
    key = jax.random.key(0)
    arms = {}
    for name, pol in [
        ("oblivious", stage_oblivious(gmsa_policy, pin_map=True)),
        ("aware", make_staged_policy(dag, wan)),
    ]:
        outs = simulate_staged_many(build, dag, wan, pol, key, N_RUNS,
                                    scalar=cfg.v)
        s = summarize_staged(outs)
        arms[name] = s
        print(f"{name:<11} {s['time_avg_total_cost']:>12.1f} "
              f"{s['time_avg_wan_cost']:>11.1f} {s['total_wan_gb']:>9.0f} "
              f"{s['time_avg_backlog']:>8.2f} {s['jobs_completed']:>10.0f}")
    saving = 1 - arms["aware"]["time_avg_total_cost"] / \
        arms["oblivious"]["time_avg_total_cost"]
    print(f"\nstage-aware saving: {saving:.1%} total cost, "
          f"{arms['oblivious']['total_wan_gb'] - arms['aware']['total_wan_gb']:.0f} "
          f"GB less intermediate WAN traffic\n")


def act2(cfg, template, dag, wan, build):
    print("Act 2 — slow-loop re-placement reshaping map locality")
    print("(ingest drifts toward ForestCity; the placement controller\n"
          " corrects it every 4 h; the staged engine replays the evolving "
          "layout)\n")
    w = EPOCH_SLOTS
    n_epochs = cfg.t_slots // w
    ingest = ingest_drift_trace(
        jax.random.key(7), n_epochs, cfg.k_types, cfg.n_sites,
        bias=jnp.array([0.05, 0.8, 0.05, 0.10]), bias_strength=0.5,
    )
    pcfg = PlacementConfig(
        epoch_slots=w, growth=0.25, dataset_gb=cfg.input_gb,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    pol = dispatch_fn(cfg.v)
    aware = make_staged_policy(dag, wan)
    key = jax.random.key(1)
    names = [s.name for s in FACEBOOK_SITES[: cfg.n_sites]]

    print(f"{'placement':<10} {'staged $/slot':>13} {'shuffle $':>10} "
          f"{'move $':>7} {'backlog':>8}")
    for arm, rule in [
        ("static", static_placement_rule),
        ("adaptive", make_adaptive_rule(wan.up)),
    ]:
        placed = simulate_placed(
            template, wan.up, wan.down, pol, rule, key, pcfg, ingest=ingest
        )
        sp = summarize_placed(placed)
        # Replay the evolving layout through the staged engine: the
        # per-epoch placements/ratios become time-varying inputs.
        staged_inputs = template._replace(
            data_dist=jnp.repeat(placed.placements, w, axis=0),
            r=jnp.repeat(placed.r_trace, w, axis=0),
        )
        outs = simulate_staged(staged_inputs, dag, wan, aware, key,
                               scalar=cfg.v)
        s = summarize_staged(outs)
        print(f"{arm:<10} {s['time_avg_total_cost']:>13.1f} "
              f"{s['time_avg_wan_cost']:>10.1f} "
              f"{sp['time_avg_wan_cost']:>7.2f} "
              f"{s['time_avg_backlog']:>8.2f}")
        if arm == "adaptive":
            print("\nmap-stage locality per epoch (type 0, adaptive arm):")
            print("epoch  " + "  ".join(f"{n:>10}" for n in names))
            for e in range(n_epochs):
                row = np.asarray(placed.placements[e, 0])
                print(f"{e:>5}  " + "  ".join(f"{x:>10.2f}" for x in row))


def main():
    cfg = StagedPaperConfig()
    template, dag, wan, build = make_staged_builder(cfg)
    with np.printoptions(precision=2, suppress=True):
        print(f"{cfg.t_slots} slots x {cfg.n_sites} DCs; stage chains:\n"
              f"  compute =\n{np.asarray(dag.compute)}\n"
              f"  shuffle GB =\n{np.asarray(dag.shuffle_gb)}\n")
    act1(cfg, template, dag, wan, build)
    act2(cfg, template, dag, wan, build)


if __name__ == "__main__":
    main()
