"""Faithful reproduction of the paper's evaluation (Figs. 5 & 6).

Runs the full methodology — 4 Facebook DCs, Poisson arrivals at 350K
jobs/month, price/PUE traces, Iridium task ratios, 288 five-minute slots,
Monte-Carlo averaging — and prints the claim-by-claim comparison against
the numbers reported in the paper.

    PYTHONPATH=src python examples/paper_repro.py [--runs 1000]
"""

import argparse

from benchmarks import fig5, fig6


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=1000)
    args = ap.parse_args()

    print("=== Fig. 5: performance along time (24 h) ===")
    out5 = fig5.run(args.runs)
    c5 = out5["checks"]
    print(f"GMSA cheapest in {100*c5['frac_slots_gmsa_cheapest']:.0f}% of slots "
          "(paper: 'almost all time slots')")
    print(f"GMSA(V=1) max avg backlog {c5['gmsa_v1_max_avg_backlog']:.1f} "
          "(paper: 'below 50 when V=1')")
    print(f"backlog slope  DATA {c5['slope_data']:+.3f}/slot, "
          f"RANDOM {c5['slope_random']:+.3f}/slot, "
          f"GMSA(V=1) {c5['slope_gmsa_v1']:+.4f}/slot "
          "(paper: baselines 'increase dramatically', GMSA stable)")

    print("\n=== Fig. 6: sensitivity to V ===")
    out6 = fig6.run(args.runs)
    c6 = out6["checks"]
    print(f"{'V':>8} {'cost $':>8} {'backlog':>8}")
    for v in out6["v_grid"]:
        row = out6["gmsa"][v]
        print(f"{v:>8} {row['cost']:>8.1f} {row['backlog']:>8.2f}")
    print(f"baselines ≈ {c6['baseline_cost']:.0f} $ "
          "(paper: 'approximately 750 dollars')")
    print(f"GMSA best {c6['best_gmsa_cost']:.0f} $ (paper: 'as low as 540')")
    print(f"reduction {100*c6['reduction_at_v100']:.1f}% (paper: '30% approximately')")
    print("cost monotone ↓ in V:", c6["cost_monotone_nonincreasing"],
          "| backlog monotone ↑ in V:", c6["backlog_monotone_nondecreasing"])


if __name__ == "__main__":
    main()
