"""Serving launcher: the simulation-dispatched fleet engine on real models.

  PYTHONPATH=src python -m repro.launch.serve --slots 24 --v 1.0 \
      [--classes qwen2-0.5b,granite-3-2b] [--no-exec] [--pods 8] \
      [--admit-max 6] [--kill "2:12"] [--dispatch kernel]

Each request class is an architecture (smoke variant on this container)
modeled as a 2-stage prefill→decode chain; prefill routes through the
placement layer's replica-read assignment over a drawn dataset layout,
every slot dispatches through the joint stage scheduler (or the Pallas
kernel path with ``--dispatch kernel``), and drained jobs actually
execute prefill+decode. ``--kill pod:slot`` injects a pod death — the
recovery drain shows up in the history/telemetry stream.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.iridium import build_task_allocation
from repro.serve.engine import FleetConfig, FleetEngine, RequestClass
from repro.telemetry.config import TelemetryConfig
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.datasets import dataset_distribution
from repro.traces.price import FACEBOOK_SITES, price_trace
from repro.traces.pue import pue_trace


def build_engine(classes: list[str], slots: int, v: float, seed: int = 0,
                 arrival: float = 6.0, n_pods: int = 4,
                 admit_max: float | None = None, dispatch: str = "staged",
                 alive: np.ndarray | None = None,
                 telemetry: TelemetryConfig | None = None,
                 health: np.ndarray | None = None,
                 link_health: np.ndarray | None = None,
                 hedge: float | None = None) -> FleetEngine:
    key = jax.random.key(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # Pods beyond the four Facebook DCs reuse their site climates (cycled).
    sites = tuple(FACEBOOK_SITES[i % len(FACEBOOK_SITES)]
                  for i in range(n_pods))
    omega = np.asarray(price_trace(k1, slots, 5.0, sites))
    pue = np.asarray(pue_trace(k2, slots, 5.0, sites))
    rcs = [
        RequestClass(name=a, cfg=get_arch(a, "smoke"),
                     energy_cfg=get_arch(a, "full"), arrival_rate=arrival)
        for a in classes
    ]
    # The dataset layout doubles as the KV-prefix placement the replica-
    # read router serves prefill from; the same draw feeds the task-
    # allocation ratios, so dispatch pricing and routing share one world.
    layout = dataset_distribution(k3, len(rcs), n_pods)
    up, down = bandwidth_draw(k4, n_pods)
    r = np.asarray(build_task_allocation(layout, up, down, manager_share=0.62))
    fcfg = FleetConfig(
        n_pods=n_pods, horizon_slots=slots, v=v, seed=seed,
        admit_max=admit_max, dispatch=dispatch, hedge_threshold=hedge,
    )
    return FleetEngine(
        fcfg, rcs, omega, pue, r,
        up=up, down=down, layout=layout, alive=alive, telemetry=telemetry,
        health=health, link_health=link_health,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", default="qwen2-0.5b,granite-3-2b")
    ap.add_argument("--slots", type=int, default=24)
    ap.add_argument("--v", type=float, default=1.0)
    ap.add_argument("--arrival", type=float, default=6.0)
    ap.add_argument("--pods", type=int, default=4)
    ap.add_argument("--admit-max", type=float, default=None,
                    help="per-class per-slot admission cap (default: admit all)")
    ap.add_argument("--dispatch", choices=["staged", "kernel"],
                    default="staged")
    ap.add_argument("--kill", default=None, metavar="POD:SLOT",
                    help="kill pod POD at slot SLOT (recovery drain demo)")
    ap.add_argument("--straggle", default=None, metavar="POD:SLOT:FACTOR",
                    help="degrade pod POD to FACTOR of its service rate "
                         "from slot SLOT on (straggler demo)")
    ap.add_argument("--hedge", type=float, default=None,
                    help="speculative re-execution threshold (clone a "
                         "stage when its pod's rate falls below this "
                         "fraction of the runner-up's)")
    ap.add_argument("--no-exec", action="store_true",
                    help="skip real model execution (dispatch-only)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    alive = None
    if args.kill:
        pod, slot = (int(x) for x in args.kill.split(":"))
        alive = np.ones((args.slots, args.pods), np.float32)
        alive[slot:, pod] = 0.0
    health = None
    if args.straggle:
        pod, slot, factor = args.straggle.split(":")
        health = np.ones((args.slots, args.pods), np.float32)
        health[int(slot):, int(pod)] = float(factor)

    engine = build_engine(
        args.classes.split(","), args.slots, args.v, args.seed, args.arrival,
        n_pods=args.pods, admit_max=args.admit_max, dispatch=args.dispatch,
        alive=alive, health=health, hedge=args.hedge,
    )
    out = engine.run(execute_real=not args.no_exec)
    print(f"slots={args.slots} classes={args.classes} pods={args.pods} "
          f"dispatch={args.dispatch}")
    print(f"mean slot cost      : {out['mean_cost']:.3e} $ "
          f"({out['mean_cost']*1e6:.3f} µ$)")
    print(f"KV-handoff WAN bill : {out['wan_cost'].sum():.3e} $ "
          f"({out['wan_gb'].sum():.2f} GB)")
    if args.hedge is not None:
        print(f"hedge bill          : {out['hedge_cost'].sum():.3e} $ "
              f"({out['hedged_jobs'].sum():.2f} jobs re-executed)")
    print(f"total billed        : {out['total_billed_cost']:.3e} $")
    print(f"final total backlog : {out['final_backlog']:.1f}")
    print(f"admitted/rejected   : {out['admitted'].sum():.0f} / "
          f"{out['rejected'].sum():.0f}")
    print(f"SLO violation frac  : {np.round(out['slo_viol_frac'], 3)}")
    print(f"model-exec seconds  : {out['exec_seconds']:.1f} "
          f"({out['exec_jobs']} jobs)")
    share = out["dispatch"].mean(axis=0).sum(axis=(1, 2))
    print("dispatch share/pod  :", np.round(share / share.sum(), 3))
    for ev in out["events"]:
        print(f"recovery event      : pod {ev['pod']} died at t={ev['t']}, "
              f"drained {ev['drained']:.1f} jobs")
    return out


if __name__ == "__main__":
    main()
