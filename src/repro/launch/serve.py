"""Serving launcher: the GMSA-dispatched fleet engine on real (small) models.

  PYTHONPATH=src python -m repro.launch.serve --slots 24 --v 1.0 \
      [--classes qwen2-0.5b,granite-3-2b] [--no-exec]

Each request class is an architecture (smoke variant on this container);
dispatch decisions per slot come from repro.core.gmsa against per-pod
price/PUE traces; drained jobs actually execute prefill+decode.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.iridium import build_task_allocation
from repro.serve.engine import FleetConfig, FleetEngine, RequestClass
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.datasets import dataset_distribution
from repro.traces.price import FACEBOOK_SITES, price_trace
from repro.traces.pue import pue_trace


def build_engine(classes: list[str], slots: int, v: float, seed: int = 0,
                 arrival: float = 6.0) -> FleetEngine:
    n_pods = 4
    key = jax.random.key(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    omega = np.asarray(price_trace(k1, slots, 5.0, FACEBOOK_SITES))
    pue = np.asarray(pue_trace(k2, slots, 5.0, FACEBOOK_SITES))
    rcs = [
        RequestClass(name=a, cfg=get_arch(a, "smoke"),
                     energy_cfg=get_arch(a, "full"), arrival_rate=arrival)
        for a in classes
    ]
    dd = dataset_distribution(k3, len(rcs), n_pods)
    up, down = bandwidth_draw(k4, n_pods)
    r = np.asarray(build_task_allocation(dd, up, down, manager_share=0.62))
    return FleetEngine(
        FleetConfig(n_pods=n_pods, horizon_slots=slots, v=v, seed=seed),
        rcs, omega, pue, r,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", default="qwen2-0.5b,granite-3-2b")
    ap.add_argument("--slots", type=int, default=24)
    ap.add_argument("--v", type=float, default=1.0)
    ap.add_argument("--arrival", type=float, default=6.0)
    ap.add_argument("--no-exec", action="store_true",
                    help="skip real model execution (dispatch-only)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    engine = build_engine(
        args.classes.split(","), args.slots, args.v, args.seed, args.arrival
    )
    out = engine.run(execute_real=not args.no_exec)
    print(f"slots={args.slots} classes={args.classes}")
    print(f"mean slot cost      : {out['mean_cost']:.3e} $ "
          f"({out['mean_cost']*1e6:.3f} µ$)")
    print(f"final total backlog : {out['final_backlog']:.1f}")
    print(f"model-exec seconds  : {out['exec_seconds']:.1f}")
    share = out["dispatch"].mean(axis=0).sum(axis=1)
    print("dispatch share/pod  :", np.round(share / share.sum(), 3))
    return out


if __name__ == "__main__":
    main()
