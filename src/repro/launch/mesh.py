"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization while tests/benches must keep seeing 1 device.

Topology (TPU v5e class):
  * single-pod:  (16, 16)    axes ("data", "model")  — 256 chips
  * multi-pod:   (2, 16, 16) axes ("pod", "data", "model") — 512 chips;
    the "pod" axis is the slow WAN/DCN tier (the paper's core network).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests / subprocess checks)."""
    n = n_devices or len(jax.devices())
    if multi_pod:
        assert n % 2 == 0 and n >= 4
        return jax.make_mesh((2, n // 4, 2), ("pod", "data", "model"))
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    return jax.make_mesh((n // 2, 2), ("data", "model"))
