"""Roofline-term derivation from compiled dry-run artifacts (DESIGN.md §7).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI per chip.

    compute    = HLO_FLOPs       / (chips × peak)
    memory     = HLO_bytes       / (chips × hbm_bw)
    collective = collective_bytes/ (chips × link_bw)

``collective_bytes`` is not in ``cost_analysis()`` — we parse the compiled
HLO text and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (fusion-safe: collective
ops are never fused on the XLA:CPU/SPMD pipeline used for the dry-run).
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every tensor literal in an HLO result type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the compiled HLO."""
    by_kind: dict[str, float] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result lines look like:  %name = TYPE kind(...), ...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\/ ]+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        kind = next((c for c in _COLLECTIVES if op == c or op.startswith(c + ".")), None)
        if kind is None:
            continue
        nbytes = _shape_bytes(m.group(1))
        by_kind[kind] = by_kind.get(kind, 0.0) + nbytes
        total += nbytes
    return {"total": total, "by_kind": by_kind}


def roofline_terms(
    flops: float, bytes_accessed: float, collective_bytes: float, chips: int,
    peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW, ici_bw: float = ICI_BW,
) -> dict:
    """Three roofline terms in seconds + the dominant bottleneck.

    NOTE on units (verified empirically, see EXPERIMENTS.md §Dry-run): after
    SPMD partitioning ``compiled.cost_analysis()`` reports PER-DEVICE
    flops/bytes — the compiled module *is* the per-device program. The
    assignment's ``HLO_FLOPs / (chips × peak)`` with whole-program FLOPs is
    therefore exactly ``flops_per_device / peak`` here; ``chips`` is kept in
    the signature for the record but not divided again. Collective bytes are
    parsed from the same per-device module.
    """
    del chips  # per-device inputs already; see docstring
    compute_s = flops / peak_flops if flops > 0 else 0.0
    memory_s = bytes_accessed / hbm_bw if bytes_accessed > 0 else 0.0
    collective_s = collective_bytes / ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    return {
        **terms,
        "dominant": dominant,
        "step_time_s": step_s,
        "roofline_fraction": compute_s / step_s if step_s > 0 else None,
    }
