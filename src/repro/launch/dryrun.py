import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import (jax locks device
# count on first init). Placeholder host devices exist ONLY for the dry-run.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each runnable cell this driver

  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the train/prefill/decode step for the architecture,
  3. ``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``
     — no parameter or activation allocation anywhere,
  4. ``.compile()`` — proving the sharding config is coherent end-to-end,
  5. records ``memory_analysis()`` (fits/doesn't-fit), ``cost_analysis()``
     (FLOPs / bytes for §Roofline) and the collective-bytes tally parsed
     from the compiled HLO,
  6. writes one JSON artifact per cell under benchmarks/artifacts/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--variant baseline] [--force]

Structurally-inapplicable cells (encoder decode, full-attention 500k) are
recorded as skipped-with-reason, per DESIGN.md §4.
"""

import argparse
import dataclasses
import json
import math
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.configs.base import SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes_from_hlo, roofline_terms
from repro.models.inputs import cache_spec, make_batch, make_decode_tokens
from repro.models.lm import init_cache, init_params
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.optimizer import adamw_init
from repro.train.step import TrainStepConfig, make_train_step

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _param_structs(cfg, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg, dtype))


def _lower_one(cfg, shape, mesh, tcfg: TrainStepConfig, unroll: bool, attn: str,
               kv_shard: str = "heads", kv_dtype=jnp.bfloat16):
    """Lower+compile one step variant; returns the compiled artifact."""
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            tc = dataclasses.replace(tcfg, unroll_layers=unroll, attn_impl=attn)
            step, _, _, shardings_for, init_efb = make_train_step(cfg, mesh, tc)
            params = _param_structs(cfg)
            opt = jax.eval_shape(adamw_init, params)
            batch = make_batch(cfg, shape, as_spec=True)
            efb = jax.eval_shape(init_efb, params)
            in_sh, out_sh = shardings_for(batch, shape.global_batch)
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(
                _sds(params), _sds(opt), batch, _sds(efb)
            )
        elif shape.kind == "prefill":
            fn, _, shardings_for = make_prefill_step(
                cfg, mesh, attn, unroll_layers=unroll
            )
            params = _param_structs(cfg)
            batch = make_batch(cfg, shape, as_spec=True)
            psh, bsh = shardings_for(batch, shape.global_batch)
            lowered = jax.jit(
                lambda p, b: fn(p, **b), in_shardings=(psh, bsh)
            ).lower(_sds(params), batch)
        else:  # decode
            fn, _, shardings_for = make_decode_step(
                cfg, mesh, unroll_layers=unroll, kv_shard=kv_shard
            )
            params = _param_structs(cfg)
            cache = cache_spec(cfg, shape, dtype=kv_dtype)
            toks = make_decode_tokens(cfg, shape, as_spec=True)
            in_sh, out_sh = shardings_for(cache, shape.global_batch)
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(
                _sds(params), cache, toks
            )
        return lowered.compile()


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total"],
        "coll_by_kind": coll["by_kind"],
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool, tcfg: TrainStepConfig,
               pad_heads: int = 0, kv_shard: str = "heads", kv_dtype=jnp.bfloat16):
    """Build + lower + compile one cell; returns the result record.

    Three compiles (DESIGN.md §7 measurement protocol):
      A. production program (blockwise attention, scan-over-layers, full L):
         the compile PROOF + memory_analysis. Its cost_analysis is recorded
         but NOT used for roofline — XLA's HloCostAnalysis counts while-loop
         bodies once, so scanned/blocked programs undercount.
      B./C. cost-extraction programs: L=1 / L=2, layers UNROLLED, naive
         attention (loop-free => exact counts; naive and blockwise compute
         identical attention FLOPs). Whole-step cost extrapolates as
         B + (L-1)·(C-B); collectives likewise (TP collectives live in the
         layer body; data-parallel grad all-reduce over stacked (L,...)
         params scales linearly and is captured by the same marginal).
    """
    cfg = get_arch(arch)
    if pad_heads and cfg.has_attention and cfg.num_heads < pad_heads:
        # Deployment head-padding (§Perf C1): extra zero-init heads make the
        # q projection shardable on the model axis; arch-equivalent at init.
        cfg = dataclasses.replace(
            cfg, num_heads=pad_heads, head_dim=cfg.resolved_head_dim
        )
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)

    t0 = time.time()
    compiled_full = _lower_one(cfg, shape, mesh, tcfg, unroll=False,
                               attn=tcfg.attn_impl, kv_shard=kv_shard,
                               kv_dtype=kv_dtype)
    compile_s = time.time() - t0
    mem = compiled_full.memory_analysis()
    full_cost = _cost_of(compiled_full)

    cfg1 = dataclasses.replace(cfg, num_layers=1)
    cfg2 = dataclasses.replace(cfg, num_layers=2)
    # Swap the registry cfg without re-registering: lower directly.
    c1 = _cost_of(_lower_one(cfg1, shape, mesh, tcfg, unroll=True, attn="naive", kv_shard=kv_shard, kv_dtype=kv_dtype))
    c2 = _cost_of(_lower_one(cfg2, shape, mesh, tcfg, unroll=True, attn="naive", kv_shard=kv_shard, kv_dtype=kv_dtype))
    ell = cfg.num_layers

    def extrap(key):
        return c1[key] + (ell - 1) * (c2[key] - c1[key])

    # Flash-floor memory bytes: the same L1/L2 extrapolation on the BLOCKWISE
    # program. Its inner KV-chunk loop is counted once by HloCostAnalysis,
    # which here is exactly what we want: score tiles held in VMEM never hit
    # HBM on the TPU target, so the undercounted bytes approximate the fused-
    # attention HBM traffic (Q/K/V/O flows). Naive bytes remain the upper
    # bound. Decode steps have no attention loops — both programs coincide.
    if shape.kind in ("train", "prefill") and cfg.has_attention:
        b1 = _cost_of(_lower_one(cfg1, shape, mesh, tcfg, unroll=True, attn="blockwise"))
        b2 = _cost_of(_lower_one(cfg2, shape, mesh, tcfg, unroll=True, attn="blockwise"))
        bytes_flash = b1["bytes"] + (ell - 1) * (b2["bytes"] - b1["bytes"])
    else:
        bytes_flash = None

    coll_by_kind = {
        k: c1["coll_by_kind"].get(k, 0.0)
        + (ell - 1) * (c2["coll_by_kind"].get(k, 0.0) - c1["coll_by_kind"].get(k, 0.0))
        for k in set(c1["coll_by_kind"]) | set(c2["coll_by_kind"])
    }

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "variant": tcfg_signature(tcfg, shape.kind),
        "compile_seconds": round(compile_s, 1),
        "flops": extrap("flops"),
        "bytes_accessed": extrap("bytes"),
        "bytes_accessed_flash": bytes_flash if bytes_flash is not None else extrap("bytes"),
        "collective_bytes": extrap("coll"),
        "collective_breakdown": coll_by_kind,
        "production_program_raw_cost": full_cost,   # loop-bodies-once numbers
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
        },
        "model_flops_6nd": model_flops(cfg, shape_name),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    record["roofline"] = roofline_terms(
        flops=record["flops"],
        bytes_accessed=record["bytes_accessed_flash"],
        collective_bytes=record["collective_bytes"],
        chips=n_chips,
    )
    record["roofline"]["memory_s_naive_upper"] = (
        record["bytes_accessed"] / 819e9
    )
    record["roofline"]["useful_flops_ratio"] = (
        record["model_flops_6nd"] / (record["flops"] * n_chips)
        if record["flops"] > 0 else None
    )
    return record


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (train: ×1 fwd+bwd already in 6;
    decode: per-step tokens = batch)."""
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens_per_step
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens_per_step
    return 2.0 * n_active * shape.global_batch


def tcfg_signature(tcfg: TrainStepConfig, kind: str) -> str:
    if kind != "train":
        return f"{kind}:attn={tcfg.attn_impl}"
    return (
        f"train:mb={tcfg.microbatches},remat={tcfg.remat},"
        f"attn={tcfg.attn_impl},sync={tcfg.grad_sync}"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="dots", choices=["none", "dots", "full"])
    ap.add_argument("--attn", default="blockwise", choices=["blockwise", "naive"])
    ap.add_argument("--grad-sync", default="native", choices=["native", "int8"])
    ap.add_argument("--pad-heads", type=int, default=0)
    ap.add_argument("--kv-shard", default="auto", choices=["auto", "heads", "seq"])
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "fp8"])
    ap.add_argument("--variant", default="baseline",
                    help="artifact filename tag for §Perf iterations")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    tcfg = TrainStepConfig(
        microbatches=args.microbatches, remat=args.remat,
        attn_impl=args.attn, grad_sync=args.grad_sync,
    )
    ART_DIR.mkdir(parents=True, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        cfg = get_arch(arch)
        for shape_name in shapes:
            ok, reason = shape_applicable(cfg, SHAPES[shape_name])
            for multi in meshes:
                mesh_tag = "2x16x16" if multi else "16x16"
                out = ART_DIR / f"{arch}__{shape_name}__{mesh_tag}__{args.variant}.json"
                if out.exists() and not args.force:
                    print(f"[cached] {out.name}")
                    n_ok += 1
                    continue
                if not ok:
                    out.write_text(json.dumps(
                        {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                         "skipped": reason}, indent=1))
                    print(f"[skip]   {arch} × {shape_name} × {mesh_tag}: {reason}")
                    n_skip += 1
                    continue
                try:
                    t0 = time.time()
                    rec = lower_cell(arch, shape_name, multi, tcfg,
                                     pad_heads=args.pad_heads, kv_shard=args.kv_shard,
                                     kv_dtype=jnp.float8_e4m3fn if args.kv_dtype == "fp8" else jnp.bfloat16)
                    out.write_text(json.dumps(rec, indent=1))
                    print(
                        f"[ok]     {arch} × {shape_name} × {mesh_tag}: "
                        f"compile={rec['compile_seconds']}s "
                        f"flops={rec['flops']:.3e} coll={rec['collective_bytes']:.3e} "
                        f"(total {time.time()-t0:.0f}s)", flush=True,
                    )
                    n_ok += 1
                except Exception as e:  # noqa: BLE001 — record and continue
                    out.with_suffix(".FAILED.json").write_text(json.dumps(
                        {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                         "error": str(e), "trace": traceback.format_exc()}, indent=1))
                    print(f"[FAIL]   {arch} × {shape_name} × {mesh_tag}: {e}", flush=True)
                    n_fail += 1
    print(f"dry-run done: ok={n_ok} skip={n_skip} fail={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
