"""Training launcher.

CPU-scale end-to-end driver (the production path in miniature): synthetic
token pipeline -> sharded train step -> AdamW -> checkpoint/restart, with
optional failure injection to exercise the fault path.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --variant smoke --steps 200 --batch 8 --seq 128 \
      [--ckpt-dir /tmp/ckpt] [--fail-at 120] [--grad-sync int8]

On a real fleet the same module runs under the production mesh
(repro.launch.mesh.make_production_mesh); here it uses whatever devices
exist (1 on this container).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, FailureInjector, run_with_restarts
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_debug_mesh
from repro.models import init_params
from repro.models.inputs import make_batch
from repro.traces.tokens import SyntheticTokenStream, TokenPipelineConfig, lm_inputs
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import TrainStepConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-sync", default="native")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, args.variant)
    mesh = make_debug_mesh()
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    tcfg = TrainStepConfig(
        microbatches=args.microbatches, remat=args.remat,
        grad_sync=args.grad_sync,
        optimizer=AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 5)),
    )
    step_fn, pspecs, opt_specs, shardings_for, init_efb = make_train_step(cfg, mesh, tcfg)

    pipe = SyntheticTokenStream(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq + 1,
        global_batch=args.batch, seed=args.seed,
    ))

    example_batch = make_batch(cfg, shape, jax.random.key(0), embed_dtype=jnp.float32)
    in_sh, out_sh = shardings_for(example_batch, args.batch)
    jit_step = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)

    def batch_for(step: int) -> dict:
        if cfg.frontend:
            # modality stubs: deterministic synthetic embeddings per step
            return make_batch(cfg, shape, jax.random.key(step), embed_dtype=jnp.float32)
        raw = lm_inputs(pipe.batch(step))
        return {k: jnp.asarray(v) for k, v in raw.items()}

    def init_state():
        with jax.set_mesh(mesh):
            params = jax.device_put(
                init_params(jax.random.key(args.seed + 1), cfg, jnp.float32), in_sh[0]
            )
            return {
                "params": params,
                "opt": jax.device_put(adamw_init(params), in_sh[1]),
                "efb": jax.device_put(init_efb(params), in_sh[3]),
            }

    losses = []
    t_start = time.time()

    def one_step(state, step):
        batch = jax.device_put(batch_for(step), in_sh[2])
        with jax.set_mesh(mesh):
            params, opt, metrics, efb = jit_step(
                state["params"], state["opt"], batch, state["efb"]
            )
        loss = float(metrics["loss"])
        losses.append((step, loss))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        return {"params": params, "opt": opt, "efb": efb}

    if args.ckpt_dir:
        manager = CheckpointManager(args.ckpt_dir, save_interval=args.ckpt_every)
        injector = FailureInjector(
            fail_at_steps=(args.fail_at,) if args.fail_at >= 0 else ()
        )
        state, stats = run_with_restarts(
            init_state, one_step, manager, args.steps, injector
        )
        print(f"done in {time.time()-t_start:.1f}s; restarts={stats['restarts']} "
              f"replayed={stats['replayed_steps']} ckpts={stats['checkpoints']}")
    else:
        state = init_state()
        for s in range(args.steps):
            state = one_step(state, s)
        print(f"done in {time.time()-t_start:.1f}s")

    first = np.mean([l for _, l in losses[:10]])
    last = np.mean([l for _, l in losses[-10:]])
    print(f"loss first10={first:.4f} last10={last:.4f} delta={first-last:+.4f}")
    return first, last


if __name__ == "__main__":
    main()
