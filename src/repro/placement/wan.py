"""WAN topology + inter-site transfer energy/latency model.

Bulk data movement between sites rides the same core network as the shuffle
traffic the Iridium layer reasons about: site i's uplink feeds the core,
site j's downlink drains it, so the effective i->j rate is the harmonic
combination 1/(1/U_i + 1/D_j). Moving bytes is not free energy-wise either —
routers/transponders burn a roughly linear energy-per-byte, and that energy
is drawn at the two endpoint DCs (at their PUE and price). The slow-timescale
placement controller charges every re-placement decision through this model,
so "chase the cheap site" is only worth it when the expected dispatch-cost
savings beat the migration bill.

Units follow the simulator's calibration (see :mod:`repro.traces.price`):
``omega`` is $/MWh, per-job IT energy is 1 MWh-equivalent, so
``energy_per_gb`` is expressed in *job-energy equivalents per GB* — the
default 0.01 means shipping 100 GB costs the energy of one analytics job.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import Array

#: WAN transfer energy per GB moved, in per-job IT-energy equivalents.
#: Calibrated so a full 100 GB dataset migration costs ~1 job's energy.
DEFAULT_ENERGY_PER_GB = 0.01


class WanModel(NamedTuple):
    """Static WAN description used by the placement controller.

    Attributes:
        up: (N,) uplink bandwidths, Gb/s.
        down: (N,) downlink bandwidths, Gb/s.
        link_bw: (N, N) effective site-to-site bulk rate, Gb/s
            (``inf`` on the diagonal — local "moves" are free).
        energy_per_gb: scalar WAN energy per GB, job-energy equivalents.
    """

    up: Array
    down: Array
    link_bw: Array
    energy_per_gb: Array


def wan_topology(
    up: Array, down: Array, energy_per_gb: float = DEFAULT_ENERGY_PER_GB
) -> WanModel:
    """Build the (N, N) core-routed link model from per-site access rates."""
    up = jnp.asarray(up, jnp.float32)
    down = jnp.asarray(down, jnp.float32)
    n = up.shape[0]
    bw = 1.0 / (1.0 / up[:, None] + 1.0 / down[None, :])        # (N, N)
    bw = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, bw)
    return WanModel(up, down, bw, jnp.asarray(energy_per_gb, jnp.float32))


def link_price_matrix(
    per_site: Array, local_free: bool = True, link_health: Array | None = None
) -> Array:
    """(N, N) endpoint-mean link weights: 0.5 * (w_i + w_j) for i -> j.

    The single definition of "a byte on link i->j draws its energy half
    at each endpoint" — shared by :func:`transfer_cost`, replica
    selection (:mod:`repro.placement.replica`) and the stage scheduler's
    shuffle pricing (:mod:`repro.jobs.scheduler`), so their $-per-GB
    semantics cannot drift apart. ``per_site`` is whatever per-site
    weight is being averaged (omega*PUE for prices, PUE for energy).
    ``local_free`` zeroes the diagonal (intra-site hand-offs are free) —
    what every consumer scoring *candidate* destinations wants; plan
    pricing may keep it, since transfer plans carry zero diagonals.

    ``link_health`` (optional (N, N) factor in [0, 1]) surcharges
    degraded links by the reciprocal of their health — a link at 50%
    capacity retransmits/reroutes into double the per-byte bill — and
    prices severed links (health 0) to ``inf`` so any plan that insists
    on crossing a partition bills loudly rather than silently. Note the
    rank-2 structure the fused ``plan_cost`` path exploits does NOT
    survive an arbitrary health matrix; degraded pricing is for the
    materialized (epoch-boundary / post-scan) paths only.
    """
    n = per_site.shape[0]
    price = 0.5 * (per_site[:, None] + per_site[None, :])
    if local_free:
        price = jnp.where(jnp.eye(n, dtype=bool), 0.0, price)
    if link_health is not None:
        health = jnp.asarray(link_health, price.dtype)
        price = jnp.where(health > 0.0, price / jnp.maximum(health, 1e-9),
                          jnp.inf)
    return price


def transfer_plan(d_old: Array, d_new: Array, sizes_gb: Array) -> Array:
    """(K, N, N) GB moved on each link to morph ``d_old`` into ``d_new``.

    Surplus sites (placement fraction shrinks) export, deficit sites import;
    the coupling routes each exporter's bytes to the importers proportionally
    to their deficits — the product coupling of the two marginals, which is
    exact on total bytes and jit-safe (no sorting / matching).

    Args:
        d_old: (..., K, N) current placement (rows on the simplex).
        d_new: (..., K, N) target placement.
        sizes_gb: (..., K) dataset sizes in GB.

    Returns:
        (..., K, N, N) plan with plan[..., k, i, j] GB moving i -> j;
        zero diagonal. Leading batch dims broadcast like
        :func:`plan_cost` (e.g. a (T, K, N) trace of placements prices
        every slot's plan in one call).
    """
    delta = d_new - d_old                                        # (..., K, N)
    out_gb = jnp.maximum(-delta, 0.0) * sizes_gb[..., None]      # exports
    in_gb = jnp.maximum(delta, 0.0) * sizes_gb[..., None]        # imports
    total = jnp.sum(in_gb, axis=-1, keepdims=True)               # (..., K, 1)
    share = in_gb / jnp.maximum(total, 1e-12)                    # (..., K, N)
    return out_gb[..., :, None] * share[..., None, :]            # (..., K, N, N)


def evacuation_plan(
    d_masked: Array,
    d_drop: Array,
    sizes_gb: Array,
    link_health: Array | None = None,
) -> Array:
    """(K, N, N) emergency re-replication traffic after a site loss.

    When sites die, the surviving replicas re-share the dataset
    (``d_drop``, rows on the simplex) but each survivor only *holds*
    ``d_masked`` (rows sum to the surviving fraction) — the gap
    ``(d_drop - d_masked) * sizes_gb`` must be shipped to every growing
    survivor, sourced from the sites that still hold a copy,
    proportionally to their holdings and never from the receiver itself.
    A dataset whose replicas were all lost (``d_masked`` row ~ 0) is
    restored from the target layout's own source mix (restore-from-backup:
    the full dataset crosses the WAN). Zero diagonal, so the result can be
    summed with :func:`transfer_plan` output and priced by
    :func:`transfer_cost` / :func:`transfer_latency` as one burst.

    Args:
        d_masked: (K, N) surviving holdings (``drop_site_mask``'s second
            output — dead columns zeroed, NOT renormalized).
        d_drop: (K, N) survivor layout after renormalization (rows sum 1).
        sizes_gb: (K,) dataset sizes in GB.
        link_health: optional (N, N) link factor — severed links
            (health 0) are excluded as sources, so the plan routes the
            re-replication traffic *around* the partition. Destinations
            whose every usable source link is severed fall back to the
            fault-oblivious weights (the bytes still flow, conserving
            GB, and :func:`transfer_cost` with the same ``link_health``
            prices them to ``inf`` — a partition you cannot route
            around is loud, not lossy).

    Returns:
        (K, N, N) plan with plan[k, i, j] GB moving i -> j.
    """
    n = d_masked.shape[1]
    need = jnp.maximum(d_drop - d_masked, 0.0) * sizes_gb[:, None]   # (K, N)
    lost_all = jnp.sum(d_masked, axis=1, keepdims=True) <= 1e-9
    src = jnp.where(lost_all, d_drop, d_masked)                      # (K, N)
    w = src[:, :, None] * (1.0 - jnp.eye(n, dtype=src.dtype))[None]  # (K,i,j)
    if link_health is not None:
        usable = (jnp.asarray(link_health, src.dtype) > 0.0)
        w_routed = w * usable[None].astype(src.dtype)
        routable = jnp.sum(w_routed, axis=1, keepdims=True) > 1e-12
        w = jnp.where(routable, w_routed, w)
    w = w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-12)
    return w * need[:, None, :]


def plan_cost(
    d_old: Array,
    d_new: Array,
    sizes_gb: Array,
    wan: WanModel,
    omega: Array,
    pue: Array,
) -> tuple[Array, Array, Array]:
    """Fused ``transfer_cost(transfer_plan(...))`` — no (K, N, N) ever built.

    The product-coupling plan is rank-1 per type (``plan[k] = out_k ⊗
    share_k``) and the endpoint-mean link price is rank-2
    (``P = 0.5 (w 1ᵀ + 1 wᵀ)``), so the whole bill collapses to the
    bilinear form ``Σ_k out_kᵀ · P · share_k`` evaluated with four (K,)
    contractions:

        cost = epg * 0.5 * Σ_k [ (out_k·w) * Σ_j share_kj
                                 + (Σ_i out_ki) * (share_k·w) ]

    (the plan's diagonal is exactly zero — a site never both exports and
    imports — so including P's diagonal is exact). This is the hot-loop
    form: the staged engine bills all S stages of all T slots in one
    batched call and the controller bills every recovery edge through it.
    Matches the materialized ``transfer_cost(transfer_plan(...))`` to
    float-reassociation tolerance (pinned ≤ 1e-5 relative in tests);
    callers needing the plan itself (e.g. :func:`transfer_latency`) keep
    using :func:`transfer_plan`.

    Args:
        d_old: (..., K, N) current placement (rows on the simplex).
        d_new: (..., K, N) target placement.
        sizes_gb: (..., K) dataset sizes in GB.
        wan: the :class:`WanModel`.
        omega: (..., N) prices; pue: (..., N) PUE.

    Returns:
        (cost, energy, gb_moved) — each (...,); scalars for unbatched
        inputs, the same contract as :func:`transfer_cost`.
    """
    delta = d_new - d_old                                        # (..., K, N)
    out_gb = jnp.maximum(-delta, 0.0) * sizes_gb[..., None]      # exports
    in_gb = jnp.maximum(delta, 0.0) * sizes_gb[..., None]        # imports
    total = jnp.sum(in_gb, axis=-1, keepdims=True)               # (..., K, 1)
    share = in_gb / jnp.maximum(total, 1e-12)                    # (..., K, N)
    o_tot = jnp.sum(out_gb, axis=-1)                             # (..., K)
    s_tot = jnp.sum(share, axis=-1)                              # ~ {0, 1}
    wpue = omega * pue

    def bilinear(w: Array) -> Array:
        ow = jnp.einsum("...kn,...n->...k", out_gb, w)
        sw = jnp.einsum("...kn,...n->...k", share, w)
        return 0.5 * (
            jnp.sum(ow * s_tot, axis=-1) + jnp.sum(o_tot * sw, axis=-1)
        )

    cost = wan.energy_per_gb * bilinear(wpue)
    energy = wan.energy_per_gb * bilinear(pue)
    return cost, energy, jnp.sum(o_tot * s_tot, axis=-1)


def evacuation_cost(
    d_masked: Array,
    d_drop: Array,
    sizes_gb: Array,
    wan: WanModel,
    omega: Array,
    pue: Array,
) -> tuple[Array, Array, Array]:
    """Fused ``transfer_cost(evacuation_plan(...))`` — no (K, N, N) built.

    The evacuation plan is ``plan[k, i, j] = w[k, i, j] * need[k, j]`` with
    column-normalized no-self source weights; under the endpoint-mean price
    the source half reduces to the per-destination leave-one-out mean source
    price ``(src_k·w - src_kj w_j) / (Σ src_k - src_kj)`` — an O(K N)
    expression. Billing is linear in the plan, so a recovery burst's total
    is exactly ``evacuation_cost(...) + plan_cost(...)`` (the controller's
    fast fault path). Same (cost, energy, gb) contract as
    :func:`transfer_cost`.
    """
    need = jnp.maximum(d_drop - d_masked, 0.0) * sizes_gb[:, None]   # (K, N)
    lost_all = jnp.sum(d_masked, axis=1, keepdims=True) <= 1e-9
    src = jnp.where(lost_all, d_drop, d_masked)                      # (K, N)
    src_sum = jnp.sum(src, axis=1, keepdims=True)                    # (K, 1)
    # The leave-one-out sums are mathematically >= 0 but are computed by
    # subtraction — clamp before the eps-guarded divide, or a one-hot
    # ``src`` row cancels to a signed ~ulp and the 1e-12 divisor turns it
    # into a huge spurious (possibly negative) bill.
    z_raw = jnp.maximum(src_sum - src, 0.0)                          # (K, N)
    z = jnp.maximum(z_raw, 1e-12)
    colsum = z_raw / z                                               # {0..1}
    wpue = omega * pue

    def half_sum(w: Array) -> Array:
        src_mean = jnp.maximum(
            (src @ w)[:, None] - src * w[None, :], 0.0
        ) / z                                                        # (K, N)
        return 0.5 * jnp.sum(need * (src_mean + w[None, :] * colsum))

    cost = wan.energy_per_gb * half_sum(wpue)
    energy = wan.energy_per_gb * half_sum(pue)
    return cost, energy, jnp.sum(need * colsum)


def expected_pull(
    src: Array, per_site: Array, assume_simplex: bool = False
) -> Array:
    """Fused ``src @ link_price_matrix(per_site)`` — no (N, N) built.

    ``pull[k, j] = Σ_i src[k, i] * 0.5 * (w_i + w_j)`` with the diagonal
    (local hand-off) free — the stage scheduler's expected-WAN-pull term
    (multiply by ``energy_per_gb`` for $-per-GB). Rank-2 price, so the
    matvec collapses to two (K,) contractions:

        pull[k, j] = 0.5 * (src_k·w + w_j * Σ_i src_ki) - src[k, j] * w_j

    ``assume_simplex=True`` skips the row-sum reduction (Σ src = 1 by
    contract — every source mix the scheduler feeds here is a
    distribution), trimming one kernel from the per-slot hot loop.
    """
    dot = src @ per_site                                             # (K,)
    half_j = (
        per_site
        if assume_simplex
        else per_site * jnp.sum(src, axis=-1)[..., None]
    )
    return 0.5 * (dot[..., None] + half_j) - src * per_site


def transfer_cost(
    plan_gb: Array,
    wan: WanModel,
    omega: Array,
    pue: Array,
    link_health: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Price the WAN bytes of one re-placement event.

    Energy for a byte on link i->j is drawn half at each endpoint, at that
    endpoint's PUE, and billed at that endpoint's current price. With
    ``link_health``, degraded links bill at ``price / health`` and bytes
    on a severed link bill ``inf`` (zero bytes on a severed link bill
    exactly zero — a plan that routes around the partition stays
    finite).

    Args:
        plan_gb: (K, N, N) bytes moved per link (from :func:`transfer_plan`).
        wan: the :class:`WanModel`.
        omega: (N,) prices at the epoch boundary.
        pue: (N,) PUE at the epoch boundary.
        link_health: optional (N, N) per-link health factor.

    Returns:
        (cost, energy, gb_moved) scalars — $ cost, PUE-weighted energy in
        job-equivalents, and total GB crossing the WAN.
    """
    wpue = omega * pue                                           # (N,)
    link_price = link_price_matrix(wpue, local_free=False,
                                   link_health=link_health)      # (N, N)
    link_energy = link_price_matrix(pue, local_free=False,
                                    link_health=link_health)
    gb_links = jnp.sum(plan_gb, axis=0)                          # (N, N)
    if link_health is None:
        cost = wan.energy_per_gb * jnp.sum(gb_links * link_price)
        energy = wan.energy_per_gb * jnp.sum(gb_links * link_energy)
    else:
        # 0 GB * inf price must stay 0, not NaN.
        moved = gb_links > 0.0
        cost = wan.energy_per_gb * jnp.sum(
            jnp.where(moved, gb_links * link_price, 0.0))
        energy = wan.energy_per_gb * jnp.sum(
            jnp.where(moved, gb_links * link_energy, 0.0))
    return cost, energy, jnp.sum(gb_links)


def transfer_latency(
    plan_gb: Array, wan: WanModel, link_health: Array | None = None
) -> Array:
    """Bottleneck completion time (seconds) of a re-placement event.

    Links run in parallel; the event finishes when the slowest link drains:
    ``max_ij plan[i, j] * 8 / bw[i, j]`` (GB -> Gb over Gb/s). With
    ``link_health``, a degraded link runs at ``bw * health`` — the event
    slows by the worst degraded link it crosses — and bytes on a severed
    link never finish (``inf``); links the plan does not use contribute
    nothing regardless of their health.
    """
    gb_links = jnp.sum(plan_gb, axis=0)                          # (N, N)
    if link_health is None:
        return jnp.max(gb_links * 8.0 / wan.link_bw)
    bw = wan.link_bw * jnp.asarray(link_health, gb_links.dtype)
    # gb > 0 on a severed link divides to inf; unused links pin to 0 so
    # a 0/0 on a severed-but-unused link cannot leak NaN into the max.
    secs = jnp.where(gb_links > 0.0, gb_links * 8.0 / bw, 0.0)
    return jnp.max(secs)


def degraded_surcharge(
    src: Array,
    dst: Array,
    vol: Array,
    wan: WanModel,
    omega: Array,
    pue: Array,
    link_health: Array,
) -> tuple[Array, Array]:
    """Extra (cost, energy) billed on degraded links, additive to the fused bill.

    The fused :func:`plan_cost` bill prices every byte at the *nominal*
    endpoint-mean link price (its rank-2 structure does not survive an
    arbitrary (N, N) health matrix), so degraded-link pricing enters as a
    **surcharge** on top: materialize the product-coupling plan, and bill
    each link's bytes the difference ``price * (1/health - 1)`` — zero on
    nominal links, ``inf`` on severed links carrying traffic. On an
    all-nominal trace the surcharge is exactly ``0.0`` everywhere, so
    ``fused_bill + surcharge`` stays bitwise the fused bill — the
    degraded path collapses to the fast path by the ``+ 0.0`` identity.

    Args:
        src/dst: (..., K, N) per-shuffle source/destination mixes.
        vol: (..., K) GB per shuffle.
        omega/pue: (..., N) per-slot prices / PUE.
        link_health: (..., N, N) link factor aligned with the batch dims.

    Returns:
        (cost, energy) — each (...,), the degraded-link premium.
    """
    plans = transfer_plan(src, dst, vol)                     # (..., K, N, N)
    gb_links = jnp.sum(plans, axis=-3)                       # (..., N, N)
    health = jnp.asarray(link_health, gb_links.dtype)
    premium = jnp.where(
        health > 0.0, 1.0 / jnp.maximum(health, 1e-9) - 1.0, jnp.inf
    )
    wpue = omega * pue

    def bill(w: Array) -> Array:
        price = 0.5 * (w[..., :, None] + w[..., None, :])    # (..., N, N)
        extra = gb_links * price * premium
        # 0 GB on a severed link must bill 0, not NaN.
        return wan.energy_per_gb * jnp.sum(
            jnp.where(gb_links > 0.0, extra, 0.0), axis=(-2, -1)
        )

    return bill(wpue), bill(pue)
