"""Replica-selection & placement scoring (slow-timescale counterpart of GMSA).

Where :func:`repro.core.gmsa.gmsa_dispatch` answers "which DC manages this
slot's jobs", this module answers the slow question "which DCs should *hold*
each dataset" — trading the co-location gain of hosting data at cheap,
capacity-rich sites (Kumar et al., data placement & replica selection)
against replication storage/sync cost and per-site storage caps.

Everything is a vectorized closed-form/greedy rule in the style of
``gmsa_dispatch``:

* :func:`hosting_scores` — the per-(type, site) linear objective;
* :func:`target_placement` — softmin over sites (temperature -> 0 recovers
  the LP-vertex one-hot, exactly as GMSA's argmin) projected onto the
  storage-capacity polytope by iterative proportional capping;
* :func:`replica_read_assignment` — the fast replica-*selection* rule: each
  reader site picks its cheapest live replica (an argmin vertex rule);
* :func:`effective_replicas` / :func:`replication_premium` /
  :func:`sync_cost` — the replication premium (the rule's objective term
  and the controller's bill share one definition);
* :func:`expected_read_cost` — spread's benefit under replica selection
  (feeds the sync-aware candidate ladder of :func:`make_adaptive_rule`).

All functions are pure jnp with static iteration counts: jit-safe inside the
controller's epoch scan, vmappable over Monte-Carlo runs.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array
from jax.nn import one_hot, softmax

from repro.placement.wan import WanModel, link_price_matrix

_EPS = 1e-12

#: A replica below this placement fraction is considered not materialized at
#: the site (it cannot serve reads, it incurs no sync traffic).
REPLICA_THRESHOLD = 0.01

#: Hosting-score penalty added to dead sites (same units as the scores,
#: $/MWh-equivalents): large enough that the softmin underflows to exactly
#: zero preference there at any realistic temperature.
DEAD_SITE_PENALTY = 1e6


def hosting_scores(
    wpue_bar: Array,
    cap_share: Array,
    up: Array,
    colo_weight: float = 0.0,
    net_weight: float = 0.0,
) -> Array:
    """Per-(type, site) cost of hosting one unit of data — lower is better.

        score[k, j] = wpue_bar_j  -  colo_weight * cap_share[k, j]
                      +  net_weight / up_j

    The first term is the epoch-average energy price paid by the data-local
    work that follows the dataset (map tasks + the Iridium-placed reduce
    pull); the second rewards co-locating data with service capacity (more
    jobs complete where the data lives); the third penalizes hosts whose
    uplink throttles shipping the data to remote executors.

    Args:
        wpue_bar: (N,) epoch-average omega * PUE per site.
        cap_share: (K, N) per-type service-capacity shares (rows sum to 1).
        up: (N,) uplink bandwidths, Gb/s.

    Returns:
        (K, N) scores.
    """
    return (
        wpue_bar[None, :]
        - colo_weight * cap_share
        + net_weight / jnp.maximum(up[None, :], _EPS)
    )


def capacity_project(
    target: Array,
    sizes_gb: Array,
    capacity_gb: Array,
    iters: int = 32,
) -> Array:
    """Project row-simplex placements onto per-site storage caps.

    Repeats (static ``iters``, jit-safe): scale down every site that exceeds
    its cap, then redistribute each row's lost mass to sites with headroom,
    proportionally to ``headroom * original preference``. With feasible
    totals (sum of dataset sizes <= sum of caps) this converges to a
    row-stochastic placement with site loads within a fraction of a percent
    of the caps; callers must provision feasible capacity.

    Args:
        target: (K, N) unconstrained placement preference (rows sum to 1).
        sizes_gb: (K,) dataset sizes.
        capacity_gb: (N,) per-site storage caps (``inf`` = uncapped).

    Returns:
        (K, N) row-stochastic placement respecting the caps.
    """
    finite_cap = jnp.isfinite(capacity_gb)
    p = target
    for _ in range(iters):
        load = jnp.sum(p * sizes_gb[:, None], axis=0)                  # (N,)
        scale = jnp.where(
            finite_cap, jnp.minimum(1.0, capacity_gb / jnp.maximum(load, _EPS)), 1.0
        )
        p = p * scale[None, :]
        headroom = jnp.where(
            finite_cap,
            jnp.maximum(capacity_gb - jnp.sum(p * sizes_gb[:, None], axis=0), 0.0),
            jnp.float32(1e9),
        )
        w = target * headroom[None, :] + _EPS
        deficit = jnp.maximum(1.0 - jnp.sum(p, axis=1), 0.0)           # (K,)
        p = p + deficit[:, None] * w / jnp.sum(w, axis=1, keepdims=True)
    return p / jnp.maximum(jnp.sum(p, axis=1, keepdims=True), _EPS)


def target_placement(
    scores: Array,
    sizes_gb: Array,
    capacity_gb: Array,
    temp: float = 2.0,
    project_iters: int = 32,
) -> Array:
    """Greedy placement target: softmin over sites, capacity-projected.

    ``temp`` is in the same units as the scores ($/MWh-equivalents); as
    ``temp -> 0`` the softmin collapses to the one-hot LP vertex (all of
    dataset k at its single cheapest feasible site), exactly mirroring
    ``gmsa_dispatch``'s argmin. Finite temperature keeps secondary replicas
    alive, which is what replica *selection* then exploits.
    """
    pref = softmax(-scores / jnp.maximum(temp, 1e-6), axis=1)          # (K, N)
    return capacity_project(pref, sizes_gb, capacity_gb, project_iters)


def replica_read_assignment(
    data_dist: Array, wan: WanModel, wpue: Array, latency_weight: float = 0.0
) -> Array:
    """Each reader site's cheapest live replica — an argmin vertex rule.

    read_cost[k, j, i] = energy_per_gb * (wpue_i + wpue_j)/2
                         + latency_weight * 8 / link_bw[i, j]      (i -> j)

    with sites holding less than :data:`REPLICA_THRESHOLD` of dataset k
    masked out. Local reads are free (link_bw diagonal is ``inf`` and the
    energy term is still paid only when i != j — enforced by zeroing the
    diagonal cost), so a reader holding a replica always serves itself.

    Returns:
        (K, N, N) selection s[k, j, i] one-hot over hosts i for each reader j.
    """
    n = wpue.shape[0]
    price = link_price_matrix(wpue) * wan.energy_per_gb                 # (N, N) i,j
    lat = latency_weight * 8.0 / wan.link_bw                            # (N, N)
    cost = price + lat
    cost = jnp.where(jnp.eye(n, dtype=bool), 0.0, cost)                 # local free
    live = data_dist >= REPLICA_THRESHOLD                               # (K, N)
    cost_kji = jnp.where(live[:, None, :], cost.T[None, :, :], jnp.inf) # (K, j, i)
    best = jnp.argmin(cost_kji, axis=2)                                 # (K, N)
    return one_hot(best, n, dtype=data_dist.dtype)                      # (K, N, N)


def effective_replicas(data_dist: Array) -> Array:
    """(K,) inverse-Simpson replica count 1 / sum_j d_kj^2.

    1.0 when a dataset is fully concentrated at one site, N when spread
    uniformly — a smooth, jit-safe proxy for "how many copies must be kept
    in sync".
    """
    return 1.0 / jnp.maximum(jnp.sum(jnp.square(data_dist), axis=1), _EPS)


def sync_cost(
    data_dist: Array,
    sizes_gb: Array,
    wan: WanModel,
    wpue: Array,
    update_fraction: float = 0.01,
) -> Array:
    """Per-epoch replication sync bill (scalar $).

    Every replica beyond the first must absorb ``update_fraction`` of its
    dataset in updates per epoch, shipped over the WAN at the mean link
    price. Shards below :data:`REPLICA_THRESHOLD` are not materialized
    (same rule as :func:`replica_read_assignment`): they hold no copy and
    sync nothing, so the softmin's residue at expensive sites is not
    billed. The billed quantity is exactly :func:`replication_premium` —
    the term the sync-aware hosting rule optimizes — priced in GB.
    """
    gb = jnp.sum(replication_premium(data_dist, update_fraction) * sizes_gb)
    return gb * wan.energy_per_gb * jnp.mean(wpue)


def replication_premium(target: Array, update_fraction: float) -> Array:
    """(K,) per-unit-data sync overhead of a candidate placement.

    ``update_fraction * (effective_replicas - 1)`` over the *materialized*
    shards (the :data:`REPLICA_THRESHOLD` rule). :func:`sync_cost` prices
    exactly this quantity, so the rule's objective and the controller's
    bill agree on what counts as a replica by construction. Units:
    fraction of the dataset re-shipped per epoch — multiplied by a
    $-per-unit weight by the caller.
    """
    live = jnp.where(target >= REPLICA_THRESHOLD, target, 0.0)
    total = jnp.sum(live, axis=1, keepdims=True)
    live = jnp.where(total > _EPS, live / jnp.maximum(total, _EPS), target)
    return update_fraction * jnp.maximum(effective_replicas(live) - 1.0, 0.0)


def expected_read_cost(target: Array, wpue: Array, reader_share: Array) -> Array:
    """(K,) per-unit-data cost of serving reads from a candidate placement.

    Each reader site pulls from its cheapest *materialized* replica —
    the exact selection rule of :func:`replica_read_assignment` (local
    reads free, remote reads at the endpoint-mean price) — weighted by
    ``reader_share`` (where the reading work actually runs). This is the
    spread-favoring half of the replication trade-off: more replicas
    mean cheaper reads, which is what finite placement temperature buys
    and what the sync premium charges for. Units: $/MWh-equivalents per
    unit data (the ``energy_per_gb`` scale is the caller's weight).

    Args:
        target: (K, N) candidate placement (rows on the simplex).
        wpue: (N,) current omega * PUE.
        reader_share: (K, N) per-type read weights (rows sum to 1).
    """
    price = link_price_matrix(wpue)                               # (i, j)
    live = target >= REPLICA_THRESHOLD                            # (K, N)
    cost_kji = jnp.where(live[:, None, :], price.T[None], jnp.inf)
    best = jnp.min(cost_kji, axis=2)                              # (K, j)
    # A candidate with no materialized replica cannot serve reads at all;
    # make it maximally unattractive (finite, so argmin stays valid).
    best = jnp.where(jnp.isfinite(best), best, jnp.max(wpue))
    return jnp.sum(reader_share * best, axis=1)


def make_adaptive_rule(
    up: Array,
    temp: float = 2.0,
    colo_weight: float = 0.0,
    net_weight: float = 0.0,
    project_iters: int = 32,
    sync_weight: float = 0.0,
    update_fraction: float = 0.01,
    read_fraction: float = 0.05,
):
    """Bind scoring weights into the controller's slow-timescale rule.

    Returns ``rule(d, obs) -> d_target`` for
    :func:`repro.placement.controller.simulate_placed`; ``obs`` is a
    :class:`repro.placement.controller.SlowObs`.

    With ``sync_weight > 0`` the rule itself trades replication's benefit
    against its overhead (not just the billing): it evaluates a ladder of
    spread candidates — softmins from 4x warmer than ``temp`` down to the
    one-hot LP vertex — under the replica-*selection* cost surrogate

        min over materialized i of score[k, i]       (primary serving)
        + read_fraction * expected_read_cost(c)      (spread's benefit)
        + sync_weight * wpue_mean
          * replication_premium(c, update_fraction)  (spread's cost)

    all in $/MWh-equivalents per unit data, and keeps the per-type argmin
    before capacity projection. Under replica selection the marginal work
    is served by the best materialized replica (so serving cost is the
    primary's score, shared by every candidate that keeps the best site
    live — NOT the linear ``c . score``, under which the vertex would
    minimize serving and premium simultaneously and no weight could ever
    spread); what extra replicas buy is read locality (every reader
    pulls from its cheapest materialized replica, the
    :func:`replica_read_assignment` rule), and what they cost is exactly
    the premium :func:`sync_cost` bills. ``sync_weight`` dials
    consolidation: 0 preserves the original single-candidate rule
    exactly; small values keep warm, replica-rich placements; large
    values collapse to the vertex.
    """
    up = jnp.asarray(up, jnp.float32)

    def rule(d: Array, obs) -> Array:
        del d  # memoryless target; the controller applies the move budget
        cap_share = (obs.mu_bar / jnp.maximum(
            jnp.sum(obs.mu_bar, axis=0, keepdims=True), _EPS
        )).T                                                            # (K, N)
        scores = hosting_scores(
            obs.wpue_bar, cap_share, up,
            colo_weight=colo_weight, net_weight=net_weight,
        )
        capacity_gb = obs.capacity_gb
        alive = getattr(obs, "alive", None)
        if alive is not None:
            # Survivor-aware: dead sites can neither host (score penalty
            # underflows the softmin to 0 there) nor store (zero cap for
            # the projection). With every site alive both terms are exact
            # no-ops, keeping the no-fault path bit-exact.
            alive = jnp.asarray(alive, jnp.float32)
            scores = scores + DEAD_SITE_PENALTY * (1.0 - alive)[None, :]
            capacity_gb = jnp.where(alive < 0.5, 0.0, capacity_gb)
        if sync_weight == 0.0:
            return target_placement(
                scores, obs.sizes_gb, capacity_gb,
                temp=temp, project_iters=project_iters,
            )
        # Sync-aware candidate ladder: warmer softmins spread replicas
        # (cheap reads, costly sync), colder ones consolidate. Chosen per
        # type under the selection surrogate: primary serving + read
        # benefit + sync premium, jit-safe.
        cands = jnp.stack([
            softmax(-scores / jnp.maximum(t, 1e-6), axis=1)
            for t in (4.0 * temp, temp, 0.25 * temp, 1e-6)
        ])                                                              # (C, K, N)
        live = cands >= REPLICA_THRESHOLD
        big = jnp.max(jnp.abs(scores)) + 1.0
        primary = jnp.min(
            jnp.where(live, scores[None], big), axis=2
        )                                                               # (C, K)
        premium = jnp.stack([
            replication_premium(c, update_fraction) for c in cands
        ])                                                              # (C, K)
        read = jnp.stack([
            expected_read_cost(c, obs.wpue_bar, cap_share) for c in cands
        ])                                                              # (C, K)
        wpue_mean = jnp.mean(obs.wpue_bar)
        total = (primary + read_fraction * read
                 + sync_weight * wpue_mean * premium)
        best = jnp.argmin(total, axis=0)                                # (K,)
        pref = jnp.take_along_axis(
            cands, best[None, :, None], axis=0
        )[0]                                                            # (K, N)
        return capacity_project(
            pref, obs.sizes_gb, capacity_gb, project_iters
        )

    return rule
