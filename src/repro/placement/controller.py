"""Two-timescale placement controller: slow re-placement x fast GMSA dispatch.

The fast loop is the paper's per-slot GMSA (or any simulator policy); the
slow loop fires every ``epoch_slots`` (W) slots and may re-place / replicate
the datasets across sites under a WAN transfer-cost model and per-site
storage caps, after which the Iridium ratio tensor ``r`` is re-derived for
the new layout. Structurally this is a ``lax.scan`` over epochs whose body
contains the placement step, the (K, N, N) Iridium rebuild, and an inner
``lax.scan`` over the epoch's W slots — one jit compilation end-to-end,
vmappable over Monte-Carlo keys exactly like ``repro.core.simulator``.

Epoch 0 always runs the *given* placement untouched (no move, no rebuild),
so with ``W >= T`` the controller degenerates to a single epoch and
``simulate_placed`` reproduces plain ``simulate`` bit-for-bit — the
equivalence the test suite pins down.

Exogenous dataset drift (new data ingested at sites the controller does not
choose — the scenario of Zhang et al., where placement must adapt over
time) enters through an optional per-epoch ``ingest`` trace; the controller
observes the drifted layout and corrects it within its per-epoch move
budget, paying for every byte through :mod:`repro.placement.wan`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.iridium import make_allocation_rebuilder
from repro.core.simulator import PolicyFn, SimInputs, energy_tables, slot_step
from repro.placement.replica import sync_cost as replica_sync_cost
from repro.placement.wan import (
    DEFAULT_ENERGY_PER_GB,
    transfer_cost,
    transfer_latency,
    transfer_plan,
    wan_topology,
)

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    """Static knobs of the two-timescale controller (hashable: jit-static).

    Attributes:
        epoch_slots: W — slow-loop period in slots. The horizon T must be a
            multiple of min(W, T).
        move_budget: alpha in [0, 1] — per epoch, the placement moves at
            most this fraction of the way from the current layout to the
            rule's target (bounds the WAN burst per epoch).
        dataset_gb: per-type dataset sizes in GB (scalar broadcasts).
        capacity_gb: per-site storage caps in GB, or ``None`` = uncapped.
        energy_per_gb: WAN energy per GB (job-energy equivalents).
        growth: fraction of each dataset that is fresh ingest per epoch
            (only effective when an ``ingest`` trace is supplied).
        update_fraction: share of each dataset that every replica beyond the
            first must absorb as sync updates per epoch (the replication
            premium of :func:`repro.placement.replica.sync_cost`, charged
            every epoch against the layout in force).
        size / manager_share / map_share: Iridium rebuild parameters.
            Defaults equal ``build_task_allocation``'s, so default-built
            ``SimInputs.r`` and the per-epoch rebuilds agree; when the
            inputs use non-default shares (e.g. ``facebook_4dc``'s
            manager_share=0.62), pass the same values here or the cost
            series jumps at the first rebuild for non-placement reasons.
    """

    epoch_slots: int = 48
    move_budget: float = 0.5
    dataset_gb: float | tuple = 100.0
    capacity_gb: tuple | None = None
    energy_per_gb: float = DEFAULT_ENERGY_PER_GB
    growth: float = 0.0
    update_fraction: float = 0.01
    size: float = 1.0
    manager_share: float = 0.3
    map_share: float = 0.6


class SlowObs(NamedTuple):
    """What the slow-timescale rule sees at an epoch boundary.

    Prices/PUE are the *upcoming* epoch's averages — day-ahead market
    structure and weather forecasts make these available in practice (the
    same assumption Iridium makes for bandwidth).
    """

    wpue_bar: Array     # (N,)   epoch-average omega * PUE
    mu_bar: Array       # (N, K) epoch-average service rates
    q: Array            # (N, K) backlogs at the boundary
    sizes_gb: Array     # (K,)   dataset sizes this epoch
    capacity_gb: Array  # (N,)   storage caps


#: rule(d_current, obs) -> d_target, both (K, N) row-stochastic.
PlacementRule = Callable[[Array, SlowObs], Array]


class PlacedOutputs(NamedTuple):
    """Flattened fast-loop outputs plus the slow-loop audit trail."""

    cost: Array            # (T,) per-slot dispatch energy cost
    energy: Array          # (T,)
    backlog_total: Array   # (T,)
    backlog_avg: Array     # (T,)
    q_final: Array         # (N, K)
    f_trace: Array         # (T, N, K)
    placements: Array      # (E, K, N) layout in force during each epoch
    r_trace: Array         # (E, K, N, N) ratio tensor per epoch
    wan_cost: Array        # (E,) $ spent moving data at each boundary
    wan_energy: Array      # (E,) WAN energy (job-equivalents)
    wan_gb: Array          # (E,) GB crossing the WAN
    wan_latency_s: Array   # (E,) bottleneck completion time of each move
    sync_cost: Array       # (E,) $ replication sync premium per epoch


@functools.partial(jax.jit, static_argnames=("policy", "rule", "cfg"))
def simulate_placed(
    inputs: SimInputs,
    up: Array,
    down: Array,
    policy: PolicyFn,
    rule: PlacementRule,
    key: Array,
    cfg: PlacementConfig,
    scalar: float | Array = 0.0,
    ingest: Array | None = None,
    sizes_gb: Array | None = None,
) -> PlacedOutputs:
    """Run the two-timescale controller over one trace.

    Args:
        inputs: the usual trace bundle; ``data_dist`` must be the static
            (K, N) form (it becomes the epoch-0 layout) and ``r`` the
            static (K, N, N) form (used verbatim for epoch 0).
        up/down: (N,) site bandwidths — feed both the WAN transfer model
            and the per-epoch Iridium rebuild.
        policy: fast-loop dispatch policy (simulator signature).
        rule: slow-loop placement rule, e.g.
            :func:`repro.placement.replica.make_adaptive_rule` or
            :func:`repro.core.baselines.static_placement_rule`.
        key: PRNG key (split per slot exactly as ``simulate`` does).
        cfg: static controller knobs.
        scalar: traced control parameter forwarded to the policy (GMSA's V).
        ingest: optional (E, K, N) exogenous ingest distributions; mixed in
            with weight ``cfg.growth`` at every boundary after epoch 0.
        sizes_gb: optional (E, K) per-epoch dataset sizes (growth trace);
            defaults to ``cfg.dataset_gb`` for all epochs.
    """
    t_slots, k_types = inputs.arrivals.shape
    n = inputs.mu.shape[1]
    if inputs.data_dist.ndim != 2 or inputs.r.ndim != 3:
        raise ValueError("simulate_placed owns the time axis: pass static "
                         "(K, N) data_dist and (K, N, N) r")
    w = min(cfg.epoch_slots, t_slots)
    if t_slots % w != 0:
        raise ValueError(f"T={t_slots} must be a multiple of W={w}")
    n_epochs = t_slots // w

    wan = wan_topology(up, down, cfg.energy_per_gb)
    rebuild = make_allocation_rebuilder(
        up, down, size=cfg.size,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    cap = (
        jnp.full((n,), jnp.inf, jnp.float32)
        if cfg.capacity_gb is None
        else jnp.asarray(cfg.capacity_gb, jnp.float32)
    )
    if sizes_gb is None:
        sizes_gb = jnp.broadcast_to(
            jnp.asarray(cfg.dataset_gb, jnp.float32), (n_epochs, k_types)
        )
    scalar = jnp.asarray(scalar, jnp.float32)
    p_it = inputs.p_it

    ep = lambda x: x.reshape((n_epochs, w) + x.shape[1:])
    arr_ep, mu_ep = ep(inputs.arrivals), ep(inputs.mu)
    om_ep, pu_ep = ep(inputs.omega), ep(inputs.pue)
    first = jnp.arange(n_epochs) == 0

    # Match ``simulate``'s PRNG stream exactly on both of its policy paths:
    # state-independent policies consume split(key, T)[t] per slot (the
    # precomputed-vmap path), everything else splits the carried key.
    state_ind = getattr(policy, "state_independent", False)
    keys_ep = ep(jax.random.split(key, t_slots)) if state_ind else None

    q0 = jnp.zeros((n, k_types), jnp.float32)
    d0 = jnp.asarray(inputs.data_dist, jnp.float32)
    r0 = inputs.r

    def epoch(carry, xs):
        q, key, d = carry
        if state_ind:
            arr_e, mu_e, om_e, pu_e, size_e, ing_e, is_first, keys_e = xs
        else:
            arr_e, mu_e, om_e, pu_e, size_e, ing_e, is_first = xs

        # -- slow timescale: drift, observe, re-place, pay the WAN bill.
        if ingest is not None:
            g = jnp.float32(cfg.growth)
            drifted = (1.0 - g) * d + g * ing_e
            drifted = drifted / jnp.maximum(
                jnp.sum(drifted, axis=1, keepdims=True), _EPS
            )
            d_drift = jnp.where(is_first, d, drifted)
        else:
            d_drift = d
        wpue_e = om_e * pu_e                                          # (W, N)
        obs = SlowObs(
            wpue_bar=jnp.mean(wpue_e, axis=0),
            mu_bar=jnp.mean(mu_e, axis=0),
            q=q, sizes_gb=size_e, capacity_gb=cap,
        )
        target = rule(d_drift, obs)
        stepped = d_drift + cfg.move_budget * (target - d_drift)
        stepped = stepped / jnp.maximum(jnp.sum(stepped, axis=1, keepdims=True), _EPS)
        d_new = jnp.where(is_first, d, stepped)
        plan = transfer_plan(d_drift, d_new, size_e)                  # (K, N, N)
        wan_c, wan_e, wan_gb = transfer_cost(plan, wan, om_e[0], pu_e[0])
        wan_lat = transfer_latency(plan, wan)
        # Ongoing replication premium: every epoch, each replica beyond the
        # first absorbs update_fraction of its dataset at the epoch-mean price.
        sync_c = replica_sync_cost(
            d_new, size_e, wan, obs.wpue_bar, cfg.update_fraction
        )
        r_e = jnp.where(is_first, r0, rebuild(d_new))                 # (K, N, N)

        # -- fast timescale: the simulator's slot body against (d_new, r_e).
        e_cost, e_raw = energy_tables(r_e, wpue_e, pu_e, p_it)

        def slot(carry2, xs2):
            q2, key2 = carry2
            if state_ind:
                arrivals, mu, ec, er, sub = xs2
            else:
                arrivals, mu, ec, er = xs2
                key2, sub = jax.random.split(key2)
            f = policy(sub, q2, arrivals, mu, ec, d_new, scalar)
            q_next, out = slot_step(q2, f, arrivals, mu, ec, er)
            return (q_next, key2), out

        slot_xs = (arr_e, mu_e, e_cost, e_raw)
        if state_ind:
            slot_xs = slot_xs + (keys_e,)
        (q, key), slot_outs = jax.lax.scan(slot, (q, key), slot_xs)
        epoch_out = slot_outs + (d_new, r_e, wan_c, wan_e, wan_gb, wan_lat,
                                 sync_c)
        return (q, key, d_new), epoch_out

    xs = (arr_ep, mu_ep, om_ep, pu_ep, sizes_gb,
          ingest if ingest is not None else jnp.zeros((n_epochs, k_types, n)),
          first)
    if state_ind:
        xs = xs + (keys_ep,)
    (q_final, _, _), outs = jax.lax.scan(epoch, (q0, key, d0), xs)
    cost, energy, btot, bavg, f_trace, d_tr, r_tr, wc, we, wgb, wlat, sc = outs
    flat = lambda x: x.reshape((t_slots,) + x.shape[2:])
    return PlacedOutputs(
        cost=flat(cost), energy=flat(energy),
        backlog_total=flat(btot), backlog_avg=flat(bavg),
        q_final=q_final, f_trace=flat(f_trace),
        placements=d_tr, r_trace=r_tr,
        wan_cost=wc, wan_energy=we, wan_gb=wgb, wan_latency_s=wlat,
        sync_cost=sc,
    )


@functools.partial(
    jax.jit, static_argnames=("build_inputs", "policy", "rule", "cfg", "n_runs")
)
def simulate_placed_many(
    build_inputs: Callable[[Array], SimInputs],
    up: Array,
    down: Array,
    policy: PolicyFn,
    rule: PlacementRule,
    key: Array,
    n_runs: int,
    cfg: PlacementConfig,
    scalar: float | Array = 0.0,
    ingest: Array | None = None,
    sizes_gb: Array | None = None,
) -> PlacedOutputs:
    """Monte-Carlo replication of :func:`simulate_placed` (vmap over keys).

    Mirrors ``simulate_many``: fresh stochastic traces + policy randomness
    per run, deterministic traces (prices, PUE, drift) shared. One
    compilation serves every run.
    """
    keys = jax.random.split(key, n_runs)

    def one(run_key):
        k_build, k_sim = jax.random.split(run_key)
        return simulate_placed(
            build_inputs(k_build), up, down, policy, rule, k_sim, cfg,
            scalar=scalar, ingest=ingest, sizes_gb=sizes_gb,
        )

    return jax.vmap(one)(keys)


def summarize_placed(outs: PlacedOutputs) -> dict:
    """Time-averaged scalars incl. WAN + sync bills (over any runs axis)."""
    t_slots = outs.cost.shape[-1]
    dispatch = jnp.mean(outs.cost)
    wan_per_slot = jnp.mean(jnp.sum(outs.wan_cost, axis=-1)) / t_slots
    sync_per_slot = jnp.mean(jnp.sum(outs.sync_cost, axis=-1)) / t_slots
    return {
        "time_avg_dispatch_cost": float(dispatch),
        "time_avg_wan_cost": float(wan_per_slot),
        "time_avg_sync_cost": float(sync_per_slot),
        "time_avg_total_cost": float(dispatch + wan_per_slot + sync_per_slot),
        "time_avg_energy": float(jnp.mean(outs.energy)),
        "time_avg_backlog": float(jnp.mean(outs.backlog_avg)),
        "total_wan_gb": float(jnp.mean(jnp.sum(outs.wan_gb, axis=-1))),
        "max_move_latency_s": float(jnp.max(outs.wan_latency_s)),
        "final_backlog_total": float(jnp.mean(outs.q_final.sum(axis=(-2, -1)))),
    }
