"""Two-timescale placement controller: slow re-placement x fast GMSA dispatch.

The fast loop is the paper's per-slot GMSA (or any simulator policy); the
slow loop fires every ``epoch_slots`` (W) slots and may re-place / replicate
the datasets across sites under a WAN transfer-cost model and per-site
storage caps, after which the Iridium ratio tensor ``r`` is re-derived for
the new layout. Structurally this is a ``lax.scan`` over epochs whose body
contains the placement step, the (K, N, N) Iridium rebuild, and an inner
``lax.scan`` over the epoch's W slots — one jit compilation end-to-end,
vmappable over Monte-Carlo keys exactly like ``repro.core.simulator``.

Epoch 0 always runs the *given* placement untouched (no move, no rebuild),
so with ``W >= T`` the controller degenerates to a single epoch and
``simulate_placed`` reproduces plain ``simulate`` bit-for-bit — the
equivalence the test suite pins down.

Exogenous dataset drift (new data ingested at sites the controller does not
choose — the scenario of Zhang et al., where placement must adapt over
time) enters through an optional per-epoch ``ingest`` trace; the controller
observes the drifted layout and corrects it within its per-epoch move
budget, paying for every byte through :mod:`repro.placement.wan`.

Site loss (the chaos scenario class, :mod:`repro.traces.faults`) enters
through an optional per-slot ``alive`` mask. On a death edge the controller
runs an immediate *off-schedule recovery epoch* inside the fast loop —
``drop_site`` semantics via :func:`repro.checkpoint.fault.drop_site_mask`:
the dead sites' backlog re-injects as an arrival burst, their dataset share
re-replicates over the survivors, the slow rule re-places restricted to
survivors, and the emergency WAN burst is billed into
``PlacedOutputs.recovery_cost``. Everything stays one jit'd scan-of-scans
— the recovery epoch is a ``lax.cond`` on the death edge, so the heavy
branch (rule re-place, Iridium rebuild, fused evacuation billing) executes
only on the handful of slots where a site actually dies and the no-edge
slot body stays the base engine's few fused ops — and with an all-ones
mask the fault path is bit-exact with the no-fault path: every masking op
is either an exact float identity (``* 1.0``, ``+ 0.0``), a select, or
behind the never-taken cond branch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.checkpoint.fault import drop_site_mask
from repro.core.iridium import make_allocation_rebuilder
from repro.core.simulator import (
    PolicyFn,
    SimInputs,
    energy_row,
    energy_tables,
    slot_step,
)
from repro.placement.replica import replica_read_assignment
from repro.placement.replica import sync_cost as replica_sync_cost
from repro.telemetry.config import TelemetryConfig
from repro.telemetry.config import enabled as _tel_enabled
from repro.telemetry.config import histograms as _tel_hist
from repro.telemetry.config import tracing as _tel_tracing
from repro.telemetry.metrics import hist_series
from repro.telemetry.ring import (
    EV_EPOCH,
    EV_INGEST_REDIRECT,
    EV_RECOVERY,
    EV_REPAIR,
    TelemetryFrame,
    ring_init,
    ring_push,
)
from repro.traces.datasets import io_slowdown_from_bandwidth
from repro.placement.wan import (
    DEFAULT_ENERGY_PER_GB,
    degraded_surcharge,
    evacuation_cost,
    evacuation_plan,
    plan_cost,
    transfer_cost,
    transfer_latency,
    transfer_plan,
    wan_topology,
)

_EPS = 1e-12


def survivor_renorm(masked: Array, fallback: Array, axis: int = -1) -> Array:
    """Renormalize a survivor-masked distribution back onto the simplex.

    ``masked`` is a distribution with dead sites already zeroed; rows whose
    mass sat entirely on dead sites are degenerate (zero sum) and take
    ``fallback`` instead. The single definition behind every
    mask-then-renormalize site in the fault path — keep the eps and the
    degenerate-row semantics in one place.
    """
    total = jnp.sum(masked, axis=axis, keepdims=True)
    return jnp.where(total > _EPS, masked / jnp.maximum(total, _EPS), fallback)


_survivor_renorm = survivor_renorm   # internal call sites / back-compat


def region_averse_weights(alive: Array, regions: Array) -> Array:
    """Survivor weights that shy away from regions already seeing deaths.

    Correlated outages share fate within a region (one grid feed, one
    fiber bundle — :func:`repro.traces.faults.regional_health_trace`), so
    a survivor in a region where peers just died is a worse re-placement
    target than an equally-capable survivor in an untouched region. Each
    survivor's weight is ``alive * (1 - dead_fraction_of_its_region)`` —
    computed with the O(N^2) same-region mask, so the region count never
    needs to be static. With every site alive the dead fraction is zero
    and the weights are exactly ``alive`` (the ``* 1.0`` identity); a
    survivor's weight stays strictly positive (a region with a survivor
    is never fully dead), so renormalization never degenerates beyond
    what plain ``alive`` weighting allows.
    """
    regions = jnp.asarray(regions)
    same = (regions[:, None] == regions[None, :]).astype(alive.dtype)
    dead_frac = (same @ (1.0 - alive)) / jnp.maximum(
        jnp.sum(same, axis=1), 1.0
    )
    return alive * (1.0 - dead_frac)


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    """Static knobs of the two-timescale controller (hashable: jit-static).

    Attributes:
        epoch_slots: W — slow-loop period in slots. The horizon T must be a
            multiple of min(W, T).
        move_budget: alpha in [0, 1] — per epoch, the placement moves at
            most this fraction of the way from the current layout to the
            rule's target (bounds the WAN burst per epoch).
        dataset_gb: per-type dataset sizes in GB (scalar broadcasts).
        capacity_gb: per-site storage caps in GB, or ``None`` = uncapped.
        energy_per_gb: WAN energy per GB (job-energy equivalents).
        growth: fraction of each dataset that is fresh ingest per epoch
            (only effective when an ``ingest`` trace is supplied).
        update_fraction: share of each dataset that every replica beyond the
            first must absorb as sync updates per epoch (the replication
            premium of :func:`repro.placement.replica.sync_cost`, charged
            every epoch against the layout in force).
        io_coupling: thread the *evolving* placement into the per-slot
            service rates (latency-aware replica reads): each epoch's mu
            is scaled by the current layout's I/O slowdown
            (:func:`repro.traces.datasets.io_slowdown_from_bandwidth`)
            relative to the epoch-0 layout the mu trace was calibrated
            against — re-placement buys throughput, not just energy
            price. The slow rule observes the drifted layout's scale;
            the fast loop runs under the chosen layout's scale, and a
            recovery re-placement inside an epoch re-derives the scale
            per slot from the carried layout (cond-gated on the death
            edge, like the energy rows — the epoch value would be stale:
            evacuated data raises the survivors' I/O slowdown). Off by
            default: the no-coupling path is untouched.
        io_compute_seconds / io_job_gb: the slowdown model's per-job
            compute time and intermediate pull volume (defaults match
            ``io_slowdown_from_bandwidth``).
        io_per_reader: resolve the I/O slowdown from the *actual*
            per-reader replica choices
            (:func:`repro.placement.replica.replica_read_assignment`)
            instead of the type-averaged locality: a (site, type) pair
            whose reader holds a live local replica is not slowed at all,
            whatever the other types pull remotely — the slowdown becomes
            (N, K) and scales mu per type. Off by default: the averaged
            (N,) model (and its bitwise path) is untouched.
        size / manager_share / map_share: Iridium rebuild parameters.
            Defaults equal ``build_task_allocation``'s, so default-built
            ``SimInputs.r`` and the per-epoch rebuilds agree; when the
            inputs use non-default shares (e.g. ``facebook_4dc``'s
            manager_share=0.62), pass the same values here or the cost
            series jumps at the first rebuild for non-placement reasons.
    """

    epoch_slots: int = 48
    move_budget: float = 0.5
    dataset_gb: float | tuple = 100.0
    capacity_gb: tuple | None = None
    energy_per_gb: float = DEFAULT_ENERGY_PER_GB
    growth: float = 0.0
    update_fraction: float = 0.01
    io_coupling: bool = False
    io_compute_seconds: float = 300.0
    io_job_gb: float = 5.0
    io_per_reader: bool = False
    size: float = 1.0
    manager_share: float = 0.3
    map_share: float = 0.6


class SlowObs(NamedTuple):
    """What the slow-timescale rule sees at an epoch boundary.

    Prices/PUE are the *upcoming* epoch's averages — day-ahead market
    structure and weather forecasts make these available in practice (the
    same assumption Iridium makes for bandwidth).
    """

    wpue_bar: Array     # (N,)   epoch-average omega * PUE
    mu_bar: Array       # (N, K) epoch-average service rates
    q: Array            # (N, K) backlogs at the boundary
    sizes_gb: Array     # (K,)   dataset sizes this epoch
    capacity_gb: Array  # (N,)   storage caps
    alive: Array | None = None  # (N,) {0,1} survivors; None = no fault model


#: rule(d_current, obs) -> d_target, both (K, N) row-stochastic.
PlacementRule = Callable[[Array, SlowObs], Array]


class PlacedOutputs(NamedTuple):
    """Flattened fast-loop outputs plus the slow-loop audit trail."""

    cost: Array            # (T,) per-slot dispatch energy cost
    energy: Array          # (T,)
    backlog_total: Array   # (T,)
    backlog_avg: Array     # (T,)
    q_final: Array         # (N, K)
    f_trace: Array         # (T, N, K)
    placements: Array      # (E, K, N) layout in force during each epoch
    r_trace: Array         # (E, K, N, N) ratio tensor per epoch
    wan_cost: Array        # (E,) $ spent moving data at each boundary
    wan_energy: Array      # (E,) WAN energy (job-equivalents)
    wan_gb: Array          # (E,) GB crossing the WAN
    wan_latency_s: Array   # (E,) bottleneck completion time of each move
    sync_cost: Array       # (E,) $ replication sync premium per epoch
    recovery_cost: Array   # (T,) $ emergency WAN burst on site-loss edges
    recovery_gb: Array     # (T,) GB evacuated/re-replicated on those edges
    mu_scale: Array        # (E, N) I/O service-rate scale per epoch (ones
                           # unless cfg.io_coupling)


@functools.partial(
    jax.jit, static_argnames=("policy", "rule", "cfg", "telemetry")
)
def simulate_placed(
    inputs: SimInputs,
    up: Array,
    down: Array,
    policy: PolicyFn,
    rule: PlacementRule,
    key: Array,
    cfg: PlacementConfig,
    scalar: float | Array = 0.0,
    ingest: Array | None = None,
    sizes_gb: Array | None = None,
    alive: Array | None = None,
    move_budget: Array | None = None,
    telemetry: TelemetryConfig | None = None,
    health: Array | None = None,
    link_health: Array | None = None,
    regions: Array | None = None,
) -> PlacedOutputs | tuple[PlacedOutputs, TelemetryFrame]:
    """Run the two-timescale controller over one trace.

    Args:
        inputs: the usual trace bundle; ``data_dist`` must be the static
            (K, N) form (it becomes the epoch-0 layout) and ``r`` the
            static (K, N, N) form (used verbatim for epoch 0).
        up/down: (N,) site bandwidths — feed both the WAN transfer model
            and the per-epoch Iridium rebuild.
        policy: fast-loop dispatch policy (simulator signature).
        rule: slow-loop placement rule, e.g.
            :func:`repro.placement.replica.make_adaptive_rule` or
            :func:`repro.core.baselines.static_placement_rule`.
        key: PRNG key (split per slot exactly as ``simulate`` does).
        cfg: static controller knobs.
        scalar: traced control parameter forwarded to the policy (GMSA's V).
        ingest: optional (E, K, N) exogenous ingest distributions; mixed in
            with weight ``cfg.growth`` at every boundary after epoch 0.
        sizes_gb: optional (E, K) per-epoch dataset sizes (growth trace);
            defaults to ``cfg.dataset_gb`` for all epochs.
        alive: optional (T, N) per-slot {0,1} site-alive mask
            (:mod:`repro.traces.faults`). On each death edge the controller
            runs an off-schedule recovery epoch: the dead sites' backlog
            re-injects as an arrival burst, their dataset share
            re-replicates over the survivors, the rule re-places restricted
            to survivors, and the emergency WAN burst lands in
            ``recovery_cost``. Dead sites receive no dispatch and serve
            nothing while down; an all-ones mask reproduces the no-fault
            outputs bit for bit.
        move_budget: optional *traced* override of ``cfg.move_budget`` —
            the hook :func:`repro.core.sweep.sweep_placed_budgets` uses to
            vmap a whole move-budget sweep through ONE compilation (the
            epoch structure stays static, the step size becomes data).
            ``None`` (default) uses the static config value, bit-exact
            with the pre-override behavior.
        telemetry: **static** flight-recorder config. ``None``/``OFF``
            (default) keeps the jaxpr byte-identical to the pre-telemetry
            controller. SUMMARY adds a per-slot per-site backlog stream
            (extra stacked scan output); TRACE additionally threads a
            fixed-capacity event ring through both scan levels, recording
            every epoch boundary (WAN GB/$, sync $, churn, move-budget
            use), every off-schedule recovery epoch (evacuated GB, $,
            dead sites — pushed right next to the ``lax.cond`` death
            edge) and every dead-site ingest redirect. Enabled levels
            return ``(outputs, TelemetryFrame)``.
        health: optional (T, N) per-slot site health factor in [0, 1]
            (:func:`repro.traces.faults.health_trace`). Degraded-mode
            generalization of ``alive``: the factor scales the service
            rates (a 0.3-health site is a 3.3x straggler), hoisted into
            the mu trace before the scan so the slot body is untouched.
            All-ones health is the ``* 1.0`` identity — bitwise the
            no-health outputs. Death semantics (queue wipe, burst,
            re-placement) stay with ``alive``; compose the two via
            :func:`repro.traces.faults.health_to_alive` when stragglers
            may also die.
        link_health: optional (T, N, N) per-link WAN health factor
            (:func:`repro.traces.bandwidth.link_fault_trace`). Degraded
            links surcharge every epoch-boundary move by
            ``price * (1/health - 1)`` and stretch the reported move
            latency; severed links price to ``inf`` when crossed. On a
            recovery edge the evacuation routes around severed links
            (:func:`repro.placement.wan.evacuation_plan`) and bills the
            degraded premium of the routed burst. All-alive links
            surcharge exactly ``0.0`` — the ``+ 0.0`` identity keeps
            the bills bitwise.
        regions: optional (N,) int region assignment
            (:func:`repro.traces.faults.region_assignment`); requires
            ``alive``. Survivor renormalization of the placement targets
            becomes shared-fate averse: survivors in regions already
            seeing deaths are downweighted by their region's dead
            fraction, so re-placement and evacuated data prefer
            untouched regions. With every site alive the weights
            collapse to ``alive`` exactly.
    """
    tel_on = _tel_enabled(telemetry)
    tel_trace = _tel_tracing(telemetry)
    tel_hist = _tel_hist(telemetry)
    t_slots, k_types = inputs.arrivals.shape
    n = inputs.mu.shape[1]
    if inputs.data_dist.ndim != 2 or inputs.r.ndim != 3:
        raise ValueError("simulate_placed owns the time axis: pass static "
                         "(K, N) data_dist and (K, N, N) r")
    w = min(cfg.epoch_slots, t_slots)
    if t_slots % w != 0:
        raise ValueError(f"T={t_slots} must be a multiple of W={w}")
    n_epochs = t_slots // w

    if health is not None:
        health = jnp.asarray(health, jnp.float32)
        if health.shape != (t_slots, n):
            raise ValueError(f"health must be (T={t_slots}, N={n}), "
                             f"got {health.shape}")
        # Hoisted: stragglers serve slower everywhere downstream, the
        # slot body never sees the factor. All-ones is * 1.0 exactly.
        inputs = inputs._replace(
            mu=inputs.mu * health[:, :, None].astype(inputs.mu.dtype)
        )
    linky = link_health is not None
    if linky:
        link_health = jnp.asarray(link_health, jnp.float32)
        if link_health.shape != (t_slots, n, n):
            raise ValueError(
                f"link_health must be (T={t_slots}, N={n}, N={n}), "
                f"got {link_health.shape}"
            )
    if regions is not None and alive is None:
        raise ValueError("regions requires an alive mask (shared-fate "
                         "aversion only matters under site loss)")

    faulty = alive is not None
    if faulty:
        alive = jnp.asarray(alive, jnp.float32)
        if alive.shape != (t_slots, n):
            raise ValueError(f"alive mask must be (T={t_slots}, N={n}), "
                             f"got {alive.shape}")
        # Slot 0 compares against an all-alive fleet, so a trace that
        # starts dead fires its death edge (and recovery) at t=0.
        alive_prev = jnp.concatenate(
            [jnp.ones((1, n), jnp.float32), alive[:-1]], axis=0
        )

    wan = wan_topology(up, down, cfg.energy_per_gb)
    rebuild = make_allocation_rebuilder(
        up, down, size=cfg.size,
        manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    cap = (
        jnp.full((n,), jnp.inf, jnp.float32)
        if cfg.capacity_gb is None
        else jnp.asarray(cfg.capacity_gb, jnp.float32)
    )
    if sizes_gb is None:
        sizes_gb = jnp.broadcast_to(
            jnp.asarray(cfg.dataset_gb, jnp.float32), (n_epochs, k_types)
        )
    scalar = jnp.asarray(scalar, jnp.float32)
    mb = cfg.move_budget if move_budget is None else jnp.asarray(
        move_budget, jnp.float32
    )
    p_it = inputs.p_it

    ep = lambda x: x.reshape((n_epochs, w) + x.shape[1:])
    arr_ep, mu_ep = ep(inputs.arrivals), ep(inputs.mu)
    om_ep, pu_ep = ep(inputs.omega), ep(inputs.pue)
    first = jnp.arange(n_epochs) == 0

    # Match ``simulate``'s PRNG stream exactly on both of its policy paths:
    # state-independent policies consume split(key, T)[t] per slot (the
    # precomputed-vmap path), everything else splits the carried key —
    # except key-ignoring policies (``consumes_key = False``: GMSA, JSQ,
    # GREEDY), whose per-slot threefry split is skipped entirely, exactly
    # as ``simulate`` skips it.
    state_ind = getattr(policy, "state_independent", False)
    uses_key = getattr(policy, "consumes_key", True)
    wants_wpue = getattr(policy, "wants_wpue", False)
    wants_r = getattr(policy, "wants_r", False)
    if getattr(policy, "static_r", False):
        raise ValueError(
            "the controller re-derives r at every epoch boundary (and "
            "recovery edge) — a policy binding a static ratio tensor would "
            "dispatch on stale ratios; build it with "
            "make_kernel_policy(r=None) so the carried r reaches the kernel."
        )
    keys_ep = ep(jax.random.split(key, t_slots)) if state_ind else None

    q0 = jnp.zeros((n, k_types), jnp.float32)
    d0 = jnp.asarray(inputs.data_dist, jnp.float32)
    r0 = inputs.r
    if cfg.io_coupling:
        if cfg.io_per_reader:
            ones_n = jnp.ones((n,), jnp.float32)

            def io_slow(d):
                # The read pattern's diagonal (local vs remote) is price-
                # invariant — local reads are free — so a constant wpue
                # yields the actual per-reader local/remote choices.
                reads = replica_read_assignment(d, wan, ones_n)
                return io_slowdown_from_bandwidth(
                    up, down, d, cfg.io_compute_seconds, cfg.io_job_gb,
                    reads=reads,
                )                                                    # (N, K)
        else:

            def io_slow(d):
                return io_slowdown_from_bandwidth(
                    up, down, d, cfg.io_compute_seconds, cfg.io_job_gb
                )                                                    # (N,)

        # The mu trace is calibrated against the epoch-0 layout; the
        # coupling rescales it by the current layout's I/O slowdown.
        slow0 = io_slow(d0)

    def epoch(carry, xs):
        if tel_trace:
            q, key, d, ring = carry
        else:
            q, key, d = carry
        rest = xs[7:]
        arr_e, mu_e, om_e, pu_e, size_e, ing_e, is_first = xs[:7]
        if state_ind:
            keys_e, rest = rest[0], rest[1:]
        if tel_trace:
            e_idx, t_e = rest[-2], rest[-1]
            rest = rest[:-2]
        if linky:
            lh_e, rest = rest[-1], rest[:-1]
        if faulty:
            alive_e, alive_prev_e = rest
            # Aliveness *entering* the epoch drives the boundary decision;
            # deaths inside the epoch are handled by the slot-level edges.
            alive_b = alive_prev_e[0]                                 # (N,)
            any_dead_b = jnp.any(alive_b < 0.5)

        # -- slow timescale: drift, observe, re-place, pay the WAN bill.
        if ingest is not None:
            g = jnp.float32(cfg.growth)
            ing_used = ing_e
            if faulty:
                # Ingest cannot land at dead sites; it redirects to the
                # survivors (renormalized; a row aimed entirely at dead
                # sites spreads uniformly over the survivors), only when
                # any site is down.
                n_alive_b = jnp.maximum(jnp.sum(alive_b), 1.0)
                unif_b = jnp.broadcast_to(alive_b / n_alive_b, ing_e.shape)
                ing_m = _survivor_renorm(ing_e * alive_b[None, :], unif_b,
                                         axis=1)
                ing_used = jnp.where(any_dead_b, ing_m, ing_e)
            drifted = (1.0 - g) * d + g * ing_used
            drifted = drifted / jnp.maximum(
                jnp.sum(drifted, axis=1, keepdims=True), _EPS
            )
            d_drift = jnp.where(is_first, d, drifted)
        else:
            d_drift = d
        wpue_e = om_e * pu_e                                          # (W, N)
        if cfg.io_coupling:
            # The rule observes service under the *drifted* layout (its
            # decision input); the realized scale below follows its choice.
            scale_obs = io_slow(d_drift) / slow0
            if not cfg.io_per_reader:
                scale_obs = scale_obs[:, None]
            mu_bar = jnp.mean(mu_e, axis=0) * scale_obs
        else:
            mu_bar = jnp.mean(mu_e, axis=0)
        if faulty:
            mu_bar = mu_bar * alive_b[:, None]   # dead sites serve nothing
        obs = SlowObs(
            wpue_bar=jnp.mean(wpue_e, axis=0),
            mu_bar=mu_bar,
            q=q, sizes_gb=size_e, capacity_gb=cap,
            alive=alive_b if faulty else None,
        )
        target = rule(d_drift, obs)
        if faulty:
            # The controller enforces survivor-only targets regardless of
            # whether the plugged-in rule is survivor-aware; with regions
            # the weights are additionally shared-fate averse.
            surv_b = (alive_b if regions is None
                      else region_averse_weights(alive_b, regions))
            t_m = _survivor_renorm(target * surv_b[None, :], d_drift, axis=1)
            target = jnp.where(any_dead_b, t_m, target)
        stepped = d_drift + mb * (target - d_drift)
        stepped = stepped / jnp.maximum(jnp.sum(stepped, axis=1, keepdims=True), _EPS)
        d_new = jnp.where(is_first, d, stepped)
        # Fused billing (no (K, N, N) plan for the $ numbers); the plan is
        # still materialized once per epoch boundary for the bottleneck
        # latency, which needs the per-link bytes.
        wan_c, wan_e, wan_gb = plan_cost(d_drift, d_new, size_e, wan,
                                         om_e[0], pu_e[0])
        if linky:
            # Degraded links enter as an additive premium on the fused
            # bill (exactly 0.0 on all-alive links) and stretch the
            # bottleneck latency of the boundary move.
            lh_b = lh_e[0]
            sur_c, sur_e = degraded_surcharge(
                d_drift, d_new, size_e, wan, om_e[0], pu_e[0], lh_b
            )
            wan_c, wan_e = wan_c + sur_c, wan_e + sur_e
            wan_lat = transfer_latency(
                transfer_plan(d_drift, d_new, size_e), wan, link_health=lh_b
            )
        else:
            wan_lat = transfer_latency(
                transfer_plan(d_drift, d_new, size_e), wan
            )
        # Ongoing replication premium: every epoch, each replica beyond the
        # first absorbs update_fraction of its dataset at the epoch-mean price.
        sync_c = replica_sync_cost(
            d_new, size_e, wan, obs.wpue_bar, cfg.update_fraction
        )
        if tel_trace:
            # Epoch-boundary flight record: realized churn vs the rule's
            # asked-for churn (move-budget use), plus the epoch's WAN and
            # sync bills — pushed once per epoch into the carried ring.
            churn = 0.5 * jnp.sum(jnp.abs(d_new - d_drift))
            tgt_churn = 0.5 * jnp.sum(jnp.abs(target - d_drift))
            ring = ring_push(
                ring, jnp.bool_(True), e_idx * w, EV_EPOCH,
                (wan_gb, wan_c, sync_c, churn,
                 churn / jnp.maximum(tgt_churn, _EPS),
                 e_idx.astype(jnp.float32)),
            )
            if ingest is not None and faulty:
                ring = ring_push(
                    ring,
                    jnp.logical_and(any_dead_b, jnp.logical_not(is_first)),
                    e_idx * w, EV_INGEST_REDIRECT,
                    (jnp.sum(ing_e * (1.0 - alive_b)[None, :]),
                     jnp.float32(n) - jnp.sum(alive_b)),
                )
        if cfg.io_coupling:
            scale_full = io_slow(d_new) / slow0             # (N,) or (N, K)
            mu_e_raw = mu_e          # pre-scale rows: the fault path re-
            if cfg.io_per_reader:    # derives from these
                mu_e = mu_e * scale_full[None]
                scale_e = jnp.mean(scale_full, axis=-1)  # (N,) audit column
            else:
                mu_e = mu_e * scale_full[None, :, None]
                scale_e = scale_full
        else:
            scale_e = jnp.ones((n,), jnp.float32)
        r_e = jnp.where(is_first, r0, rebuild(d_new))                 # (K, N, N)
        if faulty:
            r_m = r_e * alive_b[None, None, :]
            r_m = r_m / jnp.maximum(jnp.sum(r_m, axis=-1, keepdims=True), _EPS)
            r_e = jnp.where(any_dead_b, r_m, r_e)

        # -- fast timescale: the simulator's slot body against (d_new, r_e).
        e_cost, e_raw = energy_tables(r_e, wpue_e, pu_e, p_it)

        def slot(carry2, xs2):
            if faulty:
                if tel_trace:
                    q2, key2, d_c, r_c, fired, ring2 = carry2
                else:
                    q2, key2, d_c, r_c, fired = carry2
            else:
                q2, key2 = carry2
            arrivals, mu, ec, er = xs2[:4]
            rest2 = xs2[4:]
            if state_ind:
                sub, rest2 = rest2[0], rest2[1:]
            elif uses_key:
                key2, sub = jax.random.split(key2)
            else:
                sub = key2   # key-ignoring policy: no per-slot split
            if wants_wpue and not faulty:
                wpue_t, rest2 = rest2[0], rest2[1:]
            aux = d_new
            if faulty:
                if tel_trace:
                    t_t, rest2 = rest2[-1], rest2[:-1]
                if cfg.io_coupling:
                    mu_raw_t, rest2 = rest2[-1], rest2[:-1]
                if linky:
                    lh_t, rest2 = rest2[-1], rest2[:-1]
                alive_t, alive_prev_t, om_t, pu_t = rest2
                died = alive_prev_t * (1.0 - alive_t)                 # (N,)
                any_died = jnp.any(died > 0.5)
                any_dead = jnp.any(alive_t < 0.5)
                wpue_t = om_t * pu_t
                # drop_site semantics, static-shape: wipe dead queues, form
                # the re-injection burst, renormalize the survivor layout.
                q2, d_masked, d_drop, burst = drop_site_mask(
                    q2, d_c, alive_t, died
                )
                arrivals = arrivals + burst
                mu = mu * alive_t[:, None]

                # ---- the off-schedule recovery epoch, gated by lax.cond
                # on the death edge: the heavy branch (rule re-place,
                # Iridium rebuild, fused evacuation + move billing) runs
                # ONLY on the handful of slots where a site actually dies
                # — every no-edge slot takes the trivial branch and the
                # slot body stays the base engine's few fused ops. The
                # predicate depends only on the (unbatched) alive trace,
                # so the cond survives the Monte-Carlo vmap as a cond.
                def recover(q_r, d_masked_r, d_drop_r, mu_r):
                    obs_r = SlowObs(
                        wpue_bar=wpue_t, mu_bar=mu_r, q=q_r,
                        sizes_gb=size_e, capacity_gb=cap, alive=alive_t,
                    )
                    surv_t = (alive_t if regions is None
                              else region_averse_weights(alive_t, regions))
                    tgt = _survivor_renorm(
                        rule(d_drop_r, obs_r) * surv_t[None, :],
                        d_drop_r, axis=1,
                    )
                    d_rec = d_drop_r + mb * (tgt - d_drop_r)
                    d_rec = d_rec / jnp.maximum(
                        jnp.sum(d_rec, axis=1, keepdims=True), _EPS
                    )
                    # Fused billing: cost(evac + move) = cost(evac) +
                    # cost(move) — pricing is linear in the plan, and no
                    # (K, N, N) plan is materialized on the fault path.
                    ev_c, _, ev_g = evacuation_cost(
                        d_masked_r, d_drop_r, size_e, wan, om_t, pu_t
                    )
                    mv_c, _, mv_g = plan_cost(
                        d_drop_r, d_rec, size_e, wan, om_t, pu_t
                    )
                    if linky:
                        # Route the evacuation around severed links and
                        # bill the degraded premium of the routed burst
                        # plus the move's surcharge — all inside the cond's
                        # heavy branch, and every term exactly 0.0 when
                        # the links are all alive (the bills stay bitwise).
                        plan_r = evacuation_plan(
                            d_masked_r, d_drop_r, size_e, link_health=lh_t
                        )
                        deg_c, _, _ = transfer_cost(
                            plan_r, wan, om_t, pu_t, link_health=lh_t
                        )
                        nom_c, _, _ = transfer_cost(plan_r, wan, om_t, pu_t)
                        msur_c, _ = degraded_surcharge(
                            d_drop_r, d_rec, size_e, wan, om_t, pu_t, lh_t
                        )
                        ev_c = ev_c + (deg_c - nom_c) + msur_c
                    r_rec = rebuild(d_rec) * alive_t[None, None, :]
                    r_rec = r_rec / jnp.maximum(
                        jnp.sum(r_rec, axis=-1, keepdims=True), _EPS
                    )
                    return d_rec, r_rec, ev_c + mv_c, ev_g + mv_g

                def no_recover(q_r, d_masked_r, d_drop_r, mu_r):
                    zero = jnp.zeros((), jnp.float32)
                    return d_c, r_c, zero, zero

                d_c, r_c, rec_cost, rec_gb = jax.lax.cond(
                    any_died, recover, no_recover, q2, d_masked, d_drop, mu
                )
                fired = jnp.logical_or(fired, any_died)
                if tel_trace:
                    # The flight record of the recovery epoch the cond just
                    # (maybe) ran: a masked ring write, so the no-edge slot
                    # costs a handful of fused selects and writes nothing.
                    ring2 = ring_push(
                        ring2, any_died, t_t, EV_RECOVERY,
                        (rec_gb, rec_cost, jnp.sum(died),
                         jnp.argmax(died).astype(jnp.float32)),
                    )
                    # Revival edge: the companion event the SLO clock
                    # anchors to (time-to-SLO from the true repair slot,
                    # not the death slot — :mod:`repro.telemetry.collect`
                    # pairs the two). Masked write: an all-ones mask
                    # leaves the ring bitwise untouched.
                    revived = alive_t * (1.0 - alive_prev_t)
                    ring2 = ring_push(
                        ring2, jnp.any(revived > 0.5), t_t, EV_REPAIR,
                        (jnp.sum(revived),
                         jnp.argmax(revived).astype(jnp.float32)),
                    )
                # Epoch tables go stale the moment a recovery re-places
                # mid-epoch; re-derive this slot's row from the carried r
                # (also cond-gated: no fault so far -> no extra einsums).
                ec, er = jax.lax.cond(
                    fired,
                    lambda rr: energy_row(rr, wpue_t, pu_t, p_it),
                    lambda rr: (ec, er),
                    r_c,
                )
                if cfg.io_coupling:
                    # The epoch-granular mu scale is derived from the
                    # boundary layout d_new; the moment a recovery re-
                    # places mid-epoch that scale is STALE — dead sites'
                    # data landed on survivors, whose I/O slowdown rose.
                    # Re-derive this slot's scale from the carried layout
                    # (cond-gated like ec/er: no fault so far, no extra
                    # work; fired=False is the exact identity).
                    def _io_rescale(dc):
                        s = io_slow(dc) / slow0
                        if not cfg.io_per_reader:
                            s = s[:, None]
                        return mu_raw_t * s * alive_t[:, None]

                    mu = jax.lax.cond(
                        fired, _io_rescale, lambda dc: mu, d_c
                    )
                aux = d_c
            if wants_wpue:
                # The kernel-dispatch aux contract: raw per-slot prices,
                # and (wants_r) the ratio tensor actually in force — the
                # carried r_c on the fault path (recovery re-places mid-
                # epoch), the epoch rebuild r_e otherwise.
                aux = (aux, wpue_t)
            if wants_r:
                aux = aux + ((r_c if faulty else r_e),)
            f = policy(sub, q2, arrivals, mu, ec, aux, scalar)
            if faulty:
                # No dispatch mass to dead sites, whatever the policy says.
                n_alive = jnp.maximum(jnp.sum(alive_t), 1.0)
                f_fb = jnp.broadcast_to((alive_t / n_alive)[:, None], f.shape)
                f_m = _survivor_renorm(f * alive_t[:, None], f_fb, axis=0)
                f = jnp.where(any_dead, f_m, f)
            q_next, out = slot_step(q2, f, arrivals, mu, ec, er)
            if tel_on:
                tel_out = (jnp.sum(q_next, axis=-1),)     # (N,) per-site q
                if tel_hist:
                    # Per-site slice of the bill ``slot_step`` just summed
                    # — recorded in-scan because recovery epochs rewrite
                    # the energy rows mid-epoch (``ec`` is cond-carried,
                    # not reconstructible from the epoch tables post-scan).
                    tel_out = tel_out + (
                        jnp.sum(f * arrivals[None, :] * ec.T, axis=1),
                    )
            else:
                tel_out = ()
            if faulty:
                carry_next = (q_next, key2, d_c, r_c, fired)
                if tel_trace:
                    carry_next = carry_next + (ring2,)
                return carry_next, out + (rec_cost, rec_gb) + tel_out
            return (q_next, key2), out + tel_out

        slot_xs = (arr_e, mu_e, e_cost, e_raw)
        if state_ind:
            slot_xs = slot_xs + (keys_e,)
        if wants_wpue and not faulty:
            slot_xs = slot_xs + (wpue_e,)
        if faulty:
            slot_xs = slot_xs + (alive_e, alive_prev_e, om_e, pu_e)
            if linky:
                slot_xs = slot_xs + (lh_e,)
            if cfg.io_coupling:
                slot_xs = slot_xs + (mu_e_raw,)
            if tel_trace:
                slot_xs = slot_xs + (t_e,)
            carry0 = (q, key, d_new, r_e, jnp.bool_(False))
            if tel_trace:
                carry0 = carry0 + (ring,)
                (q, key, d_carry, _, _, ring), slot_outs = jax.lax.scan(
                    slot, carry0, slot_xs
                )
            else:
                (q, key, d_carry, _, _), slot_outs = jax.lax.scan(
                    slot, carry0, slot_xs
                )
        else:
            (q, key), slot_outs = jax.lax.scan(slot, (q, key), slot_xs)
            d_carry = d_new
        epoch_out = slot_outs + (d_new, r_e, wan_c, wan_e, wan_gb, wan_lat,
                                 sync_c, scale_e)
        carry_out = (q, key, d_carry)
        if tel_trace:
            carry_out = carry_out + (ring,)
        return carry_out, epoch_out

    xs = (arr_ep, mu_ep, om_ep, pu_ep, sizes_gb,
          ingest if ingest is not None else jnp.zeros((n_epochs, k_types, n)),
          first)
    if state_ind:
        xs = xs + (keys_ep,)
    if faulty:
        xs = xs + (ep(alive), ep(alive_prev))
    if linky:
        xs = xs + (ep(link_health),)
    carry_init = (q0, key, d0)
    if tel_trace:
        xs = xs + (jnp.arange(n_epochs, dtype=jnp.int32),
                   jnp.arange(t_slots, dtype=jnp.int32).reshape(n_epochs, w))
        carry_init = carry_init + (ring_init(telemetry.capacity),)
        (q_final, _, _, ring_out), outs = jax.lax.scan(epoch, carry_init, xs)
    else:
        (q_final, _, _), outs = jax.lax.scan(epoch, carry_init, xs)
    # Per-slot scan columns lead; the epoch-level audit trail follows.
    n_slot_cols = (5 + (2 if faulty else 0) + (1 if tel_on else 0)
                   + (1 if tel_hist else 0))
    slot_cols = outs[:n_slot_cols]
    (d_tr, r_tr, wc, we, wgb, wlat, sc, msc) = outs[n_slot_cols:]
    (cost, energy, btot, bavg, f_trace) = slot_cols[:5]
    if faulty:
        rec_cost, rec_gb = slot_cols[5:7]
    else:
        rec_cost = jnp.zeros((n_epochs, w), jnp.float32)
        rec_gb = jnp.zeros((n_epochs, w), jnp.float32)
    flat = lambda x: x.reshape((t_slots,) + x.shape[2:])
    placed = PlacedOutputs(
        cost=flat(cost), energy=flat(energy),
        backlog_total=flat(btot), backlog_avg=flat(bavg),
        q_final=q_final, f_trace=flat(f_trace),
        placements=d_tr, r_trace=r_tr,
        wan_cost=wc, wan_energy=we, wan_gb=wgb, wan_latency_s=wlat,
        sync_cost=sc,
        recovery_cost=flat(rec_cost), recovery_gb=flat(rec_gb),
        mu_scale=msc,
    )
    if tel_on:
        q_site = slot_cols[-2] if tel_hist else slot_cols[-1]  # (E, W, N)
        metrics = {"q_site": flat(q_site)}
        if tel_hist:
            site_cost = flat(slot_cols[-1])                    # (T, N)
            metrics["site_cost_hist"] = hist_series(
                telemetry.hist, site_cost, axis=0
            )                                                  # (N, B)
        return placed, TelemetryFrame(
            ring=ring_out if tel_trace else ring_init(1),
            metrics=metrics,
        )
    return placed


@functools.partial(
    jax.jit,
    static_argnames=("build_inputs", "policy", "rule", "cfg", "n_runs",
                     "telemetry", "mesh"),
)
def simulate_placed_many(
    build_inputs: Callable[[Array], SimInputs],
    up: Array,
    down: Array,
    policy: PolicyFn,
    rule: PlacementRule,
    key: Array,
    n_runs: int,
    cfg: PlacementConfig,
    scalar: float | Array = 0.0,
    ingest: Array | None = None,
    sizes_gb: Array | None = None,
    alive: Array | None = None,
    move_budget: Array | None = None,
    telemetry: TelemetryConfig | None = None,
    health: Array | None = None,
    link_health: Array | None = None,
    regions: Array | None = None,
    mesh=None,
) -> PlacedOutputs:
    """Monte-Carlo replication of :func:`simulate_placed` (vmap over keys).

    Mirrors ``simulate_many``: fresh stochastic traces + policy randomness
    per run, deterministic traces (prices, PUE, drift, the site-alive mask
    and the health/link-health factors) shared. One compilation serves
    every run. With telemetry enabled the frames stack on the runs axis
    like everything else — decode one run's lane with
    :func:`repro.telemetry.collect.collect_records`.

    ``mesh`` (static) shards the runs axis over a host-device mesh
    (:func:`repro.distributed.mesh.runs_mesh`) — same split keys, bitwise
    the single-device outputs at every device count.
    """
    keys = jax.random.split(key, n_runs)

    def one(run_key):
        k_build, k_sim = jax.random.split(run_key)
        return simulate_placed(
            build_inputs(k_build), up, down, policy, rule, k_sim, cfg,
            scalar=scalar, ingest=ingest, sizes_gb=sizes_gb, alive=alive,
            move_budget=move_budget, telemetry=telemetry, health=health,
            link_health=link_health, regions=regions,
        )

    if mesh is None:
        return jax.vmap(one)(keys)
    from repro.distributed.mesh import sharded_runs

    return sharded_runs(one, keys, mesh)


def summarize_placed(outs: PlacedOutputs) -> dict:
    """Time-averaged scalars incl. WAN/sync/recovery bills (any runs axis)."""
    t_slots = outs.cost.shape[-1]
    dispatch = jnp.mean(outs.cost)
    wan_per_slot = jnp.mean(jnp.sum(outs.wan_cost, axis=-1)) / t_slots
    sync_per_slot = jnp.mean(jnp.sum(outs.sync_cost, axis=-1)) / t_slots
    recovery_per_slot = jnp.mean(outs.recovery_cost)
    return {
        "time_avg_dispatch_cost": float(dispatch),
        "time_avg_wan_cost": float(wan_per_slot),
        "time_avg_sync_cost": float(sync_per_slot),
        "time_avg_recovery_cost": float(recovery_per_slot),
        "time_avg_total_cost": float(
            dispatch + wan_per_slot + sync_per_slot + recovery_per_slot
        ),
        "time_avg_energy": float(jnp.mean(outs.energy)),
        "time_avg_backlog": float(jnp.mean(outs.backlog_avg)),
        "total_wan_gb": float(jnp.mean(jnp.sum(outs.wan_gb, axis=-1))),
        "mean_mu_scale": float(jnp.mean(outs.mu_scale)),
        "total_recovery_gb": float(jnp.mean(jnp.sum(outs.recovery_gb, axis=-1))),
        "max_move_latency_s": float(jnp.max(outs.wan_latency_s)),
        "final_backlog_total": float(jnp.mean(outs.q_final.sum(axis=(-2, -1)))),
    }
