"""repro.placement — two-timescale data placement & replica selection.

The paper's GMSA decides *per slot* which DC manages each job against a
frozen dataset layout; this subsystem adds the slow timescale the paper
names as future work (Sec. VI): every W slots a placement controller may
re-place / replicate the datasets across sites — under a WAN transfer-cost
model and per-site storage caps — while GMSA keeps dispatching against the
current layout.

* :mod:`repro.placement.wan`        — WAN topology, transfer energy/latency.
* :mod:`repro.placement.replica`    — placement & replica-selection scoring
  (vectorized greedy / LP-vertex rules in the style of ``gmsa_dispatch``).
* :mod:`repro.placement.controller` — the two-timescale scan-of-scans engine
  (``simulate_placed`` / ``simulate_placed_many``), jit-compiled end-to-end
  and vmappable over Monte-Carlo keys.

The STATIC-PLACEMENT comparison baseline lives with the other baselines in
:func:`repro.core.baselines.static_placement_rule`; drifting-dataset traces
come from :mod:`repro.traces.drift`; site-failure alive masks (the chaos
scenario class, driving the controller's off-schedule recovery epochs) come
from :mod:`repro.traces.faults`.
"""

from repro.placement.controller import (
    PlacedOutputs,
    PlacementConfig,
    SlowObs,
    simulate_placed,
    simulate_placed_many,
    summarize_placed,
)
from repro.placement.replica import (
    capacity_project,
    effective_replicas,
    expected_read_cost,
    hosting_scores,
    make_adaptive_rule,
    replica_read_assignment,
    replication_premium,
    sync_cost,
    target_placement,
)
from repro.placement.wan import (
    WanModel,
    evacuation_cost,
    evacuation_plan,
    expected_pull,
    link_price_matrix,
    plan_cost,
    transfer_cost,
    transfer_latency,
    transfer_plan,
    wan_topology,
)

__all__ = [
    "PlacedOutputs",
    "PlacementConfig",
    "SlowObs",
    "simulate_placed",
    "simulate_placed_many",
    "summarize_placed",
    "capacity_project",
    "effective_replicas",
    "expected_read_cost",
    "hosting_scores",
    "make_adaptive_rule",
    "replica_read_assignment",
    "replication_premium",
    "sync_cost",
    "target_placement",
    "WanModel",
    "evacuation_cost",
    "evacuation_plan",
    "expected_pull",
    "link_price_matrix",
    "plan_cost",
    "transfer_cost",
    "transfer_latency",
    "transfer_plan",
    "wan_topology",
]
