"""granite-3-2b — 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "granite-3-2b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=40,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=49155,
        act="swiglu",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        act="swiglu",
        tie_embeddings=True,
    )


register_arch(ARCH_ID, full, smoke)
