"""hymba-1.5b — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16; parallel attention + Mamba heads in each layer, sliding-window
attention in most layers. [arXiv:2411.13676; hf]

``long_500k`` runs for this arch: attention is sliding-window (bounded KV)
and the SSM path carries long-range state — sub-quadratic end to end.
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "hymba-1.5b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        head_dim=64,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        hybrid=True,
        sliding_window=1024,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_expand=2,
        hybrid=True,
        sliding_window=64,
        act="swiglu",
    )


register_arch(ARCH_ID, full, smoke)
