"""hubert-xlarge — 48L d_model=1280 16H (MHA, kv=16) d_ff=5120 vocab=504.
Encoder-only (same backbone as wav2vec2). [arXiv:2106.07447; unverified]

Audio frontend (the 7-layer strided conv feature extractor) is a STUB:
``input_specs()`` supplies precomputed frame embeddings. Encoder-only =>
no autoregressive decode: decode_32k / long_500k cells are skipped and
documented (DESIGN.md §4). "vocab" is the HuBERT codebook (504 clusters)
used as the masked-prediction target inventory.
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "hubert-xlarge"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        act="gelu",
        frontend="audio",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=64,
        causal=False,
        act="gelu",
        frontend="audio",
    )


register_arch(ARCH_ID, full, smoke)
