"""Shared configuration dataclasses for the model zoo and workload shapes."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture's hyperparameters (LM backbone view).

    ``[audio]``/``[vlm]`` entries describe the transformer backbone only; the
    modality frontend is a stub supplying precomputed frame/patch embeddings
    (``repro.models.frontends``).
    """

    name: str
    family: str                   # dense | moe | hybrid | vlm | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attention-free (ssm)
    num_kv_heads: int
    d_ff: int                     # dense FFN hidden (or 0 for pure ssm)
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0          # routed experts
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0             # expert hidden size (0 -> d_ff)
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256          # SSD chunk length
    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True           # False for encoder-only backbones
    sliding_window: int = 0       # >0 -> sliding-window attention (hybrid)
    norm_eps: float = 1e-5
    act: str = "swiglu"           # swiglu | gelu
    tie_embeddings: bool = False
    # --- hybrid (Hymba): parallel attention + SSM heads in each layer ---
    hybrid: bool = False
    # --- modality frontend stub ---
    frontend: str = ""            # "" | "vision" | "audio"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameter count N (embedding + blocks), exact to the layer
        definitions in repro.models (used for MODEL_FLOPS = 6·N·D)."""
        from repro.models.lm import count_params  # local import: avoid cycle

        return count_params(self)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k + shared experts only)."""
        from repro.models.lm import count_params

        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.global_batch * self.seq_len


#: The four assigned LM-family shapes (identical across the 10 archs).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; else the documented reason.

    Policy (DESIGN.md §4):
      * encoder-only backbones have no autoregressive step -> no decode shapes;
      * ``long_500k`` needs sub-quadratic attention -> SSM / sliding-window
        hybrids only; pure full-attention archs skip it.
    """
    if shape.kind == "decode" and not model.causal:
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k":
        subquadratic = (not model.has_attention) or model.sliding_window > 0
        if not subquadratic:
            return False, "full quadratic attention: 500k context inapplicable"
    return True, ""


def applicable_shapes(model: ModelConfig) -> list[ShapeConfig]:
    return [s for s in SHAPES.values() if shape_applicable(model, s)[0]]
