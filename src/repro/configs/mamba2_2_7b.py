"""mamba2-2.7b — 64L d_model=2560, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]

d_inner = 2*d_model = 5120, head_dim = 64 -> 80 SSD heads.
``long_500k`` runs for this arch (O(1) decode state).
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "mamba2-2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        num_layers=2,
        d_model=128,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=32,
        tie_embeddings=True,
    )


register_arch(ARCH_ID, full, smoke)
