"""qwen2-0.5b — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
GQA with QKV bias; tied embeddings. [arXiv:2407.10671; hf]
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "qwen2-0.5b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151936,
        qkv_bias=True,
        act="swiglu",
        tie_embeddings=True,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=320,
        vocab_size=512,
        qkv_bias=True,
        act="swiglu",
        tie_embeddings=True,
    )


register_arch(ARCH_ID, full, smoke)
