"""repro.configs — architecture & experiment configuration registry.

Every assigned architecture has one module here defining its exact published
configuration plus a reduced smoke-test variant, self-registering under its
``--arch`` id. ``repro.configs.registry`` resolves ids; ``repro.configs.base``
holds the shared dataclasses; ``repro.configs.facebook_4dc`` is the paper's
own simulation setup (Sec. V-A).
"""

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    shape_applicable,
    applicable_shapes,
)
from repro.configs.registry import register_arch, get_arch, list_archs

# Self-registering architecture modules (import order = registry order).
from repro.configs import (  # noqa: F401
    phi35_moe,
    deepseek_moe_16b,
    granite_3_2b,
    stablelm_12b,
    phi4_mini,
    qwen2_0_5b,
    hymba_1_5b,
    internvl2_76b,
    mamba2_2_7b,
    hubert_xlarge,
)
from repro.configs.facebook_4dc import PaperSimConfig

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "shape_applicable",
    "applicable_shapes",
    "register_arch",
    "get_arch",
    "list_archs",
    "PaperSimConfig",
]
