"""phi4-mini-3.8b — 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
RoPE, SwiGLU, GQA. [arXiv:2412.08905; hf]
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "phi4-mini-3.8b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=200064,
        act="swiglu",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        act="swiglu",
        tie_embeddings=True,
    )


register_arch(ARCH_ID, full, smoke)
