"""deepseek-moe-16b — 28L d_model=2048 16H (GQA kv=16, i.e. MHA) d_ff=1408
vocab=102400, MoE: 2 shared + 64 routed experts, top-6, fine-grained
(expert hidden = 1408). [arXiv:2401.06066; hf]

Simplification vs. HF checkpoint: the released model's first layer is a
dense FFN (d_ff=10944); we apply the MoE block uniformly to all layers —
the paper's Table 1 architecture, noted here per DESIGN.md.
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "deepseek-moe-16b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        num_experts=64,
        num_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=96,
        vocab_size=512,
        num_experts=8,
        num_shared_experts=2,
        top_k=3,
        moe_d_ff=96,
        act="swiglu",
    )


register_arch(ARCH_ID, full, smoke)
