"""The paper's own evaluation setup (Sec. V-A): four Facebook DCs.

* Sites: Prineville OR, Forest City NC, Luleå SE, Altoona IA.
* One job type; Poisson arrivals at 350K jobs/month (40.5 jobs / 5-min slot).
* omega(t): electricity-price traces; PUE(t): dashboard-like PUE traces.
* r: Iridium task-allocation ratios; 100 GB input/job; 100 Mb/s–2 Gb/s links.
* 24 h horizon at 5-min slots (T = 288); results averaged over 1000 runs.
* P^k = 1 (the paper's "one watt" per-job IT energy).

``make_sim_builder`` returns (static SimInputs pieces, per-run builder) so
``repro.core.simulator.simulate_many`` can vmap fresh stochastic traces
(arrivals, service rates) per run while keeping the price/PUE/placement
traces fixed — matching the paper's methodology (real traces are one
realization; the randomness across the 1000 runs is in arrivals/service).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

import numpy as np

from repro.core.iridium import build_task_allocation
from repro.core.simulator import SimInputs
from repro.traces.arrivals import (
    poisson_pair_from_tables,
    poisson_table,
    rate_per_slot,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.datasets import (
    DEFAULT_CAPACITY_SHARES,
    dataset_distribution,
    io_slowdown_from_bandwidth,
)
from repro.traces.price import FACEBOOK_SITES, price_trace
from repro.traces.pue import pue_trace


@dataclasses.dataclass(frozen=True)
class PaperSimConfig:
    """Sec. V-A experimental configuration (defaults = the paper's values)."""

    n_sites: int = 4
    k_types: int = 1
    t_slots: int = 288                 # 24 h of 5-min slots
    slot_minutes: float = 5.0
    monthly_jobs: float = 350_000.0
    a_max: float = 128.0               # finite A_max (P[poisson(40.5)>128]≈0)
    mu_max: float = 128.0
    capacity_shares: tuple = DEFAULT_CAPACITY_SHARES
    manager_share: float = 0.62
    map_share: float = 0.6
    n_runs: int = 1000
    trace_seed: int = 2060             # fixes price/PUE/placement traces
    v: float = 1.0                     # GMSA trade-off parameter

    @property
    def lam(self) -> float:
        return rate_per_slot(self.slot_minutes, self.monthly_jobs)


def make_sim_builder(
    cfg: PaperSimConfig,
) -> tuple[SimInputs, Callable]:
    """Build the paper's simulation inputs.

    Returns:
        (template, build_inputs) where ``template`` carries the deterministic
        traces (usable directly for a single run) and ``build_inputs(key)``
        regenerates the stochastic components for Monte-Carlo replication.
    """
    root = jax.random.key(cfg.trace_seed)
    k_price, k_pue, k_bw, k_data, k_arr, k_mu = jax.random.split(root, 6)

    sites = FACEBOOK_SITES[: cfg.n_sites]
    omega = price_trace(k_price, cfg.t_slots, cfg.slot_minutes, sites)
    pue = pue_trace(k_pue, cfg.t_slots, cfg.slot_minutes, sites)
    up, down = bandwidth_draw(k_bw, cfg.n_sites)
    data_dist = dataset_distribution(k_data, cfg.k_types, cfg.n_sites)
    r = build_task_allocation(
        data_dist, up, down,
        size=1.0, manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    p_it = jnp.ones((cfg.k_types,), jnp.float32)   # paper: 1 unit per job
    slowdown = io_slowdown_from_bandwidth(up, down, data_dist)

    # Static-rate Poisson CDF tables (exact truncated sampling — §Perf v4):
    # arrivals (K, A_max+1); service rates (N, K, mu_max+1).
    arr_cdf = jnp.asarray(poisson_table(
        np.full((cfg.k_types,), cfg.lam), int(cfg.a_max)
    ))
    mu_mean = (
        np.asarray(cfg.capacity_shares, np.float64)[:, None]
        * np.asarray(slowdown, np.float64)[:, None]
        * cfg.lam
        * np.ones((1, cfg.k_types))
    )
    mu_cdf = jnp.asarray(poisson_table(mu_mean, int(cfg.mu_max)))

    def stochastic(key) -> tuple:
        ka, km = jax.random.split(key)
        # One batched binary search for both traces (§Perf v6) — bitwise
        # the same draws as the two separate poisson_from_table calls.
        return poisson_pair_from_tables(ka, km, arr_cdf, mu_cdf, cfg.t_slots)

    arr0, mu0 = stochastic(jax.random.fold_in(root, 99))
    template = SimInputs(
        arrivals=arr0, mu=mu0, omega=omega, pue=pue,
        r=r, p_it=p_it, data_dist=data_dist,
    )

    def build_inputs(key) -> SimInputs:
        arrivals, mu = stochastic(key)
        return template._replace(arrivals=arrivals, mu=mu)

    return template, build_inputs
