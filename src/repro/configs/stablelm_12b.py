"""stablelm-12b — 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-12b; hf]
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "stablelm-12b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=100352,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=160,
        num_heads=4,
        num_kv_heads=2,
        d_ff=432,
        vocab_size=512,
        act="swiglu",
    )


register_arch(ARCH_ID, full, smoke)
