"""Synthetic fleet-scale scenario: N = 256 heterogeneous sites (§Perf v6).

The paper's evaluation stops at four DCs; the ROADMAP's north star is a
control plane that serves *fleet* scale — hundreds of sites, heterogeneous
power markets, PUE climates and access links — where the (K, N, N) ratio
tensor is what the :mod:`repro.kernels.gmsa_score` Pallas kernel was tiled
for (N_T = J_T = 128: at N = 256 the grid is 2x2 tiles per type-block).
This module synthesizes that scenario:

* **sites**: 256 :class:`repro.traces.price.SiteSpec`s drawn from seeded
  distributions spanning the real spread — base prices log-uniform
  ~$9–45/MWh (hydro-rich grids to expensive coastal markets), UTC offsets
  over the whole day (follow-the-sun arbitrage exists by construction),
  PUE 1.04–1.25, diurnal amplitudes proportional to base price;
* **traces**: the same calibrated synthesizers the paper setup uses
  (:func:`repro.traces.price.price_trace`, :func:`repro.traces.pue.pue_trace`)
  — they are site-count agnostic;
* **bandwidths**: fleet backbone, 1–40 Gb/s per access link;
* **datasets**: K = 8 job classes, skewed Dirichlet layouts (data lives
  where it was ingested), Iridium ratios from the same
  :func:`repro.core.iridium.build_task_allocation` as the 4-DC setup;
* **arrivals/service**: the inverse-CDF Poisson tables of the paper
  config, scaled to fleet traffic (``jobs_per_slot`` per class) with
  capacity spread over 256 sites.

``make_fleet_builder`` returns the same ``(template, build_inputs)``
contract as :func:`repro.configs.facebook_4dc.make_sim_builder`, so every
engine and bench composes unchanged. The canonical end-to-end consumer is
``benchmarks/kernel_bench.py``: a full GMSA run through
``gmsa_dispatch(..., impl="kernel")`` (interpret mode on CPU/CI).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.iridium import build_task_allocation
from repro.core.simulator import SimInputs
from repro.traces.arrivals import poisson_pair_from_tables, poisson_table
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.datasets import (
    dataset_distribution,
    io_slowdown_from_bandwidth,
)
from repro.traces.price import SiteSpec, price_trace
from repro.traces.pue import pue_trace


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """The synthetic N = 256 fleet scenario (hashable: jit-static)."""

    n_sites: int = 256
    k_types: int = 8
    t_slots: int = 288                 # 24 h of 5-min slots
    slot_minutes: float = 5.0
    jobs_per_slot: float = 80.0        # per class — fleet-scale traffic
    a_max: float = 192.0               # P[poisson(80) > 192] ~ 1e-22
    mu_max: float = 64.0
    headroom: float = 1.4              # fleet capacity / offered load
    bw_lo_gbps: float = 1.0            # fleet backbone access links
    bw_hi_gbps: float = 40.0
    dataset_conc: float = 0.5          # skewed layouts (ingest locality)
    manager_share: float = 0.3
    map_share: float = 0.6
    n_runs: int = 100
    trace_seed: int = 4096
    v: float = 10.0                    # GMSA trade-off parameter


def fleet_sites(cfg: FleetConfig) -> tuple[SiteSpec, ...]:
    """Synthesize the fleet's per-site price/PUE climates (seeded)."""
    rng = np.random.default_rng(cfg.trace_seed)
    base = np.exp(rng.uniform(np.log(9.0), np.log(45.0), cfg.n_sites))
    amp = base * rng.uniform(0.15, 0.30, cfg.n_sites)
    noise = base * rng.uniform(0.02, 0.06, cfg.n_sites)
    off = rng.uniform(-12.0, 12.0, cfg.n_sites)
    pue0 = rng.uniform(1.04, 1.25, cfg.n_sites)
    pue_amp = rng.uniform(0.01, 0.05, cfg.n_sites)
    return tuple(
        SiteSpec(
            name=f"site{i:03d}",
            region="synthetic",
            utc_offset_h=float(off[i]),
            base_price=float(base[i]),
            diurnal_amp=float(amp[i]),
            noise_std=float(noise[i]),
            base_pue=float(pue0[i]),
            pue_amp=float(pue_amp[i]),
        )
        for i in range(cfg.n_sites)
    )


def make_fleet_builder(
    cfg: FleetConfig,
) -> tuple[SimInputs, Callable]:
    """Build the fleet scenario's inputs.

    Returns:
        (template, build_inputs): deterministic trace bundle (usable
        directly for one run) and the per-run stochastic regenerator for
        Monte-Carlo replication — the ``facebook_4dc`` contract at N = 256.
    """
    root = jax.random.key(cfg.trace_seed)
    k_price, k_pue, k_bw, k_data, _, _ = jax.random.split(root, 6)

    sites = fleet_sites(cfg)
    omega = price_trace(k_price, cfg.t_slots, cfg.slot_minutes, sites)
    pue = pue_trace(k_pue, cfg.t_slots, cfg.slot_minutes, sites)
    up, down = bandwidth_draw(
        k_bw, cfg.n_sites, lo=cfg.bw_lo_gbps, hi=cfg.bw_hi_gbps
    )
    data_dist = dataset_distribution(
        k_data, cfg.k_types, cfg.n_sites, conc=cfg.dataset_conc
    )
    r = build_task_allocation(
        data_dist, up, down,
        size=1.0, manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    p_it = jnp.ones((cfg.k_types,), jnp.float32)
    slowdown = io_slowdown_from_bandwidth(up, down, data_dist)

    # Heterogeneous per-site capacity shares summing to `headroom` of the
    # per-class load — big cheap sites, small expensive ones, exactly the
    # regime GMSA arbitrages.
    rng = np.random.default_rng(cfg.trace_seed + 1)
    shares = rng.dirichlet(np.full(cfg.n_sites, 2.0)) * cfg.headroom

    arr_cdf = jnp.asarray(poisson_table(
        np.full((cfg.k_types,), cfg.jobs_per_slot), int(cfg.a_max)
    ))
    mu_mean = (
        shares[:, None]
        * np.asarray(slowdown, np.float64)[:, None]
        * cfg.jobs_per_slot
        * np.ones((1, cfg.k_types))
    )
    mu_cdf = jnp.asarray(poisson_table(mu_mean, int(cfg.mu_max)))

    def stochastic(key) -> tuple:
        ka, km = jax.random.split(key)
        return poisson_pair_from_tables(ka, km, arr_cdf, mu_cdf, cfg.t_slots)

    arr0, mu0 = stochastic(jax.random.fold_in(root, 99))
    template = SimInputs(
        arrivals=arr0, mu=mu0, omega=omega, pue=pue,
        r=r, p_it=p_it, data_dist=data_dist,
    )

    def build_inputs(key) -> SimInputs:
        arrivals, mu = stochastic(key)
        return template._replace(arrivals=arrivals, mu=mu)

    return template, build_inputs


def make_score_operands(cfg: FleetConfig, warm_slots: int = 48):
    """One realistic fleet-scale slot of kernel operands.

    Returns ``(q, mu, a, vp, r, wpue, e)`` — everything the three dispatch
    arms of the ``benchmarks/kernel_bench.py`` timing matrix consume:

    * ``q`` (K, N) is a *developed* backlog — the reference engine is run
      for ``warm_slots`` so the argmin is scored against the queue state
      GMSA actually produces, not an arbitrary random tensor;
    * ``mu``/``a`` are slot-0 draws from the scenario's Poisson tables,
      ``wpue`` the slot-0 prices, ``r`` the scenario's (K, N, N) Iridium
      ratios, ``vp = V * P^k``;
    * ``e`` (K, N) is the hoisted-einsum per-job cost row
      (:func:`repro.core.simulator.energy_row`) the precomputed-table arm
      dispatches from.

    Kernel orientation throughout: (K, N), matching
    :func:`repro.kernels.gmsa_score.ops.gmsa_score`.
    """
    from repro.core.gmsa import gmsa_policy
    from repro.core.simulator import energy_row, simulate

    template, _ = make_fleet_builder(cfg)
    warm = template._replace(
        arrivals=template.arrivals[:warm_slots],
        mu=template.mu[:warm_slots],
        omega=template.omega[:warm_slots],
        pue=template.pue[:warm_slots],
    )
    outs = simulate(warm, gmsa_policy, jax.random.key(cfg.trace_seed), cfg.v)
    q = outs.q_final.T                                   # (K, N)
    mu = template.mu[0].T                                # (K, N)
    a = template.arrivals[0]                             # (K,)
    vp = cfg.v * template.p_it                           # (K,)
    wpue = template.omega[0] * template.pue[0]           # (N,)
    e, _ = energy_row(template.r, wpue, template.pue[0], template.p_it)
    return q, mu, a, vp, template.r, wpue, e


def make_serve_grid(cfg: FleetConfig, k_classes: int, slots: int):
    """The fleet scenario re-cut as a SERVING pod grid.

    Returns ``(omega, pue, r, up, down, layout, shares)`` — everything
    :class:`repro.serve.engine.FleetEngine` needs to run an N = 256 pod
    grid: the same seeded site climates and backbone as the batch
    scenario, a ``k_classes``-dataset layout (the KV-prefix placement the
    replica-read router serves prefill from), Iridium ratios over it, and
    the Dirichlet capacity shares (summing to ``cfg.headroom`` of offered
    load) to hand to ``FleetConfig.capacity_shares``. With
    ``dispatch="kernel"`` the engine's per-slot decision then runs
    through ``gmsa_dispatch(impl="kernel")`` — the Pallas path this grid
    was tiled for (interpret mode on CPU/CI).
    """
    root = jax.random.key(cfg.trace_seed)
    k_price, k_pue, k_bw, k_data, _, _ = jax.random.split(root, 6)
    sites = fleet_sites(cfg)
    omega = np.asarray(price_trace(k_price, slots, cfg.slot_minutes, sites))
    pue = np.asarray(pue_trace(k_pue, slots, cfg.slot_minutes, sites))
    up, down = bandwidth_draw(
        k_bw, cfg.n_sites, lo=cfg.bw_lo_gbps, hi=cfg.bw_hi_gbps
    )
    layout = dataset_distribution(
        k_data, k_classes, cfg.n_sites, conc=cfg.dataset_conc
    )
    r = np.asarray(build_task_allocation(
        layout, up, down,
        size=1.0, manager_share=cfg.manager_share, map_share=cfg.map_share,
    ))
    rng = np.random.default_rng(cfg.trace_seed + 1)
    shares = tuple(
        float(s) for s in rng.dirichlet(np.full(cfg.n_sites, 2.0)) * cfg.headroom
    )
    return omega, pue, r, up, down, layout, shares
