"""internvl2-76b — 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
InternViT-6B vision frontend + LLaMA-3-70B-class language backbone.
[arXiv:2404.16821; unverified]

The vision tower is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings which are linearly projected and prepended to
the token stream (repro.models.frontends.VisionStub).
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "internvl2-76b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        act="swiglu",
        rope_theta=500_000.0,
        frontend="vision",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        act="swiglu",
        frontend="vision",
    )


register_arch(ARCH_ID, full, smoke)
