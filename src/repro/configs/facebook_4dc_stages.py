"""Multi-stage Facebook-4DC scenario (the staged-jobs evaluation setup).

The paper's Sec. V-A setup — four Facebook DCs, diurnal prices, PUE
traces, Iridium ratios — extended with the stage structure the base
simulator collapses: a K = 3 mix of shuffle-heavy analytics jobs (2–3
stage chains, ~100 GB input each) whose intermediate data must physically
cross the WAN between consecutive stages' sites.

The canonical mix is hand-calibrated (exactly as the paper pins its own
evaluation constants) so the scenario is stable and the trade-off it
exercises is real:

* **ETL/filter-join** (3 stages, compute 0.30/0.45/0.25, shuffle
  60 -> 12 GB): dataset concentrated at ForestCity — the priciest power —
  so "pull the shuffle to the data" and "chase cheap power" genuinely
  conflict.
* **scan-aggregate** (2 stages, 0.30/0.70, shuffle 30 GB): Altoona-heavy.
* **iterative/ML** (3 stages, 0.30/0.40/0.30, shuffle 45 -> 15 GB):
  Prineville-heavy.

Map compute shares are lean (0.30) — shuffle-heavy analytics burn most
cycles in the reduce rounds — which also keeps the data-local map stage
inside every site's service capacity (effective map rate is
``mu / 0.30``; margins >= 1.25x at the worst (site, type) pair).

Other deliberate deviations from the base ``facebook_4dc`` scenario:

* the per-type dataset layouts are *skewed* (rows concentrate 0.5–0.6 at
  one site): real datasets live where they were ingested, and skew is
  what makes stage placement non-trivial (a near-uniform layout prices
  every pull the same and the subsystem degenerates to base GMSA).
* ``energy_per_gb = 0.03`` — inter-stage shuffle rides the long-haul WAN
  (transponder chains + core routers), pricier per byte than the bulk
  re-placement default (0.01) that can be scheduled over off-peak paths.
  At 30–60 GB intermediate volume per job this puts the WAN bill in the
  same order as the compute bill — the regime where stage-aware
  placement matters.
* the service-rate I/O slowdown is derived from the *scenario's own*
  skewed layout, keeping mu consistent with where the data actually is.

``mix_seed`` swaps the canonical mix for a random one drawn from the
:mod:`repro.traces.stages` generators (depths, Dirichlet compute splits,
log-normal selectivities, Dirichlet layouts) — the path Monte-Carlo
scenario sweeps use; the canonical mix is the benchmarked one.

``make_staged_builder`` returns ``(template, dag, wan, build_inputs)``:
the deterministic trace bundle, the padded stage chain, the WAN pricing
model, and the per-run stochastic regenerator for Monte-Carlo replication
— the same contract as ``facebook_4dc.make_sim_builder`` plus the staged
pieces.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

import numpy as np

from repro.core.iridium import build_task_allocation
from repro.core.simulator import SimInputs
from repro.jobs.dag import (
    StageDag,
    chain_dag,
    pad_chains,
    shuffle_volumes_from_selectivity,
    validate_dag,
)
from repro.placement.wan import WanModel, wan_topology
from repro.traces.arrivals import (
    poisson_pair_from_tables,
    poisson_table,
    rate_per_slot,
)
from repro.traces.bandwidth import bandwidth_draw
from repro.traces.datasets import (
    DEFAULT_CAPACITY_SHARES,
    dataset_distribution,
    io_slowdown_from_bandwidth,
)
from repro.traces.price import FACEBOOK_SITES, price_trace
from repro.traces.pue import pue_trace
from repro.traces.stages import staged_mix_profile

#: The canonical K = 3 per-type dataset layouts (rows sum to 1): each
#: dataset concentrates where it was ingested — ForestCity, Altoona,
#: Prineville respectively.
CANONICAL_DATA_DIST = (
    (0.15, 0.50, 0.25, 0.10),   # ETL/filter-join — ForestCity-heavy
    (0.10, 0.10, 0.20, 0.60),   # scan-aggregate  — Altoona-heavy
    (0.50, 0.10, 0.25, 0.15),   # iterative/ML    — Prineville-heavy
)

#: Per-stage compute intensities (fractions of P^k; rows sum to 1).
CANONICAL_COMPUTE = (
    (0.30, 0.45, 0.25),
    (0.30, 0.70),
    (0.30, 0.40, 0.30),
)

#: GB entering each stage per job (stage 0 is the data-local map: free).
CANONICAL_SHUFFLE_GB = (
    (0.0, 60.0, 12.0),
    (0.0, 30.0),
    (0.0, 45.0, 15.0),
)


@dataclasses.dataclass(frozen=True)
class StagedPaperConfig:
    """The staged-jobs evaluation configuration (Sec. V-A + stage mix)."""

    n_sites: int = 4
    k_types: int = 3                   # shuffle-heavy analytics mix
    t_slots: int = 288                 # 24 h of 5-min slots
    slot_minutes: float = 5.0
    monthly_jobs: float = 350_000.0    # per type (a 3x larger fleet)
    a_max: float = 128.0
    mu_max: float = 128.0
    capacity_shares: tuple = DEFAULT_CAPACITY_SHARES
    manager_share: float = 0.62
    map_share: float = 0.6
    input_gb: float = 100.0            # per-job input dataset
    energy_per_gb: float = 0.03        # long-haul WAN energy per shuffle GB
    mix_seed: int | None = None        # None = the canonical mix
    s_max: int = 3                     # drawn-mix depth cap
    min_stages: int = 2
    dataset_conc: float = 2.0          # drawn-mix layout skew
    n_runs: int = 200
    trace_seed: int = 2060
    v: float = 10.0                    # GMSA trade-off parameter

    @property
    def lam(self) -> float:
        return rate_per_slot(self.slot_minutes, self.monthly_jobs)


def _scenario_mix(cfg: StagedPaperConfig) -> tuple[jnp.ndarray, StageDag]:
    """(data_dist, dag) — canonical hand-set mix, or a seeded draw."""
    if cfg.mix_seed is None:
        if cfg.k_types != 3 or cfg.n_sites != 4:
            raise ValueError(
                "the canonical mix is 3 types x 4 sites; pass mix_seed to "
                "draw a random mix for other shapes"
            )
        data_dist = jnp.asarray(CANONICAL_DATA_DIST, jnp.float32)
        dag = pad_chains(CANONICAL_COMPUTE, CANONICAL_SHUFFLE_GB)
        return data_dist, dag
    k_data, k_mix = jax.random.split(jax.random.key(cfg.mix_seed))
    data_dist = dataset_distribution(
        k_data, cfg.k_types, cfg.n_sites, conc=cfg.dataset_conc
    )
    mask, compute, selectivity = staged_mix_profile(
        k_mix, cfg.k_types, cfg.s_max, cfg.min_stages
    )
    shuffle = shuffle_volumes_from_selectivity(cfg.input_gb, selectivity)
    return data_dist, chain_dag(compute, shuffle, mask)


def make_staged_builder(
    cfg: StagedPaperConfig,
) -> tuple[SimInputs, StageDag, WanModel, Callable]:
    """Build the multi-stage scenario's inputs.

    Returns:
        (template, dag, wan, build_inputs): deterministic trace bundle
        (usable directly for one run), the padded stage chain, the WAN
        pricing model, and ``build_inputs(key) -> SimInputs``
        regenerating the stochastic components per Monte-Carlo run.
    """
    root = jax.random.key(cfg.trace_seed)
    k_price, k_pue, k_bw, _, _, _ = jax.random.split(root, 6)

    sites = FACEBOOK_SITES[: cfg.n_sites]
    omega = price_trace(k_price, cfg.t_slots, cfg.slot_minutes, sites)
    pue = pue_trace(k_pue, cfg.t_slots, cfg.slot_minutes, sites)
    up, down = bandwidth_draw(k_bw, cfg.n_sites)
    wan = wan_topology(up, down, energy_per_gb=cfg.energy_per_gb)

    data_dist, dag = _scenario_mix(cfg)
    validate_dag(dag)

    r = build_task_allocation(
        data_dist, up, down,
        size=1.0, manager_share=cfg.manager_share, map_share=cfg.map_share,
    )
    p_it = jnp.ones((cfg.k_types,), jnp.float32)
    slowdown = io_slowdown_from_bandwidth(up, down, data_dist)

    arr_cdf = jnp.asarray(poisson_table(
        np.full((cfg.k_types,), cfg.lam), int(cfg.a_max)
    ))
    mu_mean = (
        np.asarray(cfg.capacity_shares, np.float64)[:, None]
        * np.asarray(slowdown, np.float64)[:, None]
        * cfg.lam
        * np.ones((1, cfg.k_types))
    )
    mu_cdf = jnp.asarray(poisson_table(mu_mean, int(cfg.mu_max)))

    def stochastic(key) -> tuple:
        ka, km = jax.random.split(key)
        # One batched binary search for both traces (§Perf v6) — bitwise
        # the same draws as the two separate poisson_from_table calls.
        return poisson_pair_from_tables(ka, km, arr_cdf, mu_cdf, cfg.t_slots)

    arr0, mu0 = stochastic(jax.random.fold_in(root, 99))
    template = SimInputs(
        arrivals=arr0, mu=mu0, omega=omega, pue=pue,
        r=r, p_it=p_it, data_dist=data_dist,
    )

    def build_inputs(key) -> SimInputs:
        arrivals, mu = stochastic(key)
        return template._replace(arrivals=arrivals, mu=mu)

    return template, dag, wan, build_inputs
