"""phi3.5-moe-42b-a6.6b — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from repro.configs.base import ModelConfig
from repro.configs.registry import register_arch

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        num_experts=16,
        top_k=2,
        moe_d_ff=6400,
        act="swiglu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        num_experts=4,
        top_k=2,
        moe_d_ff=192,
        act="swiglu",
    )


register_arch(ARCH_ID, full, smoke)
