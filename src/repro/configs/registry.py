"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

from typing import Callable

from repro.configs.base import ModelConfig

_ARCHS: dict[str, dict[str, Callable[[], ModelConfig]]] = {}


def register_arch(
    arch_id: str,
    full: Callable[[], ModelConfig],
    smoke: Callable[[], ModelConfig],
) -> None:
    """Register an architecture id with its full and smoke config builders."""
    if arch_id in _ARCHS:
        raise ValueError(f"duplicate arch id {arch_id!r}")
    _ARCHS[arch_id] = {"full": full, "smoke": smoke}


def get_arch(arch_id: str, variant: str = "full") -> ModelConfig:
    """Resolve an ``--arch`` id to its ModelConfig (variant: full|smoke)."""
    try:
        entry = _ARCHS[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_ARCHS)}"
        ) from None
    return entry[variant]()


def list_archs() -> list[str]:
    return sorted(_ARCHS)
