"""Dataset distributions and service-rate traces (paper Sec. IV-A & V-A).

* Each job type's 100 GB input is "dynamically distributed in four data
  centers randomly" — we draw a Dirichlet dataset distribution per type.
* The per-DC service rate mu_i^k(t) is random and "closely associated with
  computational capacity, dataset distribution, network I/O and the task
  allocation strategy". We model it as a Poisson around a per-DC capacity,
  modulated by the Iridium bottleneck transfer time for that type: DCs that
  must pull data over slow links complete fewer jobs per slot. Capacities
  are deliberately heterogeneous so the paper's Fig. 5(b) regime appears:
  uniform dispatch (DATA/RANDOM) overloads the slow DCs and their backlogs
  diverge, while GMSA stays stable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

#: Default per-DC capacity shares of the total arrival rate. After the I/O
#: slowdown (below) the effective total is ~1.4x lambda — inside the capacity
#: region (GMSA stabilizable) — while the slow DCs sit below the uniform
#: 1/N split (so DATA/RANDOM overload them and their backlogs diverge,
#: reproducing the paper's Fig. 5(b) regime).
#: Ordering follows the real fleet: the cheap-power sites (Luleå, Altoona)
#: are the big ones.
DEFAULT_CAPACITY_SHARES = (0.30, 0.20, 0.90, 0.60)

#: Paper: fixed 100 GB input dataset per job.
JOB_INPUT_GB = 100.0

#: Intermediate (shuffle) data per job moved across the core network. Map
#: output is typically a few percent of the 100 GB input for analytics jobs.
JOB_INTERMEDIATE_GB = 5.0


def dataset_distribution(key: Array, k_types: int, n_sites: int, conc: float = 6.0) -> Array:
    """(K, N) Dirichlet dataset distribution per job type (rows sum to 1)."""
    alpha = jnp.full((n_sites,), conc, jnp.float32)
    return jax.random.dirichlet(key, alpha, (k_types,))


def service_rate_trace(
    key: Array,
    t_slots: int,
    lam: float | Array,
    capacity_shares: Array | tuple = DEFAULT_CAPACITY_SHARES,
    k_types: int = 1,
    io_slowdown: Array | None = None,
    mu_max: float | None = None,
) -> Array:
    """(T, N, K) stochastic service rates.

    Args:
        key: PRNG key.
        t_slots: number of slots.
        lam: (K,) or scalar arrival rate (jobs/slot) — capacities scale off it.
        capacity_shares: (N,) per-DC capacity as a fraction of total lam.
        k_types: number of job types.
        io_slowdown: optional (N,) multiplier in (0, 1] from the Iridium
            bottleneck (slower links -> lower effective service rate).
        mu_max: optional truncation enforcing the paper's finite mu_max.
    """
    shares = jnp.asarray(capacity_shares, jnp.float32)            # (N,)
    lam_arr = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (k_types,))
    mean = shares[:, None] * lam_arr[None, :]                      # (N, K)
    if io_slowdown is not None:
        mean = mean * io_slowdown[:, None]
    draws = jax.random.poisson(key, mean, (t_slots,) + mean.shape)
    mu = draws.astype(jnp.float32)
    if mu_max is not None:
        mu = jnp.minimum(mu, mu_max)
    return mu


def io_slowdown_from_bandwidth(
    up: Array, down: Array, data_dist: Array, compute_seconds: float = 300.0,
    job_gb: float = JOB_INTERMEDIATE_GB, reads: Array | None = None,
) -> Array:
    """Effective-rate multiplier from network I/O — (N,) or (N, K).

    A DC managing a job pulls the non-local share of the *intermediate*
    (shuffle) data through its downlink; the slowdown is
    compute/(compute + transfer). The input data itself never moves (the
    GDA premise — map tasks are data-local).

    With ``reads=None`` (default), ``data_dist`` is averaged over types for
    a per-DC locality estimate: every job type at a site shares one (N,)
    slowdown, even types whose data sits entirely local. Passing the
    (K, N, N) per-reader replica selection from
    :func:`repro.placement.replica.replica_read_assignment` resolves the
    pull per (site, type) instead: reader j's type-k jobs transfer nothing
    when its chosen replica is itself (``reads[k, j, j] == 1``) and pull
    the full intermediate volume otherwise — returned as an (N, K)
    multiplier, so a type pinned to a local replica is not slowed by other
    types' remote reads.
    """
    if reads is not None:
        local = jnp.diagonal(reads, axis1=1, axis2=2)              # (K, N)
        remote_gb = job_gb * (1.0 - local)
        transfer_s = remote_gb * 8.0 / jnp.maximum(down[None, :], 1e-6)
        return (compute_seconds / (compute_seconds + transfer_s)).T  # (N, K)
    locality = jnp.mean(data_dist, axis=0)                         # (N,)
    remote_gb = job_gb * (1.0 - locality)
    transfer_s = remote_gb * 8.0 / jnp.maximum(down, 1e-6)         # Gb / Gbps
    return compute_seconds / (compute_seconds + transfer_s)
