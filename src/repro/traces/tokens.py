"""Token-data pipeline for LM training (substrate for repro.train).

Deterministic synthetic corpus with realistic statistics: Zipfian unigram
distribution plus a first-order Markov "phrase" structure so the loss curve
is non-trivial (a model can actually learn bigram structure). Documents are
packed into fixed-length sequences with EOS separators and per-token loss
masks — the standard production packing scheme — and served by a host-side
loader that yields globally-consistent shards per data-parallel host.

Everything is seeded: step `s` of loader `seed` is reproducible across
restarts (checkpoint/restart tests rely on this).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    zipf_a: float = 1.2          # Zipf exponent for unigram draws
    mean_doc_len: int = 256      # geometric document lengths
    markov_blend: float = 0.5    # weight of the bigram component


class SyntheticTokenStream:
    """Deterministic, restartable synthetic token stream.

    The stream for (seed, step) is a pure function — resuming from a
    checkpointed ``step`` reproduces the exact batches a non-failed run
    would have seen (asserted in tests/test_checkpoint.py).
    """

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = ranks ** (-cfg.zipf_a)
        self._unigram /= self._unigram.sum()
        # A sparse-ish random bigram kernel: each token prefers a small set
        # of successors (phrase structure the model can learn).
        succ = base.integers(0, v, size=(v, 4))
        self._succ = succ.astype(np.int64)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Materialize the (global_batch, seq_len) batch for ``step``."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        # Unigram draws for the whole batch.
        uni = rng.choice(v, size=(b, s), p=self._unigram)
        # Markov pass: with prob markov_blend, token t+1 is a preferred
        # successor of token t. Vectorized over batch, scanned over seq.
        out = uni.copy()
        use_succ = rng.random((b, s)) < cfg.markov_blend
        pick = rng.integers(0, self._succ.shape[1], size=(b, s))
        for t in range(1, s):
            succ_t = self._succ[out[:, t - 1], pick[:, t]]
            out[:, t] = np.where(use_succ[:, t], succ_t, out[:, t])
        # Document boundaries: geometric lengths -> EOS + loss-mask reset.
        boundary = rng.random((b, s)) < (1.0 / cfg.mean_doc_len)
        out = np.where(boundary, cfg.eos_id, out)
        mask = np.ones((b, s), np.float32)
        return {
            "tokens": out.astype(np.int32),
            "loss_mask": mask,
            "segment_starts": boundary,
        }

    def shard_iterator(
        self, host_index: int, host_count: int, start_step: int = 0
    ) -> Iterator[dict[str, np.ndarray]]:
        """Host-sharded iterator: host h sees rows [h::host_count] of each batch.

        All hosts draw the same global batch (seeded) and slice their shard —
        the idiom that keeps multi-host data loading consistent without a
        central dispatcher.
        """
        if self.cfg.global_batch % host_count:
            raise ValueError("global_batch must divide evenly across hosts")
        step = start_step
        while True:
            full = self.batch(step)
            yield {k: val[host_index::host_count] for k, val in full.items()}
            step += 1


def lm_inputs(batch: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Shift a packed batch into (inputs, labels, mask) for next-token loss."""
    toks = batch["tokens"]
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "loss_mask": batch["loss_mask"][:, 1:],
    }
