"""Shuffle-volume / selectivity traces for stage-structured jobs.

Production analytics mixes are stage-structured: a map/filter pass over
the input, one or more shuffle+reduce rounds, a small aggregation at the
end. Two numbers characterize each stage for the geo control plane:

* **selectivity** — output/input volume ratio. Filter-heavy map stages
  shrink data 3–30x (selectivity 0.03–0.3); join/expand stages can exceed
  1. Log-normal across a mix is the standard empirical fit.
* **compute share** — the fraction of the job's IT work the stage burns.

These generators draw padded (K, S) profiles for the K job types of a
scenario — depths, compute splits, selectivities — which
:mod:`repro.jobs.dag` assembles into a :class:`repro.jobs.dag.StageDag`
(volumes via ``shuffle_volumes_from_selectivity``). All draws are seeded
and shapes static, so a config can pin its scenario exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

_EPS = 1e-6


def stage_depth_mask(
    key: Array, k_types: int, s_max: int, min_stages: int = 2
) -> Array:
    """(K, S) monotone activity masks with uniform depths in [min, S].

    Every row is a prefix of ones — the padded-chain precedence contract
    of :class:`repro.jobs.dag.StageDag`.
    """
    if not 1 <= min_stages <= s_max:
        raise ValueError(f"need 1 <= min_stages <= {s_max}, got {min_stages}")
    depths = jax.random.randint(key, (k_types,), min_stages, s_max + 1)
    return (jnp.arange(s_max)[None, :] < depths[:, None]).astype(jnp.float32)


def stage_compute_profile(
    key: Array,
    mask: Array,
    conc: float = 12.0,
    map_weight: float = 0.8,
) -> Array:
    """(K, S) per-stage compute shares (active entries sum to 1 per row).

    Dirichlet over the active stages with a mildly *down-weighted* map
    stage (``map_weight`` < 1): shuffle-heavy analytics burn most of their
    cycles in the reduce rounds, and a lean map stage keeps the data-local
    map placement stable even when a dataset concentrates at a
    small-capacity site (the map stage's effective service rate is
    ``mu / share``). Padded stages get the identity share 1.0 (masked out
    by the dag contract).

    Args:
        key: PRNG key.
        mask: (K, S) monotone activity mask.
        conc: Dirichlet concentration (larger = closer to the prior mix).
        map_weight: prior weight of stage 0 relative to the others.
    """
    k_types, s_max = mask.shape
    prior = jnp.concatenate(
        [jnp.full((1,), map_weight), jnp.ones((s_max - 1,))]
    )                                                              # (S,)
    gam = jax.random.gamma(key, conc * prior[None, :], (k_types, s_max))
    gam = gam * mask
    shares = gam / jnp.maximum(jnp.sum(gam, axis=1, keepdims=True), _EPS)
    return jnp.where(mask > 0.5, shares, 1.0)


def selectivity_trace(
    key: Array,
    k_types: int,
    s_max: int,
    log10_mean: float = -0.65,
    log10_std: float = 0.35,
    clip: tuple[float, float] = (0.02, 1.2),
) -> Array:
    """(K, S) per-stage selectivities (output/input ratio), log-normal.

    The default centers stages around ~0.22x shrink with occasional
    near-1 (shuffle-heavy joins) and deep filters, matching published
    analytics-trace fits. ``selectivity[k, s]`` is the ratio *out of*
    stage s, so the volume entering stage s is
    ``input_gb * prod_{u<s} selectivity[k, u]`` — see
    :func:`repro.jobs.dag.shuffle_volumes_from_selectivity`.
    """
    logs = log10_mean + log10_std * jax.random.normal(key, (k_types, s_max))
    return jnp.clip(10.0 ** logs, clip[0], clip[1])


def staged_mix_profile(
    key: Array,
    k_types: int,
    s_max: int,
    min_stages: int = 2,
    conc: float = 12.0,
    map_weight: float = 0.8,
    log10_mean: float = -0.65,
    log10_std: float = 0.35,
) -> tuple[Array, Array, Array]:
    """Draw one scenario's full (mask, compute, selectivity) bundle.

    Convenience wrapper splitting one key over the three generators;
    returns padded (K, S) arrays ready for
    :func:`repro.jobs.dag.chain_dag` +
    :func:`repro.jobs.dag.shuffle_volumes_from_selectivity`.
    """
    k_depth, k_comp, k_sel = jax.random.split(key, 3)
    mask = stage_depth_mask(k_depth, k_types, s_max, min_stages)
    compute = stage_compute_profile(k_comp, mask, conc, map_weight)
    selectivity = selectivity_trace(k_sel, k_types, s_max, log10_mean, log10_std)
    return mask, compute, selectivity
