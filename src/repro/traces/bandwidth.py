"""Inter-site bandwidth traces (paper Sec. V-A: 100 Mb/s – 2 Gb/s).

The paper varies core-network<->site bandwidths uniformly in [100 Mb/s, 2 Gb/s]
(per Iridium's setup). Bandwidths feed the Iridium placement layer
(:mod:`repro.core.iridium`) and the service-rate model
(:mod:`repro.traces.datasets`).

Degraded-mode link health lives here too: :func:`link_fault_trace` and
:func:`scheduled_link_fault_trace` produce a ``(T, N, N)`` per-link
health factor in ``[0, 1]`` (1 = nominal, interior = degraded — the
link carries that fraction of its provisioned bandwidth and its traffic
is priced up by the reciprocal — 0 = severed; diagonal pinned to 1).
:mod:`repro.placement.wan` folds it into ``link_price_matrix`` /
``transfer_latency`` / ``evacuation_plan``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

#: Paper's bandwidth range, in Gb/s.
BW_MIN_GBPS = 0.1
BW_MAX_GBPS = 2.0


def bandwidth_draw(
    key: Array,
    n_sites: int,
    lo: float = BW_MIN_GBPS,
    hi: float = BW_MAX_GBPS,
) -> tuple[Array, Array]:
    """Draw static (up, down) bandwidths per site, uniform in [lo, hi] Gb/s."""
    k_up, k_down = jax.random.split(key)
    up = jax.random.uniform(k_up, (n_sites,), minval=lo, maxval=hi)
    down = jax.random.uniform(k_down, (n_sites,), minval=lo, maxval=hi)
    return up, down


def bandwidth_trace(
    key: Array,
    t_slots: int,
    n_sites: int,
    lo: float = BW_MIN_GBPS,
    hi: float = BW_MAX_GBPS,
    wobble: float = 0.15,
) -> tuple[Array, Array]:
    """Time-varying bandwidths: static draw modulated by bounded noise.

    Models "other applications sharing the same links" (paper Sec. II):
    available bandwidth wobbles by ±``wobble`` around the provisioned value.
    """
    k_static, k_up, k_down = jax.random.split(key, 3)
    up0, down0 = bandwidth_draw(k_static, n_sites, lo, hi)
    u = 1.0 + wobble * (2.0 * jax.random.uniform(k_up, (t_slots, n_sites)) - 1.0)
    d = 1.0 + wobble * (2.0 * jax.random.uniform(k_down, (t_slots, n_sites)) - 1.0)
    return up0[None, :] * u, down0[None, :] * d


def link_fault_trace(
    key: Array,
    t_slots: int,
    n_sites: int,
    degrade_prob: float = 0.01,
    recover_prob: float = 0.25,
    sever_frac: float = 0.25,
    min_factor: float = 0.1,
) -> Array:
    """(T, N, N) seeded link-health factor: Markov degrade/recover per link.

    Each nominal directed link i→j independently degrades with
    ``degrade_prob`` per slot; a degrade event severs the link entirely
    (factor 0) with conditional probability ``sever_frac``, otherwise it
    drops to a factor drawn uniform in ``[min_factor, 1)``. A faulted
    link recovers to nominal with ``recover_prob``. The diagonal is
    pinned to 1 (local "transfers" are free and never fault).

    An all-nominal draw is exactly 1.0 everywhere, so degraded pricing
    (``price / health``) stays bit-exact with the nominal WAN bill.
    """
    if not 0.0 < min_factor <= 1.0:
        raise ValueError(f"min_factor={min_factor} must be in (0, 1]")
    keys = jax.random.split(key, t_slots)
    eye = jnp.eye(n_sites, dtype=bool)

    def slot(factor, kk):
        k_on, k_sev, k_cut, k_off = jax.random.split(kk, 4)
        shape = (n_sites, n_sites)
        nominal = factor >= 1.0
        faults = nominal & (jax.random.uniform(k_on, shape) < degrade_prob)
        sev = jax.random.uniform(k_sev, shape, minval=min_factor, maxval=1.0)
        cut = faults & (jax.random.uniform(k_cut, shape) < sever_frac)
        sev = jnp.where(cut, 0.0, sev)
        recovers = (~nominal) & (jax.random.uniform(k_off, shape)
                                 < recover_prob)
        nxt = jnp.where(faults, sev, jnp.where(recovers, 1.0, factor))
        nxt = jnp.where(eye, 1.0, nxt)
        return nxt, nxt.astype(jnp.float32)

    _, health = jax.lax.scan(slot, jnp.ones((n_sites, n_sites)), keys)
    return health                                              # (T, N, N)


def scheduled_link_fault_trace(
    t_slots: int,
    n_sites: int,
    events: list[tuple[int, int, int, int | None, float]],
    symmetric: bool = True,
) -> Array:
    """(T, N, N) link health from (src, dst, start, end, factor) events.

    ``end=None`` means the fault never clears; windows are half-open and
    overlapping windows take the minimum factor. ``symmetric=True``
    (default) applies each event to both directions of the link.
    Validation mirrors ``scheduled_failure_trace``: out-of-range sites,
    self-links, negative ``start``, empty windows, and factors outside
    ``[0, 1]`` all raise.
    """
    health = np.ones((t_slots, n_sites, n_sites), np.float32)
    for src, dst, start, end, factor in events:
        for site in (src, dst):
            if not 0 <= site < n_sites:
                raise ValueError(f"site {site} out of range for N={n_sites}")
        if src == dst:
            raise ValueError(f"self-link {src}->{dst} cannot fault")
        if start < 0:
            raise ValueError(f"start={start} must be >= 0")
        if end is not None and end <= start:
            raise ValueError(f"end={end} must be > start={start} (or None)")
        if not 0.0 <= factor <= 1.0:
            raise ValueError(f"factor={factor} must be in [0, 1]")
        stop = t_slots if end is None else min(end, t_slots)
        pairs = [(src, dst), (dst, src)] if symmetric else [(src, dst)]
        for i, j in pairs:
            health[start:stop, i, j] = np.minimum(
                health[start:stop, i, j], np.float32(factor))
    return jnp.asarray(health)
