"""Inter-site bandwidth traces (paper Sec. V-A: 100 Mb/s – 2 Gb/s).

The paper varies core-network<->site bandwidths uniformly in [100 Mb/s, 2 Gb/s]
(per Iridium's setup). Bandwidths feed the Iridium placement layer
(:mod:`repro.core.iridium`) and the service-rate model
(:mod:`repro.traces.datasets`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

#: Paper's bandwidth range, in Gb/s.
BW_MIN_GBPS = 0.1
BW_MAX_GBPS = 2.0


def bandwidth_draw(
    key: Array,
    n_sites: int,
    lo: float = BW_MIN_GBPS,
    hi: float = BW_MAX_GBPS,
) -> tuple[Array, Array]:
    """Draw static (up, down) bandwidths per site, uniform in [lo, hi] Gb/s."""
    k_up, k_down = jax.random.split(key)
    up = jax.random.uniform(k_up, (n_sites,), minval=lo, maxval=hi)
    down = jax.random.uniform(k_down, (n_sites,), minval=lo, maxval=hi)
    return up, down


def bandwidth_trace(
    key: Array,
    t_slots: int,
    n_sites: int,
    lo: float = BW_MIN_GBPS,
    hi: float = BW_MAX_GBPS,
    wobble: float = 0.15,
) -> tuple[Array, Array]:
    """Time-varying bandwidths: static draw modulated by bounded noise.

    Models "other applications sharing the same links" (paper Sec. II):
    available bandwidth wobbles by ±``wobble`` around the provisioned value.
    """
    k_static, k_up, k_down = jax.random.split(key, 3)
    up0, down0 = bandwidth_draw(k_static, n_sites, lo, hi)
    u = 1.0 + wobble * (2.0 * jax.random.uniform(k_up, (t_slots, n_sites)) - 1.0)
    d = 1.0 + wobble * (2.0 * jax.random.uniform(k_down, (t_slots, n_sites)) - 1.0)
    return up0[None, :] * u, down0[None, :] * d
