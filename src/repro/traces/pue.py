"""PUE traces (paper Sec. III & V-A).

Facebook publishes near-real-time PUE dashboards for the four sites the paper
simulates; Google computes PUE every 30 seconds. This module synthesizes
dashboard-like traces: a site-specific base (climate-driven: Luleå lowest),
a diurnal cooling swing peaking in local mid-afternoon, and small
measurement noise. A CSV loader mirrors :mod:`repro.traces.price`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.traces.price import SiteSpec, FACEBOOK_SITES


def pue_trace(
    key: Array,
    t_slots: int,
    slot_minutes: float,
    sites: tuple[SiteSpec, ...] = FACEBOOK_SITES,
    start_hour_utc: float = 0.0,
) -> Array:
    """(T, N) synthetic PUE traces (dimensionless, ~1.04-1.12)."""
    hours = start_hour_utc + jnp.arange(t_slots) * (slot_minutes / 60.0)
    base = jnp.asarray([s.base_pue for s in sites], jnp.float32)
    amp = jnp.asarray([s.pue_amp for s in sites], jnp.float32)
    off = np.asarray([s.utc_offset_h for s in sites], np.float32)

    # Cooling load peaks mid-afternoon local time (15:00).
    diurnal = jnp.stack(
        [jnp.cos(2.0 * jnp.pi * (hours + float(o) - 15.0) / 24.0) for o in off],
        axis=1,
    )
    noise = 0.004 * jax.random.normal(key, (t_slots, len(sites)))
    trace = base[None, :] + amp[None, :] * diurnal + noise
    return jnp.maximum(trace, 1.0)  # PUE >= 1 by definition


def load_pue_csv(path: str, n_sites: int) -> Array:
    """Load a real (T, N) PUE trace from CSV."""
    data = np.loadtxt(path, delimiter=",", dtype=np.float32)
    if data.ndim == 1:
        data = data[:, None]
    if data.shape[1] != n_sites:
        raise ValueError(f"expected {n_sites} columns, got {data.shape[1]}")
    return jnp.asarray(data)
