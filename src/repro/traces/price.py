"""Electricity-price traces (paper Sec. V-A).

The paper uses real electricity prices "obtained from publicly available
government agencies" for the four Facebook DC regions. Those exact CSVs are
not redistributable, so this module provides:

  * a *calibrated synthesizer*: per-site diurnal price curves with realistic
    base levels (EIA state-level industrial rates for OR / NC / IA, Nord Pool
    area price for Luleå SE1), timezone-shifted diurnal swing, weekly
    modulation and AR(1) noise — the statistical shape GMSA exploits;
  * a CSV loader with the same output contract, for plugging in real traces.

Prices are in $/MWh. ``omega_j(t)`` in the paper is a *weight*; using $/MWh
directly with P^k = 1 MWh-equivalent per job reproduces the paper's cost
scale (hundreds of dollars per slot at ~40 jobs/slot).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array


@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """Static description of one DC site's price/PUE climate."""

    name: str
    region: str
    utc_offset_h: float       # local-time shift for the diurnal component
    base_price: float         # $/MWh mean industrial price
    diurnal_amp: float        # peak-to-mean diurnal swing ($/MWh)
    noise_std: float          # AR(1) innovation std ($/MWh)
    base_pue: float           # site mean PUE (Facebook dashboards ~1.07-1.10)
    pue_amp: float            # diurnal PUE swing (cooling load)


#: The four Facebook DCs of the paper's evaluation. Relative price levels from
#: public EIA / Nord Pool ranges (Luleå cheapest, ForestCity priciest); PUE
#: levels from Facebook's public dashboards. The absolute scale is calibrated
#: so the baselines' time-average slot cost lands at the paper's ≈$750
#: (Fig. 6(a)) given 40.5 jobs/slot and P^k = 1 — see EXPERIMENTS.md
#: §Calibration.
FACEBOOK_SITES: tuple[SiteSpec, ...] = (
    SiteSpec("Prineville", "Oregon, US", -8.0, 15.98, 3.76, 0.8, 1.078, 0.02),
    SiteSpec("ForestCity", "North Carolina, US", -5.0, 24.44, 5.64, 1.0, 1.082, 0.03),
    SiteSpec("Lulea", "Sweden (SE1)", 1.0, 9.87, 3.29, 1.2, 1.046, 0.01),
    SiteSpec("Altoona", "Iowa, US", -6.0, 18.33, 4.70, 0.9, 1.071, 0.025),
)


def _diurnal(hours_utc: Array, utc_offset: float, phase_peak_h: float = 17.0) -> Array:
    """Unit diurnal curve peaking at local ``phase_peak_h`` (evening peak)."""
    local = hours_utc + utc_offset
    return jnp.cos(2.0 * jnp.pi * (local - phase_peak_h) / 24.0)


def price_trace(
    key: Array,
    t_slots: int,
    slot_minutes: float,
    sites: tuple[SiteSpec, ...] = FACEBOOK_SITES,
    start_hour_utc: float = 0.0,
) -> Array:
    """(T, N) synthetic electricity-price traces ($/MWh).

    Deterministic given the key; the AR(1) component gives each run's price
    path realistic short-term wiggle while the diurnal/weekly structure is
    shared (as with real market data, where day-ahead structure dominates).
    """
    n = len(sites)
    hours = start_hour_utc + jnp.arange(t_slots) * (slot_minutes / 60.0)   # (T,)
    base = jnp.asarray([s.base_price for s in sites], jnp.float32)
    amp = jnp.asarray([s.diurnal_amp for s in sites], jnp.float32)
    noise_std = jnp.asarray([s.noise_std for s in sites], jnp.float32)
    off = np.asarray([s.utc_offset_h for s in sites], np.float32)

    diurnal = jnp.stack([_diurnal(hours, float(o)) for o in off], axis=1)  # (T, N)
    weekly = 1.0 + 0.03 * jnp.sin(2.0 * jnp.pi * hours[:, None] / (24.0 * 7.0))

    # AR(1) noise, phi = 0.9, stationary init.
    phi = 0.9
    innov = jax.random.normal(key, (t_slots, n)) * noise_std

    def ar_step(prev, inn):
        cur = phi * prev + inn
        return cur, cur

    init = innov[0] / jnp.sqrt(1.0 - phi * phi)
    _, noise = jax.lax.scan(ar_step, init, innov)

    trace = base[None, :] * weekly + amp[None, :] * diurnal + noise
    return jnp.maximum(trace, 1.0)  # prices stay positive


def load_price_csv(path: str, n_sites: int) -> Array:
    """Load a real (T, N) price trace from CSV (slot rows × site columns)."""
    data = np.loadtxt(path, delimiter=",", dtype=np.float32)
    if data.ndim == 1:
        data = data[:, None]
    if data.shape[1] != n_sites:
        raise ValueError(f"expected {n_sites} columns, got {data.shape[1]}")
    return jnp.asarray(data)
