"""Seeded site-failure / recovery traces (the chaos scenario class).

The reliability gap of geo-distributed analytics (Zhang et al., reliable
geo-distributed executions) is site loss: a whole DC drops out of the fleet
— power event, WAN partition, regional outage — and the control plane must
re-place data and re-dispatch the lost backlog over the survivors. These
generators produce the per-slot **alive mask** consumed by
:func:`repro.placement.controller.simulate_placed`:

* :func:`site_failure_trace` — a seeded Markov on/off process per site:
  alive sites die with ``failure_prob`` per slot, dead sites come back after
  ``repair_slots`` (``None`` = permanent loss). Never kills below
  ``min_alive`` survivors, so the control plane always has somewhere to
  evacuate to.
* :func:`scheduled_failure_trace` — deterministic (site, down_at, up_at)
  events for regression tests and benchmarks.

Masks are (T, N) float32 in {0, 1}; 1 = alive. An all-ones mask is the
no-fault scenario and the controller's fault path is bit-exact with its
no-fault path on it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array


def site_failure_trace(
    key: Array,
    t_slots: int,
    n_sites: int,
    failure_prob: float = 0.002,
    repair_slots: int | None = None,
    min_alive: int = 1,
) -> Array:
    """(T, N) seeded alive mask: per-slot death coins + timed repair.

    Each alive site dies independently with ``failure_prob`` per slot; a
    dead site stays down for ``repair_slots`` slots and then revives
    (``None`` = it never comes back). Any slot whose deaths would leave
    fewer than ``min_alive`` survivors suppresses that slot's deaths
    entirely — the fleet never loses its last evacuation target.

    Deterministic given ``key``: the same seed replays the same outage
    schedule (the alive-mask analogue of the seeded-by-step data pipeline).
    """
    if not 0 <= min_alive <= n_sites:
        raise ValueError(f"min_alive={min_alive} out of range for N={n_sites}")
    # repair_slots=0 would revive in the same slot the site died (no-op
    # failures); treat it as permanent=False with a 1-slot floor.
    repair = 0 if repair_slots is None else max(int(repair_slots), 1)
    permanent = repair_slots is None
    keys = jax.random.split(key, t_slots)

    def slot(down_left, kk):
        # down_left[i] > 0 <=> site i is dead for that many more slots.
        alive = (down_left == 0)
        coins = jax.random.uniform(kk, (n_sites,))
        dies = alive & (coins < failure_prob)
        survivors_after = jnp.sum(alive) - jnp.sum(dies)
        dies = jnp.where(survivors_after >= min_alive, dies, False)
        new_down = jnp.where(
            dies,
            jnp.iinfo(jnp.int32).max if permanent else repair,
            jnp.maximum(down_left - 1, 0),
        )
        # A site is alive *this slot* unless it is (still) down after the
        # decrement or died this slot.
        alive_now = (new_down == 0)
        return new_down, alive_now.astype(jnp.float32)

    _, mask = jax.lax.scan(slot, jnp.zeros((n_sites,), jnp.int32), keys)
    return mask                                                   # (T, N)


def scheduled_failure_trace(
    t_slots: int,
    n_sites: int,
    events: list[tuple[int, int, int | None]],
) -> Array:
    """(T, N) alive mask from explicit (site, down_at, up_at) events.

    ``up_at=None`` means the site never recovers. Slots are half-open:
    site is dead for ``down_at <= t < up_at``.
    """
    mask = np.ones((t_slots, n_sites), np.float32)
    for site, down_at, up_at in events:
        if not 0 <= site < n_sites:
            raise ValueError(f"site {site} out of range for N={n_sites}")
        end = t_slots if up_at is None else min(up_at, t_slots)
        mask[down_at:end, site] = 0.0
    return jnp.asarray(mask)


def failure_edges(alive: Array) -> Array:
    """(T, N) mask of death edges: 1 where a site is newly dead this slot.

    Slot 0 compares against an all-alive fleet, so a trace that starts with
    a dead site fires its edge at t=0 — the controller's recovery epoch
    triggers exactly on these edges.
    """
    alive = jnp.asarray(alive, jnp.float32)
    prev = jnp.concatenate([jnp.ones_like(alive[:1]), alive[:-1]], axis=0)
    return prev * (1.0 - alive)
