"""Seeded site-failure / recovery traces (the chaos scenario class).

The reliability gap of geo-distributed analytics (Zhang et al., reliable
geo-distributed executions) is site loss: a whole DC drops out of the fleet
— power event, WAN partition, regional outage — and the control plane must
re-place data and re-dispatch the lost backlog over the survivors. These
generators produce the per-slot **alive mask** consumed by
:func:`repro.placement.controller.simulate_placed`:

* :func:`site_failure_trace` — a seeded Markov on/off process per site:
  alive sites die with ``failure_prob`` per slot, dead sites come back after
  ``repair_slots`` (``None`` = permanent loss). Never kills below
  ``min_alive`` survivors, so the control plane always has somewhere to
  evacuate to.
* :func:`scheduled_failure_trace` — deterministic (site, down_at, up_at)
  events for regression tests and benchmarks.

Masks are (T, N) float32 in {0, 1}; 1 = alive. An all-ones mask is the
no-fault scenario and the controller's fault path is bit-exact with its
no-fault path on it.

Beyond binary death, the *degraded-mode* generators produce a **health
factor** in ``[0, 1]`` — 0 = dead, 1 = nominal, interior = straggler
(the dominant hazard of practical geo-analytics per Zhang et al.,
1802.00245: slow-but-alive sites):

* :func:`health_trace` — seeded Markov straggler onset/recovery per
  site: healthy sites degrade with ``straggle_prob`` to a drawn severity
  factor, stragglers recover with ``recover_prob``.
* :func:`region_assignment` / :func:`regional_health_trace` — contiguous
  region blocks and shared-fate regional outages: a whole region
  degrades (or dies) together, modeling correlated outages.
* :func:`compose_health` — elementwise-min composition of independent
  hazard traces (site stragglers × regional outages).
* :func:`scheduled_health_trace` — deterministic (site, start, end,
  factor) degradation windows for regression tests.
* :func:`health_to_alive` — project a health trace back to the binary
  alive mask the PR-2 fault path consumes (``health > 0``).

Engines consume health by scaling per-slot service rates: an all-ones
health trace is bitwise identical to the no-fault path (``mu * 1.0`` is
an exact identity).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array


def site_failure_trace(
    key: Array,
    t_slots: int,
    n_sites: int,
    failure_prob: float = 0.002,
    repair_slots: int | None = None,
    min_alive: int = 1,
) -> Array:
    """(T, N) seeded alive mask: per-slot death coins + timed repair.

    Each alive site dies independently with ``failure_prob`` per slot; a
    dead site stays down for ``repair_slots`` slots and then revives
    (``None`` = it never comes back). Any slot whose deaths would leave
    fewer than ``min_alive`` survivors suppresses that slot's deaths
    entirely — the fleet never loses its last evacuation target.

    Deterministic given ``key``: the same seed replays the same outage
    schedule (the alive-mask analogue of the seeded-by-step data pipeline).
    """
    if not 0 <= min_alive <= n_sites:
        raise ValueError(f"min_alive={min_alive} out of range for N={n_sites}")
    # repair_slots=0 would revive in the same slot the site died (no-op
    # failures); treat it as permanent=False with a 1-slot floor.
    repair = 0 if repair_slots is None else max(int(repair_slots), 1)
    permanent = repair_slots is None
    keys = jax.random.split(key, t_slots)

    def slot(down_left, kk):
        # down_left[i] > 0 <=> site i is dead for that many more slots.
        alive = (down_left == 0)
        coins = jax.random.uniform(kk, (n_sites,))
        dies = alive & (coins < failure_prob)
        survivors_after = jnp.sum(alive) - jnp.sum(dies)
        dies = jnp.where(survivors_after >= min_alive, dies, False)
        new_down = jnp.where(
            dies,
            jnp.iinfo(jnp.int32).max if permanent else repair,
            jnp.maximum(down_left - 1, 0),
        )
        # A site is alive *this slot* unless it is (still) down after the
        # decrement or died this slot.
        alive_now = (new_down == 0)
        return new_down, alive_now.astype(jnp.float32)

    _, mask = jax.lax.scan(slot, jnp.zeros((n_sites,), jnp.int32), keys)
    return mask                                                   # (T, N)


def scheduled_failure_trace(
    t_slots: int,
    n_sites: int,
    events: list[tuple[int, int, int | None]],
) -> Array:
    """(T, N) alive mask from explicit (site, down_at, up_at) events.

    ``up_at=None`` means the site never recovers. Slots are half-open:
    site is dead for ``down_at <= t < up_at``.
    """
    mask = np.ones((t_slots, n_sites), np.float32)
    for site, down_at, up_at in events:
        if not 0 <= site < n_sites:
            raise ValueError(f"site {site} out of range for N={n_sites}")
        if down_at < 0:
            # A negative down_at would silently wrap via Python slice
            # semantics and kill the *tail* of the trace instead.
            raise ValueError(f"down_at={down_at} must be >= 0")
        if up_at is not None and up_at <= down_at:
            # An empty/inverted window silently no-ops; reject it loudly.
            raise ValueError(
                f"up_at={up_at} must be > down_at={down_at} (or None)"
            )
        end = t_slots if up_at is None else min(up_at, t_slots)
        mask[down_at:end, site] = 0.0
    return jnp.asarray(mask)


def failure_edges(alive: Array) -> Array:
    """(T, N) mask of death edges: 1 where a site is newly dead this slot.

    Slot 0 compares against an all-alive fleet, so a trace that starts with
    a dead site fires its edge at t=0 — the controller's recovery epoch
    triggers exactly on these edges.
    """
    alive = jnp.asarray(alive, jnp.float32)
    prev = jnp.concatenate([jnp.ones_like(alive[:1]), alive[:-1]], axis=0)
    return prev * (1.0 - alive)


def repair_edges(alive: Array) -> Array:
    """(T, N) mask of repair edges: 1 where a site revives this slot.

    The companion of :func:`failure_edges`. Slot 0 compares against an
    all-alive fleet, so a trace can never open with a revival — a repair
    edge always pairs with an earlier death edge, which is what lets the
    flight recorder show recovery timelines as down *and* up and lets
    time-to-SLO measure from the true revival slot.
    """
    alive = jnp.asarray(alive, jnp.float32)
    prev = jnp.concatenate([jnp.ones_like(alive[:1]), alive[:-1]], axis=0)
    return (1.0 - prev) * alive


# ---------------------------------------------------------------------------
# Degraded-mode health: stragglers, regions, shared fate.
# ---------------------------------------------------------------------------


def health_trace(
    key: Array,
    t_slots: int,
    n_sites: int,
    straggle_prob: float = 0.02,
    recover_prob: float = 0.25,
    severity: tuple[float, float] = (0.2, 0.7),
    death_prob: float = 0.0,
) -> Array:
    """(T, N) seeded health factor: Markov straggler onset/recovery.

    Each healthy site starts straggling with ``straggle_prob`` per slot,
    drawing a severity factor uniform in ``severity`` (the fraction of
    nominal service rate it retains); a straggling site recovers with
    ``recover_prob``. With ``death_prob > 0`` an onset event is instead a
    full death (factor 0) with that conditional probability — dead sites
    rejoin the same recovery Markov chain.

    Deterministic given ``key``; an all-healthy draw is exactly 1.0
    everywhere, so downstream ``mu * health`` stays bit-exact with the
    nominal path.
    """
    lo, hi = severity
    if not 0.0 <= lo <= hi <= 1.0:
        raise ValueError(f"severity bounds {severity} must satisfy "
                         "0 <= lo <= hi <= 1")
    keys = jax.random.split(key, t_slots)

    def slot(factor, kk):
        # factor[i] == 1.0 <=> site i is healthy.
        k_on, k_sev, k_dead, k_off = jax.random.split(kk, 4)
        healthy = factor >= 1.0
        onsets = healthy & (jax.random.uniform(k_on, (n_sites,))
                            < straggle_prob)
        sev = jax.random.uniform(k_sev, (n_sites,), minval=lo, maxval=hi)
        dies = onsets & (jax.random.uniform(k_dead, (n_sites,)) < death_prob)
        sev = jnp.where(dies, 0.0, sev)
        recovers = (~healthy) & (jax.random.uniform(k_off, (n_sites,))
                                 < recover_prob)
        nxt = jnp.where(onsets, sev, jnp.where(recovers, 1.0, factor))
        return nxt, nxt.astype(jnp.float32)

    _, health = jax.lax.scan(slot, jnp.ones((n_sites,)), keys)
    return health                                                 # (T, N)


def region_assignment(n_sites: int, n_regions: int) -> Array:
    """(N,) int32 region ids: contiguous, balanced blocks of sites.

    Site ``i`` lands in region ``i * n_regions // n_sites`` — regions
    are contiguous index ranges, matching how fleet scenarios cycle site
    climates, so "same-region survivors" is a meaningful shared-fate
    domain for the evacuation planner to avoid.
    """
    if not 1 <= n_regions <= n_sites:
        raise ValueError(
            f"n_regions={n_regions} out of range for N={n_sites}")
    return (jnp.arange(n_sites, dtype=jnp.int32) * n_regions) // n_sites


def regional_health_trace(
    key: Array,
    t_slots: int,
    regions: Array,
    outage_prob: float = 0.01,
    repair_slots: int = 6,
    outage_factor: float = 0.0,
    min_regions_up: int = 1,
) -> Array:
    """(T, N) shared-fate health: whole regions degrade or die together.

    A healthy region suffers an outage with ``outage_prob`` per slot;
    every site in it drops to ``outage_factor`` (0 = regional blackout,
    interior = brownout) for ``repair_slots`` slots. Outages that would
    leave fewer than ``min_regions_up`` healthy regions are suppressed,
    mirroring ``min_alive`` in :func:`site_failure_trace`.

    Compose with per-site stragglers via :func:`compose_health`.
    """
    regions = jnp.asarray(regions, jnp.int32)
    n_regions = int(jnp.max(regions)) + 1
    if not 1 <= min_regions_up <= n_regions:
        raise ValueError(f"min_regions_up={min_regions_up} out of range "
                         f"for {n_regions} regions")
    repair = max(int(repair_slots), 1)
    keys = jax.random.split(key, t_slots)

    def slot(down_left, kk):
        healthy = (down_left == 0)
        coins = jax.random.uniform(kk, (n_regions,))
        fails = healthy & (coins < outage_prob)
        up_after = jnp.sum(healthy) - jnp.sum(fails)
        fails = jnp.where(up_after >= min_regions_up, fails, False)
        new_down = jnp.where(fails, repair, jnp.maximum(down_left - 1, 0))
        region_factor = jnp.where(new_down == 0, 1.0, outage_factor)
        return new_down, region_factor[regions].astype(jnp.float32)

    _, health = jax.lax.scan(slot, jnp.zeros((n_regions,), jnp.int32), keys)
    return health                                                 # (T, N)


def compose_health(*traces: Array) -> Array:
    """Elementwise-min composition of independent (T, N) hazard traces.

    The binding constraint wins: a straggling site inside a browned-out
    region runs at the *worse* of the two factors, and any dead factor
    (0) dominates.
    """
    if not traces:
        raise ValueError("compose_health needs at least one trace")
    health = jnp.asarray(traces[0], jnp.float32)
    for t in traces[1:]:
        health = jnp.minimum(health, jnp.asarray(t, jnp.float32))
    return health


def scheduled_health_trace(
    t_slots: int,
    n_sites: int,
    events: list[tuple[int, int, int | None, float]],
) -> Array:
    """(T, N) health factor from explicit (site, start, end, factor) events.

    ``end=None`` means the degradation never lifts. Windows are
    half-open (``start <= t < end``); overlapping windows take the
    minimum factor. Validation mirrors :func:`scheduled_failure_trace`:
    negative ``start`` and empty windows raise instead of silently
    wrapping / no-opping.
    """
    health = np.ones((t_slots, n_sites), np.float32)
    for site, start, end, factor in events:
        if not 0 <= site < n_sites:
            raise ValueError(f"site {site} out of range for N={n_sites}")
        if start < 0:
            raise ValueError(f"start={start} must be >= 0")
        if end is not None and end <= start:
            raise ValueError(f"end={end} must be > start={start} (or None)")
        if not 0.0 <= factor <= 1.0:
            raise ValueError(f"factor={factor} must be in [0, 1]")
        stop = t_slots if end is None else min(end, t_slots)
        health[start:stop, site] = np.minimum(
            health[start:stop, site], np.float32(factor))
    return jnp.asarray(health)


def health_to_alive(health: Array) -> Array:
    """Project a (T, N) health factor to the binary alive mask.

    Only factor 0 is death; every straggler is alive. This is the mask
    the PR-2 fault machinery (death edges, recovery epochs, evacuation)
    consumes — degraded-mode traces drive it through this projection so
    recovery fires only on true deaths, never on slowdowns.
    """
    return (jnp.asarray(health, jnp.float32) > 0.0).astype(jnp.float32)
