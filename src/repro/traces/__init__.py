"""repro.traces — trace pipelines for the GDA control plane and LM training.

Control-plane traces (paper Sec. V-A experimental setup):
    * :mod:`repro.traces.arrivals`  — Poisson job arrivals (350K jobs/month).
    * :mod:`repro.traces.price`     — diurnal electricity-price synthesizers
      calibrated to the four Facebook DC regions, CSV-loadable for real data.
    * :mod:`repro.traces.pue`       — PUE traces (Facebook dashboard-like).
    * :mod:`repro.traces.bandwidth` — inter-site up/down bandwidths (100 Mb/s–2 Gb/s).
    * :mod:`repro.traces.datasets`  — per-type dataset distributions & service rates.
    * :mod:`repro.traces.drift`     — slow-timescale dataset drift/growth (feeds
      the repro.placement two-timescale controller).
    * :mod:`repro.traces.faults`    — seeded site-failure/recovery alive masks
      (the chaos scenario class; feeds the controller's recovery epochs).
    * :mod:`repro.traces.stages`    — stage-depth / compute-share /
      selectivity profiles for stage-structured job mixes (feeds the
      repro.jobs staged scheduling subsystem).

Training-data pipeline (used by repro.train):
    * :mod:`repro.traces.tokens`    — deterministic synthetic token corpus,
      sequence packing, host-sharded batch loader with prefetch.
"""

from repro.traces.arrivals import poisson_arrivals, FACEBOOK_MONTHLY_JOBS
from repro.traces.price import price_trace, SiteSpec, FACEBOOK_SITES
from repro.traces.pue import pue_trace
from repro.traces.bandwidth import (
    bandwidth_draw,
    link_fault_trace,
    scheduled_link_fault_trace,
)
from repro.traces.datasets import dataset_distribution, service_rate_trace
from repro.traces.drift import dataset_growth_trace, ingest_drift_trace
from repro.traces.faults import (
    compose_health,
    failure_edges,
    health_to_alive,
    health_trace,
    region_assignment,
    regional_health_trace,
    repair_edges,
    scheduled_failure_trace,
    scheduled_health_trace,
    site_failure_trace,
)
from repro.traces.stages import (
    selectivity_trace,
    stage_compute_profile,
    stage_depth_mask,
    staged_mix_profile,
)

__all__ = [
    "poisson_arrivals",
    "FACEBOOK_MONTHLY_JOBS",
    "price_trace",
    "SiteSpec",
    "FACEBOOK_SITES",
    "pue_trace",
    "bandwidth_draw",
    "link_fault_trace",
    "scheduled_link_fault_trace",
    "dataset_distribution",
    "service_rate_trace",
    "dataset_growth_trace",
    "ingest_drift_trace",
    "compose_health",
    "failure_edges",
    "health_to_alive",
    "health_trace",
    "region_assignment",
    "regional_health_trace",
    "repair_edges",
    "scheduled_failure_trace",
    "scheduled_health_trace",
    "site_failure_trace",
    "selectivity_trace",
    "stage_compute_profile",
    "stage_depth_mask",
    "staged_mix_profile",
]
