"""Job-arrival traces (paper Sec. V-A).

The paper drives its evaluation with the production rate of Facebook's Hadoop
cluster — 350K jobs/month — and models slot-level arrivals as Poisson, citing
the measurement study that validated the Poisson assumption.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

#: Facebook Hadoop production trace rate used by the paper.
FACEBOOK_MONTHLY_JOBS = 350_000

#: Minutes per month used to convert the monthly rate (30-day month).
_MINUTES_PER_MONTH = 30 * 24 * 60


def rate_per_slot(slot_minutes: float, monthly_jobs: float = FACEBOOK_MONTHLY_JOBS) -> float:
    """Poisson rate per slot for a given slot length (paper: 5-minute slots)."""
    return monthly_jobs * slot_minutes / _MINUTES_PER_MONTH


def poisson_arrivals(
    key: Array,
    t_slots: int,
    k_types: int,
    lam: float | Array,
    a_max: float | None = None,
) -> Array:
    """(T, K) Poisson arrival counts, optionally truncated at A_max.

    The paper assumes a finite A^k_max exists; truncation (rare for the
    defaults: P[X > 3*lam] ~ 1e-9) enforces it so the Lemma-1 constant B is
    finite and testable.
    """
    lam_arr = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (k_types,))
    draws = jax.random.poisson(key, lam_arr, (t_slots, k_types)).astype(jnp.float32)
    if a_max is not None:
        draws = jnp.minimum(draws, a_max)
    return draws


# ---------------------------------------------------------------------------
# Fast exact Poisson via inverse-CDF tables (EXPERIMENTS.md §Perf v4).
#
# jax.random.poisson's transformed-rejection sampler dominated the Monte-
# Carlo engine's wall time (~97%). The rates here are STATIC per
# configuration, so inverse-CDF sampling from a precomputed table is exact
# (the distribution is already truncated at A_max by the model) and turns
# 1.4M rejection loops into one vectorized searchsorted.
# ---------------------------------------------------------------------------

def poisson_table(lam, max_value: int) -> np.ndarray:
    """(..., max_value+1) float32 CDF table(s) for static rate(s) ``lam``.

    Computed in float64 numpy at trace-build time (outside jit).
    """
    import scipy.special

    lam = np.asarray(lam, np.float64)[..., None]            # (..., 1)
    k = np.arange(max_value + 1, dtype=np.float64)
    logpmf = k * np.log(np.maximum(lam, 1e-300)) - lam - scipy.special.gammaln(k + 1)
    cdf = np.cumsum(np.exp(logpmf), axis=-1)
    cdf = cdf / cdf[..., -1:]                                # renormalize truncation
    return cdf.astype(np.float32)


def poisson_from_table(key: Array, cdf: Array, shape: tuple) -> Array:
    """Exact truncated-Poisson draws via inverse CDF (binary search).

    Args:
        key: PRNG key.
        cdf: (..., M+1) tables; leading dims must equal ``shape``'s trailing
            dims (e.g. cdf (N, K, M+1) with shape (T, N, K)).
        shape: output shape (leading axis = time/slot axis).
    Returns: float32 counts in [0, M].

    §Perf v5: ``searchsorted`` (7 binary-search steps) instead of a full
    (M+1)-wide compare+sum — the compare materialized a (T, N, K, M+1) bool
    tensor that dominated Monte-Carlo wall time.
    """
    u = jax.random.uniform(key, shape)
    batch_dims = cdf.shape[:-1]
    m1 = cdf.shape[-1]
    if batch_dims == ():
        return jnp.searchsorted(cdf, u, side="left").astype(jnp.float32)
    # Flatten table batch; move the time axis last so each table binary-
    # searches its own draw vector.
    t_axes = len(shape) - len(batch_dims)
    cdf_flat = cdf.reshape(-1, m1)                              # (B, M+1)
    u_moved = jnp.moveaxis(
        u.reshape(shape[:t_axes] + (-1,)), -1, 0
    ).reshape(-1, *shape[:t_axes])                              # (B, T...)
    out = jax.vmap(lambda c, uu: jnp.searchsorted(c, uu, side="left"))(
        cdf_flat, u_moved.reshape(cdf_flat.shape[0], -1)
    )                                                           # (B, prod(T))
    out = out.reshape((-1,) + shape[:t_axes])                   # (B, T...)
    out = jnp.moveaxis(out, 0, -1).reshape(shape)
    return out.astype(jnp.float32)
