"""Job-arrival traces (paper Sec. V-A).

The paper drives its evaluation with the production rate of Facebook's Hadoop
cluster — 350K jobs/month — and models slot-level arrivals as Poisson, citing
the measurement study that validated the Poisson assumption.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

#: Facebook Hadoop production trace rate used by the paper.
FACEBOOK_MONTHLY_JOBS = 350_000

#: Minutes per month used to convert the monthly rate (30-day month).
_MINUTES_PER_MONTH = 30 * 24 * 60


def rate_per_slot(slot_minutes: float, monthly_jobs: float = FACEBOOK_MONTHLY_JOBS) -> float:
    """Poisson rate per slot for a given slot length (paper: 5-minute slots)."""
    return monthly_jobs * slot_minutes / _MINUTES_PER_MONTH


def poisson_arrivals(
    key: Array,
    t_slots: int,
    k_types: int,
    lam: float | Array,
    a_max: float | None = None,
) -> Array:
    """(T, K) Poisson arrival counts, optionally truncated at A_max.

    The paper assumes a finite A^k_max exists; truncation (rare for the
    defaults: P[X > 3*lam] ~ 1e-9) enforces it so the Lemma-1 constant B is
    finite and testable.
    """
    lam_arr = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (k_types,))
    draws = jax.random.poisson(key, lam_arr, (t_slots, k_types)).astype(jnp.float32)
    if a_max is not None:
        draws = jnp.minimum(draws, a_max)
    return draws


def admission_split(
    arrivals: Array, admit_max: float | Array | None
) -> tuple[Array, Array]:
    """Per-class per-slot admission control: (admitted, rejected).

    The serving front end caps each class's per-slot intake at
    ``admit_max`` (scalar broadcasts over classes; a (K,) array gives
    per-class caps; ``None`` admits everything). Rejected mass is load
    shed at the door — it never enters a queue and is never billed —
    and the split is exact: ``arrivals == admitted + rejected``
    elementwise, the conservation identity the serving tests pin.
    """
    arrivals = jnp.asarray(arrivals, jnp.float32)
    if admit_max is None:
        return arrivals, jnp.zeros_like(arrivals)
    cap = jnp.broadcast_to(
        jnp.asarray(admit_max, jnp.float32), arrivals.shape[-1:]
    )
    admitted = jnp.minimum(arrivals, cap[None, :])
    return admitted, arrivals - admitted


def serve_rate_tables(
    rates, shares, mu_headroom: float = 1.0
) -> tuple[np.ndarray, np.ndarray]:
    """Inverse-CDF tables for a serving front end's (arrivals, capacity).

    Args:
        rates: (K,) per-class Poisson request rates (jobs/slot).
        shares: (N,) per-pod capacity shares; pod i's per-class service
            rate is ``shares[i] * sum(rates) / K * mu_headroom`` — the
            same straggler-noise model the original ``FleetEngine`` drew
            per slot with ``np.random``, now precomputed so the whole
            horizon is ONE batched ``searchsorted``
            (:func:`poisson_pair_from_tables`).
        mu_headroom: fleet capacity / offered load multiplier.

    Returns:
        (arr_cdf (K, M+1), mu_cdf (N, K, M+1)) float32 CDF tables sharing
        one truncation width M (Poisson tails beyond mean + 8·sqrt(mean)
        are below ~1e-9 — the finite-A_max premise of Lemma 1).
    """
    rates = np.asarray(rates, np.float64)
    shares = np.asarray(shares, np.float64)
    k = rates.shape[0]
    mu_mean = shares[:, None] * rates.sum() / k * mu_headroom * np.ones((1, k))
    top = max(float(rates.max()), float(mu_mean.max()), 1.0)
    m = int(np.ceil(top + 8.0 * np.sqrt(top) + 8.0))
    return poisson_table(rates, m), poisson_table(mu_mean, m)


# ---------------------------------------------------------------------------
# Fast exact Poisson via inverse-CDF tables (EXPERIMENTS.md §Perf v4).
#
# jax.random.poisson's transformed-rejection sampler dominated the Monte-
# Carlo engine's wall time (~97%). The rates here are STATIC per
# configuration, so inverse-CDF sampling from a precomputed table is exact
# (the distribution is already truncated at A_max by the model) and turns
# 1.4M rejection loops into one vectorized searchsorted.
# ---------------------------------------------------------------------------

def poisson_table(lam, max_value: int) -> np.ndarray:
    """(..., max_value+1) float32 CDF table(s) for static rate(s) ``lam``.

    Computed in float64 numpy at trace-build time (outside jit).
    """
    import scipy.special

    lam = np.asarray(lam, np.float64)[..., None]            # (..., 1)
    k = np.arange(max_value + 1, dtype=np.float64)
    logpmf = k * np.log(np.maximum(lam, 1e-300)) - lam - scipy.special.gammaln(k + 1)
    cdf = np.cumsum(np.exp(logpmf), axis=-1)
    cdf = cdf / cdf[..., -1:]                                # renormalize truncation
    return cdf.astype(np.float32)


def poisson_pair_from_tables(
    key_arr: Array,
    key_mu: Array,
    arr_cdf: Array,
    mu_cdf: Array,
    t_slots: int,
) -> tuple[Array, Array]:
    """Draw one run's (arrivals, mu) traces in ONE batched binary search.

    §Perf v6: the per-run Monte-Carlo build used to run two separate
    ``searchsorted`` binary-search loops (arrivals' K tables, then mu's
    N·K tables) — two compiled while-loops per run. The tables share one
    truncation width, so both searches batch into a single vmapped
    ``searchsorted`` over K + N·K rows. The uniform draws are bitwise the
    ones :func:`poisson_from_table` would consume (same keys, same
    shapes), so the realized traces are unchanged — this is purely a
    launch-count optimization.

    Args:
        key_arr / key_mu: the PRNG keys the two separate calls would use.
        arr_cdf: (K, M+1) arrival CDF tables.
        mu_cdf: (N, K, M+1) service-rate CDF tables (same M as arr_cdf).
        t_slots: T.

    Returns:
        (arrivals (T, K), mu (T, N, K)) float32 counts.
    """
    k_types = arr_cdf.shape[0]
    n, k2, m1 = mu_cdf.shape
    if arr_cdf.shape[-1] != m1:
        # Different truncation widths (e.g. fleet_256's a_max != mu_max):
        # pad the narrower CDF with trailing 1.0s — a monotone CDF padded
        # at 1.0 returns identical searchsorted results for u in [0, 1).
        m1 = max(arr_cdf.shape[-1], m1)
        arr_cdf = jnp.pad(
            arr_cdf, ((0, 0), (0, m1 - arr_cdf.shape[-1])),
            constant_values=1.0,
        )
        mu_cdf = jnp.pad(
            mu_cdf, ((0, 0), (0, 0), (0, m1 - mu_cdf.shape[-1])),
            constant_values=1.0,
        )
    u_arr = jax.random.uniform(key_arr, (t_slots, k_types))        # (T, K)
    u_mu = jax.random.uniform(key_mu, (t_slots, n, k2))            # (T, N, K)
    tables = jnp.concatenate(
        [arr_cdf.reshape(-1, m1), mu_cdf.reshape(-1, m1)], axis=0
    )                                                              # (K+NK, M+1)
    u = jnp.concatenate(
        [u_arr.reshape(t_slots, -1).T, u_mu.reshape(t_slots, -1).T], axis=0
    )                                                              # (K+NK, T)
    out = jax.vmap(lambda c, uu: jnp.searchsorted(c, uu, side="left"))(
        tables, u
    )
    arrivals = out[:k_types].T.astype(jnp.float32)                 # (T, K)
    mu = out[k_types:].T.reshape(t_slots, n, k2).astype(jnp.float32)
    return arrivals, mu


def poisson_from_table(key: Array, cdf: Array, shape: tuple) -> Array:
    """Exact truncated-Poisson draws via inverse CDF (binary search).

    Args:
        key: PRNG key.
        cdf: (..., M+1) tables; leading dims must equal ``shape``'s trailing
            dims (e.g. cdf (N, K, M+1) with shape (T, N, K)).
        shape: output shape (leading axis = time/slot axis).
    Returns: float32 counts in [0, M].

    §Perf v5: ``searchsorted`` (7 binary-search steps) instead of a full
    (M+1)-wide compare+sum — the compare materialized a (T, N, K, M+1) bool
    tensor that dominated Monte-Carlo wall time.
    """
    u = jax.random.uniform(key, shape)
    batch_dims = cdf.shape[:-1]
    m1 = cdf.shape[-1]
    if batch_dims == ():
        return jnp.searchsorted(cdf, u, side="left").astype(jnp.float32)
    # Flatten table batch; move the time axis last so each table binary-
    # searches its own draw vector.
    t_axes = len(shape) - len(batch_dims)
    cdf_flat = cdf.reshape(-1, m1)                              # (B, M+1)
    u_moved = jnp.moveaxis(
        u.reshape(shape[:t_axes] + (-1,)), -1, 0
    ).reshape(-1, *shape[:t_axes])                              # (B, T...)
    out = jax.vmap(lambda c, uu: jnp.searchsorted(c, uu, side="left"))(
        cdf_flat, u_moved.reshape(cdf_flat.shape[0], -1)
    )                                                           # (B, prod(T))
    out = out.reshape((-1,) + shape[:t_axes])                   # (B, T...)
    out = jnp.moveaxis(out, 0, -1).reshape(shape)
    return out.astype(jnp.float32)
