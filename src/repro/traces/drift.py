"""Slow-timescale dataset drift & growth traces.

The base paper freezes the dataset distribution for the whole horizon; real
geo-distributed datasets drift — new data is ingested where users generate
it, and total volume grows — which is exactly why placement must be
re-decided over time (Zhang et al., reliable geo-distributed executions).
These generators produce the slow-timescale inputs of
:func:`repro.placement.controller.simulate_placed`:

* :func:`ingest_drift_trace` — per-epoch (E, K, N) ingest distributions: a
  Dirichlet random walk on the simplex, optionally biased toward a target
  mix (e.g. "user growth concentrates at the expensive sites" — the
  adversarial scenario for static placement);
* :func:`dataset_growth_trace` — per-epoch (E, K) dataset sizes under
  compound growth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

_EPS = 1e-6


def ingest_drift_trace(
    key: Array,
    n_epochs: int,
    k_types: int,
    n_sites: int,
    conc: float = 40.0,
    bias: Array | None = None,
    bias_strength: float = 0.0,
) -> Array:
    """(E, K, N) ingest distributions: Dirichlet random walk per job type.

    Each epoch's ingest mix is drawn Dirichlet around the previous one
    (concentration ``conc`` — larger = slower drift), then pulled toward
    ``bias`` with weight ``bias_strength``. Rows sum to 1.

    Args:
        key: PRNG key.
        n_epochs / k_types / n_sites: trace shape.
        conc: Dirichlet concentration of the walk (wander speed).
        bias: optional (N,) attractor distribution.
        bias_strength: per-epoch pull toward the attractor in [0, 1].
    """
    if bias is None:
        bias = jnp.full((n_sites,), 1.0 / n_sites, jnp.float32)
    bias = jnp.asarray(bias, jnp.float32)

    k_init, k_walk = jax.random.split(key)
    c0 = jax.random.dirichlet(
        k_init, jnp.full((n_sites,), 6.0, jnp.float32), (k_types,)
    )                                                               # (K, N)
    step_keys = jax.random.split(k_walk, n_epochs)

    def step(c, kk):
        keys = jax.random.split(kk, k_types)
        walked = jax.vmap(
            lambda k1, ck: jax.random.dirichlet(k1, conc * ck + _EPS)
        )(keys, c)                                                  # (K, N)
        pulled = (1.0 - bias_strength) * walked + bias_strength * bias[None, :]
        pulled = pulled / jnp.sum(pulled, axis=1, keepdims=True)
        return pulled, pulled

    _, trace = jax.lax.scan(step, c0, step_keys)
    return trace                                                    # (E, K, N)


def dataset_growth_trace(
    n_epochs: int,
    k_types: int,
    base_gb: float | Array = 100.0,
    growth_per_epoch: float = 0.0,
) -> Array:
    """(E, K) dataset sizes: ``base_gb * (1 + g)^e`` compound growth."""
    base = jnp.broadcast_to(jnp.asarray(base_gb, jnp.float32), (k_types,))
    factor = (1.0 + growth_per_epoch) ** jnp.arange(n_epochs, dtype=jnp.float32)
    return factor[:, None] * base[None, :]
