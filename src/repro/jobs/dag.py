"""Padded, jit-safe stage-DAG representation of geo-analytics jobs.

The paper's fine-grained paradigm (Sec. III) decomposes an analytics job
into map tasks at the data sites, an intermediate-data transfer over the
WAN, and aggregation at the global manager — a *chain of stages* with data
shrinking (or occasionally growing) at each hop. The base simulator
collapses this structure into a single dispatch fraction per job; the
:mod:`repro.jobs` subsystem makes it first-class.

A :class:`StageDag` describes the per-type stage chain in three padded
(K, S) arrays — S is the maximum stage count over the K job types, shorter
chains are padded with identity stages and masked out:

* ``compute[k, s]``   — compute intensity of stage s (fraction of the
  job's total IT work P^k; active rows typically sum to 1). A stage with
  intensity c consumes service capacity at rate c — its effective service
  rate is ``mu / c`` — and bills ``c * e[k, i]`` per job at its chosen
  site.
* ``shuffle_gb[k, s]`` — GB of input data a type-k job must feed *into*
  stage s. For s = 0 this is the map stage's remote-input pull (zero under
  the paper's data-local-map premise); for s > 0 it is the intermediate
  (shuffle) volume produced by stage s-1, i.e. the quantity GMSA routes
  implicitly but never bills.
* ``stage_mask[k, s]`` — 1.0 while the chain is active, then 0.0. Masks
  are monotone (a prefix of ones): precedence is the linear chain
  s -> s+1, the level-ordered frontier every stage-structured DAG
  scheduler executes.

Everything is a plain array NamedTuple — hashable-free, traceable,
vmappable — so a dag rides through ``jax.jit`` closures untouched.

Volumes are conveniently derived from *selectivities* (output/input volume
ratio per stage, the standard analytics measure):
``shuffle_gb[k, s] = input_gb[k] * prod_{u<s} selectivity[k, u]`` — see
:func:`shuffle_volumes_from_selectivity` and the trace generators in
:mod:`repro.traces.stages`.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
from jax import Array


class StageDag(NamedTuple):
    """Padded stage-chain description of K job types (shapes (K, S)).

    Attributes:
        compute: per-stage compute intensity (fraction of P^k; padded 1.0).
        shuffle_gb: GB entering each stage per job (padded 0.0).
        stage_mask: {0, 1} activity mask, monotone non-increasing per row.
    """

    compute: Array
    shuffle_gb: Array
    stage_mask: Array

    @property
    def k_types(self) -> int:
        return self.compute.shape[0]

    @property
    def s_max(self) -> int:
        return self.compute.shape[1]

    @property
    def n_stages(self) -> Array:
        """(K,) number of active stages per job type."""
        return jnp.sum(self.stage_mask, axis=1).astype(jnp.int32)


def chain_dag(
    compute: Array | Sequence,
    shuffle_gb: Array | Sequence,
    stage_mask: Array | Sequence | None = None,
) -> StageDag:
    """Build a :class:`StageDag` from (K, S) arrays, normalizing dtypes.

    ``stage_mask`` defaults to all-active. Padded (masked-out) entries are
    forced to the identity values — compute 1.0 (so the padded stage's
    effective service rate stays finite) and shuffle 0.0 — regardless of
    what the caller put there, keeping the engine's arithmetic on dead
    stages exact no-ops.
    """
    compute = jnp.asarray(compute, jnp.float32)
    shuffle_gb = jnp.asarray(shuffle_gb, jnp.float32)
    if stage_mask is None:
        stage_mask = jnp.ones_like(compute)
    stage_mask = jnp.asarray(stage_mask, jnp.float32)
    compute = jnp.where(stage_mask > 0.5, compute, 1.0)
    shuffle_gb = jnp.where(stage_mask > 0.5, shuffle_gb, 0.0)
    return StageDag(compute, shuffle_gb, stage_mask)


def single_stage_dag(k_types: int) -> StageDag:
    """The trivial one-stage chain: the base paper's monolithic job.

    compute 1, no shuffle — :func:`repro.jobs.engine.simulate_staged` over
    this dag reproduces :func:`repro.core.simulator.simulate` bit for bit
    (the equivalence the test suite pins down).
    """
    ones = jnp.ones((k_types, 1), jnp.float32)
    return StageDag(ones, jnp.zeros((k_types, 1), jnp.float32), ones)


def map_reduce_dag(
    k_types: int,
    intermediate_gb: float | Array = 5.0,
    map_share: float = 0.6,
    input_gb: float | Array = 0.0,
) -> StageDag:
    """The canonical two-stage map -> reduce/aggregate chain.

    Args:
        k_types: number of job types (the scalars broadcast).
        intermediate_gb: per-job map-output volume shuffled into the
            reduce stage.
        map_share: compute fraction of the map stage (reduce gets the rest).
        input_gb: optional remote-input pull billed to the map stage
            (0 under the paper's data-local-map premise).
    """
    compute = jnp.broadcast_to(
        jnp.asarray([map_share, 1.0 - map_share], jnp.float32), (k_types, 2)
    )
    shuffle = jnp.stack(
        [
            jnp.broadcast_to(jnp.asarray(input_gb, jnp.float32), (k_types,)),
            jnp.broadcast_to(jnp.asarray(intermediate_gb, jnp.float32), (k_types,)),
        ],
        axis=1,
    )
    return chain_dag(compute, shuffle)


def pad_chains(
    computes: Sequence[Sequence[float]],
    shuffles: Sequence[Sequence[float]],
) -> StageDag:
    """Assemble per-type chains of *different* depths into one padded dag.

    Args:
        computes: K lists of per-stage compute intensities.
        shuffles: K lists of per-stage input volumes (same lengths).

    Returns:
        A (K, S_max) :class:`StageDag` with monotone masks.
    """
    if len(computes) != len(shuffles):
        raise ValueError("computes and shuffles must list the same K types")
    s_max = max(len(c) for c in computes)
    comp, shuf, mask = [], [], []
    for c, g in zip(computes, shuffles):
        if len(c) != len(g):
            raise ValueError(
                f"stage count mismatch: {len(c)} intensities vs "
                f"{len(g)} volumes"
            )
        pad = s_max - len(c)
        comp.append(list(c) + [1.0] * pad)
        shuf.append(list(g) + [0.0] * pad)
        mask.append([1.0] * len(c) + [0.0] * pad)
    return chain_dag(jnp.asarray(comp), jnp.asarray(shuf), jnp.asarray(mask))


def shuffle_volumes_from_selectivity(
    input_gb: Array | float,
    selectivity: Array,
    bill_input: bool = False,
) -> Array:
    """(K, S) per-stage input volumes from per-stage selectivities.

    Stage s's input volume is the job input shrunk by every upstream
    stage: ``input_gb * prod_{u<s} selectivity[:, u]``. Stage 0's entry is
    0 unless ``bill_input`` (the data-local-map premise — map input never
    crosses the WAN).

    Args:
        input_gb: (K,) or scalar per-job input dataset size.
        selectivity: (K, S) per-stage output/input volume ratios.
        bill_input: charge the full input to stage 0 (remote-map model).
    """
    selectivity = jnp.asarray(selectivity, jnp.float32)
    k_types = selectivity.shape[0]
    base = jnp.broadcast_to(jnp.asarray(input_gb, jnp.float32), (k_types,))
    # Volume entering stage s = input * prod of selectivities before s.
    shifted = jnp.concatenate(
        [jnp.ones((k_types, 1), jnp.float32), selectivity[:, :-1]], axis=1
    )
    vols = base[:, None] * jnp.cumprod(shifted, axis=1)            # (K, S)
    if not bill_input:
        vols = vols.at[:, 0].set(0.0)
    return vols


def validate_dag(dag: StageDag) -> None:
    """Eager sanity checks (not jit-safe; call at construction time)."""
    k, s = dag.compute.shape
    if dag.shuffle_gb.shape != (k, s) or dag.stage_mask.shape != (k, s):
        raise ValueError(
            f"inconsistent dag shapes: compute {dag.compute.shape}, "
            f"shuffle {dag.shuffle_gb.shape}, mask {dag.stage_mask.shape}"
        )
    mask = jnp.asarray(dag.stage_mask)
    if not bool(jnp.all((mask == 0.0) | (mask == 1.0))):
        raise ValueError("stage_mask must be {0, 1}")
    if not bool(jnp.all(mask[:, 0] == 1.0)):
        raise ValueError("every job type needs at least one active stage")
    if not bool(jnp.all(mask[:, :-1] >= mask[:, 1:])):
        raise ValueError("stage_mask rows must be monotone (a prefix of 1s)")
    if not bool(jnp.all(jnp.where(mask > 0.5, dag.compute, 1.0) > 0.0)):
        raise ValueError("active stages need strictly positive compute")
    if not bool(jnp.all(dag.shuffle_gb >= 0.0)):
        raise ValueError("shuffle volumes must be non-negative")
