"""Joint manager selection & stage placement (per-slot decision rules).

Extends GMSA's LP-vertex trick (:mod:`repro.core.gmsa`) from one decision
per job type to one decision per *stage*: the map stage is pinned to
``data_dist`` locality (the GDA premise — map tasks run where the data
lives, nothing crosses the WAN), and every downstream stage's site is
chosen by a drift-plus-penalty score that now includes the
intermediate-data WAN energy term the base algorithm routes implicitly
but never bills:

    score[k, s, i] = F^{k,s} * ( Q_i^{k,s} - mu_i^{k,s}
                                 + V * [ c^{k,s} e_i^k  +  G^{k,s} w_i^{k,s} ] )

with ``F`` the flow entering the stage this slot, ``c`` the stage compute
intensity, ``G`` the stage's shuffle volume, and
``w_i = sum_{j != i} src_j * price[j, i]`` the expected $-per-GB of
pulling the upstream output mix ``src`` to site i, priced exactly as
:func:`repro.placement.wan.transfer_cost` bills it (half the energy at
each endpoint, local pulls free). For one-hot decisions the score's WAN
term equals the engine's ``transfer_plan`` bill to the byte, so the argmin
vertex remains the exact LP optimum of the per-stage relaxation.

Because downstream shuffle sources depend on upstream completions, the
policy replicates the engine's within-slot flow propagation
(:func:`flow_step` — the single definition shared with
:mod:`repro.jobs.engine`) stage by stage: decide f^{k,0}, advance the
flow, decide f^{k,1} against the realized source mix, and so on. All
closed-form, jit-safe, vmappable over Monte-Carlo runs.

``stage_oblivious`` adapts any base simulator policy (GMSA, DATA, RANDOM,
JSQ, GREEDY-COST) to the staged engine: one manager choice per type from
the aggregate backlog, applied to every stage — the current, shuffle-blind
dispatch the benchmarks compare against.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array

from repro.core.gmsa import drift_plus_penalty_scores
from repro.jobs.dag import StageDag
from repro.placement.wan import WanModel, expected_pull

_EPS = 1e-12


def stage_service_rates(mu: Array, dag: StageDag) -> Array:
    """(N, K, S) effective per-stage service rates.

    A stage with compute intensity c occupies a server c job-units per
    completion, so the base (N, K) service-rate trace stretches to
    ``mu / c`` per stage. Padded stages carry intensity 1.0 (exact
    identity — the single-stage dag reproduces ``mu`` bit for bit).
    """
    return mu[:, :, None] / dag.compute[None, :, :]


def stage_service_rates_all(mu_all: Array, dag: StageDag) -> Array:
    """(T, N, K, S) per-stage service rates for a whole trace in one op.

    The hoisted form of :func:`stage_service_rates` — the staged engine
    computes it once outside its scan body instead of per slot. Identical
    values (same ``mu / c`` divide, padded stages at the exact-identity
    intensity 1.0).
    """
    return mu_all[..., None] / dag.compute[None, None, :, :]


def flow_step(
    q_s: Array, f_s: Array, total_in: Array, mu_s: Array
) -> tuple[Array, Array]:
    """Within-slot flow through one stage: completions and their locations.

    Stage s receives ``f_s * total_in`` on top of backlog ``q_s`` and
    serves at most ``mu_s`` — completions this slot are
    ``min(q_s + f_s * total_in, mu_s)`` (the served mass of Eq. 1's max).
    The single definition shared by the engine's billing loop and the
    stage-aware policy's lookahead, so the score's source mix is exactly
    the mix the engine bills.

    Returns:
        (total_done, src): (K,) completions leaving the stage and their
        (K, N) site distribution (uniform fallback for zero flow — the
        downstream volume is zero there, so the choice is billing-inert).
    """
    n = q_s.shape[0]
    done = jnp.minimum(q_s + f_s * total_in[None, :], mu_s)        # (N, K)
    total_done = jnp.sum(done, axis=0)                             # (K,)
    src = jnp.where(
        total_done[:, None] > _EPS,
        done.T / jnp.maximum(total_done[:, None], _EPS),
        1.0 / n,
    )                                                              # (K, N)
    return total_done, src


def staged_stage_scores(
    q_s: Array,
    total_in: Array,
    mu_s: Array,
    e: Array,
    compute_s: Array,
    shuffle_gb_s: Array,
    pull: Array,
    v: float | Array,
) -> Array:
    """(K, N) drift-plus-penalty scores for one stage's site choice.

    The base GMSA score (:func:`repro.core.gmsa.drift_plus_penalty_scores`)
    with the per-job penalty extended by the stage's WAN pull term:
    ``e_stage[k, i] = compute_s[k] * e[k, i] + shuffle_gb_s[k] * pull[k, i]``
    where ``pull[k, i] = sum_j src[k, j] * price[j, i]`` — the expected
    $-per-GB of pulling the upstream output mix to site i, computed fused
    by :func:`repro.placement.wan.expected_pull` (no (N, N) price matrix
    materialized per slot).
    """
    e_stage = compute_s[:, None] * e + shuffle_gb_s[:, None] * pull
    return drift_plus_penalty_scores(q_s, total_in, mu_s, e_stage, v)


def hedge_clone_choice(
    f_s: Array, mu_s: Array, stage_mask_s: Array, hedge: float
) -> tuple[Array, Array]:
    """Speculative re-execution decision for one stage: clone site + boost.

    The straggler signal is *relative*: the dispatched sites' effective
    service rate ``mu_p = Σ_n f·mu`` (exact for one-hot downstream
    choices; the f-weighted mean for the fractional pinned map) against
    the best alternative site ``r = argmax_n mu·(1 - f)`` — high spare
    rate, low current share. The hedge fires when ``mu_p < hedge·mu_r``:
    the dispatch target is running at less than ``hedge`` of what the
    runner-up could deliver, so the stage is cloned there.

    First-completion enters the fluid recursion as a service-rate boost
    at the dispatched sites: the clone re-executes the same queued work,
    and whichever copy finishes first completes the job, so the stage's
    drain rate rises by the clone's rate — ``mu_eff = mu + f·boost``
    with ``boost = mu_r`` where the hedge fired, 0 elsewhere (an exact
    ``+ 0.0`` identity when nothing fires). The engine bills the work
    the clone actually completes (the boost-attributable completions) at
    the clone site's energy price plus the WAN pull of its inputs.

    Returns:
        (g_s, boost): the (N, K) one-hot clone matrix (zero columns
        where the hedge did not fire) and the (K,) rate boost.
    """
    n = f_s.shape[0]
    mu_p = jnp.sum(f_s * mu_s, axis=0)                         # (K,)
    alt = mu_s * (1.0 - f_s)                                   # (N, K)
    r_hot = (
        jnp.arange(n)[:, None] == jnp.argmax(alt, axis=0)[None]
    ).astype(f_s.dtype)                                        # (N, K)
    mu_r = jnp.sum(r_hot * mu_s, axis=0)                       # (K,)
    fire = ((mu_p < hedge * mu_r) & (stage_mask_s > 0.0)).astype(f_s.dtype)
    return r_hot * fire[None, :], mu_r * fire


def make_staged_policy(dag: StageDag, wan: WanModel, pin_map: bool = True,
                       hedge: float | None = None):
    """Stage-aware GMSA: per-stage LP-vertex dispatch with WAN pricing.

    Returns a policy with the staged signature
    ``(key, q, arrivals, mu, e, aux, scalar) -> f`` where ``q``/``f`` are
    (N, K, S) and ``aux = (data_dist, wpue)`` — V rides in as the traced
    ``scalar`` exactly like :func:`repro.core.gmsa.gmsa_policy`, so a
    V-sweep reuses one compilation.

    Args:
        dag: the stage chain (closed over; arrays, so the closure stays
            jit-transparent).
        wan: WAN model pricing the shuffle pulls.
        pin_map: pin stage 0 to ``data_dist`` (data-local map). When
            False, stage 0 is score-chosen like any other stage — only
            meaningful when the dag bills a stage-0 input pull.
        hedge: speculative re-execution threshold (``None`` disables —
            the policy keeps its exact pre-hedging contract). When set,
            each stage whose dispatched service rate falls below
            ``hedge`` times the runner-up site's rate is cloned there
            (:func:`hedge_clone_choice`), the within-slot flow walk runs
            on the first-completion boosted rates, and the policy
            additionally returns the (N, K, S) clone matrix
            (``returns_hedge`` contract of ``simulate_staged``).
    """

    def policy(key, q, arrivals, mu, e, aux, scalar):
        del key
        data_dist, wpue = aux
        n = q.shape[0]
        mu_stages = stage_service_rates(mu, dag)                   # (N, K, S)
        total_in = arrivals                                        # (K,)
        src = data_dist                                            # (K, N)
        cols, ins, clones = [], [], []
        for s in range(dag.s_max):
            mu_s = mu_stages[:, :, s]
            if s == 0 and pin_map:
                f_s = data_dist.T                                  # (N, K)
            else:
                # Fused expected-pull pricing (link_price_matrix *
                # energy_per_gb semantics, no (N, N) matrix in the
                # per-slot body; src is on the simplex by the flow_step
                # contract).
                pull = (expected_pull(src, wpue, assume_simplex=True)
                        * wan.energy_per_gb)
                scores = staged_stage_scores(
                    q[:, :, s], total_in, mu_s, e,
                    dag.compute[:, s], dag.shuffle_gb[:, s],
                    pull, scalar,
                )                                                  # (K, N)
                f_s = (
                    jnp.arange(n)[:, None] == jnp.argmin(scores, axis=1)[None]
                ).astype(q.dtype)                                  # (N, K)
            cols.append(f_s)
            ins.append(total_in)
            if hedge is not None:
                # Clone stragglers to the runner-up and walk the flow on
                # the boosted (first-completion) rates — the engine
                # re-derives the identical boost from g, so the exported
                # inflows replay bit-for-bit.
                g_s, boost = hedge_clone_choice(
                    f_s, mu_s, dag.stage_mask[:, s], hedge
                )
                clones.append(g_s)
                mu_s = mu_s + f_s * boost[None, :]
            total_done, src = flow_step(q[:, :, s], f_s, total_in, mu_s)
            if s + 1 < dag.s_max:
                total_in = total_done * dag.stage_mask[:, s + 1]
        # The lookahead already walked the exact within-slot flow the
        # engine would re-derive (flow_step is the shared definition), so
        # export the per-stage inflows and let the engine skip its own
        # recursion (``returns_flow`` contract of ``simulate_staged``).
        f = jnp.stack(cols, axis=-1)
        in_stack = jnp.stack(ins, axis=-1)                         # (K, S)
        if hedge is not None:
            return f, in_stack, jnp.stack(clones, axis=-1)
        return f, in_stack

    policy.staged = True
    policy.consumes_key = False
    policy.returns_flow = True
    policy.returns_hedge = hedge is not None
    return policy


def staged_dispatch_fn(dag: StageDag, wan: WanModel, v: float,
                       pin_map: bool = True, hedge: float | None = None):
    """Closure adapter binding a static V (one compilation per V)."""
    base = make_staged_policy(dag, wan, pin_map=pin_map, hedge=hedge)

    def policy(key, q, arrivals, mu, e, aux, scalar):
        del scalar
        return base(key, q, arrivals, mu, e, aux, v)

    policy.staged = True
    policy.consumes_key = False
    policy.returns_flow = True
    policy.returns_hedge = hedge is not None
    return policy


def stage_oblivious(policy, pin_map: bool = False):
    """Adapt a base simulator policy to the staged engine, shuffle-blind.

    The base policy sees the aggregate backlog ``sum_s Q`` and the plain
    per-job cost table — exactly what it sees in ``simulate`` — and its
    (N, K) decision applies to *every* stage: the job follows its manager,
    no per-stage queues, no WAN term. This is the "current" dispatch the
    jobs benchmarks compare stage-aware scheduling against; with a
    single-stage dag it reproduces ``simulate`` bit for bit.

    Args:
        policy: any base policy ``(key, q(N,K), arrivals, mu, e, aux,
            scalar) -> f(N,K)``. Policies declaring ``wants_wpue = True``
            (the Pallas-kernel dispatch of
            :func:`repro.core.gmsa.make_kernel_policy`) receive the full
            ``(data_dist, omega*PUE)`` aux pair, exactly as
            :func:`repro.core.simulator.simulate` hands it to them — the
            staged engines always carry ``wpue``, so the fleet-scale kernel
            path composes with stage-structured queues unchanged. Policies
            additionally declaring ``wants_r = True`` (the carried-r kernel
            variant) get the engine's ``(data_dist, wpue, r_t)`` triple
            passed through verbatim.
        pin_map: override stage 0 with data-local map placement (used when
            benchmarking against stage-aware policies under the same
            data-local-map premise; keep False for exact base semantics).
    """
    wants_wpue = getattr(policy, "wants_wpue", False)
    wants_r = getattr(policy, "wants_r", False)

    def staged(key, q, arrivals, mu, e, aux, scalar):
        data_dist = aux[0]
        if wants_r:
            base_aux = aux                 # (data_dist, wpue, r_t) verbatim
        elif wants_wpue:
            base_aux = (data_dist, aux[1])
        else:
            base_aux = data_dist
        q_total = jnp.sum(q, axis=-1)                              # (N, K)
        f_base = policy(key, q_total, arrivals, mu, e, base_aux, scalar)
        f = jnp.broadcast_to(f_base[:, :, None], q.shape)
        if pin_map:
            f = jnp.concatenate(
                [data_dist.T[:, :, None], f[:, :, 1:]], axis=-1
            )
        return f

    staged.staged = True
    staged.state_independent = getattr(policy, "state_independent", False)
    staged.consumes_key = getattr(policy, "consumes_key", True)
    staged.wants_r = wants_r
    staged.static_r = getattr(policy, "static_r", False)
    return staged
