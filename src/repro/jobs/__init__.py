"""repro.jobs — stage-structured geo-analytics jobs.

The paper's GMSA treats a job as one indivisible unit: a single dispatch
fraction per slot, the (K, N, N) ratio tensor silently absorbing where the
map/reduce/aggregation work lands, and the intermediate-data transfer it
implies never modeled or billed. This subsystem makes jobs first-class
multi-stage chains and schedules them *jointly* with GMSA:

* :mod:`repro.jobs.dag`       — padded, jit-safe stage-DAG representation
  (per-stage compute intensity, shuffle volume/selectivity, chain
  precedence via monotone masks).
* :mod:`repro.jobs.scheduler` — per-slot joint decision rules: map pinned
  to ``data_dist`` locality, downstream stages chosen by the GMSA
  drift-plus-penalty score extended with the intermediate-data WAN energy
  term (priced via :class:`repro.placement.wan.WanModel`); plus the
  ``stage_oblivious`` adapter exposing every base policy to the staged
  engine.
* :mod:`repro.jobs.engine`    — ``simulate_staged``: a jit scan engine
  with per-stage queues generalizing Eq. 1, reusing the simulator's
  ``slot_step``/``energy_tables``, vmappable for Monte-Carlo, and
  composable with ``simulate_placed`` (time-varying ``r``/``data_dist``)
  so slow-loop re-placement reshapes map locality.

Shuffle-volume/selectivity traces live in :mod:`repro.traces.stages`; the
multi-stage Facebook-4DC scenario in
:mod:`repro.configs.facebook_4dc_stages`; the stage-aware vs.
stage-oblivious comparison in ``benchmarks/jobs_bench.py``.
"""

from repro.jobs.dag import (
    StageDag,
    chain_dag,
    map_reduce_dag,
    pad_chains,
    shuffle_volumes_from_selectivity,
    single_stage_dag,
    validate_dag,
)
from repro.jobs.engine import (
    StagedOutputs,
    simulate_staged,
    simulate_staged_many,
    summarize_staged,
)
from repro.jobs.scheduler import (
    flow_step,
    hedge_clone_choice,
    make_staged_policy,
    stage_oblivious,
    stage_service_rates,
    stage_service_rates_all,
    staged_dispatch_fn,
    staged_stage_scores,
)

__all__ = [
    "StageDag",
    "chain_dag",
    "map_reduce_dag",
    "pad_chains",
    "shuffle_volumes_from_selectivity",
    "single_stage_dag",
    "validate_dag",
    "StagedOutputs",
    "simulate_staged",
    "simulate_staged_many",
    "summarize_staged",
    "flow_step",
    "hedge_clone_choice",
    "make_staged_policy",
    "stage_oblivious",
    "stage_service_rates",
    "stage_service_rates_all",
    "staged_dispatch_fn",
    "staged_stage_scores",
]
