"""``simulate_staged`` — trace-driven engine for stage-structured jobs.

Generalizes :func:`repro.core.simulator.simulate` from one queue per
(DC, type) to one per (DC, type, stage): per slot, each stage's inflow is
dispatched by the policy's (N, K, S) decision, Eq. 1 advances every stage
queue (via the shared :func:`repro.core.simulator.slot_step` body — the
equivalence with ``simulate`` is structural), completions flow down the
chain within the slot (a tandem of queues), and the intermediate bytes
each hop ships across the WAN are billed through
:func:`repro.placement.wan.transfer_plan` / ``transfer_cost`` — the
surplus/deficit coupling, so a stage whose destination mix equals its
source mix (a data-local map, a co-located reduce) moves nothing.

The per-slot semantics, stage by stage (s = 0..S-1, a static unrolled
loop):

    in^{k,s}   = f^{k,s} * F^{k,s}          F^{k,0} = A^k(t), else the
                                            upstream completions
    Q^{k,s}    + Eq. 1 under (in, mu / c^{k,s})
    done^{k,s} = min(Q + in, mu/c)          flows to stage s+1 (or out)
    WAN bill   = transfer_cost(transfer_plan(src^{k,s}, f^{k,s},
                               F^{k,s} * G^{k,s}))

With a single-stage dag (compute 1, shuffle 0) every extra term is an
exact float identity and ``simulate_staged`` reproduces ``simulate``'s
cost/backlog/dispatch bit for bit on every policy — the test suite pins
this down. ``r`` and ``data_dist`` may carry a leading time axis exactly
as in ``simulate``, which is how the subsystem composes with
:func:`repro.placement.controller.simulate_placed`: run the slow loop,
repeat its per-epoch ``placements``/``r_trace`` per slot, and feed them
here — re-placement reshapes the map stage's locality (and the whole
chain's shuffle sources) over the horizon.

The whole run is one ``jax.lax.scan`` (jit); Monte-Carlo replication is a
``jax.vmap`` over PRNG keys (``simulate_staged_many``), sharing one
compilation — the same perf structure as the base simulator.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.simulator import (
    PolicyFn,
    SimInputs,
    _energy_tables,
    slot_step,
)
from repro.jobs.dag import StageDag
from repro.jobs.scheduler import flow_step, stage_oblivious, stage_service_rates
from repro.placement.wan import WanModel, transfer_cost, transfer_plan


class StagedOutputs(NamedTuple):
    """Per-slot traces of one staged run (leading runs axis under vmap)."""

    cost: Array           # (T,) per-slot stage-compute energy cost
    energy: Array         # (T,) PUE-weighted compute energy (unpriced)
    backlog_total: Array  # (T,) sum over all (DC, type, stage) backlogs
    backlog_avg: Array    # (T,) mean backlog per (DC, type, stage)
    q_final: Array        # (N, K, S)
    f_trace: Array        # (T, N, K, S) per-stage dispatch decisions
    wan_cost: Array       # (T,) $ billed for intermediate-data movement
    wan_energy: Array     # (T,) WAN energy (job-energy equivalents)
    wan_gb: Array         # (T,) intermediate GB crossing the WAN
    completed: Array      # (T, K) jobs finishing their last stage per slot


def _chain_sum(terms: list) -> Array:
    """Left-fold sum that is the identity for one term (bit-exactness)."""
    acc = terms[0]
    for t in terms[1:]:
        acc = acc + t
    return acc


@functools.partial(jax.jit, static_argnames=("policy",))
def simulate_staged(
    inputs: SimInputs,
    dag: StageDag,
    wan: WanModel,
    policy: PolicyFn,
    key: Array,
    scalar: float | Array = 0.0,
) -> StagedOutputs:
    """Run one stage-structured trace-driven simulation under ``policy``.

    Args:
        inputs: the usual trace bundle; ``r``/``data_dist`` may be static
            or time-varying exactly as in ``simulate``.
        dag: the (K, S) stage chain.
        wan: WAN model pricing the inter-stage shuffle bytes.
        policy: a staged policy (attribute ``staged = True``, signature
            ``(key, q(N,K,S), arrivals, mu, e, (data_dist, wpue), scalar)
            -> f(N,K,S)``) or any base simulator policy, which is wrapped
            by :func:`repro.jobs.scheduler.stage_oblivious` automatically.
        key: PRNG key (consumed exactly as ``simulate`` does, on both the
            precomputed and the carried-key policy paths).
        scalar: traced control parameter forwarded to the policy (GMSA's V).
    """
    t_slots, k_types = inputs.arrivals.shape
    n = inputs.mu.shape[1]
    s_max = dag.s_max
    if dag.compute.shape[0] != k_types:
        raise ValueError(
            f"dag is for K={dag.compute.shape[0]} types, inputs carry "
            f"K={k_types}"
        )
    q0 = jnp.zeros((n, k_types, s_max), jnp.float32)
    e_cost_all, e_raw_all = _energy_tables(inputs)                 # (T, K, N)
    wpue_all = inputs.omega * inputs.pue                           # (T, N)
    scalar = jnp.asarray(scalar, jnp.float32)

    pol = policy if getattr(policy, "staged", False) else stage_oblivious(policy)
    dd_varying = inputs.data_dist.ndim == 3                        # (T, K, N)

    f_all = None
    if getattr(pol, "state_independent", False):
        keys = jax.random.split(key, t_slots)
        if dd_varying:
            f_all = jax.vmap(
                lambda kk, a, m, e, d, w: pol(kk, q0, a, m, e, (d, w), scalar)
            )(keys, inputs.arrivals, inputs.mu, e_cost_all,
              inputs.data_dist, wpue_all)
        else:
            f_all = jax.vmap(
                lambda kk, a, m, e, w: pol(
                    kk, q0, a, m, e, (inputs.data_dist, w), scalar
                )
            )(keys, inputs.arrivals, inputs.mu, e_cost_all, wpue_all)

    def slot(carry, xs):
        q, key = carry
        if dd_varying:
            xs, dd_t = xs[:-1], xs[-1]
        else:
            dd_t = inputs.data_dist
        arrivals, mu, e_cost, e_raw, omega_t, pue_t = xs[:6]
        rest = xs[6:]
        if f_all is None:
            key, sub = jax.random.split(key)
            wpue_t = omega_t * pue_t
            f = pol(sub, q, arrivals, mu, e_cost, (dd_t, wpue_t), scalar)
        else:
            (f,) = rest

        mu_stages = stage_service_rates(mu, dag)                   # (N, K, S)
        total_in = arrivals                                        # (K,)
        src = dd_t                                                 # (K, N)
        costs, energies, btots, bavgs = [], [], [], []
        wan_cs, wan_es, wan_gbs = [], [], []
        q_cols = []
        completed = jnp.zeros((k_types,), jnp.float32)
        for s in range(s_max):
            f_s = f[:, :, s]                                       # (N, K)
            mu_s = mu_stages[:, :, s]
            ec_s = e_cost * dag.compute[:, s, None]                # (K, N)
            er_s = e_raw * dag.compute[:, s, None]
            # Intermediate bytes: only the source/destination mismatch
            # crosses the WAN (transfer_plan's surplus/deficit coupling).
            vol = total_in * dag.shuffle_gb[:, s]                  # (K,)
            plan = transfer_plan(src, f_s.T, vol)                  # (K, N, N)
            wc, we, wgb = transfer_cost(plan, wan, omega_t, pue_t)
            q_next_s, (c_s, en_s, bt_s, ba_s, _) = slot_step(
                q[:, :, s], f_s, total_in, mu_s, ec_s, er_s
            )
            total_done, src = flow_step(q[:, :, s], f_s, total_in, mu_s)
            nxt = (
                dag.stage_mask[:, s + 1]
                if s + 1 < s_max
                else jnp.zeros((k_types,), jnp.float32)
            )
            completed = completed + total_done * (dag.stage_mask[:, s] - nxt)
            total_in = total_done * nxt
            q_cols.append(q_next_s)
            costs.append(c_s)
            energies.append(en_s)
            btots.append(bt_s)
            bavgs.append(ba_s)
            wan_cs.append(wc)
            wan_es.append(we)
            wan_gbs.append(wgb)

        q_next = jnp.stack(q_cols, axis=-1)                        # (N, K, S)
        out = (
            _chain_sum(costs),
            _chain_sum(energies),
            _chain_sum(btots),
            _chain_sum(bavgs) / s_max,
            f,
            _chain_sum(wan_cs),
            _chain_sum(wan_es),
            _chain_sum(wan_gbs),
            completed,
        )
        return (q_next, key), out

    xs = (inputs.arrivals, inputs.mu, e_cost_all, e_raw_all,
          inputs.omega, inputs.pue)
    if f_all is not None:
        xs = xs + (f_all,)
    if dd_varying:
        xs = xs + (inputs.data_dist,)
    (q_final, _), (cost, energy, btot, bavg, f_trace, wan_c, wan_e,
                   wan_gb, completed) = jax.lax.scan(slot, (q0, key), xs)
    return StagedOutputs(
        cost=cost, energy=energy, backlog_total=btot, backlog_avg=bavg,
        q_final=q_final, f_trace=f_trace,
        wan_cost=wan_c, wan_energy=wan_e, wan_gb=wan_gb,
        completed=completed,
    )


@functools.partial(jax.jit, static_argnames=("policy", "build_inputs", "n_runs"))
def simulate_staged_many(
    build_inputs: Callable[[Array], SimInputs],
    dag: StageDag,
    wan: WanModel,
    policy: PolicyFn,
    key: Array,
    n_runs: int,
    scalar: float | Array = 0.0,
) -> StagedOutputs:
    """Monte-Carlo replication of :func:`simulate_staged` (vmap over keys).

    Mirrors ``simulate_many``: fresh stochastic traces + policy randomness
    per run, deterministic traces (prices, PUE, the dag, the WAN model)
    shared. One compilation serves every run.
    """
    keys = jax.random.split(key, n_runs)

    def one(run_key):
        k_build, k_sim = jax.random.split(run_key)
        return simulate_staged(
            build_inputs(k_build), dag, wan, policy, k_sim, scalar
        )

    return jax.vmap(one)(keys)


def summarize_staged(outs: StagedOutputs) -> dict:
    """Time-averaged scalars incl. the shuffle WAN bill (any runs axis)."""
    compute = jnp.mean(outs.cost)
    wan = jnp.mean(outs.wan_cost)
    return {
        "time_avg_compute_cost": float(compute),
        "time_avg_wan_cost": float(wan),
        "time_avg_total_cost": float(compute + wan),
        "time_avg_energy": float(jnp.mean(outs.energy)),
        "time_avg_backlog": float(jnp.mean(outs.backlog_avg)),
        "total_wan_gb": float(jnp.mean(jnp.sum(outs.wan_gb, axis=-1))),
        "jobs_completed": float(jnp.mean(jnp.sum(outs.completed, axis=(-2, -1)))),
        "final_backlog_total": float(
            jnp.mean(outs.q_final.sum(axis=(-3, -2, -1)))
        ),
    }
