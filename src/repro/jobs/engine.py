"""``simulate_staged`` — trace-driven engine for stage-structured jobs.

Generalizes :func:`repro.core.simulator.simulate` from one queue per
(DC, type) to one per (DC, type, stage): per slot, each stage's inflow is
dispatched by the policy's (N, K, S) decision, Eq. 1 advances every stage
queue (the scan body evaluates :func:`repro.core.simulator.slot_step`'s
own expressions with the stage axis folded into the type axis, so the
single-stage equivalence with ``simulate`` stays bitwise — pinned in
tests), completions flow down the chain within the slot (a tandem of
queues), and the intermediate bytes
each hop ships across the WAN are billed through
:func:`repro.placement.wan.plan_cost` — the fused bilinear form of
``transfer_cost(transfer_plan(...))``, same surplus/deficit coupling
semantics (a stage whose destination mix equals its source mix — a
data-local map, a co-located reduce — moves nothing) but no (K, N, N)
plan is ever materialized in the scan body.

The per-slot semantics, stage by stage (s = 0..S-1, a static unrolled
loop):

    in^{k,s}   = f^{k,s} * F^{k,s}          F^{k,0} = A^k(t), else the
                                            upstream completions
    Q^{k,s}    + Eq. 1 under (in, mu / c^{k,s})
    done^{k,s} = min(Q + in, mu/c)          flows to stage s+1 (or out)
    WAN bill   = plan_cost(src^{k,s}, f^{k,s}, F^{k,s} * G^{k,s})
                 (== transfer_cost(transfer_plan(...)) to ≤ 1e-5 rel.)

With a single-stage dag (compute 1, shuffle 0) every extra term is an
exact float identity and ``simulate_staged`` reproduces ``simulate``'s
cost/backlog/dispatch bit for bit on every policy — the test suite pins
this down. ``r`` and ``data_dist`` may carry a leading time axis exactly
as in ``simulate``, which is how the subsystem composes with
:func:`repro.placement.controller.simulate_placed`: run the slow loop,
repeat its per-epoch ``placements``/``r_trace`` per slot, and feed them
here — re-placement reshapes the map stage's locality (and the whole
chain's shuffle sources) over the horizon.

The whole run is one ``jax.lax.scan`` (jit); Monte-Carlo replication is a
``jax.vmap`` over PRNG keys (``simulate_staged_many``), sharing one
compilation — the same perf structure as the base simulator.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.simulator import (
    PolicyFn,
    SimInputs,
    _energy_tables,
)
from repro.jobs.dag import StageDag
from repro.jobs.scheduler import stage_oblivious, stage_service_rates_all
from repro.placement.wan import WanModel, degraded_surcharge, plan_cost
from repro.telemetry.config import TelemetryConfig
from repro.telemetry.config import enabled as _tel_enabled
from repro.telemetry.config import histograms as _tel_hist
from repro.telemetry.metrics import hist_series
from repro.telemetry.ring import TelemetryFrame, ring_init

#: Zero-flow guard for the source-mix normalization — the same epsilon
#: :func:`repro.jobs.scheduler.flow_step` uses, so the engine's replayed
#: mixes equal the policy lookahead's exactly.
_EPS = 1e-12


def hedged_mu(f: Array, g: Array, mu_stages: Array) -> Array:
    """First-completion service rates under the clone matrix ``g``.

    The boost the policy's flow walk applied, re-derived from the same
    inputs — ``mu + f · (Σ_n g·mu)`` — so the engine's Eq. 1 drains
    exactly the flow the scheduler exported. Gated behind ``lax.cond``:
    slots where no hedge fired keep the unboosted rates bit-for-bit
    (and pay the branch, not the FMA, in the scan body).
    """

    def boosted(ms):
        boost = jnp.sum(g * ms, axis=0)                        # (K, S)
        return ms + f * boost[None]

    return jax.lax.cond(
        jnp.any(g > 0.0), boosted, lambda ms: ms, mu_stages
    )


def staged_slot_update(
    dag: StageDag,
    q: Array,
    ret,
    arrivals: Array,
    mu_stages: Array,
    returns_flow: bool,
    returns_hedge: bool = False,
) -> tuple[Array, Array, Array, Array]:
    """One slot of the staged engine: tandem flow + Eq. 1 for every stage.

    ``ret`` is the policy's output — ``(f, in_stack)`` for ``returns_flow``
    policies (the stage-aware scheduler already walked the within-slot flow
    via :func:`repro.jobs.scheduler.flow_step`), bare ``f`` otherwise (the
    recursion is replayed here). ``returns_hedge`` policies append the
    (N, K, S) speculative-clone matrix ``g`` — the queues then drain at
    the first-completion boosted rates (:func:`hedged_mu`), cond-gated so
    hedge-free slots stay bit-identical. This is the SINGLE definition of
    the per-slot staged update: :func:`simulate_staged`'s scan body calls
    it, and :class:`repro.serve.engine.FleetEngine`'s serving loop calls
    it on live traffic — which is what makes a dispatch-only serving run
    replay bit-for-bit against the simulator on a shared scenario.

    Returns:
        (q_next, f, acc, in_stack): the advanced (N, K, S) queues, the
        dispatch decision, the landed mass ``q + f·in`` (the inside of
        Eq. 1's max) and the (K, S) per-stage inflows.
    """
    s_max = dag.s_max
    if returns_hedge:
        f, in_stack, g = ret
        acc = q + f * in_stack[None, :, :]                         # (N, K, S)
        mu_stages = hedged_mu(f, g, mu_stages)
    elif returns_flow:
        f, in_stack = ret
        acc = q + f * in_stack[None, :, :]                         # (N, K, S)
    else:
        f = ret
        total_in = arrivals                                        # (K,)
        ins, accs = [], []
        for s in range(s_max):
            ins.append(total_in)
            acc_s = q[:, :, s] + f[:, :, s] * total_in[None, :]
            accs.append(acc_s)
            if s + 1 < s_max:
                done_s = jnp.minimum(acc_s, mu_stages[:, :, s])
                total_in = (jnp.sum(done_s, axis=0)
                            * dag.stage_mask[:, s + 1])
        acc = jnp.stack(accs, axis=-1)                             # (N, K, S)
        in_stack = jnp.stack(ins, axis=-1)                         # (K, S)

    # Eq. 1 for ALL stages at once, the stage axis folded into the
    # type axis (one queue per (DC, type·stage)). The expression is
    # ``slot_step``'s own — ``max((q + fa) - mu, 0)`` — and for S = 1
    # every reshape is the identity, keeping the single-stage path
    # bitwise the base engine's.
    q_next = jnp.maximum(acc - mu_stages, 0.0)
    return q_next, f, acc, in_stack


def staged_shuffle_mixes(
    f_trace: Array,
    in_all: Array,
    done_all: Array,
    dd_all: Array,
    dag: StageDag,
) -> tuple[Array, Array, Array]:
    """Source/destination mixes + volumes for every (slot, stage) shuffle.

    Vectorized over the whole horizon from the stacked per-slot outputs:
    stage 0 pulls from ``data_dist``; stage s > 0 pulls from where stage
    s-1's completions actually ran (uniform fallback for zero flow — the
    volume is zero there, so the choice is billing-inert, the same
    ``flow_step`` contract the policy lookahead uses).

    Returns:
        (src, dst, vol): (T, S, K, N), (T, S, K, N), (T, S, K).
    """
    t_slots, n = f_trace.shape[0], f_trace.shape[1]
    s_max = dag.s_max
    td_all = jnp.sum(done_all, axis=1)                             # (T,K,S)
    if s_max == 1:
        src_all = dd_all[:, None]                                  # (T,1,K,N)
    else:
        done_up = done_all[:, :, :, :-1].transpose(0, 3, 2, 1)     # (T,S-1,K,N)
        td_up = td_all[:, :, :-1].transpose(0, 2, 1)[..., None]    # (T,S-1,K,1)
        src_up = jnp.where(
            td_up > _EPS, done_up / jnp.maximum(td_up, _EPS), 1.0 / n
        )                                                          # (T,S-1,K,N)
        src_all = jnp.concatenate([dd_all[:, None], src_up], axis=1)
    dst_all = f_trace.transpose(0, 3, 2, 1)                        # (T,S,K,N)
    vol_all = (in_all * dag.shuffle_gb[None]).transpose(0, 2, 1)   # (T,S,K)
    return src_all, dst_all, vol_all


def _hedge_bill(
    dag: StageDag,
    wan: WanModel,
    g_all: Array,
    acc_all: Array,
    mu_stage_all: Array,
    mu_eff_all: Array,
    ec_stage_all: Array,
    src_all: Array,
    wpue_all: Array,
) -> tuple[Array, Array, Array]:
    """Honest post-scan bill for speculative re-execution.

    In the fluid first-completion model the clone's contribution is the
    boost-attributable completions — ``min(acc, mu_eff) - min(acc, mu)``
    — re-executed at the clone site. Each re-executed job-unit bills the
    clone site's per-stage energy cost (compute) plus the expected WAN
    pull of the stage's input shuffle from the upstream source mix to
    the clone site (the same fused rank-2 expected-pull form the
    scheduler prices dispatch with). All (T,)-vectorized, nothing in the
    scan body.

    Returns:
        (hedge_cost, hedge_gb, hedged_jobs) — (T,) each: total $ billed
        (compute + WAN pull), GB pulled to clone sites, and re-executed
        job-units completed by clones.
    """
    extra = (jnp.minimum(acc_all, mu_eff_all)
             - jnp.minimum(acc_all, mu_stage_all))             # (T,N,K,S)
    extra_ks = jnp.sum(extra, axis=1)                          # (T,K,S)
    ec_clone = jnp.einsum("tnks,tksn->tks", g_all, ec_stage_all)
    compute_bill = jnp.sum(extra_ks * ec_clone, axis=(1, 2))   # (T,)
    g_skn = g_all.transpose(0, 3, 2, 1)                        # (T,S,K,N)
    w = wpue_all[:, None, None, :]                             # (T,1,1,N)
    dot = jnp.sum(src_all * w, axis=-1)                        # (T,S,K)
    pull = 0.5 * (dot[..., None] + w) - src_all * w            # (T,S,K,N)
    price_clone = jnp.sum(pull * g_skn, axis=-1)               # (T,S,K)
    vol = extra_ks.transpose(0, 2, 1) * dag.shuffle_gb.T[None]  # (T,S,K)
    wan_bill = wan.energy_per_gb * jnp.sum(price_clone * vol, axis=(1, 2))
    hedge_gb = jnp.sum(vol, axis=(1, 2))
    hedged_jobs = jnp.sum(extra_ks, axis=(1, 2))
    return compute_bill + wan_bill, hedge_gb, hedged_jobs


class StagedOutputs(NamedTuple):
    """Per-slot traces of one staged run (leading runs axis under vmap).

    The three hedge columns are all-zero for policies without the
    ``returns_hedge`` contract (and on healthy fleets where the hedge
    never fires), so downstream consumers need no feature detection.
    """

    cost: Array           # (T,) per-slot stage-compute energy cost
    energy: Array         # (T,) PUE-weighted compute energy (unpriced)
    backlog_total: Array  # (T,) sum over all (DC, type, stage) backlogs
    backlog_avg: Array    # (T,) mean backlog per (DC, type, stage)
    q_final: Array        # (N, K, S)
    f_trace: Array        # (T, N, K, S) per-stage dispatch decisions
    wan_cost: Array       # (T,) $ billed for intermediate-data movement
    wan_energy: Array     # (T,) WAN energy (job-energy equivalents)
    wan_gb: Array         # (T,) intermediate GB crossing the WAN
    completed: Array      # (T, K) jobs finishing their last stage per slot
    hedge_cost: Array     # (T,) $ billed for speculative re-execution
    hedge_gb: Array       # (T,) GB pulled to clone sites by hedges
    hedged_jobs: Array    # (T,) job-units completed by speculative clones


@functools.partial(jax.jit, static_argnames=("policy", "telemetry"))
def simulate_staged(
    inputs: SimInputs,
    dag: StageDag,
    wan: WanModel,
    policy: PolicyFn,
    key: Array,
    scalar: float | Array = 0.0,
    telemetry: TelemetryConfig | None = None,
    health: Array | None = None,
    link_health: Array | None = None,
) -> StagedOutputs | tuple[StagedOutputs, TelemetryFrame]:
    """Run one stage-structured trace-driven simulation under ``policy``.

    Args:
        inputs: the usual trace bundle; ``r``/``data_dist`` may be static
            or time-varying exactly as in ``simulate``.
        dag: the (K, S) stage chain.
        wan: WAN model pricing the inter-stage shuffle bytes.
        policy: a staged policy (attribute ``staged = True``, signature
            ``(key, q(N,K,S), arrivals, mu, e, (data_dist, wpue), scalar)
            -> f(N,K,S)``) or any base simulator policy, which is wrapped
            by :func:`repro.jobs.scheduler.stage_oblivious` automatically.
        key: PRNG key (consumed exactly as ``simulate`` does, on both the
            precomputed and the carried-key policy paths).
        scalar: traced control parameter forwarded to the policy (GMSA's V).
        telemetry: **static** flight-recorder config. ``None``/``OFF``
            (default) keeps the jaxpr byte-identical to the pre-telemetry
            engine. Enabled levels return ``(outputs, TelemetryFrame)``
            whose metrics are per-(slot, stage) streams — backlog and the
            WAN bill split by stage. Everything is derived post-scan from
            the stacked ``(f, acc, ins)`` outputs the fast path already
            produces (the PR-4 structure), so TRACE adds ZERO ops to the
            scan body here; the per-stage billing runs ``plan_cost``
            batched once more over ``(T, S)`` without the type-axis fold.
        health: optional (T, N) degraded-mode factor
            (:func:`repro.traces.faults.health_trace`): per-slot service
            rates scale as ``mu * health``, hoisted into the trace
            bundle before any table is derived — zero extra ops in the
            scan body, and an all-ones trace is an exact ``* 1.0``
            identity (``None`` leaves the jaxpr untouched).
        link_health: optional (T, N, N) link factor
            (:func:`repro.traces.bandwidth.link_fault_trace`): the WAN
            bill gains the post-scan
            :func:`repro.placement.wan.degraded_surcharge` premium —
            degraded links cost more, severed links carrying traffic
            bill ``inf`` — added on top of the untouched fused bill (an
            exact ``+ 0.0`` identity on an all-nominal trace).
    """
    tel_on = _tel_enabled(telemetry)
    if health is not None:
        inputs = inputs._replace(
            mu=inputs.mu * jnp.asarray(health, inputs.mu.dtype)[:, :, None]
        )
    t_slots, k_types = inputs.arrivals.shape
    n = inputs.mu.shape[1]
    s_max = dag.s_max
    if dag.compute.shape[0] != k_types:
        raise ValueError(
            f"dag is for K={dag.compute.shape[0]} types, inputs carry "
            f"K={k_types}"
        )
    q0 = jnp.zeros((n, k_types, s_max), jnp.float32)
    e_cost_all, e_raw_all = _energy_tables(inputs)                 # (T, K, N)
    wpue_all = inputs.omega * inputs.pue                           # (T, N)
    scalar = jnp.asarray(scalar, jnp.float32)

    # Perf (EXPERIMENTS.md §Perf): everything per-slot-invariant is hoisted
    # out of the scan body — per-stage service rates (mu / c), the per-stage
    # energy tables (e * c, already laid out (K, S, N) so the in-body
    # flatten to (K·S, N) is a free reshape) and omega*PUE are computed for
    # all T slots in one pass each. Stage padding uses exact identities
    # (c = 1.0), so the single-stage tables are bitwise the base engine's.
    mu_stage_all = stage_service_rates_all(inputs.mu, dag)         # (T,N,K,S)
    ec_stage_all = e_cost_all[:, :, None, :] * dag.compute[None, :, :, None]
    er_stage_all = e_raw_all[:, :, None, :] * dag.compute[None, :, :, None]

    pol = policy if getattr(policy, "staged", False) else stage_oblivious(policy)
    uses_key = getattr(pol, "consumes_key", True)
    returns_flow = getattr(pol, "returns_flow", False)
    returns_hedge = getattr(pol, "returns_hedge", False)
    dd_varying = inputs.data_dist.ndim == 3                        # (T, K, N)
    r_varying = inputs.r.ndim == 4                              # (T, K, N, N)
    wants_r = getattr(pol, "wants_r", False)
    if r_varying and getattr(pol, "static_r", False):
        raise ValueError(
            "policy binds a static (K, N, N) ratio tensor but inputs.r is "
            "time-varying (T, K, N, N) — the kernel would silently dispatch "
            "on stale ratios. Build it with make_kernel_policy(r=None) so "
            "the per-slot r reaches the kernel through the policy aux."
        )

    if returns_flow and getattr(pol, "state_independent", False):
        raise ValueError(
            "returns_flow policies are state-dependent by construction "
            "(the exported inflows depend on the live backlog); do not "
            "also mark them state_independent"
        )

    f_all = None
    if getattr(pol, "state_independent", False):
        keys = jax.random.split(key, t_slots)

        def call(kk, a, m, e, d, w, rr):
            aux = (d, w)
            if wants_r:
                aux = aux + (rr,)
            return pol(kk, q0, a, m, e, aux, scalar)

        f_all = jax.vmap(
            call,
            in_axes=(0, 0, 0, 0, 0 if dd_varying else None, 0,
                     0 if r_varying else None),
        )(keys, inputs.arrivals, inputs.mu, e_cost_all,
          inputs.data_dist, wpue_all, inputs.r if wants_r else None)

    keyed = f_all is None and uses_key
    key0 = key   # for key-ignoring policies (signature filler, never used)

    def slot(carry, xs):
        q, key = carry if keyed else (carry, None)
        if wants_r and r_varying:
            xs, r_t = xs[:-1], xs[-1]
        if dd_varying:
            xs, dd_t = xs[:-1], xs[-1]
        else:
            dd_t = inputs.data_dist
        arrivals, mu, e_cost, mu_stages, wpue_t = xs[:5]
        rest = xs[5:]
        if f_all is None:
            if keyed:
                key, sub = jax.random.split(key)
            else:
                sub = key0   # key-ignoring policy: no per-slot split
            aux = (dd_t, wpue_t)
            if wants_r:
                aux = aux + ((r_t if r_varying else inputs.r),)
            ret = pol(sub, q, arrivals, mu, e_cost, aux, scalar)
        else:
            (ret,) = rest

        # Within-slot tandem flow — the only genuinely sequential part,
        # stripped to its recursion via the shared :func:`staged_slot_update`
        # (acc = Q + f·F is the inside of Eq. 1's max — exactly
        # ``slot_step``'s ``q + fa``; completions min(acc, mu) seed the next
        # stage). Policies that walked this exact chain already
        # (``returns_flow = True`` — the stage-aware scheduler's lookahead
        # shares flow_step's definition) export the per-stage inflows and
        # the recursion is skipped entirely. Everything derivable from
        # (f, acc, ins) — cost/energy accrual, backlogs, source mixes,
        # shuffle volumes, completions, the WAN bill — is recomputed
        # vectorized over all T slots AFTER the scan, keeping the per-slot
        # body minimal.
        q_next, f, acc, in_stack = staged_slot_update(
            dag, q, ret, arrivals, mu_stages, returns_flow, returns_hedge
        )

        out = (f, acc, in_stack)
        if returns_hedge:
            out = out + (ret[2],)
        return ((q_next, key) if keyed else q_next), out

    xs = (inputs.arrivals, inputs.mu, e_cost_all, mu_stage_all, wpue_all)
    if f_all is not None:
        xs = xs + (f_all,)
    if dd_varying:
        xs = xs + (inputs.data_dist,)
    if wants_r and r_varying:
        xs = xs + (inputs.r,)
    carry0 = (q0, key) if keyed else q0
    final_carry, scan_outs = jax.lax.scan(slot, carry0, xs)
    if returns_hedge:
        f_trace, acc_all, in_all, g_all = scan_outs
    else:
        f_trace, acc_all, in_all = scan_outs
        g_all = None
    q_final = final_carry[0] if keyed else final_carry

    # Everything the scan body did NOT compute, recovered vectorized over
    # all T slots from (f, acc, ins) — the expressions are ``slot_step``'s
    # and ``flow_step``'s own, evaluated batched so each is one kernel for
    # the whole horizon instead of T per-slot launches:
    #   * cost/energy: sum(fa * e.T) with fa = f * in;
    #   * backlogs: q_next = max(acc - mu, 0) summed/averaged;
    #   * completions per stage: min(acc, mu) summed over sites;
    #   * source mixes + shuffle volumes + the WAN bill — billed for ALL
    #     (slot, stage) pairs in ONE fused batched plan_cost call, stages
    #     folded into the type axis; no (K, N, N) plan is materialized.
    fa_all = f_trace * in_all[:, None]                             # (T,N,K,S)
    cost = jnp.sum(fa_all * ec_stage_all.transpose(0, 3, 1, 2),
                   axis=(1, 2, 3))                                 # (T,)
    energy = jnp.sum(fa_all * er_stage_all.transpose(0, 3, 1, 2),
                     axis=(1, 2, 3))
    if returns_hedge:
        # The carried queues drained at the first-completion boosted
        # rates (cond-gated in the scan body); the vectorized replay
        # applies the same boost unconditionally — slots without a
        # hedge add an exact ``f * 0.0`` identity, so the stats stay
        # bitwise the scan's.
        boost_all = jnp.sum(g_all * mu_stage_all, axis=1)          # (T,K,S)
        mu_eff_all = mu_stage_all + f_trace * boost_all[:, None]
    else:
        mu_eff_all = mu_stage_all
    q_next_all = jnp.maximum(acc_all - mu_eff_all, 0.0)            # (T,N,K,S)
    btot = jnp.sum(q_next_all, axis=(1, 2, 3))
    bavg = btot / jnp.float32(n * k_types * s_max)
    done_all = jnp.minimum(acc_all, mu_eff_all)                    # (T,N,K,S)
    td_all = jnp.sum(done_all, axis=1)                             # (T,K,S)
    nxt = jnp.concatenate(
        [dag.stage_mask[:, 1:], jnp.zeros((k_types, 1), jnp.float32)], axis=1
    )
    completed = jnp.einsum("tks,ks->tk", td_all, dag.stage_mask - nxt)

    dd_all = (
        inputs.data_dist
        if dd_varying
        else jnp.broadcast_to(inputs.data_dist, (t_slots, k_types, n))
    )                                                              # (T, K, N)
    src_all, dst_all, vol_all = staged_shuffle_mixes(
        f_trace, in_all, done_all, dd_all, dag
    )
    wan_c, wan_e, wan_gb = plan_cost(
        src_all.reshape(t_slots, s_max * k_types, n),
        dst_all.reshape(t_slots, s_max * k_types, n),
        vol_all.reshape(t_slots, s_max * k_types),
        wan, inputs.omega, inputs.pue,
    )                                                              # (T,) each
    if link_health is not None:
        # Degraded-link premium on the shuffle traffic, additive to the
        # untouched fused bill (exact zero on an all-nominal trace).
        sur_c, sur_e = degraded_surcharge(
            src_all.reshape(t_slots, s_max * k_types, n),
            dst_all.reshape(t_slots, s_max * k_types, n),
            vol_all.reshape(t_slots, s_max * k_types),
            wan, inputs.omega, inputs.pue, link_health,
        )
        wan_c = wan_c + sur_c
        wan_e = wan_e + sur_e
    if returns_hedge:
        hedge_cost, hedge_gb, hedged_jobs = _hedge_bill(
            dag, wan, g_all, acc_all, mu_stage_all, mu_eff_all,
            ec_stage_all, src_all, wpue_all,
        )
    else:
        zeros_t = jnp.zeros((t_slots,), jnp.float32)
        hedge_cost = hedge_gb = hedged_jobs = zeros_t
    outs = StagedOutputs(
        cost=cost, energy=energy, backlog_total=btot, backlog_avg=bavg,
        q_final=q_final, f_trace=f_trace,
        wan_cost=wan_c, wan_energy=wan_e, wan_gb=wan_gb,
        completed=completed,
        hedge_cost=hedge_cost, hedge_gb=hedge_gb, hedged_jobs=hedged_jobs,
    )
    if tel_on:
        # Per-stage streams, recovered from the same stacked (f, acc, ins)
        # outputs — the per-(slot, stage) WAN split is a SECOND batched
        # plan_cost call over (T, S) (stages as the leading batch dim, the
        # type axis folded as usual), leaving the fused OFF-path bill and
        # its reduction order untouched.
        stage_backlog = jnp.sum(q_next_all, axis=(1, 2))           # (T, S)
        sw_c, _, sw_gb = plan_cost(
            src_all.transpose(1, 0, 2, 3),                         # (S,T,K,N)
            dst_all.transpose(1, 0, 2, 3),
            vol_all.transpose(1, 0, 2),                            # (S,T,K)
            wan, inputs.omega, inputs.pue,
        )                                                          # (S, T)
        metrics = {
            "q_site": jnp.sum(q_next_all, axis=(2, 3)),            # (T, N)
            "stage_backlog": stage_backlog,                        # (T, S)
            "stage_wan_cost": sw_c.T,                              # (T, S)
            "stage_wan_gb": sw_gb.T,                               # (T, S)
        }
        if _tel_hist(telemetry):
            # Per-(slot, stage) queue delay in slots — the stage's total
            # backlog over its fleet-wide service capacity (the fluid
            # analogue of "how long does work admitted now wait here") —
            # histogrammed per stage over the horizon, post-scan.
            cap_stage = jnp.sum(mu_stage_all, axis=(1, 2))         # (T, S)
            delay = stage_backlog / jnp.maximum(cap_stage, _EPS)
            metrics["queue_delay"] = delay                         # (T, S)
            metrics["queue_delay_hist"] = hist_series(
                telemetry.hist, delay, axis=0
            )                                                      # (S, B)
        return outs, TelemetryFrame(ring=ring_init(1), metrics=metrics)
    return outs


@functools.partial(
    jax.jit,
    static_argnames=("policy", "build_inputs", "n_runs", "telemetry", "mesh"),
)
def simulate_staged_many(
    build_inputs: Callable[[Array], SimInputs],
    dag: StageDag,
    wan: WanModel,
    policy: PolicyFn,
    key: Array,
    n_runs: int,
    scalar: float | Array = 0.0,
    telemetry: TelemetryConfig | None = None,
    health: Array | None = None,
    link_health: Array | None = None,
    mesh=None,
) -> StagedOutputs:
    """Monte-Carlo replication of :func:`simulate_staged` (vmap over keys).

    Mirrors ``simulate_many``: fresh stochastic traces + policy randomness
    per run, deterministic traces (prices, PUE, the dag, the WAN model —
    and the degraded-mode health/link traces, when given) shared. One
    compilation serves every run; telemetry frames (when enabled) stack
    on the leading runs axis like every other output.

    ``mesh`` (static) shards the runs axis over a host-device mesh
    (:func:`repro.distributed.mesh.runs_mesh`) — same split keys, bitwise
    the single-device outputs at every device count.
    """
    keys = jax.random.split(key, n_runs)

    def one(run_key):
        k_build, k_sim = jax.random.split(run_key)
        return simulate_staged(
            build_inputs(k_build), dag, wan, policy, k_sim, scalar,
            telemetry, health, link_health,
        )

    if mesh is None:
        return jax.vmap(one)(keys)
    from repro.distributed.mesh import sharded_runs

    return sharded_runs(one, keys, mesh)


def summarize_staged(outs: StagedOutputs) -> dict:
    """Time-averaged scalars incl. the shuffle WAN bill (any runs axis).

    The total includes the speculative re-execution bill (zero for
    hedge-free runs, so pre-hedging totals are unchanged).
    """
    compute = jnp.mean(outs.cost)
    wan = jnp.mean(outs.wan_cost)
    hedge = jnp.mean(outs.hedge_cost)
    return {
        "time_avg_compute_cost": float(compute),
        "time_avg_wan_cost": float(wan),
        "time_avg_hedge_cost": float(hedge),
        "time_avg_total_cost": float(compute + wan + hedge),
        "time_avg_energy": float(jnp.mean(outs.energy)),
        "time_avg_backlog": float(jnp.mean(outs.backlog_avg)),
        "total_wan_gb": float(jnp.mean(jnp.sum(outs.wan_gb, axis=-1))),
        "jobs_completed": float(jnp.mean(jnp.sum(outs.completed, axis=(-2, -1)))),
        "hedged_jobs": float(jnp.mean(jnp.sum(outs.hedged_jobs, axis=-1))),
        "final_backlog_total": float(
            jnp.mean(outs.q_final.sum(axis=(-3, -2, -1)))
        ),
    }
