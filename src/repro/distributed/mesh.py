"""Host-device mesh for sharding the Monte-Carlo ``runs`` axis.

Every ``*_many`` / ``sweep_*`` entry point replicates one simulation over a
(n_runs,) axis of PRNG keys. This module maps that axis across devices with
``shard_map`` (via the 0.4.x/0.5.x shim in :mod:`repro.distributed.compat`):

* :func:`ensure_host_devices` — the ``XLA_FLAGS`` bootstrap idiom
  (``--xla_force_host_platform_device_count=8``): one process, eight CPU
  "pod" devices, CI-reproducible. Must run **before** jax initializes its
  backends; it raises loudly when called too late instead of letting the
  flag be ignored silently.
* :func:`runs_mesh` — a 1-D ``Mesh`` over host devices with axis ``"runs"``.
* :func:`sharded_runs` — ``vmap(one)(keys)`` partitioned over that mesh.

Determinism contract: the (n_runs,) key array is computed exactly as in the
single-device path (one ``jax.random.split`` at the entry point) and then
merely *laid out* across devices — no per-device folding enters the key
stream, and each run's trace build + simulation is elementwise in the runs
axis. Sharded outputs are therefore bitwise-identical to the single-device
vmap at every device count (pinned by ``tests/test_sharded.py``).

Non-divisible ``n_runs`` pads the key axis by repeating the leading keys up
to a device multiple and slices the padding back off, so downstream
summaries see exactly the real runs — never a truncation, never a crash.
"""

from __future__ import annotations

import os
import re
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax import Array
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map

__all__ = [
    "RUNS_AXIS",
    "ensure_host_devices",
    "host_platform_flag",
    "runs_mesh",
    "sharded_runs",
]

#: Mesh axis name carrying the Monte-Carlo runs dimension.
RUNS_AXIS = "runs"

_FLAG = "--xla_force_host_platform_device_count"


def host_platform_flag(n_devices: int) -> str:
    """The XLA flag splitting the host CPU into ``n_devices`` devices."""
    return f"{_FLAG}={int(n_devices)}"


def _backends_initialized() -> bool:
    """Whether jax has already materialized its backends (flag too late)."""
    try:
        from jax._src import xla_bridge

        if hasattr(xla_bridge, "backends_are_initialized"):
            return bool(xla_bridge.backends_are_initialized())
        return bool(getattr(xla_bridge, "_backends", {}))
    except Exception:  # pragma: no cover - private-API drift
        return True  # can't tell: assume live, forcing the loud path


def ensure_host_devices(n_devices: int) -> int:
    """Request ``n_devices`` host CPU devices; must run before backend init.

    Installs ``--xla_force_host_platform_device_count=n`` into ``XLA_FLAGS``
    (replacing any previous count). XLA reads the flag once, at backend
    initialization — the first ``jax.devices()`` / jit dispatch — so this
    only works at process entry, before anything touches a device. Called
    too late it raises ``RuntimeError`` (unless the process already has
    enough devices, which is a no-op) rather than silently running on
    however many devices happened to exist.

    Returns the device count that will be (or already is) available.
    """
    n_devices = int(n_devices)
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if _backends_initialized():
        have = jax.device_count()
        if have >= n_devices:
            return have
        raise RuntimeError(
            f"jax backends already initialized with {have} device(s); "
            f"set XLA_FLAGS={host_platform_flag(n_devices)} (or call "
            "ensure_host_devices) before the first jax.devices()/jit "
            "dispatch — e.g. at process entry, before importing modules "
            "that touch jax device state."
        )
    flags = os.environ.get("XLA_FLAGS", "")
    stripped = re.sub(rf"{_FLAG}=\d+", "", flags).strip()
    sep = " " if stripped else ""
    os.environ["XLA_FLAGS"] = f"{stripped}{sep}{host_platform_flag(n_devices)}"
    return n_devices


def runs_mesh(n_devices: int | None = None) -> Mesh:
    """A 1-D mesh over host devices with Monte-Carlo axis ``"runs"``.

    ``n_devices=None`` takes every available device; an explicit count
    takes the first ``n_devices`` (raising if the process has fewer —
    see :func:`ensure_host_devices` for getting more on CPU).
    """
    devices = jax.devices()
    if n_devices is not None:
        n_devices = int(n_devices)
        if n_devices < 1 or n_devices > len(devices):
            raise ValueError(
                f"runs_mesh: asked for {n_devices} device(s) but the "
                f"process has {len(devices)} (hint: ensure_host_devices "
                "before jax initializes, or pass n_devices=None)"
            )
        devices = devices[:n_devices]
    return Mesh(np.asarray(devices), (RUNS_AXIS,))


def sharded_runs(
    one: Callable[[Array], Any], keys: Array, mesh: Mesh
) -> Any:
    """``vmap(one)(keys)`` with the runs axis partitioned over ``mesh``.

    ``keys`` is the (n_runs,) PRNG key array the single-device path would
    vmap over — identical keys, so identical per-run streams. When
    ``n_runs`` is not a device multiple the key axis is padded by
    repeating the leading keys and the surplus rows are sliced off the
    stacked outputs, so every summary downstream weights exactly the real
    run count. Output pytrees keep the leading (n_runs,) axis.
    """
    if RUNS_AXIS not in mesh.shape:
        raise ValueError(
            f"sharded_runs needs a mesh with axis {RUNS_AXIS!r}; got axes "
            f"{tuple(mesh.axis_names)} (build one with runs_mesh())"
        )
    n_runs = keys.shape[0]
    n_dev = mesh.shape[RUNS_AXIS]
    pad = (-n_runs) % n_dev
    if pad:
        keys = jnp.concatenate([keys, keys[:pad]], axis=0)
    body = shard_map(
        lambda ks: jax.vmap(one)(ks),
        mesh=mesh,
        in_specs=P(RUNS_AXIS),
        out_specs=P(RUNS_AXIS),
        check_vma=False,
    )
    outs = body(keys)
    if pad:
        outs = jax.tree_util.tree_map(lambda x: x[:n_runs], outs)
    return outs
