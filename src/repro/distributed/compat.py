"""Version-compat shims for the shard_map / mesh-context API surface.

The repo targets the ``jax.shard_map`` spelling (jax >= 0.5, where shard_map
is a public top-level API with ``axis_names`` / ``check_vma``); the pinned CI
image ships jax 0.4.x where the same machinery lives in
``jax.experimental.shard_map`` with a ``check_rep`` knob and a mandatory
concrete mesh. These wrappers present the new surface on both.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "get_abstract_mesh"]


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        kwargs = {}
        if mesh is not None:
            kwargs["mesh"] = mesh
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs, **kwargs)

else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        del axis_names  # implied by the specs on the old API
        if mesh is None:
            # jax>=0.5 resolves a missing mesh from the ambient context; the
            # old API wants it explicit, so resolve it the same way here.
            mesh = _ambient_physical_mesh()
            if mesh is None or mesh.empty:
                raise ValueError(
                    "jax<0.5 shard_map requires a concrete mesh (pass mesh= "
                    "or call under `with mesh:`)"
                )
        kwargs = {}
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        return _shard_map_old(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )


class _EmptyMesh:
    """Stand-in for "no ambient mesh" with the AbstractMesh query surface."""

    empty = True
    axis_names = ()
    shape = {}


_EMPTY_MESH = _EmptyMesh()


def _ambient_physical_mesh():
    """jax 0.4.x: the concrete mesh installed by old-style ``with mesh:``."""
    from jax._src import mesh as _mesh_lib

    env = getattr(getattr(_mesh_lib, "thread_resources", None), "env", None)
    return getattr(env, "physical_mesh", None)


def get_abstract_mesh():
    """The ambient (abstract) mesh, or an empty mesh when none is set."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as _mesh_lib

    mesh = _mesh_lib.get_abstract_mesh()
    if hasattr(mesh, "empty"):
        return mesh
    # jax 0.4.x initializes the abstract-mesh thread-local to a raw tuple;
    # an old-style ``with mesh:`` context registers the concrete mesh in
    # thread_resources instead — a Mesh answers the same .empty/.axis_names/
    # .shape queries, so it serves as the ambient mesh here.
    physical = _ambient_physical_mesh()
    if physical is not None and not physical.empty:
        return physical
    return _EMPTY_MESH
