"""int8 compressed cross-pod gradient all-reduce (with error feedback).

The multi-pod mesh has two communication tiers: fast intra-pod ICI (the
"data"/"model" axes) and the slow inter-pod WAN/DCN link (the "pod" axis) —
exactly the paper's heterogeneous "core network". Gradient sync therefore
splits:

  * within-pod reduction: native fp32 (XLA's all-reduce over "data");
  * cross-pod reduction: int8 quantized reduce-scatter + all-gather
    implemented here, cutting pod-link bytes ~4x.

Scheme (standard 1-bit-Adam-family construction, 8-bit variant):

  1. per-leaf flatten, pad to a multiple of n_pods, view as (n_pods, chunk);
  2. per-chunk absmax scale -> int8 quantize;
  3. ``all_to_all`` over "pod" (the reduce-scatter data exchange: each pod
     receives every pod's copy of *its* chunk);
  4. dequantize + sum in fp32 (each pod owns the exact sum of its chunk);
  5. requantize the summed chunk, ``all_gather`` over "pod", dequantize.

Error feedback: the quantization residual of step 2 is returned so the
training loop can carry it into the next step's gradients (the standard EF
trick that restores convergence under biased compression).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array


def _quantize(x: Array) -> tuple[Array, Array]:
    """Symmetric int8 quantization per leading-axis row."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_pod(
    leaf: Array, n_pods: int, axis_name: str = "pod"
) -> tuple[Array, Array]:
    """Compressed sum over the pod axis for one (per-device local) leaf.

    Must run inside ``shard_map`` manual over ``axis_name``. Returns
    (summed fp32 leaf, error-feedback residual with the leaf's shape/dtype).
    """
    shape, dtype = leaf.shape, leaf.dtype
    flat = leaf.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % n_pods
    flat = jnp.pad(flat, (0, pad))
    rows = flat.reshape(n_pods, -1)                         # (pods, chunk)

    q, scale = _quantize(rows)
    residual = (rows - _dequantize(q, scale)).reshape(-1)[: flat.size - pad]

    # Reduce-scatter data exchange: row p goes to pod p.
    q_recv = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                                tiled=False)
    s_recv = jax.lax.all_to_all(scale, axis_name, split_axis=0, concat_axis=0,
                                tiled=False)
    # (pods, chunk) received copies -> owned chunk sum in fp32.
    owned = jnp.sum(_dequantize(q_recv, s_recv), axis=0, keepdims=True)  # (1, chunk)

    q2, s2 = _quantize(owned)                               # (1, chunk), (1, 1)
    q_all = jax.lax.all_gather(q2, axis_name, axis=0, tiled=True)   # (pods, chunk)
    s_all = jax.lax.all_gather(s2, axis_name, axis=0, tiled=True)   # (pods, 1)
    total = _dequantize(q_all, s_all).reshape(-1)[: flat.size - pad]

    return total.reshape(shape).astype(dtype), residual.reshape(shape).astype(dtype)


def sync_tree(grads, n_pods: int, axis_name: str = "pod", error_fb=None):
    """Tree-wise compressed pod-axis mean. Runs INSIDE a shard_map that is
    manual over ``axis_name`` (the train step owns that shard_map).

    Args:
        grads: per-pod partial gradient tree.
        n_pods: pod-axis size.
        error_fb: optional residual tree from the previous step (error
            feedback is added before quantization).

    Returns:
        (grads averaged over pods, new error-feedback residual tree).
    """
    if error_fb is not None:
        grads = jax.tree.map(lambda g, e: g + e.astype(g.dtype), grads, error_fb)
    leaves, treedef = jax.tree.flatten(grads)
    outs = [compressed_psum_pod(leaf, n_pods, axis_name) for leaf in leaves]
    synced = treedef.unflatten([t / n_pods for t, _ in outs])
    resid = treedef.unflatten([r for _, r in outs])
    return synced, resid
