"""Logical-axis sharding rules with semantic divisibility fallback.

Every parameter name from ``repro.models.lm.layer_param_specs`` /
``top_param_specs`` maps to a tuple of *logical axes*; logical axes resolve
to mesh axes through ``RULES``; and each (logical axis, config) pair has a
semantic divisibility condition (e.g. ``q_out`` shards by *head count*, not
by the flat fused dim). Failing the condition falls back to replication and
is reported, never fatal — e.g. qwen2's 14 heads on a 16-way model axis.
"""

from __future__ import annotations

import math
from typing import Any

from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.lm import layer_param_specs, padded_vocab, top_param_specs

#: logical axis -> mesh axes (None = always replicated)
RULES: dict[str, tuple[str, ...] | None] = {
    "embed": None,            # d_model activations/params replicated on model
    "layers": None,           # stacked-layer axis (scanned over)
    "vocab": ("model",),
    "q_out": ("model",),      # attention heads × head_dim (shard by heads)
    "kv_out": ("model",),     # kv heads × head_dim
    "mlp": ("model",),        # FFN hidden
    "experts": None,          # TP-in-expert design: E replicated (DESIGN §5)
    "ssm_inner": ("model",),  # d_inner, shard by SSD heads
    "ssm_heads": ("model",),
    "ssm_state": None,        # B/C projections shared across heads
    "conv_w": None,
    "stub": None,
    "batch": ("pod", "data"),
    "seq": None,
}


def axis_size(mesh: Mesh, names: tuple[str, ...] | None) -> int:
    if not names:
        return 1
    return math.prod(mesh.shape[n] for n in names if n in mesh.shape)


def _shardable(logical: str, cfg: ModelConfig, size: int) -> bool:
    """Semantic divisibility of logical axis ``logical`` by ``size`` devices."""
    if size == 1:
        return True
    checks = {
        "vocab": lambda: padded_vocab(cfg) % size == 0,
        "q_out": lambda: cfg.num_heads % size == 0,
        "kv_out": lambda: cfg.num_kv_heads % size == 0,
        "mlp": lambda: (cfg.moe_d_ff or cfg.d_ff) % size == 0,
        "ssm_inner": lambda: cfg.ssm_heads % size == 0,
        "ssm_heads": lambda: cfg.ssm_heads % size == 0,
    }
    fn = checks.get(logical)
    return True if fn is None else fn()


#: parameter name -> logical axes (excluding the stacked "layers" dim).
_LAYER_LOGICAL: dict[str, tuple[str, ...]] = {
    "ln1": ("embed",), "ln1_bias": ("embed",), "ln2": ("embed",),
    "ln2_bias": ("embed",), "ln_ssm": ("embed",),
    "branch_attn_norm": ("embed",), "branch_ssm_norm": ("embed",),
    "wq": ("embed", "q_out"), "wk": ("embed", "kv_out"), "wv": ("embed", "kv_out"),
    "wo": ("q_out", "embed"),
    "bq": ("q_out",), "bk": ("kv_out",), "bv": ("kv_out",),
    "w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"), "w_down": ("mlp", "embed"),
    "b_up": ("mlp",), "b_down": ("embed",),
    "router": ("embed", "experts"),
    "we_gate": ("experts", "embed", "mlp"),
    "we_up": ("experts", "embed", "mlp"),
    "we_down": ("experts", "mlp", "embed"),
    "ws_gate": ("embed", "mlp"), "ws_up": ("embed", "mlp"), "ws_down": ("mlp", "embed"),
    "w_z": ("embed", "ssm_inner"), "w_x": ("embed", "ssm_inner"),
    "w_b": ("embed", "ssm_state"), "w_c": ("embed", "ssm_state"),
    "w_dt": ("embed", "ssm_heads"),
    "conv_x_w": ("conv_w", "ssm_inner"), "conv_x_b": ("ssm_inner",),
    "conv_b_w": ("conv_w", "ssm_state"), "conv_b_b": ("ssm_state",),
    "conv_c_w": ("conv_w", "ssm_state"), "conv_c_b": ("ssm_state",),
    "a_log": ("ssm_heads",), "d_skip": ("ssm_heads",), "dt_bias": ("ssm_heads",),
    "ssm_norm": ("ssm_inner",), "ssm_out": ("ssm_inner", "embed"),
}

_TOP_LOGICAL: dict[str, tuple[str, ...]] = {
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "final_norm": ("embed",), "final_norm_bias": ("embed",),
    "frontend_proj": ("stub", "embed"), "frontend_norm": ("embed",),
}


def _resolve(
    logical: tuple[str, ...], cfg: ModelConfig, mesh: Mesh, log: dict | None
) -> P:
    parts: list[Any] = []
    for lax in logical:
        mesh_axes = RULES.get(lax)
        if mesh_axes is None:
            parts.append(None)
            continue
        present = tuple(a for a in mesh_axes if a in mesh.shape)
        size = axis_size(mesh, present)
        if present and _shardable(lax, cfg, size):
            parts.append(present if len(present) > 1 else present[0])
        else:
            parts.append(None)
            if log is not None and size > 1:
                log.setdefault("replicated_fallbacks", []).append(lax)
    return P(*parts)


def param_pspecs(cfg: ModelConfig, mesh: Mesh, log: dict | None = None):
    """PartitionSpec pytree exactly matching ``init_params``' structure."""
    specs: dict[str, Any] = {"blocks": {}}
    for name in top_param_specs(cfg):
        specs[name] = _resolve(_TOP_LOGICAL[name], cfg, mesh, log)
    for name in layer_param_specs(cfg):
        inner = _resolve(_LAYER_LOGICAL[name], cfg, mesh, log)
        specs["blocks"][name] = P(None, *inner)   # leading stacked-layer axis
    return specs


def _batch_axes(mesh: Mesh, batch: int) -> Any:
    present = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not present:
        return None
    size = axis_size(mesh, present)
    if batch % size == 0:
        return present if len(present) > 1 else present[0]
    # partial fallback: shard over the largest prefix that divides
    for cut in range(len(present) - 1, 0, -1):
        sub = present[:cut]
        if batch % axis_size(mesh, sub) == 0:
            return sub if len(sub) > 1 else sub[0]
    return None


def batch_pspecs(batch_tree: dict, mesh: Mesh, batch_size: int):
    """Shard every batch leaf on its leading (batch) axis."""
    ax = _batch_axes(mesh, batch_size)

    def leaf_spec(x):
        nd = len(x.shape)
        return P(ax, *([None] * (nd - 1)))

    import jax
    return jax.tree.map(leaf_spec, batch_tree)


def cache_pspecs(
    cache_tree: dict, cfg: ModelConfig, mesh: Mesh, batch_size: int,
    kv_shard: str = "auto",
):
    """Decode-cache sharding: batch over ("pod","data") plus one model-axis
    strategy for the KV cache:

      * "heads" — shard KV heads over "model" when divisible, else replicate;
      * "seq"   — shard the cache SEQUENCE dim over "model": each model
        shard holds S/16 slots and computes partial attention, combined by
        small softmax-stat collectives — flash-decoding (split-KV) mapped
        onto the mesh (EXPERIMENTS.md §Perf A2);
      * "auto"  — "heads" when kv_heads divide the axis, else "seq"
        (production default; 15.9x decode step time on granite-3-2b).
    """
    if kv_shard == "auto":
        kv_shard = resolve_kv_shard(cfg, mesh)
    ax = _batch_axes(mesh, batch_size)
    msize = axis_size(mesh, ("model",))
    kv_ok = cfg.num_kv_heads % msize == 0 if msize > 1 else True
    ssm_ok = cfg.ssm_heads % msize == 0 if (msize > 1 and cfg.has_ssm) else True

    def kv_spec(x):
        # (L, B, S, Hkv, hd)
        if kv_shard == "seq" and msize > 1 and x.shape[2] % msize == 0:
            return P(None, ax, "model", None, None)
        return P(None, ax, None, "model" if kv_ok else None, None)

    def leaf_spec(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(x.shape)
        if name == "pos":
            return P(ax)
        if name in ("k", "v"):
            return kv_spec(x)
        if name == "ssm_state":      # (L, B, H, P, N)
            return P(None, ax, "model" if ssm_ok else None, None, None)
        if name == "conv_state":     # (L, B, W-1, conv_dim)
            return P(None, ax, None, None)
        return P(*([None] * nd))

    import jax
    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def resolve_kv_shard(cfg: ModelConfig, mesh: Mesh) -> str:
    """'heads' when kv heads divide the model axis, else 'seq' (split-KV)."""
    msize = axis_size(mesh, ("model",))
    if msize <= 1 or not cfg.has_attention:
        return "heads"
    return "heads" if cfg.num_kv_heads % msize == 0 else "seq"


def logits_pspec(cfg: ModelConfig, mesh: Mesh, batch_size: int) -> P:
    ax = _batch_axes(mesh, batch_size)
    msize = axis_size(mesh, ("model",))
    vocab_ok = padded_vocab(cfg) % msize == 0 if msize > 1 else True
    return P(ax, None, "model" if vocab_ok else None)
