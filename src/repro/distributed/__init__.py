"""repro.distributed — sharding rules, collectives, gradient compression.

The mesh is ("pod", "data", "model") — multi-pod — or ("data", "model")
single-pod (repro.launch.mesh). Design (DESIGN.md §5):

* params: Megatron-style TP over "model" (col-parallel up-proj / row-parallel
  down-proj, head-sharded attention, head-sharded SSD, TP-in-expert MoE),
  replicated over ("pod", "data");
* batch: sharded over ("pod", "data");
* divisibility policy: a dim shards only if its *semantic unit* (head count,
  expert hidden, vocab pad) divides the axis size, else replicates — recorded
  by `param_pspecs(..., log=...)`;
* gradient sync: within-pod all-reduce is native fp32 (fast ICI); the
  cross-pod leg (the paper's "core network" tier) optionally runs the int8
  compressed all-reduce in repro.distributed.compression.
"""

from repro.distributed.sharding import (
    param_pspecs,
    batch_pspecs,
    cache_pspecs,
    logits_pspec,
    axis_size,
)
from repro.distributed.compression import compressed_psum_pod, sync_tree

__all__ = [
    "param_pspecs",
    "batch_pspecs",
    "cache_pspecs",
    "logits_pspec",
    "axis_size",
    "compressed_psum_pod",
    "sync_tree",
]
