"""Host-side decoding: engine outputs + TelemetryFrame -> JSON-ready records.

One flat record stream per run, newline-delimited when written to disk
(:mod:`repro.telemetry.export`). Record types:

* ``{"type": "meta", ...}`` — engine kind, horizon, level, schema version.
* ``{"type": "event", "t": ..., "code": "recovery" | "epoch" | "switch" |
  "ingest_redirect", ...}`` — the in-scan ring decoded by code schema,
  plus the post-scan *derived* events (GMSA manager-switch edges from
  ``f_trace``); recovery events carry ``time_to_slo`` (slots from the
  death edge until the backlog stream re-enters the SLO band; ``null`` if
  it never does within the horizon).
* ``{"type": "metric", "t": ..., ...}`` — per-slot streams (dispatch /
  compute cost, backlog, per-slot WAN for staged runs, the SUMMARY-level
  extra scan outputs).
* ``{"type": "summary", ...}`` — the engine's ``summarize_*`` dict,
  embedded so the report tool can cross-check the stream standalone.

This module never imports the engines (duck-typing on output fields keeps
``repro.telemetry`` dependency-free and cycle-free); engines import only
:mod:`repro.telemetry.config` / :mod:`repro.telemetry.ring`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.telemetry.config import TelemetryConfig
from repro.telemetry.metrics import percentile_table
from repro.telemetry.ring import (
    CODE_NAMES,
    EV_EPOCH,
    EV_INGEST_REDIRECT,
    EV_RECOVERY,
    EV_REPAIR,
    TelemetryFrame,
    ring_events,
)

SCHEMA_VERSION = 1

#: Per-code payload field names, in ring lane order.
FIELDS_BY_CODE = {
    EV_RECOVERY: ("recovery_gb", "recovery_cost", "n_died", "site"),
    EV_EPOCH: ("wan_gb", "wan_cost", "sync_cost", "churn", "budget_use",
               "epoch"),
    EV_INGEST_REDIRECT: ("redirected_mass", "n_dead"),
    EV_REPAIR: ("n_revived", "site"),
}
_INT_FIELDS = {"n_died", "site", "epoch", "n_dead", "k", "src", "dst",
               "stage", "n_revived"}


def _np(x):
    return np.asarray(x)


def engine_kind(outs) -> str:
    """Duck-typed engine identification from the outputs NamedTuple."""
    if hasattr(outs, "recovery_cost"):
        return "placed"
    if hasattr(outs, "completed"):
        return "staged"
    return "sim"


def switch_events(f_trace: np.ndarray) -> list[dict]:
    """GMSA manager-switch edges derived from the dispatch trace.

    ``f_trace`` is (T, N, K) or (T, N, K, S); a switch fires at slot t for
    type k (stage s) when the argmax site differs from slot t-1's. One-hot
    dispatch makes the argmax the manager choice; fractional policies
    (DATA/RANDOM) report their modal site, which is still the natural
    "where is the mass going" edge.
    """
    f = _np(f_trace)
    staged = f.ndim == 4
    if not staged:
        f = f[..., None]                                    # (T, N, K, 1)
    site = f.argmax(axis=1)                                 # (T, K, S)
    events: list[dict] = []
    prev = site[0]
    for t in range(1, site.shape[0]):
        cur = site[t]
        moved = np.argwhere(cur != prev)
        for k, s in moved:
            ev = {
                "type": "event", "t": int(t), "code": "switch",
                "k": int(k), "src": int(prev[k, s]), "dst": int(cur[k, s]),
            }
            if staged:
                ev["stage"] = int(s)
            events.append(ev)
        prev = cur
    return events


def hedge_events(hedged_jobs, hedge_cost=None) -> list[dict]:
    """Speculation events derived from the per-slot hedge trace.

    The staged/serve engines bill hedging post-scan, so there is no
    in-ring record; one ``hedge`` event per slot where speculative clones
    actually completed work, carrying the re-executed job-units (and the
    $ bill when the cost series is given).
    """
    hj = _np(hedged_jobs)
    hc = _np(hedge_cost) if hedge_cost is not None else None
    events = []
    for t in np.nonzero(hj > 0.0)[0]:
        ev = {"type": "event", "t": int(t), "code": "hedge",
              "hedged_jobs": float(hj[t])}
        if hc is not None:
            ev["hedge_cost"] = float(hc[t])
        events.append(ev)
    return events


def link_down_events(link_health) -> list[dict]:
    """Severed-link edges derived from a (T, N, N) link-health trace.

    One ``link_down`` event per directed off-diagonal link transition:
    ``edge="down"`` the slot the factor first hits zero, ``edge="up"``
    the slot it recovers. Degraded-but-alive links emit nothing — they
    are priced, not partitioned.
    """
    lh = _np(link_health)
    severed = lh <= 0.0
    n = lh.shape[1]
    prev = np.zeros((n, n), bool)
    events = []
    for t in range(lh.shape[0]):
        cur = severed[t]
        for i, j in np.argwhere(cur & ~prev):
            if i != j:
                events.append({"type": "event", "t": int(t),
                               "code": "link_down", "src": int(i),
                               "dst": int(j), "edge": "down"})
        for i, j in np.argwhere(prev & ~cur):
            if i != j:
                events.append({"type": "event", "t": int(t),
                               "code": "link_down", "src": int(i),
                               "dst": int(j), "edge": "up"})
        prev = cur
    return events


def time_to_slo(
    backlog: np.ndarray, t_edge: int, cfg: TelemetryConfig
) -> tuple[int | None, float]:
    """Slots from a death edge until backlog re-enters the SLO band.

    The threshold is ``cfg.slo_backlog`` when set, else ``cfg.slo_factor``
    times the mean backlog over the ``cfg.slo_window`` slots before the
    edge (the pre-fault operating level). Returns ``(slots_or_None, thr)``.
    """
    backlog = _np(backlog)
    if cfg.slo_backlog is not None:
        thr = float(cfg.slo_backlog)
    else:
        lo = max(0, t_edge - cfg.slo_window)
        pre = backlog[lo:t_edge]
        thr = cfg.slo_factor * (float(pre.mean()) if pre.size else 0.0)
    after = backlog[t_edge:]
    ok = np.nonzero(after <= thr)[0]
    return (int(ok[0]) if ok.size else None), thr


def _decoded_ring(frame: TelemetryFrame) -> tuple[list[dict], int]:
    events, dropped = ring_events(frame.ring)
    out = []
    for ev in events:
        code = ev["code"]
        rec = {"type": "event", "t": ev["t"],
               "code": CODE_NAMES.get(code, str(code))}
        for i, name in enumerate(FIELDS_BY_CODE.get(code, ())):
            v = float(ev["val"][i])
            rec[name] = int(v) if name in _INT_FIELDS else v
        out.append(rec)
    return out, dropped


def collect_records(
    outs,
    frame: TelemetryFrame | None = None,
    *,
    cfg: TelemetryConfig | None = None,
    summary: dict | None = None,
    meta: dict | None = None,
    include_switches: bool = True,
    include_metrics: bool = True,
    link_health=None,
) -> list[dict]:
    """Build the full record stream for one run.

    ``outs`` must be a single run (no Monte-Carlo axis) — flight recording
    is per-run by construction; pick one lane of a vmapped sweep first.

    Recovery events pair with the next ``repair`` event (the revival edge
    the controller records): ``time_to_slo`` measures from the TRUE
    revival slot — a dead site cannot re-enter the SLO band before it is
    back — with the repair slot surfaced as ``repair_t``; an unpaired
    recovery falls back to its own death slot. Staged/serve runs with a
    nonzero hedge trace add derived ``hedge`` events; passing the run's
    ``link_health`` trace adds derived ``link_down`` edges.
    """
    cfg = cfg or TelemetryConfig()
    kind = engine_kind(outs)
    cost = _np(outs.cost)
    if cost.ndim != 1:
        raise ValueError(
            "collect_records decodes ONE run; index the Monte-Carlo axis "
            f"first (got cost shape {cost.shape})"
        )
    t_slots = cost.shape[0]
    backlog = _np(outs.backlog_avg)

    records: list[dict] = [{
        "type": "meta", "schema": SCHEMA_VERSION, "kind": kind,
        "t_slots": int(t_slots),
        "level": int(cfg.level), **(meta or {}),
    }]

    events: list[dict] = []
    dropped = 0
    if frame is not None:
        events, dropped = _decoded_ring(frame)
        repair_ts = sorted(e["t"] for e in events if e["code"] == "repair")
        for ev in events:
            if ev["code"] == "recovery":
                t0 = next((rt for rt in repair_ts if rt >= ev["t"]), ev["t"])
                tts, thr = time_to_slo(backlog, t0, cfg)
                ev["time_to_slo"] = tts
                ev["slo_backlog"] = thr
                if t0 != ev["t"]:
                    ev["repair_t"] = t0
    records[0]["events_dropped"] = dropped
    hedged = getattr(outs, "hedged_jobs", None)
    if hedged is not None and float(_np(hedged).sum()) > 0.0:
        events.extend(hedge_events(hedged, getattr(outs, "hedge_cost", None)))
    if link_health is not None:
        events.extend(link_down_events(link_health))
    if include_switches:
        events.extend(switch_events(outs.f_trace))
    events.sort(key=lambda e: (e["t"], e["code"]))
    records.extend(events)

    if include_metrics:
        metrics = dict(frame.metrics) if frame is not None else {}
        q_site = metrics.get("q_site")
        stage_wan = metrics.get("stage_wan_cost")
        stage_gb = metrics.get("stage_wan_gb")
        wan_slot = _np(outs.wan_cost) if kind == "staged" else None
        wan_gb_slot = _np(outs.wan_gb) if kind == "staged" else None
        rec_slot = _np(outs.recovery_cost) if kind == "placed" else None
        rec_gb_slot = _np(outs.recovery_gb) if kind == "placed" else None
        for t in range(t_slots):
            rec = {"type": "metric", "t": t,
                   "cost": float(cost[t]), "backlog": float(backlog[t])}
            if q_site is not None:
                rec["q_site"] = [float(x) for x in _np(q_site)[t]]
            if wan_slot is not None:
                rec["wan_cost"] = float(wan_slot[t])
                rec["wan_gb"] = float(wan_gb_slot[t])
            if stage_wan is not None:
                rec["stage_wan_cost"] = [float(x) for x in _np(stage_wan)[t]]
                rec["stage_wan_gb"] = [float(x) for x in _np(stage_gb)[t]]
            if rec_slot is not None and rec_slot[t] != 0.0:
                rec["recovery_cost"] = float(rec_slot[t])
                rec["recovery_gb"] = float(rec_gb_slot[t])
            records.append(rec)

    if frame is not None and cfg.histograms:
        # The distribution layer: per-row bucket counts plus the decoded
        # percentile table (with error bounds), one record per family.
        dims = {"site_cost_hist": "site", "queue_delay_hist": "stage",
                "sojourn_hist": "class"}
        for name, h in frame.metrics.items():
            if not name.endswith("_hist"):
                continue
            counts = _np(h)
            records.append({
                "type": "hist", "name": name[:-5],
                "dim": dims.get(name, "row"),
                "spec": dataclasses.asdict(cfg.hist),
                "counts": counts.tolist(),
                "percentiles": percentile_table(counts, cfg.hist),
            })

    if summary is not None:
        records.append({"type": "summary", "kind": kind, **summary})
    return records


def fleet_records(out: dict, *, meta: dict | None = None,
                  slo=None) -> list[dict]:
    """Record stream for one :meth:`repro.serve.engine.FleetEngine.run`.

    The serving engine returns a plain dict (its history carries host-side
    per-slot records already), so this is a thin re-shaping into the same
    meta / event / metric / summary stream ``collect_records`` emits for
    the scan engines — one writer (:func:`repro.telemetry.export.write_jsonl`)
    and one report tool serve all engines. Recovery events carry
    ``time_to_slo`` against the run's total-backlog series, thresholded at
    the engine's own ``slo_backlog`` (summed over classes).

    Metric rows carry per-class ``admitted_k`` / ``completed_k`` /
    ``choice`` columns so the span builder
    (:func:`repro.telemetry.spans.spans_from_records`) can rebuild
    request-cohort lifecycles from the saved stream alone. A run with
    the histogram layer on adds a ``hist`` record (sojourn counts +
    decoded percentiles); passing ``slo`` (a
    :class:`repro.telemetry.slo.SloSpec`) folds multi-window burn-rate
    alerts into the event stream and per-class SLO verdicts into the
    summary.
    """
    from repro.telemetry.metrics import HistogramSpec
    from repro.telemetry.slo import burn_events, evaluate_slo

    cost = _np(out["cost"])
    backlog = _np(out["backlog"])
    t_slots = cost.shape[0]
    n_k = len(out["history"][0]["admitted"])
    class_names = list(out.get("class_names")
                       or [f"class{i}" for i in range(n_k)])
    slo_thr = None
    records: list[dict] = [{
        "type": "meta", "schema": SCHEMA_VERSION, "kind": "serve",
        "t_slots": int(t_slots), "level": 0, "events_dropped": 0,
        "class_names": class_names,
        **(meta or {}),
    }]

    events = [dict(ev) for ev in out.get("events", ())]
    if slo is not None:
        events.extend(burn_events(out["admitted"], out["completed"], slo,
                                  class_names=class_names))
    for ev in events:
        if slo_thr is None:
            # Fleet-level SLO: every class at its per-class threshold.
            slo_thr = float(meta.get("slo_backlog", 0.0)) * n_k if meta else 0.0
        tts, thr = time_to_slo(
            backlog, ev["t"],
            TelemetryConfig(slo_backlog=slo_thr or float(backlog.mean())),
        )
        ev["time_to_slo"] = tts
        ev["slo_backlog"] = thr
    if "hedged_jobs" in out:
        events.extend(hedge_events(out["hedged_jobs"],
                                   out.get("hedge_cost")))
    events.extend(switch_events(out["dispatch"]))
    events.sort(key=lambda e: (e["t"], e["code"]))
    records.extend(events)

    wan_slot = _np(out["wan_cost"])
    wan_gb = _np(out["wan_gb"])
    for t, h in enumerate(out["history"]):
        records.append({
            "type": "metric", "t": t,
            "cost": float(cost[t]), "backlog": float(backlog[t]),
            "wan_cost": float(wan_slot[t]), "wan_gb": float(wan_gb[t]),
            "admitted": float(sum(h["admitted"])),
            "rejected": float(sum(h["rejected"])),
            "served": float(sum(h["served"])),
            "energy_j": float(sum(h["energy_j"])),
            "slo_viol": int(sum(h["slo_viol"])),
            "admitted_k": [float(x) for x in h["admitted"]],
            "completed_k": [float(x) for x in h["completed"]],
            "choice": [int(x) for x in h["choice"]],
        })

    if "sojourn_hist" in out:
        spec = HistogramSpec(**out["sojourn_spec"])
        counts = _np(out["sojourn_hist"])
        records.append({
            "type": "hist", "name": "sojourn", "dim": "class",
            "spec": dataclasses.asdict(spec),
            "counts": counts.tolist(),
            "percentiles": percentile_table(counts, spec,
                                            names=class_names),
        })
        if slo is not None:
            records.append({
                "type": "slo", "verdicts": evaluate_slo(
                    counts, spec, slo, names=class_names),
            })

    summary = {
        "type": "summary", "kind": "serve",
        "mean_cost": float(out["mean_cost"]),
        "final_backlog": float(out["final_backlog"]),
        "total_billed_cost": float(out["total_billed_cost"]),
        "admitted": float(_np(out["admitted"]).sum()),
        "rejected": float(_np(out["rejected"]).sum()),
        "served": float(_np(out["served"]).sum()),
        "exec_jobs": int(out["exec_jobs"]),
        "n_recoveries": int(len(out.get("events", ()))),
    }
    if "hedged_jobs" in out:
        summary["hedged_jobs"] = float(_np(out["hedged_jobs"]).sum())
        summary["hedge_cost"] = float(_np(out["hedge_cost"]).sum())
    records.append(summary)
    return records
