"""Telemetry levels and the static config every engine threads through.

The contract that keeps the PR-4 fast path intact: the telemetry level is
**static** (a jit-static argument), so ``OFF`` — the default — traces to
the byte-identical jaxpr the engines produced before telemetry existed:
zero extra scan outputs, zero ring carries, zero cost. ``SUMMARY`` adds
per-slot metric streams as extra stacked scan outputs; ``TRACE`` adds the
fixed-capacity, mask-compacted event ring recorded inside the
``lax.scan`` / ``lax.cond`` bodies (:mod:`repro.telemetry.ring`).

Engines that enable telemetry return ``(outputs, TelemetryFrame)`` instead
of bare ``outputs`` — the frame is a pytree (device arrays), decoded
host-side by :mod:`repro.telemetry.collect` / :mod:`repro.telemetry.export`.
"""

from __future__ import annotations

import dataclasses
import enum

from repro.telemetry.metrics import HistogramSpec


class Level(enum.IntEnum):
    """Telemetry verbosity. Static: each level is its own jit compilation."""

    OFF = 0       # byte-identical jaxpr to the pre-telemetry engines
    SUMMARY = 1   # per-slot metric streams (extra stacked scan outputs)
    TRACE = 2     # SUMMARY + the in-scan event ring


OFF = Level.OFF
SUMMARY = Level.SUMMARY
TRACE = Level.TRACE


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static flight-recorder knobs (hashable: rides in jit static args).

    Attributes:
        level: :class:`Level`. ``OFF`` is bit-exact with no telemetry.
        capacity: event-ring slots. Events beyond capacity overwrite the
            oldest (the ring keeps a total count, so the exporter reports
            exactly how many were dropped — and the cross-check refuses to
            certify a stream that lost events).
        slo_backlog: absolute backlog-per-queue SLO used for the
            recovery-time-to-SLO metric. ``None`` derives the threshold
            per event from the pre-fault backlog window
            (``slo_factor`` × the mean over the ``slo_window`` slots
            before the death edge).
        slo_factor / slo_window: the derived-threshold parameters.
        hist: optional :class:`repro.telemetry.metrics.HistogramSpec`
            enabling the distribution layer at SUMMARY+ — per-class
            request-sojourn histograms in ``FleetEngine``, per-stage
            queue-delay histograms in ``simulate_staged``, per-site
            energy-cost histograms in ``simulate``/``simulate_placed``.
            ``None`` (default) adds nothing; OFF ignores it entirely, so
            the byte-identical-jaxpr contract is unchanged.
    """

    level: Level = Level.OFF
    capacity: int = 256
    slo_backlog: float | None = None
    slo_factor: float = 1.5
    slo_window: int = 12
    hist: HistogramSpec | None = None

    @property
    def histograms(self) -> bool:
        return self.enabled and self.hist is not None

    @property
    def enabled(self) -> bool:
        return self.level >= Level.SUMMARY

    @property
    def tracing(self) -> bool:
        return self.level >= Level.TRACE


def enabled(cfg: TelemetryConfig | None) -> bool:
    """True when ``cfg`` asks for any telemetry (None counts as OFF)."""
    return cfg is not None and cfg.enabled


def tracing(cfg: TelemetryConfig | None) -> bool:
    """True when ``cfg`` asks for the in-scan event ring."""
    return cfg is not None and cfg.tracing


def histograms(cfg: TelemetryConfig | None) -> bool:
    """True when ``cfg`` asks for the histogram metrics layer."""
    return cfg is not None and cfg.histograms
