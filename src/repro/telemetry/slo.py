"""Percentile SLOs with multi-window burn-rate alerts (SRE-style).

The backlog-threshold SLO the engines already track is a level check;
this module evaluates *latency-percentile* SLOs — "p99 sojourn ≤ target
slots" — against the serving engine's fluid request flow, and raises
burn-rate alerts the way an error-budget policy would: the per-slot
fraction of served mass that missed the target is an error rate, the SLO
leaves a budget of ``1 - percentile/100``, and an alert fires only when
BOTH a short and a long rolling window burn the budget faster than a
threshold multiple — fast enough to matter, long enough to not be noise.

Inputs are host-side (T, K) admitted/completed arrays (the engine's own
accounting), replayed FIFO — the same order the device-side sojourn
histogram assumes — so the monitor needs no extra device work.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.telemetry.metrics import HistogramSpec, hist_quantiles

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class SloSpec:
    """One latency SLO: percentile ``percentile`` of sojourn ≤ ``target``.

    ``windows`` is a tuple of ``(short, long, threshold)`` triples in
    slots: an alert fires when the budget burn rate over BOTH windows
    exceeds ``threshold`` (the classic multi-window guard — the short
    window gives fast detection, the long window keeps one bad slot from
    paging). The default pair is sized for the smoke horizons used in
    tests and benches; production horizons would scale them up.
    """

    target: float                 # sojourn target, in slots
    percentile: float = 99.0
    windows: tuple = ((4, 16, 2.0),)

    def __post_init__(self):
        if not (0.0 < self.percentile < 100.0):
            raise ValueError("percentile must be in (0, 100)")
        if self.target < 0:
            raise ValueError("target must be >= 0")
        for short, long_, thr in self.windows:
            if not (0 < short <= long_) or thr <= 0:
                raise ValueError(f"bad window triple {(short, long_, thr)}")

    @property
    def budget(self) -> float:
        """Allowed bad fraction: 1 - percentile/100."""
        return 1.0 - self.percentile / 100.0


def bad_fraction(admitted: np.ndarray, completed: np.ndarray,
                 target: float) -> np.ndarray:
    """(T, K) per-slot fraction of served mass with sojourn > ``target``.

    FIFO replay: mass completing at slot ``t`` that was admitted at slot
    ``s`` experienced sojourn ``t - s``; the bad fraction at ``t`` is the
    over-target share of everything completing at ``t`` (0 where nothing
    completes — an idle slot burns no budget).
    """
    admitted = np.asarray(admitted, np.float64)
    completed = np.asarray(completed, np.float64)
    t_slots, k = admitted.shape
    bad = np.zeros((t_slots, k))
    tot = np.zeros((t_slots, k))
    for ki in range(k):
        ca = np.concatenate([[0.0], np.cumsum(admitted[:, ki])])
        cc = np.concatenate([[0.0], np.cumsum(completed[:, ki])])
        for t in range(t_slots):
            lo_c, hi_c = cc[t], cc[t + 1]
            if hi_c - lo_c <= _EPS:
                continue
            for s in range(t + 1):
                m = min(hi_c, ca[s + 1]) - max(lo_c, ca[s])
                if m > _EPS:
                    tot[t, ki] += m
                    if t - s > target:
                        bad[t, ki] += m
    return np.where(tot > _EPS, bad / np.maximum(tot, _EPS), 0.0)


def _rolling_mean(x: np.ndarray, w: int) -> np.ndarray:
    """Trailing rolling mean over ``w`` slots (shorter at the start)."""
    c = np.concatenate([[0.0], np.cumsum(x, dtype=np.float64)])
    t = np.arange(1, x.shape[0] + 1)
    lo = np.maximum(t - w, 0)
    return (c[t] - c[lo]) / (t - lo)


def burn_events(admitted, completed, slo: SloSpec,
                class_names=None) -> list[dict]:
    """Multi-window burn-rate alert events for one serving run.

    Returns ``{"type": "event", "code": "slo_burn", ...}`` records in the
    flight-record stream shape, one per (class, window pair, rising
    edge): an alert opens when both windows' burn rates cross the
    threshold and does not re-fire while it stays open.
    """
    admitted = np.asarray(admitted, np.float64)
    completed = np.asarray(completed, np.float64)
    t_slots, k = admitted.shape
    names = list(class_names or [f"class{i}" for i in range(k)])
    frac = bad_fraction(admitted, completed, slo.target)
    budget = max(slo.budget, _EPS)
    events: list[dict] = []
    for ki in range(k):
        for short, long_, thr in slo.windows:
            burn_s = _rolling_mean(frac[:, ki], short) / budget
            burn_l = _rolling_mean(frac[:, ki], long_) / budget
            firing = (burn_s > thr) & (burn_l > thr)
            edges = np.flatnonzero(firing & ~np.concatenate([[False],
                                                             firing[:-1]]))
            for t in edges:
                events.append({
                    "type": "event", "code": "slo_burn", "t": int(t),
                    "class": names[ki], "percentile": slo.percentile,
                    "target": slo.target, "window": [int(short), int(long_)],
                    "threshold": float(thr),
                    "burn_short": float(burn_s[t]),
                    "burn_long": float(burn_l[t]),
                })
    events.sort(key=lambda e: (e["t"], e["class"]))
    return events


def evaluate_slo(counts, spec: HistogramSpec, slo: SloSpec,
                 names=None) -> list[dict]:
    """End-of-run SLO verdicts from device-side histogram counts.

    ``counts`` is (K, n_buckets); each row yields
    ``{"name", "percentile", "target", "estimate", "err", "ok"}`` where
    ``ok`` is conservative: the SLO only passes when the estimate passes
    by more than the decode error bound (an overflow-bucket estimate —
    infinite error — can never certify a pass).
    """
    counts = np.asarray(counts, np.float64)
    if counts.ndim == 1:
        counts = counts[None]
    est, err = hist_quantiles(counts, spec, (slo.percentile,))
    rows = []
    for i in range(counts.shape[0]):
        e, b = float(est[i, 0]), float(err[i, 0])
        ok = bool(np.isfinite(e) and np.isfinite(b) and e + b <= slo.target)
        rows.append({
            "name": (names[i] if names else f"class{i}"),
            "percentile": slo.percentile, "target": slo.target,
            "estimate": e, "err": b, "ok": ok,
        })
    return rows
