"""repro.telemetry — a jit-safe flight recorder for every engine.

Three layers:

* **Recording** (device side, jit-safe): a static :class:`TelemetryConfig`
  level gates everything — ``OFF`` (default) keeps each engine's jaxpr
  byte-identical to the pre-telemetry build; ``SUMMARY`` adds per-slot
  metric streams as extra stacked scan outputs; ``TRACE`` adds the
  fixed-capacity, mask-compacted :class:`EventRing` written inside
  ``lax.scan`` / ``lax.cond`` bodies (recovery epochs, placement-epoch
  churn, dead-site ingest redirects). Engines return
  ``(outputs, TelemetryFrame)`` when a level is enabled.
* **Decoding** (host side): :func:`collect_records` turns outputs + frame
  into a flat JSON-ready record stream — in-scan events, derived events
  (GMSA manager-switch edges), per-slot metrics, the embedded summary.
* **Export**: :func:`write_jsonl` / :func:`read_jsonl`,
  :func:`render_timeline`, and :func:`cross_check`, with the CLI
  ``python -m repro.telemetry.report run.jsonl --check``.
* **Distributions & spans** (PR 8): :class:`HistogramSpec` enables
  jit-safe log-bucket histograms riding the scan bodies (request sojourn,
  queue delay, site cost) decoded to p50/p95/p99 with error bounds
  (:mod:`repro.telemetry.metrics`); :mod:`repro.telemetry.spans` folds
  record streams into lifecycle spans exported as Chrome trace-event
  JSON; :mod:`repro.telemetry.slo` evaluates percentile SLOs with
  multi-window burn-rate alerts; and
  ``python -m repro.telemetry.bench_check BENCH_sim.json`` is the
  perf-regression sentinel over the committed bench trajectory.
"""

from repro.telemetry.config import (
    OFF,
    SUMMARY,
    TRACE,
    Level,
    TelemetryConfig,
    enabled,
    histograms,
    tracing,
)
from repro.telemetry.metrics import (
    HistogramSpec,
    fifo_sojourn_replay,
    hist_add,
    hist_init,
    hist_quantiles,
    hist_series,
    percentile_table,
    sojourn_init,
    sojourn_step,
    weighted_percentile,
)
from repro.telemetry.slo import SloSpec, burn_events, evaluate_slo
from repro.telemetry.spans import (
    controller_spans,
    request_spans,
    spans_from_records,
    straggler_spans,
    to_chrome_trace,
    write_chrome_trace,
)
from repro.telemetry.ring import (
    EV_EPOCH,
    EV_HEDGE,
    EV_INGEST_REDIRECT,
    EV_LINK_DOWN,
    EV_RECOVERY,
    EV_REPAIR,
    EV_SWITCH,
    EventRing,
    TelemetryFrame,
    empty_frame,
    ring_events,
    ring_init,
    ring_push,
)
from repro.telemetry.collect import (
    collect_records,
    engine_kind,
    fleet_records,
    hedge_events,
    link_down_events,
    switch_events,
    time_to_slo,
)
from repro.telemetry.export import (
    cross_check,
    read_jsonl,
    render_timeline,
    sparkline,
    write_jsonl,
)

__all__ = [
    "Level", "TelemetryConfig", "OFF", "SUMMARY", "TRACE",
    "enabled", "tracing", "histograms",
    "EventRing", "TelemetryFrame", "empty_frame",
    "ring_init", "ring_push", "ring_events",
    "EV_RECOVERY", "EV_EPOCH", "EV_SWITCH", "EV_INGEST_REDIRECT",
    "EV_REPAIR", "EV_HEDGE", "EV_LINK_DOWN",
    "collect_records", "engine_kind", "fleet_records", "switch_events",
    "hedge_events", "link_down_events", "time_to_slo",
    "write_jsonl", "read_jsonl", "render_timeline", "sparkline",
    "cross_check",
    "HistogramSpec", "hist_init", "hist_add", "hist_series",
    "hist_quantiles", "percentile_table", "sojourn_init", "sojourn_step",
    "fifo_sojourn_replay", "weighted_percentile",
    "SloSpec", "burn_events", "evaluate_slo",
    "request_spans", "controller_spans", "spans_from_records",
    "straggler_spans", "to_chrome_trace", "write_chrome_trace",
]
