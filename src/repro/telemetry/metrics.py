"""Jit-safe fixed-bucket histograms — latency/cost distributions on-device.

The flight recorder (PR 6) gave the engines event and metric *streams*;
this module gives them *distributions*: a static :class:`HistogramSpec`
describes a log-spaced bucket layout (plus an underflow bucket below
``lo`` and an overflow bucket at ``hi``), and the accumulators are plain
``(..., n_buckets)`` float32 count arrays updated with masked scatter-adds
— safe inside ``jax.lax.scan`` bodies, `vmap`, and `lax.cond`, exactly
like :mod:`repro.telemetry.ring`. Engines either fold values into a
carried histogram (``FleetEngine``'s per-class request-sojourn clock,
which needs the FIFO age ring below) or histogram a post-scan derived
stream in one vectorized pass (``simulate_staged``'s per-stage queue
delays, ``simulate``/``simulate_placed``'s per-site energy cost) — either
way the OFF path stays byte-identical because everything is gated on the
static :class:`repro.telemetry.config.TelemetryConfig`.

Host-side, :func:`hist_quantiles` decodes counts into percentile
estimates with **error bounds**: within a bucket the estimate linearly
interpolates the bucket's range, so the true quantile is within one
bucket width (log-spaced: a fixed *relative* resolution of
``ratio - 1``); the overflow bucket yields its lower edge with an
unbounded error — widen ``hi`` if p99 lands there.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
from jax import Array


@dataclasses.dataclass(frozen=True)
class HistogramSpec:
    """Static log-spaced bucket layout (hashable: rides in jit static args).

    Buckets: ``[0, lo)`` (underflow), ``n_buckets - 2`` log-spaced buckets
    covering ``[lo, hi)`` at ratio ``(hi/lo)**(1/(n_buckets-2))``, and
    ``[hi, inf)`` (overflow). The relative quantile resolution is
    ``ratio - 1`` — the default 26-bucket 0.5..512 layout resolves to
    ~33% anywhere in range, tight enough to rank policies on p99 while
    keeping the accumulator a single cache line per series.
    """

    lo: float = 0.5
    hi: float = 512.0
    n_buckets: int = 26

    def __post_init__(self):
        if not (self.lo > 0.0 and self.hi > self.lo):
            raise ValueError(f"need 0 < lo < hi, got [{self.lo}, {self.hi})")
        if self.n_buckets < 3:
            raise ValueError("need >= 3 buckets (under, interior, over)")

    @property
    def ratio(self) -> float:
        return (self.hi / self.lo) ** (1.0 / (self.n_buckets - 2))

    def edges(self) -> np.ndarray:
        """(n_buckets + 1,) bucket edges: 0, lo, lo*r, ..., hi, inf."""
        interior = self.lo * self.ratio ** np.arange(self.n_buckets - 1)
        interior[-1] = self.hi          # kill the **(n-2) rounding drift
        return np.concatenate([[0.0], interior, [np.inf]])

    def bucket_index(self, values: Array) -> Array:
        """Bucket of each value — jit-safe, clipped into [0, n_buckets)."""
        v = jnp.asarray(values, jnp.float32)
        step = np.log(self.ratio)
        idx = 1 + jnp.floor(
            (jnp.log(jnp.maximum(v, self.lo)) - np.log(self.lo)) / step
        ).astype(jnp.int32)
        idx = jnp.where(v < self.lo, 0, idx)
        return jnp.clip(idx, 0, self.n_buckets - 1)


def hist_init(spec: HistogramSpec, *lead: int) -> Array:
    """A zeroed ``(*lead, n_buckets)`` count accumulator."""
    return jnp.zeros((*lead, spec.n_buckets), jnp.float32)


def hist_add(
    spec: HistogramSpec,
    counts: Array,
    values: Array,
    weights: Array | None = None,
) -> Array:
    """Fold ``values`` (any shape) into a 1-D ``(n_buckets,)`` accumulator.

    ``weights`` defaults to 1 per value; a masked update is just a zero
    weight, so this composes with ``lax.cond``/death-edge gating the same
    way :func:`repro.telemetry.ring.ring_push` does.
    """
    idx = spec.bucket_index(values).reshape(-1)
    w = (jnp.ones(idx.shape, jnp.float32) if weights is None
         else jnp.asarray(weights, jnp.float32).reshape(-1))
    return counts.at[idx].add(w)


def hist_series(spec: HistogramSpec, values: Array, axis: int = -1) -> Array:
    """Histogram a batched series along ``axis`` in one vectorized pass.

    ``values`` of shape (..., T) (after moving ``axis`` last) becomes
    (..., n_buckets) counts — the post-scan path: derived per-slot streams
    (per-site cost, per-stage queue delay) histogrammed for the whole
    horizon at once, zero ops added to any scan body.
    """
    v = jnp.moveaxis(jnp.asarray(values, jnp.float32), axis, -1)
    idx = spec.bucket_index(v)                           # (..., T)
    one_hot = (idx[..., None] == jnp.arange(spec.n_buckets)).astype(jnp.float32)
    return jnp.sum(one_hot, axis=-2)                     # (..., n_buckets)


# ---------------------------------------------------------------------------
# The FIFO sojourn clock: a carried age ring for fluid request queues
# ---------------------------------------------------------------------------

def sojourn_init(spec: HistogramSpec, k: int, max_age: int) -> tuple[Array, Array]:
    """Carried state for :func:`sojourn_step`: (age ring, histogram).

    ``age[k, a]`` is class-k request mass admitted ``a`` slots ago and not
    yet served; ``max_age`` >= the horizon keeps the ring exact (mass
    older than ``max_age`` pools in the last lane and still drains FIFO).
    """
    return jnp.zeros((k, max_age + 1), jnp.float32), hist_init(spec, k)


def sojourn_step(
    spec: HistogramSpec,
    age: Array,
    hist: Array,
    admitted: Array,
    completed: Array,
) -> tuple[Array, Array]:
    """One slot of the per-class FIFO sojourn clock — jit-safe, carried.

    The fluid-queue analogue of request span timing: ``admitted`` (K,)
    mass enters at age 0, ``completed`` (K,) mass drains oldest-first
    (the tandem queues are work-conserving and order-preserving in the
    fluid limit), and each drained sliver lands in the sojourn histogram
    at its age in slots. Mass wiped and re-injected by a pod-death drain
    is *not* re-admitted here — its clock keeps running, so recovery
    re-execution shows up as tail latency, which is the point.

    Returns the advanced ``(age, hist)`` pair.
    """
    k, a_max = age.shape
    # Admit this slot's arrivals at age 0 (they may complete this slot:
    # the queue step lets f·A flow straight through min(acc, mu)).
    age = age.at[:, 0].add(jnp.asarray(admitted, jnp.float32))
    # FIFO drain: oldest age first. tail[k, a] = mass strictly older
    # than lane a; lane a gives up min(its mass, remaining demand).
    rev_cum = jnp.cumsum(age[:, ::-1], axis=1)[:, ::-1]            # incl. self
    tail = rev_cum - age                                           # excl. self
    c = jnp.asarray(completed, jnp.float32)[:, None]
    take = jnp.clip(c - tail, 0.0, age)                            # (K, A)
    ages = jnp.arange(a_max, dtype=jnp.float32)
    idx = spec.bucket_index(ages)                                  # (A,)
    hist = hist.at[:, idx].add(take)
    age = age - take
    # Advance the clock: every survivor is one slot older; mass at the
    # ring's edge pools in the last lane (still drains FIFO, its sojourn
    # clipped at max_age — size the ring to the horizon and it never fires).
    age = jnp.concatenate(
        [jnp.zeros((k, 1), jnp.float32), age[:, :-1]], axis=1
    ).at[:, -1].add(age[:, -1])
    return age, hist


def fifo_sojourn_replay(
    admitted: np.ndarray, completed: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Exact host-side FIFO replay: per-class sojourn samples + weights.

    ``admitted``/``completed`` are (T, K) fluid counts. Returns
    ``(sojourn, weight)`` of shape (K, T, T) flattened to (K, T*T) where
    ``sojourn[k, i]`` is a sojourn in slots and ``weight[k, i]`` the mass
    that experienced it — the ground truth the device-side
    :func:`sojourn_step` histogram is validated against (and the input to
    exact weighted percentiles via :func:`weighted_percentile`).
    """
    admitted = np.asarray(admitted, np.float64)
    completed = np.asarray(completed, np.float64)
    t_slots, k = admitted.shape
    soj = np.zeros((k, t_slots * t_slots))
    wgt = np.zeros((k, t_slots * t_slots))
    for ki in range(k):
        ca = np.concatenate([[0.0], np.cumsum(admitted[:, ki])])
        cc = np.concatenate([[0.0], np.cumsum(completed[:, ki])])
        out = 0
        for t in range(t_slots):
            # Mass completing at slot t occupies [cc[t], cc[t+1]) of the
            # cumulative-arrival axis; intersect with each admit slot's
            # segment [ca[s], ca[s+1]) to attribute sojourn t - s.
            lo_c, hi_c = cc[t], cc[t + 1]
            if hi_c <= lo_c:
                continue
            for s in range(t + 1):
                m = min(hi_c, ca[s + 1]) - max(lo_c, ca[s])
                if m > 1e-12:
                    soj[ki, out] = t - s
                    wgt[ki, out] = m
                    out += 1
    return soj, wgt


def weighted_percentile(
    values: np.ndarray, weights: np.ndarray, qs
) -> np.ndarray:
    """Exact weighted percentiles (inverse empirical CDF) of mass samples."""
    values = np.asarray(values, np.float64).reshape(-1)
    weights = np.asarray(weights, np.float64).reshape(-1)
    keep = weights > 0
    values, weights = values[keep], weights[keep]
    if values.size == 0:
        return np.full(np.shape(qs), np.nan)
    order = np.argsort(values)
    values, weights = values[order], weights[order]
    cum = np.cumsum(weights)
    targets = np.asarray(qs, np.float64) / 100.0 * cum[-1]
    return values[np.searchsorted(cum, targets, side="left").clip(0, values.size - 1)]


# ---------------------------------------------------------------------------
# Host-side decode: counts -> percentiles with error bounds
# ---------------------------------------------------------------------------

def hist_quantiles(
    counts, spec: HistogramSpec, qs=(50.0, 95.0, 99.0)
) -> tuple[np.ndarray, np.ndarray]:
    """Percentile estimates + error bounds from bucket counts.

    ``counts`` is (..., n_buckets); returns ``(est, err)`` of shape
    (..., len(qs)). Within a bucket the estimate linearly interpolates
    the bucket range, so ``|est - true| <= err`` with ``err`` = the
    bucket width (``inf`` for the overflow bucket, whose estimate is its
    lower edge ``hi``; ``nan`` where the histogram is empty).
    """
    counts = np.asarray(counts, np.float64)
    lead = counts.shape[:-1]
    flat = counts.reshape(-1, spec.n_buckets)
    edges = spec.edges()
    width = np.diff(edges)
    qs = np.asarray(qs, np.float64)
    est = np.full((flat.shape[0], qs.size), np.nan)
    err = np.full((flat.shape[0], qs.size), np.nan)
    for i, row in enumerate(flat):
        total = row.sum()
        if total <= 0:
            continue
        cum = np.cumsum(row)
        targets = qs / 100.0 * total
        b = np.searchsorted(cum, targets, side="left").clip(0, spec.n_buckets - 1)
        prev = np.where(b > 0, cum[b - 1], 0.0)
        frac = np.where(row[b] > 0, (targets - prev) / np.maximum(row[b], 1e-300), 0.0)
        overflow = b == spec.n_buckets - 1
        est[i] = np.where(
            overflow, edges[-2], edges[b] + frac * np.where(np.isfinite(width[b]), width[b], 0.0)
        )
        err[i] = width[b]
    return est.reshape(*lead, qs.size), err.reshape(*lead, qs.size)


def percentile_table(
    counts, spec: HistogramSpec, qs=(50.0, 95.0, 99.0), names=None
) -> list[dict]:
    """JSON-ready per-row percentile summaries for (R, n_buckets) counts."""
    counts = np.asarray(counts, np.float64)
    if counts.ndim == 1:
        counts = counts[None]
    est, err = hist_quantiles(counts, spec, qs)
    rows = []
    for i in range(counts.shape[0]):
        row = {"count": float(counts[i].sum())}
        if names is not None:
            row = {"name": names[i], **row}
        for j, q in enumerate(qs):
            row[f"p{q:g}"] = float(est[i, j])
            row[f"p{q:g}_err"] = float(err[i, j])
        rows.append(row)
    return rows
