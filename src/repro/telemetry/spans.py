"""Span tracing: fold ordered record streams into lifecycle spans.

The flight recorder emits flat per-slot records; this module folds them
into *spans* — named intervals on named tracks — and exports Chrome
trace-event JSON (the ``chrome://tracing`` / Perfetto format), so a
faulted serving run opens as a timeline: request cohorts admit →
dispatch → prefill → KV shuffle → decode → served per class, controller
epochs and recovery-to-SLO windows on their own tracks, death edges and
manager switches as instants.

Two builders:

* :func:`request_spans` — per-request-class lifecycle spans from a
  :meth:`repro.serve.engine.FleetEngine.run` output dict. The engine is
  a fluid queue, so "a request" is a *cohort*: the mass admitted in one
  slot, tracked FIFO (the same order the sojourn clock in
  :mod:`repro.telemetry.metrics` assumes) until it drains.
* :func:`controller_spans` — epoch / recovery / switch spans from a
  ``collect_records`` / ``fleet_records`` stream (the list of dicts, or
  whatever :func:`repro.telemetry.export.read_jsonl` returned).
* :func:`straggler_spans` — degraded-health windows from a (T, N) health
  trace (:mod:`repro.traces.faults`): per site, each maximal sub-nominal
  window becomes a ``straggler`` (interior factor) or ``dead`` (factor
  hits zero) span with a ``repaired`` instant at its close — overlay
  these on a faulted run's timeline to see WHY the tail moved.

Both return plain span dicts (``name``/``cat``/``t0``/``t1``/``track``/
``args``; ``t1 is None`` marks an instant), which
:func:`to_chrome_trace` converts — slots mapped to milliseconds — and
:func:`write_chrome_trace` writes. The JSON loads directly in Perfetto
(ui.perfetto.dev) or ``chrome://tracing``.
"""

from __future__ import annotations

import json

import numpy as np

_EPS = 1e-9


def span(name, cat, t0, t1=None, track="main", **args) -> dict:
    """One span: an interval (``t1`` set) or an instant (``t1 is None``)."""
    return {
        "name": str(name), "cat": str(cat), "t0": float(t0),
        "t1": None if t1 is None else float(t1),
        "track": str(track), "args": args,
    }


def fifo_cohorts(admitted: np.ndarray, completed: np.ndarray) -> list[list[tuple]]:
    """FIFO cohort attribution: per class, a list of (s, t, mass) triples.

    ``admitted``/``completed`` are (T, K) fluid counts; mass admitted at
    slot ``s`` is matched FIFO against mass completed at slot ``t >= s``
    by intersecting cumulative-count segments — the exact replay of the
    device-side sojourn clock's drain order.
    """
    admitted = np.asarray(admitted, np.float64)
    completed = np.asarray(completed, np.float64)
    t_slots, k = admitted.shape
    out: list[list[tuple]] = []
    for ki in range(k):
        ca = np.concatenate([[0.0], np.cumsum(admitted[:, ki])])
        cc = np.concatenate([[0.0], np.cumsum(completed[:, ki])])
        tri = []
        for t in range(t_slots):
            lo_c, hi_c = cc[t], cc[t + 1]
            if hi_c - lo_c <= _EPS:
                continue
            for s in range(t + 1):
                m = min(hi_c, ca[s + 1]) - max(lo_c, ca[s])
                if m > _EPS:
                    tri.append((s, t, m))
        out.append(tri)
    return out


def request_spans(out: dict, class_names=None) -> list[dict]:
    """Request-cohort lifecycle spans from a ``FleetEngine.run`` dict.

    One track per request class. Each admit-slot cohort with mass gets a
    parent ``request`` span from its admit slot to its last completion
    slot, with phase children: an ``admit`` instant, a one-slot
    ``prefill`` span at dispatch (the fluid step drains prefill in the
    dispatch slot), a ``kv_shuffle`` instant at the prefill → decode
    handoff, a ``decode`` span covering the completion window, and a
    ``served`` instant at the end. Cohorts still backlogged at the
    horizon close with cat ``unserved`` at ``t_slots``. Recovery events
    from the run add death-edge instants and (when ``time_to_slo`` is
    known from a record stream — see :func:`controller_spans`) windows.
    """
    admitted = np.asarray(out["admitted"], np.float64)
    completed = np.asarray(out["completed"], np.float64)
    t_slots, k = admitted.shape
    names = list(class_names or out.get("class_names")
                 or [f"class{i}" for i in range(k)])
    history = out.get("history", [])
    spans: list[dict] = []
    for ki, tri in enumerate(fifo_cohorts(admitted, completed)):
        track = names[ki]
        by_s: dict[int, list[tuple]] = {}
        for s, t, m in tri:
            by_s.setdefault(s, []).append((t, m))
        for s in range(t_slots):
            adm = admitted[s, ki]
            if adm <= _EPS:
                continue
            done = by_s.get(s, [])
            done_mass = sum(m for _, m in done)
            if done:
                t_first = done[0][0]
                t_end = done[-1][0] + 1
                cat = "request"
            else:
                t_first, t_end, cat = s, t_slots, "unserved"
            decode_pod = None
            if history and done:
                decode_pod = history[done[-1][0]]["choice"][ki]
            spans.append(span(
                f"req {track}@t{s}", cat, s, t_end, track=track,
                mass=round(adm, 3), served_mass=round(done_mass, 3),
                decode_pod=decode_pod,
            ))
            spans.append(span("admit", "phase", s, track=track,
                              mass=round(adm, 3)))
            spans.append(span("prefill", "phase", s, s + 1, track=track))
            if done:
                spans.append(span("kv_shuffle", "phase", t_first,
                                  track=track, decode_pod=decode_pod))
                spans.append(span("decode", "phase", t_first, t_end,
                                  track=track))
                spans.append(span("served", "phase", t_end, track=track,
                                  mass=round(done_mass, 3)))
    for ev in out.get("events", ()):
        spans.append(span(
            f"pod {ev['pod']} died", "fault", ev["t"], track="faults",
            n_died=ev.get("n_died"), drained=ev.get("drained"),
        ))
    return spans


def controller_spans(records: list[dict]) -> list[dict]:
    """Controller-plane spans from a flight-record stream.

    * ``epoch`` events become back-to-back placement-epoch spans on the
      ``controller`` track (args: WAN/sync bills, churn, budget use).
    * ``recovery`` events become a death-edge instant plus — when the
      event carries ``time_to_slo`` — a ``recovery→SLO`` span from the
      edge until the backlog re-enters the SLO band (``unrecovered`` to
      the horizon when it never does).
    * ``switch`` events become instants on the ``dispatch`` track.
    """
    meta = next((r for r in records if r.get("type") == "meta"), {})
    t_slots = int(meta.get("t_slots", 0)) or max(
        (int(r.get("t", 0)) + 1 for r in records), default=0
    )
    spans: list[dict] = []
    prev_edge = 0
    for r in records:
        if r.get("type") != "event":
            continue
        t = int(r["t"])
        code = r.get("code")
        if code == "epoch":
            spans.append(span(
                f"epoch {r.get('epoch', '?')}", "epoch", prev_edge, t + 1,
                track="controller", wan_gb=r.get("wan_gb"),
                wan_cost=r.get("wan_cost"), sync_cost=r.get("sync_cost"),
                churn=r.get("churn"), budget_use=r.get("budget_use"),
            ))
            prev_edge = t + 1
        elif code == "recovery":
            site = r.get("site", r.get("pod"))
            spans.append(span(
                f"death edge @{site}", "fault", t, track="faults",
                n_died=r.get("n_died", r.get("n_dead")),
                recovery_gb=r.get("recovery_gb", r.get("drained")),
            ))
            tts = r.get("time_to_slo")
            if tts is not None:
                spans.append(span(
                    "recovery→SLO", "recovery", t, t + max(int(tts), 1),
                    track="controller", slo_backlog=r.get("slo_backlog"),
                ))
            elif "time_to_slo" in r:
                spans.append(span(
                    "unrecovered", "recovery", t, t_slots,
                    track="controller", slo_backlog=r.get("slo_backlog"),
                ))
        elif code == "switch":
            spans.append(span(
                f"switch k{r.get('k')}→{r.get('dst')}", "switch", t,
                track="dispatch", src=r.get("src"), dst=r.get("dst"),
                stage=r.get("stage"),
            ))
        elif code == "slo_burn":
            spans.append(span(
                f"slo burn {r.get('class', '')}", "slo", t, track="slo",
                burn_short=r.get("burn_short"), burn_long=r.get("burn_long"),
                threshold=r.get("threshold"),
            ))
    return spans


def straggler_spans(health, site_names=None, link_health=None) -> list[dict]:
    """Degraded-health windows from a ``(T, N)`` health trace.

    One track per site. Each maximal window where a site's health factor
    sits below 1.0 becomes an interval span — cat ``dead`` when the
    factor bottoms out at zero inside the window, ``straggler``
    otherwise — carrying the window's min/mean factor, with a
    ``repaired`` instant at its close (when it closes before the
    horizon). Pass ``link_health`` (``(T, N, N)``) to additionally emit
    ``link down``/``link up`` instants on a ``links`` track for every
    severed-edge transition. Overlay on a faulted run's request timeline
    to see why the tail moved.
    """
    h = np.asarray(health, np.float64)
    t_slots, n = h.shape
    names = list(site_names or [f"site{i}" for i in range(n)])
    spans: list[dict] = []
    for i in range(n):
        t = 0
        while t < t_slots:
            if h[t, i] >= 1.0 - _EPS:
                t += 1
                continue
            t0 = t
            while t < t_slots and h[t, i] < 1.0 - _EPS:
                t += 1
            win = h[t0:t, i]
            lo = float(win.min())
            cat = "dead" if lo <= _EPS else "straggler"
            label = (f"{names[i]} dead" if cat == "dead"
                     else f"{names[i]} x{lo:.2f}")
            spans.append(span(
                label, cat, t0, t, track=names[i],
                factor_min=round(lo, 4),
                factor_mean=round(float(win.mean()), 4),
            ))
            if t < t_slots:
                spans.append(span("repaired", "repair", t, track=names[i]))
    if link_health is not None:
        lh = np.asarray(link_health, np.float64)
        down = lh <= _EPS
        for t in range(t_slots):
            prev = down[t - 1] if t else np.zeros_like(down[0])
            for src, dst in zip(*np.nonzero(down[t] != prev)):
                if src == dst:
                    continue
                edge = "down" if down[t, src, dst] else "up"
                spans.append(span(
                    f"link {names[src]}→{names[dst]} {edge}", "link", t,
                    track="links", src=int(src), dst=int(dst), edge=edge,
                ))
    return spans


def spans_from_records(records: list[dict]) -> list[dict]:
    """All spans recoverable from one saved record stream.

    Controller spans always; request-cohort spans additionally when the
    metric rows carry the per-class ``admitted_k`` / ``completed_k``
    columns (``fleet_records`` writes them) — so the report tool can
    emit a Chrome trace from a JSONL file alone, no engine rerun.
    """
    spans = controller_spans(records)
    metrics = [r for r in records if r.get("type") == "metric"]
    if metrics and "admitted_k" in metrics[0]:
        meta = next((r for r in records if r.get("type") == "meta"), {})
        out = {
            "admitted": np.asarray([m["admitted_k"] for m in metrics]),
            "completed": np.asarray([m["completed_k"] for m in metrics]),
            "history": [{"choice": m["choice"], "admitted": m["admitted_k"],
                         "completed": m["completed_k"]} for m in metrics],
            "class_names": meta.get("class_names"),
        }
        spans = request_spans(out) + spans
    return spans


def to_chrome_trace(spans: list[dict], slot_ms: float = 1.0,
                    process: str = "repro") -> dict:
    """Spans -> Chrome trace-event JSON (dict), 1 slot = ``slot_ms`` ms.

    Interval spans become complete (``ph="X"``) events, instants become
    thread-scoped instant (``ph="i"``) events; tracks map to tids with
    ``thread_name`` metadata so Perfetto labels the rows. Timestamps are
    microseconds per the trace-event spec; zero-length intervals are
    widened to one microsecond so they stay visible.
    """
    tids: dict[str, int] = {}
    events: list[dict] = [{
        "ph": "M", "pid": 0, "tid": 0, "name": "process_name",
        "args": {"name": process},
    }]
    for sp in spans:
        track = sp["track"]
        if track not in tids:
            tids[track] = len(tids) + 1
            events.append({
                "ph": "M", "pid": 0, "tid": tids[track],
                "name": "thread_name", "args": {"name": track},
            })
        ts = sp["t0"] * slot_ms * 1000.0
        base = {
            "name": sp["name"], "cat": sp["cat"], "pid": 0,
            "tid": tids[track], "ts": ts,
            "args": {k: v for k, v in sp["args"].items() if v is not None},
        }
        if sp["t1"] is None:
            events.append({**base, "ph": "i", "s": "t"})
        else:
            dur = max((sp["t1"] - sp["t0"]) * slot_ms * 1000.0, 1.0)
            events.append({**base, "ph": "X", "dur": dur})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans: list[dict], path, slot_ms: float = 1.0,
                       process: str = "repro"):
    """Write :func:`to_chrome_trace` JSON to ``path``; returns the path."""
    trace = to_chrome_trace(spans, slot_ms=slot_ms, process=process)
    with open(path, "w") as f:
        json.dump(trace, f)
    return path
