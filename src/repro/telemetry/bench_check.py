"""Perf-regression sentinel over the committed bench trajectory.

  PYTHONPATH=src python -m repro.telemetry.bench_check BENCH_sim.json

``BENCH_sim.json`` accumulates one entry per bench run (label, git sha,
backend, per-bench ``us_per_call``); this tool treats each
``(label, name)`` pair as a time series and flags the LATEST point when
it regresses against the trailing baseline. The detector is robust, not
parametric — container-to-container timing noise is heavy-tailed, so the
baseline is the median of the prior points and the scale is the MAD
(``sigma ≈ 1.4826 × MAD``, zero-floored at a fraction of the median):
a point is a regression only when its robust z-score exceeds ``--z``
AND its relative slowdown exceeds ``--min-rel`` — both gates, so a tiny
absolute wobble on a microbench can't page and a huge MAD can't mask a
2× cliff. Series shorter than ``--min-points`` are skipped (reported,
never failed): a fresh bench needs history before it can regress.

Exit status: 0 = no regressions (or nothing checkable), 1 = at least
one regression, 2 = unreadable input. CI runs this right after the
bench steps against the repo's committed trajectory.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

#: MAD -> sigma for a normal core; the usual robust-scale constant.
MAD_SIGMA = 1.4826


def load_series(path) -> dict[tuple[str, str], list[float]]:
    """``BENCH_sim.json`` -> ``{(label, name): [us_per_call, ...]}``.

    File order is run order (the writer appends and dedupes same-label
    snapshots in place), so each list is the bench's trajectory with the
    LATEST point last.
    """
    with open(path) as f:
        entries = json.load(f)
    series: dict[tuple[str, str], list[float]] = {}
    for entry in entries:
        label = str(entry.get("label", ""))
        for b in entry.get("benches", ()):
            key = (label, str(b["name"]))
            series.setdefault(key, []).append(float(b["us_per_call"]))
    return series


def check_series(values, z_max: float = 3.0, min_rel: float = 0.25,
                 min_points: int = 4, rel_floor: float = 0.05) -> dict:
    """Verdict for one trajectory (latest point vs trailing baseline).

    Returns ``{"status": "ok" | "regression" | "skipped", "z", "rel",
    "latest", "median", "sigma", "n"}``. ``sigma`` is the MAD-derived
    scale, floored at ``rel_floor × median`` so an eerily stable series
    (MAD ~ 0) doesn't turn measurement jitter into a 100-sigma page.
    """
    v = np.asarray(values, np.float64)
    n = v.size
    if n < min_points:
        return {"status": "skipped", "n": int(n), "latest": float(v[-1])
                if n else float("nan")}
    base, latest = v[:-1], float(v[-1])
    med = float(np.median(base))
    mad = float(np.median(np.abs(base - med)))
    sigma = max(MAD_SIGMA * mad, rel_floor * max(med, 1e-12))
    z = (latest - med) / sigma
    rel = latest / max(med, 1e-12) - 1.0
    status = "regression" if (z > z_max and rel > min_rel) else "ok"
    return {"status": status, "n": int(n), "latest": latest, "median": med,
            "sigma": sigma, "z": float(z), "rel": float(rel)}


def check_file(path, z_max: float = 3.0, min_rel: float = 0.25,
               min_points: int = 4, label: str | None = None) -> dict:
    """Run the sentinel over every (label, name) series in the file."""
    series = load_series(path)
    results = {}
    for (lbl, name), values in sorted(series.items()):
        if label is not None and lbl != label:
            continue
        results[f"{lbl}/{name}"] = check_series(
            values, z_max=z_max, min_rel=min_rel, min_points=min_points
        )
    regressions = [k for k, r in results.items()
                   if r["status"] == "regression"]
    return {"ok": not regressions, "regressions": regressions,
            "results": results}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when the latest bench entry regresses vs the "
                    "trailing median/MAD baseline")
    ap.add_argument("path", help="BENCH_sim.json")
    ap.add_argument("--z", type=float, default=3.0,
                    help="robust z-score gate (default 3)")
    ap.add_argument("--min-rel", type=float, default=0.25,
                    help="minimum relative slowdown gate (default 0.25)")
    ap.add_argument("--min-points", type=int, default=4,
                    help="series shorter than this are skipped (default 4)")
    ap.add_argument("--label", default=None,
                    help="check only series from this bench label")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    try:
        res = check_file(args.path, z_max=args.z, min_rel=args.min_rel,
                         min_points=args.min_points, label=args.label)
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
        print(f"bench_check: cannot read {args.path}: {e}", file=sys.stderr)
        return 2

    n_ok = sum(r["status"] == "ok" for r in res["results"].values())
    n_skip = sum(r["status"] == "skipped" for r in res["results"].values())
    if not args.quiet:
        for key, r in res["results"].items():
            if r["status"] == "skipped":
                print(f"  SKIP {key}: only {r['n']} point(s)")
            else:
                mark = "FAIL" if r["status"] == "regression" else "  ok"
                print(f"  {mark} {key}: {r['latest']:.1f} us vs median "
                      f"{r['median']:.1f} (z={r['z']:+.1f}, "
                      f"rel={r['rel']:+.0%}, n={r['n']})")
        verdict = ("REGRESSION in: " + ", ".join(res["regressions"])
                   if res["regressions"] else "no regressions")
        print(f"bench_check: {verdict} "
              f"({n_ok} ok, {n_skip} skipped, "
              f"{len(res['regressions'])} failed)")
    return 1 if res["regressions"] else 0


if __name__ == "__main__":
    sys.exit(main())
