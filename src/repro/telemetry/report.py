"""Flight-record report tool.

    PYTHONPATH=src python -m repro.telemetry.report run.jsonl [--check]
        [--codes recovery,epoch] [--max-events 40]

Renders the timeline of a JSONL record stream
(:func:`repro.telemetry.export.render_timeline`); ``--check`` additionally
rebuilds the summarize totals from the stream and exits non-zero when they
disagree with the embedded summary record — the CI round-trip smoke.
"""

from __future__ import annotations

import argparse
import sys

from repro.telemetry.export import cross_check, read_jsonl, render_timeline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="JSONL record stream to render")
    ap.add_argument("--check", action="store_true",
                    help="cross-check stream totals against the embedded "
                         "summary record (exit 1 on mismatch)")
    ap.add_argument("--codes", default=None,
                    help="comma-separated event codes to show "
                         "(default: all)")
    ap.add_argument("--max-events", type=int, default=200)
    args = ap.parse_args(argv)

    records = read_jsonl(args.path)
    codes = set(args.codes.split(",")) if args.codes else None
    print(render_timeline(records, codes=codes, max_events=args.max_events))

    if args.check:
        res = cross_check(records)
        status = "OK" if res["ok"] else "MISMATCH"
        print(f"\ncross-check [{status}] kind={res['kind']} "
              f"dropped={res['events_dropped']}")
        for name, c in res.get("checks", {}).items():
            mark = "✓" if c["ok"] else "✗"
            print(f"  {mark} {name:<15} stream={c['stream']:.6g} "
                  f"summary={c['summary']:.6g}")
        if "error" in res:
            print(f"  error: {res['error']}")
        return 0 if res["ok"] else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
