"""Flight-record report tool.

    PYTHONPATH=src python -m repro.telemetry.report run.jsonl [--check]
        [--codes recovery,epoch] [--max-events 40] [--percentiles]
        [--spans trace.json]

Renders the timeline of a JSONL record stream
(:func:`repro.telemetry.export.render_timeline`); ``--check`` additionally
rebuilds the summarize totals from the stream and exits non-zero when they
disagree with the embedded summary record — the CI round-trip smoke.
``--percentiles`` prints the decoded percentile tables of every ``hist``
record in the stream (p50/p95/p99 with error bounds); ``--spans OUT.json``
folds the stream into lifecycle spans and writes Chrome trace-event JSON
(open in Perfetto / ``chrome://tracing``).
"""

from __future__ import annotations

import argparse
import sys

from repro.telemetry.export import cross_check, read_jsonl, render_timeline
from repro.telemetry.spans import spans_from_records, write_chrome_trace


def _print_percentiles(records: list[dict]) -> None:
    hists = [r for r in records if r.get("type") == "hist"]
    if not hists:
        print("\nno hist records in stream (run with "
              "TelemetryConfig(hist=HistogramSpec(...)))")
        return
    for h in hists:
        dim = h.get("dim", "row")
        print(f"\n{h['name']} percentiles (per {dim}, "
              f"±err = one bucket width):")
        for i, row in enumerate(h.get("percentiles", [])):
            name = row.get("name", f"{dim}{i}")
            cells = "  ".join(
                f"{k}={row[k]:.3g}±{row[f'{k}_err']:.2g}"
                for k in sorted(row)
                if k.startswith("p") and not k.endswith("_err")
            )
            print(f"  {name:<16} n={row['count']:.1f}  {cells}")
    for r in records:
        if r.get("type") == "slo":
            print("\nSLO verdicts:")
            for v in r["verdicts"]:
                mark = "PASS" if v["ok"] else "FAIL"
                print(f"  {mark} {v['name']}: p{v['percentile']:g} = "
                      f"{v['estimate']:.3g}±{v['err']:.2g} "
                      f"vs target {v['target']:g}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="JSONL record stream to render")
    ap.add_argument("--check", action="store_true",
                    help="cross-check stream totals against the embedded "
                         "summary record (exit 1 on mismatch)")
    ap.add_argument("--codes", default=None,
                    help="comma-separated event codes to show "
                         "(default: all)")
    ap.add_argument("--max-events", type=int, default=200)
    ap.add_argument("--percentiles", action="store_true",
                    help="print decoded percentile tables from the "
                         "stream's hist records")
    ap.add_argument("--spans", default=None, metavar="OUT.json",
                    help="write lifecycle spans as Chrome trace-event "
                         "JSON to OUT.json")
    args = ap.parse_args(argv)

    records = read_jsonl(args.path)
    codes = set(args.codes.split(",")) if args.codes else None
    print(render_timeline(records, codes=codes, max_events=args.max_events))

    if args.percentiles:
        _print_percentiles(records)

    if args.spans:
        spans = spans_from_records(records)
        write_chrome_trace(spans, args.spans)
        print(f"\nwrote {len(spans)} spans to {args.spans} "
              "(open in Perfetto / chrome://tracing)")

    if args.check:
        res = cross_check(records)
        status = "OK" if res["ok"] else "MISMATCH"
        print(f"\ncross-check [{status}] kind={res['kind']} "
              f"dropped={res['events_dropped']}")
        for name, c in res.get("checks", {}).items():
            mark = "✓" if c["ok"] else "✗"
            print(f"  {mark} {name:<15} stream={c['stream']:.6g} "
                  f"summary={c['summary']:.6g}")
        if "error" in res:
            print(f"  error: {res['error']}")
        return 0 if res["ok"] else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
