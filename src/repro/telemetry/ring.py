"""Fixed-capacity, mask-compacted event ring — jit-safe flight recording.

The recorder that can live inside ``lax.scan`` / ``lax.cond`` bodies: a
static-shape circular buffer carried through the scan, written with masked
dynamic updates (``do`` is a traced bool — no control flow, no shape
change), so recording an event on the rare branch of a ``lax.cond`` costs
a handful of fused ops and recording *nothing* costs the same handful with
the mask low. The ring keeps a monotone push count; host-side
:func:`ring_events` reorders the buffer into push order and reports how
many events fell off the back (capacity overflow is detected, never
silent).

Event payloads are ``N_FIELDS`` float32 lanes whose meaning depends on the
event code — the schema lives with the codes below and is decoded by
:mod:`repro.telemetry.collect`.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np
from jax import Array

#: Payload lanes per event (fixed so the ring's shape is static).
N_FIELDS = 6

# -- event codes ------------------------------------------------------------
#: Off-schedule recovery epoch fired on a site-death edge (placed engine).
#: fields: [recovery_gb, recovery_cost, n_died, first_dead_site, 0, 0]
EV_RECOVERY = 1
#: Slow-loop epoch boundary (placed engine).
#: fields: [wan_gb, wan_cost, sync_cost, churn, budget_use, epoch]
EV_EPOCH = 2
#: GMSA manager-switch edge (derived post-scan from f_trace).
#: fields: [k, from_site, to_site, stage, 0, 0]
EV_SWITCH = 3
#: Ingest aimed at dead sites redirected to survivors (placed engine).
#: fields: [redirected_mass, n_dead, 0, 0, 0, 0]
EV_INGEST_REDIRECT = 4
#: Site revival edge (placed engine) — the companion of EV_RECOVERY; the
#: SLO clock measures recovery from this slot, not the death slot.
#: fields: [n_revived, site, 0, 0, 0, 0]
EV_REPAIR = 5
#: Speculative re-execution fired (staged/serve engines; derived
#: post-scan from the hedge trace). fields: [hedged_jobs, hedge_cost]
EV_HEDGE = 6
#: A WAN link severed (derived from the link-health trace).
#: fields: [src, dst, 0/1 down-edge vs up-edge]
EV_LINK_DOWN = 7

CODE_NAMES = {
    EV_RECOVERY: "recovery",
    EV_EPOCH: "epoch",
    EV_SWITCH: "switch",
    EV_INGEST_REDIRECT: "ingest_redirect",
    EV_REPAIR: "repair",
    EV_HEDGE: "hedge",
    EV_LINK_DOWN: "link_down",
}


class EventRing(NamedTuple):
    """The carried recorder state: (count, t, code, val) — all static shape."""

    count: Array   # ()  int32  total pushes attempted (drops = count - C)
    t: Array       # (C,) int32  slot index of each buffered event
    code: Array    # (C,) int32  event code
    val: Array     # (C, N_FIELDS) float32 payload


class TelemetryFrame(NamedTuple):
    """What an engine returns next to its outputs when telemetry is on.

    ``ring`` holds the in-scan events (empty when the engine records none
    or the level is SUMMARY); ``metrics`` maps stream names to per-slot
    (or per-epoch) arrays — the extra stacked scan outputs and post-scan
    derived streams.
    """

    ring: EventRing
    metrics: dict


def ring_init(capacity: int) -> EventRing:
    """An empty ring of ``capacity`` slots."""
    return EventRing(
        count=jnp.zeros((), jnp.int32),
        t=jnp.full((capacity,), -1, jnp.int32),
        code=jnp.zeros((capacity,), jnp.int32),
        val=jnp.zeros((capacity, N_FIELDS), jnp.float32),
    )


def ring_push(
    ring: EventRing,
    do: Array,
    t: Array,
    code: int,
    fields: Sequence[Array],
) -> EventRing:
    """Record one event iff ``do`` — a masked write, safe anywhere in jit.

    ``do`` is a traced bool scalar; when low, every buffer row keeps its
    old value and the count does not advance, so the no-event path is
    bitwise idempotent on the ring. ``fields`` is up to ``N_FIELDS``
    scalars (zero-padded).
    """
    cap = ring.t.shape[0]
    if len(fields) > N_FIELDS:
        raise ValueError(f"at most {N_FIELDS} payload fields, got {len(fields)}")
    pos = jnp.mod(ring.count, cap)
    row = jnp.zeros((N_FIELDS,), jnp.float32)
    if fields:
        row = row.at[: len(fields)].set(
            jnp.stack([jnp.asarray(f, jnp.float32) for f in fields])
        )
    do = jnp.asarray(do, bool)
    return EventRing(
        count=ring.count + do.astype(jnp.int32),
        t=ring.t.at[pos].set(jnp.where(do, jnp.asarray(t, jnp.int32), ring.t[pos])),
        code=ring.code.at[pos].set(
            jnp.where(do, jnp.int32(code), ring.code[pos])
        ),
        val=ring.val.at[pos].set(jnp.where(do, row, ring.val[pos])),
    )


def empty_frame() -> TelemetryFrame:
    """A frame with a zero-capacity ring — engines that derive all events."""
    return TelemetryFrame(ring=ring_init(1), metrics={})


def ring_events(ring: EventRing) -> tuple[list[dict], int]:
    """Host-side decode: buffered events in push order + dropped count.

    Returns ``(events, dropped)`` where each event is
    ``{"t": int, "code": int, "val": np.ndarray(N_FIELDS,)}`` and
    ``dropped`` counts pushes that fell off the back of the ring.
    """
    count = int(np.asarray(ring.count))
    cap = ring.t.shape[0]
    n = min(count, cap)
    dropped = count - n
    idx = (count - n + np.arange(n)) % cap
    t = np.asarray(ring.t)[idx]
    code = np.asarray(ring.code)[idx]
    val = np.asarray(ring.val)[idx]
    return (
        [{"t": int(t[i]), "code": int(code[i]), "val": val[i]} for i in range(n)],
        dropped,
    )
