"""Exporters: JSONL event logs, text timelines, and the summary cross-check.

The cross-check is the telemetry layer's own regression: the event/metric
stream must carry enough information to rebuild the engine's
``summarize_*`` totals — dispatch/compute cost from the per-slot metric
stream, WAN + sync from the epoch events, recovery cost/GB from the
recovery events — to float tolerance. A stream that dropped ring events
(capacity overflow) is refused outright: a flight recorder that lost
frames cannot certify anything.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

_BLOCKS = " ▁▂▃▄▅▆▇█"


def write_jsonl(records: list[dict], path) -> pathlib.Path:
    """Write one record per line; parents created; returns the path."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as fh:
        for rec in records:
            fh.write(json.dumps(rec) + "\n")
    return path


def read_jsonl(path) -> list[dict]:
    """Read a JSONL record stream back into a list of dicts."""
    with pathlib.Path(path).open() as fh:
        return [json.loads(line) for line in fh if line.strip()]


def _by_type(records: list[dict]) -> dict[str, list[dict]]:
    out: dict[str, list[dict]] = {}
    for rec in records:
        out.setdefault(rec.get("type", "?"), []).append(rec)
    return out


def sparkline(values, width: int = 60) -> str:
    """Downsampled unicode sparkline of a 1-D series.

    When downsampling leaves a bin empty (integer edges can collide for
    ``size`` barely above ``width``), the bin carries the PREVIOUS bin's
    mean — a flat continuation — rather than duplicating whatever sample
    sits at the collision index, which would invent a spike out of a
    value the bin never contained. An all-constant series renders as the
    lowest visible block (never blank).
    """
    v = np.asarray(values, np.float64)
    if v.size == 0:
        return ""
    if v.size > width:
        edge = np.linspace(0, v.size, width + 1).astype(int)
        bins, prev = [], float(v[0])
        for a, b in zip(edge[:-1], edge[1:]):
            if b > a:
                prev = float(v[a:b].mean())
            bins.append(prev)
        v = np.asarray(bins)
    lo, hi = float(v.min()), float(v.max())
    if hi == lo:
        return _BLOCKS[1] * v.size
    idx = ((v - lo) / (hi - lo) * (len(_BLOCKS) - 1)).round().astype(int)
    return "".join(_BLOCKS[i] for i in idx)


def _fmt_event(ev: dict) -> str:
    code = ev.get("code", "?")
    if code == "recovery":
        tts = ev.get("time_to_slo")
        tts_s = f"{tts} slots" if tts is not None else "never (horizon)"
        return (f"death edge ▸ site {ev.get('site', ev.get('pod'))} "
                f"({ev.get('n_died')} died)  evacuated "
                f"{ev.get('recovery_gb', 0.0):.1f} GB  "
                f"${ev.get('recovery_cost', 0.0):.2f}  time-to-SLO {tts_s}")
    if code == "epoch":
        return (f"epoch {ev.get('epoch')}  moved {ev.get('wan_gb', 0.0):.1f} GB "
                f"(${ev.get('wan_cost', 0.0):.2f})  sync "
                f"${ev.get('sync_cost', 0.0):.2f}  churn "
                f"{ev.get('churn', 0.0):.3f} "
                f"(budget use {100 * ev.get('budget_use', 0.0):.0f}%)")
    if code == "switch":
        stage = f" s{ev['stage']}" if "stage" in ev else ""
        return (f"manager switch k{ev.get('k')}{stage}: "
                f"site {ev.get('src')} → {ev.get('dst')}")
    if code == "ingest_redirect":
        return (f"ingest redirect: {ev.get('redirected_mass', 0.0):.3f} mass "
                f"off {ev.get('n_dead')} dead site(s)")
    return json.dumps(ev)


def render_timeline(
    records: list[dict],
    *,
    codes: set[str] | None = None,
    max_events: int = 200,
    width: int = 60,
) -> str:
    """Human-readable flight-record timeline.

    ``codes`` filters the event stream (e.g. ``{"recovery", "epoch"}``);
    the backlog/cost sparklines come from the metric stream when present.
    """
    by = _by_type(records)
    meta = by.get("meta", [{}])[0]
    lines = [
        f"flight record · engine={meta.get('kind', '?')} "
        f"T={meta.get('t_slots', '?')} level={meta.get('level', '?')} "
        f"dropped_events={meta.get('events_dropped', 0)}"
    ]
    metrics = by.get("metric", [])
    if metrics:
        lines.append(
            "  cost    " + sparkline([m["cost"] for m in metrics], width)
        )
        lines.append(
            "  backlog " + sparkline([m["backlog"] for m in metrics], width)
        )
    events = by.get("event", [])
    if codes is not None:
        events = [e for e in events if e.get("code") in codes]
    shown = events[:max_events]
    for ev in shown:
        lines.append(f"  t={ev.get('t', -1):>5}  {_fmt_event(ev)}")
    if len(events) > len(shown):
        lines.append(f"  … {len(events) - len(shown)} more events")
    summ = by.get("summary", [])
    if summ:
        s = summ[0]
        keys = [k for k in s if k.startswith(("time_avg_", "total_"))]
        lines.append("  summary: " + "  ".join(
            f"{k}={s[k]:.4g}" for k in sorted(keys)
        ))
    return "\n".join(lines)


def cross_check(records: list[dict], rtol: float = 1e-5) -> dict:
    """Rebuild the ``summarize_*`` totals from the stream and compare.

    Returns ``{"ok": bool, "kind": ..., "checks": {name: {"stream": x,
    "summary": y, "ok": bool}}, "events_dropped": int}``. Requires the
    stream to contain a ``summary`` record and per-slot metrics. Dropped
    ring events fail the check unconditionally.
    """
    by = _by_type(records)
    meta = by.get("meta", [{}])[0]
    kind = meta.get("kind", "sim")
    t_slots = meta.get("t_slots")
    summary = (by.get("summary") or [None])[0]
    metrics = by.get("metric", [])
    events = by.get("event", [])
    out = {"ok": True, "kind": kind,
           "events_dropped": int(meta.get("events_dropped", 0)),
           "checks": {}}
    if summary is None or not metrics or t_slots is None:
        out["ok"] = False
        out["error"] = "stream lacks summary/metric records"
        return out
    if out["events_dropped"]:
        out["ok"] = False
        out["error"] = f"{out['events_dropped']} ring events dropped"

    def check(name: str, stream_val: float, summary_key: str):
        ref = summary.get(summary_key)
        if ref is None:
            return
        ok = bool(np.isclose(stream_val, ref, rtol=rtol, atol=1e-6))
        out["checks"][name] = {
            "stream": float(stream_val), "summary": float(ref), "ok": ok,
        }
        out["ok"] = out["ok"] and ok

    cost = float(np.sum([m["cost"] for m in metrics])) / t_slots
    if kind == "placed":
        wan = sum(e.get("wan_cost", 0.0) for e in events
                  if e.get("code") == "epoch") / t_slots
        sync = sum(e.get("sync_cost", 0.0) for e in events
                   if e.get("code") == "epoch") / t_slots
        rec = sum(e.get("recovery_cost", 0.0) for e in events
                  if e.get("code") == "recovery") / t_slots
        rec_gb = sum(e.get("recovery_gb", 0.0) for e in events
                     if e.get("code") == "recovery")
        check("dispatch_cost", cost, "time_avg_dispatch_cost")
        check("wan_cost", wan, "time_avg_wan_cost")
        check("sync_cost", sync, "time_avg_sync_cost")
        check("recovery_cost", rec, "time_avg_recovery_cost")
        check("recovery_gb", rec_gb, "total_recovery_gb")
        check("total_cost", cost + wan + sync + rec, "time_avg_total_cost")
    elif kind == "staged":
        wan = float(np.sum([m.get("wan_cost", 0.0) for m in metrics])) / t_slots
        wan_gb = float(np.sum([m.get("wan_gb", 0.0) for m in metrics]))
        hedge = sum(e.get("hedge_cost", 0.0) for e in events
                    if e.get("code") == "hedge") / t_slots
        check("compute_cost", cost, "time_avg_compute_cost")
        check("wan_cost", wan, "time_avg_wan_cost")
        check("wan_gb", wan_gb, "total_wan_gb")
        check("hedge_cost", hedge, "time_avg_hedge_cost")
        check("total_cost", cost + wan + hedge, "time_avg_total_cost")
    else:
        check("cost", cost, "time_avg_cost")
    return out
