"""Bandwidth-aware task placement in the style of Iridium [Pu et al., SIGCOMM'15].

The paper generates its task-allocation ratios ``r`` with Iridium: place the
reduce tasks of a geo-distributed job so the *bottleneck* inter-site transfer
time is minimized, given per-site up/down bandwidths and the distribution of
intermediate data.

For one job with intermediate data of total size ``S``, a fraction ``d_j``
of it residing at site j, uplink ``U_j`` and downlink ``D_j``, a reduce
placement ``r`` (fractions of reduce tasks per site) induces transfer times

    T_up(j)   = (1 - r_j) * d_j * S / U_j      (j's data shipped to remote reducers)
    T_down(j) = r_j * (1 - d_j) * S / D_j      (remote data pulled to j's reducers)

Iridium's placement LP is  min_r max_j max(T_up(j), T_down(j)) s.t. r in simplex.
For a fixed bottleneck ``z`` the feasible set is a box
``lo_j(z) <= r_j <= hi_j(z)`` intersected with the simplex, so the optimum is
found by bisection on ``z`` — fully vectorized and jit-safe here (fixed
iteration count), vmappable over job types.

``build_task_allocation`` assembles the paper's (K, N, N) manager-conditioned
ratio tensor by combining data-local map work, Iridium-placed reduce work and
a manager-local aggregation share.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array, lax, vmap

_BISECT_ITERS = 50
_EPS = 1e-12


def _bounds(z: Array, d: Array, up: Array, down: Array, size: Array):
    """Per-site feasible box [lo, hi] for reduce fractions at bottleneck z."""
    hi = jnp.where(d < 1.0, z * down / jnp.maximum((1.0 - d) * size, _EPS), jnp.inf)
    lo = jnp.where(d > 0.0, 1.0 - z * up / jnp.maximum(d * size, _EPS), 0.0)
    lo = jnp.maximum(lo, 0.0)
    return lo, hi


def _feasible(z: Array, d: Array, up: Array, down: Array, size: Array) -> Array:
    lo, hi = _bounds(z, d, up, down, size)
    return (
        (jnp.sum(lo) <= 1.0 + 1e-9)
        & (jnp.sum(jnp.minimum(hi, 1.0)) >= 1.0 - 1e-9)
        & jnp.all(lo <= hi + 1e-9)
    )


def iridium_reduce_placement(
    d: Array, up: Array, down: Array, size: float | Array = 1.0
) -> tuple[Array, Array]:
    """Bottleneck-minimizing reduce placement for one job type.

    Args:
        d: (N,) fractions of intermediate data per site (sums to 1).
        up: (N,) uplink bandwidths (bytes/s — any consistent unit).
        down: (N,) downlink bandwidths.
        size: total intermediate data size (same unit-seconds as bandwidths).

    Returns:
        (r, z): (N,) reduce fractions in the simplex, and the achieved
        bottleneck transfer time z*.
    """
    d = jnp.asarray(d, jnp.float32)
    size = jnp.asarray(size, jnp.float32)
    # Upper bound: put everything on one site through the slowest links.
    z_hi0 = size * (1.0 / jnp.min(up) + 1.0 / jnp.min(down))

    def body(carry, _):
        z_lo, z_hi = carry
        mid = 0.5 * (z_lo + z_hi)
        ok = _feasible(mid, d, up, down, size)
        return (jnp.where(ok, z_lo, mid), jnp.where(ok, mid, z_hi)), None

    (z_lo, z_hi), _ = lax.scan(body, (jnp.float32(0.0), z_hi0), None, length=_BISECT_ITERS)
    z = z_hi
    lo, hi = _bounds(z, d, up, down, size)
    hi = jnp.minimum(hi, 1.0)
    # Distribute the remaining simplex mass proportionally to box headroom.
    slack = jnp.maximum(hi - lo, 0.0)
    missing = jnp.maximum(1.0 - jnp.sum(lo), 0.0)
    share = jnp.where(jnp.sum(slack) > _EPS, slack / jnp.maximum(jnp.sum(slack), _EPS), 0.0)
    r = lo + missing * share
    r = r / jnp.maximum(jnp.sum(r), _EPS)   # numeric cleanup onto simplex
    return r, z


def build_task_allocation(
    data_dist: Array,
    up: Array,
    down: Array,
    size: float | Array = 1.0,
    manager_share: float = 0.3,
    map_share: float = 0.6,
) -> Array:
    """Assemble the (K, N, N) manager-conditioned task-allocation ratios.

    When DC i manages a type-k job, the job's compute splits into:
      * a manager-local coordination/aggregation share (``manager_share``) at i,
      * data-local map work (fraction ``map_share`` of the remainder) placed
        proportionally to the type-k dataset distribution,
      * Iridium-placed reduce work (the rest) at the bottleneck-minimizing
        placement for the type-k intermediate data.

    Args:
        data_dist: (K, N) per-type dataset distribution (rows sum to 1).
        up/down: (N,) site bandwidths.
        size: intermediate data size per job.
        manager_share: fraction of per-job work pinned to the manager site.
        map_share: of the non-manager work, the data-local (map) fraction.

    Returns:
        (K, N, N) row-stochastic-over-last-axis ratio tensor r[k, i, j].
    """
    data_dist = jnp.asarray(data_dist, jnp.float32)
    k_types, n = data_dist.shape
    reduce_r, _ = vmap(lambda dk: iridium_reduce_placement(dk, up, down, size))(data_dist)
    base = map_share * data_dist + (1.0 - map_share) * reduce_r          # (K, N)
    eye = jnp.eye(n, dtype=jnp.float32)
    r = manager_share * eye[None, :, :] + (1.0 - manager_share) * base[:, None, :]
    return r


def make_allocation_rebuilder(
    up: Array,
    down: Array,
    size: float | Array = 1.0,
    manager_share: float = 0.3,
    map_share: float = 0.6,
):
    """Bind the static placement parameters into a ``data_dist -> r`` closure.

    The returned function is pure jnp (bisection with a fixed iteration
    count), so the slow-timescale placement controller
    (:mod:`repro.placement.controller`) can call it *inside* a jitted
    ``lax.scan`` to re-derive the (K, N, N) ratio tensor every epoch as the
    dataset distribution evolves — the same math `build_task_allocation`
    runs once at trace-build time today.
    """
    up = jnp.asarray(up, jnp.float32)
    down = jnp.asarray(down, jnp.float32)

    def rebuild(data_dist: Array) -> Array:
        return build_task_allocation(
            data_dist, up, down,
            size=size, manager_share=manager_share, map_share=map_share,
        )

    return rebuild
