"""Queueing law of the GDA service engine (paper Eq. 1).

Each global-manager DC maintains one queue of unfinished jobs per job type.
Per slot, the backlog evolves as

    Q_i^k(t+1) = max[ Q_i^k(t) + f_i^k(t) A^k(t) - mu_i^k(t), 0 ].

All functions are pure, jit-safe, and operate on the shared (N, K) layout
documented in :mod:`repro.core`.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array


def queue_step(q: Array, f: Array, arrivals: Array, mu: Array) -> Array:
    """One application of the queueing law (Eq. 1).

    Args:
        q: (N, K) current backlogs.
        f: (N, K) dispatch fractions for this slot (columns sum to 1).
        arrivals: (K,) job arrivals A^k(t) in this slot.
        mu: (N, K) service rates mu_i^k(t) in this slot.

    Returns:
        (N, K) backlogs at the start of slot t+1.
    """
    return jnp.maximum(q + f * arrivals[None, :] - mu, 0.0)


def total_backlog(q: Array) -> Array:
    """Aggregate backlog sum_{i,k} Q_i^k — the quantity bounded by Eq. 2."""
    return jnp.sum(q)


def average_backlog(q: Array) -> Array:
    """Per-(DC, type) mean backlog — the y-axis of the paper's Fig. 5(b)/6(b)."""
    return jnp.mean(q)


def lyapunov(q: Array) -> Array:
    """Quadratic Lyapunov function L(t) = 1/2 * sum_{i,k} Q_i^k(t)^2."""
    return 0.5 * jnp.sum(jnp.square(q))
