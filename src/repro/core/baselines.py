"""Baseline global-manager-selection strategies (paper Sec. V-A).

The paper compares GMSA against:

* **DATA**   — the fraction of type-k jobs dispatched to DC i is proportional
  to the fraction of the type-k dataset stored at DC i.
* **RANDOM** — every job picks its manager uniformly at random. At the slot
  level with ``A^k(t)`` integral arrivals this is a multinomial split; we
  sample it exactly so small-A slots show the correct variance.

Two extra references (not in the paper, used for ablations in EXPERIMENTS.md):

* **JSQ**    — join-the-shortest-queue: all type-k jobs to argmin_i Q_i^k.
  Isolates the "drift-only" half of GMSA (V = 0).
* **GREEDY-COST** — all type-k jobs to argmin_i e_i^k. The V -> inf limit of
  GMSA; minimizes instantaneous cost with no regard for stability.

All policies share the simulator signature
``(key, q, arrivals, mu, e, aux) -> f`` where ``aux`` carries the (K, N)
dataset distribution (used only by DATA).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array
from jax.nn import one_hot

# Static upper bound on per-slot arrivals of one job type; the exact
# multinomial sampler draws this many candidate picks and masks the tail.
# Configs assert A_max <= MAX_SLOT_ARRIVALS.
MAX_SLOT_ARRIVALS = 512


def data_dispatch(key, q: Array, arrivals: Array, mu: Array, e: Array, aux: Array, scalar=0.0) -> Array:
    """DATA baseline: f[i, k] = dataset_fraction[k, i]."""
    del key, q, arrivals, mu, e, scalar
    return aux.T  # (K, N) -> (N, K)


data_dispatch.state_independent = True
data_dispatch.consumes_key = False


def random_dispatch(key, q: Array, arrivals: Array, mu: Array, e: Array, aux: Array, scalar=0.0) -> Array:
    """RANDOM baseline: exact multinomial split of each slot's arrivals.

    For a slot with A^k jobs, each job independently picks one of N managers
    uniformly; f_i^k is the realized fraction. Empty slots (A^k = 0) fall back
    to the uniform vector (the choice is irrelevant since f multiplies A).
    """
    del mu, e, aux, scalar
    n, k_types = q.shape
    keys = jax.random.split(key, k_types)
    counts = jax.vmap(lambda kk, a: _multinomial_uniform(kk, a, n))(
        keys, arrivals
    )                                                    # (K, N)
    denom = jnp.maximum(arrivals[:, None], 1.0)
    frac = jnp.where(arrivals[:, None] > 0, counts / denom, 1.0 / n)
    return frac.T                                        # (N, K)


def _multinomial_uniform(key, count: Array, n: int) -> Array:
    """Exact Multinomial(count, uniform-over-n) with a static draw budget.

    Draws ``MAX_SLOT_ARRIVALS`` uniform categorical picks, masks picks beyond
    ``count`` into a scratch category, and histograms. jit-safe: all shapes
    static, ``count`` may be a traced (integral-valued) scalar.
    """
    picks = jax.random.randint(key, (MAX_SLOT_ARRIVALS,), 0, n)
    idx = jnp.arange(MAX_SLOT_ARRIVALS)
    masked = jnp.where(idx < count, picks, n)            # overflow bucket n
    hist = jnp.sum(one_hot(masked, n + 1, dtype=jnp.float32), axis=0)
    return hist[:n]


random_dispatch.state_independent = True


def jsq_dispatch(key, q: Array, arrivals: Array, mu: Array, e: Array, aux: Array, scalar=0.0) -> Array:
    """Join-the-shortest-queue (drift-only; GMSA with V = 0)."""
    del key, arrivals, mu, e, aux, scalar
    best = jnp.argmin(q, axis=0)                      # (K,)
    return one_hot(best, q.shape[0], dtype=q.dtype).T


jsq_dispatch.consumes_key = False


def greedy_cost_dispatch(key, q: Array, arrivals: Array, mu: Array, e: Array, aux: Array, scalar=0.0) -> Array:
    """Greedy instantaneous-cost minimizer (GMSA's V -> inf limit)."""
    del key, arrivals, mu, aux, scalar
    best = jnp.argmin(e, axis=1)                      # (K,)
    return one_hot(best, q.shape[0], dtype=q.dtype).T


greedy_cost_dispatch.state_independent = True
greedy_cost_dispatch.consumes_key = False


def static_placement_rule(d: Array, obs) -> Array:
    """STATIC-PLACEMENT baseline for the two-timescale controller.

    Never re-places: the dataset layout stays wherever the trace (initial
    Dirichlet draw + any exogenous ingest drift) puts it, exactly the frozen
    ``data_dist`` assumption of the base paper. Plugs into
    :func:`repro.placement.controller.simulate_placed` as the ``rule``
    operand; the adaptive counterpart is
    :func:`repro.placement.replica.make_adaptive_rule`.

    Survivor-aware: when the controller reports dead sites through
    ``obs.alive``, the layout renormalizes over the survivors (``drop_site``
    semantics — a static placement cannot keep data at a site that no
    longer exists), but it still never *optimizes*. With every site alive
    the input ``d`` is returned untouched, bit for bit.
    """
    alive = getattr(obs, "alive", None)
    if alive is None:
        return d
    alive = jnp.asarray(alive, d.dtype)
    masked = d * alive[None, :]
    held = jnp.sum(masked, axis=1, keepdims=True)
    n_alive = jnp.maximum(jnp.sum(alive), 1.0)
    uniform = jnp.broadcast_to(alive / n_alive, masked.shape)
    dropped = jnp.where(held > 1e-9, masked / jnp.maximum(held, 1e-9), uniform)
    return jnp.where(jnp.any(alive < 0.5), dropped, d)
