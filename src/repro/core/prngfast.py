"""Unrolled threefry lowering on CPU (EXPERIMENTS.md §Perf v6).

jax lowers ``threefry2x32`` — the bit generator behind every
``jax.random`` call — as a *rolled* ``fori_loop`` over the 5 round-groups
on CPU (a compile-size tradeoff) and *unrolled* everywhere else. Both
lowerings compute the identical function (bitwise-equal streams — pinned
in tests/test_simulator.py), but on the CPU thunk executor the rolled
form costs a full while-loop execution (~5 x several kernel launches) per
``random.uniform`` / ``random.split`` call, which dominated the
Monte-Carlo trace builds (~25% of a simulated run).

:func:`enable_unrolled_threefry_cpu` re-registers jax's own unrolled rule
for the CPU platform — no custom math, just the other of jax's two
lowerings, ~4x faster bit generation here. Called at ``repro`` import;
set ``REPRO_ROLLED_THREEFRY=1`` to keep jax's default, and any failure to
reach the (internal, version-pinned: jax 0.4.37 in CI) registration APIs
degrades silently to that default.
"""

from __future__ import annotations

import os

_INSTALLED = False


def enable_unrolled_threefry_cpu() -> bool:
    """Swap CPU threefry to jax's unrolled lowering. Returns success."""
    global _INSTALLED
    if _INSTALLED:
        return True
    if os.environ.get("REPRO_ROLLED_THREEFRY"):
        return False
    try:
        from jax._src import prng as _prng
        from jax._src.interpreters import mlir as _mlir

        _mlir.register_lowering(
            _prng.threefry2x32_p,
            _prng._threefry2x32_lowering_rule,   # the unrolled rule
            platform="cpu",
        )
        _INSTALLED = True
        return True
    except Exception:  # pragma: no cover - newer jax moved the internals
        return False
