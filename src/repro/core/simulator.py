"""Time-slotted trace-driven simulator (paper Sec. V).

One simulation run replays T slots:

    observe (A(t), Q(t), mu(t), omega(t), PUE(t))
      -> policy picks f(t)                       (GMSA / DATA / RANDOM / ...)
      -> Cost(t) accrues                          (repro.core.energy)
      -> queues update by Eq. 1                   (repro.core.queues)

The whole run is a single ``jax.lax.scan`` (jit-compiled); Monte-Carlo
replication is a ``jax.vmap`` over PRNG keys (the paper averages 1000 runs).
Policies are closures with signature
``(key, q, arrivals, mu, e, aux, scalar) -> f`` so GMSA and every baseline
share one engine; ``scalar`` carries a *traced* control parameter (GMSA's V)
so parameter sweeps reuse one compilation.

Perf notes (EXPERIMENTS.md §Perf wall-clock track):
  * the (K,N,N)×(N,) energy matvec is hoisted out of the scan body and
    computed for all T slots in one einsum — and it is *closed over* rather
    than vmapped, so Monte-Carlo runs share it;
  * policies that declare ``state_independent = True`` (DATA, RANDOM) are
    evaluated for all slots in one vectorized pass outside the scan;
  * policies that declare ``consumes_key = False`` (GMSA, JSQ, GREEDY —
    anything that deletes its key) skip the per-slot PRNG split entirely;
  * the per-slot body is then 4 fused elementwise/contraction ops.

Policies that declare ``wants_wpue = True`` receive ``aux = (data_dist,
omega_t * pue_t)`` instead of the bare distribution — the hook the fused
Pallas dispatch path (:func:`repro.core.gmsa.make_kernel_policy`) uses to
see raw per-slot prices; the product is hoisted out of the scan body.
Policies that additionally declare ``wants_r = True`` get the per-slot
ratio tensor appended — ``aux = (data_dist, wpue_t, r_t)`` — so the kernel
dispatch path sees time-varying ``(T, K, N, N)`` ratio traces instead of a
stale static binding; a policy marked ``static_r = True`` fed a
time-varying trace raises instead of silently dispatching on stale ratios.

Monte-Carlo replication shards across devices when ``simulate_many`` is
given a ``mesh`` (:func:`repro.distributed.mesh.runs_mesh`): the runs axis
partitions over the mesh with ``shard_map``, bitwise-identical to the
single-device vmap at every device count.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.energy import manager_energy, manager_energy_cost
from repro.core.queues import queue_step
from repro.telemetry.config import TelemetryConfig
from repro.telemetry.config import enabled as _tel_enabled
from repro.telemetry.config import histograms as _tel_hist
from repro.telemetry.metrics import hist_series
from repro.telemetry.ring import TelemetryFrame, ring_init


class SimInputs(NamedTuple):
    """Trace bundle for one simulation run.

    Shapes: T slots, N DCs, K job types.

    ``r`` and ``data_dist`` may carry a leading time axis — (T, K, N, N) and
    (T, K, N) respectively — when the placement layer
    (:mod:`repro.placement`) evolves the dataset layout over the horizon;
    the static (K, N, N) / (K, N) forms remain the common case and are
    broadcast over all slots.
    """

    arrivals: Array   # (T, K)   jobs arriving per slot
    mu: Array         # (T, N, K) service rates per slot
    omega: Array      # (T, N)   energy-price weights
    pue: Array        # (T, N)   PUE traces
    r: Array          # (K, N, N) or (T, K, N, N) task-allocation ratios
    p_it: Array       # (K,)     per-job IT energy
    data_dist: Array  # (K, N) or (T, K, N) dataset distribution (policy aux)


class SimOutputs(NamedTuple):
    cost: Array           # (T,) per-slot energy cost
    energy: Array         # (T,) per-slot energy (PUE-weighted, unpriced)
    backlog_total: Array  # (T,) sum of all queue backlogs
    backlog_avg: Array    # (T,) mean backlog per (DC, type)
    q_final: Array        # (N, K)
    f_trace: Array        # (T, N, K) dispatch decisions


PolicyFn = Callable[..., Array]


def energy_tables(
    r: Array, wpue: Array, pue: Array, p_it: Array
) -> tuple[Array, Array]:
    """(T,K,N) dispatch cost and raw-energy tables in one einsum each.

    The single definition of the per-slot energy accounting, shared by
    ``simulate`` and the placement controller's per-epoch tables (the other
    half of the structural equivalence alongside :func:`slot_step`).
    ``r`` is (K, N, N) broadcast over slots, or (T, K, N, N) time-varying;
    ``wpue`` / ``pue`` are (T, N).
    """
    if r.ndim == 4:
        e_cost = jnp.einsum("tkij,tj->tki", r, wpue)
        e_raw = jnp.einsum("tkij,tj->tki", r, pue)
    else:
        e_cost = jnp.einsum("kij,tj->tki", r, wpue)
        e_raw = jnp.einsum("kij,tj->tki", r, pue)
    return e_cost * p_it[None, :, None], e_raw * p_it[None, :, None]


def energy_row(
    r: Array, wpue_t: Array, pue_t: Array, p_it: Array
) -> tuple[Array, Array]:
    """(K, N) dispatch cost and raw-energy tables for ONE slot.

    The per-slot form of :func:`energy_tables`, for control loops whose
    ratio tensor changes *inside* an epoch — the placement controller's
    off-schedule recovery epochs invalidate the precomputed epoch tables,
    and re-derive each remaining slot's row from the carried ``r``.
    """
    e_cost = jnp.einsum("kij,j->ki", r, wpue_t)
    e_raw = jnp.einsum("kij,j->ki", r, pue_t)
    return e_cost * p_it[:, None], e_raw * p_it[:, None]


def _energy_tables(inputs: SimInputs) -> tuple[Array, Array]:
    """(T,K,N) cost and raw-energy tables for every slot of a trace bundle."""
    return energy_tables(
        inputs.r, inputs.omega * inputs.pue, inputs.pue, inputs.p_it
    )


def slot_step(
    q: Array, f: Array, arrivals: Array, mu: Array, e_cost: Array, e_raw: Array
) -> tuple[Array, tuple]:
    """Advance one slot under dispatch ``f``: accrue cost/energy, step queues.

    The single definition of the per-slot semantics, shared by ``simulate``
    and the placement controller's fast loop (so their W >= T bit-exact
    equivalence is structural, not just test-enforced). Returns
    ``(q_next, (cost, energy, backlog_total, backlog_avg, f))`` — the scan
    output contract behind ``SimOutputs``' per-slot columns.

    Callers feeding this body masked inputs (the controller's fault path)
    must mask with exact identities (``* 1.0``, ``+ 0.0``) or selects —
    see ``drop_site_mask`` — so that bitwise-equal inputs keep producing
    bitwise-equal outputs under XLA's fusion choices.
    """
    fa = f * arrivals[None, :]
    cost = jnp.sum(fa * e_cost.T)
    energy = jnp.sum(fa * e_raw.T)
    q_next = queue_step(q, f, arrivals, mu)
    return q_next, (cost, energy, jnp.sum(q_next), jnp.mean(q_next), f)


@functools.partial(jax.jit, static_argnames=("policy", "telemetry"))
def simulate(
    inputs: SimInputs,
    policy: PolicyFn,
    key: Array,
    scalar: float | Array = 0.0,
    telemetry: TelemetryConfig | None = None,
    health: Array | None = None,
) -> SimOutputs | tuple[SimOutputs, TelemetryFrame]:
    """Run one trace-driven simulation under ``policy``.

    ``telemetry`` is **static**: ``None``/``OFF`` (default) traces to the
    byte-identical jaxpr of the pre-telemetry engine (pinned in tests);
    SUMMARY/TRACE adds a per-slot per-site backlog stream as an extra
    stacked scan output and returns ``(outputs, TelemetryFrame)`` —
    manager-switch events are derived post-scan from ``f_trace`` by
    :func:`repro.telemetry.collect.switch_events`, so this engine records
    nothing inside the scan body beyond the metric stream.

    ``health`` is an optional (T, N) degraded-mode factor
    (:func:`repro.traces.faults.health_trace`): per-slot service rates
    scale as ``mu * health`` — 0 = dead, interior = straggler — applied
    once *before* the scan (hoisted into the trace bundle, zero extra
    ops in the scan body). ``None`` leaves the engine's jaxpr untouched,
    and an all-ones trace is an exact ``* 1.0`` identity, so the
    degraded path is bitwise the nominal path when nothing degrades.
    """
    tel_on = _tel_enabled(telemetry)
    if health is not None:
        inputs = inputs._replace(
            mu=inputs.mu * jnp.asarray(health, inputs.mu.dtype)[:, :, None]
        )
    t_slots, k_types = inputs.arrivals.shape
    n = inputs.mu.shape[1]
    q0 = jnp.zeros((n, k_types), jnp.float32)
    e_cost_all, e_raw_all = _energy_tables(inputs)                 # (T, K, N)
    scalar = jnp.asarray(scalar, jnp.float32)

    dd_varying = inputs.data_dist.ndim == 3                        # (T, K, N)
    r_varying = inputs.r.ndim == 4                              # (T, K, N, N)
    uses_key = getattr(policy, "consumes_key", True)
    wants_wpue = getattr(policy, "wants_wpue", False)
    wants_r = getattr(policy, "wants_r", False)
    if r_varying and getattr(policy, "static_r", False):
        raise ValueError(
            "policy binds a static (K, N, N) ratio tensor but inputs.r is "
            "time-varying (T, K, N, N) — the kernel would silently dispatch "
            "on stale ratios. Build it with make_kernel_policy(r=None) so "
            "the per-slot r reaches the kernel through the policy aux."
        )
    if wants_r and not wants_wpue:
        raise ValueError(
            "wants_r policies must also declare wants_wpue: the aux "
            "contract is (data_dist, wpue_t, r_t)"
        )
    wpue_all = inputs.omega * inputs.pue if wants_wpue else None

    f_all = None
    if getattr(policy, "state_independent", False):
        keys = jax.random.split(key, t_slots)

        def call(kk, a, m, e, d, w, rr):
            aux = d
            if wants_wpue:
                aux = (aux, w)
            if wants_r:
                aux = aux + (rr,)
            return policy(kk, q0, a, m, e, aux, scalar)

        f_all = jax.vmap(
            call,
            in_axes=(0, 0, 0, 0, 0 if dd_varying else None,
                     0 if wants_wpue else None,
                     0 if r_varying else None),
        )(keys, inputs.arrivals, inputs.mu, e_cost_all,
          inputs.data_dist, wpue_all,
          inputs.r if wants_r else None)                           # (T, N, K)

    # The PRNG key rides in the scan carry ONLY when the policy actually
    # consumes it — for key-ignoring policies the per-slot threefry split
    # (and the whole key chain) disappears from the compiled body.
    keyed = f_all is None and uses_key
    key0 = key   # signature filler for key-ignoring policies (never used)

    def slot(carry, xs):
        q, key = carry if keyed else (carry, None)
        if wants_r and r_varying:
            xs, r_t = xs[:-1], xs[-1]
        if wants_wpue:
            xs, wpue_t = xs[:-1], xs[-1]
        if dd_varying:
            xs, aux = xs[:-1], xs[-1]
        else:
            aux = inputs.data_dist
        if wants_wpue:
            aux = (aux, wpue_t)
        if wants_r:
            aux = aux + ((r_t if r_varying else inputs.r),)
        if f_all is None:
            arrivals, mu, e_cost, e_raw = xs
            if keyed:
                key, sub = jax.random.split(key)
            else:
                sub = key0
            f = policy(sub, q, arrivals, mu, e_cost, aux, scalar)
        else:
            arrivals, mu, e_cost, e_raw, f = xs
        q_next, out = slot_step(q, f, arrivals, mu, e_cost, e_raw)
        if tel_on:
            out = out + (jnp.sum(q_next, axis=-1),)       # (N,) per-site q
        return ((q_next, key) if keyed else q_next), out

    xs = (inputs.arrivals, inputs.mu, e_cost_all, e_raw_all)
    if f_all is not None:
        xs = xs + (f_all,)
    if dd_varying:
        xs = xs + (inputs.data_dist,)
    if wants_wpue:
        xs = xs + (wpue_all,)
    if wants_r and r_varying:
        xs = xs + (inputs.r,)
    carry0 = (q0, key) if keyed else q0
    final_carry, scan_outs = jax.lax.scan(slot, carry0, xs)
    if tel_on:
        (cost, energy, btot, bavg, f_trace, q_site) = scan_outs
    else:
        (cost, energy, btot, bavg, f_trace) = scan_outs
    q_final = final_carry[0] if keyed else final_carry
    outs = SimOutputs(cost, energy, btot, bavg, q_final, f_trace)
    if tel_on:
        metrics = {"q_site": q_site}
        if _tel_hist(telemetry):
            # Per-site energy-cost distribution, derived post-scan from
            # the stacked dispatch trace (zero ops in the scan body): the
            # per-slot (N,) site bill is sum_k (f·A) * e_cost, the same
            # contraction ``slot_step`` sums globally.
            site_cost = jnp.einsum(
                "tnk,tk,tkn->tn", f_trace, inputs.arrivals, e_cost_all
            )
            metrics["site_cost_hist"] = hist_series(
                telemetry.hist, site_cost, axis=0
            )                                                  # (N, B)
        return outs, TelemetryFrame(ring=ring_init(1), metrics=metrics)
    return outs


@functools.partial(
    jax.jit,
    static_argnames=("policy", "build_inputs", "n_runs", "telemetry", "mesh"),
)
def simulate_many(
    build_inputs: Callable[[Array], SimInputs],
    policy: PolicyFn,
    key: Array,
    n_runs: int,
    scalar: float | Array = 0.0,
    telemetry: TelemetryConfig | None = None,
    health: Array | None = None,
    mesh=None,
) -> SimOutputs:
    """Monte-Carlo replication: fresh traces + fresh policy randomness per run.

    ``build_inputs(key) -> SimInputs`` regenerates the stochastic traces
    (arrivals, service rates) for each run; deterministic traces (prices,
    PUE, ratios — and the degraded-mode ``health`` factor, when given)
    are closed over and shared. Outputs are stacked on a leading
    (n_runs,) axis (telemetry frames too, when enabled).

    ``mesh`` (static) shards the runs axis over a host-device mesh built by
    :func:`repro.distributed.mesh.runs_mesh` — same split keys, same
    per-run streams, bitwise-identical outputs at every device count;
    non-divisible ``n_runs`` is padded and sliced, never truncated.
    ``None`` keeps the single-device vmap.
    """
    keys = jax.random.split(key, n_runs)

    def one(run_key):
        k_build, k_sim = jax.random.split(run_key)
        return simulate(build_inputs(k_build), policy, k_sim, scalar,
                        telemetry, health)

    if mesh is None:
        return jax.vmap(one)(keys)
    from repro.distributed.mesh import sharded_runs

    return sharded_runs(one, keys, mesh)


def summarize(outs: SimOutputs) -> dict:
    """Time-averaged scalars (averaged over runs if a runs axis is present)."""
    cost = jnp.mean(outs.cost)
    backlog = jnp.mean(outs.backlog_avg)
    return {
        "time_avg_cost": float(cost),
        "time_avg_energy": float(jnp.mean(outs.energy)),
        "time_avg_backlog": float(backlog),
        "final_backlog_total": float(jnp.mean(outs.q_final.sum(axis=(-2, -1)))),
    }
