"""Energy-consumption / energy-cost model of GDA (paper Sec. III & IV-A).

The power drawn by a type-k job is fixed on the IT side (``P^k``) but its
*effective* energy — and the dollar cost of that energy — depends on where the
job's parallel tasks physically execute:

    energy(k, manager=i, t)  =  sum_j PUE_j(t) * r^k_{ij} * P^k
    cost(k, manager=i, t)    =  sum_j omega_j(t) * PUE_j(t) * r^k_{ij} * P^k

with the slot-level system cost

    Cost(t) = sum_k sum_i f_i^k(t) * A^k(t) * cost(k, i, t).

``r^k`` is the task-allocation-ratio matrix produced by the placement layer
(:mod:`repro.core.iridium`), ``PUE_j(t)`` / ``omega_j(t)`` come from the trace
pipeline (:mod:`repro.traces`).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
from jax import Array


def manager_energy_cost(omega: Array, pue: Array, r: Array, p_it: Array) -> Array:
    """Per-job energy cost e[k, i] of choosing DC i as manager for type k.

    e[k, i] = P^k * sum_j omega_j * PUE_j * r[k, i, j]

    Args:
        omega: (N,) energy-price weights at this slot.
        pue:   (N,) PUE values at this slot.
        r:     (K, N, N) task-allocation ratios.
        p_it:  (K,) fixed IT energy per job.

    Returns:
        (K, N) per-job energy cost for every (type, manager) pair.
    """
    weighted = omega * pue                                # (N,)
    # einsum over the executor axis j; MXU-friendly batched matvec.
    e = jnp.einsum("kij,j->ki", r, weighted)              # (K, N)
    return e * p_it[:, None]


def manager_energy(pue: Array, r: Array, p_it: Array) -> Array:
    """Per-job *energy* (not cost): E[k, i] = P^k * sum_j PUE_j * r[k, i, j]."""
    return jnp.einsum("kij,j->ki", r, pue) * p_it[:, None]


def slot_cost(f: Array, arrivals: Array, e: Array) -> Array:
    """System energy cost of one slot, Cost(t) (scalar).

    Args:
        f: (N, K) dispatch fractions.
        arrivals: (K,) arrivals this slot.
        e: (K, N) per-job manager energy costs from :func:`manager_energy_cost`.
    """
    # sum_k sum_i f[i,k] * A[k] * e[k,i]
    return jnp.sum(f.T * arrivals[:, None] * e)


def slot_energy(f: Array, arrivals: Array, energy_ki: Array) -> Array:
    """System energy of one slot (same contraction, PUE-weighted only)."""
    return jnp.sum(f.T * arrivals[:, None] * energy_ki)


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Bundles the static pieces of the cost model.

    Attributes:
        r: (K, N, N) task-allocation ratios (row-stochastic over the last axis).
        p_it: (K,) fixed per-job IT energy. The paper's evaluation sets this
            to 1 for its single job type; the fleet configuration derives it
            per workload class from the compiled step's roofline (DESIGN.md §7).
    """

    r: Array
    p_it: Array

    def cost_of_managers(self, omega: Array, pue: Array) -> Array:
        """(K, N) per-job cost table for one slot's (omega, pue)."""
        return manager_energy_cost(omega, pue, self.r, self.p_it)

    def slot_cost(self, f: Array, arrivals: Array, omega: Array, pue: Array) -> Array:
        return slot_cost(f, arrivals, self.cost_of_managers(omega, pue))

    def validate(self) -> None:
        """Eager sanity checks (not jit-safe; call at construction time)."""
        k, n, n2 = self.r.shape
        if n != n2:
            raise ValueError(f"r must be (K, N, N), got {self.r.shape}")
        if self.p_it.shape != (k,):
            raise ValueError(
                f"p_it must be (K,)={k}, got {self.p_it.shape}"
            )
        rowsum = jnp.sum(self.r, axis=-1)
        if not bool(jnp.allclose(rowsum, 1.0, atol=1e-5)):
            raise ValueError("task-allocation ratios must be row-stochastic")
        if bool(jnp.any(self.r < -1e-7)):
            raise ValueError("task-allocation ratios must be non-negative")
