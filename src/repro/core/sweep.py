"""One-launch parameter-sweep grids (EXPERIMENTS.md §Perf wall-clock track).

The engines already make their control parameter *traced* (``scalar`` —
GMSA's V — and, since this module landed, the placement controller's
``move_budget``), so a parameter sweep never re-compiles. But the benches
still launched one device program per grid point: a Fig.-6 V-sweep was 7
launches of ``simulate_many``, a ``placement_bench --sweep`` column was one
launch per move budget. This module stacks the swept axis *on top of* the
Monte-Carlo vmap, so a whole grid is ONE compilation and ONE launch:

    sweep_grid(build, gmsa_policy, key, 1000, V_GRID)   # (V, runs, T) out

Wall-clock wins come from two places: per-launch dispatch overhead is paid
once instead of per point, and XLA sees the whole grid at once (shared
trace generation is hoisted across the sweep axis — the V lanes reuse one
set of Monte-Carlo traces *per run index*, exactly as the per-point loop
with a fixed key did).

Axes convention: the swept axis is always leading — outputs are
``SimOutputs``/``PlacedOutputs`` pytrees whose arrays carry a leading
``(n_points,)`` axis (then ``(n_runs,)`` for the ``*_grid`` forms).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.simulator import (
    PolicyFn,
    SimInputs,
    SimOutputs,
    simulate,
    simulate_many,
)


@functools.partial(jax.jit, static_argnames=("policy",))
def simulate_sweep(
    inputs: SimInputs, policy: PolicyFn, key: Array, scalars: Array
) -> SimOutputs:
    """Run ONE trace under ``policy`` at every scalar in ``scalars``.

    The vmapped axis is the *traced* control parameter (GMSA's V), so the
    whole sweep is one compilation + one launch. Outputs carry a leading
    ``(len(scalars),)`` axis.
    """
    scalars = jnp.asarray(scalars, jnp.float32)
    return jax.vmap(lambda v: simulate(inputs, policy, key, v))(scalars)


@functools.partial(
    jax.jit, static_argnames=("build_inputs", "policy", "n_runs", "mesh")
)
def sweep_grid(
    build_inputs: Callable[[Array], SimInputs],
    policy: PolicyFn,
    key: Array,
    n_runs: int,
    scalars: Array,
    mesh=None,
) -> SimOutputs:
    """A full Monte-Carlo sweep at every scalar — one compilation, one launch.

    ``vmap(scalars) ∘ vmap(runs) ∘ scan(slots)``: the Fig.-6 grid shape.
    Every scalar lane sees the SAME per-run stochastic traces (the key is
    shared across lanes, exactly like calling ``simulate_many`` per point
    with a fixed key), so the V-axis comparison is paired, not just
    distributionally matched. Outputs: leading ``(len(scalars), n_runs)``.

    ``mesh`` (static) shards the *runs* axis over a host-device mesh
    (:func:`repro.distributed.mesh.runs_mesh`): the scalar vmap moves
    inside the per-run function (each run builds its traces once, shared
    across all scalar lanes) and the output axes are swapped back to the
    leading ``(len(scalars), n_runs)`` contract.
    """
    scalars = jnp.asarray(scalars, jnp.float32)
    if mesh is None:
        return jax.vmap(
            lambda v: simulate_many(build_inputs, policy, key, n_runs, v)
        )(scalars)
    from repro.distributed.mesh import sharded_runs

    keys = jax.random.split(key, n_runs)

    def one(run_key):
        k_build, k_sim = jax.random.split(run_key)
        inp = build_inputs(k_build)
        return jax.vmap(lambda v: simulate(inp, policy, k_sim, v))(scalars)

    outs = sharded_runs(one, keys, mesh)        # leading (n_runs, n_points)
    return jax.tree_util.tree_map(lambda x: jnp.swapaxes(x, 0, 1), outs)


@functools.partial(
    jax.jit,
    static_argnames=("build_inputs", "policy", "rule", "cfg", "n_runs",
                     "mesh"),
)
def sweep_placed_budgets(
    build_inputs: Callable[[Array], SimInputs],
    up: Array,
    down: Array,
    policy: PolicyFn,
    rule,
    key: Array,
    n_runs: int,
    cfg,
    budgets: Array,
    scalar: float | Array = 0.0,
    ingest: Array | None = None,
    sizes_gb: Array | None = None,
    alive: Array | None = None,
    mesh=None,
):
    """One-launch move-budget sweep of the two-timescale controller.

    The epoch structure (``cfg.epoch_slots``) is static — one compilation
    per W — but the per-epoch correction step alpha is data, so a whole
    ``placement_bench --sweep`` column (all move budgets at one W) runs as
    ONE launch via the controller's traced ``move_budget`` override.
    Outputs: ``PlacedOutputs`` with leading ``(len(budgets), n_runs)``.

    ``mesh`` (static) shards the runs axis, mirroring :func:`sweep_grid`:
    the budget vmap moves inside the per-run function and the leading two
    output axes are swapped back to ``(len(budgets), n_runs)``.
    """
    from repro.placement.controller import simulate_placed, simulate_placed_many

    budgets = jnp.asarray(budgets, jnp.float32)
    if mesh is None:
        return jax.vmap(
            lambda b: simulate_placed_many(
                build_inputs, up, down, policy, rule, key, n_runs, cfg,
                scalar=scalar, ingest=ingest, sizes_gb=sizes_gb, alive=alive,
                move_budget=b,
            )
        )(budgets)
    from repro.distributed.mesh import sharded_runs

    keys = jax.random.split(key, n_runs)

    def one(run_key):
        k_build, k_sim = jax.random.split(run_key)
        inp = build_inputs(k_build)
        return jax.vmap(
            lambda b: simulate_placed(
                inp, up, down, policy, rule, k_sim, cfg, scalar=scalar,
                ingest=ingest, sizes_gb=sizes_gb, alive=alive, move_budget=b,
            )
        )(budgets)

    outs = sharded_runs(one, keys, mesh)        # leading (n_runs, n_budgets)
    return jax.tree_util.tree_map(lambda x: jnp.swapaxes(x, 0, 1), outs)
