"""repro.core — the paper's primary contribution.

Implements the GDA (geo-distributed analytics) control plane of
"Energy-efficient Analytics for Geographically Distributed Big Data":

* :mod:`repro.core.queues`    — the per-DC/per-type queueing law (Eq. 1).
* :mod:`repro.core.energy`    — the PUE/price/task-ratio energy-cost model (Sec. III/IV-A).
* :mod:`repro.core.gmsa`      — the dynamic Global Manager Selection Algorithm:
  Lyapunov drift-plus-penalty dispatch, exact per-slot LP solution (Sec. IV-B).
* :mod:`repro.core.baselines` — DATA / RANDOM baselines (Sec. V-A) plus JSQ and
  greedy-cost references.
* :mod:`repro.core.iridium`   — bandwidth-aware task-allocation ratios in the
  style of Iridium [Pu et al., SIGCOMM'15], used by the paper to generate r.
* :mod:`repro.core.simulator` — the time-slotted trace-driven simulator
  (jit + lax.scan over slots, vmap over Monte-Carlo runs).

Array conventions (shared by every module here):
    N — number of data centers / pods;  K — job types;  T — time slots.
    Q     (N, K)  queue backlogs
    A     (K,)    arrivals in the current slot
    mu    (N, K)  service rates in the current slot
    omega (N,)    energy-price weight per DC
    pue   (N,)    PUE per DC
    r     (K, N, N)  r[k, i, j] = fraction of type-k tasks executed at DC j
                     when DC i is the global manager (rows sum to 1 over j)
    P     (K,)    per-job IT energy of a type-k job
    f     (N, K)  dispatch fractions (columns sum to 1)
"""

from repro.core.energy import EnergyModel, manager_energy_cost, slot_cost
from repro.core.queues import queue_step, total_backlog
from repro.core.gmsa import (
    GMSAConfig,
    drift_plus_penalty_scores,
    gmsa_dispatch,
    lp_objective,
    lyapunov_drift_bound_B,
    make_kernel_policy,
)
from repro.core.baselines import (
    data_dispatch,
    random_dispatch,
    jsq_dispatch,
    greedy_cost_dispatch,
    static_placement_rule,
)
from repro.core.iridium import (
    iridium_reduce_placement,
    build_task_allocation,
    make_allocation_rebuilder,
)
from repro.core.simulator import SimInputs, SimOutputs, simulate, simulate_many
from repro.core.sweep import simulate_sweep, sweep_grid, sweep_placed_budgets

__all__ = [
    "EnergyModel",
    "manager_energy_cost",
    "slot_cost",
    "queue_step",
    "total_backlog",
    "GMSAConfig",
    "drift_plus_penalty_scores",
    "gmsa_dispatch",
    "lp_objective",
    "lyapunov_drift_bound_B",
    "make_kernel_policy",
    "data_dispatch",
    "random_dispatch",
    "jsq_dispatch",
    "greedy_cost_dispatch",
    "static_placement_rule",
    "iridium_reduce_placement",
    "build_task_allocation",
    "make_allocation_rebuilder",
    "SimInputs",
    "SimOutputs",
    "simulate",
    "simulate_many",
    "simulate_sweep",
    "sweep_grid",
    "sweep_placed_budgets",
]
