"""GMSA — dynamic Global Manager Selection Algorithm (paper Sec. IV-B).

Per slot the algorithm observes (A, Q, mu, omega, PUE), and picks dispatch
fractions f(t) minimizing the drift-plus-penalty upper bound (Lemma 1):

    min_f  sum_{i,k} [ f_i^k A^k (Q_i^k - mu_i^k) - Q_i^k mu_i^k ]  +  V * Cost(t)
    s.t.   sum_i f_i^k = 1,   f_i^k >= 0.

Because the objective is linear in ``f`` and the constraint set is a product
of independent K simplices, the exact LP optimum is attained at a vertex:
all type-k mass goes to

    i*(k) = argmin_i  A^k * [ Q_i^k - mu_i^k + V * e_i^k ]

with ``e_i^k`` the per-job manager energy cost. We implement this closed form
(vectorized over K, vmappable over Monte-Carlo runs, kernelizable for fleet-
scale N — see ``repro.kernels.gmsa_score``) and verify it against
``scipy.optimize.linprog`` in the test suite.

The module also exposes the LP objective itself and the Lemma-1 drift bound
constant ``B`` so properties of the algorithm can be asserted directly.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import Array


@dataclasses.dataclass(frozen=True)
class GMSAConfig:
    """Control knobs of GMSA.

    Attributes:
        v: the Lyapunov trade-off parameter V >= 0. Larger V weights energy
            cost more (cost -> within O(1/V) of optimal) at the price of
            O(V) average backlog.
    """

    v: float = 1.0


def drift_plus_penalty_scores(
    q: Array, arrivals: Array, mu: Array, e: Array, v: float | Array
) -> Array:
    """Per-(type, manager) score whose argmin is the exact LP solution.

    score[k, i] = A^k * ( Q_i^k - mu_i^k + V * e[k, i] )

    Args:
        q: (N, K) backlogs.
        arrivals: (K,) arrivals this slot.
        mu: (N, K) service rates this slot.
        e: (K, N) per-job manager energy costs.
        v: scalar V.

    Returns:
        (K, N) scores.
    """
    drift = (q - mu).T                       # (K, N)
    return arrivals[:, None] * (drift + v * e)


def gmsa_dispatch(
    q: Array,
    arrivals: Array,
    mu: Array,
    e: Array | None,
    v: float | Array,
    *,
    impl: str = "ref",
    r: Array | None = None,
    wpue: Array | None = None,
    p_it: Array | None = None,
    interpret: bool | None = None,
) -> Array:
    """Exact per-slot GMSA decision f(t).

    Returns the (N, K) one-hot-per-column dispatch matrix placing all type-k
    jobs on the score-minimizing manager. Ties break to the lowest index
    (deterministic; matches the LP vertex scipy reports for degenerate ties
    up to objective equality, which is what the tests assert).

    Two implementations share this entry point:

    * ``impl="ref"`` (default) — the pure-XLA closed form against the
      precomputed per-job cost table ``e`` (the simulator's hoisted-einsum
      path). This is the fastest route when ``e`` is already amortized
      across slots.
    * ``impl="kernel"`` — the fused Pallas path for fleet-scale N: score,
      cost matvec and argmin in ONE kernel pass over the raw ``(r, wpue)``
      operands (:mod:`repro.kernels.gmsa_score`), never materializing the
      (K, N) score matrix in HBM between them. Requires ``r`` (K, N, N)
      and ``wpue`` (N,) instead of ``e``; ``p_it`` defaults to ones.
      ``interpret=None`` auto-selects interpret mode off-TPU (the CI/CPU
      path — the compiled kernel is the TPU target), and the pure-jnp
      oracle :func:`repro.kernels.gmsa_score.gmsa_score_ref` remains the
      fallback for callers that want raw-(r, wpue) dispatch without
      Pallas: pass ``impl="ref"`` with ``r``/``wpue`` and no ``e``.
    """
    n = q.shape[0]
    if impl == "kernel" or (impl == "ref" and e is None):
        if r is None or wpue is None:
            raise ValueError(
                f"impl={impl!r} without a precomputed cost table needs the "
                "raw operands: pass r=(K, N, N) and wpue=(N,)"
            )
        p = jnp.ones_like(arrivals) if p_it is None else p_it
        vp = jnp.asarray(v, jnp.float32) * p                    # (K,) V·P^k
        if impl == "kernel":
            from repro.kernels import default_interpret
            from repro.kernels.gmsa_score.ops import gmsa_score

            if interpret is None:
                interpret = default_interpret()
            _, best = gmsa_score(
                q.T, mu.T, arrivals, vp, r, wpue, interpret=interpret
            )                                                   # best (K,)
        else:
            from repro.kernels.gmsa_score.ref import gmsa_score_ref

            _, best = gmsa_score_ref(q.T, mu.T, arrivals, vp, r, wpue)
    elif impl == "ref":
        scores = drift_plus_penalty_scores(q, arrivals, mu, e, v)  # (K, N)
        best = jnp.argmin(scores, axis=1)                          # (K,)
    else:
        raise ValueError(f"unknown impl {impl!r}; use 'ref' or 'kernel'")
    # One-hot built directly in (N, K) orientation — same values as
    # one_hot(best, N).T without the transpose kernel in the hot loop.
    return (jnp.arange(n)[:, None] == best[None, :]).astype(q.dtype)


def lp_objective(
    f: Array, q: Array, arrivals: Array, mu: Array, e: Array, v: float | Array
) -> Array:
    """The full per-slot LP objective (including the f-independent term).

    obj(f) = sum_{i,k} [ f_i^k A^k (Q_i^k - mu_i^k) - Q_i^k mu_i^k ]
             + V * sum_{i,k} f_i^k A^k e[k, i]
    """
    fa = f * arrivals[None, :]                     # (N, K)
    drift_term = jnp.sum(fa * (q - mu))
    const_term = -jnp.sum(q * mu)
    cost_term = v * jnp.sum(fa * e.T)
    return drift_term + const_term + cost_term


def lyapunov_drift_bound_B(a_max: Array, mu_max: Array, n: int) -> Array:
    """The Lemma-1 constant  B = N/2 * sum_k (A_max^k)^2 + N/2 * sum_k (mu_max^k)^2.

    Used by the property tests to check the one-slot drift inequality.
    """
    return 0.5 * n * (jnp.sum(jnp.square(a_max)) + jnp.sum(jnp.square(mu_max)))


def gmsa_policy(key, q, arrivals, mu, e, aux, scalar):
    """GMSA with V supplied as the simulator's *traced* scalar — a V-sweep
    (paper Fig. 6) reuses a single compiled simulation."""
    del key, aux
    return gmsa_dispatch(q, arrivals, mu, e, scalar)


gmsa_policy.consumes_key = False


def dispatch_fn(v: float):
    """Closure adapter binding a static V (one compilation per V).

    Returns a function with the simulator's policy signature
    ``(key, q, arrivals, mu, e, aux, scalar) -> f``; GMSA ignores the PRNG
    key, the auxiliary (dataset-distribution) operand and the traced scalar.
    """

    def _policy(key, q, arrivals, mu, e, aux, scalar):
        del key, aux, scalar
        return gmsa_dispatch(q, arrivals, mu, e, v)

    _policy.consumes_key = False
    return _policy


def make_kernel_policy(
    r: Array | None = None,
    p_it: Array | None = None,
    impl: str = "kernel",
    interpret: bool | None = None,
):
    """GMSA policy driving dispatch through the fused Pallas kernel.

    Routes every slot's decision through ``gmsa_dispatch(..., impl=...)``
    on the raw ``(r, wpue)`` operands — the fleet-scale path where the
    kernel fuses the cost matvec, the drift score and the argmin in one
    pass (:mod:`repro.kernels.gmsa_score`). V rides in as the simulator's
    traced ``scalar``, exactly like :func:`gmsa_policy`.

    Two ratio-tensor modes:

    * ``r=None`` (carried-r) — the policy declares ``wants_r = True`` and
      reads the ratio tensor in force *this slot* from its aux,
      ``aux = (data_dist, omega_t * pue_t, r_t)``: the engines slice a
      time-varying ``(T, K, N, N)`` trace per slot, and the placement
      controller hands the carried ``r_c``/``r_e`` its epoch rebuilds and
      recovery re-placements actually produced. This is the only mode the
      controller accepts.
    * explicit ``(K, N, N)`` ``r`` — statically bound, as before. The
      policy is marked ``static_r = True`` and the engines raise loudly
      if a time-varying ratio trace reaches it (the kernel would silently
      dispatch on stale ratios).

    Either way the policy declares ``wants_wpue = True``, so
    :func:`repro.core.simulator.simulate` hands it raw per-slot prices —
    this is what lets an N = 256 ``configs.fleet_256`` run complete
    end-to-end through the kernel (interpret mode on CPU/CI, compiled on
    TPU; ``impl="ref"`` selects the pure-jnp oracle instead — the
    fallback when Pallas is unavailable).
    """
    if r is not None:
        r = jnp.asarray(r, jnp.float32)

    def policy(key, q, arrivals, mu, e, aux, scalar):
        del key, e
        if r is None:
            _, wpue, r_t = aux
        else:
            wpue, r_t = aux[1], r
        return gmsa_dispatch(
            q, arrivals, mu, None, scalar,
            impl=impl, r=r_t, wpue=wpue, p_it=p_it, interpret=interpret,
        )

    policy.consumes_key = False
    policy.wants_wpue = True
    policy.wants_r = r is None
    policy.static_r = r is not None
    return policy
