"""Distributed train-step builder.

Produces a jitted SPMD train step for any (arch × mesh) with:

* microbatch gradient accumulation (``lax.scan`` over microbatches — the
  standard way to hold global batch at 256×4k tokens within HBM);
* activation checkpointing (remat policy: none | dots | full);
* bf16 compute / fp32 optimizer moments;
* gradient sync in one of two modes:
    - "native": XLA's fused all-reduce over ("pod","data") — the baseline;
    - "int8":   within-pod native all-reduce + int8-compressed cross-pod
      reduce (repro.distributed.compression) with error feedback — the
      WAN-tier optimization matching the paper's heterogeneous core network.

The returned step has signature
    step(params, opt_state, batch, error_fb) -> (params, opt_state, metrics, error_fb)
and is lowered by the dry-run via ``.lower(**input_specs)``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.compat import shard_map
from repro.distributed.compression import sync_tree
from repro.distributed.sharding import batch_pspecs, param_pspecs
from repro.models.lm import loss_fn
from repro.train.optimizer import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1            # grad-accumulation steps per global step
    remat: str = "dots"              # none | dots | full
    attn_impl: str = "blockwise"
    grad_sync: str = "native"        # native | int8
    unroll_layers: bool = False      # dry-run cost-extraction only
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def _split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for the accumulation scan."""
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
    )


def make_train_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainStepConfig):
    """Build the jitted SPMD train step plus its in/out shardings."""
    pspecs = param_pspecs(cfg, mesh)
    opt_specs = {"m": pspecs, "v": pspecs, "step": P()}

    def grad_fn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(
                p, cfg, batch, attn_impl=tcfg.attn_impl, remat=tcfg.remat,
                unroll_layers=tcfg.unroll_layers,
            ),
            has_aux=True,
        )(params)
        return loss, metrics, grads

    def accumulate(params, batch):
        """Microbatched gradients (mean over microbatches)."""
        if tcfg.microbatches == 1:
            return grad_fn(params, batch)
        mb = _split_microbatches(batch, tcfg.microbatches)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, one):
            loss, metrics, grads = grad_fn(params, one)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, (loss, metrics)

        acc, (losses, metricses) = jax.lax.scan(body, zero, mb)
        grads = jax.tree.map(lambda a: a / tcfg.microbatches, acc)
        loss = jnp.mean(losses)
        metrics = jax.tree.map(jnp.mean, metricses)
        return loss, metrics, grads

    multi_pod = "pod" in mesh.shape
    metric_keys = ("ce", "z_loss", "aux")

    if tcfg.grad_sync == "int8" and multi_pod:
        n_pods = mesh.shape["pod"]

        def step(params, opt_state, batch, error_fb):
            # Manual over "pod": per-pod partial grads, compressed WAN sync.
            # error_fb leaves carry a leading (n_pods,) axis — residuals are
            # genuinely per-pod state.
            def pod_local(params, batch, error_fb):
                loss, metrics, grads = accumulate(params, batch)
                efb_local = jax.tree.map(lambda e: e[0], error_fb)
                grads, resid = sync_tree(grads, n_pods, "pod", efb_local)
                loss = jax.lax.pmean(loss, "pod")
                metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
                resid = jax.tree.map(lambda r: r[None], resid)
                return loss, metrics, grads, resid

            pspec_rep = jax.tree.map(lambda _: P(), params)
            loss, metrics, grads, resid = shard_map(
                pod_local,
                mesh=mesh,
                in_specs=(
                    pspec_rep,
                    jax.tree.map(lambda _: P("pod"), batch),
                    jax.tree.map(lambda _: P("pod"), error_fb),
                ),
                out_specs=(
                    P(),
                    {k: P() for k in metric_keys},
                    pspec_rep,
                    jax.tree.map(lambda _: P("pod"), error_fb),
                ),
                axis_names={"pod"},
                check_vma=False,
            )(params, batch, error_fb)
            new_params, new_opt, opt_metrics = adamw_update(
                tcfg.optimizer, params, grads, opt_state
            )
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return new_params, new_opt, metrics, resid

    else:

        def step(params, opt_state, batch, error_fb):
            loss, metrics, grads = accumulate(params, batch)
            new_params, new_opt, opt_metrics = adamw_update(
                tcfg.optimizer, params, grads, opt_state
            )
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return new_params, new_opt, metrics, error_fb

    def efb_pspecs():
        """Error-feedback sharding: leading pod axis in int8 mode; scalar
        placeholders (replicated) in native mode."""
        if tcfg.grad_sync == "int8" and multi_pod:
            return jax.tree.map(
                lambda s: P("pod", *s), param_pspecs(cfg, mesh)
            )
        return jax.tree.map(lambda _: P(), pspecs)

    def init_error_fb(params):
        if tcfg.grad_sync == "int8" and multi_pod:
            n_pods = mesh.shape["pod"]
            return jax.tree.map(
                lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params
            )
        return jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)

    def shardings_for(batch_tree, batch_size: int):
        bspecs = batch_pspecs(batch_tree, mesh, batch_size)
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
        in_shardings = (ns(pspecs), ns(opt_specs), ns(bspecs), ns(efb_pspecs()))
        out_shardings = (ns(pspecs), ns(opt_specs), None, ns(efb_pspecs()))
        return in_shardings, out_shardings

    return step, pspecs, opt_specs, shardings_for, init_error_fb
