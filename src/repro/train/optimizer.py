"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Self-contained (no optax in this environment). Optimizer state mirrors the
param tree (m, v in fp32 regardless of param dtype — the standard mixed-
precision recipe: bf16 params / fp32 moments + master-quality updates).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup then cosine decay to ``min_lr_ratio * lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos)


def adamw_init(params) -> dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, state) -> tuple:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        m_hat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        v_hat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        # Decoupled weight decay on matrices only (ndim >= 2).
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in outs])
    new_state = {
        "m": treedef.unflatten([o[1] for o in outs]),
        "v": treedef.unflatten([o[2] for o in outs]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
