"""repro.train — optimizer, distributed train step, training loop."""

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train.step import TrainStepConfig, make_train_step

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "TrainStepConfig",
    "make_train_step",
]
