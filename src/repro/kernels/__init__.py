"""repro.kernels — Pallas TPU kernels for the framework's compute hot spots.

Two kernels, each a (kernel.py, ops.py, ref.py) triple validated in
interpret mode against the pure-jnp oracle (tests/test_kernels.py):

* ``gmsa_score`` — the paper's per-slot dispatch inner loop at fleet scale:
  fused cost matvec (MXU) + drift add (VPU) + running argmin reduction, one
  VMEM pass over the (K, N, N) task-allocation tensor.
* ``ssd_scan``   — Mamba-2 chunked SSD forward (the long_500k hot spot):
  intra-chunk attention-form + cross-chunk recurrence carried in VMEM
  scratch across the sequential chunk grid.

The dry-run lowers the pure-JAX paths (XLA cost analysis cannot see inside
``pallas_call`` custom-calls); kernels are opt-in for real TPU execution and
benchmarked separately (benchmarks/kernel_bench.py). See DESIGN.md §6.

Compiled-vs-interpret policy: both kernels are TPU-tiled (``pltpu.VMEM``
scratch, Mosaic lowering), so native compilation is a TPU capability —
:func:`supports_compiled_pallas` gates it, :func:`default_interpret` is the
per-backend default every ``interpret=None`` entry point resolves through,
and the benchmarks record their timing matrix per backend against it.
"""

import jax

__all__ = ["default_interpret", "pallas_backend", "supports_compiled_pallas"]


def pallas_backend() -> str:
    """The backend kernels would lower for (``"cpu"``/``"gpu"``/``"tpu"``)."""
    return jax.default_backend()


def supports_compiled_pallas() -> bool:
    """Whether the repo's Pallas kernels can compile natively here.

    Both kernels target Mosaic (TPU memory spaces and tiling); off-TPU they
    run under the Pallas interpreter, numerically identical and test-pinned
    against the jnp oracles, but orders of magnitude slower.
    """
    return pallas_backend() == "tpu"


def default_interpret() -> bool:
    """The ``interpret=`` default: compiled on TPU, interpret elsewhere."""
    return not supports_compiled_pallas()
