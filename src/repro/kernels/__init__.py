"""repro.kernels — Pallas TPU kernels for the framework's compute hot spots.

Two kernels, each a (kernel.py, ops.py, ref.py) triple validated in
interpret mode against the pure-jnp oracle (tests/test_kernels.py):

* ``gmsa_score`` — the paper's per-slot dispatch inner loop at fleet scale:
  fused cost matvec (MXU) + drift add (VPU) + running argmin reduction, one
  VMEM pass over the (K, N, N) task-allocation tensor.
* ``ssd_scan``   — Mamba-2 chunked SSD forward (the long_500k hot spot):
  intra-chunk attention-form + cross-chunk recurrence carried in VMEM
  scratch across the sequential chunk grid.

The dry-run lowers the pure-JAX paths (XLA cost analysis cannot see inside
``pallas_call`` custom-calls); kernels are opt-in for real TPU execution and
benchmarked separately (benchmarks/kernel_bench.py). See DESIGN.md §6.
"""
