"""Pallas TPU kernel: fused GMSA drift-plus-penalty score + argmin.

Grid (nk, ni, nj), row-major sequential on TPU (j innermost):

  * j loop  — accumulate the cost matvec  acc[kt, it] += r[kt, it, jt] @ wpue[jt]
              on the MXU ((K_T*N_T, J_T) x (J_T, 1));
  * at j=last — fuse the drift term (VPU), emit the score tile, and fold it
              into the running (min, argmin) scratch carried across i tiles;
  * at i=last — write best[kt].

One pass over the (K, N, N) ratio tensor in (K_T, N_T, J_T) VMEM tiles; the
(K, N) score matrix never round-trips to HBM between cost, drift and argmin
(the fusion the pure-XLA path cannot express across the argmin reduction).

VMEM budget/tile: r (8·128·128·4B = 512 KiB) + score/acc (2×4 KiB) + operand
tiles — comfortably under the ~16 MiB/core budget; J_T/N_T are lane-aligned
(128) and K_T sublane-aligned (8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

K_T = 8      # job-type tile (sublane-aligned)
N_T = 128    # manager tile (lane-aligned)
J_T = 128    # executor tile (matvec contraction)


def _kernel(q_ref, mu_ref, a_ref, vp_ref, wpue_ref, r_ref,
            scores_ref, best_ref, acc_ref, minval_ref, minidx_ref):
    i = pl.program_id(1)
    j = pl.program_id(2)
    ni = pl.num_programs(1)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Cost matvec on the MXU: (K_T*N_T, J_T) @ (J_T, 1).
    r_tile = r_ref[...].reshape(K_T * N_T, J_T)
    partial = jax.lax.dot_general(
        r_tile, wpue_ref[...],                      # (J_T, 1)
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(K_T, N_T)
    acc_ref[...] += partial

    @pl.when(j == nj - 1)
    def _finalize_tile():
        score = a_ref[...] * (
            q_ref[...] - mu_ref[...] + vp_ref[...] * acc_ref[...]
        )
        scores_ref[...] = score
        row_min = jnp.min(score, axis=1, keepdims=True)            # (K_T, 1)
        local_arg = jnp.argmin(score, axis=1).astype(jnp.int32)
        row_arg = (local_arg + i * N_T).reshape(K_T, 1)

        @pl.when(i == 0)
        def _first():
            minval_ref[...] = row_min
            minidx_ref[...] = row_arg

        @pl.when(i > 0)
        def _update():
            better = row_min < minval_ref[...]
            minval_ref[...] = jnp.where(better, row_min, minval_ref[...])
            minidx_ref[...] = jnp.where(better, row_arg, minidx_ref[...])

        @pl.when(i == ni - 1)
        def _emit():
            best_ref[...] = minidx_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gmsa_score_kernel(q, mu, a, vp, wpue, r, *, interpret: bool = False):
    """Padded-shape entry point. q/mu: (K, N); a/vp: (K, 1); wpue: (N, 1);
    r: (K, N, N). K % K_T == 0, N % N_T == 0 (ops.py pads)."""
    k_dim, n_dim = q.shape
    grid = (k_dim // K_T, n_dim // N_T, n_dim // J_T)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K_T, N_T), lambda k, i, j: (k, i)),        # q
            pl.BlockSpec((K_T, N_T), lambda k, i, j: (k, i)),        # mu
            pl.BlockSpec((K_T, 1), lambda k, i, j: (k, 0)),          # a
            pl.BlockSpec((K_T, 1), lambda k, i, j: (k, 0)),          # vp
            pl.BlockSpec((J_T, 1), lambda k, i, j: (j, 0)),          # wpue
            pl.BlockSpec((K_T, N_T, J_T), lambda k, i, j: (k, i, j)),  # r
        ],
        out_specs=[
            pl.BlockSpec((K_T, N_T), lambda k, i, j: (k, i)),        # scores
            pl.BlockSpec((K_T, 1), lambda k, i, j: (k, 0)),          # best
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_dim, n_dim), jnp.float32),
            jax.ShapeDtypeStruct((k_dim, 1), jnp.int32),
        ],
        scratch_shapes=[
            # VMEM scratch persisting across the sequential TPU grid:
            pltpu.VMEM((K_T, N_T), jnp.float32),   # acc (cost matvec)
            pltpu.VMEM((K_T, 1), jnp.float32),     # running min
            pltpu.VMEM((K_T, 1), jnp.int32),       # running argmin
        ],
        interpret=interpret,
    )(q, mu, a, vp, wpue, r)
