"""jit'd public wrapper for the gmsa_score kernel: padding + unpacking.

Padding semantics: managers are padded with q=+BIG so a padded column can
never win the argmin; job types pad with zeros (their rows are discarded on
slice-out); the executor axis pads r/wpue with zeros (no cost contribution).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array

from repro.kernels.gmsa_score.kernel import J_T, K_T, N_T, gmsa_score_kernel

_BIG = 3e38


def _pad_to(x: Array, axis: int, mult: int, value: float = 0.0) -> Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gmsa_score(
    q: Array,        # (K, N) backlogs (pre-transposed)
    mu: Array,       # (K, N) service rates
    a: Array,        # (K,)   arrivals
    vp: Array,       # (K,)   V * P^k
    r: Array,        # (K, N, N) task-allocation ratios
    wpue: Array,     # (N,)   omega ⊙ PUE
    *,
    interpret: bool | None = None,
) -> tuple[Array, Array]:
    """Fused dispatch scores + argmin. Returns (scores (K, N), best (K,)).

    ``interpret=None`` resolves per backend
    (:func:`repro.kernels.default_interpret`): compiled on TPU, interpret
    elsewhere.
    """
    if interpret is None:
        from repro.kernels import default_interpret

        interpret = default_interpret()
    k_dim, n_dim = q.shape
    qp = _pad_to(_pad_to(q.astype(jnp.float32), 1, N_T, _BIG), 0, K_T)
    mup = _pad_to(_pad_to(mu.astype(jnp.float32), 1, N_T), 0, K_T)
    ap = _pad_to(a.astype(jnp.float32)[:, None], 0, K_T, 1.0)
    vpp = _pad_to(vp.astype(jnp.float32)[:, None], 0, K_T)
    wp = _pad_to(wpue.astype(jnp.float32)[:, None], 0, J_T)
    rp = _pad_to(_pad_to(_pad_to(r.astype(jnp.float32), 2, J_T), 1, N_T), 0, K_T)

    scores, best = gmsa_score_kernel(qp, mup, ap, vpp, wp, rp, interpret=interpret)
    return scores[:k_dim, :n_dim], best[:k_dim, 0]
