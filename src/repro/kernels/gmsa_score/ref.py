"""Pure-jnp oracle for the fused GMSA dispatch score + argmin.

score[k, i] = a[k] * ( q[k, i] - mu[k, i] + vp[k] * sum_j r[k, i, j] * wpue[j] )
best[k]     = argmin_i score[k, i]

(q/mu arrive (K, N) pre-transposed; ``vp`` = V * P^k folded by the caller;
``wpue`` = omega ⊙ PUE.)
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array


def gmsa_score_ref(
    q: Array, mu: Array, a: Array, vp: Array, r: Array, wpue: Array
) -> tuple[Array, Array]:
    """Returns (scores (K, N) fp32, best (K,) int32)."""
    cost = jnp.einsum(
        "kij,j->ki", r.astype(jnp.float32), wpue.astype(jnp.float32)
    )
    scores = a[:, None].astype(jnp.float32) * (
        q.astype(jnp.float32) - mu.astype(jnp.float32)
        + vp[:, None].astype(jnp.float32) * cost
    )
    return scores, jnp.argmin(scores, axis=1).astype(jnp.int32)
