from repro.kernels.gmsa_score.ops import gmsa_score
from repro.kernels.gmsa_score.ref import gmsa_score_ref

__all__ = ["gmsa_score", "gmsa_score_ref"]
