"""Pallas TPU kernel: Mamba-2 chunked SSD forward.

Grid (B, H, S/Q) — the chunk index innermost so the (P, N) recurrent state
lives in VMEM scratch across chunks of one (batch, head) stream:

  per chunk (Q = chunk length):
    cum   = cumsum(a * dt)                          (VPU, (Q,1))
    CB    = C @ Bᵀ                                  (MXU, (Q,Q))
    W     = CB ⊙ tril(exp(cum_t - cum_s)) ⊙ dt_s    (VPU)
    y     = W @ x  +  (C @ h_inᵀ) ⊙ exp(cum)        (MXU + MXU)
    h_out = exp(cum_Q) · h_in + (x ⊙ decay·dt)ᵀ @ B (MXU)

TPU adaptation of the paper's (GPU) SSD kernel shape: the (Q,Q) intra-chunk
"attention" matrix is sized to the MXU (Q=128 ⇒ 64 KiB fp32 in VMEM), state
(P×N = 64×128) stays resident in VMEM across the whole stream — HBM traffic
is exactly x/dt/B/C in and y out, the roofline floor for this op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, state_ref):
    c_idx = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    a = a_ref[0, 0]                                  # scalar
    bm = b_ref[0].astype(jnp.float32)                # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                # (Q, N)

    q_len = x.shape[0]
    adt = a * dt                                     # (Q,)
    cum = jnp.cumsum(adt)                            # (Q,)

    # Intra-chunk attention-form term.
    seg = cum[:, None] - cum[None, :]                # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    l_mat = jnp.exp(jnp.where(tri, seg, -jnp.inf))
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    w = cb * l_mat * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q, P)

    # Inter-chunk term from the carried state.
    h_in = state_ref[...]                            # (P, N)
    y_inter = jax.lax.dot_general(cm, h_in, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q, P)
    y = y + y_inter * jnp.exp(cum)[:, None]

    # State update: h' = exp(cum_Q) h + sum_s decay_out_s dt_s x_s ⊗ B_s.
    decay_out = jnp.exp(cum[-1] - cum) * dt          # (Q,)
    xw = x * decay_out[:, None]                      # (Q, P)
    upd = jax.lax.dot_general(xw, bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = jnp.exp(cum[-1]) * h_in + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x, dt, a2d, b_mat, c_mat, *, chunk: int, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); a2d: (H,1); b/c: (B,S,N). S % chunk == 0."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    grid = (bsz, h, s // chunk)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),  # x
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),        # dt
            pl.BlockSpec((1, 1), lambda b, hh, c: (hh, 0)),                  # a
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),         # B
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),         # C
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),  # y
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),      # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2d, b_mat, c_mat)
