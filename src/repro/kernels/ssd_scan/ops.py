"""jit'd public wrapper for the SSD scan kernel (padding + dtype policy)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import Array

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: Array,      # (B, S, H, P)
    dt: Array,     # (B, S, H)
    a: Array,      # (H,)
    b_mat: Array,  # (B, S, N)
    c_mat: Array,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool | None = None,
) -> tuple[Array, Array]:
    """Chunked SSD forward. Returns (y (B,S,H,P), final_state (B,H,P,N)).

    Sequence length is padded to a chunk multiple with dt=0 steps (exp(0)=1,
    zero update — exact no-ops for the recurrence). ``interpret=None``
    resolves per backend (:func:`repro.kernels.default_interpret`):
    compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        from repro.kernels import default_interpret

        interpret = default_interpret()
    bsz, s, h, p = x.shape
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    y, h_final = ssd_scan_kernel(
        x, dt, a.astype(jnp.float32)[:, None], b_mat, c_mat,
        chunk=chunk, interpret=interpret,
    )
    return y[:, :s], h_final
