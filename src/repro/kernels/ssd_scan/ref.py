"""Pure-jnp oracle for the chunked SSD scan: the O(S) sequential recurrence.

    h_t = exp(a_h * dt_t) * h_{t-1} + dt_t * (x_t ⊗ B_t)
    y_t = h_t @ C_t

Deliberately the *sequential* form (not the chunked algebra) so the kernel
and the chunked pure-JAX path (repro.models.ssm.ssd_chunked) are validated
against an independent formulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array


def ssd_scan_ref(
    x: Array,      # (B, S, H, P)
    dt: Array,     # (B, S, H)
    a: Array,      # (H,)
    b_mat: Array,  # (B, S, N)
    c_mat: Array,  # (B, S, N)
) -> tuple[Array, Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]

    def step(state, t_in):
        xt, dtt, bt, ct = t_in
        decay = jnp.exp(dtt.astype(jnp.float32) * a.astype(jnp.float32))  # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt.astype(jnp.float32),
                         xt.astype(jnp.float32), bt.astype(jnp.float32))
        state = decay[:, :, None, None] * state + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, y

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(
        step, h0,
        (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
         b_mat.transpose(1, 0, 2), c_mat.transpose(1, 0, 2)),
    )
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
