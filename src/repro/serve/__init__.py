"""repro.serve — serving runtime: sharded prefill/decode steps + the
simulation-stack-dispatched fleet engine (staged prefill→decode dispatch,
replica-read routing, admission control, pod-death recovery)."""

from repro.serve.step import make_decode_step, make_local_exec, make_prefill_step
from repro.serve.engine import (
    FleetConfig,
    FleetEngine,
    RequestClass,
    ServeScenario,
    build_serve_scenario,
    serve_policy,
)

__all__ = [
    "make_decode_step",
    "make_local_exec",
    "make_prefill_step",
    "FleetEngine",
    "FleetConfig",
    "RequestClass",
    "ServeScenario",
    "build_serve_scenario",
    "serve_policy",
]
