"""repro.serve — serving runtime: sharded prefill/decode steps + the
GMSA-dispatched continuous-batching fleet engine."""

from repro.serve.step import make_decode_step, make_prefill_step
from repro.serve.engine import FleetEngine, FleetConfig, RequestClass

__all__ = [
    "make_decode_step",
    "make_prefill_step",
    "FleetEngine",
    "FleetConfig",
    "RequestClass",
]
