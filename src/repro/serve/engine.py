"""FleetEngine — GMSA-dispatched continuous-batching across logical pods.

This is the paper's Sec. II framework made concrete for LLM serving: the
front-end receives stochastic requests per class (architecture × request
shape), and each slot selects the *global manager pod* per class with GMSA
(repro.core.gmsa), trading energy cost (pod PUE × regional price) against
queue backlogs. Pods then execute REAL prefill+decode steps for the jobs
they drain (small models; all pods run on the local device but keep
independent queues/capacities — capacity heterogeneity and wall-clock noise
model stragglers).

Energy accounting follows DESIGN.md §7: per-job energy derives from the
model's parameter count and tokens processed (6·N_active·tokens FLOPs at
chip efficiency), weighted by per-pod PUE and price traces — the paper's
abstract P^k made measurable.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.energy import manager_energy_cost
from repro.core.gmsa import gmsa_dispatch
from repro.core.queues import queue_step
from repro.models.lm import decode_step, init_params, prefill_step

# TPU v5e-class constants (DESIGN.md §7).
CHIP_PEAK_FLOPS = 197e12
CHIP_TDP_W = 200.0
CHIP_EFFICIENCY = 0.45


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One workload class k: an architecture at a request shape.

    ``cfg`` is the model actually executed (smoke-scale on this container);
    ``energy_cfg`` (default: cfg) is the architecture whose parameter count
    prices the job — pass the FULL config so the control plane sees
    production-scale energy while execution stays CPU-sized.
    """

    name: str
    cfg: ModelConfig
    energy_cfg: ModelConfig | None = None
    prompt_len: int = 32
    gen_len: int = 8
    arrival_rate: float = 6.0     # jobs / slot (Poisson)

    def flops_per_job(self) -> float:
        toks = self.prompt_len + self.gen_len
        ecfg = self.energy_cfg or self.cfg
        return 6.0 * ecfg.active_param_count() * toks

    def energy_per_job_j(self) -> float:
        """IT-side energy per job (Joules): chip-seconds × TDP."""
        chip_seconds = self.flops_per_job() / (CHIP_PEAK_FLOPS * CHIP_EFFICIENCY)
        return chip_seconds * CHIP_TDP_W


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_pods: int = 4
    horizon_slots: int = 32
    v: float = 1.0
    seed: int = 0
    batch_per_exec: int = 4       # jobs executed per model invocation
    capacity_shares: tuple = (0.3, 0.2, 0.9, 0.6)   # pod throughput skew


class FleetEngine:
    """Slot-driven serving loop with GMSA dispatch and real model execution."""

    def __init__(
        self,
        fcfg: FleetConfig,
        classes: list[RequestClass],
        omega: np.ndarray,          # (T, N) price traces
        pue: np.ndarray,            # (T, N)
        r: np.ndarray,              # (K, N, N) task-allocation ratios
    ):
        self.fcfg = fcfg
        self.classes = classes
        self.omega, self.pue, self.r = omega, pue, r
        self.key = jax.random.key(fcfg.seed)
        self.params = {}
        self._decode_jit = {}
        self._prefill_jit = {}
        for rc in classes:
            self.key, sub = jax.random.split(self.key)
            self.params[rc.name] = init_params(sub, rc.cfg, jnp.float32)
            self._decode_jit[rc.name] = jax.jit(
                lambda p, c, t, _cfg=rc.cfg: decode_step(p, _cfg, c, t)
            )
            self._prefill_jit[rc.name] = jax.jit(
                lambda p, t, _cfg=rc.cfg, _g=rc.gen_len: prefill_step(
                    p, _cfg, t, cache_dtype=jnp.float32,
                    cache_len=t.shape[1] + _g,
                )
            )
        self.p_it = jnp.asarray(
            [rc.energy_per_job_j() / 3.6e6 for rc in classes], jnp.float32
        )  # kWh/job — priced by omega in $/MWh => dollars×1e-3 scale

    def _execute_jobs(self, rc: RequestClass, n_jobs: int) -> tuple[int, float]:
        """Actually run prefill+decode for up to n_jobs; returns (done, secs)."""
        if n_jobs <= 0:
            return 0, 0.0
        b = self.fcfg.batch_per_exec
        done = 0
        t0 = time.perf_counter()
        self.key, sub = jax.random.split(self.key)
        tokens = jax.random.randint(
            sub, (b, rc.prompt_len), 0, rc.cfg.vocab_size, dtype=jnp.int32
        )
        while done < n_jobs:
            logits, cache = self._prefill_jit[rc.name](self.params[rc.name], tokens)
            tok = jnp.argmax(logits[:, -1:, : rc.cfg.vocab_size], axis=-1).astype(jnp.int32)
            for _ in range(rc.gen_len):
                logits, cache = self._decode_jit[rc.name](
                    self.params[rc.name], cache, tok
                )
                tok = jnp.argmax(logits[:, :, : rc.cfg.vocab_size], axis=-1).astype(jnp.int32)
            tok.block_until_ready()
            done += b
        return min(done, n_jobs), time.perf_counter() - t0

    def run(self, execute_real: bool = True, stream=None) -> dict:
        """Run the slot loop. Returns per-slot traces + summary.

        Args:
            execute_real: run real prefill+decode for drained jobs.
            stream: optional callable receiving one JSON-ready dict per
                slot as the run progresses (live telemetry). The record
                is emitted through ``jax.experimental.io_callback``
                (``ordered=True``) from a jitted emitter — the same
                host-callback mechanism a fully jitted serving loop
                would stream through, so consumers see records in slot
                order even under async dispatch.

        The returned dict keeps its original keys (backward-compatible)
        and adds ``history``: one record per slot with the dispatch
        choice per class (argmax pod), per-pod queue depth after the
        slot, and IT energy in Joules per class — what
        ``examples/serve_geo.py`` prints as a timeline.
        """
        fcfg = self.fcfg
        n, k = fcfg.n_pods, len(self.classes)
        q = jnp.zeros((n, k), jnp.float32)
        shares = np.asarray(fcfg.capacity_shares[:n], np.float32)
        costs, backlogs, dispatches, exec_secs = [], [], [], 0.0
        history: list[dict] = []
        e_per_job = np.asarray(
            [rc.energy_per_job_j() for rc in self.classes], np.float64
        )
        rng = np.random.default_rng(fcfg.seed)

        emit = None
        if stream is not None:
            from jax.experimental import io_callback

            def _host_emit(t_, cost_, backlog_):
                stream({
                    "type": "metric", "engine": "serve",
                    "t": int(t_), "cost": float(cost_),
                    "backlog": float(backlog_),
                })

            @jax.jit
            def emit(t_, cost_, backlog_):
                io_callback(_host_emit, None, t_, cost_, backlog_,
                            ordered=True)

        for t in range(fcfg.horizon_slots):
            arrivals = jnp.asarray(
                [rng.poisson(rc.arrival_rate) for rc in self.classes], jnp.float32
            )
            omega_t = jnp.asarray(self.omega[t % len(self.omega)])
            pue_t = jnp.asarray(self.pue[t % len(self.pue)])
            e = manager_energy_cost(omega_t, pue_t, jnp.asarray(self.r), self.p_it)
            # Service capacity per pod/class this slot (jobs), straggler noise.
            lam_tot = sum(rc.arrival_rate for rc in self.classes)
            mu = jnp.asarray(
                rng.poisson(shares[:, None] * lam_tot / k, size=(n, k)), jnp.float32
            )
            f = gmsa_dispatch(q, arrivals, mu, e, fcfg.v)
            cost = float(jnp.sum((f * arrivals[None, :]).T * e))
            # Execute drained jobs on the real models.
            if execute_real:
                served = np.minimum(np.asarray(q + f * arrivals[None, :]), np.asarray(mu))
                for ki, rc in enumerate(self.classes):
                    njobs = int(served[:, ki].sum())
                    _, secs = self._execute_jobs(rc, min(njobs, 2 * fcfg.batch_per_exec))
                    exec_secs += secs
            q = queue_step(q, f, arrivals, mu)
            costs.append(cost)
            backlogs.append(float(jnp.sum(q)))
            f_np = np.asarray(f)
            dispatches.append(f_np)
            history.append({
                "t": t,
                "choice": np.argmax(f_np, axis=0).tolist(),       # pod per k
                "q_pod": np.asarray(jnp.sum(q, axis=1)).tolist(),
                "energy_j": (
                    f_np.sum(axis=0) * np.asarray(arrivals) * e_per_job
                ).tolist(),
            })
            if emit is not None:
                emit(jnp.int32(t), jnp.float32(cost),
                     jnp.float32(backlogs[-1]))

        return {
            "cost": np.asarray(costs),
            "backlog": np.asarray(backlogs),
            "dispatch": np.asarray(dispatches),
            "exec_seconds": exec_secs,
            "mean_cost": float(np.mean(costs)),
            "final_backlog": backlogs[-1],
            "history": history,
        }
