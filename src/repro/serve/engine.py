"""FleetEngine — the serving control plane, driven by the simulation stack.

This is the paper's Sec. II framework serving live LLM traffic: the front
end ingests stochastic requests per class (architecture × request shape)
from batched :mod:`repro.traces.arrivals` tables, applies per-class
admission control, and every slot dispatches through the SAME joint
stage scheduler that wins in ``simulate_staged`` — each request class is
a 2-stage prefill → decode :class:`repro.jobs.dag.StageDag` (the KV-cache
handoff is the shuffle volume billed when decode runs on a different pod
than prefill), and prefill traffic routes through a placement layout via
:func:`repro.placement.replica.replica_read_assignment` (replica reads
pick the serving pod). Pods then execute REAL prefill+decode steps for
the jobs they drain.

The per-slot update is :func:`repro.jobs.engine.staged_slot_update` — the
single definition shared with ``simulate_staged``'s scan body — and the
post-run cost/WAN bills evaluate the simulator's own batched expressions,
so a dispatch-only :meth:`FleetEngine.run` replays bit-for-bit against
``simulate_staged`` on the shared :class:`ServeScenario` (test-pinned).

Pod death (an optional ``(T, N)`` alive mask) mirrors the placement
controller's fault path: on a death edge the dead pod's queues are wiped
(a select, never ``* alive`` — the ULP trap), the backlog re-injects as
an arrival burst at the prefill stage (the KV cache died with the pod, so
decode-stage work re-executes from scratch — the re-execution discipline
of the reliable-geo-analytics reference, PAPERS.md 1802.00245), routing
renormalizes over the survivors, and the recovery event lands in the
history/telemetry stream. An all-ones mask is bit-exact with the
no-fault loop.

Energy accounting follows DESIGN.md §7: per-job energy derives from the
model's parameter count and tokens processed (6·N_active·tokens FLOPs at
chip efficiency), weighted by per-pod PUE and price traces — and
``history[t]["energy_j"]`` prices jobs actually SERVED
(``min(q + f·A, mu)`` per stage, compute-weighted), not jobs dispatched:
a saturated pod bills only what it drains.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.configs.base import ModelConfig
from repro.core.gmsa import make_kernel_policy
from repro.core.simulator import SimInputs, _energy_tables
from repro.jobs.dag import StageDag, chain_dag
from repro.jobs.engine import (
    _hedge_bill,
    hedged_mu,
    staged_shuffle_mixes,
    staged_slot_update,
)
from repro.jobs.scheduler import (
    make_staged_policy,
    stage_oblivious,
    stage_service_rates_all,
)
from repro.models.lm import init_params
from repro.placement.controller import survivor_renorm
from repro.placement.replica import replica_read_assignment
from repro.placement.wan import (
    WanModel,
    degraded_surcharge,
    plan_cost,
    wan_topology,
)
from repro.serve.step import make_local_exec
from repro.telemetry.config import TelemetryConfig
from repro.telemetry.config import histograms as _tel_hist
from repro.telemetry.metrics import (
    percentile_table,
    sojourn_init,
    sojourn_step,
)
from repro.traces.arrivals import (
    admission_split,
    poisson_pair_from_tables,
    serve_rate_tables,
)

# TPU v5e-class constants (DESIGN.md §7).
CHIP_PEAK_FLOPS = 197e12
CHIP_TDP_W = 200.0
CHIP_EFFICIENCY = 0.45

#: Pod throughput skew cycled to any fleet size (FleetConfig.__post_init__).
DEFAULT_CAPACITY_SHARES = (0.3, 0.2, 0.9, 0.6)


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One workload class k: an architecture at a request shape.

    ``cfg`` is the model actually executed (smoke-scale on this container);
    ``energy_cfg`` (default: cfg) is the architecture whose parameter count
    prices the job — pass the FULL config so the control plane sees
    production-scale energy while execution stays CPU-sized.
    """

    name: str
    cfg: ModelConfig
    energy_cfg: ModelConfig | None = None
    prompt_len: int = 32
    gen_len: int = 8
    arrival_rate: float = 6.0     # jobs / slot (Poisson)

    def flops_per_job(self) -> float:
        toks = self.prompt_len + self.gen_len
        ecfg = self.energy_cfg or self.cfg
        return 6.0 * ecfg.active_param_count() * toks

    def energy_per_job_j(self) -> float:
        """IT-side energy per job (Joules): chip-seconds × TDP."""
        chip_seconds = self.flops_per_job() / (CHIP_PEAK_FLOPS * CHIP_EFFICIENCY)
        return chip_seconds * CHIP_TDP_W

    def stage_compute(self) -> tuple[float, float]:
        """(prefill, decode) compute shares — token-proportional split."""
        toks = float(self.prompt_len + self.gen_len)
        return self.prompt_len / toks, self.gen_len / toks

    def kv_gb(self) -> float:
        """Prefill → decode handoff volume per job (GB): the KV cache.

        Priced at the production architecture (``energy_cfg``), bf16:
        2 (K and V) × layers × kv_heads × head_dim × prompt tokens.
        Attention-free (SSM) backbones hand off the recurrent state
        snapshot instead.
        """
        ecfg = self.energy_cfg or self.cfg
        if ecfg.has_attention:
            by = (2 * ecfg.num_layers * ecfg.num_kv_heads
                  * ecfg.resolved_head_dim * self.prompt_len * 2)
        else:
            by = ecfg.num_layers * ecfg.d_inner * ecfg.ssm_state * 2
        return by / 1e9


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Static serving-fleet knobs.

    ``capacity_shares`` shorter (or longer) than ``n_pods`` is completed
    deterministically in ``__post_init__`` by cycling the given tuple —
    ``FleetConfig(n_pods=8)`` runs end-to-end instead of crashing in the
    straggler-noise Poisson draw. An empty tuple raises.
    """

    n_pods: int = 4
    horizon_slots: int = 32
    v: float = 1.0
    seed: int = 0
    batch_per_exec: int = 4       # jobs executed per model invocation
    capacity_shares: tuple = DEFAULT_CAPACITY_SHARES   # pod throughput skew
    admit_max: float | None = None    # per-class per-slot admission cap
    slo_backlog: float = 50.0     # per-class backlog SLO threshold
    exec_cap: int | None = 8      # real-execution throttle per (class, slot)
                                  # (smoke-scale containers; None = every
                                  # drained job executes)
    dispatch: str = "staged"      # "staged" (joint stage scheduler) or
                                  # "kernel" (gmsa_dispatch impl="kernel")
    hedge_threshold: float | None = None
                                  # speculative re-execution: clone a
                                  # dispatched stage to the runner-up pod
                                  # when its effective service rate falls
                                  # below this fraction of the runner-up's
                                  # (staged dispatch only; None = off)

    def __post_init__(self):
        shares = tuple(float(s) for s in self.capacity_shares)
        if not shares:
            raise ValueError("capacity_shares must not be empty")
        if len(shares) != self.n_pods:
            shares = tuple(
                itertools.islice(itertools.cycle(shares), self.n_pods)
            )
            object.__setattr__(self, "capacity_shares", shares)
        if self.dispatch not in ("staged", "kernel"):
            raise ValueError(f"unknown dispatch impl {self.dispatch!r}")
        if self.hedge_threshold is not None:
            if self.dispatch != "staged":
                raise ValueError(
                    "hedge_threshold requires the staged dispatcher"
                )
            if not self.hedge_threshold > 0.0:
                raise ValueError(
                    f"hedge_threshold must be > 0, got {self.hedge_threshold}"
                )


class ServeScenario(NamedTuple):
    """The shared scenario a serving run and ``simulate_staged`` agree on.

    ``inputs.arrivals`` is the ADMITTED trace (post admission control) and
    ``inputs.data_dist`` the replica-read serving distribution — feed this
    bundle to ``simulate_staged(inputs, dag, wan, policy, ...)`` and a
    dispatch-only ``FleetEngine.run`` replays it bit for bit.
    """

    inputs: SimInputs       # arrivals (T,K) admitted, mu (T,N,K), ...
    dag: StageDag           # (K, 2) prefill -> decode chain
    wan: WanModel           # KV-handoff pricing
    raw_arrivals: Array     # (T, K) pre-admission request counts
    rejected: Array         # (T, K) load shed at the door
    layout: Array           # (K, N) dataset/KV-prefix placement layout
    reads: Array            # (K, N, N) replica-read assignment (one-hot)


def build_serve_scenario(
    fcfg: FleetConfig,
    classes: list[RequestClass],
    omega: np.ndarray,
    pue: np.ndarray,
    r: np.ndarray,
    *,
    up: Array | None = None,
    down: Array | None = None,
    layout: Array | None = None,
) -> ServeScenario:
    """Build the scenario bundle the engine and the simulator share.

    Arrivals and straggler-noise capacities for the WHOLE horizon come
    from one batched inverse-CDF draw (:mod:`repro.traces.arrivals` —
    the per-slot ``np.random`` loop is gone); admission control splits
    them exactly; prefill routing is the placement layer's cheapest-live-
    replica read assignment averaged over (uniform) reader locations.
    """
    n, k = fcfg.n_pods, len(classes)
    t_slots = fcfg.horizon_slots
    key = jax.random.key(fcfg.seed)

    # Price/PUE traces tiled to the horizon (callers may pass shorter).
    idx = np.arange(t_slots)
    omega_t = jnp.asarray(omega, jnp.float32)[idx % len(omega)]
    pue_t = jnp.asarray(pue, jnp.float32)[idx % len(pue)]

    # Batched arrival ingestion + straggler-noise capacity: one
    # searchsorted for the whole horizon.
    rates = np.asarray([rc.arrival_rate for rc in classes], np.float64)
    arr_cdf, mu_cdf = serve_rate_tables(rates, fcfg.capacity_shares)
    ka, km = jax.random.split(jax.random.fold_in(key, 1))
    raw_arrivals, mu = poisson_pair_from_tables(
        ka, km, jnp.asarray(arr_cdf), jnp.asarray(mu_cdf), t_slots
    )
    admitted, rejected = admission_split(raw_arrivals, fcfg.admit_max)

    if up is None or down is None:
        up = jnp.full((n,), 10.0, jnp.float32)
        down = jnp.full((n,), 10.0, jnp.float32)
    wan = wan_topology(jnp.asarray(up), jnp.asarray(down))
    if layout is None:
        layout = jnp.full((k, n), 1.0 / n, jnp.float32)
    layout = jnp.asarray(layout, jnp.float32)

    # Replica reads pick the serving pod: each (uniformly located) reader
    # pulls from its cheapest live replica at the horizon-mean energy
    # price; the class's prefill serving distribution is the read
    # assignment averaged over readers.
    wpue_bar = jnp.mean(omega_t * pue_t, axis=0)                   # (N,)
    reads = replica_read_assignment(layout, wan, wpue_bar)         # (K,N,N)
    serve_dist = jnp.mean(reads, axis=1)                           # (K, N)

    # Prefill -> decode as a 2-stage chain: compute split token-
    # proportional, the KV cache as the inter-stage shuffle volume.
    comp = jnp.asarray([rc.stage_compute() for rc in classes], jnp.float32)
    shuf = jnp.asarray([[0.0, rc.kv_gb()] for rc in classes], jnp.float32)
    dag = chain_dag(comp, shuf)

    p_it = jnp.asarray(
        [rc.energy_per_job_j() / 3.6e6 for rc in classes], jnp.float32
    )  # kWh/job — priced by omega in $/MWh => dollars×1e-3 scale
    inputs = SimInputs(
        arrivals=admitted, mu=mu, omega=omega_t, pue=pue_t,
        r=jnp.asarray(r, jnp.float32), p_it=p_it, data_dist=serve_dist,
    )
    return ServeScenario(
        inputs=inputs, dag=dag, wan=wan, raw_arrivals=raw_arrivals,
        rejected=rejected, layout=layout, reads=reads,
    )


def serve_policy(fcfg: FleetConfig, scenario: ServeScenario):
    """The dispatch policy of a serving run — the simulator's own.

    ``"staged"`` is the joint stage scheduler (prefill pinned to the
    replica-read layout, decode site scored drift-plus-penalty with the
    KV pull priced); ``"kernel"`` routes the per-slot decision through
    ``gmsa_dispatch(impl="kernel")`` — the fleet-scale Pallas path —
    adapted by ``stage_oblivious`` (prefill stays layout-pinned).
    """
    if fcfg.dispatch == "kernel":
        base = make_kernel_policy(scenario.inputs.r, p_it=scenario.inputs.p_it)
        return stage_oblivious(base, pin_map=True)
    return make_staged_policy(scenario.dag, scenario.wan, pin_map=True,
                              hedge=fcfg.hedge_threshold)


class FleetEngine:
    """Slot-driven serving loop, dispatched by the simulation stack."""

    def __init__(
        self,
        fcfg: FleetConfig,
        classes: list[RequestClass],
        omega: np.ndarray,          # (T, N) price traces
        pue: np.ndarray,            # (T, N)
        r: np.ndarray,              # (K, N, N) task-allocation ratios
        *,
        up: Array | None = None,    # (N,) access bandwidths (KV pricing)
        down: Array | None = None,
        layout: Array | None = None,   # (K, N) placement layout
        alive: np.ndarray | None = None,  # (T, N) pod-alive mask
        telemetry: TelemetryConfig | None = None,
        health: np.ndarray | None = None,  # (T, N) pod health in [0, 1]
        link_health: np.ndarray | None = None,  # (T, N, N) WAN link factor
    ):
        self.fcfg = fcfg
        # The distribution layer (ISSUE 8): a TelemetryConfig with a
        # HistogramSpec threads a per-class FIFO sojourn clock through the
        # jitted step — OFF/None leaves the step's jaxpr untouched.
        self.telemetry = telemetry
        self._hist_on = _tel_hist(telemetry)
        self.classes = classes
        self.omega, self.pue, self.r = omega, pue, r
        self.key = jax.random.key(fcfg.seed)
        self.params = {}
        self._decode_jit = {}
        self._prefill_jit = {}
        for rc in classes:
            self.key, sub = jax.random.split(self.key)
            self.params[rc.name] = init_params(sub, rc.cfg, jnp.float32)
            self._prefill_jit[rc.name], self._decode_jit[rc.name] = (
                make_local_exec(rc.cfg, rc.gen_len)
            )
        self.scenario = build_serve_scenario(
            fcfg, classes, omega, pue, r, up=up, down=down, layout=layout
        )
        self.health = None
        if health is not None:
            health = np.asarray(health, np.float32)
            if health.shape != (fcfg.horizon_slots, fcfg.n_pods):
                raise ValueError(
                    f"health must be (T={fcfg.horizon_slots}, "
                    f"N={fcfg.n_pods}), got {health.shape}"
                )
            self.health = health
            # Hoisted exactly like the scan engines: stragglers serve
            # slower everywhere downstream (dispatch scoring, the hedge
            # trigger, the drain), the per-slot step never sees the
            # factor. All-ones health is the * 1.0 identity — the
            # scenario stays bitwise, and so does every replay pin.
            inputs = self.scenario.inputs
            self.scenario = self.scenario._replace(
                inputs=inputs._replace(
                    mu=inputs.mu * jnp.asarray(health)[:, :, None]
                )
            )
        self.link_health = None
        if link_health is not None:
            link_health = np.asarray(link_health, np.float32)
            if link_health.shape != (
                fcfg.horizon_slots, fcfg.n_pods, fcfg.n_pods
            ):
                raise ValueError(
                    f"link_health must be (T={fcfg.horizon_slots}, "
                    f"N={fcfg.n_pods}, N={fcfg.n_pods}), "
                    f"got {link_health.shape}"
                )
            self.link_health = link_health
        self.p_it = self.scenario.inputs.p_it
        self.policy = serve_policy(fcfg, self.scenario)
        if getattr(self.policy, "consumes_key", True):
            raise ValueError(
                "FleetEngine dispatch policies must be key-free "
                "(consumes_key=False) so the serving loop carries no PRNG "
                "chain — both built-in dispatch impls are"
            )
        self.alive = None
        if alive is not None:
            alive = np.asarray(alive, np.float32)
            if alive.shape != (fcfg.horizon_slots, fcfg.n_pods):
                raise ValueError(
                    f"alive mask must be (T={fcfg.horizon_slots}, "
                    f"N={fcfg.n_pods}), got {alive.shape}"
                )
            self.alive = alive
        self._step = self._make_step(faulty=self.alive is not None)

    # ------------------------------------------------------------------
    # the per-slot control-plane step (jitted once per engine)
    # ------------------------------------------------------------------
    def _make_step(self, faulty: bool):
        pol = self.policy
        dag = self.scenario.dag
        returns_flow = getattr(pol, "returns_flow", False)
        returns_hedge = getattr(pol, "returns_hedge", False)
        key0 = jax.random.key(0)   # signature filler: key-free policies only
        hist_on = self._hist_on
        spec = self.telemetry.hist if hist_on else None

        def core(q, arrivals, mu, e_cost, mu_stages, dd_t, wpue_t, v):
            ret = pol(key0, q, arrivals, mu, e_cost, (dd_t, wpue_t), v)
            q_next, f, acc, in_stack = staged_slot_update(
                dag, q, ret, arrivals, mu_stages, returns_flow, returns_hedge
            )
            if returns_hedge:
                # Queues drained at the first-completion boosted rates;
                # ``done`` must drain the same flow, so hand the boosted
                # rates (and the clone matrix, for the honest post-run
                # bill) back to the step. Hedge off keeps ``mu_stages``
                # itself — the non-hedging step's jaxpr is untouched.
                g = ret[2]
                return q_next, f, acc, in_stack, g, hedged_mu(f, g, mu_stages)
            return q_next, f, acc, in_stack, None, mu_stages

        def clock(age, hist, admitted, done):
            # Sojourn inflow is ADMITTED mass only — recovery-burst
            # re-injections keep their original clock, so re-executed
            # work shows up as tail latency rather than restarting at 0.
            completed = jnp.sum(done[:, :, -1], axis=0)            # (K,)
            return sojourn_step(spec, age, hist, admitted, completed)

        if not faulty:
            if not hist_on:
                @jax.jit
                def step(q, arrivals, mu, e_cost, mu_stages, dd_t, wpue_t, v):
                    q_next, f, acc, in_stack, g, mu_eff = core(
                        q, arrivals, mu, e_cost, mu_stages, dd_t, wpue_t, v
                    )
                    done = jnp.minimum(acc, mu_eff)
                    out = (q_next, f, acc, in_stack, done, jnp.float32(0.0))
                    if returns_hedge:
                        out = out + (g,)
                    return out
                return step

            @jax.jit
            def step(q, arrivals, mu, e_cost, mu_stages, dd_t, wpue_t, v,
                     age, hist):
                q_next, f, acc, in_stack, g, mu_eff = core(
                    q, arrivals, mu, e_cost, mu_stages, dd_t, wpue_t, v
                )
                done = jnp.minimum(acc, mu_eff)
                age, hist = clock(age, hist, arrivals, done)
                out = (q_next, f, acc, in_stack, done, jnp.float32(0.0))
                if returns_hedge:
                    out = out + (g,)
                return out + (age, hist)
            return step

        @jax.jit
        def step(q, arrivals, mu, e_cost, mu_stages, dd_t, wpue_t, v,
                 alive_t, died_t, *tel):
            admitted0 = arrivals   # pre-burst: the sojourn inflow
            any_died = jnp.any(died_t > 0.5)
            any_dead = jnp.any(alive_t < 0.5)
            # Recovery drain, mirroring the placement controller's fault
            # path: wipe dead pods' queues (a SELECT — ``* alive`` would
            # leave -0.0 ULP residue), re-inject the drained backlog as a
            # prefill-stage arrival burst (the KV cache died with the pod:
            # in-flight decode work re-executes from scratch), and route
            # around the dead pods by what the policy SEES — zero service,
            # prohibitive energy, survivor-renormalized prefill layout —
            # so its within-slot flow walk (in_stack) stays consistent
            # with the dispatch it returns. Every rewrite is gated on
            # any_dead / exact (* 1.0), so an all-ones mask is bit-exact
            # with the no-fault step.
            q_wiped = jnp.where(alive_t[:, None, None] > 0.5, q, 0.0)
            burst = jnp.sum(q * died_t[:, None, None], axis=(0, 2))   # (K,)
            q = jnp.where(any_dead, q_wiped, q)
            arrivals = arrivals + jnp.where(any_died, burst, 0.0)
            mu = mu * alive_t[:, None]
            mu_stages = mu_stages * alive_t[:, None, None]
            e_cost = jnp.where(
                jnp.logical_and(any_dead, alive_t[None, :] < 0.5),
                1e30, e_cost,
            )
            n_alive = jnp.maximum(jnp.sum(alive_t), 1.0)
            unif = jnp.broadcast_to((alive_t / n_alive)[None, :], dd_t.shape)
            dd_m = survivor_renorm(dd_t * alive_t[None, :], unif, axis=1)
            dd_t = jnp.where(any_dead, dd_m, dd_t)
            q_next, f, acc, in_stack, g, mu_eff = core(
                q, arrivals, mu, e_cost, mu_stages, dd_t, wpue_t, v
            )
            done = jnp.minimum(acc, mu_eff)
            out = (q_next, f, acc, in_stack, done, jnp.sum(burst))
            if returns_hedge:
                out = out + (g,)
            if hist_on:
                age, hist = clock(tel[0], tel[1], admitted0, done)
                out = out + (age, hist)
            return out

        return step

    def _execute_jobs(self, rc: RequestClass, n_jobs: int) -> tuple[int, float]:
        """Run real prefill+decode for EXACTLY n_jobs; returns (done, secs).

        The final batch is sliced to the remainder instead of over-running
        (and over-timing) up to ``batch_per_exec - 1`` phantom jobs.
        """
        if n_jobs <= 0:
            return 0, 0.0
        b = self.fcfg.batch_per_exec
        done = 0
        t0 = time.perf_counter()
        self.key, sub = jax.random.split(self.key)
        tokens = jax.random.randint(
            sub, (b, rc.prompt_len), 0, rc.cfg.vocab_size, dtype=jnp.int32
        )
        while done < n_jobs:
            nb = min(b, n_jobs - done)
            logits, cache = self._prefill_jit[rc.name](
                self.params[rc.name], tokens[:nb]
            )
            tok = jnp.argmax(logits[:, -1:, : rc.cfg.vocab_size], axis=-1).astype(jnp.int32)
            for _ in range(rc.gen_len):
                logits, cache = self._decode_jit[rc.name](
                    self.params[rc.name], cache, tok
                )
                tok = jnp.argmax(logits[:, :, : rc.cfg.vocab_size], axis=-1).astype(jnp.int32)
            tok.block_until_ready()
            done += nb
        return done, time.perf_counter() - t0

    def run(self, execute_real: bool = True, stream=None) -> dict:
        """Run the serving loop. Returns per-slot traces + summary.

        Args:
            execute_real: run real prefill+decode for drained jobs (only
                completed decode drains execute, throttled at
                ``fcfg.exec_cap`` per class per slot).
            stream: optional callable receiving one JSON-ready dict per
                slot as the run progresses (live telemetry), emitted
                through ``jax.experimental.io_callback`` (``ordered=True``)
                — metric records every slot, plus a
                ``{"type": "event", "code": "recovery", ...}`` record on
                every pod-death edge, in slot order.

        The returned dict keeps its original keys (backward-compatible:
        ``cost``/``backlog``/``dispatch``/``exec_seconds``/``mean_cost``/
        ``final_backlog``/``history``) and adds the staged serving
        telemetry: admission splits, per-class served/completed mass,
        the KV-handoff WAN bill, SLO violations and recovery events.
        ``history[t]["energy_j"]`` prices jobs actually served.
        """
        fcfg = self.fcfg
        scn = self.scenario
        inputs = scn.inputs
        dag = scn.dag
        n, k = fcfg.n_pods, len(self.classes)
        s_max = dag.s_max
        t_slots = fcfg.horizon_slots
        v = jnp.float32(fcfg.v)

        # Hoisted tables — the simulator's own (bitwise: the parity pin).
        e_cost_all, _ = _energy_tables(inputs)                     # (T, K, N)
        wpue_all = inputs.omega * inputs.pue                       # (T, N)
        mu_stage_all = stage_service_rates_all(inputs.mu, dag)     # (T,N,K,S)
        ec_stage_all = (
            e_cost_all[:, :, None, :] * dag.compute[None, :, :, None]
        )                                                          # (T,K,S,N)

        e_per_job = np.asarray(
            [rc.energy_per_job_j() for rc in self.classes], np.float64
        )
        compute_np = np.asarray(dag.compute)                       # (K, S)
        admitted_np = np.asarray(inputs.arrivals)
        rejected_np = np.asarray(scn.rejected)
        faulty = self.alive is not None
        if faulty:
            alive_prev = np.concatenate(
                [np.ones((1, n), np.float32), self.alive[:-1]], axis=0
            )
            died_np = alive_prev * (1.0 - self.alive)

        emit = None
        if stream is not None:
            from jax.experimental import io_callback

            def _host_emit(kind_, t_, a, b_, c, d, e_, g):
                if int(kind_) == 0:
                    stream({
                        "type": "metric", "engine": "serve",
                        "t": int(t_), "cost": float(a),
                        "backlog": float(b_), "admitted": float(c),
                        "rejected": float(d), "served": float(e_),
                        "slo_viol": int(g),
                    })
                else:
                    stream({
                        "type": "event", "engine": "serve",
                        "code": "recovery", "t": int(t_),
                        "drained": float(a), "pod": int(b_),
                        "n_died": int(c),
                    })

            @jax.jit
            def emit(kind_, t_, a, b_, c, d, e_, g):
                io_callback(_host_emit, None, kind_, t_, a, b_, c, d, e_, g,
                            ordered=True)

        q = jnp.zeros((n, k, s_max), jnp.float32)
        hist_on = self._hist_on
        hedging = getattr(self.policy, "returns_hedge", False)
        if hist_on:
            # Per-class FIFO sojourn clock: the age ring is bounded by the
            # horizon (no request can wait longer than the run).
            age, soj_hist = sojourn_init(self.telemetry.hist, k, t_slots)
        f_slots, in_slots, done_slots = [], [], []
        g_slots, acc_slots = [], []
        history: list[dict] = []
        events: list[dict] = []
        backlogs = []
        exec_secs, exec_jobs = 0.0, 0
        served_np = np.zeros((t_slots, k))
        completed_np = np.zeros((t_slots, k))

        for t in range(t_slots):
            args = (
                q, inputs.arrivals[t], inputs.mu[t], e_cost_all[t],
                mu_stage_all[t], inputs.data_dist, wpue_all[t], v,
            )
            if faulty:
                args = args + (
                    jnp.asarray(self.alive[t]), jnp.asarray(died_np[t]),
                )
            if hist_on:
                args = args + (age, soj_hist)
            res = self._step(*args)
            if hist_on:
                res, (age, soj_hist) = res[:-2], res[-2:]
            q, f, acc, in_stack, done, drained = res[:6]
            if hedging:
                g_slots.append(res[6])
                acc_slots.append(acc)
            f_slots.append(f)
            in_slots.append(in_stack)
            done_slots.append(done)

            done_np = np.asarray(done)                             # (N, K, S)
            served_k = (done_np * compute_np[None]).sum(axis=(0, 2))
            completed_k = done_np[:, :, -1].sum(axis=0)
            served_np[t] = served_k
            completed_np[t] = completed_k
            energy_j = served_k * e_per_job                        # SERVED-priced
            q_np = np.asarray(q)
            q_class = q_np.sum(axis=(0, 2))                        # (K,)
            slo_viol = q_class > fcfg.slo_backlog
            backlogs.append(float(q_np.sum()))

            rec = {
                "t": t,
                # Manager pod per class: where the decode (response) stage
                # landed this slot.
                "choice": np.argmax(np.asarray(f)[:, :, -1], axis=0).tolist(),
                "q_pod": q_np.sum(axis=(1, 2)).tolist(),
                "energy_j": energy_j.tolist(),
                "admitted": admitted_np[t].tolist(),
                "rejected": rejected_np[t].tolist(),
                "served": served_k.tolist(),
                "completed": completed_k.tolist(),
                "slo_viol": slo_viol.tolist(),
            }
            if faulty and died_np[t].sum() > 0.5:
                ev = {
                    "type": "event", "code": "recovery", "t": t,
                    "pod": int(np.argmax(died_np[t])),
                    "n_died": int(died_np[t].sum()),
                    "drained": float(drained),
                }
                events.append(ev)
                rec["recovery"] = ev
            history.append(rec)

            if execute_real:
                for ki, rc in enumerate(self.classes):
                    njobs = int(round(completed_k[ki]))
                    if fcfg.exec_cap is not None:
                        njobs = min(njobs, fcfg.exec_cap)
                    ndone, secs = self._execute_jobs(rc, njobs)
                    exec_secs += secs
                    exec_jobs += ndone
            if emit is not None:
                cost_t = jnp.sum(
                    (f * in_stack[None]) * ec_stage_all[t].transpose(2, 0, 1)
                )
                emit(jnp.int32(0), jnp.int32(t), cost_t,
                     jnp.float32(backlogs[-1]),
                     jnp.float32(admitted_np[t].sum()),
                     jnp.float32(rejected_np[t].sum()),
                     jnp.float32(served_k.sum()),
                     jnp.int32(int(slo_viol.sum())))
                if faulty and died_np[t].sum() > 0.5:
                    emit(jnp.int32(1), jnp.int32(t), drained,
                         jnp.float32(np.argmax(died_np[t])),
                         jnp.float32(died_np[t].sum()),
                         jnp.float32(0), jnp.float32(0), jnp.int32(0))

        # Post-run billing: the simulator's own batched expressions over
        # the stacked per-slot outputs — identical reduction order to
        # simulate_staged's post-scan block, so a dispatch-only run's cost
        # series replays the one simulate_staged reports on this scenario.
        f_trace = jnp.stack(f_slots)                               # (T,N,K,S)
        in_all = jnp.stack(in_slots)                               # (T,K,S)
        done_all = jnp.stack(done_slots)                           # (T,N,K,S)
        fa_all = f_trace * in_all[:, None]
        cost = jnp.sum(fa_all * ec_stage_all.transpose(0, 3, 1, 2),
                       axis=(1, 2, 3))                             # (T,)
        dd_all = jnp.broadcast_to(inputs.data_dist, (t_slots, k, n))
        src_all, dst_all, vol_all = staged_shuffle_mixes(
            f_trace, in_all, done_all, dd_all, dag
        )
        wan_c, wan_e, wan_gb = plan_cost(
            src_all.reshape(t_slots, s_max * k, n),
            dst_all.reshape(t_slots, s_max * k, n),
            vol_all.reshape(t_slots, s_max * k),
            scn.wan, inputs.omega, inputs.pue,
        )
        if self.link_health is not None:
            # Degraded-link premium on the KV-handoff traffic — the same
            # additive surcharge simulate_staged applies (exact zero on
            # an all-nominal trace, so the replay pin survives).
            sur_c, sur_e = degraded_surcharge(
                src_all.reshape(t_slots, s_max * k, n),
                dst_all.reshape(t_slots, s_max * k, n),
                vol_all.reshape(t_slots, s_max * k),
                scn.wan, inputs.omega, inputs.pue,
                jnp.asarray(self.link_health),
            )
            wan_c = wan_c + sur_c
            wan_e = wan_e + sur_e
        if hedging:
            # The honest speculation bill, identical to simulate_staged's
            # post-scan block: boost-attributable completions billed at
            # the clone pod's stage energy plus the expected KV pull.
            g_all = jnp.stack(g_slots)                         # (T,N,K,S)
            acc_all = jnp.stack(acc_slots)                     # (T,N,K,S)
            mu_used = mu_stage_all
            if faulty:
                mu_used = mu_used * jnp.asarray(
                    self.alive
                )[:, :, None, None]
            boost_all = jnp.sum(g_all * mu_used, axis=1)       # (T,K,S)
            mu_eff_all = mu_used + f_trace * boost_all[:, None]
            hedge_c, hedge_gb, hedged_jobs = _hedge_bill(
                dag, scn.wan, g_all, acc_all, mu_used, mu_eff_all,
                ec_stage_all, src_all, wpue_all,
            )
        else:
            hedge_c = hedge_gb = hedged_jobs = jnp.zeros(
                (t_slots,), jnp.float32
            )
        hedge_costs = np.asarray(hedge_c)
        hedged_np = np.asarray(hedged_jobs)
        for t, h in enumerate(history):
            h["hedged_jobs"] = float(hedged_np[t])
        costs = np.asarray(cost)
        wan_costs = np.asarray(wan_c)
        slo_viol_frac = np.mean(
            [h["slo_viol"] for h in history], axis=0
        )

        out_tel = {}
        if hist_on:
            spec = self.telemetry.hist
            counts = np.asarray(soj_hist)                          # (K, B)
            out_tel = {
                "sojourn_hist": counts,
                "sojourn_spec": dataclasses.asdict(spec),
                "class_names": [rc.name for rc in self.classes],
                "sojourn_percentiles": percentile_table(
                    counts, spec, names=[rc.name for rc in self.classes]
                ),
            }

        return {
            **out_tel,
            "cost": costs,
            "backlog": np.asarray(backlogs),
            "dispatch": np.asarray(f_trace),
            "exec_seconds": exec_secs,
            "exec_jobs": exec_jobs,
            "mean_cost": float(np.mean(costs)),
            "final_backlog": backlogs[-1],
            "history": history,
            "q_final": np.asarray(q),
            "wan_cost": wan_costs,
            "wan_gb": np.asarray(wan_gb),
            "wan_energy": np.asarray(wan_e),
            "hedge_cost": hedge_costs,
            "hedge_gb": np.asarray(hedge_gb),
            "hedged_jobs": hedged_np,
            "total_billed_cost": float(
                costs.sum() + wan_costs.sum() + hedge_costs.sum()
            ),
            "raw_arrivals": np.asarray(scn.raw_arrivals),
            "admitted": admitted_np,
            "rejected": rejected_np,
            "served": served_np,
            "completed": completed_np,
            "slo_viol_frac": slo_viol_frac,
            "events": events,
        }
