"""Sharded serving steps (prefill + decode) for any (arch × mesh).

Decode shapes in the assignment ("decode_32k", "long_500k") lower
``serve_step`` — one new token against a KV/state cache of ``seq_len`` —
NOT ``train_step``. The cache is sharded batch×("pod","data") and
heads×"model"; for batch=1 long-context cells the batch axes fall back to
replication (the cell is latency-bound; recorded in the roofline notes).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    logits_pspec,
    param_pspecs,
)
from repro.models.lm import decode_step, prefill_step


def make_decode_step(
    cfg: ModelConfig, mesh: Mesh, unroll_layers: bool = False,
    uniform_pos: bool = True, kv_shard: str = "auto",
):
    """Returns (fn, shardings_for) for the single-token decode step."""
    pspecs = param_pspecs(cfg, mesh)
    from repro.distributed.sharding import resolve_kv_shard
    if kv_shard == "auto":
        kv_shard = resolve_kv_shard(cfg, mesh)

    def fn(params, cache, tokens):
        return decode_step(
            params, cfg, cache, tokens,
            unroll_layers=unroll_layers, uniform_pos=uniform_pos,
            kv_shard=kv_shard,
        )

    def shardings_for(cache_tree, batch_size: int):
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
        cspecs = cache_pspecs(cache_tree, cfg, mesh, batch_size, kv_shard=kv_shard)
        tok_spec = batch_pspecs({"t": jax.ShapeDtypeStruct((batch_size, 1), "int32")},
                                mesh, batch_size)["t"]
        in_shardings = (ns(pspecs), ns(cspecs), NamedSharding(mesh, tok_spec))
        out_shardings = (
            NamedSharding(mesh, logits_pspec(cfg, mesh, batch_size)),
            ns(cspecs),
        )
        return in_shardings, out_shardings

    return fn, pspecs, shardings_for


def make_prefill_step(
    cfg: ModelConfig, mesh: Mesh, attn_impl: str = "blockwise",
    unroll_layers: bool = False,
):
    """Returns (fn, shardings_for) for the prompt-prefill step."""
    pspecs = param_pspecs(cfg, mesh)

    def fn(params, **batch):
        return prefill_step(
            params, cfg,
            batch.get("tokens"),
            prefix_embeds=batch.get("prefix_embeds"),
            attn_impl=attn_impl,
            unroll_layers=unroll_layers,
        )

    def shardings_for(batch_tree, batch_size: int):
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
        bspecs = batch_pspecs(batch_tree, mesh, batch_size)
        return ns(pspecs), ns(bspecs)

    return fn, pspecs, shardings_for
