"""Sharded serving steps (prefill + decode) for any (arch × mesh).

Decode shapes in the assignment ("decode_32k", "long_500k") lower
``serve_step`` — one new token against a KV/state cache of ``seq_len`` —
NOT ``train_step``. The cache is sharded batch×("pod","data") and
heads×"model"; for batch=1 long-context cells the batch axes fall back to
replication (the cell is latency-bound; recorded in the roofline notes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    logits_pspec,
    param_pspecs,
)
from repro.models.lm import decode_step, prefill_step


def make_local_exec(cfg: ModelConfig, gen_len: int):
    """Jitted (prefill_fn, decode_fn) for single-device pod execution.

    The serving engine's pods all run on the local device (capacity
    heterogeneity + wall-clock noise model the geo-distribution); this
    factory owns the jit construction the engine used to inline, so the
    sharded (:func:`make_prefill_step`/:func:`make_decode_step`) and local
    paths live side by side. ``prefill_fn(params, tokens)`` returns
    ``(logits, cache)`` with the cache sized for ``gen_len`` extra tokens;
    ``decode_fn(params, cache, tok)`` advances one token.
    """
    prefill_fn = jax.jit(
        lambda p, t: prefill_step(
            p, cfg, t, cache_dtype=jnp.float32, cache_len=t.shape[1] + gen_len
        )
    )
    decode_fn = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    return prefill_fn, decode_fn


def make_decode_step(
    cfg: ModelConfig, mesh: Mesh, unroll_layers: bool = False,
    uniform_pos: bool = True, kv_shard: str = "auto",
):
    """Returns (fn, shardings_for) for the single-token decode step."""
    pspecs = param_pspecs(cfg, mesh)
    from repro.distributed.sharding import resolve_kv_shard
    if kv_shard == "auto":
        kv_shard = resolve_kv_shard(cfg, mesh)

    def fn(params, cache, tokens):
        return decode_step(
            params, cfg, cache, tokens,
            unroll_layers=unroll_layers, uniform_pos=uniform_pos,
            kv_shard=kv_shard,
        )

    def shardings_for(cache_tree, batch_size: int):
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
        cspecs = cache_pspecs(cache_tree, cfg, mesh, batch_size, kv_shard=kv_shard)
        tok_spec = batch_pspecs({"t": jax.ShapeDtypeStruct((batch_size, 1), "int32")},
                                mesh, batch_size)["t"]
        in_shardings = (ns(pspecs), ns(cspecs), NamedSharding(mesh, tok_spec))
        out_shardings = (
            NamedSharding(mesh, logits_pspec(cfg, mesh, batch_size)),
            ns(cspecs),
        )
        return in_shardings, out_shardings

    return fn, pspecs, shardings_for


def make_prefill_step(
    cfg: ModelConfig, mesh: Mesh, attn_impl: str = "blockwise",
    unroll_layers: bool = False,
):
    """Returns (fn, shardings_for) for the prompt-prefill step."""
    pspecs = param_pspecs(cfg, mesh)

    def fn(params, **batch):
        return prefill_step(
            params, cfg,
            batch.get("tokens"),
            prefix_embeds=batch.get("prefix_embeds"),
            attn_impl=attn_impl,
            unroll_layers=unroll_layers,
        )

    def shardings_for(batch_tree, batch_size: int):
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
        bspecs = batch_pspecs(batch_tree, mesh, batch_size)
        return ns(pspecs), ns(bspecs)

    return fn, pspecs, shardings_for
