"""Atomic, integrity-checked checkpointing for arbitrary pytrees.

Layout:  <dir>/step_<N>/
             manifest.json     — tree structure, leaf paths, shapes, dtypes,
                                 crc32 checksums, user metadata
             <leaf>.npy        — one file per leaf (keystr-derived names)

Atomicity: writes land in ``step_<N>.tmp`` and are renamed only after the
manifest (written last) is fsync'd — a crash mid-write can never leave a
directory that ``latest_step`` would pick up. Restores verify checksums.

Sharded arrays: leaves are gathered to host via ``np.asarray`` (single-host
container); on a real multi-host fleet the same manifest schema holds
per-shard files keyed by process index — the write path is isolated in
``_leaf_to_host`` for that swap.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import shutil
import zlib

import jax
import numpy as np


def _keystr(path) -> str:
    s = jax.tree_util.keystr(path)
    return re.sub(r"[^A-Za-z0-9_.]+", "_", s).strip("_") or "leaf"


def _leaf_to_host(x) -> np.ndarray:
    return np.asarray(x)


def save_tree(directory: str | pathlib.Path, step: int, tree, metadata: dict | None = None) -> pathlib.Path:
    """Atomically write one checkpoint. Returns the final directory."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "metadata": metadata or {},
        "leaves": [],
    }
    names = set()
    for path, leaf in leaves_with_paths:
        name = _keystr(path)
        while name in names:
            name += "_"
        names.add(name)
        arr = _leaf_to_host(leaf)
        np.save(tmp / f"{name}.npy", arr)
        manifest["leaves"].append({
            "name": name,
            "path": jax.tree_util.keystr(path),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        })
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def restore_tree(directory: str | pathlib.Path, step: int, like=None):
    """Restore (tree, metadata); verifies checksums.

    ``like``: an example pytree supplying the structure (leaf values are
    replaced by the restored arrays in flatten order).
    """
    ckpt = pathlib.Path(directory) / f"step_{step:08d}"
    with open(ckpt / "manifest.json") as f:
        manifest = json.load(f)
    import jax.numpy as jnp

    arrays = []
    for leaf in manifest["leaves"]:
        arr = np.load(ckpt / f"{leaf['name']}.npy")
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != leaf["crc32"]:
            raise IOError(
                f"checksum mismatch for {leaf['name']} in {ckpt} "
                f"(expected {leaf['crc32']}, got {crc})"
            )
        arrays.append(jnp.asarray(arr))   # device arrays, like what was saved
    if like is not None:
        flat, treedef = jax.tree_util.tree_flatten(like)
        if len(flat) != len(arrays):
            raise ValueError(
                f"leaf count mismatch: checkpoint has {len(arrays)}, "
                f"template has {len(flat)}"
            )
        return treedef.unflatten(arrays), manifest["metadata"]
    return arrays, manifest["metadata"]


@dataclasses.dataclass
class CheckpointManager:
    """Step-indexed checkpoint directory with retention.

    ``save_async`` snapshots leaves to host (cheap) and writes files on a
    background thread so the train step isn't blocked by disk I/O — the
    standard production pattern; ``wait()`` joins before restore/exit.
    """

    directory: str | pathlib.Path
    keep: int = 3
    save_interval: int = 50

    def __post_init__(self):
        pathlib.Path(self.directory).mkdir(parents=True, exist_ok=True)
        self._pending = None

    def steps(self) -> list[int]:
        out = []
        for p in pathlib.Path(self.directory).glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval == 0

    def save(self, step: int, tree, metadata: dict | None = None):
        save_tree(self.directory, step, tree, metadata)
        self._gc()

    def save_async(self, step: int, tree, metadata: dict | None = None):
        """Non-blocking save: host-snapshot now, write on a worker thread."""
        import threading

        self.wait()
        snapshot = jax.tree.map(_leaf_to_host, tree)
        self._pending_error = None

        def _write():
            try:
                save_tree(self.directory, step, snapshot, metadata)
                self._gc()
            except BaseException as e:  # surface in wait(), never swallow
                self._pending_error = e

        self._pending = threading.Thread(target=_write, daemon=True)
        self._pending.start()

    def wait(self):
        """Join any in-flight async save (call before restore/exit).

        Re-raises any exception the writer thread hit — a silently-failed
        checkpoint must not masquerade as durable progress.
        """
        if self._pending is not None:
            self._pending.join()
            self._pending = None
            err = getattr(self, "_pending_error", None)
            if err is not None:
                self._pending_error = None
                raise err

    def restore(self, like, step: int | None = None):
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        tree, meta = restore_tree(self.directory, step, like)
        return tree, meta, step

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(pathlib.Path(self.directory) / f"step_{s:08d}")
