"""Fault tolerance: failure injection, restart orchestration, elastic re-mesh.

Three layers (DESIGN.md §5):

* **checkpoint/restart** — ``run_with_restarts`` drives a step function,
  checkpointing on the manager's schedule and replaying from the last
  checkpoint after a (simulated or real) failure. The data pipeline is
  seeded-by-step (repro.traces.tokens), so replayed batches are identical —
  a restarted run is bit-reproducible (asserted in tests/test_checkpoint.py).
* **straggler mitigation** — GMSA itself: a slow pod's queue grows, the
  drift term shifts dispatch away (the paper's mechanism *is* the
  mitigation). ``FleetEngine`` models stragglers as service-rate noise.
* **elastic re-mesh** — ``drop_site`` shrinks the control-plane state when a
  pod is lost: its queue backlog is re-injected as an arrival burst and the
  task-allocation ratios / dataset distribution are renormalized over the
  survivors.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    """Raised by FailureInjector to model a node/pod loss."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at given steps (or by seeded coin-flip).

    The failure *schedule* is a pure function of the injector's static
    config — :meth:`fails_at` derives the ``probability`` path's coin from
    ``(seed, step)`` alone, never from call order — so every injector built
    with the same config sees the identical outage schedule. ``_fired``
    only records which scheduled failures this run has already experienced
    (a transient failure does not recur when the surviving run replays the
    step); :func:`run_with_restarts` persists it through checkpoint
    metadata so a *restarted process* does not re-experience them either.
    """

    fail_at_steps: tuple[int, ...] = ()
    probability: float = 0.0
    seed: int = 0
    _fired: set = dataclasses.field(default_factory=set)

    def fails_at(self, step: int) -> bool:
        """Pure schedule membership: does the config fail at ``step``?"""
        if step in self.fail_at_steps:
            return True
        if self.probability > 0:
            rng = np.random.default_rng((self.seed, step))
            return bool(rng.random() < self.probability)
        return False

    def maybe_fail(self, step: int):
        if step not in self._fired and self.fails_at(step):
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")

    def fired_steps(self) -> list[int]:
        """JSON-serializable record of already-experienced failures."""
        return sorted(self._fired)

    def mark_fired(self, steps) -> None:
        """Restore the experienced-failure record (from checkpoint meta)."""
        self._fired.update(int(s) for s in steps)


def run_with_restarts(
    init_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    manager: CheckpointManager,
    total_steps: int,
    injector: FailureInjector | None = None,
    max_restarts: int = 10,
) -> tuple[dict, dict]:
    """Drive ``step_fn`` with checkpoint/restart.

    ``state`` is any pytree dict; ``step_fn(state, step) -> state``.
    Returns (final_state, stats) where stats counts restarts/replays.
    """
    stats = {"restarts": 0, "replayed_steps": 0, "checkpoints": 0}
    state = init_state()
    start = 0
    if manager.latest_step() is not None:
        state, meta, start = manager.restore(state)
        # A restarted process must see the same failure schedule as the one
        # it replaced: failures already experienced (and survived) before
        # the checkpoint must not fire again on replay.
        if injector is not None:
            injector.mark_fired(meta.get("fired_steps", ()))
    step = start
    while step < total_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            state = step_fn(state, step)
            step += 1
            if manager.should_save(step):
                # async: disk I/O overlaps the next steps; restore()/wait()
                # join the in-flight write before any read.
                meta = {"step": step}
                if injector is not None:
                    meta["fired_steps"] = injector.fired_steps()
                manager.save_async(step, state, meta)
                stats["checkpoints"] += 1
        except SimulatedFailure:
            stats["restarts"] += 1
            if stats["restarts"] > max_restarts:
                raise
            manager.wait()   # join any in-flight async write before listing
            latest = manager.latest_step()
            if latest is None:
                stats["replayed_steps"] += step   # cold restart: all lost
                state, step = init_state(), 0
            else:
                state, _, ckpt_step = manager.restore(state)
                stats["replayed_steps"] += step - ckpt_step
                step = ckpt_step
    manager.wait()
    return state, stats


def drop_site(q, r, data_dist, dead: int):
    """Elastic shrink of the GDA control plane when DC/pod ``dead`` is lost.

    Returns (q', r', data_dist', burst) over the surviving N-1 sites:
      * q'          — backlogs with the dead row removed;
      * burst       — the dead site's backlog (K,), to be re-injected as
                      arrivals (those jobs must be re-dispatched);
      * r'          — ratios with dead row/column removed, renormalized;
      * data_dist'  — dataset distribution renormalized (the dead site's
                      replica share redistributes proportionally).
    """
    q = jnp.asarray(q)
    r = jnp.asarray(r)
    data_dist = jnp.asarray(data_dist)
    n = q.shape[0]
    keep = jnp.asarray([i for i in range(n) if i != dead])

    burst = q[dead]
    q2 = q[keep]
    r2 = r[:, keep][:, :, keep]
    r2 = r2 / jnp.maximum(r2.sum(-1, keepdims=True), 1e-9)
    d2 = data_dist[:, keep]
    d2 = d2 / jnp.maximum(d2.sum(-1, keepdims=True), 1e-9)
    return q2, r2, d2, burst


def drop_site_mask(q, data_dist, alive, died=None):
    """Static-shape ``drop_site`` for jit'd control loops (N stays N).

    Where :func:`drop_site` physically removes the dead row (shape change —
    host-side only), this variant zeroes it under an ``alive`` mask so the
    placement controller can run it *inside* ``lax.scan``. Same semantics:
    the dead sites' backlog comes back as an arrival burst, and their
    dataset share re-distributes proportionally over the surviving
    replicas. A dataset whose replicas were *all* on dead sites falls back
    to uniform-over-survivors (restore-from-backup; the WAN bill for it is
    the caller's to charge).

    Args:
        q: (N, K) backlogs.
        data_dist: (K, N) dataset distribution (rows on the simplex).
        alive: (N,) {0,1} mask of surviving sites.
        died: optional (N,) {0,1} mask of *newly* dead sites whose backlog
            forms the burst; defaults to every currently-dead site.

    Returns:
        (q', d_masked, d_drop, burst):
          * q'        — (N, K) backlogs, dead rows zeroed;
          * d_masked  — (K, N) placement with dead shares zeroed (rows sum
                        to the surviving fraction — what is still held);
          * d_drop    — (K, N) renormalized survivor placement (rows back
                        on the simplex — what must be held after recovery);
          * burst     — (K,) the newly-dead sites' backlog to re-inject.
    """
    q = jnp.asarray(q)
    data_dist = jnp.asarray(data_dist)
    alive = jnp.asarray(alive, data_dist.dtype)
    if died is None:
        died = 1.0 - alive
    burst = jnp.sum(q * died[:, None], axis=0)                     # (K,)
    # The wipe must be a select, not `q * alive`: a mask multiply invites
    # XLA to refuse/fuse the backlog recurrence differently and costs a ULP
    # against the no-fault program, breaking the all-alive bit-exactness
    # the controller guarantees.
    q2 = jnp.where(alive[:, None] > 0.5, q, 0.0)
    d_masked = data_dist * alive[None, :]                          # (K, N)
    surviving = jnp.sum(d_masked, axis=1, keepdims=True)           # (K, 1)
    n_alive = jnp.maximum(jnp.sum(alive), 1.0)
    uniform = jnp.broadcast_to(alive / n_alive, d_masked.shape)
    d_drop = jnp.where(
        surviving > 1e-9, d_masked / jnp.maximum(surviving, 1e-9), uniform
    )
    return q2, d_masked, d_drop, burst
