"""Fault tolerance: failure injection, restart orchestration, elastic re-mesh.

Three layers (DESIGN.md §5):

* **checkpoint/restart** — ``run_with_restarts`` drives a step function,
  checkpointing on the manager's schedule and replaying from the last
  checkpoint after a (simulated or real) failure. The data pipeline is
  seeded-by-step (repro.traces.tokens), so replayed batches are identical —
  a restarted run is bit-reproducible (asserted in tests/test_checkpoint.py).
* **straggler mitigation** — GMSA itself: a slow pod's queue grows, the
  drift term shifts dispatch away (the paper's mechanism *is* the
  mitigation). ``FleetEngine`` models stragglers as service-rate noise.
* **elastic re-mesh** — ``drop_site`` shrinks the control-plane state when a
  pod is lost: its queue backlog is re-injected as an arrival burst and the
  task-allocation ratios / dataset distribution are renormalized over the
  survivors.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    """Raised by FailureInjector to model a node/pod loss."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at given steps (or by seeded coin-flip)."""

    fail_at_steps: tuple[int, ...] = ()
    probability: float = 0.0
    seed: int = 0
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")
        if self.probability > 0:
            rng = np.random.default_rng((self.seed, step))
            if rng.random() < self.probability and step not in self._fired:
                self._fired.add(step)
                raise SimulatedFailure(f"random failure at step {step}")


def run_with_restarts(
    init_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    manager: CheckpointManager,
    total_steps: int,
    injector: FailureInjector | None = None,
    max_restarts: int = 10,
) -> tuple[dict, dict]:
    """Drive ``step_fn`` with checkpoint/restart.

    ``state`` is any pytree dict; ``step_fn(state, step) -> state``.
    Returns (final_state, stats) where stats counts restarts/replays.
    """
    stats = {"restarts": 0, "replayed_steps": 0, "checkpoints": 0}
    state = init_state()
    start = 0
    if manager.latest_step() is not None:
        state, _, start = manager.restore(state)
    step = start
    while step < total_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            state = step_fn(state, step)
            step += 1
            if manager.should_save(step):
                # async: disk I/O overlaps the next steps; restore()/wait()
                # join the in-flight write before any read.
                manager.save_async(step, state, {"step": step})
                stats["checkpoints"] += 1
        except SimulatedFailure:
            stats["restarts"] += 1
            if stats["restarts"] > max_restarts:
                raise
            manager.wait()   # join any in-flight async write before listing
            latest = manager.latest_step()
            if latest is None:
                stats["replayed_steps"] += step   # cold restart: all lost
                state, step = init_state(), 0
            else:
                state, _, ckpt_step = manager.restore(state)
                stats["replayed_steps"] += step - ckpt_step
                step = ckpt_step
    manager.wait()
    return state, stats


def drop_site(q, r, data_dist, dead: int):
    """Elastic shrink of the GDA control plane when DC/pod ``dead`` is lost.

    Returns (q', r', data_dist', burst) over the surviving N-1 sites:
      * q'          — backlogs with the dead row removed;
      * burst       — the dead site's backlog (K,), to be re-injected as
                      arrivals (those jobs must be re-dispatched);
      * r'          — ratios with dead row/column removed, renormalized;
      * data_dist'  — dataset distribution renormalized (the dead site's
                      replica share redistributes proportionally).
    """
    q = jnp.asarray(q)
    r = jnp.asarray(r)
    data_dist = jnp.asarray(data_dist)
    n = q.shape[0]
    keep = jnp.asarray([i for i in range(n) if i != dead])

    burst = q[dead]
    q2 = q[keep]
    r2 = r[:, keep][:, :, keep]
    r2 = r2 / jnp.maximum(r2.sum(-1, keepdims=True), 1e-9)
    d2 = data_dist[:, keep]
    d2 = d2 / jnp.maximum(d2.sum(-1, keepdims=True), 1e-9)
    return q2, r2, d2, burst
