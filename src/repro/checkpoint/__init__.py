"""repro.checkpoint — atomic sharded checkpoints + fault tolerance."""

from repro.checkpoint.checkpoint import CheckpointManager, save_tree, restore_tree
from repro.checkpoint.fault import (
    SimulatedFailure,
    FailureInjector,
    run_with_restarts,
    drop_site,
    drop_site_mask,
)

__all__ = [
    "CheckpointManager",
    "save_tree",
    "restore_tree",
    "SimulatedFailure",
    "FailureInjector",
    "run_with_restarts",
    "drop_site",
    "drop_site_mask",
]
