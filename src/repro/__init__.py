"""repro — Energy-efficient analytics for geographically distributed big data (GMSA).

A production-oriented, multi-pod JAX framework implementing the paper's
dynamic Global Manager Selection Algorithm (GMSA, Lyapunov drift-plus-penalty
dispatch) as a first-class scheduling layer for geo-distributed TPU fleets,
together with the full substrate it needs: trace pipelines, a model zoo
(dense / MoE / SSM / hybrid / encoder / VLM backbones), pjit/shard_map
distribution, training + serving runtimes, checkpointing and fault
tolerance, and Pallas TPU kernels for the dispatch and SSD hot spots.

Layout:
    repro.core         — the paper's contribution (queues, energy, GMSA, Iridium)
    repro.placement    — two-timescale data placement & replica selection
    repro.traces       — arrival/price/PUE/bandwidth/token pipelines
    repro.models       — architecture zoo
    repro.distributed  — sharding rules, collectives, compression
    repro.train        — optimizer, train_step, loop
    repro.serve        — KV/state caches, prefill/decode, batching engine
    repro.checkpoint   — atomic sharded checkpoints, fault handling
    repro.kernels      — Pallas TPU kernels (+ pure-jnp oracles)
    repro.configs      — architecture & experiment configs (registry)
    repro.launch       — mesh, dry-run, train/serve entry points
"""

__version__ = "1.0.0"

# Perf (EXPERIMENTS.md §Perf v6): use jax's unrolled threefry lowering on
# CPU — bitwise-identical random streams, ~4x faster bit generation (the
# Monte-Carlo trace builds are threefry-bound). No-op off-CPU / on failure;
# opt out with REPRO_ROLLED_THREEFRY=1.
from repro.core.prngfast import enable_unrolled_threefry_cpu as _unroll_threefry

_unroll_threefry()
del _unroll_threefry
