"""repro.models — the architecture zoo (10 assigned LM-family backbones).

Pure-functional JAX models (no framework deps): params are pytrees with
layer-stacked leaves (leading ``L`` axis) consumed by ``jax.lax.scan``, so
HLO size and compile time are O(1) in depth — essential for the 512-device
dry-runs of the 76B/80L configs on this single-core host.

Modules:
    layers.py     — norms, MLPs, embeddings, RoPE
    attention.py  — GQA attention: naive + blockwise(flash-style) + decode
    moe.py        — top-k routed experts (sort-based dispatch, capacity drop)
    ssm.py        — Mamba-2 SSD (chunked scan) + single-step decode
    lm.py         — init / train & hybrid blocks / decode step / counting
    frontends.py  — vision & audio stubs (precomputed embeddings)
    inputs.py     — batch builders / ShapeDtypeStruct specs per (arch, shape)
"""

from repro.models.lm import (
    init_params,
    forward,
    prefill_step,
    decode_step,
    loss_fn,
    count_params,
    init_cache,
)
from repro.models.inputs import make_batch

__all__ = [
    "init_params",
    "forward",
    "prefill_step",
    "decode_step",
    "loss_fn",
    "count_params",
    "init_cache",
    "make_batch",
]
