"""Batch builders / input specs per (architecture × shape) cell.

One function serves three callers with identical structure:

* smoke tests          — concrete random arrays on CPU;
* the training loop    — concrete arrays from the token pipeline;
* the multi-pod dry-run — ``jax.ShapeDtypeStruct`` stand-ins (``as_spec=True``,
  no allocation, the shannon/kernels pattern).

Frontend stubs (assignment spec): VLM batches carry 256 precomputed
1024-dim patch embeddings per sample alongside text tokens; audio batches
carry per-frame 512-dim embeddings *instead of* tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.frontends import AUDIO_STUB_DIM, VISION_STUB_DIM, VISION_TOKENS


def _mk(key, shape, dtype, kind, vocab=None, as_spec=False):
    if as_spec:
        return jax.ShapeDtypeStruct(shape, dtype)
    if kind == "tokens":
        return jax.random.randint(key, shape, 0, vocab, dtype=dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    return (0.02 * jax.random.normal(key, shape)).astype(dtype)


def make_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    key=None,
    as_spec: bool = False,
    embed_dtype=jnp.bfloat16,
) -> dict:
    """Inputs for train/prefill kinds. Decode tokens come from make_decode_batch."""
    if key is None and not as_spec:
        key = jax.random.key(0)
    keys = jax.random.split(key, 4) if key is not None else [None] * 4
    b, s = shape.global_batch, shape.seq_len
    tok_i32 = jnp.int32
    batch: dict = {}

    if cfg.frontend == "audio":
        batch["prefix_embeds"] = _mk(keys[0], (b, s, AUDIO_STUB_DIM), embed_dtype, "emb", as_spec=as_spec)
        if shape.kind == "train":
            batch["labels"] = _mk(keys[1], (b, s), tok_i32, "tokens", cfg.vocab_size, as_spec)
            batch["loss_mask"] = _mk(keys[2], (b, s), jnp.float32, "ones", as_spec=as_spec)
        return batch

    if cfg.frontend == "vision":
        # 256 image tokens for the assigned shapes; scale down for tiny
        # smoke sequences so the text span stays non-empty.
        n_img = min(VISION_TOKENS, s // 2)
        s_text = s - n_img
        batch["prefix_embeds"] = _mk(keys[0], (b, n_img, VISION_STUB_DIM), embed_dtype, "emb", as_spec=as_spec)
        batch["tokens"] = _mk(keys[1], (b, s_text), tok_i32, "tokens", cfg.vocab_size, as_spec)
        if shape.kind == "train":
            batch["labels"] = _mk(keys[2], (b, s_text), tok_i32, "tokens", cfg.vocab_size, as_spec)
            batch["loss_mask"] = _mk(keys[3], (b, s_text), jnp.float32, "ones", as_spec=as_spec)
        return batch

    batch["tokens"] = _mk(keys[0], (b, s), tok_i32, "tokens", cfg.vocab_size, as_spec)
    if shape.kind == "train":
        batch["labels"] = _mk(keys[1], (b, s), tok_i32, "tokens", cfg.vocab_size, as_spec)
        batch["loss_mask"] = _mk(keys[2], (b, s), jnp.float32, "ones", as_spec=as_spec)
    return batch


def make_decode_tokens(
    cfg: ModelConfig, shape: ShapeConfig, key=None, as_spec: bool = False
):
    """(B, 1) next-token ids for a decode cell."""
    if as_spec:
        return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    if key is None:
        key = jax.random.key(1)
    return jax.random.randint(key, (shape.global_batch, 1), 0, cfg.vocab_size, dtype=jnp.int32)


def cache_spec(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct tree matching ``init_cache`` (for dry-run lowering).

    ``dtype`` may be ``jnp.float8_e4m3fn`` for the quantized-KV variant
    (halves KV HBM; attend_decode upcasts for the einsums).
    """
    from repro.models.lm import init_cache

    shapes = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, dtype,
                           prefilled=shape.seq_len)
    )
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), shapes)
