"""GQA attention: naive, blockwise (flash-style, non-materializing), decode.

Three execution paths share one set of weights:

* ``attend_naive``    — materializes the (S, S) score matrix. Reference path;
  used for short sequences and as the oracle for the blockwise path.
* ``attend_blockwise``— online-softmax over KV chunks via ``lax.scan``; peak
  activation memory O(S·chunk) instead of O(S²). This is the path the 32k
  prefill and all training shapes use (a beyond-paper memory optimization
  recorded in EXPERIMENTS.md §Perf).
* ``attend_decode``   — one query token against a (possibly ring-buffered)
  KV cache.

Masks: causal, bidirectional (encoder), sliding-window (Hymba), all handled
in both naive and blockwise forms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

_NEG_INF = -1e30
#: Sentinel position for padded KV slots; any k_pos below _PAD_LIMIT is
#: excluded by every mask mode (found by the hypothesis sweep: padded keys
#: leaked into *bidirectional* attention, whose mask has no diff test).
_PAD_POS = -(10 ** 9)
_PAD_LIMIT = -(10 ** 8)


def _mask_bias(
    q_pos: Array, k_pos: Array, causal: bool, window: int
) -> Array:
    """(Sq, Sk) additive mask bias from absolute positions."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = jnp.broadcast_to(k_pos[None, :] > _PAD_LIMIT, diff.shape)
    if causal:
        ok = ok & (diff >= 0)
    if window > 0:
        ok = ok & (diff < window)
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def _repeat_kv(k: Array, groups: int) -> Array:
    """(B, S, Hkv, d) -> (B, S, Hkv*groups, d) by head repetition."""
    if groups == 1:
        return k
    b, s, hkv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, groups, d)).reshape(
        b, s, hkv * groups, d
    )


def attend_naive(
    q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
    causal: bool = True, window: int = 0,
) -> Array:
    """Reference attention. q: (B,Sq,H,d); k/v: (B,Sk,Hkv,d). Returns (B,Sq,H,d)."""
    groups = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    logits = logits + _mask_bias(q_pos, k_pos, causal, window)[None, None]
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attend_blockwise(
    q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
    causal: bool = True, window: int = 0, chunk: int = 1024,
    q_chunk: int = 1024,
) -> Array:
    """Flash-style online-softmax attention, blocked over BOTH Q and KV.

    Never materializes (Sq, Sk): the inner ``lax.scan`` runs online softmax
    over KV chunks; the outer ``lax.map`` tiles Q so the live score block is
    (B, H, q_chunk, chunk). Numerics match ``attend_naive`` to bf16 tolerance
    (asserted in tests/test_models.py).
    """
    b, sq, h, d = q.shape
    if sq > q_chunk:
        if sq % q_chunk:
            pad_q = q_chunk - sq % q_chunk
            q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
            q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=q_pos[-1])
        nq = q.shape[1] // q_chunk
        q_tiles = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
        qp_tiles = q_pos.reshape(nq, q_chunk)
        out_tiles = jax.lax.map(
            lambda xs: _attend_blockwise_inner(
                xs[0], k, v, xs[1], k_pos, causal, window, chunk
            ),
            (q_tiles, qp_tiles),
        )
        out = out_tiles.transpose(1, 0, 2, 3, 4).reshape(b, -1, h, d)
        return out[:, :sq]
    return _attend_blockwise_inner(q, k, v, q_pos, k_pos, causal, window, chunk)


def _attend_blockwise_inner(
    q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
    causal: bool, window: int, chunk: int,
) -> Array:
    b, sq, h, d = q.shape
    sk = k.shape[1]
    if sk % chunk:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=_PAD_POS)
        sk += pad
    groups = h // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    n_chunks = sk // chunk
    k = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    v = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    k_pos_c = k_pos.reshape(n_chunks, chunk)
    scale = d ** -0.5

    def body(carry, xs):
        m, l, acc = carry                       # (B,H,Sq), (B,H,Sq), (B,H,Sq,d)
        kc, vc, kp = xs                          # (B,chunk,H,d), ..., (chunk,)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kc).astype(jnp.float32) * scale
        logits = logits + _mask_bias(q_pos, kp, causal, window)[None, None]
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # Guard fully-masked rows: keep m finite so exp() stays 0, not NaN.
        m_safe = jnp.maximum(m_new, -1e29)
        p = jnp.exp(logits - m_safe[..., None])
        alpha = jnp.exp(jnp.clip(m - m_new, a_max=0.0))
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (k, v, k_pos_c))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,Sq,H,d)


def _constrain_seq_sharded(x: Array, seq_axis: int) -> Array:
    """Pin ``x``'s seq dim to "model" AND keep dim 0 batch-sharded
    (split-KV decode).

    No-op outside a mesh context or when "model" is absent. Forcing the
    logits to be sequence-sharded makes XLA emit the flash-decoding
    partition (partial softmax stats + psum) instead of its default
    head-partition, which all-gathers the whole KV cache per layer
    (measured: 43 GB/step on granite-3-2b decode_32k — §Perf A2). The batch
    axes must be named explicitly: an unmentioned dim in a sharding
    constraint means *replicated*, and the partitioner obliges with a
    full-batch all-gather (§Perf A3).
    """
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    if mesh.empty or "model" not in mesh.axis_names:
        return x
    if x.shape[seq_axis] % mesh.shape["model"]:
        return x
    batch_axes = tuple(
        a for a in ("pod", "data")
        if a in mesh.axis_names and x.shape[0] % mesh.shape[a] == 0
    )
    spec = [None] * x.ndim
    if batch_axes:
        spec[0] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    spec[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def attend_decode(
    q: Array, k_cache: Array, v_cache: Array, q_pos: Array, cache_pos: Array,
    window: int = 0, seq_sharded: bool = False,
) -> Array:
    """Single-token attention against a KV cache.

    Grouped-query form: the KV head dim is never materialized ``groups``
    times (the broadcast+reshape of ``_repeat_kv`` blocks SPMD propagation
    through the cache). With ``seq_sharded`` the score/probs tensors are
    constrained to the "model" axis on the cache-seq dim — distributed
    flash-decoding (split-KV), combined by small softmax-stat collectives.

    Args:
        q: (B, 1, H, d) query for the new token.
        k_cache/v_cache: (B, S_cache, Hkv, d). For sliding-window layers this
            is a ring buffer of size ``window``.
        q_pos: (B,) absolute position of the query token.
        cache_pos: (B, S_cache) absolute position per cache slot
            (−1 for unwritten slots).
    Returns: (B, 1, H, d).
    """
    b, _, h, d = q.shape
    if k_cache.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2):
        # Quantized KV cache (direct-cast fp8): upcast for the MXU einsums.
        k_cache = k_cache.astype(jnp.bfloat16)
        v_cache = v_cache.astype(jnp.bfloat16)
    hkv = k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, 1, hkv, g, d)
    scale = d ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache).astype(jnp.float32) * scale
    if seq_sharded:
        logits = _constrain_seq_sharded(logits, 4)
    diff = q_pos[:, None] - cache_pos                 # (B, S_cache)
    ok = (cache_pos >= 0) & (diff >= 0)
    if window > 0:
        ok &= diff < window
    bias = jnp.where(ok, 0.0, _NEG_INF)[:, None, None, None, :]
    probs = jax.nn.softmax(logits + bias, axis=-1).astype(q.dtype)
    if seq_sharded:
        probs = _constrain_seq_sharded(probs, 4)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache)
    return out.reshape(b, 1, h, d)
