"""Mamba-2 SSD (state-space duality) layer — chunked scan + one-step decode.

The SSD forward computes, per head h with state (P, N):

    h_t = exp(A_h * dt_t) * h_{t-1} + dt_t * (x_t  outer  B_t)
    y_t = h_t @ C_t + D_h * x_t

The chunked algorithm (Mamba-2 paper, Sec. 6) splits the sequence into
chunks of length Q: a dense "attention-form" intra-chunk term, a per-chunk
state contraction, an inter-chunk recurrence (lax.scan), and a state
broadcast back into each chunk. State math runs in fp32.

This file is the *reference/pure-JAX* path; ``repro.kernels.ssd_scan`` holds
the Pallas TPU kernel with the same chunk structure (validated against
:func:`ssd_chunked` in interpret mode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array


def ssd_chunked(
    x: Array,       # (B, S, H, P)  inputs per head
    dt: Array,      # (B, S, H)     softplus'd step sizes
    a: Array,       # (H,)          negative decay rates (A = -exp(A_log))
    b_mat: Array,   # (B, S, N)     input projections (G=1 group)
    c_mat: Array,   # (B, S, N)     output projections
    chunk: int,
    h0: Array | None = None,   # (B, H, P, N) initial state
) -> tuple[Array, Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    pad = (-s) % chunk
    if pad:
        # Zero-dt padding steps are exact no-ops for the recurrence
        # (decay exp(0)=1, zero state update, outputs discarded).
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        s_padded = s + pad
    else:
        s_padded = s
    nc = s_padded // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    adt = dtc * a.astype(jnp.float32)                     # (B,NC,Q,H) (negative)
    cum = jnp.cumsum(adt, axis=2)                         # inclusive cumsum
    # Intra-chunk "attention" weights: L[t, s_] = exp(cum_t - cum_s) for t >= s_.
    # Mask BEFORE exp: the upper triangle has positive exponents that overflow,
    # and 0*inf = NaN in the backward pass if exp'd first.
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,NC,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.exp(jnp.where(tri[None, None, :, :, None], seg, -jnp.inf))
    cb = jnp.einsum("bcqn,bcsn->bcqs", cc.astype(jnp.float32), bc.astype(jnp.float32))
    w_intra = cb[..., None] * l_mat * dtc[:, :, None, :, :]      # (B,NC,Q,S=Q,H)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", w_intra, xc.astype(jnp.float32))

    # Per-chunk state contribution: sum_s exp(cum_Q - cum_s) * dt_s * x_s ⊗ B_s.
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,NC,Q,H)
    states = jnp.einsum(
        "bcqh,bcqhp,bcqn->bchpn",
        decay_out * dtc, xc.astype(jnp.float32), bc.astype(jnp.float32),
    )                                                     # (B,NC,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,NC,H)

    def inter(hprev, xs):
        st, dec = xs                                      # (B,H,P,N), (B,H)
        hnext = dec[:, :, None, None] * hprev + st
        return hnext, hprev                               # emit state *entering* chunk

    h_init = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if h0 is None else h0.astype(jnp.float32)
    )
    h_last, h_in = jax.lax.scan(
        inter, h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)                  # (B,NC,H,P,N)

    # Inter-chunk output: y_t += exp(cum_t) * C_t @ h_in.
    y_inter = jnp.einsum(
        "bcqn,bchpn->bcqhp", cc.astype(jnp.float32), h_in
    ) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s_padded, h, p)[:, :s].astype(x.dtype)
    return y, h_last


def ssd_step(
    x: Array,       # (B, H, P)
    dt: Array,      # (B, H)
    a: Array,       # (H,)
    b_vec: Array,   # (B, N)
    c_vec: Array,   # (B, N)
    state: Array,   # (B, H, P, N) fp32
) -> tuple[Array, Array]:
    """One decode step of the SSD recurrence. Returns (y (B,H,P), new_state)."""
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * a.astype(jnp.float32))          # (B, H)
    upd = jnp.einsum(
        "bh,bhp,bn->bhpn", dtf, x.astype(jnp.float32), b_vec.astype(jnp.float32)
    )
    new_state = decay[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, c_vec.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def causal_conv_update(
    conv_state: Array,   # (B, W-1, C) previous inputs
    new: Array,          # (B, C) current input
    w: Array,            # (W, C) depthwise filter
    b: Array,            # (C,)
) -> tuple[Array, Array]:
    """Depthwise causal conv, single step. Returns (out (B,C), new_state)."""
    window = jnp.concatenate([conv_state, new[:, None, :]], axis=1)   # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window, w) + b
    return out, window[:, 1:, :]


def causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, S, C) with filter (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    windows = jnp.stack(
        [xp[:, i : i + x.shape[1], :] for i in range(width)], axis=2
    )                                                     # (B, S, W, C)
    return jnp.einsum("bswc,wc->bsc", windows, w) + b
