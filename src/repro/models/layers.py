"""Shared neural building blocks (functional, framework-free)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array


def trunc_normal(key: Array, shape: tuple, fan_in: int, dtype) -> Array:
    """Truncated-normal init with 1/sqrt(fan_in) scale (standard LM init)."""
    std = fan_in ** -0.5
    return (std * jax.random.truncated_normal(key, -3.0, 3.0, shape)).astype(dtype)


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    """RMSNorm in fp32 statistics (bf16-safe), cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def gelu_mlp(x: Array, w_up: Array, b_up: Array, w_down: Array, b_down: Array) -> Array:
    return jax.nn.gelu(x @ w_up + b_up) @ w_down + b_down


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_table(positions: Array, head_dim: int, theta: float) -> tuple[Array, Array]:
    """(sin, cos) tables for given positions.

    Args:
        positions: (...,) integer positions.
        head_dim: per-head dim (even).
    Returns:
        sin, cos of shape positions.shape + (head_dim // 2,), fp32.
    """
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: Array, sin: Array, cos: Array) -> Array:
    """Rotate pairs (split-half convention). x: (..., S, H, hd); sin/cos: (..., S, half).

    sin/cos broadcast over the head axis.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin_b = sin[..., None, :]   # (..., S, 1, half) broadcasting over heads
    cos_b = cos[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos_b - xf2 * sin_b, xf2 * cos_b + xf1 * sin_b], axis=-1
    )
    return out.astype(x.dtype)
