"""Mixture-of-Experts FFN: top-k routing with sort-based dispatch.

Production dispatch path (MegaBlocks/MaxText-style), chosen for TPU + pjit:

* routing/top-k in fp32;
* *per-sequence* dispatch: the argsort/scatter runs vmapped over the batch
  axis, so with batch sharded over ("pod","data") every device sorts and
  scatters only its local rows — no cross-device scatter, no (T, E, C)
  one-hot dispatch tensor;
* tokens are packed into (E, C, D) capacity buffers by a stable sort over
  expert ids (overflow dropped, standard capacity-factor semantics);
* expert weights are *tensor-parallel over the hidden dim F* ("model" axis),
  i.e. TP-in-expert + DP-over-tokens. Expert-parallelism (sharding E) is the
  alternative; the trade-off is recorded in DESIGN.md §5 and revisited in the
  §Perf hillclimb.
* shared experts (DeepSeekMoE) are a fused dense SwiGLU applied to every
  token.

Returns the load-balancing auxiliary loss (Switch-style) alongside outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.models.layers import swiglu


def expert_capacity(seq_len: int, cfg: ModelConfig, capacity_factor: float) -> int:
    """Static per-sequence expert capacity C (multiple of 8, >= 1)."""
    raw = capacity_factor * seq_len * cfg.top_k / cfg.num_experts
    c = max(int(raw + 0.999), 1)
    return max((c + 7) // 8 * 8, 8) if seq_len >= 64 else c


def route_topk(x: Array, w_router: Array, top_k: int) -> tuple[Array, Array, Array]:
    """fp32 router: returns (gates (S,k), expert_idx (S,k), aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (S, E)
    gates, idx = jax.lax.top_k(probs, top_k)                    # (S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * sum_e fraction_e * mean_prob_e.
    e = probs.shape[-1]
    occupancy = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = occupancy / jnp.maximum(occupancy.sum(), 1.0)
    aux = e * jnp.sum(frac * probs.mean(axis=0))
    return gates, idx, aux


def _dispatch_one_row(x: Array, gates: Array, idx: Array, num_experts: int, cap: int):
    """Pack one sequence's tokens into (E, C, D) buffers via stable sort.

    Returns (buffers, dest, token_src, weight) with dest/token_src/weight flat
    over (S * k,); ``dest`` is an index into the flattened (E*C) buffer and is
    out-of-bounds for capacity-dropped entries (scatter/gather use drop mode).
    """
    s, d = x.shape
    k = idx.shape[-1]
    flat_e = idx.reshape(-1)                                    # (S*k,)
    sort_i = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_i]
    token_src = sort_i // k                                     # (S*k,)
    counts = jnp.bincount(flat_e, length=num_experts)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(s * k) - offsets[sorted_e]
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, s * k + num_experts * cap)
    buf = jnp.zeros((num_experts * cap, d), x.dtype)
    buf = buf.at[dest].set(x[token_src], mode="drop")
    weight = gates.reshape(-1)[sort_i]
    return buf.reshape(num_experts, cap, d), dest, token_src, weight


def _moe_local(x, w_router, we_gate, we_up, we_down, cfg, cap, psum_axis=None):
    """MoE over LOCAL rows (B_local, S, D) — sort/scatter stay on-device.

    ``psum_axis``: when expert weights arrive as local F-shards (manual TP
    inside shard_map), the down-projection partial sums reduce over it.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k

    def per_row(xr):
        gates, idx, aux = route_topk(xr, w_router, k)
        buf, dest, token_src, weight = _dispatch_one_row(xr, gates, idx, e, cap)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, we_gate))
        h = h * jnp.einsum("ecd,edf->ecf", buf, we_up)
        out = jnp.einsum("ecf,efd->ecd", h, we_down)
        if psum_axis is not None:
            out = jax.lax.psum(out, psum_axis)
        out_buf = out.reshape(e * cap, d)
        gathered = jnp.take(out_buf, jnp.minimum(dest, e * cap - 1), axis=0)
        gathered = jnp.where((dest < e * cap)[:, None], gathered, 0.0)
        yr = jnp.zeros((s, d), x.dtype).at[token_src].add(
            (gathered * weight[:, None]).astype(x.dtype)
        )
        return yr, aux

    y, aux = jax.vmap(per_row)(x)
    return y, jnp.mean(aux)


def moe_ffn(
    x: Array,
    w_router: Array,
    we_gate: Array,
    we_up: Array,
    we_down: Array,
    cfg: ModelConfig,
    capacity_factor: float = 1.25,
) -> tuple[Array, Array]:
    """Top-k MoE over (B, S, D) activations.

    Expert weights: we_gate/we_up (E, D, F), we_down (E, F, D).
    Returns (output (B, S, D), aux_loss scalar).

    When an ambient mesh with batch axes exists, the token path runs under a
    FULLY-MANUAL ``shard_map`` over ("pod","data","model"): XLA's SPMD
    partitioner cannot prove the vmapped dispatch scatter parallel over the
    batch dim and falls back to replicating the (B, E·C, D) buffers —
    measured 172 GB/step of all-gathers on phi3.5-moe train_4k
    (EXPERIMENTS.md §Perf B1). Manual batch locality removes them by
    construction; expert weights enter as local F-shards (manual TP) and the
    down-projection partial sums psum over "model" explicitly. (A
    partial-auto shard_map would be lighter, but mixing manual batch axes
    with an auto model axis inside grad+remat trips an XLA crash on this
    backend — documented in §Perf B1.)
    """
    cap = expert_capacity(x.shape[1], cfg, capacity_factor)

    # Deferred: importing repro.distributed at module scope is circular
    # (distributed/__init__ -> sharding -> models.lm -> this module).
    from repro.distributed.compat import get_abstract_mesh
    from repro.distributed.compat import shard_map as _shard_map

    mesh = get_abstract_mesh()
    f = cfg.moe_d_ff or cfg.d_ff
    batch_axes = tuple(
        a for a in ("pod", "data")
        if (not mesh.empty) and a in mesh.axis_names and x.shape[0] % mesh.shape[a] == 0
    )
    model_ok = (
        (not mesh.empty)
        and "model" in mesh.axis_names
        and f % mesh.shape["model"] == 0
    )
    if not batch_axes or not model_ok:
        return _moe_local(x, w_router, we_gate, we_up, we_down, cfg, cap)

    from jax.sharding import PartitionSpec as P

    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def local_fn(xl, wr, wg, wu, wd):
        y, aux = _moe_local(xl, wr, wg, wu, wd, cfg, cap, psum_axis="model")
        return y, jax.lax.pmean(aux, batch_axes)

    return _shard_map(
        local_fn,
        in_specs=(
            P(bspec),                      # x: rows local per batch shard
            P(),                           # router replicated
            P(None, None, "model"),        # we_gate: F-shard
            P(None, None, "model"),        # we_up:   F-shard
            P(None, "model", None),        # we_down: F-shard (row-parallel)
        ),
        out_specs=(P(bspec), P()),
        axis_names=set(batch_axes) | {"model"},
        check_vma=False,
    )(x, w_router, we_gate, we_up, we_down)


def shared_expert_ffn(x: Array, ws_gate: Array, ws_up: Array, ws_down: Array) -> Array:
    """DeepSeekMoE shared experts — a fused dense SwiGLU over all tokens."""
    return swiglu(x, ws_gate, ws_up, ws_down)
