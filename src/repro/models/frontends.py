"""Modality-frontend STUBS (per the assignment spec).

``[audio]`` / ``[vlm]`` architecture entries specify the transformer backbone
only; the modality frontend supplies *precomputed* frame/patch embeddings via
``input_specs()``. These helpers define the stub geometry the launchers and
dry-run share.

* vision (InternVL2): 256 image tokens per sample, 1024-dim patch embeddings
  (the pixel-shuffled InternViT output dimensionality class).
* audio (HuBERT): 50 frames/s conv-extractor output, 512-dim (the wav2vec2
  conv stack's channel width); for shape cells the frame count equals the
  assigned seq_len (the backbone sees one embedding per frame).
"""

from __future__ import annotations

import dataclasses

VISION_STUB_DIM = 1024
VISION_TOKENS = 256
AUDIO_STUB_DIM = 512


@dataclasses.dataclass(frozen=True)
class FrontendSpec:
    kind: str          # "vision" | "audio"
    stub_dim: int
    prefix_tokens: int # embeddings prepended per sample (0 = replaces tokens)


def frontend_spec(kind: str, seq_len: int) -> FrontendSpec | None:
    if kind == "vision":
        return FrontendSpec("vision", VISION_STUB_DIM, VISION_TOKENS)
    if kind == "audio":
        # Encoder consumes frame embeddings only; no token prefix.
        return FrontendSpec("audio", AUDIO_STUB_DIM, 0)
    return None
