"""Model assembly: init / forward / decode / loss / param counting.

All ten assigned architectures are built from one block vocabulary
(dense-attention, MoE-FFN, SSD, hybrid attention+SSD, encoder) selected by
``ModelConfig`` flags. Layer parameters are *stacked* on a leading L axis and
consumed with ``jax.lax.scan`` so HLO size / compile time are depth-
independent (DESIGN.md §5).

Param-shape specs (`layer_param_specs`) are the single source of truth shared
by ``init_params`` and ``count_params`` — the two cannot drift.

Vocab padding: embedding/logit dims are padded to a multiple of 128 so the
"model" mesh axis (16) always divides them; padded logit columns are masked
to -inf in the loss and sampling paths.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig
from repro.models.attention import attend_blockwise, attend_decode, attend_naive
from repro.models.layers import (
    apply_rope,
    gelu_mlp,
    layer_norm,
    rms_norm,
    rope_table,
    swiglu,
    trunc_normal,
)
from repro.models.moe import moe_ffn, shared_expert_ffn
from repro.models.ssm import (
    causal_conv,
    causal_conv_update,
    ssd_chunked,
    ssd_step,
)

VOCAB_PAD_MULTIPLE = 128

Params = dict[str, Any]


def padded_vocab(cfg: ModelConfig) -> int:
    return math.ceil(cfg.vocab_size / VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


# ---------------------------------------------------------------------------
# Param specs (single source of truth for init + counting)
# ---------------------------------------------------------------------------

def _ssm_dims(cfg: ModelConfig) -> dict[str, int]:
    d_inner = cfg.d_inner
    n = cfg.ssm_state
    heads = cfg.ssm_heads
    conv_dim = d_inner + 2 * n
    in_total = 2 * d_inner + 2 * n + heads      # z, x, B, C, dt
    return dict(d_inner=d_inner, n=n, heads=heads, conv_dim=conv_dim, in_total=in_total)


def layer_param_specs(cfg: ModelConfig) -> dict[str, tuple[tuple, int, str]]:
    """name -> (shape, fan_in, kind) for one layer. kind: normal|zeros|ones|special."""
    d = cfg.d_model
    specs: dict[str, tuple[tuple, int, str]] = {}

    if cfg.has_attention:
        hd = cfg.resolved_head_dim
        specs["ln1"] = ((d,), 0, "ones")
        if cfg.act == "gelu":
            specs["ln1_bias"] = ((d,), 0, "zeros")
        specs["wq"] = ((d, cfg.num_heads * hd), d, "normal")
        specs["wk"] = ((d, cfg.num_kv_heads * hd), d, "normal")
        specs["wv"] = ((d, cfg.num_kv_heads * hd), d, "normal")
        specs["wo"] = ((cfg.num_heads * hd, d), cfg.num_heads * hd, "normal")
        if cfg.qkv_bias:
            specs["bq"] = ((cfg.num_heads * hd,), 0, "zeros")
            specs["bk"] = ((cfg.num_kv_heads * hd,), 0, "zeros")
            specs["bv"] = ((cfg.num_kv_heads * hd,), 0, "zeros")

    if cfg.has_ssm:
        s = _ssm_dims(cfg)
        di, n, heads = s["d_inner"], s["n"], s["heads"]
        w = cfg.ssm_conv_width
        specs["ln_ssm"] = ((d,), 0, "ones")
        # The Mamba-2 in_proj/conv are split per component (z, x, B, C, dt)
        # so tensor-parallel sharding can put the head-structured pieces
        # (z, x, dt — sharded over SSD heads) and the shared-state pieces
        # (B, C — replicated) on different layouts. Depthwise conv and the
        # fused matmul split exactly; mathematically identical to the fused
        # checkpoint layout.
        specs["w_z"] = ((d, di), d, "normal")
        specs["w_x"] = ((d, di), d, "normal")
        specs["w_b"] = ((d, n), d, "normal")
        specs["w_c"] = ((d, n), d, "normal")
        specs["w_dt"] = ((d, heads), d, "normal")
        specs["conv_x_w"] = ((w, di), w, "normal")
        specs["conv_x_b"] = ((di,), 0, "zeros")
        specs["conv_b_w"] = ((w, n), w, "normal")
        specs["conv_b_b"] = ((n,), 0, "zeros")
        specs["conv_c_w"] = ((w, n), w, "normal")
        specs["conv_c_b"] = ((n,), 0, "zeros")
        specs["a_log"] = ((heads,), 0, "a_log")
        specs["d_skip"] = ((heads,), 0, "ones")
        specs["dt_bias"] = ((heads,), 0, "dt_bias")
        specs["ssm_norm"] = ((di,), 0, "ones")
        specs["ssm_out"] = ((di, d), di, "normal")

    if cfg.hybrid:
        specs["branch_attn_norm"] = ((d,), 0, "ones")
        specs["branch_ssm_norm"] = ((d,), 0, "ones")

    if cfg.is_moe:
        f = cfg.moe_d_ff or cfg.d_ff
        specs["ln2"] = ((d,), 0, "ones")
        specs["router"] = ((d, cfg.num_experts), d, "normal")
        specs["we_gate"] = ((cfg.num_experts, d, f), d, "normal")
        specs["we_up"] = ((cfg.num_experts, d, f), d, "normal")
        specs["we_down"] = ((cfg.num_experts, f, d), f, "normal")
        if cfg.num_shared_experts:
            fs = cfg.num_shared_experts * f
            specs["ws_gate"] = ((d, fs), d, "normal")
            specs["ws_up"] = ((d, fs), d, "normal")
            specs["ws_down"] = ((fs, d), fs, "normal")
    elif cfg.d_ff > 0:
        f = cfg.d_ff
        specs["ln2"] = ((d,), 0, "ones")
        if cfg.act == "gelu":
            specs["ln2_bias"] = ((d,), 0, "zeros")
            specs["w_up"] = ((d, f), d, "normal")
            specs["b_up"] = ((f,), 0, "zeros")
            specs["w_down"] = ((f, d), f, "normal")
            specs["b_down"] = ((d,), 0, "zeros")
        else:
            specs["w_gate"] = ((d, f), d, "normal")
            specs["w_up"] = ((d, f), d, "normal")
            specs["w_down"] = ((f, d), f, "normal")
    return specs


_FRONTEND_STUB_DIM = {"vision": 1024, "audio": 512}


def top_param_specs(cfg: ModelConfig) -> dict[str, tuple[tuple, int, str]]:
    d, vp = cfg.d_model, padded_vocab(cfg)
    specs = {"embed": ((vp, d), d, "normal"), "final_norm": ((d,), 0, "ones")}
    if cfg.act == "gelu":
        specs["final_norm_bias"] = ((d,), 0, "zeros")
    if not cfg.tie_embeddings:
        specs["lm_head"] = ((d, vp), d, "normal")
    if cfg.frontend:
        ds = _FRONTEND_STUB_DIM[cfg.frontend]
        specs["frontend_proj"] = ((ds, d), ds, "normal")
        specs["frontend_norm"] = ((d,), 0, "ones")
    return specs


def _init_one(key: Array, shape: tuple, fan_in: int, kind: str, dtype) -> Array:
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "zeros":
        return jnp.zeros(shape, dtype)
    if kind == "a_log":
        # Mamba-2 init: A ~ uniform[1, 16]  =>  store log A.
        u = jax.random.uniform(key, shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(jnp.float32)          # kept fp32 (state math)
    if kind == "dt_bias":
        # dt ~ loguniform[1e-3, 1e-1]; store softplus^{-1}(dt).
        u = jax.random.uniform(key, shape)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return jnp.log(jnp.expm1(dt)).astype(jnp.float32)
    return trunc_normal(key, shape, fan_in, dtype)


def init_params(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    """Initialize the full parameter pytree (blocks stacked on L)."""
    lspecs = layer_param_specs(cfg)
    tspecs = top_param_specs(cfg)
    keys = jax.random.split(key, len(lspecs) + len(tspecs))
    params: Params = {"blocks": {}}
    for (name, (shape, fan, kind)), k in zip(tspecs.items(), keys):
        params[name] = _init_one(k, shape, fan, kind, dtype)
    for (name, (shape, fan, kind)), k in zip(
        lspecs.items(), keys[len(tspecs):]
    ):
        stacked = jax.vmap(
            lambda kk: _init_one(kk, shape, fan, kind, dtype)
        )(jax.random.split(k, cfg.num_layers))
        params["blocks"][name] = stacked
    return params


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count implied by the specs (== leaves of init_params)."""
    total = sum(math.prod(s) for s, _, _ in top_param_specs(cfg).values())
    for name, (shape, _, _) in layer_param_specs(cfg).items():
        n = math.prod(shape)
        if active_only and name.startswith("we_"):
            n = n * cfg.top_k // cfg.num_experts
        total += n * cfg.num_layers
    return total


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attn_block(lp, h, cfg: ModelConfig, sin, cos, attn_impl: str, q_pos, k_pos):
    b, s, d = h.shape
    hd = cfg.resolved_head_dim
    norm = (
        layer_norm(h, lp["ln1"], lp["ln1_bias"], cfg.norm_eps)
        if cfg.act == "gelu"
        else rms_norm(h, lp["ln1"], cfg.norm_eps)
    )
    q = norm @ lp["wq"]
    k = norm @ lp["wk"]
    v = norm @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.causal:   # RoPE for decoder LMs; encoder stub uses none (abs emb in stub)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    attend = attend_blockwise if attn_impl == "blockwise" else attend_naive
    out = attend(
        q, k, v, q_pos, k_pos, causal=cfg.causal, window=cfg.sliding_window
    )
    return out.reshape(b, s, cfg.num_heads * hd) @ lp["wo"], (k, v)


def _ssm_block(lp, h, cfg: ModelConfig):
    """Mamba-2 layer body (training/prefill form).

    Returns (out, final_state, conv_tail) — conv_tail is the last (W-1) raw
    [x|B|C] projections, i.e. exactly the conv ring state decode_step carries.
    """
    b, s, d = h.shape
    dims = _ssm_dims(cfg)
    norm = rms_norm(h, lp["ln_ssm"], cfg.norm_eps)
    di, n, heads = dims["d_inner"], dims["n"], dims["heads"]
    z = norm @ lp["w_z"]
    x_raw = norm @ lp["w_x"]
    b_raw = norm @ lp["w_b"]
    c_raw = norm @ lp["w_c"]
    dt_raw = norm @ lp["w_dt"]                       # (B,S,H)
    conv_tail = jnp.concatenate(
        [x_raw[:, -(cfg.ssm_conv_width - 1):],
         b_raw[:, -(cfg.ssm_conv_width - 1):],
         c_raw[:, -(cfg.ssm_conv_width - 1):]], axis=-1,
    )
    x_c = jax.nn.silu(causal_conv(x_raw, lp["conv_x_w"], lp["conv_x_b"]))
    b_mat = jax.nn.silu(causal_conv(b_raw, lp["conv_b_w"], lp["conv_b_b"]))
    c_mat = jax.nn.silu(causal_conv(c_raw, lp["conv_c_w"], lp["conv_c_b"]))
    x_in = x_c.reshape(b, s, heads, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"])
    y, state = ssd_chunked(x_in, dt, a, b_mat, c_mat, min(cfg.ssm_chunk, s))
    y = y + lp["d_skip"][None, None, :, None].astype(y.dtype) * x_in
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), lp["ssm_norm"], cfg.norm_eps)
    return y @ lp["ssm_out"], state, conv_tail


def _mlp_block(lp, h, cfg: ModelConfig):
    """Dense or MoE FFN half-block. Returns (out, aux_loss)."""
    if cfg.is_moe:
        norm = rms_norm(h, lp["ln2"], cfg.norm_eps)
        out, aux = moe_ffn(
            norm, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"], cfg
        )
        if cfg.num_shared_experts:
            out = out + shared_expert_ffn(
                norm, lp["ws_gate"], lp["ws_up"], lp["ws_down"]
            )
        return out, aux
    if cfg.d_ff == 0:
        return jnp.zeros_like(h), jnp.float32(0.0)
    if cfg.act == "gelu":
        norm = layer_norm(h, lp["ln2"], lp["ln2_bias"], cfg.norm_eps)
        return gelu_mlp(norm, lp["w_up"], lp["b_up"], lp["w_down"], lp["b_down"]), jnp.float32(0.0)
    norm = rms_norm(h, lp["ln2"], cfg.norm_eps)
    return swiglu(norm, lp["w_gate"], lp["w_up"], lp["w_down"]), jnp.float32(0.0)


def _ring_gather(kv: Array, sc: int) -> Array:
    """(B, S, ...) -> (B, sc, ...) arranged so slot j holds the position p
    with p % sc == j (ring-buffer layout expected by decode_step).

    Slots with no matching position (sc > S headroom for generation) hold
    clamped garbage — decode_step masks them out via ``_ring_positions``.
    """
    s = kv.shape[1]
    j = jnp.arange(sc)
    p = (s - 1) - jnp.mod((s - 1) - j, sc)
    return jnp.take(kv, jnp.clip(p, 0, s - 1), axis=1)


def make_block_fn(
    cfg: ModelConfig, sin, cos, attn_impl: str, q_pos, k_pos,
    collect_cache: bool = False, cache_dtype=jnp.bfloat16,
    cache_capacity: int | None = None,
):
    """One transformer block as a scan body: (h, lp) -> (h', ys).

    ys is the aux loss, plus (when ``collect_cache``) this layer's decode
    cache contribution — stacked by the scan into the (L, ...) cache arrays.
    """

    def block(h, lp):
        aux = jnp.float32(0.0)
        cache_out = {}
        if cfg.hybrid:
            attn_out, kv = _attn_block(lp, h, cfg, sin, cos, attn_impl, q_pos, k_pos)
            ssm_out, state, conv_tail = _ssm_block(lp, h, cfg)
            mixed = 0.5 * (
                rms_norm(attn_out, lp["branch_attn_norm"], cfg.norm_eps)
                + rms_norm(ssm_out, lp["branch_ssm_norm"], cfg.norm_eps)
            )
            h = h + mixed
        elif cfg.has_attention:
            attn_out, kv = _attn_block(lp, h, cfg, sin, cos, attn_impl, q_pos, k_pos)
            h = h + attn_out
        elif cfg.has_ssm:
            ssm_out, state, conv_tail = _ssm_block(lp, h, cfg)
            h = h + ssm_out
        if collect_cache:
            if cfg.has_attention:
                sc = cache_len_for(cfg, cache_capacity or h.shape[1])
                cache_out["k"] = _ring_gather(kv[0], sc).astype(cache_dtype)
                cache_out["v"] = _ring_gather(kv[1], sc).astype(cache_dtype)
            if cfg.has_ssm:
                cache_out["ssm_state"] = state
                cache_out["conv_state"] = conv_tail.astype(cache_dtype)
        if cfg.d_ff > 0 or cfg.is_moe:
            mlp_out, aux = _mlp_block(lp, h, cfg)
            h = h + mlp_out
        return h, (aux, cache_out)

    return block


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

_REMAT_POLICIES = {
    "none": None,
    "dots": "dots_saveable",
    "full": "nothing_saveable",
}


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Array | None,
    prefix_embeds: Array | None = None,
    attn_impl: str = "blockwise",
    remat: str = "none",
    collect_cache: bool = False,
    cache_dtype=jnp.bfloat16,
    cache_len: int | None = None,
    unroll_layers: bool = False,
) -> tuple[Array, Array] | tuple[Array, Array, Params]:
    """Full-sequence forward. Returns (logits fp32 (B,S,Vp), aux_loss)
    — plus the assembled decode cache when ``collect_cache`` (prefill).

    ``prefix_embeds``: (B, S_pre, stub_dim) precomputed modality embeddings
    (vision patches / audio frames) — the frontend STUB mandated by the
    assignment. For VLM they are prepended to the token embeddings; for the
    audio encoder they *are* the input (``tokens`` may be None).
    """
    h = None if tokens is None else jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend and prefix_embeds is not None:
        dtype = params["final_norm"].dtype
        pre = prefix_embeds.astype(dtype) @ params["frontend_proj"]
        pre = rms_norm(pre, params["frontend_norm"], cfg.norm_eps)
        h = pre if h is None else jnp.concatenate([pre, h], axis=1)
    s = h.shape[1]
    positions = jnp.arange(s)
    if cfg.has_attention:
        sin, cos = rope_table(positions, cfg.resolved_head_dim, cfg.rope_theta)
    else:
        sin = cos = jnp.zeros((s, 1), jnp.float32)
    block = make_block_fn(
        cfg, sin, cos, attn_impl, positions, positions,
        collect_cache=collect_cache, cache_dtype=cache_dtype,
        cache_capacity=cache_len,
    )
    policy = _REMAT_POLICIES[remat]
    if policy is not None:
        block = jax.checkpoint(
            block, policy=getattr(jax.checkpoint_policies, policy)
        )
    elif remat == "full_recompute":
        block = jax.checkpoint(block)
    # unroll_layers: used by the dry-run's cost-extraction compiles — XLA's
    # HloCostAnalysis counts while-loop bodies ONCE regardless of trip count,
    # so exact FLOP/byte counts require a loop-free graph (DESIGN.md §7).
    h, (aux, layer_caches) = jax.lax.scan(
        block, h, params["blocks"], unroll=cfg.num_layers if unroll_layers else 1
    )
    h = (
        layer_norm(h, params["final_norm"], params["final_norm_bias"], cfg.norm_eps)
        if cfg.act == "gelu"
        else rms_norm(h, params["final_norm"], cfg.norm_eps)
    )
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
    if collect_cache:
        cache = dict(layer_caches)
        cache["pos"] = jnp.full((logits.shape[0],), s, jnp.int32)
        return logits, jnp.sum(aux), cache
    return logits, jnp.sum(aux)


def prefill_step(
    params: Params,
    cfg: ModelConfig,
    tokens: Array | None,
    prefix_embeds: Array | None = None,
    attn_impl: str = "blockwise",
    cache_dtype=jnp.bfloat16,
    cache_len: int | None = None,
    unroll_layers: bool = False,
) -> tuple[Array, Params]:
    """Serving prefill: run the prompt, return (last-token logits, cache).

    ``cache_len``: total KV capacity (prompt + generation headroom);
    defaults to the prompt length (ring eviction starts immediately).
    """
    logits, _, cache = forward(
        params, cfg, tokens, prefix_embeds=prefix_embeds,
        attn_impl=attn_impl, collect_cache=True, cache_dtype=cache_dtype,
        cache_len=cache_len, unroll_layers=unroll_layers,
    )
    return logits[:, -1:, :], cache


def loss_fn(
    params: Params,
    cfg: ModelConfig,
    batch: dict[str, Array],
    attn_impl: str = "blockwise",
    remat: str = "none",
    aux_coef: float = 0.01,
    z_coef: float = 1e-4,
    unroll_layers: bool = False,
) -> tuple[Array, dict[str, Array]]:
    """Masked next-token cross-entropy + router aux + z-loss."""
    logits, aux = forward(
        params, cfg, batch.get("tokens"),
        prefix_embeds=batch.get("prefix_embeds"),
        attn_impl=attn_impl, remat=remat, unroll_layers=unroll_layers,
    )
    labels = batch["labels"]
    mask = batch["loss_mask"].astype(jnp.float32)
    if cfg.frontend == "vision" and batch.get("prefix_embeds") is not None:
        pre = batch["prefix_embeds"].shape[1]
        logits = logits[:, pre:, :]
    vp = logits.shape[-1]
    # Mask padded vocab columns.
    col_ok = jnp.arange(vp) < cfg.vocab_size
    logits = jnp.where(col_ok[None, None, :], logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce_mean = ce.sum() / denom
    z_loss = z_coef * ((logz * mask) ** 2).sum() / denom
    total = ce_mean + z_loss + aux_coef * aux
    return total, {"ce": ce_mean, "z_loss": z_loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window > 0 and cfg.has_attention:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(
    cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16,
    prefilled: int = 0,
) -> Params:
    """Decode-state pytree. ``prefilled`` marks how many slots are valid."""
    cache: Params = {"pos": jnp.full((batch,), prefilled, jnp.int32)}
    sc = cache_len_for(cfg, seq_len)
    hd = cfg.resolved_head_dim
    if cfg.has_attention:
        kv_shape = (cfg.num_layers, batch, sc, cfg.num_kv_heads, hd)
        cache["k"] = jnp.zeros(kv_shape, dtype)
        cache["v"] = jnp.zeros(kv_shape, dtype)
    if cfg.has_ssm:
        dims = _ssm_dims(cfg)
        cache["ssm_state"] = jnp.zeros(
            (cfg.num_layers, batch, dims["heads"], cfg.ssm_head_dim, dims["n"]),
            jnp.float32,
        )
        cache["conv_state"] = jnp.zeros(
            (cfg.num_layers, batch, cfg.ssm_conv_width - 1, dims["conv_dim"]), dtype
        )
    return cache


def _ring_positions(pos: Array, sc: int) -> Array:
    """(B, sc) absolute position held by each ring slot, −1 if unwritten."""
    j = jnp.arange(sc)
    last = pos[:, None] - 1
    p = last - jnp.mod(last - j[None, :], sc)
    return jnp.where(p >= 0, p, -1)


def decode_step(
    params: Params, cfg: ModelConfig, cache: Params, tokens: Array,
    unroll_layers: bool = False, uniform_pos: bool = True,
    kv_shard: str = "heads",
) -> tuple[Array, Params]:
    """One serving step.

    ``uniform_pos``: the assigned decode shapes have every sequence at the
    same cache length, so the ring-slot write can be a single
    ``dynamic_update_slice`` on the sequence dim — SPMD-partitionable on a
    batch- or sequence-sharded cache. The per-example scatter path
    (``uniform_pos=False``) supports ragged continuous batching but forces
    XLA to all-gather the cache over the model axis (measured: +43 GB/step
    on granite-3-2b decode_32k — EXPERIMENTS.md §Perf A1).
    """
    """One serving step: (B, 1) new tokens -> (B, 1, Vp) fp32 logits + cache."""
    b = tokens.shape[0]
    pos = cache["pos"]                                     # (B,)
    h = jnp.take(params["embed"], tokens, axis=0)          # (B,1,D)
    hd = cfg.resolved_head_dim
    if cfg.has_attention:
        sin, cos = rope_table(pos[:, None], hd, cfg.rope_theta)   # (B,1,half)
    else:
        sin = cos = None

    def block(h, xs):
        lp, layer_cache = xs
        new_cache = dict(layer_cache)
        if cfg.hybrid or cfg.has_attention:
            if cfg.has_attention:
                sc = layer_cache["k"].shape[1]
                norm = (
                    layer_norm(h, lp["ln1"], lp["ln1_bias"], cfg.norm_eps)
                    if cfg.act == "gelu" else rms_norm(h, lp["ln1"], cfg.norm_eps)
                )
                q = norm @ lp["wq"]
                k = norm @ lp["wk"]
                v = norm @ lp["wv"]
                if cfg.qkv_bias:
                    q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
                q = q.reshape(b, 1, cfg.num_heads, hd)
                k = k.reshape(b, 1, cfg.num_kv_heads, hd)
                v = v.reshape(b, 1, cfg.num_kv_heads, hd)
                q = apply_rope(q, sin, cos)
                k = apply_rope(k, sin, cos)
                if uniform_pos:
                    slot0 = jnp.mod(pos[0], sc)
                    k_cache = jax.lax.dynamic_update_slice(
                        layer_cache["k"], k.astype(layer_cache["k"].dtype),
                        (0, slot0, 0, 0),
                    )
                    v_cache = jax.lax.dynamic_update_slice(
                        layer_cache["v"], v.astype(layer_cache["v"].dtype),
                        (0, slot0, 0, 0),
                    )
                else:
                    slot = jnp.mod(pos, sc)                 # (B,)
                    bi = jnp.arange(b)
                    k_cache = layer_cache["k"].at[bi, slot].set(k[:, 0].astype(layer_cache["k"].dtype))
                    v_cache = layer_cache["v"].at[bi, slot].set(v[:, 0].astype(layer_cache["v"].dtype))
                cache_pos = _ring_positions(pos + 1, sc)
                attn_out = attend_decode(
                    q, k_cache, v_cache, pos, cache_pos,
                    window=cfg.sliding_window,
                    seq_sharded=(kv_shard == "seq"),
                )
                attn_out = attn_out.reshape(b, 1, cfg.num_heads * hd) @ lp["wo"]
                new_cache["k"], new_cache["v"] = k_cache, v_cache
        if cfg.hybrid or cfg.has_ssm:
            if cfg.has_ssm:
                dims = _ssm_dims(cfg)
                di, n, heads = dims["d_inner"], dims["n"], dims["heads"]
                norm_s = rms_norm(h, lp["ln_ssm"], cfg.norm_eps)
                ns = norm_s[:, 0]                          # (B, D)
                z = ns @ lp["w_z"]
                x_raw = ns @ lp["w_x"]
                b_raw = ns @ lp["w_b"]
                c_raw = ns @ lp["w_c"]
                dt_raw = ns @ lp["w_dt"]
                xbc = jnp.concatenate([x_raw, b_raw, c_raw], axis=-1)
                conv_w = jnp.concatenate(
                    [lp["conv_x_w"], lp["conv_b_w"], lp["conv_c_w"]], axis=-1
                )
                conv_b = jnp.concatenate(
                    [lp["conv_x_b"], lp["conv_b_b"], lp["conv_c_b"]], axis=-1
                )
                conv_out, conv_state = causal_conv_update(
                    layer_cache["conv_state"], xbc, conv_w, conv_b
                )
                xbc = jax.nn.silu(conv_out)
                x_in = xbc[:, :di].reshape(b, heads, cfg.ssm_head_dim)
                b_vec = xbc[:, di : di + n]
                c_vec = xbc[:, di + n :]
                dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
                a = -jnp.exp(lp["a_log"])
                y, state = ssd_step(x_in, dt, a, b_vec, c_vec, layer_cache["ssm_state"])
                y = y + lp["d_skip"][None, :, None].astype(y.dtype) * x_in
                y = y.reshape(b, 1, di)
                y = rms_norm(y * jax.nn.silu(z[:, None, :]), lp["ssm_norm"], cfg.norm_eps)
                ssm_out = y @ lp["ssm_out"]
                new_cache["ssm_state"], new_cache["conv_state"] = state, conv_state
        if cfg.hybrid:
            h = h + 0.5 * (
                rms_norm(attn_out, lp["branch_attn_norm"], cfg.norm_eps)
                + rms_norm(ssm_out, lp["branch_ssm_norm"], cfg.norm_eps)
            )
        elif cfg.has_attention:
            h = h + attn_out
        else:
            h = h + ssm_out
        if cfg.d_ff > 0 or cfg.is_moe:
            mlp_out, _ = _mlp_block(lp, h, cfg)
            h = h + mlp_out
        return h, new_cache

    layer_caches = {
        k: v for k, v in cache.items() if k not in ("pos",)
    }
    h, new_layer_caches = jax.lax.scan(
        block, h, (params["blocks"], layer_caches),
        unroll=cfg.num_layers if unroll_layers else 1,
    )
    h = (
        layer_norm(h, params["final_norm"], params["final_norm_bias"], cfg.norm_eps)
        if cfg.act == "gelu"
        else rms_norm(h, params["final_norm"], cfg.norm_eps)
    )
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
    new_cache = dict(new_layer_caches)
    new_cache["pos"] = pos + 1
    return logits, new_cache
